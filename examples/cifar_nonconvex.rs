//! Non-convex workload driver: the paper's Fig-1-bottom (a 4-hidden-layer
//! 92K-parameter network on synthetic CIFAR-10 at C_comm/C_comp = 1000).
//!
//! Focuses on the period-length trade-off (fig1g): τ too small ⇒ paying the
//! communication bottleneck every iteration; τ too large ⇒ local drift.
//! The paper finds the interior optimum around τ=10.
//!
//! ```bash
//! cargo run --release --example cifar_nonconvex [--fast]
//! ```

use fedpaq::config::EngineKind;
use fedpaq::figures::{figure, Runner};

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    anyhow::ensure!(
        std::path::Path::new("artifacts/manifest.json").exists(),
        "NN models need the PJRT artifacts: run `make artifacts` first"
    );
    let mut runner = Runner::new(EngineKind::Pjrt, "artifacts");
    if fast {
        runner.t_override = Some(20);
    }
    let out = std::path::Path::new("results");

    // τ sweep + the three-way benchmark comparison.
    for id in ["fig1g", "fig1h"] {
        let spec = figure(id).unwrap();
        println!("=== {} — {}", spec.id, spec.title);
        let fig = runner.run_and_save(&spec, out)?;
        if id == "fig1g" {
            // Rank τ by final (time, loss): the paper's trade-off.
            println!("tau trade-off (end of T iterations):");
            let mut rows: Vec<_> = fig
                .curves
                .iter()
                .map(|c| (c.label.clone(), c.total_time(), c.final_loss().unwrap_or(f64::NAN)))
                .collect();
            rows.sort_by(|a, b| a.1.total_cmp(&b.1));
            for (label, t, loss) in rows {
                println!("  {label:<10} total-time {t:>10.0}  final-loss {loss:.4}");
            }
        }
        println!();
    }
    Ok(())
}
