//! END-TO-END DRIVER: federated training of a transformer LM with FedPAQ.
//!
//! Proves all three layers compose on a real (small) workload:
//!   L1 Pallas dense kernels → L2 JAX transformer fwd/bwd (AOT HLO) →
//!   L3 rust coordinator running Algorithm 1 with QSGD uploads.
//!
//! Trains a 2-layer, d=64 decoder-only LM (110K params — scaled to this
//! single-CPU-core testbed from the paper-prompted 100M; see DESIGN.md §4)
//! on seeded Markov-chain token sequences for a few hundred rounds, and
//! logs the loss curve to results/e2e_transformer.csv. Next-token CE must
//! fall from ~ln(64) ≈ 4.16 toward the chain's conditional entropy.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_transformer [--rounds N]
//! ```

use fedpaq::config::{EngineKind, ExperimentConfig};
use fedpaq::data::DatasetKind;
use fedpaq::figures::Runner;
use fedpaq::metrics::FigureData;
use fedpaq::opt::LrSchedule;
use fedpaq::quant::CodecSpec;

fn main() -> anyhow::Result<()> {
    anyhow::ensure!(
        std::path::Path::new("artifacts/manifest.json").exists(),
        "run `make artifacts` first (the transformer is PJRT-only)"
    );
    let args: Vec<String> = std::env::args().collect();
    let rounds = args
        .iter()
        .position(|a| a == "--rounds")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(200);
    let tau = 4;

    let cfg = ExperimentConfig {
        name: "e2e transformer FedPAQ (s=4, r=5/20, tau=4)".into(),
        model: "transformer".into(),
        dataset: DatasetKind::LmMarkov,
        n_nodes: 20,
        per_node: 64,
        r: 5,
        tau,
        t_total: rounds * tau,
        codec: CodecSpec::qsgd(4),
        lr: LrSchedule::Const { eta: 0.05 },
        ratio: 1000.0,
        seed: 7,
        eval_every: 10,
        engine: EngineKind::Pjrt,
        partition: fedpaq::data::PartitionKind::Iid,
        async_rounds: false,
        buffer_size: 0,
        max_staleness: 8,
        staleness_rule: Default::default(),
        agg_shards: 1,
        down_codec: None,
    }
    .validated()?;

    println!(
        "federated transformer: {} rounds x (r={} nodes x tau={} steps), T={}",
        cfg.rounds(),
        cfg.r,
        cfg.tau,
        cfg.t_total
    );
    let t0 = std::time::Instant::now();
    let mut runner = Runner::new(EngineKind::Pjrt, "artifacts");
    let res = runner.run_config(cfg.clone(), fedpaq::ops::RunControl::default())?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\nround  iters  virtual-time  loss");
    for p in &res.curve.points {
        println!("{:>5}  {:>5}  {:>12.1}  {:.4}", p.round, p.iterations, p.time, p.loss);
    }
    let first = res.curve.points.first().unwrap().loss;
    let last = res.curve.points.last().unwrap().loss;
    println!("\nnext-token CE: {first:.4} -> {last:.4} (ln V = {:.4})", (64f64).ln());
    println!("wall-clock: {wall:.1}s for {} PJRT-backed local steps", cfg.t_total * cfg.r);
    println!(
        "upload total: {:.2} MBit (vs {:.2} MBit unquantized)",
        res.total_bits as f64 / 1e6,
        (res.rounds.len() * cfg.r * 32 * res.params.len()) as f64 / 1e6
    );

    let mut fig = FigureData::new("e2e_transformer", &cfg.name);
    fig.curves.push(res.curve);
    let path = fig.write_csv(std::path::Path::new("results"))?;
    println!("curve written to {}", path.display());

    anyhow::ensure!(last < first * 0.75, "loss did not drop enough: {first} -> {last}");
    println!("e2e OK: all three layers compose and the model learns");
    Ok(())
}
