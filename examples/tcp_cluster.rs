//! Real distributed FedPAQ over TCP on localhost: one leader + W worker
//! *processes*, each running its own PJRT engine and regenerating only its
//! shard — nothing but quantized updates crosses the sockets.
//!
//! The same binary re-execs itself in worker role:
//!
//! ```bash
//! cargo run --release --example tcp_cluster            # 2 workers
//! cargo run --release --example tcp_cluster -- 4       # 4 workers
//! ```
//!
//! Verifies at the end that the distributed run reproduces the in-process
//! simulation's final parameters (same seeds ⇒ same uploads).

use fedpaq::config::{EngineKind, ExperimentConfig};
use fedpaq::figures::Runner;
use std::path::Path;
use std::process::{Child, Command};

fn cluster_config() -> ExperimentConfig {
    // Default to the pure-rust engine: the cluster demo is about the
    // *network* path (the PJRT engine is exercised by every other example
    // and by integration_pjrt.rs; running several PJRT CPU clients as
    // sibling subprocesses of one parent is flaky on this image). Pass
    // --pjrt to force the AOT engine.
    let engine = if std::env::args().any(|a| a == "--pjrt")
        && Path::new("artifacts/manifest.json").exists()
    {
        EngineKind::Pjrt
    } else {
        EngineKind::Rust
    };
    let mut cfg = ExperimentConfig::fig1_logreg_base()
        .with_name("tcp-cluster FedPAQ")
        .with_engine(engine);
    cfg.t_total = 40; // 8 rounds at tau=5: quick but non-trivial
    cfg.r = 10;
    cfg.n_nodes = 20;
    cfg.per_node = 500; // keep 10_000 samples for the logreg eval slab
    cfg
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    // Worker role: `tcp_cluster --worker <addr>`.
    if args.get(1).map(String::as_str) == Some("--worker") {
        let addr = args.get(2).cloned().unwrap_or("127.0.0.1:7071".into());
        // The parent spawns workers before its listener is up: keep
        // re-dialing through the shared retry helper.
        return fedpaq::net::run_worker_retrying(
            &addr,
            Path::new("artifacts"),
            Default::default(),
            std::time::Duration::from_secs(10),
        );
    }

    let n_workers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);
    let addr = "127.0.0.1:7071";
    let exe = std::env::current_exe()?;
    println!("spawning {n_workers} worker processes ...");
    let mut children: Vec<Child> = (0..n_workers)
        .map(|_| {
            Command::new(&exe)
                .arg("--worker")
                .arg(addr)
                .spawn()
                .expect("spawn worker")
        })
        .collect();

    let cfg = cluster_config();
    let dist = {
        let mut engine = fedpaq::net::worker::build_engine(&cfg, Path::new("artifacts"))?;
        fedpaq::net::run_leader(
            cfg.clone(),
            addr,
            n_workers,
            engine.as_mut(),
            Path::new("artifacts"),
            &fedpaq::ops::RunControl::default(),
        )?
    };
    for c in children.iter_mut() {
        let _ = c.wait();
    }

    println!("\ndistributed curve (wall-clock seconds):");
    for p in &dist.curve.points {
        println!("  k={:<3} wall={:<8.3}s loss={:.6}", p.round, p.time, p.loss);
    }

    // Cross-check against the in-process simulation.
    println!("\nreplaying in-process for parity check ...");
    let mut runner = Runner::new(cfg.engine.clone(), "artifacts");
    let sim = runner.run_config(cfg, fedpaq::ops::RunControl::default())?;
    let max_diff = dist
        .params
        .iter()
        .zip(&sim.params)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("max |dist - sim| over params: {max_diff:e}");
    anyhow::ensure!(max_diff < 1e-4, "distributed run diverged from simulation");
    println!("tcp_cluster OK: distributed == simulated");
    Ok(())
}
