//! Quickstart: train a federated logistic-regression model with FedPAQ.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the whole public API in ~30 lines: build a config, load
//! the PJRT engine (falling back to the pure-rust engine when artifacts
//! are missing), run Algorithm 1, inspect the loss-vs-time curve.

use fedpaq::config::{EngineKind, ExperimentConfig};
use fedpaq::figures::Runner;
use fedpaq::quant::Quantizer;

fn main() -> anyhow::Result<()> {
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    let engine = if have_artifacts { EngineKind::Pjrt } else { EngineKind::Rust };
    println!("engine: {engine:?} (artifacts present: {have_artifacts})");

    // FedPAQ on the paper's Fig-1 logreg workload: n=50 nodes, r=25
    // participate per round, τ=5 local steps, 1-level QSGD quantization.
    let cfg = ExperimentConfig::fig1_logreg_base()
        .with_name("quickstart FedPAQ (s=1, r=25, tau=5)")
        .with_quantizer(Quantizer::qsgd(1))
        .with_engine(engine.clone());

    let mut runner = Runner::new(engine, "artifacts");
    let result = runner.run_config(cfg)?;

    println!("\nround  iters  virtual-time  uploaded-bits  train-loss");
    for p in &result.curve.points {
        println!(
            "{:>5}  {:>5}  {:>12.2}  {:>13}  {:.6}",
            p.round, p.iterations, p.time, p.bits_up, p.loss
        );
    }
    let first = result.curve.points.first().unwrap().loss;
    let last = result.curve.points.last().unwrap().loss;
    println!("\nloss {first:.4} -> {last:.4} over {} rounds", result.rounds.len());
    println!(
        "total upload: {:.2} MBit ({:.0}x less than unquantized FedAvg)",
        result.total_bits as f64 / 1e6,
        (result.curve.points.last().unwrap().round as u64
            * 25
            * 32
            * result.params.len() as u64) as f64
            / result.total_bits as f64
    );
    Ok(())
}
