//! Quickstart: train a federated logistic-regression model with FedPAQ.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the composable round-pipeline API in ~40 lines: build a
//! config, load an engine (falling back to the pure-rust engine when PJRT
//! artifacts are missing), assemble the server with `ServerBuilder`, run
//! Algorithm 1, then swap the upload codec for top-k sparsification
//! without touching anything else.

use fedpaq::config::{EngineKind, ExperimentConfig};
use fedpaq::coordinator::ServerBuilder;
use fedpaq::quant::{CodecSpec, TopKCodec};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    let engine_kind = if have_artifacts { EngineKind::Pjrt } else { EngineKind::Rust };
    println!("engine: {engine_kind:?} (artifacts present: {have_artifacts})");

    // FedPAQ on the paper's Fig-1 logreg workload: n=50 nodes, r=25
    // participate per round, τ=5 local steps, 1-level QSGD uploads.
    let cfg = ExperimentConfig::fig1_logreg_base()
        .with_name("quickstart FedPAQ (s=1, r=25, tau=5)")
        .with_codec(CodecSpec::qsgd(1))
        .with_engine(engine_kind);

    let mut engine = fedpaq::net::worker::build_engine(&cfg, Path::new("artifacts"))?;
    let result = ServerBuilder::new(cfg.clone())
        .engine(engine.as_mut())
        .build()?
        .run()?;

    println!("\nround  iters  virtual-time  uploaded-bits  train-loss");
    for p in &result.curve.points {
        println!(
            "{:>5}  {:>5}  {:>12.2}  {:>13}  {:.6}",
            p.round, p.iterations, p.time, p.bits_up, p.loss
        );
    }
    let first = result.curve.points.first().unwrap().loss;
    let last = result.curve.points.last().unwrap().loss;
    println!("\nloss {first:.4} -> {last:.4} over {} rounds", result.rounds.len());
    println!(
        "total upload: {:.2} MBit ({:.0}x less than unquantized FedAvg)",
        result.total_bits as f64 / 1e6,
        (result.curve.points.last().unwrap().round as u64
            * 25
            * 32
            * result.params.len() as u64) as f64
            / result.total_bits as f64
    );

    // The codec is a pluggable seam: rerun the identical experiment with
    // top-k sparsification (keep the 10% largest-magnitude coordinates)
    // just by overriding the codec on the builder.
    let topk = ServerBuilder::new(cfg.with_name("quickstart top-k (10%)"))
        .engine(engine.as_mut())
        .codec(TopKCodec::new(100))
        .build()?
        .run()?;
    let t_last = topk.curve.points.last().unwrap().loss;
    println!(
        "\ntop-k 10%: loss {first:.4} -> {t_last:.4}, {:.2} MBit uploaded",
        topk.total_bits as f64 / 1e6
    );
    Ok(())
}
