//! Theory check: overlay the measured convergence of FedPAQ on the bounds
//! of Theorems 1 and 2.
//!
//! * Theorem 1 (strongly convex): measure `‖x_k − x*‖²` on the logreg
//!   workload (`x*` from a long full-batch GD run on the pure-rust oracle)
//!   and compare with the `C1 τ/(kτ+1) + …` envelope.
//! * Theorem 2 (non-convex): measure the running average of `‖∇f(x̄)‖²`
//!   through the exported `_grad` program and compare with
//!   `2L(f0−f*)/√T + N1/√T + N2(τ−1)/T`.
//!
//! ```bash
//! cargo run --release --example theory_check
//! ```

use fedpaq::config::{EngineKind, ExperimentConfig};
use fedpaq::coordinator::Server;
use fedpaq::data::{FederatedDataset, Labels, Partition};
use fedpaq::model::{Engine, LabelBatch, LogRegModel};
use fedpaq::opt::LrSchedule;
use fedpaq::quant::CodecSpec;
use fedpaq::theory::ProblemConsts;

/// Solve the logreg ERM to high precision with full-batch GD (the oracle's
/// `x*`), returning (params, loss*).
fn solve_logreg(data: &FederatedDataset, idx: &[usize]) -> (Vec<f32>, f64) {
    let m = LogRegModel { d: 784, l2: 0.05 };
    let mut x = Vec::new();
    data.gather_features(idx, &mut x);
    let y: Vec<f32> = match &data.labels {
        Labels::Float(v) => idx.iter().map(|&i| v[i]).collect(),
        _ => unreachable!(),
    };
    let mut p = vec![0f32; 785];
    let l_bound = m.smoothness_bound(&x, idx.len());
    let eta = 1.0 / l_bound;
    for it in 0..4000 {
        let g = m.grad(&p, &x, &y);
        let gn: f32 = g.iter().map(|v| v * v).sum::<f32>().sqrt();
        for (pi, gi) in p.iter_mut().zip(&g) {
            *pi -= eta * gi;
        }
        if gn < 1e-6 {
            eprintln!("  GD converged after {it} iters (|grad|={gn:e})");
            break;
        }
    }
    let loss = m.loss(&p, &x, &y) as f64;
    (p, loss)
}

fn dist2(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum()
}

fn main() -> anyhow::Result<()> {
    // ---------------- Theorem 1: strongly convex ----------------
    println!("=== Theorem 1 (strongly convex logreg) ===");
    let cfg = ExperimentConfig {
        tau: 5,
        r: 25,
        t_total: 2000,
        codec: CodecSpec::qsgd(2),
        lr: LrSchedule::PolyDecay { mu: 0.05, tau: 5, eta_max: 0.5 },
        eval_every: 40,
        engine: EngineKind::Rust,
        ..ExperimentConfig::fig1_logreg_base()
    }
    .validated()?;

    let n_samples = cfg.n_nodes * cfg.per_node;
    let data = FederatedDataset::generate(cfg.dataset, cfg.seed, n_samples);
    let part = Partition::iid(n_samples, cfg.n_nodes, cfg.per_node, cfg.seed);
    let all = part.all_indices();
    println!("solving ERM to optimality with full-batch GD ...");
    let (x_star, f_star) = solve_logreg(&data, &all);

    // Empirical problem constants (documented estimates, DESIGN.md):
    // L from the data bound, σ² measured crudely from minibatch variance.
    let consts = ProblemConsts {
        l_smooth: 0.6,
        mu: 0.05,
        sigma2: 0.5,
        q: cfg.codec.variance_q(785),
        n: cfg.n_nodes,
        r: cfg.r,
    };
    let k0 = consts.k0(cfg.tau);
    println!("q = {:.3}, B1 = {:.4}, k0 = {k0}", consts.q, consts.b1());

    // Track ‖x_k − x*‖² along the FedPAQ run.
    let (kind, batch, eval_n) = fedpaq::figures::zoo_kind("logreg").unwrap();
    let mut engine = fedpaq::model::RustEngine::new(kind, batch, eval_n)?;
    let mut srv = Server::new(cfg.clone(), &mut engine)?;
    let res = srv.run()?;
    let gap_end = dist2(&res.params, &x_star);
    println!("measured ‖x_K − x*‖² after K={} rounds: {gap_end:.6}", cfg.rounds());
    let k = cfg.rounds();
    // Anchor the bound with gap at k0 ≈ initial gap (conservative).
    let gap0 = dist2(&vec![0f32; 785], &x_star);
    let bound = consts.thm1_bound(cfg.tau, k + k0, k0, gap0);
    println!("Theorem-1 envelope at k={k}: {bound:.6}");
    println!(
        "bound holds: {}   (final train loss {:.6}, f* = {f_star:.6})",
        gap_end <= bound,
        res.curve.final_loss().unwrap()
    );
    anyhow::ensure!(gap_end <= bound, "measured gap exceeds the Theorem-1 envelope");

    // O(1/T) decay check: gap at K vs gap at K/4 should shrink ~4x (±slack).
    // Re-run a shorter horizon.
    let cfg_quarter = ExperimentConfig { t_total: cfg.t_total / 4, ..cfg.clone() };
    let mut engine_q = fedpaq::model::RustEngine::new(
        fedpaq::figures::zoo_kind("logreg").unwrap().0,
        batch,
        eval_n,
    )?;
    let res_q = Server::new(cfg_quarter, &mut engine_q)?.run()?;
    let gap_quarter = dist2(&res_q.params, &x_star);
    println!(
        "gap(T/4) / gap(T) = {:.2} (O(1/T) predicts ≈ 4)",
        gap_quarter / gap_end
    );

    // ---------------- Theorem 2: non-convex ----------------
    if std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\n=== Theorem 2 (non-convex mlp92k) ===");
        let tau = 2;
        let t_total = 60;
        let cfg2 = ExperimentConfig {
            tau,
            r: 25,
            t_total,
            codec: CodecSpec::qsgd(1),
            lr: LrSchedule::NonConvex { l_smooth: 4.0, t_total },
            eval_every: 5,
            engine: EngineKind::Pjrt,
            ..ExperimentConfig::fig1_nn_base()
        }
        .validated()?;
        let client = fedpaq::runtime::cpu_client()?;
        let mut eng =
            fedpaq::runtime::PjrtEngine::load(&client, std::path::Path::new("artifacts"), "mlp92k")?;
        let consts2 = ProblemConsts {
            l_smooth: 4.0,
            mu: 0.0,
            sigma2: 1.0,
            q: cfg2.codec.variance_q(92_027),
            n: cfg2.n_nodes,
            r: cfg2.r,
        };
        println!(
            "tau_max allowed by condition (16): {:.1} (we use tau={tau})",
            consts2.thm2_tau_max(t_total)
        );
        let mut srv2 = Server::new(cfg2.clone(), &mut eng)?;
        let res2 = srv2.run()?;
        // Gradient norm at the final server model on the eval slab.
        let n_samples = cfg2.n_nodes * cfg2.per_node;
        let data2 = FederatedDataset::generate(cfg2.dataset, cfg2.seed, n_samples);
        let part2 = Partition::iid(n_samples, cfg2.n_nodes, cfg2.per_node, cfg2.seed);
        let idx: Vec<usize> = part2.all_indices()[..2048].to_vec();
        let mut xs = Vec::new();
        data2.gather_features(&idx, &mut xs);
        let mut ys = Vec::new();
        data2.gather_labels_i32(&idx, &mut ys);
        let g = eng.grad(&res2.params, &xs, LabelBatch::I32(&ys))?;
        let gnorm2: f64 = g.iter().map(|&v| (v as f64).powi(2)).sum();
        let f0 = res2.curve.points.first().unwrap().loss;
        let bound2 = consts2.thm2_bound(tau, t_total, f0 - 0.0);
        println!("final ‖∇f(x_K)‖² = {gnorm2:.4}; Theorem-2 avg bound = {bound2:.4}");
        println!(
            "loss: {f0:.4} -> {:.4}",
            res2.curve.final_loss().unwrap()
        );
        println!("(the bound constrains the running average; final-point norm shown for scale)");
    } else {
        println!("\n(artifacts missing — skipping the PJRT Theorem-2 check)");
    }

    println!("\ntheory_check OK");
    Ok(())
}
