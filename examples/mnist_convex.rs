//! Fig-1-top reproduction driver: the strongly-convex workload (logistic
//! regression on synthetic MNIST-0/8) under the paper's three sweeps.
//!
//! Runs the full fig1a–fig1d grids and prints the communication/computation
//! trade-off summary: time-to-target-loss per curve, which is the ordering
//! the paper's Figure 1 (top) demonstrates.
//!
//! ```bash
//! cargo run --release --example mnist_convex [--fast]
//! ```

use fedpaq::config::EngineKind;
use fedpaq::figures::{figure, Runner};

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let engine = if std::path::Path::new("artifacts/manifest.json").exists() {
        EngineKind::Pjrt
    } else {
        EngineKind::Rust
    };
    let mut runner = Runner::new(engine, "artifacts");
    if fast {
        runner.t_override = Some(40);
    }
    let out = std::path::Path::new("results");

    for id in ["fig1a", "fig1b", "fig1c", "fig1d"] {
        let spec = figure(id).unwrap();
        println!("=== {} — {}", spec.id, spec.title);
        let fig = runner.run_and_save(&spec, out)?;
        // Time-to-loss table: pick a target reachable by every curve.
        let worst_final = fig
            .curves
            .iter()
            .filter_map(|c| c.final_loss())
            .fold(f64::MIN, f64::max);
        let target = worst_final.max(0.05) * 1.15;
        println!("time to reach loss {target:.4}:");
        for c in &fig.curves {
            match c.time_to_loss(target) {
                Some(t) => println!("  {:<26} t = {t:>10.1}", c.label),
                None => println!("  {:<26} (not reached)", c.label),
            }
        }
        println!();
    }
    println!("CSV series written under results/fig1[a-d].csv");
    Ok(())
}
