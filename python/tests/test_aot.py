"""AOT path: lowering to HLO text must succeed and produce loadable,
shape-consistent artifacts + a manifest the rust runtime can trust."""

import json
import os
import tempfile

import jax
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def lowered_logreg(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = {"batch": aot.BATCH, "models": {}}
    aot.lower_model(M.model_zoo()["logreg"], out, manifest)
    return out, manifest


def test_lowering_writes_hlo_text(lowered_logreg):
    out, manifest = lowered_logreg
    for prog in ["logreg_step", "logreg_loss", "logreg_init", "logreg_grad"]:
        path = os.path.join(out, f"{prog}.hlo.txt")
        assert os.path.exists(path), prog
        text = open(path).read()
        assert text.startswith("HloModule"), prog
        # Untupled root: the entry computation must return an array, not a
        # tuple (required for the rust runtime's buffer chaining).
        assert "ENTRY" in text


def test_manifest_entry_consistent(lowered_logreg):
    _, manifest = lowered_logreg
    e = manifest["models"]["logreg"]
    assert e["param_count"] == 785
    assert e["batch"] == 10
    assert e["eval_n"] == 10000
    assert e["kind"] == "logreg"
    assert e["label_dtype"] == "f32"
    assert sorted(e["programs"]) == [
        "logreg_grad", "logreg_init", "logreg_loss", "logreg_step",
    ]


def test_step_hlo_has_expected_parameter_count(lowered_logreg):
    out, _ = lowered_logreg
    text = open(os.path.join(out, "logreg_step.hlo.txt")).read()
    # (params, x, y, lr) = 4 entry parameters.
    entry = text[text.index("ENTRY"):]
    head = entry[: entry.index("\n")]
    assert head.count("parameter") == 0  # signature names live in the body
    assert "f32[785]" in text  # param vector appears
    assert "f32[10,784]" in text  # batch appears


def test_repo_manifest_matches_zoo():
    """If `make artifacts` has run, its manifest must agree with model.py."""
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    manifest = json.load(open(path))
    zoo = M.model_zoo()
    for name, entry in manifest["models"].items():
        assert name in zoo
        assert entry["param_count"] == zoo[name].param_count, name
    q = manifest["quantizer"]
    assert os.path.exists(
        os.path.join(os.path.dirname(path), f"{q['name']}.hlo.txt")
    )


def test_eval_n_per_model_kind():
    zoo = M.model_zoo()
    assert aot.eval_n(zoo["logreg"]) == 10000  # full train set
    assert aot.eval_n(zoo["mlp92k"]) == 2048
    assert aot.eval_n(zoo["transformer"]) == 64
