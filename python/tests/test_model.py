"""L2 model correctness: shapes, losses, gradients and SGD behaviour for
every exported model variant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

ZOO = M.model_zoo()


def batch_for(spec, n, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    if spec.kind == "logreg":
        x = jax.random.normal(k1, (n, spec.d), jnp.float32)
        y = (jax.random.uniform(k2, (n,)) > 0.5).astype(jnp.float32)
    elif spec.kind == "mlp":
        x = jax.random.normal(k1, (n, spec.layers[0]), jnp.float32)
        y = jax.random.randint(k2, (n,), 0, spec.layers[-1]).astype(jnp.int32)
    else:
        x = jax.random.randint(k1, (n, spec.seq), 0, spec.vocab).astype(jnp.int32)
        y = jax.random.randint(k2, (n, spec.seq), 0, spec.vocab).astype(jnp.int32)
    return x, y


# -------------------------------------------------- param counts / shapes


def test_zoo_contains_paper_models():
    assert set(ZOO) == {
        "logreg", "mlp92k", "mlp248k", "mlp_c100", "mlp_fashion", "transformer",
    }
    # Paper: "more that 92K" and "more than 248K" parameters.
    assert 92_000 <= ZOO["mlp92k"].param_count <= 95_000
    assert 248_000 <= ZOO["mlp248k"].param_count <= 255_000
    assert ZOO["logreg"].param_count == 785


@pytest.mark.parametrize("name", list(ZOO))
def test_init_shape_and_determinism(name):
    spec = ZOO[name]
    p1 = M.init_params(spec, seed=0)
    p2 = M.init_params(spec, seed=0)
    assert p1.shape == (spec.param_count,)
    assert p1.dtype == jnp.float32
    np.testing.assert_array_equal(p1, p2)


@pytest.mark.parametrize("name", list(ZOO))
def test_step_reduces_loss_and_keeps_shape(name):
    spec = ZOO[name]
    params = M.init_params(spec, seed=1)
    x, y = batch_for(spec, 10, seed=2)
    l0 = float(M.eval_loss(spec, params, x, y)[0])
    lr = jnp.float32(0.5 if spec.kind == "logreg" else 0.05)
    p = params
    for _ in range(10):
        (p,) = M.sgd_step(spec, p, x, y, lr)
    l1 = float(M.eval_loss(spec, p, x, y)[0])
    assert p.shape == params.shape
    assert l1 < l0, f"{name}: {l0} -> {l1}"


def test_initial_losses_match_theory():
    # Zero-init logreg: ln 2. Fresh softmax over C classes: ~ln C.
    spec = ZOO["logreg"]
    x, y = batch_for(spec, 50)
    l0 = float(M.eval_loss(spec, M.init_params(spec), x, y)[0])
    assert abs(l0 - np.log(2)) < 1e-5

    # He-init + unit-variance inputs leave some logit variance, so the
    # fresh softmax CE sits a bit above ln C (never far below it).
    for name, classes in [("mlp92k", 10), ("mlp_c100", 100)]:
        spec = ZOO[name]
        x, y = batch_for(spec, 64)
        l0 = float(M.eval_loss(spec, M.init_params(spec), x, y)[0])
        assert np.log(classes) - 0.1 < l0 < np.log(classes) + 2.0, (name, l0)

    t = ZOO["transformer"]
    x, y = batch_for(t, 4)
    l0 = float(M.eval_loss(t, M.init_params(t), x, y)[0])
    assert abs(l0 - np.log(t.vocab)) < 0.5


# -------------------------------------------------- gradients


def test_logreg_grad_matches_finite_difference():
    spec = ZOO["logreg"]
    params = jax.random.normal(jax.random.PRNGKey(3), (spec.param_count,)) * 0.1
    x, y = batch_for(spec, 4, seed=4)
    (g,) = M.grad_fn(spec, params, x, y)
    eps = 1e-3
    for j in [0, 100, 500, 784]:
        pp = params.at[j].add(eps)
        pm = params.at[j].add(-eps)
        fd = (M.loss_fn(spec, pp, x, y) - M.loss_fn(spec, pm, x, y)) / (2 * eps)
        assert abs(float(fd) - float(g[j])) < 2e-3, j


def test_mlp_grad_matches_finite_difference_spotcheck():
    spec = M.MlpSpec("tiny", (6, 5, 3))
    params = M.init_params(spec, seed=5)
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (4, 6), jnp.float32)
    y = jnp.array([0, 2, 1, 1], jnp.int32)
    g = jax.grad(lambda f: M.loss_fn(spec, f, x, y))(params)
    eps = 1e-2
    for j in range(0, spec.param_count, 7):
        pp = params.at[j].add(eps)
        pm = params.at[j].add(-eps)
        fd = (M.loss_fn(spec, pp, x, y) - M.loss_fn(spec, pm, x, y)) / (2 * eps)
        assert abs(float(fd) - float(g[j])) < 5e-3, j


def test_unflatten_roundtrip_transformer():
    spec = ZOO["transformer"]
    flat = M.init_params(spec, seed=7)
    p = M.unflatten_transformer(spec, flat)
    back = M.flatten_transformer(spec, p)
    np.testing.assert_array_equal(flat, back)


def test_step_is_plain_sgd():
    # step == params - lr * grad, exactly.
    spec = ZOO["logreg"]
    params = jax.random.normal(jax.random.PRNGKey(8), (spec.param_count,)) * 0.1
    x, y = batch_for(spec, 10, seed=9)
    lr = jnp.float32(0.3)
    (stepped,) = M.sgd_step(spec, params, x, y, lr)
    (g,) = M.grad_fn(spec, params, x, y)
    np.testing.assert_allclose(stepped, params - lr * g, rtol=1e-6, atol=1e-7)
