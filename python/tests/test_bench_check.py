"""bench_check.py gate semantics: floor proposals, malformed-file
diagnostics, missing-baseline-record failures and unfloored-extra
warnings — the behaviours CI leans on."""

import json

import pytest

import bench_check


def write(path, doc):
    path.write_text(json.dumps(doc))
    return str(path)


def group(records, **extra):
    return {"group": "aggregate", **extra, "records": records}


def rec(name, rate):
    return {"name": name, "elems_per_sec": rate}


def run_main(monkeypatch, *argv):
    monkeypatch.setattr("sys.argv", ["bench_check.py", *argv])
    return bench_check.main()


# -------------------------------------------------- --propose artifact


def test_propose_writes_headroom_scaled_floors(tmp_path, monkeypatch):
    current = write(tmp_path / "cur.json",
                    group([rec("a/fused", 100.0), rec("b", 50.0)]))
    baseline = write(tmp_path / "base.json",
                     group([rec("a/fused", 10.0)], _comment="policy note"))
    out = tmp_path / "proposal.json"

    assert run_main(monkeypatch, current, baseline, "--propose", str(out)) == 0

    doc = json.loads(out.read_text())
    assert doc["group"] == "aggregate"
    # The baseline's policy note rides along into the proposal.
    assert doc["_comment"] == "policy note"
    floors = {r["name"]: r["elems_per_sec"] for r in doc["records"]}
    assert floors == {"a/fused": 80.0, "b": 40.0}


def test_propose_headroom_is_configurable(tmp_path, monkeypatch):
    current = write(tmp_path / "cur.json", group([rec("a", 100.0)]))
    baseline = write(tmp_path / "base.json", group([rec("a", 10.0)]))
    out = tmp_path / "proposal.json"

    assert run_main(monkeypatch, current, baseline, "--propose", str(out),
                    "--propose-headroom", "0.5") == 0
    doc = json.loads(out.read_text())
    assert doc["records"] == [rec("a", 50.0)]


# -------------------------------------------------- malformed inputs


def test_missing_records_key_names_the_file(tmp_path, monkeypatch):
    current = write(tmp_path / "cur.json", {"group": "aggregate"})
    baseline = write(tmp_path / "base.json", group([rec("a", 1.0)]))

    with pytest.raises(SystemExit) as exc:
        run_main(monkeypatch, current, baseline)
    msg = str(exc.value)
    assert "cur.json" in msg and "no 'records' key" in msg
    assert "'group'" in msg  # the keys it DID find


def test_record_without_name_names_the_index(tmp_path, monkeypatch):
    current = write(tmp_path / "cur.json",
                    group([rec("a", 1.0), {"elems_per_sec": 2.0}]))
    baseline = write(tmp_path / "base.json", group([rec("a", 1.0)]))

    with pytest.raises(SystemExit) as exc:
        run_main(monkeypatch, current, baseline)
    assert "record 1 has no 'name'" in str(exc.value)


# -------------------------------------------------- gate semantics


def test_baseline_record_missing_from_run_fails(tmp_path, monkeypatch, capsys):
    current = write(tmp_path / "cur.json", group([rec("kept", 100.0)]))
    baseline = write(tmp_path / "base.json",
                     group([rec("kept", 10.0), rec("deleted", 10.0)]))

    assert run_main(monkeypatch, current, baseline) == 1
    err = capsys.readouterr().err
    assert "deleted" in err and "missing" in err


def test_extra_measured_records_warn_but_pass(tmp_path, monkeypatch, capsys):
    current = write(tmp_path / "cur.json",
                    group([rec("floored", 100.0), rec("new_bench", 5.0)]))
    baseline = write(tmp_path / "base.json", group([rec("floored", 10.0)]))

    assert run_main(monkeypatch, current, baseline) == 0
    out = capsys.readouterr().out
    assert "WARN" in out and "new_bench" in out
    assert "1 unfloored group(s)" in out


def test_regression_beyond_budget_fails(tmp_path, monkeypatch, capsys):
    current = write(tmp_path / "cur.json", group([rec("a", 70.0)]))
    baseline = write(tmp_path / "base.json", group([rec("a", 100.0)]))

    assert run_main(monkeypatch, current, baseline) == 1
    assert "REGRESSION" in capsys.readouterr().out
    # A 30% drop passes once the budget is widened to match.
    assert run_main(monkeypatch, current, baseline,
                    "--max-regression", "0.35") == 0
