"""L1 kernel correctness: Pallas vs pure-jnp oracle, swept with hypothesis.

This is the core correctness signal for the compute layer — the same
kernels lower into every exported HLO artifact the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense as K
from compile.kernels import quantize as Q
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

DIMS = st.integers(min_value=1, max_value=300)


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- matmul


@settings(max_examples=10, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_random_shapes(m, k, n, seed):
    a = rand(seed, m, k)
    b = rand(seed + 1, k, n)
    got = K.matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (10, 3072, 29),  # the mlp92k first-layer shape (B=10)
        (128, 128, 128),  # exactly one MXU tile
        (129, 513, 127),  # off-by-one around tile boundaries
        (10, 784, 1),  # logreg shape
    ],
)
def test_matmul_paper_shapes(m, k, n):
    a = rand(7, m, k)
    b = rand(8, k, n)
    np.testing.assert_allclose(
        K.matmul(a, b), ref.matmul_ref(a, b), rtol=2e-4, atol=2e-4
    )


def test_matmul_gradients_flow_through_custom_vjp():
    a = rand(1, 6, 5)
    b = rand(2, 5, 4)

    def f_pallas(a, b):
        return jnp.sum(K.matmul(a, b) ** 2)

    def f_ref(a, b):
        return jnp.sum(ref.matmul_ref(a, b) ** 2)

    ga_p, gb_p = jax.grad(f_pallas, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga_p, ga_r, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(gb_p, gb_r, rtol=1e-3, atol=1e-4)


def test_dense_act_variants():
    x = rand(3, 9, 7)
    w = rand(4, 7, 5)
    b = rand(5, 5)
    z = ref.dense_ref(x, w, b)
    np.testing.assert_allclose(
        K.dense_act(x, w, b, act="relu"), jnp.maximum(z, 0), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        K.dense_act(x, w, b, act="tanh"), jnp.tanh(z), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        K.dense_act(x, w, b, act="none"), z, rtol=1e-4, atol=1e-4
    )


def test_pick_blocks_respects_vmem_budget():
    for m, k, n in [(10, 3072, 29), (2048, 3072, 100), (1, 1, 1), (4096, 4096, 4096)]:
        bm, bk, bn = K.pick_blocks(m, k, n)
        assert 4 * (bm * bk + bk * bn + bm * bn) <= K.VMEM_BUDGET_BYTES
        assert bm % 8 == 0 or bm == min(128, m)
        assert bm >= 1 and bk >= 1 and bn >= 1


# ---------------------------------------------------------------- quantize


@settings(max_examples=10, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=5000),
    s=st.sampled_from([1.0, 2.0, 5.0, 10.0, 64.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_matches_ref(p, s, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (p,), jnp.float32)
    u = jax.random.uniform(k2, (p,), jnp.float32)
    got = Q.quantize(x, u, s)
    want = ref.quantize_ref(x, u, s)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_quantize_levels_on_grid():
    x = rand(11, 1000)
    u = jax.random.uniform(jax.random.PRNGKey(12), (1000,), jnp.float32)
    s = 4.0
    q = np.asarray(Q.quantize(x, u, s))
    norm = float(jnp.linalg.norm(x))
    levels = np.abs(q) / norm * s
    np.testing.assert_allclose(levels, np.round(levels), atol=1e-4)
    assert levels.max() <= s + 1e-4


def test_quantize_unbiased_monte_carlo():
    p = 64
    x = np.asarray(rand(13, p))
    trials = 3000
    key = jax.random.PRNGKey(14)
    us = jax.random.uniform(key, (trials, p), jnp.float32)
    qs = jax.vmap(lambda u: ref.quantize_ref(jnp.array(x), u, 2.0))(us)
    mean = np.asarray(qs).mean(axis=0)
    norm = np.linalg.norm(x)
    tol = 5.0 * (norm / 2.0) / np.sqrt(trials)
    np.testing.assert_allclose(mean, x, atol=tol)


def test_quantize_variance_bound():
    # E||Q(x)-x||^2 <= q ||x||^2, q = min(p/s^2, sqrt(p)/s)
    p, s = 128, 2.0
    x = np.asarray(rand(15, p))
    trials = 2000
    us = jax.random.uniform(jax.random.PRNGKey(16), (trials, p), jnp.float32)
    qs = np.asarray(jax.vmap(lambda u: ref.quantize_ref(jnp.array(x), u, s))(us))
    err = ((qs - x[None]) ** 2).sum(axis=1).mean()
    qparam = min(p / s**2, np.sqrt(p) / s)
    bound = qparam * (np.linalg.norm(x) ** 2)
    assert err <= bound * 1.05, (err, bound)


def test_quantize_zero_vector():
    z = jnp.zeros((100,), jnp.float32)
    u = jax.random.uniform(jax.random.PRNGKey(17), (100,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(Q.quantize(z, u, 4.0)), 0.0)


def test_quantize_runtime_s_is_dynamic():
    # One jitted function must serve multiple quantization levels.
    f = jax.jit(Q.quantize)
    x = rand(18, 256)
    u = jax.random.uniform(jax.random.PRNGKey(19), (256,), jnp.float32)
    for s in [1.0, 5.0, 10.0]:
        np.testing.assert_allclose(
            f(x, u, s), ref.quantize_ref(x, u, s), rtol=1e-5, atol=1e-6
        )
