#!/usr/bin/env python3
"""Benchmark-regression gate for the BENCH_*.json files that
`fedpaq::util::bench::Group::finish` emits (CI runs it against
`rust/target/bench-results/BENCH_aggregate.json`).

Compares the current run's `elems_per_sec` per record against a baseline
JSON committed in-repo (`rust/benches/baseline/`) and exits non-zero when
any record regresses by more than --max-regression (default 25%).

The committed baselines are deliberately conservative *floors*, not
point-in-time measurements: CI runs the benches under FEDPAQ_BENCH_FAST=1
on shared runners, so absolute numbers are noisy — the gate exists to
catch order-of-magnitude regressions (an accidental re-allocation per
upload, a serialization of the sharded path), not 5% drifts. Tighten a
floor by editing the baseline, or refresh all floors from a run with:

    python3 python/bench_check.py CURRENT BASELINE --update

which rewrites BASELINE with CURRENT's measured rates scaled by
--update-headroom (default 0.5, i.e. new floor = half the measured rate).

Baseline records whose name is missing from the current run fail the gate
(a silently deleted bench is a coverage regression); current records
missing from the baseline are reported but pass, so adding a bench does
not require touching the baseline in the same commit.
"""

import argparse
import json
import sys


def load_doc(path):
    with open(path) as f:
        return json.load(f)


def records_of(doc):
    # Group files are {"group": ..., "records": [...]}; tolerate a bare
    # list so hand-written baselines can stay minimal.
    records = doc["records"] if isinstance(doc, dict) else doc
    out = {}
    for r in records:
        if r.get("elems_per_sec") is not None:
            out[r["name"]] = float(r["elems_per_sec"])
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="BENCH_*.json from the run under test")
    ap.add_argument("baseline", help="committed baseline BENCH_*.json")
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.25,
        help="maximum tolerated fractional throughput drop (default 0.25)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="rewrite BASELINE from CURRENT instead of checking",
    )
    ap.add_argument(
        "--update-headroom",
        type=float,
        default=0.5,
        help="when updating: new floor = measured rate * headroom",
    )
    args = ap.parse_args()

    current_doc = load_doc(args.current)
    current = records_of(current_doc)
    if args.update:
        group = (current_doc.get("group", "bench")
                 if isinstance(current_doc, dict) else "bench")
        doc = {"group": group}
        # Keep the old baseline's policy note, if any — it documents why
        # the floors are what they are.
        try:
            old = load_doc(args.baseline)
            if isinstance(old, dict) and "_comment" in old:
                doc["_comment"] = old["_comment"]
        except (OSError, ValueError):
            pass
        doc["records"] = [
            {"name": name, "elems_per_sec": rate * args.update_headroom}
            for name, rate in sorted(current.items())
        ]
        with open(args.baseline, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"rewrote {args.baseline} from {args.current} "
              f"(headroom {args.update_headroom})")
        return 0

    baseline = records_of(load_doc(args.baseline))
    if not baseline:
        print(f"error: no comparable records in baseline {args.baseline}")
        return 2

    failures = []
    floor_frac = 1.0 - args.max_regression
    for name, want in sorted(baseline.items()):
        got = current.get(name)
        if got is None:
            failures.append(f"{name}: present in baseline but missing from run")
            continue
        floor = want * floor_frac
        verdict = "OK" if got >= floor else "REGRESSION"
        print(f"{verdict:>10}  {name}: {got/1e6:10.1f} Melem/s "
              f"(baseline {want/1e6:.1f}, floor {floor/1e6:.1f})")
        if got < floor:
            failures.append(
                f"{name}: {got/1e6:.1f} Melem/s < floor {floor/1e6:.1f} Melem/s"
            )
    for name in sorted(set(current) - set(baseline)):
        print(f"{'NEW':>10}  {name}: {current[name]/1e6:10.1f} Melem/s "
              f"(no baseline yet)")

    if failures:
        print(f"\n{len(failures)} benchmark regression(s) beyond "
              f"{args.max_regression:.0%}:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print("\nall benchmarks within the regression budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
