#!/usr/bin/env python3
"""Benchmark-regression gate for the BENCH_*.json files that
`fedpaq::util::bench::Group::finish` emits (CI runs it against
`rust/target/bench-results/BENCH_aggregate.json`).

Compares the current run's `elems_per_sec` per record against a baseline
JSON committed in-repo (`rust/benches/baseline/`) and exits non-zero when
any record regresses by more than --max-regression (default 25%).

The committed baselines are deliberately conservative *floors*, not
point-in-time measurements: CI runs the benches under FEDPAQ_BENCH_FAST=1
on shared runners, so absolute numbers are noisy — the gate exists to
catch order-of-magnitude regressions (an accidental re-allocation per
upload, a serialization of the sharded path), not 5% drifts. Tighten a
floor by editing the baseline, or refresh all floors from a run with:

    python3 python/bench_check.py CURRENT BASELINE --update

which rewrites BASELINE with CURRENT's measured rates scaled by
--update-headroom (default 0.5, i.e. new floor = half the measured rate).

CI additionally emits a *proposal* (never applied automatically) as a
workflow artifact from every bench run:

    python3 python/bench_check.py CURRENT BASELINE --propose OUT

writes OUT with tightened floors at --propose-headroom (default 0.8) of
the measured rates — so the PR that lands a speedup can ratchet the
committed floors by copying the artifact instead of hand-editing numbers.

Baseline records whose name is missing from the current run fail the gate
with the missing name spelled out (a silently deleted or renamed bench is
a coverage regression); current records missing from the baseline are
warned about but pass, so adding a bench does not require touching the
baseline in the same commit.
"""

import argparse
import json
import sys


def load_doc(path):
    with open(path) as f:
        return json.load(f)


def records_of(doc, path):
    # Group files are {"group": ..., "records": [...]}; tolerate a bare
    # list so hand-written baselines can stay minimal. Malformed files
    # name themselves and the offending key instead of a bare KeyError.
    if isinstance(doc, dict):
        if "records" not in doc:
            sys.exit(
                f"error: {path}: no 'records' key (got keys "
                f"{sorted(doc)}) — not a BENCH_*.json group file?"
            )
        records = doc["records"]
    else:
        records = doc
    out = {}
    for i, r in enumerate(records):
        if not isinstance(r, dict) or "name" not in r:
            sys.exit(f"error: {path}: record {i} has no 'name': {r!r}")
        if r.get("elems_per_sec") is not None:
            out[r["name"]] = float(r["elems_per_sec"])
    return out


def write_floors(path, group, comment, records, headroom):
    doc = {"group": group}
    if comment is not None:
        doc["_comment"] = comment
    doc["records"] = [
        {"name": name, "elems_per_sec": rate * headroom}
        for name, rate in sorted(records.items())
    ]
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="BENCH_*.json from the run under test")
    ap.add_argument("baseline", help="committed baseline BENCH_*.json")
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.25,
        help="maximum tolerated fractional throughput drop (default 0.25)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="rewrite BASELINE from CURRENT instead of checking",
    )
    ap.add_argument(
        "--update-headroom",
        type=float,
        default=0.5,
        help="when updating: new floor = measured rate * headroom",
    )
    ap.add_argument(
        "--propose",
        metavar="OUT",
        help="instead of checking, write a tightened-floor proposal JSON "
        "to OUT (CI uploads it as the bench-floor-proposal artifact)",
    )
    ap.add_argument(
        "--propose-headroom",
        type=float,
        default=0.8,
        help="when proposing: new floor = measured rate * headroom",
    )
    args = ap.parse_args()

    current_doc = load_doc(args.current)
    current = records_of(current_doc, args.current)
    group = (current_doc.get("group", "bench")
             if isinstance(current_doc, dict) else "bench")
    # Keep the old baseline's policy note, if any — it documents why the
    # floors are what they are.
    comment = None
    try:
        old = load_doc(args.baseline)
        if isinstance(old, dict) and "_comment" in old:
            comment = old["_comment"]
    except (OSError, ValueError):
        pass

    if args.update:
        write_floors(args.baseline, group, comment, current,
                     args.update_headroom)
        print(f"rewrote {args.baseline} from {args.current} "
              f"(headroom {args.update_headroom})")
        return 0
    if args.propose:
        write_floors(args.propose, group, comment, current,
                     args.propose_headroom)
        print(f"proposed floors in {args.propose} from {args.current} "
              f"(headroom {args.propose_headroom}; review and copy over "
              f"{args.baseline} to ratchet)")
        return 0

    baseline = records_of(load_doc(args.baseline), args.baseline)
    if not baseline:
        print(f"error: no comparable records in baseline {args.baseline}")
        return 2

    failures = []
    floor_frac = 1.0 - args.max_regression
    for name, want in sorted(baseline.items()):
        got = current.get(name)
        if got is None:
            failures.append(
                f"{name}: present in baseline {args.baseline} but missing "
                f"from run {args.current} (deleted or renamed bench?)"
            )
            continue
        floor = want * floor_frac
        verdict = "OK" if got >= floor else "REGRESSION"
        print(f"{verdict:>10}  {name}: {got/1e6:10.1f} Melem/s "
              f"(baseline {want/1e6:.1f}, floor {floor/1e6:.1f})")
        if got < floor:
            failures.append(
                f"{name}: {got/1e6:.1f} Melem/s < floor {floor/1e6:.1f} Melem/s"
            )
    extras = sorted(set(current) - set(baseline))
    for name in extras:
        print(f"{'WARN':>10}  {name}: {current[name]/1e6:10.1f} Melem/s "
              f"(measured but not in the baseline — add a floor)")

    if failures:
        print(f"\n{len(failures)} benchmark regression(s) beyond "
              f"{args.max_regression:.0%}:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    if extras:
        print(f"\nall benchmarks within the regression budget "
              f"({len(extras)} unfloored group(s) warned above)")
    else:
        print("\nall benchmarks within the regression budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
