#!/usr/bin/env python3
"""Extract the time-free portion of a RunResult JSON dump.

Networked runs (`fedpaq leader --out-json`) carry wall-clock `time` /
`compute_time` / `comm_time` fields that differ between repeats; the rest
of the dump — losses, iteration counts, uploaded bits, drop/staleness
telemetry, and the exact final parameters — is a deterministic function
of `(config, seed)` for the barrier protocol and for the degenerate
buffered-async protocol (`buffer_size == r`, `max_staleness == 0`).

The CI async-TCP and tree-topology legs byte-diff this extraction
between repeat cluster runs and against the in-process simulation's
dump of the same config.

`bits_edge_to_root` (the second hop of the split uplink accounting on
aggregation trees) is included by default; pass `--no-edge-bits` to
omit those keys when diffing a tree run against a flat run of the same
config — the flat side reports 0 while a relay tree charges the
forwarded frames to both hops, so the key differs by construction even
though every model bit matches.

Usage: curve_extract.py [--no-edge-bits] RUN_RESULT.json
       (extraction on stdout)
"""

import json
import sys

POINT_KEYS = ("round", "iterations", "bits_up", "bits_down",
              "bits_edge_to_root", "loss")
ROUND_KEYS = ("round", "bits_up", "bits_down", "bits_edge_to_root",
              "dropped", "staleness_max", "staleness_mean")


def extract(doc, edge_bits=True):
    def keep(k):
        return edge_bits or k != "bits_edge_to_root"

    out = {
        "label": doc["curve"]["label"],
        "points": [
            {k: p[k] for k in POINT_KEYS if keep(k)}
            for p in doc["curve"]["points"]
        ],
        "rounds": [
            {k: r[k] for k in ROUND_KEYS if keep(k)}
            for r in doc["rounds"]
        ],
        "total_bits": doc["total_bits"],
        "total_bits_down": doc["total_bits_down"],
        "params": doc["params"],
    }
    if edge_bits:
        out["total_bits_edge_to_root"] = doc["total_bits_edge_to_root"]
    return out


def main():
    argv = sys.argv[1:]
    edge_bits = True
    if argv and argv[0] == "--no-edge-bits":
        edge_bits = False
        argv = argv[1:]
    if len(argv) != 1:
        sys.exit(__doc__.strip())
    with open(argv[0]) as f:
        doc = json.load(f)
    json.dump(extract(doc, edge_bits), sys.stdout, indent=1, sort_keys=True)
    sys.stdout.write("\n")


if __name__ == "__main__":
    main()
