#!/usr/bin/env python3
"""Extract the time-free portion of a RunResult JSON dump.

Networked runs (`fedpaq leader --out-json`) carry wall-clock `time` /
`compute_time` / `comm_time` fields that differ between repeats; the rest
of the dump — losses, iteration counts, uploaded bits, drop/staleness
telemetry, and the exact final parameters — is a deterministic function
of `(config, seed)` for the barrier protocol and for the degenerate
buffered-async protocol (`buffer_size == r`, `max_staleness == 0`).

The CI async-TCP leg byte-diffs this extraction between repeat cluster
runs and against the in-process simulation's dump of the same config.

Usage: curve_extract.py RUN_RESULT.json   (extraction on stdout)
"""

import json
import sys


def extract(doc):
    return {
        "label": doc["curve"]["label"],
        "points": [
            {k: p[k] for k in ("round", "iterations", "bits_up", "bits_down", "loss")}
            for p in doc["curve"]["points"]
        ],
        "rounds": [
            {
                k: r[k]
                for k in (
                    "round",
                    "bits_up",
                    "bits_down",
                    "dropped",
                    "staleness_max",
                    "staleness_mean",
                )
            }
            for r in doc["rounds"]
        ],
        "total_bits": doc["total_bits"],
        "total_bits_down": doc["total_bits_down"],
        "params": doc["params"],
    }


def main():
    if len(sys.argv) != 2:
        sys.exit(__doc__.strip())
    with open(sys.argv[1]) as f:
        doc = json.load(f)
    json.dump(extract(doc), sys.stdout, indent=1, sort_keys=True)
    sys.stdout.write("\n")


if __name__ == "__main__":
    main()
