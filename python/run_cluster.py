#!/usr/bin/env python3
"""Spawn a loopback fedpaq TCP cluster: one leader on an ephemeral port
plus N workers, wait for every process, collect the leader's --out-json.

This is the one orchestration helper behind every TCP leg of the CI
determinism job (plain loopback runs, leader kill/resume, worker churn) —
it replaces the shell `run_cluster`/`run_leader` functions the job had
grown five near-copies of. The protocol it automates:

1. launch `fedpaq leader --bind 127.0.0.1:0` with stderr to a log file
   (truncated first, so a second invocation never scrapes a stale
   address);
2. poll the log for the `leader: listening on <addr>` line;
3. launch the workers against that address (`--retry-secs 30` unless the
   per-worker extra args already say otherwise);
4. wait for every process individually — any non-zero exit dumps the
   leader log and fails the run.

Examples:

    python3 python/run_cluster.py --fedpaq target/release/fedpaq \\
        --config configs/async_tcp_logreg.json --out-json /tmp/a.json
    python3 python/run_cluster.py ... \\
        --leader-args "--checkpoint /tmp/tcp.ck --stop-after 3"
    python3 python/run_cluster.py ... --workers 2 \\
        --worker-args "--max-jobs 4"   # worker 0 only; worker 1 plain
"""

import argparse
import shlex
import subprocess
import sys
import time

ADDR_PREFIX = "leader: listening on "


def scrape_addr(log_path, timeout):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        try:
            with open(log_path) as f:
                for line in f:
                    if line.startswith(ADDR_PREFIX):
                        return line[len(ADDR_PREFIX):].strip()
        except OSError:
            pass
        time.sleep(0.1)
    return None


def dump_log(log_path):
    try:
        with open(log_path) as f:
            sys.stderr.write(f.read())
    except OSError as e:
        print(f"(no leader log: {e})", file=sys.stderr)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fedpaq", default="target/release/fedpaq",
                    help="path to the fedpaq binary")
    ap.add_argument("--config", required=True,
                    help="experiment config JSON for the leader")
    ap.add_argument("--workers", type=int, default=2,
                    help="number of worker processes (default 2)")
    ap.add_argument("--out-json", required=True,
                    help="leader RunResult output path")
    ap.add_argument("--leader-args", default="",
                    help="extra leader args, one shell-quoted string "
                    "(e.g. \"--checkpoint /tmp/x.ck --stop-after 3\")")
    ap.add_argument("--worker-args", action="append", default=[],
                    help="extra args for one worker (repeatable; i-th flag "
                    "goes to the i-th worker, later workers get none)")
    ap.add_argument("--leader-log", default=None,
                    help="leader stderr log path "
                    "(default: <out-json>.leader.log)")
    ap.add_argument("--listen-timeout", type=float, default=10.0,
                    help="seconds to wait for the leader's listen line")
    args = ap.parse_args()

    log_path = args.leader_log or args.out_json + ".leader.log"
    leader_cmd = [
        args.fedpaq, "leader", "--config", args.config,
        "--bind", "127.0.0.1:0", "--workers", str(args.workers),
    ] + shlex.split(args.leader_args) + ["--out-json", args.out_json]

    procs = []  # (name, Popen)
    try:
        with open(log_path, "w") as log:
            leader = subprocess.Popen(leader_cmd, stderr=log)
        procs.append(("leader", leader))

        addr = scrape_addr(log_path, args.listen_timeout)
        if addr is None:
            print("leader never started listening", file=sys.stderr)
            dump_log(log_path)
            return 1

        extras = args.worker_args + [""] * (args.workers - len(args.worker_args))
        for i in range(args.workers):
            extra = shlex.split(extras[i])
            cmd = [args.fedpaq, "worker", "--connect", addr]
            if "--retry-secs" not in extra:
                cmd += ["--retry-secs", "30"]
            procs.append((f"worker{i}", subprocess.Popen(cmd + extra)))

        ok = True
        for name, proc in procs:
            rc = proc.wait()
            if rc != 0:
                print(f"{name} exited with {rc}", file=sys.stderr)
                ok = False
        if not ok:
            dump_log(log_path)
            return 1
        return 0
    finally:
        for _, proc in procs:
            if proc.poll() is None:
                proc.terminate()


if __name__ == "__main__":
    sys.exit(main())
