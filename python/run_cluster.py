#!/usr/bin/env python3
"""Spawn a loopback fedpaq TCP cluster: one leader on an ephemeral port
plus N workers, wait for every process, collect the leader's --out-json.

This is the one orchestration helper behind every TCP leg of the CI
determinism job (plain loopback runs, leader kill/resume, worker churn,
aggregation trees) — it replaces the shell `run_cluster`/`run_leader`
functions the job had grown five near-copies of. The protocol it
automates:

1. launch `fedpaq leader --bind 127.0.0.1:0` with stderr to a log file
   (truncated first, so a second invocation never scrapes a stale
   address);
2. poll the log for the `leader: listening on <addr>` line;
3. launch the workers against that address (`--retry-secs 30` unless the
   per-worker extra args already say otherwise);
4. wait for every process individually — any non-zero exit dumps the
   leader log and fails the run.

With `--edge-leaders N` the cluster is a two-level aggregation tree:
the leader runs as the tree root, N `fedpaq edge` processes dial it
(each scraped for its own `edge: listening on <addr>` line), and the
workers split evenly across the edges — worker i dials edge i // K,
where K = workers / N (which must divide evenly). Tree-mode leader
flags (`--tree-summed`) go through `--leader-args` as usual.

With `--run-dir DIR` every process keeps its own stderr log under DIR
(leader.log, edge0.log, worker0.log, ...) instead of sharing the
terminal — the CI determinism job uploads that directory as a failure
artifact, so a red cluster leg ships the logs that explain it.

Examples:

    python3 python/run_cluster.py --fedpaq target/release/fedpaq \\
        --config configs/async_tcp_logreg.json --out-json /tmp/a.json
    python3 python/run_cluster.py ... \\
        --leader-args "--checkpoint /tmp/tcp.ck --stop-after 3"
    python3 python/run_cluster.py ... --workers 2 \\
        --worker-args "--max-jobs 4"   # worker 0 only; worker 1 plain
    python3 python/run_cluster.py ... --workers 4 --edge-leaders 2 \\
        --run-dir /tmp/tree-run       # 2 edges, 2 workers each
"""

import argparse
import os
import shlex
import subprocess
import sys
import time

ADDR_PREFIX = "leader: listening on "
EDGE_ADDR_PREFIX = "edge: listening on "


def scrape_addr(log_path, timeout, prefix=ADDR_PREFIX):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        try:
            with open(log_path) as f:
                for line in f:
                    if line.startswith(prefix):
                        return line[len(prefix):].strip()
        except OSError:
            pass
        time.sleep(0.1)
    return None


def dump_log(log_path):
    try:
        with open(log_path) as f:
            sys.stderr.write(f.read())
    except OSError as e:
        print(f"(no log {log_path}: {e})", file=sys.stderr)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fedpaq", default="target/release/fedpaq",
                    help="path to the fedpaq binary")
    ap.add_argument("--config", required=True,
                    help="experiment config JSON for the leader")
    ap.add_argument("--workers", type=int, default=2,
                    help="number of worker processes (default 2)")
    ap.add_argument("--edge-leaders", type=int, default=0,
                    help="run a two-level tree with this many edge-leader "
                    "processes; workers split evenly across them "
                    "(--workers must be a multiple)")
    ap.add_argument("--out-json", required=True,
                    help="leader RunResult output path")
    ap.add_argument("--leader-args", default="",
                    help="extra leader args, one shell-quoted string "
                    "(e.g. \"--checkpoint /tmp/x.ck --stop-after 3\" or "
                    "\"--tree-summed\")")
    ap.add_argument("--worker-args", action="append", default=[],
                    help="extra args for one worker (repeatable; i-th flag "
                    "goes to the i-th worker, later workers get none)")
    ap.add_argument("--edge-args", action="append", default=[],
                    help="extra args for one edge leader (repeatable, like "
                    "--worker-args; e.g. \"--max-partials 3\")")
    ap.add_argument("--run-dir", default=None,
                    help="keep per-process stderr logs under this directory "
                    "(leader.log, edge0.log, worker0.log, ...) — what CI "
                    "uploads as the failure artifact")
    ap.add_argument("--leader-log", default=None,
                    help="leader stderr log path (default: "
                    "<run-dir>/leader.log or <out-json>.leader.log)")
    ap.add_argument("--listen-timeout", type=float, default=10.0,
                    help="seconds to wait for each listen line")
    args = ap.parse_args()

    n_edges = args.edge_leaders
    if n_edges:
        if args.workers % n_edges:
            print(f"--workers {args.workers} must be a multiple of "
                  f"--edge-leaders {n_edges}", file=sys.stderr)
            return 2
        cohort = args.workers // n_edges

    if args.run_dir:
        os.makedirs(args.run_dir, exist_ok=True)

    def log_file(name, default):
        if args.run_dir:
            return os.path.join(args.run_dir, name + ".log")
        return default

    log_path = args.leader_log or log_file("leader", args.out_json + ".leader.log")
    leader_cmd = [args.fedpaq, "leader", "--config", args.config,
                  "--bind", "127.0.0.1:0"]
    if n_edges:
        leader_cmd += ["--edge-leaders", str(n_edges)]
    else:
        leader_cmd += ["--workers", str(args.workers)]
    leader_cmd += shlex.split(args.leader_args) + ["--out-json", args.out_json]

    procs = []      # (name, Popen)
    open_logs = []  # file handles to close on exit
    all_logs = [log_path]

    def spawn(name, cmd, logname=None):
        if logname is not None:
            path = log_file(name, logname)
            all_logs.append(path)
            log = open(path, "w")
            open_logs.append(log)
            procs.append((name, subprocess.Popen(cmd, stderr=log)))
            return path
        if args.run_dir:
            path = log_file(name, None)
            all_logs.append(path)
            log = open(path, "w")
            open_logs.append(log)
            procs.append((name, subprocess.Popen(cmd, stderr=log)))
            return path
        procs.append((name, subprocess.Popen(cmd)))
        return None

    try:
        with open(log_path, "w") as log:
            leader = subprocess.Popen(leader_cmd, stderr=log)
        procs.append(("leader", leader))

        addr = scrape_addr(log_path, args.listen_timeout)
        if addr is None:
            print("leader never started listening", file=sys.stderr)
            dump_log(log_path)
            return 1

        # Workers dial the leader directly on a flat run, or their pinned
        # edge on a tree run (worker i -> edge i // cohort).
        worker_targets = [addr] * args.workers
        if n_edges:
            edge_extras = args.edge_args + [""] * (n_edges - len(args.edge_args))
            edge_logs = []
            for e in range(n_edges):
                cmd = [args.fedpaq, "edge", "--connect", addr,
                       "--bind", "127.0.0.1:0", "--workers", str(cohort),
                       "--retry-secs", "30"] + shlex.split(edge_extras[e])
                # Edge logs are mandatory even without --run-dir: the
                # edge's listen line is how its workers find it.
                edge_logs.append(spawn(f"edge{e}", cmd,
                                       logname=f"{args.out_json}.edge{e}.log"))
            for e, elog in enumerate(edge_logs):
                eaddr = scrape_addr(elog, args.listen_timeout, EDGE_ADDR_PREFIX)
                if eaddr is None:
                    print(f"edge{e} never started listening", file=sys.stderr)
                    for p in all_logs:
                        dump_log(p)
                    return 1
                for i in range(e * cohort, (e + 1) * cohort):
                    worker_targets[i] = eaddr

        extras = args.worker_args + [""] * (args.workers - len(args.worker_args))
        for i in range(args.workers):
            extra = shlex.split(extras[i])
            cmd = [args.fedpaq, "worker", "--connect", worker_targets[i]]
            if "--retry-secs" not in extra:
                cmd += ["--retry-secs", "30"]
            spawn(f"worker{i}", cmd + extra)

        ok = True
        for name, proc in procs:
            rc = proc.wait()
            if rc != 0:
                print(f"{name} exited with {rc}", file=sys.stderr)
                ok = False
        if not ok:
            for p in all_logs:
                print(f"--- {p} ---", file=sys.stderr)
                dump_log(p)
            return 1
        return 0
    finally:
        for _, proc in procs:
            if proc.poll() is None:
                proc.terminate()
        for log in open_logs:
            log.close()


if __name__ == "__main__":
    sys.exit(main())
