"""L2: JAX model definitions (forward/backward) over FLAT parameter vectors.

Every model variant exposes two pure functions that the rust coordinator
calls through AOT-compiled HLO:

  step(params[p], x[B,...], y[B], lr[])  -> params'[p]   one SGD minibatch step
  loss(params[p], X[E,...], Y[E])        -> loss[]        training-loss eval

Parameters travel as a single f32[p] vector — the rust side owns exactly one
buffer per model and never needs to know the layer structure.  Un/flattening
happens inside JAX with static offsets, so XLA fuses it away.

All dense algebra goes through the L1 Pallas kernel (kernels.dense.matmul),
including the custom-VJP backward pass.

Model zoo (matching the paper's §5/§9 workloads):
  logreg       784 -> 1, l2-regularized logistic loss (strongly convex)
  mlp92k       3072 -> [28]*4 -> 10   (~92K params;  Fig 1 bottom)
  mlp248k      3072 -> [76]*4 -> 10   (~248K params; Fig 2)
  mlp_c100     3072 -> 64 -> 100      (one hidden layer; Fig 3)
  mlp_fashion  784 -> 128 -> 10       (one hidden layer; Fig 4)
  transformer  tiny GPT (2 layers, d=64) for the e2e driver
"""

from dataclasses import dataclass, field
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernels import dense as K


# --------------------------------------------------------------------------
# Specs
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class LogRegSpec:
    """Binary l2-regularized logistic regression (strongly convex)."""

    name: str = "logreg"
    d: int = 784
    l2: float = 0.05

    @property
    def param_count(self) -> int:
        return self.d + 1  # w, b

    @property
    def kind(self) -> str:
        return "logreg"


@dataclass(frozen=True)
class MlpSpec:
    """Fully-connected classifier with ReLU hidden layers, softmax CE loss."""

    name: str
    layers: Tuple[int, ...]  # (d_in, h1, ..., n_classes)
    l2: float = 0.0

    @property
    def param_count(self) -> int:
        return sum(
            self.layers[i] * self.layers[i + 1] + self.layers[i + 1]
            for i in range(len(self.layers) - 1)
        )

    @property
    def kind(self) -> str:
        return "mlp"


@dataclass(frozen=True)
class TransformerSpec:
    """Tiny decoder-only transformer LM (next-token CE loss)."""

    name: str = "transformer"
    vocab: int = 64
    seq: int = 32
    d_model: int = 64
    n_heads: int = 2
    n_layers: int = 2
    d_ff: int = 256

    @property
    def kind(self) -> str:
        return "transformer"

    @property
    def param_count(self) -> int:
        d, f = self.d_model, self.d_ff
        per_layer = 4 * d * d + 4 * d  # qkvo
        per_layer += d * f + f + f * d + d  # mlp
        per_layer += 4 * d  # 2 layernorms (scale+bias)
        tot = self.vocab * d  # embed
        tot += self.seq * d  # positional
        tot += self.n_layers * per_layer
        tot += 2 * d  # final LN
        tot += d * self.vocab + self.vocab  # unembed
        return tot


def model_zoo():
    """All exported model variants, keyed by name."""
    specs = [
        LogRegSpec(),
        MlpSpec("mlp92k", (3072, 29, 29, 29, 29, 10)),
        MlpSpec("mlp248k", (3072, 76, 76, 76, 76, 10)),
        MlpSpec("mlp_c100", (3072, 64, 100)),
        MlpSpec("mlp_fashion", (784, 128, 10)),
        TransformerSpec(),
    ]
    return {s.name: s for s in specs}


# --------------------------------------------------------------------------
# Flat <-> structured parameters
# --------------------------------------------------------------------------


def _take(flat, offset, shape):
    n = 1
    for s in shape:
        n *= s
    return flat[offset : offset + n].reshape(shape), offset + n


def unflatten_mlp(spec: MlpSpec, flat):
    """Split a flat vector into [(W_i, b_i)] for each layer."""
    params, off = [], 0
    for i in range(len(spec.layers) - 1):
        w, off = _take(flat, off, (spec.layers[i], spec.layers[i + 1]))
        b, off = _take(flat, off, (spec.layers[i + 1],))
        params.append((w, b))
    assert off == spec.param_count
    return params


def unflatten_transformer(spec: TransformerSpec, flat):
    d, f = spec.d_model, spec.d_ff
    off = 0
    p = {}
    p["embed"], off = _take(flat, off, (spec.vocab, d))
    p["pos"], off = _take(flat, off, (spec.seq, d))
    p["blocks"] = []
    for _ in range(spec.n_layers):
        blk = {}
        for nm in ("wq", "wk", "wv", "wo"):
            blk[nm], off = _take(flat, off, (d, d))
            blk[nm + "_b"], off = _take(flat, off, (d,))
        blk["w1"], off = _take(flat, off, (d, f))
        blk["b1"], off = _take(flat, off, (f,))
        blk["w2"], off = _take(flat, off, (f, d))
        blk["b2"], off = _take(flat, off, (d,))
        blk["ln1_s"], off = _take(flat, off, (d,))
        blk["ln1_b"], off = _take(flat, off, (d,))
        blk["ln2_s"], off = _take(flat, off, (d,))
        blk["ln2_b"], off = _take(flat, off, (d,))
        p["blocks"].append(blk)
    p["lnf_s"], off = _take(flat, off, (d,))
    p["lnf_b"], off = _take(flat, off, (d,))
    p["unembed"], off = _take(flat, off, (d, spec.vocab))
    p["unembed_b"], off = _take(flat, off, (spec.vocab,))
    assert off == spec.param_count, (off, spec.param_count)
    return p


# --------------------------------------------------------------------------
# Initialization (mirrored bit-for-bit nowhere: rust fetches init via the
# exported `<name>_init` artifact so both engines start identically).
# --------------------------------------------------------------------------


def init_params(spec, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    if spec.kind == "logreg":
        return jnp.zeros((spec.param_count,), jnp.float32)
    if spec.kind == "mlp":
        chunks = []
        for i in range(len(spec.layers) - 1):
            key, k1 = jax.random.split(key)
            fan_in = spec.layers[i]
            w = jax.random.normal(
                k1, (fan_in, spec.layers[i + 1]), jnp.float32
            ) * jnp.sqrt(2.0 / fan_in)
            chunks += [w.reshape(-1), jnp.zeros((spec.layers[i + 1],))]
        return jnp.concatenate(chunks).astype(jnp.float32)
    if spec.kind == "transformer":
        key, k = jax.random.split(key)
        flat = jax.random.normal(k, (spec.param_count,), jnp.float32) * 0.02
        # LayerNorm scales must start at 1: rebuild via unflatten offsets.
        p = unflatten_transformer(spec, flat)
        ones = jnp.ones((spec.d_model,), jnp.float32)
        for blk in p["blocks"]:
            blk["ln1_s"] = ones
            blk["ln2_s"] = ones
        p["lnf_s"] = ones
        return flatten_transformer(spec, p)
    raise ValueError(spec.kind)


def flatten_transformer(spec: TransformerSpec, p) -> jnp.ndarray:
    parts = [p["embed"].reshape(-1), p["pos"].reshape(-1)]
    for blk in p["blocks"]:
        for nm in ("wq", "wk", "wv", "wo"):
            parts += [blk[nm].reshape(-1), blk[nm + "_b"].reshape(-1)]
        parts += [
            blk["w1"].reshape(-1), blk["b1"].reshape(-1),
            blk["w2"].reshape(-1), blk["b2"].reshape(-1),
            blk["ln1_s"], blk["ln1_b"], blk["ln2_s"], blk["ln2_b"],
        ]
    parts += [p["lnf_s"], p["lnf_b"], p["unembed"].reshape(-1),
              p["unembed_b"].reshape(-1)]
    return jnp.concatenate(parts).astype(jnp.float32)


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------


def loss_logreg(spec: LogRegSpec, flat, x, y):
    """Mean logistic loss + (l2/2)||w||^2; y in {0,1} as f32."""
    w, b = flat[: spec.d], flat[spec.d]
    z = K.matmul(x, w.reshape(spec.d, 1)).reshape(-1) + b
    sgn = 2.0 * y - 1.0
    losses = jnp.logaddexp(0.0, -sgn * z)
    return jnp.mean(losses) + 0.5 * spec.l2 * jnp.dot(w, w)


def loss_mlp(spec: MlpSpec, flat, x, y):
    """Softmax cross-entropy; y int32 class labels."""
    params = unflatten_mlp(spec, flat)
    h = x
    for w, b in params[:-1]:
        h = jnp.maximum(K.dense(h, w, b), 0.0)
    w, b = params[-1]
    logits = K.dense(h, w, b)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32), axis=-1)
    ce = jnp.mean(logz - ll.reshape(-1))
    if spec.l2 > 0.0:
        ce = ce + 0.5 * spec.l2 * jnp.dot(flat, flat)
    return ce


def _layernorm(h, s, b):
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.var(h, axis=-1, keepdims=True)
    return (h - mu) / jnp.sqrt(var + 1e-5) * s + b


def loss_transformer(spec: TransformerSpec, flat, tokens, targets):
    """Next-token CE. tokens/targets: int32[B, seq]."""
    p = unflatten_transformer(spec, flat)
    B, S = tokens.shape
    d, H = spec.d_model, spec.n_heads
    hd = d // H
    h = p["embed"][tokens] + p["pos"][None, :S, :]
    mask = jnp.tril(jnp.ones((S, S), jnp.float32))
    for blk in p["blocks"]:
        hn = _layernorm(h, blk["ln1_s"], blk["ln1_b"])
        flat_h = hn.reshape(B * S, d)
        q = K.dense(flat_h, blk["wq"], blk["wq_b"]).reshape(B, S, H, hd)
        k = K.dense(flat_h, blk["wk"], blk["wk_b"]).reshape(B, S, H, hd)
        v = K.dense(flat_h, blk["wv"], blk["wv_b"]).reshape(B, S, H, hd)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
        att = jnp.where(mask[None, None] > 0, att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(B * S, d)
        h = h + K.dense(o, blk["wo"], blk["wo_b"]).reshape(B, S, d)
        hn = _layernorm(h, blk["ln2_s"], blk["ln2_b"]).reshape(B * S, d)
        ff = jnp.maximum(K.dense(hn, blk["w1"], blk["b1"]), 0.0)
        h = h + K.dense(ff, blk["w2"], blk["b2"]).reshape(B, S, d)
    h = _layernorm(h, p["lnf_s"], p["lnf_b"]).reshape(B * S, d)
    logits = K.dense(h, p["unembed"], p["unembed_b"])
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, targets.reshape(B * S, 1).astype(jnp.int32), axis=-1
    )
    return jnp.mean(logz - ll.reshape(-1))


def loss_fn(spec, flat, x, y):
    if spec.kind == "logreg":
        return loss_logreg(spec, flat, x, y)
    if spec.kind == "mlp":
        return loss_mlp(spec, flat, x, y)
    if spec.kind == "transformer":
        return loss_transformer(spec, flat, x, y)
    raise ValueError(spec.kind)


# --------------------------------------------------------------------------
# The two exported programs
# --------------------------------------------------------------------------


def sgd_step(spec, flat, x, y, lr):
    """One SGD minibatch step: params - lr * grad(loss)(params; batch)."""
    g = jax.grad(lambda f: loss_fn(spec, f, x, y))(flat)
    return (flat - lr * g,)


def eval_loss(spec, flat, x, y):
    return (loss_fn(spec, flat, x, y),)


def grad_fn(spec, flat, x, y):
    """Raw gradient (used by Theorem-2 checks: E||grad f||^2)."""
    return (jax.grad(lambda f: loss_fn(spec, f, x, y))(flat),)
