"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact (up to float round-off)
counterpart here; pytest/hypothesis compare the two across shapes, dtypes
and random inputs.  The references are also used directly by the L2 model
code when ``FEDPAQ_NO_PALLAS=1`` (debug escape hatch).
"""

import jax.numpy as jnp


def matmul_ref(a, b):
    """Plain dense matmul: ``a @ b`` with f32 accumulation."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def dense_ref(x, w, b):
    """Affine layer: ``x @ w + b``."""
    return matmul_ref(x, w) + b


def quantize_ref(x, u, s):
    """QSGD low-precision quantizer (paper Example 1), dequantized output.

    For each coordinate ``i``::

        a_i     = |x_i| / ||x||_2 * s          (in [0, s])
        l_i     = floor(a_i)
        xi_i    = (l_i + 1)/s  with prob  a_i - l_i,  else  l_i / s
        Q_i(x)  = ||x|| * sign(x_i) * xi_i

    ``u`` are i.i.d. uniforms in [0,1) driving the stochastic rounding.
    ``s`` may be a traced scalar (runtime quantization level).  The
    quantizer is unbiased, E[Q(x)|x] = x, with variance
    E||Q(x)-x||^2 <= q ||x||^2 for q = min(p/s^2, sqrt(p)/s).
    """
    x = x.astype(jnp.float32)
    norm = jnp.linalg.norm(x)
    safe = jnp.where(norm > 0.0, norm, 1.0)
    a = jnp.abs(x) / safe * s
    lo = jnp.floor(a)
    up = (u < (a - lo)).astype(jnp.float32)
    level = lo + up
    q = safe * jnp.sign(x) * level / s
    return jnp.where(norm > 0.0, q, jnp.zeros_like(x))
