"""L1 Pallas kernel for the QSGD low-precision quantizer (paper Example 1).

Elementwise VPU-style pass: the grid streams 1-D blocks of the update
vector through VMEM; the global l2-norm and the level count ``s`` ride
along as tiny broadcast blocks.  Stochastic rounding is driven by a
caller-supplied uniform tensor (the rust coordinator owns RNG seeds, so
quantization is reproducible across engines).

Output is the *dequantized* value ``||x|| * sign(x_i) * level_i / s``; the
bit-exact wire encoding (sign + level integers + norm) lives in the rust
``quant`` module, which must agree with this kernel — cross-checked by an
integration test through the exported ``quantize`` artifact.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_BLOCK = 1024


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _quantize_kernel(x_ref, u_ref, norm_ref, s_ref, o_ref):
    x = x_ref[...]
    u = u_ref[...]
    norm = norm_ref[0]
    s = s_ref[0]
    safe = jnp.where(norm > 0.0, norm, 1.0)
    a = jnp.abs(x) / safe * s
    lo = jnp.floor(a)
    level = lo + (u < (a - lo)).astype(jnp.float32)
    q = safe * jnp.sign(x) * level / s
    o_ref[...] = jnp.where(norm > 0.0, q, jnp.zeros_like(x))


def quantize(x, u, s):
    """QSGD-quantize ``x`` with levels ``s`` and uniforms ``u`` (both 1-D).

    ``s`` is a runtime scalar (f32), so one compiled artifact serves every
    quantization level in the experiment grid.
    """
    (p,) = x.shape
    assert u.shape == (p,)
    x = x.astype(jnp.float32)
    norm = jnp.linalg.norm(x).reshape((1,))
    s_arr = jnp.asarray(s, jnp.float32).reshape((1,))
    block = min(_BLOCK, _round_up(p, 8))
    pp = _round_up(p, block)
    x_p = jnp.pad(x, (0, pp - p))
    u_p = jnp.pad(u.astype(jnp.float32), (0, pp - p))
    out = pl.pallas_call(
        _quantize_kernel,
        grid=(pp // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp,), jnp.float32),
        interpret=True,
    )(x_p, u_p, norm, s_arr)
    return out[:p]


def quantize_ref(x, u, s):
    """Re-export of the pure-jnp oracle (for parity tests)."""
    return ref.quantize_ref(x, u, s)
