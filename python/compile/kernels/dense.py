"""L1 Pallas matmul / dense-layer kernel.

TPU-style tiling: the grid walks (M/bm, N/bn, K/bk) output/contraction
blocks; each step loads an (bm, bk) tile of ``a`` and a (bk, bn) tile of
``b`` into VMEM and accumulates into the (bm, bn) output tile resident in
VMEM — the classic MXU-feeding schedule expressed with BlockSpec instead of
CUDA threadblocks.  Block sizes adapt to the (often tiny) federated batch
shapes so padding waste stays bounded.

The kernel MUST be lowered with ``interpret=True`` on this testbed: real TPU
lowering emits a Mosaic custom-call that the CPU PJRT plugin cannot run.
Interpret-mode lowering turns the kernel into plain HLO (fused loops), which
XLA CPU then compiles — so the exported artifact is still fast at runtime.

``matmul`` carries a custom VJP (Pallas calls have no autodiff rule), with
both backward matmuls routed through the same kernel, so the L2 backward
pass also exercises L1.
"""

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# VMEM budget we tile for (TPU v4 has 16 MiB/core; keep ~25% headroom).
VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pick_blocks(m: int, k: int, n: int):
    """Choose (bm, bk, bn) tiles.

    Prefers MXU-shaped 128x128 output tiles with a 512-deep contraction
    block, shrinking to the (8-padded) actual dims when they are smaller so
    tiny federated batches (B=10) do not pay a 128-row padding tax.
    """
    bm = min(128, _round_up(m, 8))
    bn = min(128, _round_up(n, 8))
    bk = min(512, _round_up(k, 8))
    # Shrink bk if the three tiles would blow the VMEM budget (f32).
    while bk > 8 and 4 * (bm * bk + bk * bn + bm * bn) > VMEM_BUDGET_BYTES:
        bk //= 2
    return bm, bk, bn


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (i, j, kk) grid step: accumulate a-tile @ b-tile into o-tile."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _matmul_pallas(a, b):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul shape mismatch {a.shape} @ {b.shape}"
    bm, bk, bn = pick_blocks(m, k, n)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else a
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else b
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p.astype(jnp.float32), b_p.astype(jnp.float32))
    return out[:m, :n]


def _use_pallas() -> bool:
    return os.environ.get("FEDPAQ_NO_PALLAS", "0") != "1"


@jax.custom_vjp
def matmul(a, b):
    """``a @ b`` through the Pallas kernel, differentiable via custom VJP."""
    if _use_pallas():
        return _matmul_pallas(a, b)
    return ref.matmul_ref(a, b)


def _matmul_fwd(a, b):
    return matmul(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    # da = g @ b^T ; db = a^T @ g — both through the Pallas kernel too.
    return matmul(g, b.T), matmul(a.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def dense(x, w, b):
    """Affine layer ``x @ w + b`` on the Pallas matmul."""
    return matmul(x, w) + b


@functools.partial(jax.jit, static_argnames=("act",))
def dense_act(x, w, b, act="relu"):
    """Fused-style dense + activation (activation fuses in XLA)."""
    z = dense(x, w, b)
    if act == "relu":
        return jnp.maximum(z, 0.0)
    if act == "tanh":
        return jnp.tanh(z)
    if act == "none":
        return z
    raise ValueError(f"unknown activation {act!r}")
