"""L1 perf report: VMEM footprint + MXU utilization *estimates* for the
Pallas dense kernel's block choices on each paper shape.

Interpret-mode wallclock is CPU-numpy, not a TPU proxy, so (per the repro
methodology) real-TPU efficiency is estimated structurally:

* VMEM bytes = 4·(bm·bk + bk·bn + bm·bn) must fit the 16 MiB/core budget;
* MXU utilization estimate = useful FLOPs / FLOPs issued on padded tiles
  = (m·k·n) / (ceil- padded m̃·k̃·ñ), times the systolic-array occupancy
  of the tile shape min(bm,128)/128 · min(bn,128)/128.

Run: `cd python && python -m compile.mxu_report` (also invoked by the
EXPERIMENTS.md §Perf recipe).
"""

from .kernels.dense import pick_blocks, VMEM_BUDGET_BYTES


def _round_up(x, m):
    return (x + m - 1) // m * m


SHAPES = [
    # (label, m, k, n)
    ("logreg step fwd  (B=10)", 10, 784, 1),
    ("mlp92k  layer1   (B=10)", 10, 3072, 29),
    ("mlp92k  layer1 bwd dW", 3072, 10, 29),
    ("mlp248k layer1   (B=10)", 10, 3072, 76),
    ("mlp_c100 hidden  (B=10)", 10, 3072, 64),
    ("logreg eval      (E=10k)", 10000, 784, 1),
    ("mlp92k eval      (E=2048)", 2048, 3072, 29),
    ("transformer qkv  (B*S=320)", 320, 64, 64),
    ("transformer ff   (B*S=320)", 320, 64, 256),
    ("square 1k (reference)", 1024, 1024, 1024),
]


def report(shapes=SHAPES):
    rows = []
    for label, m, k, n in shapes:
        bm, bk, bn = pick_blocks(m, k, n)
        vmem = 4 * (bm * bk + bk * bn + bm * bn)
        mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
        pad_eff = (m * k * n) / (mp * kp * np_)
        occ = min(bm, 128) / 128 * min(bn, 128) / 128
        rows.append((label, (bm, bk, bn), vmem, pad_eff, occ, pad_eff * occ))
    return rows


def main():
    print(f"VMEM budget: {VMEM_BUDGET_BYTES / 2**20:.0f} MiB")
    print(f"{'shape':28s} {'blocks':>15s} {'VMEM':>9s} {'pad-eff':>8s} "
          f"{'MXU-occ':>8s} {'est-util':>9s}")
    for label, blocks, vmem, pad, occ, util in report():
        print(f"{label:28s} {str(blocks):>15s} {vmem/2**20:8.2f}M "
              f"{pad:8.2%} {occ:8.2%} {util:9.2%}")
    print("\nNote: B=10 rows pad to bm=16 (not 128), capping the padding tax"
          "\nat 1.6x; large eval/bwd shapes run at full-tile utilization.")


if __name__ == "__main__":
    main()
