"""AOT compile path: lower every model variant to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published `xla` rust crate links) rejects; the text parser reassigns ids and
round-trips cleanly.

Run once via `make artifacts`; python never runs on the training path.

Artifacts per model <name> (see model.py for the zoo):
  <name>_step.hlo.txt   (params, x[B], y[B], lr)   -> (params',)
  <name>_loss.hlo.txt   (params, X[E], Y[E])       -> (loss,)
  <name>_init.hlo.txt   ()                          -> (params0,)
  <name>_grad.hlo.txt   (params, X[E], Y[E])       -> (grad,)   [theory models]
plus the standalone L1 quantizer demo:
  quantize4096.hlo.txt  (x[4096], u[4096], s[])     -> (q,)

artifacts/manifest.json records shapes + dtypes so the rust runtime can
validate its buffers against what was compiled.
"""

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import quantize as Q

BATCH = 10  # paper §5: batchsize B = 10 everywhere


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    # return_tuple=False: every exported program has exactly one output
    # array, and an untupled root lets the rust runtime chain an output
    # buffer straight into the next execute_b call (τ on-device local
    # steps without host round-trips).
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def data_shapes(spec, n: int):
    """(x, y) ShapeDtypeStructs for a batch of n examples."""
    f32, i32 = jnp.float32, jnp.int32
    if spec.kind == "logreg":
        return (
            jax.ShapeDtypeStruct((n, spec.d), f32),
            jax.ShapeDtypeStruct((n,), f32),
        )
    if spec.kind == "mlp":
        return (
            jax.ShapeDtypeStruct((n, spec.layers[0]), f32),
            jax.ShapeDtypeStruct((n,), i32),
        )
    if spec.kind == "transformer":
        return (
            jax.ShapeDtypeStruct((n, spec.seq), i32),
            jax.ShapeDtypeStruct((n, spec.seq), i32),
        )
    raise ValueError(spec.kind)


def eval_n(spec) -> int:
    """Eval-slab size per model (full logreg train set; subsample for NNs)."""
    if spec.kind == "logreg":
        return 10000
    if spec.kind == "transformer":
        return 64
    return 2048


THEORY_GRAD = ("logreg", "mlp92k")  # models that export a _grad artifact


def lower_model(spec, outdir: str, manifest: dict) -> None:
    f32 = jnp.float32
    p = spec.param_count
    params = jax.ShapeDtypeStruct((p,), f32)
    lr = jax.ShapeDtypeStruct((), f32)
    xb, yb = data_shapes(spec, BATCH)
    xe, ye = data_shapes(spec, eval_n(spec))

    progs = {
        f"{spec.name}_step": (
            functools.partial(M.sgd_step, spec), (params, xb, yb, lr)),
        f"{spec.name}_loss": (
            functools.partial(M.eval_loss, spec), (params, xe, ye)),
        f"{spec.name}_init": (
            lambda: (M.init_params(spec, seed=0),), ()),
    }
    if spec.name in THEORY_GRAD:
        progs[f"{spec.name}_grad"] = (
            functools.partial(M.grad_fn, spec), (params, xe, ye))

    for name, (fn, args) in progs.items():
        path = os.path.join(outdir, f"{name}.hlo.txt")
        # §Perf (EXPERIMENTS.md): the *step* programs keep the L1 Pallas
        # kernels (the training hot path); *loss*/*grad* eval programs
        # lower with the pure-jnp dot — the interpret-mode grid loop does
        # not fuse on XLA CPU for the 2048-row eval shapes (~25x slower).
        eval_prog = name.endswith("_loss") or name.endswith("_grad")
        os.environ["FEDPAQ_NO_PALLAS"] = "1" if eval_prog else "0"
        try:
            text = to_hlo_text(jax.jit(fn).lower(*args))
        finally:
            os.environ.pop("FEDPAQ_NO_PALLAS", None)
        with open(path, "w") as f:
            f.write(text)
        print(f"  wrote {path} ({len(text)} chars)", file=sys.stderr)

    entry = {
        "kind": spec.kind,
        "param_count": p,
        "batch": BATCH,
        "eval_n": eval_n(spec),
        "programs": sorted(progs),
    }
    if spec.kind == "logreg":
        entry.update(d_in=spec.d, n_classes=2, l2=spec.l2,
                     label_dtype="f32")
    elif spec.kind == "mlp":
        entry.update(d_in=spec.layers[0], n_classes=spec.layers[-1],
                     layers=list(spec.layers), l2=spec.l2,
                     label_dtype="i32")
    else:
        entry.update(vocab=spec.vocab, seq=spec.seq, d_model=spec.d_model,
                     n_layers=spec.n_layers, label_dtype="i32")
    manifest["models"][spec.name] = entry


def lower_quantizer(outdir: str, manifest: dict, p: int = 4096) -> None:
    f32 = jnp.float32
    x = jax.ShapeDtypeStruct((p,), f32)
    u = jax.ShapeDtypeStruct((p,), f32)
    s = jax.ShapeDtypeStruct((), f32)
    name = f"quantize{p}"
    text = to_hlo_text(jax.jit(lambda x, u, s: (Q.quantize(x, u, s),)).lower(x, u, s))
    path = os.path.join(outdir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)", file=sys.stderr)
    manifest["quantizer"] = {"name": name, "p": p}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--only", default=None,
                    help="comma-separated model names (default: all)")
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)

    zoo = M.model_zoo()
    names = args.only.split(",") if args.only else list(zoo)
    manifest = {"batch": BATCH, "models": {}}
    for name in names:
        print(f"lowering {name} ...", file=sys.stderr)
        lower_model(zoo[name], outdir, manifest)
    lower_quantizer(outdir, manifest)

    mpath = os.path.join(outdir, "manifest.json")
    # Merge with an existing manifest so --only runs don't drop entries.
    if os.path.exists(mpath) and args.only:
        with open(mpath) as f:
            old = json.load(f)
        old["models"].update(manifest["models"])
        manifest = old
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}", file=sys.stderr)


if __name__ == "__main__":
    main()
