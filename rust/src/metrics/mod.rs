//! Run metrics: loss curves, CSV emission and quick terminal plots.

use std::io::Write;
use std::path::Path;

/// One evaluated point of a training run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CurvePoint {
    /// Communication round index `k` (1-based after the round completes).
    pub round: usize,
    /// SGD iterations completed so far (`k·τ`).
    pub iterations: usize,
    /// Virtual training time (paper's x-axis).
    pub time: f64,
    /// Cumulative uploaded bits.
    pub bits_up: u64,
    /// Cumulative downlink (broadcast) bits, per-node accounting.
    pub bits_down: u64,
    /// Cumulative edge→root bits on hierarchical transports (the second
    /// uplink hop of the split accounting; the worker→edge hop is
    /// `bits_up`). Always 0 on flat topologies.
    pub bits_edge_to_root: u64,
    /// Training loss at the server model.
    pub loss: f64,
}

impl CurvePoint {
    /// Total communication so far, both directions — the x-axis of the
    /// bidirectional-compression tradeoff figures.
    pub fn bits_total(&self) -> u64 {
        self.bits_up + self.bits_down
    }
}

/// A named loss-vs-time series (one line on a paper plot).
#[derive(Debug, Clone)]
pub struct Curve {
    pub label: String,
    pub points: Vec<CurvePoint>,
}

impl Curve {
    pub fn new(label: impl Into<String>) -> Self {
        Curve { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, p: CurvePoint) {
        self.points.push(p);
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.points.last().map(|p| p.loss)
    }

    pub fn total_time(&self) -> f64 {
        self.points.last().map_or(0.0, |p| p.time)
    }

    /// First virtual time at which the loss reaches `target` (linear
    /// interpolation between evaluated rounds); `None` if never reached.
    /// This is the headline "time-to-loss" comparison metric.
    pub fn time_to_loss(&self, target: f64) -> Option<f64> {
        let mut prev: Option<&CurvePoint> = None;
        for p in &self.points {
            if p.loss <= target {
                return Some(match prev {
                    Some(q) if q.loss > p.loss => {
                        let f = (q.loss - target) / (q.loss - p.loss);
                        q.time + f * (p.time - q.time)
                    }
                    _ => p.time,
                });
            }
            prev = Some(p);
        }
        None
    }
}

/// A figure = several curves sharing axes (one sub-plot of Fig 1–4).
#[derive(Debug, Clone)]
pub struct FigureData {
    pub id: String,
    pub title: String,
    pub curves: Vec<Curve>,
}

impl FigureData {
    pub fn new(id: impl Into<String>, title: impl Into<String>) -> Self {
        FigureData { id: id.into(), title: title.into(), curves: Vec::new() }
    }

    /// Write `<dir>/<id>.csv` with columns
    /// `label,round,iterations,time,bits_up,bits_down,bits_edge_to_root,loss`.
    pub fn write_csv(&self, dir: &Path) -> crate::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.id));
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
        writeln!(f, "label,round,iterations,time,bits_up,bits_down,bits_edge_to_root,loss")?;
        for c in &self.curves {
            for p in &c.points {
                writeln!(
                    f,
                    "{},{},{},{:.6},{},{},{},{:.6}",
                    c.label,
                    p.round,
                    p.iterations,
                    p.time,
                    p.bits_up,
                    p.bits_down,
                    p.bits_edge_to_root,
                    p.loss
                )?;
            }
        }
        Ok(path)
    }

    /// Compact terminal rendering: per curve, the loss at a few time marks
    /// plus final (time, loss) — enough to eyeball the paper's orderings.
    pub fn ascii_summary(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("=== {} — {}\n", self.id, self.title));
        let t_max = self
            .curves
            .iter()
            .map(Curve::total_time)
            .fold(0.0f64, f64::max);
        for c in &self.curves {
            out.push_str(&format!("  {:<28}", c.label));
            for frac in [0.25, 0.5, 0.75, 1.0] {
                let t = t_max * frac;
                let loss = c
                    .points
                    .iter()
                    .take_while(|p| p.time <= t)
                    .last()
                    .map(|p| p.loss);
                match loss {
                    Some(l) => out.push_str(&format!(" t{:>3.0}%:{l:>8.4}", frac * 100.0)),
                    None => out.push_str(&format!(" t{:>3.0}%:{:>8}", frac * 100.0, "-")),
                }
            }
            out.push_str(&format!(
                "  end t={:.1} loss={:.4}\n",
                c.total_time(),
                c.final_loss().unwrap_or(f64::NAN)
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(label: &str, pts: &[(f64, f64)]) -> Curve {
        let mut c = Curve::new(label);
        for (i, &(t, l)) in pts.iter().enumerate() {
            c.push(CurvePoint {
                round: i + 1,
                iterations: (i + 1) * 5,
                time: t,
                bits_up: 0,
                bits_down: 0,
                bits_edge_to_root: 0,
                loss: l,
            });
        }
        c
    }

    #[test]
    fn time_to_loss_interpolates() {
        let c = curve("a", &[(1.0, 1.0), (2.0, 0.5), (3.0, 0.25)]);
        assert_eq!(c.time_to_loss(0.5), Some(2.0));
        let t = c.time_to_loss(0.75).unwrap();
        assert!((t - 1.5).abs() < 1e-12);
        assert_eq!(c.time_to_loss(0.1), None);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let dir = std::env::temp_dir().join(format!("fedpaq-metrics-{}", std::process::id()));
        let mut fig = FigureData::new("figX", "test");
        fig.curves.push(curve("s=1", &[(1.0, 0.9), (2.0, 0.5)]));
        let path = fig.write_csv(&dir).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("label,round"));
        assert!(lines[1].starts_with("s=1,1,5,1.000000,0,0,0,0.9"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ascii_summary_mentions_all_curves() {
        let mut fig = FigureData::new("f", "t");
        fig.curves.push(curve("alpha", &[(1.0, 0.9)]));
        fig.curves.push(curve("beta", &[(2.0, 0.8)]));
        let s = fig.ascii_summary();
        assert!(s.contains("alpha") && s.contains("beta"));
    }
}
