//! Process-global dataset cache.
//!
//! A figure sweep runs many configs over the *same* synthetic dataset
//! (same kind/seed/size); regeneration costs ~1s for the 10K×3072
//! CIFAR-like worlds (30M Box–Muller draws), which would dominate short
//! runs. Datasets are immutable after generation, so sharing an `Arc` is
//! safe; the cache keeps a handful of worlds and evicts wholesale when
//! it grows past that (worlds are ~30–100 MB each).

use super::synth::{DatasetKind, FederatedDataset};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

type Key = (DatasetKind, u64, usize);

fn cache() -> &'static Mutex<HashMap<Key, Arc<FederatedDataset>>> {
    static CACHE: OnceLock<Mutex<HashMap<Key, Arc<FederatedDataset>>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// At most this many cached worlds before wholesale eviction.
const MAX_ENTRIES: usize = 4;

/// Generate-or-reuse the dataset for `(kind, seed, n_samples)`.
pub fn cached_generate(kind: DatasetKind, seed: u64, n_samples: usize) -> Arc<FederatedDataset> {
    let key = (kind, seed, n_samples);
    let mut map = cache().lock().unwrap();
    if let Some(ds) = map.get(&key) {
        return ds.clone();
    }
    let ds = Arc::new(FederatedDataset::generate(kind, seed, n_samples));
    if map.len() >= MAX_ENTRIES {
        map.clear();
    }
    map.insert(key, ds.clone());
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn returns_same_arc_for_same_key() {
        let a = cached_generate(DatasetKind::Mnist08, 777, 100);
        let b = cached_generate(DatasetKind::Mnist08, 777, 100);
        assert!(Arc::ptr_eq(&a, &b));
        let c = cached_generate(DatasetKind::Mnist08, 778, 100);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(c.features.len(), a.features.len());
    }
}
