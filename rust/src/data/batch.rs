//! Per-node minibatch sampling for the local SGD loop (Algorithm 1 line 7).
//!
//! Each node samples `B` indices *with replacement* from its own shard for
//! every local iteration — the paper's stochastic-gradient model (a fresh
//! ξ ~ D_i per step). Sampling is keyed by `(seed, node, round, step)` so
//! any engine (sim, TCP worker, pure-rust oracle) regenerates the exact
//! same batch sequence independently.

use crate::util::rng::Rng;

/// Deterministic minibatch index sampler.
#[derive(Debug, Clone, Copy)]
pub struct BatchSampler {
    seed: u64,
    batch: usize,
}

impl BatchSampler {
    pub fn new(seed: u64, batch: usize) -> Self {
        assert!(batch > 0);
        Self { seed, batch }
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Indices (into the node's shard) for local step `t` of round `k`.
    pub fn sample(&self, node: usize, round: usize, step: usize, shard_len: usize) -> Vec<usize> {
        let mut out = vec![0usize; self.batch];
        self.sample_into(node, round, step, shard_len, &mut out);
        out
    }

    /// Allocation-free variant for the hot loop.
    pub fn sample_into(
        &self,
        node: usize,
        round: usize,
        step: usize,
        shard_len: usize,
        out: &mut [usize],
    ) {
        debug_assert_eq!(out.len(), self.batch);
        let mut rng = self.rng_for(node, round, step);
        for o in out.iter_mut() {
            *o = rng.gen_range(0, shard_len);
        }
    }

    fn rng_for(&self, node: usize, round: usize, step: usize) -> Rng {
        Rng::from_coords(self.seed, &[1, node as u64, round as u64, step as u64])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_coordinates() {
        let s = BatchSampler::new(1, 10);
        assert_eq!(s.sample(3, 5, 2, 200), s.sample(3, 5, 2, 200));
        assert_ne!(s.sample(3, 5, 2, 200), s.sample(3, 5, 3, 200));
        assert_ne!(s.sample(3, 5, 2, 200), s.sample(4, 5, 2, 200));
        assert_ne!(s.sample(3, 5, 2, 200), s.sample(3, 6, 2, 200));
    }

    #[test]
    fn indices_in_range() {
        let s = BatchSampler::new(9, 64);
        for round in 0..5 {
            let idx = s.sample(0, round, 0, 17);
            assert_eq!(idx.len(), 64);
            assert!(idx.iter().all(|&i| i < 17));
        }
    }

    #[test]
    fn roughly_uniform() {
        let s = BatchSampler::new(2, 10);
        let mut counts = vec![0usize; 20];
        for round in 0..500 {
            for &i in &s.sample(1, round, 0, 20) {
                counts[i] += 1;
            }
        }
        let total: usize = counts.iter().sum();
        assert_eq!(total, 5000);
        for (i, &c) in counts.iter().enumerate() {
            assert!((150..350).contains(&c), "bucket {i}: {c}");
        }
    }
}
