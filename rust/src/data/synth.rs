//! Seeded synthetic dataset generators (paper-dataset stand-ins).
//!
//! All generators are deterministic in `(kind, seed, n_samples)` — the sim
//! engine, the TCP workers and the test suite regenerate identical data
//! from the config alone, so no tensors ever need to ship.

use crate::util::rng::Rng;

/// Which paper workload this dataset stands in for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DatasetKind {
    /// MNIST digits '0' vs '8' (binary, d=784) — Fig 1 top.
    Mnist08,
    /// CIFAR-10 (10 classes, d=3072) — Fig 1 bottom / Fig 2.
    Cifar10,
    /// CIFAR-100 (100 classes, d=3072) — Fig 3.
    Cifar100,
    /// Fashion-MNIST (10 classes, d=784) — Fig 4.
    FashionMnist,
    /// Markov-chain token sequences for the transformer e2e driver.
    LmMarkov,
}

impl DatasetKind {
    /// Stable string name (config files, CLI).
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::Mnist08 => "mnist08",
            DatasetKind::Cifar10 => "cifar10",
            DatasetKind::Cifar100 => "cifar100",
            DatasetKind::FashionMnist => "fashion",
            DatasetKind::LmMarkov => "lm",
        }
    }

    /// Inverse of [`DatasetKind::name`].
    pub fn parse(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "mnist08" => DatasetKind::Mnist08,
            "cifar10" => DatasetKind::Cifar10,
            "cifar100" => DatasetKind::Cifar100,
            "fashion" => DatasetKind::FashionMnist,
            "lm" => DatasetKind::LmMarkov,
            other => anyhow::bail!("unknown dataset {other:?}"),
        })
    }

    pub fn dim(&self) -> usize {
        match self {
            DatasetKind::Mnist08 | DatasetKind::FashionMnist => 784,
            DatasetKind::Cifar10 | DatasetKind::Cifar100 => 3072,
            DatasetKind::LmMarkov => 32, // sequence length
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            DatasetKind::Mnist08 => 2,
            DatasetKind::Cifar10 | DatasetKind::FashionMnist => 10,
            DatasetKind::Cifar100 => 100,
            DatasetKind::LmMarkov => 64, // vocab
        }
    }

    /// Class-mean separation scale (tuned per workload difficulty).
    fn sep(&self) -> f32 {
        match self {
            DatasetKind::Mnist08 => 2.2,
            DatasetKind::Cifar10 => 1.0,
            DatasetKind::Cifar100 => 0.8,
            DatasetKind::FashionMnist => 1.2,
            DatasetKind::LmMarkov => 0.0,
        }
    }

    /// Label-noise rate (fraction of flipped labels).
    fn label_noise(&self) -> f64 {
        match self {
            DatasetKind::Mnist08 => 0.01,
            DatasetKind::Cifar10 | DatasetKind::FashionMnist => 0.05,
            DatasetKind::Cifar100 => 0.05,
            DatasetKind::LmMarkov => 0.0,
        }
    }
}

/// Labels are f32 {0,1} for the binary logreg task, i32 classes otherwise;
/// for LM data `Int` holds flattened token sequences (features unused).
#[derive(Debug, Clone)]
pub enum Labels {
    Float(Vec<f32>),
    Int(Vec<i32>),
}

impl Labels {
    pub fn len(&self) -> usize {
        match self {
            Labels::Float(v) => v.len(),
            Labels::Int(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The full federated dataset: `n_samples` rows of dimension `dim`,
/// row-major features + labels, plus the generator config for provenance.
#[derive(Debug, Clone)]
pub struct FederatedDataset {
    pub kind: DatasetKind,
    pub seed: u64,
    pub dim: usize,
    pub n_samples: usize,
    /// Row-major `[n_samples * dim]` features. For `LmMarkov` this holds
    /// the *input* token ids as f32 (converted on upload); targets are the
    /// shifted sequence stored in `labels`.
    pub features: Vec<f32>,
    pub labels: Labels,
}

impl FederatedDataset {
    /// Generate the synthetic stand-in for `kind`.
    ///
    /// Gaussian mixture construction: class means `μ_c = sep · g_c / √d`
    /// with `g_c ~ N(0, I)` drawn from the seed, inputs
    /// `x = μ_{y} + ε, ε ~ N(0, I/√d)`-ish (coordinate σ chosen so the
    /// SNR stays in the paper's training-difficulty regime), labels
    /// flipped with the per-kind noise rate.
    pub fn generate(kind: DatasetKind, seed: u64, n_samples: usize) -> Self {
        match kind {
            DatasetKind::LmMarkov => Self::generate_lm(seed, n_samples),
            _ => Self::generate_mixture(kind, seed, n_samples),
        }
    }

    fn generate_mixture(kind: DatasetKind, seed: u64, n_samples: usize) -> Self {
        let d = kind.dim();
        let c = kind.n_classes();
        let mut rng = Rng::from_coords(seed, &[0x5eed_da7a]);
        // Class means.
        let scale = kind.sep() / (d as f32).sqrt();
        let means: Vec<Vec<f32>> = (0..c)
            .map(|_| (0..d).map(|_| rng.gen_normal() * scale).collect())
            .collect();
        let mut features = Vec::with_capacity(n_samples * d);
        let noise_sigma = 1.0 / (d as f32).sqrt();
        let flip = kind.label_noise();
        let binary = c == 2;
        let mut fl = Vec::new();
        let mut il = Vec::new();
        for _ in 0..n_samples {
            let mut y = rng.gen_range(0, c);
            let mu = &means[y];
            for j in 0..d {
                features.push(mu[j] + rng.gen_normal() * noise_sigma);
            }
            if rng.gen_bool(flip) {
                y = rng.gen_range(0, c);
            }
            if binary {
                fl.push(y as f32);
            } else {
                il.push(y as i32);
            }
        }
        let labels = if binary { Labels::Float(fl) } else { Labels::Int(il) };
        FederatedDataset { kind, seed, dim: d, n_samples, features, labels }
    }

    /// Order-1 Markov-chain token sequences: each token prefers a small
    /// set of successors, so next-token entropy is well below ln(vocab)
    /// and the LM loss has real signal to descend.
    fn generate_lm(seed: u64, n_samples: usize) -> Self {
        let kind = DatasetKind::LmMarkov;
        let seq = kind.dim();
        let vocab = kind.n_classes();
        let mut rng = Rng::from_coords(seed, &[0x1a27_83ff]);
        // Transition table: per token, 4 preferred successors (p=0.22 each)
        // and uniform leakage over the rest.
        let succ: Vec<[usize; 4]> = (0..vocab)
            .map(|_| {
                [
                    rng.gen_range(0, vocab),
                    rng.gen_range(0, vocab),
                    rng.gen_range(0, vocab),
                    rng.gen_range(0, vocab),
                ]
            })
            .collect();
        let mut features = Vec::with_capacity(n_samples * seq);
        let mut targets = Vec::with_capacity(n_samples * seq);
        for _ in 0..n_samples {
            let mut t = rng.gen_range(0, vocab);
            let mut toks = Vec::with_capacity(seq + 1);
            toks.push(t);
            for _ in 0..seq {
                t = if rng.gen_bool(0.88) {
                    succ[t][rng.gen_range(0, 4)]
                } else {
                    rng.gen_range(0, vocab)
                };
                toks.push(t);
            }
            for i in 0..seq {
                features.push(toks[i] as f32);
                targets.push(toks[i + 1] as i32);
            }
        }
        FederatedDataset {
            kind,
            seed,
            dim: seq,
            n_samples,
            features,
            labels: Labels::Int(targets),
        }
    }

    /// Borrow the feature row(s) for sample `idx`.
    pub fn row(&self, idx: usize) -> &[f32] {
        &self.features[idx * self.dim..(idx + 1) * self.dim]
    }

    /// Gather features for `idx` into `out` (row-major, len = idx.len()*dim).
    pub fn gather_features(&self, idx: &[usize], out: &mut Vec<f32>) {
        out.clear();
        out.reserve(idx.len() * self.dim);
        for &i in idx {
            out.extend_from_slice(self.row(i));
        }
    }

    /// Gather f32 labels (binary task only).
    pub fn gather_labels_f32(&self, idx: &[usize], out: &mut Vec<f32>) {
        out.clear();
        match &self.labels {
            Labels::Float(v) => out.extend(idx.iter().map(|&i| v[i])),
            Labels::Int(_) => panic!("dataset has integer labels"),
        }
    }

    /// Gather i32 labels. For LM data a "label" for sample `i` is the whole
    /// target sequence (dim entries).
    pub fn gather_labels_i32(&self, idx: &[usize], out: &mut Vec<i32>) {
        out.clear();
        match &self.labels {
            Labels::Int(v) => {
                if self.kind == DatasetKind::LmMarkov {
                    for &i in idx {
                        out.extend_from_slice(&v[i * self.dim..(i + 1) * self.dim]);
                    }
                } else {
                    out.extend(idx.iter().map(|&i| v[i]));
                }
            }
            Labels::Float(_) => panic!("dataset has float labels"),
        }
    }
}



#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let a = FederatedDataset::generate(DatasetKind::Mnist08, 7, 100);
        let b = FederatedDataset::generate(DatasetKind::Mnist08, 7, 100);
        assert_eq!(a.features, b.features);
        let c = FederatedDataset::generate(DatasetKind::Mnist08, 8, 100);
        assert_ne!(a.features, c.features);
    }

    #[test]
    fn shapes_per_kind() {
        for kind in [
            DatasetKind::Mnist08,
            DatasetKind::Cifar10,
            DatasetKind::Cifar100,
            DatasetKind::FashionMnist,
        ] {
            let ds = FederatedDataset::generate(kind, 1, 50);
            assert_eq!(ds.features.len(), 50 * kind.dim());
            assert_eq!(ds.labels.len(), 50);
        }
        let lm = FederatedDataset::generate(DatasetKind::LmMarkov, 1, 20);
        assert_eq!(lm.features.len(), 20 * 32);
        assert_eq!(lm.labels.len(), 20 * 32); // per-token targets
    }

    #[test]
    fn binary_labels_are_01() {
        let ds = FederatedDataset::generate(DatasetKind::Mnist08, 3, 500);
        match &ds.labels {
            Labels::Float(v) => {
                assert!(v.iter().all(|&y| y == 0.0 || y == 1.0));
                let ones = v.iter().filter(|&&y| y == 1.0).count();
                // Roughly balanced classes.
                assert!(ones > 150 && ones < 350, "ones={ones}");
            }
            _ => panic!("expected float labels"),
        }
    }

    #[test]
    fn class_labels_in_range() {
        let ds = FederatedDataset::generate(DatasetKind::Cifar100, 5, 300);
        match &ds.labels {
            Labels::Int(v) => assert!(v.iter().all(|&y| (0..100).contains(&y))),
            _ => panic!("expected int labels"),
        }
    }

    #[test]
    fn lm_tokens_in_vocab() {
        let ds = FederatedDataset::generate(DatasetKind::LmMarkov, 5, 10);
        assert!(ds.features.iter().all(|&t| (0.0..64.0).contains(&t)));
        match &ds.labels {
            Labels::Int(v) => assert!(v.iter().all(|&t| (0..64).contains(&t))),
            _ => panic!(),
        }
    }

    #[test]
    fn classes_are_separable_on_average() {
        // Mean within-class distance must be smaller than between-class.
        let ds = FederatedDataset::generate(DatasetKind::Mnist08, 11, 400);
        let ys = match &ds.labels {
            Labels::Float(v) => v.clone(),
            _ => unreachable!(),
        };
        let mut mean0 = vec![0f32; ds.dim];
        let mut mean1 = vec![0f32; ds.dim];
        let (mut n0, mut n1) = (0, 0);
        for i in 0..ds.n_samples {
            let row = ds.row(i);
            if ys[i] == 0.0 {
                n0 += 1;
                for (m, &x) in mean0.iter_mut().zip(row) {
                    *m += x;
                }
            } else {
                n1 += 1;
                for (m, &x) in mean1.iter_mut().zip(row) {
                    *m += x;
                }
            }
        }
        let gap: f32 = mean0
            .iter()
            .zip(&mean1)
            .map(|(&a, &b)| {
                let d = a / n0 as f32 - b / n1 as f32;
                d * d
            })
            .sum::<f32>()
            .sqrt();
        assert!(gap > 0.5, "class means too close: {gap}");
    }
}
