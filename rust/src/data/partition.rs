//! The i.i.d. federated partitioner: `n` nodes × `m` samples each.
//!
//! The paper's setting (§2) is i.i.d. data uniformly spread over nodes; we
//! shuffle the global sample indices with a seeded RNG and deal them out
//! contiguously. Invariant (property-tested): the node shards are a
//! *partition* — disjoint and jointly covering the first `n*m` samples.

use crate::util::rng::Rng;

/// How samples are spread over nodes.
///
/// The paper's setting is [`PartitionKind::Iid`]; `Dirichlet` is the
/// standard label-skew heterogeneity model (an extension ablation — the
/// paper lists statistical heterogeneity as future work).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PartitionKind {
    Iid,
    /// Per-node class proportions drawn from `Dir(alpha·1)`; smaller
    /// `alpha` ⇒ more skew (`alpha → ∞` recovers iid).
    Dirichlet { alpha: f64 },
}

/// Assignment of dataset sample indices to nodes.
#[derive(Debug, Clone)]
pub struct Partition {
    shards: Vec<Vec<usize>>,
}

impl Partition {
    /// Deal `n_nodes * per_node` samples out of `n_samples` (must suffice)
    /// into `n_nodes` equal shards, i.i.d. via a seeded shuffle.
    pub fn iid(n_samples: usize, n_nodes: usize, per_node: usize, seed: u64) -> Self {
        assert!(
            n_nodes * per_node <= n_samples,
            "need {} samples, dataset has {}",
            n_nodes * per_node,
            n_samples
        );
        let mut idx: Vec<usize> = (0..n_samples).collect();
        let mut rng = Rng::from_coords(seed, &[0x9a27_11c3]);
        rng.shuffle(&mut idx);
        let shards = (0..n_nodes)
            .map(|i| idx[i * per_node..(i + 1) * per_node].to_vec())
            .collect();
        Partition { shards }
    }

    /// Label-skew partition: node `i` draws class proportions
    /// `p_i ~ Dir(alpha·1)` and fills its shard by sampling classes from
    /// the remaining per-class pools (falling back to whatever is left
    /// when a pool drains). `class_of[j]` gives sample `j`'s label.
    pub fn dirichlet(
        class_of: &[usize],
        n_classes: usize,
        n_nodes: usize,
        per_node: usize,
        alpha: f64,
        seed: u64,
    ) -> Self {
        assert!(alpha > 0.0, "alpha must be positive");
        assert!(
            n_nodes * per_node <= class_of.len(),
            "need {} samples, dataset has {}",
            n_nodes * per_node,
            class_of.len()
        );
        let mut rng = Rng::from_coords(seed, &[0xd112_c137]);
        // Per-class index pools, shuffled.
        let mut pools: Vec<Vec<usize>> = vec![Vec::new(); n_classes];
        for (j, &c) in class_of.iter().enumerate() {
            pools[c].push(j);
        }
        for pool in pools.iter_mut() {
            rng.shuffle(pool);
        }
        let mut shards = Vec::with_capacity(n_nodes);
        for _ in 0..n_nodes {
            let props = dirichlet_sample(&mut rng, n_classes, alpha);
            let mut shard = Vec::with_capacity(per_node);
            for _ in 0..per_node {
                // Sample a class by proportion, restricted to non-empty pools.
                let total: f64 = pools
                    .iter()
                    .zip(&props)
                    .filter(|(p, _)| !p.is_empty())
                    .map(|(_, &w)| w)
                    .sum();
                let mut pick = None;
                if total > 0.0 {
                    let mut u = rng.gen_f64() * total;
                    for (c, pool) in pools.iter().enumerate() {
                        if pool.is_empty() {
                            continue;
                        }
                        u -= props[c];
                        if u <= 0.0 {
                            pick = Some(c);
                            break;
                        }
                    }
                }
                let c = pick.unwrap_or_else(|| {
                    // All weighted pools empty: take any non-empty class.
                    pools.iter().position(|p| !p.is_empty()).expect("samples left")
                });
                shard.push(pools[c].pop().unwrap());
            }
            shards.push(shard);
        }
        Partition { shards }
    }

    /// Dispatch on [`PartitionKind`]; `Dirichlet` needs class labels and
    /// falls back to iid for the LM dataset (per-token labels).
    pub fn build(
        kind: PartitionKind,
        data: &super::synth::FederatedDataset,
        n_nodes: usize,
        per_node: usize,
        seed: u64,
    ) -> Self {
        match kind {
            PartitionKind::Iid => Self::iid(data.n_samples, n_nodes, per_node, seed),
            PartitionKind::Dirichlet { alpha } => {
                use super::synth::{DatasetKind, Labels};
                if data.kind == DatasetKind::LmMarkov {
                    return Self::iid(data.n_samples, n_nodes, per_node, seed);
                }
                let class_of: Vec<usize> = match &data.labels {
                    Labels::Float(v) => v.iter().map(|&y| y as usize).collect(),
                    Labels::Int(v) => v.iter().map(|&y| y as usize).collect(),
                };
                Self::dirichlet(
                    &class_of,
                    data.kind.n_classes(),
                    n_nodes,
                    per_node,
                    alpha,
                    seed,
                )
            }
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, node: usize) -> &[usize] {
        &self.shards[node]
    }

    /// All assigned indices in node order (used for full-train-set eval).
    pub fn all_indices(&self) -> Vec<usize> {
        self.shards.iter().flatten().copied().collect()
    }
}

/// One `Dir(alpha·1_k)` draw via normalized `Gamma(alpha, 1)` samples.
fn dirichlet_sample(rng: &mut Rng, k: usize, alpha: f64) -> Vec<f64> {
    let mut v: Vec<f64> = (0..k).map(|_| gamma_sample(rng, alpha)).collect();
    let sum: f64 = v.iter().sum();
    if sum <= 0.0 {
        return vec![1.0 / k as f64; k];
    }
    for x in v.iter_mut() {
        *x /= sum;
    }
    v
}

/// Marsaglia–Tsang Gamma(shape, 1) sampler (with the alpha<1 boost).
fn gamma_sample(rng: &mut Rng, shape: f64) -> f64 {
    if shape < 1.0 {
        let u = rng.gen_f64().max(1e-300);
        return gamma_sample(rng, shape + 1.0) * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.gen_normal() as f64;
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u = rng.gen_f64().max(1e-300);
        if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn covers_exactly_once() {
        let p = Partition::iid(10_000, 50, 200, 42);
        let mut seen = HashSet::new();
        for node in 0..50 {
            for &i in p.shard(node) {
                assert!(seen.insert(i), "sample {i} assigned twice");
                assert!(i < 10_000);
            }
        }
        assert_eq!(seen.len(), 10_000);
    }

    #[test]
    fn deterministic() {
        let a = Partition::iid(1000, 10, 100, 7);
        let b = Partition::iid(1000, 10, 100, 7);
        for n in 0..10 {
            assert_eq!(a.shard(n), b.shard(n));
        }
    }

    #[test]
    #[should_panic(expected = "need")]
    fn too_few_samples_panics() {
        Partition::iid(99, 10, 10, 0);
    }

    fn fake_labels(n: usize, classes: usize, seed: u64) -> Vec<usize> {
        let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
        (0..n).map(|_| rng.gen_range(0, classes)).collect()
    }

    #[test]
    fn dirichlet_is_disjoint_and_sized() {
        let labels = fake_labels(1000, 10, 1);
        let p = Partition::dirichlet(&labels, 10, 8, 100, 0.3, 2);
        let mut seen = HashSet::new();
        for node in 0..8 {
            assert_eq!(p.shard(node).len(), 100);
            for &i in p.shard(node) {
                assert!(seen.insert(i));
            }
        }
    }

    #[test]
    fn small_alpha_skews_more_than_large() {
        // Measure mean per-node label entropy: low alpha => low entropy.
        let labels = fake_labels(4000, 10, 3);
        let entropy = |alpha: f64| -> f64 {
            let p = Partition::dirichlet(&labels, 10, 10, 200, alpha, 4);
            let mut acc = 0.0;
            for node in 0..10 {
                let mut counts = [0f64; 10];
                for &i in p.shard(node) {
                    counts[labels[i]] += 1.0;
                }
                let n: f64 = counts.iter().sum();
                acc -= counts
                    .iter()
                    .filter(|&&c| c > 0.0)
                    .map(|&c| (c / n) * (c / n).ln())
                    .sum::<f64>();
            }
            acc / 10.0
        };
        let skewed = entropy(0.05);
        let near_iid = entropy(100.0);
        assert!(
            skewed < near_iid - 0.5,
            "skewed {skewed} vs near-iid {near_iid}"
        );
    }

    #[test]
    fn dirichlet_deterministic() {
        let labels = fake_labels(500, 5, 5);
        let a = Partition::dirichlet(&labels, 5, 4, 100, 0.5, 6);
        let b = Partition::dirichlet(&labels, 5, 4, 100, 0.5, 6);
        for n in 0..4 {
            assert_eq!(a.shard(n), b.shard(n));
        }
    }
}
