//! The i.i.d. federated partitioner: `n` nodes × `m` samples each.
//!
//! The paper's setting (§2) is i.i.d. data uniformly spread over nodes.
//! The synthetic datasets draw every sample from a per-sample seeded RNG
//! (statistically i.i.d. by construction), so the IID partition needs no
//! shuffle: node `i`'s shard is the **arithmetic range**
//! `{(i·m + j) mod n_samples : j < m}`, computed on demand in
//! [`Partition::shard`] and never materialized. That is the other half of
//! the simulator's O(active) memory contract — 10^7 nodes cost zero
//! resident partition state, and when `n·m > n_samples` (a capped
//! dataset, `cfg.dataset_cap`) shards wrap around and share samples, the
//! standard way to simulate huge cohorts over a bounded dataset.
//! Invariant (property-tested): with `n·m ≤ n_samples` the node shards
//! are a *partition* — disjoint and jointly covering the first `n*m`
//! samples.
//!
//! The Dirichlet label-skew partitioner still stores explicit per-node
//! index lists (its shards are data-dependent); both shapes are served
//! through the [`Shard`] view.

use crate::util::rng::Rng;

/// How samples are spread over nodes.
///
/// The paper's setting is [`PartitionKind::Iid`]; `Dirichlet` is the
/// standard label-skew heterogeneity model (an extension ablation — the
/// paper lists statistical heterogeneity as future work).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PartitionKind {
    Iid,
    /// Per-node class proportions drawn from `Dir(alpha·1)`; smaller
    /// `alpha` ⇒ more skew (`alpha → ∞` recovers iid).
    Dirichlet { alpha: f64 },
}

/// A node's shard of sample indices, as a cheap copyable view: either a
/// slice of explicitly stored indices (Dirichlet) or an arithmetic range
/// (lazy IID — nothing resident).
#[derive(Debug, Clone, Copy)]
pub enum Shard<'a> {
    /// Explicit index list (label-skew partitions).
    Explicit(&'a [usize]),
    /// `{(start + j) mod modulo : j < len}` — the lazy IID shard.
    Range { start: usize, len: usize, modulo: usize },
}

impl<'a> Shard<'a> {
    pub fn len(&self) -> usize {
        match *self {
            Shard::Explicit(s) => s.len(),
            Shard::Range { len, .. } => len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `i`-th sample index of this shard (panics out of range).
    pub fn get(&self, i: usize) -> usize {
        match *self {
            Shard::Explicit(s) => s[i],
            Shard::Range { start, len, modulo } => {
                assert!(i < len, "shard index {i} out of 0..{len}");
                (start + i) % modulo
            }
        }
    }

    /// Iterate the shard's sample indices (by value; `Shard` is `Copy`,
    /// so the iterator outlives the view it was made from).
    pub fn iter(self) -> impl Iterator<Item = usize> + 'a {
        (0..self.len()).map(move |i| self.get(i))
    }
}

/// The two storage shapes behind [`Partition`].
#[derive(Debug, Clone)]
enum Shards {
    Explicit(Vec<Vec<usize>>),
    /// Lazy IID: node `i` owns `{(i·per_node + j) mod n_samples}`.
    Arithmetic { n_nodes: usize, per_node: usize, n_samples: usize },
}

/// Assignment of dataset sample indices to nodes.
#[derive(Debug, Clone)]
pub struct Partition {
    shards: Shards,
}

impl Partition {
    /// The i.i.d. partition: `n_nodes` equal shards of `per_node`
    /// arithmetic-range indices over a dataset of `n_samples`. O(1) time
    /// and memory regardless of cohort size; when
    /// `n_nodes · per_node > n_samples` shards wrap around and share
    /// samples (oversubscription — how 10^6+-client cohorts run on a
    /// bounded dataset).
    pub fn iid(n_samples: usize, n_nodes: usize, per_node: usize) -> Self {
        assert!(n_samples > 0, "need a non-empty dataset to partition");
        Partition { shards: Shards::Arithmetic { n_nodes, per_node, n_samples } }
    }

    /// Label-skew partition: node `i` draws class proportions
    /// `p_i ~ Dir(alpha·1)` and fills its shard by sampling classes from
    /// the remaining per-class pools (falling back to whatever is left
    /// when a pool drains). `class_of[j]` gives sample `j`'s label.
    pub fn dirichlet(
        class_of: &[usize],
        n_classes: usize,
        n_nodes: usize,
        per_node: usize,
        alpha: f64,
        seed: u64,
    ) -> Self {
        assert!(alpha > 0.0, "alpha must be positive");
        assert!(
            n_nodes * per_node <= class_of.len(),
            "need {} samples, dataset has {}",
            n_nodes * per_node,
            class_of.len()
        );
        let mut rng = Rng::from_coords(seed, &[0xd112_c137]);
        // Per-class index pools, shuffled.
        let mut pools: Vec<Vec<usize>> = vec![Vec::new(); n_classes];
        for (j, &c) in class_of.iter().enumerate() {
            pools[c].push(j);
        }
        for pool in pools.iter_mut() {
            rng.shuffle(pool);
        }
        let mut shards = Vec::with_capacity(n_nodes);
        for _ in 0..n_nodes {
            let props = dirichlet_sample(&mut rng, n_classes, alpha);
            let mut shard = Vec::with_capacity(per_node);
            for _ in 0..per_node {
                // Sample a class by proportion, restricted to non-empty pools.
                let total: f64 = pools
                    .iter()
                    .zip(&props)
                    .filter(|(p, _)| !p.is_empty())
                    .map(|(_, &w)| w)
                    .sum();
                let mut pick = None;
                if total > 0.0 {
                    let mut u = rng.gen_f64() * total;
                    for (c, pool) in pools.iter().enumerate() {
                        if pool.is_empty() {
                            continue;
                        }
                        u -= props[c];
                        if u <= 0.0 {
                            pick = Some(c);
                            break;
                        }
                    }
                }
                let c = pick.unwrap_or_else(|| {
                    // All weighted pools empty: take any non-empty class.
                    pools.iter().position(|p| !p.is_empty()).expect("samples left")
                });
                shard.push(pools[c].pop().unwrap());
            }
            shards.push(shard);
        }
        Partition { shards: Shards::Explicit(shards) }
    }

    /// Dispatch on [`PartitionKind`]; `Dirichlet` needs class labels and
    /// falls back to iid for the LM dataset (per-token labels).
    pub fn build(
        kind: PartitionKind,
        data: &super::synth::FederatedDataset,
        n_nodes: usize,
        per_node: usize,
        seed: u64,
    ) -> Self {
        match kind {
            PartitionKind::Iid => Self::iid(data.n_samples, n_nodes, per_node),
            PartitionKind::Dirichlet { alpha } => {
                use super::synth::{DatasetKind, Labels};
                if data.kind == DatasetKind::LmMarkov {
                    return Self::iid(data.n_samples, n_nodes, per_node);
                }
                let class_of: Vec<usize> = match &data.labels {
                    Labels::Float(v) => v.iter().map(|&y| y as usize).collect(),
                    Labels::Int(v) => v.iter().map(|&y| y as usize).collect(),
                };
                Self::dirichlet(
                    &class_of,
                    data.kind.n_classes(),
                    n_nodes,
                    per_node,
                    alpha,
                    seed,
                )
            }
        }
    }

    pub fn n_nodes(&self) -> usize {
        match &self.shards {
            Shards::Explicit(s) => s.len(),
            Shards::Arithmetic { n_nodes, .. } => *n_nodes,
        }
    }

    /// Total assigned sample slots across all nodes (with wraparound
    /// these are not necessarily distinct samples).
    pub fn assigned(&self) -> usize {
        match &self.shards {
            Shards::Explicit(s) => s.iter().map(Vec::len).sum(),
            Shards::Arithmetic { n_nodes, per_node, .. } => n_nodes * per_node,
        }
    }

    pub fn shard(&self, node: usize) -> Shard<'_> {
        match &self.shards {
            Shards::Explicit(s) => Shard::Explicit(&s[node]),
            Shards::Arithmetic { n_nodes, per_node, n_samples } => {
                assert!(node < *n_nodes, "node {node} out of 0..{n_nodes}");
                Shard::Range {
                    start: (node * per_node) % n_samples,
                    len: *per_node,
                    modulo: *n_samples,
                }
            }
        }
    }

    /// The first `n` assigned indices in node order (the eval slab).
    /// Lazy for the arithmetic partition — never materializes
    /// O(n_nodes · per_node) state, the historical `all_indices()` cost
    /// that capped cohort size.
    pub fn eval_indices(&self, n: usize) -> Vec<usize> {
        match &self.shards {
            Shards::Explicit(s) => s.iter().flatten().copied().take(n).collect(),
            Shards::Arithmetic { n_samples, .. } => (0..n).map(|i| i % n_samples).collect(),
        }
    }
}

/// One `Dir(alpha·1_k)` draw via normalized `Gamma(alpha, 1)` samples.
fn dirichlet_sample(rng: &mut Rng, k: usize, alpha: f64) -> Vec<f64> {
    let mut v: Vec<f64> = (0..k).map(|_| gamma_sample(rng, alpha)).collect();
    let sum: f64 = v.iter().sum();
    if sum <= 0.0 {
        return vec![1.0 / k as f64; k];
    }
    for x in v.iter_mut() {
        *x /= sum;
    }
    v
}

/// Marsaglia–Tsang Gamma(shape, 1) sampler (with the alpha<1 boost).
fn gamma_sample(rng: &mut Rng, shape: f64) -> f64 {
    if shape < 1.0 {
        let u = rng.gen_f64().max(1e-300);
        return gamma_sample(rng, shape + 1.0) * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.gen_normal() as f64;
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u = rng.gen_f64().max(1e-300);
        if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn covers_exactly_once() {
        let p = Partition::iid(10_000, 50, 200);
        let mut seen = HashSet::new();
        for node in 0..50 {
            for i in p.shard(node).iter() {
                assert!(seen.insert(i), "sample {i} assigned twice");
                assert!(i < 10_000);
            }
        }
        assert_eq!(seen.len(), 10_000);
        assert_eq!(p.assigned(), 10_000);
    }

    #[test]
    fn deterministic() {
        let a = Partition::iid(1000, 10, 100);
        let b = Partition::iid(1000, 10, 100);
        for n in 0..10 {
            let av: Vec<usize> = a.shard(n).collect_vec();
            let bv: Vec<usize> = b.shard(n).collect_vec();
            assert_eq!(av, bv);
        }
    }

    #[test]
    fn oversubscribed_shards_wrap_around_the_dataset() {
        // 10 nodes × 15 samples over a 100-sample dataset: every shard is
        // full-length, indices stay in range, and node 9's shard wraps
        // from 135 % 100 back to the front.
        let p = Partition::iid(100, 10, 15);
        assert_eq!(p.assigned(), 150);
        for node in 0..10 {
            let s = p.shard(node);
            assert_eq!(s.len(), 15);
            assert!(s.iter().all(|i| i < 100));
        }
        let last: Vec<usize> = p.shard(9).collect_vec();
        assert_eq!(last[0], 35);
        assert_eq!(last[14], 49);
        let wrap: Vec<usize> = p.shard(6).collect_vec(); // starts at 90
        assert_eq!(wrap[9], 99);
        assert_eq!(wrap[10], 0);
    }

    #[test]
    fn eval_indices_is_lazy_prefix_modulo_dataset() {
        let p = Partition::iid(100, 1_000_000, 10);
        // O(eval_n), not O(n_nodes * per_node): a 10^7-slot assignment
        // must not materialize to serve a 250-index eval slab.
        let idx = p.eval_indices(250);
        assert_eq!(idx.len(), 250);
        assert_eq!(&idx[..3], &[0, 1, 2]);
        assert_eq!(idx[100], 0);
        assert_eq!(idx[249], 49);
    }

    #[test]
    #[should_panic(expected = "non-empty")]
    fn empty_dataset_panics() {
        Partition::iid(0, 10, 10);
    }

    /// Collect a [`Shard`] view into owned indices (test convenience).
    trait CollectVec {
        fn collect_vec(&self) -> Vec<usize>;
    }
    impl CollectVec for Shard<'_> {
        fn collect_vec(&self) -> Vec<usize> {
            self.iter().collect()
        }
    }

    fn fake_labels(n: usize, classes: usize, seed: u64) -> Vec<usize> {
        let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
        (0..n).map(|_| rng.gen_range(0, classes)).collect()
    }

    #[test]
    fn dirichlet_is_disjoint_and_sized() {
        let labels = fake_labels(1000, 10, 1);
        let p = Partition::dirichlet(&labels, 10, 8, 100, 0.3, 2);
        let mut seen = HashSet::new();
        for node in 0..8 {
            assert_eq!(p.shard(node).len(), 100);
            for i in p.shard(node).iter() {
                assert!(seen.insert(i));
            }
        }
    }

    #[test]
    fn small_alpha_skews_more_than_large() {
        // Measure mean per-node label entropy: low alpha => low entropy.
        let labels = fake_labels(4000, 10, 3);
        let entropy = |alpha: f64| -> f64 {
            let p = Partition::dirichlet(&labels, 10, 10, 200, alpha, 4);
            let mut acc = 0.0;
            for node in 0..10 {
                let mut counts = [0f64; 10];
                for i in p.shard(node).iter() {
                    counts[labels[i]] += 1.0;
                }
                let n: f64 = counts.iter().sum();
                acc -= counts
                    .iter()
                    .filter(|&&c| c > 0.0)
                    .map(|&c| (c / n) * (c / n).ln())
                    .sum::<f64>();
            }
            acc / 10.0
        };
        let skewed = entropy(0.05);
        let near_iid = entropy(100.0);
        assert!(
            skewed < near_iid - 0.5,
            "skewed {skewed} vs near-iid {near_iid}"
        );
    }

    #[test]
    fn dirichlet_deterministic() {
        let labels = fake_labels(500, 5, 5);
        let a = Partition::dirichlet(&labels, 5, 4, 100, 0.5, 6);
        let b = Partition::dirichlet(&labels, 5, 4, 100, 0.5, 6);
        for n in 0..4 {
            assert_eq!(a.shard(n).collect_vec(), b.shard(n).collect_vec());
        }
    }
}
