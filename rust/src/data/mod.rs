//! Federated datasets: synthetic generators, the i.i.d. partitioner and
//! per-node minibatch sampling.
//!
//! The paper trains on MNIST('0'/'8'), CIFAR-10, CIFAR-100 and
//! Fashion-MNIST. This testbed has no dataset downloads, so per DESIGN.md
//! §4 each is substituted by a *deterministic, seeded* synthetic workload
//! with the same dimensionality, class count and per-node sample budget —
//! Gaussian class clusters whose separation/noise are tuned so the
//! optimization difficulty (gradient noise σ², conditioning) is in the
//! regime the paper's curves live in.

pub mod batch;
pub mod cache;
pub mod partition;
pub mod synth;

pub use batch::BatchSampler;
pub use partition::{Partition, PartitionKind, Shard};
pub use cache::cached_generate;
pub use synth::{DatasetKind, FederatedDataset, Labels};
