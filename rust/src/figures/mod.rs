//! Figure-regeneration harness: one [`FigureSpec`] per sub-plot of the
//! paper's Figures 1–4, each a grid of [`ExperimentConfig`]s sharing axes.
//!
//! `fedpaq figure <id|all>` (or the criterion benches in `rust/benches/`)
//! runs every config of a figure through the same engine and writes
//! `results/<id>.csv` plus a terminal summary. Absolute losses/times are
//! testbed-specific; what must reproduce is the paper's *orderings* —
//! see EXPERIMENTS.md for the recorded shapes.

use crate::config::{EngineKind, ExperimentConfig};
use crate::coordinator::ServerBuilder;
use crate::data::DatasetKind;
use crate::metrics::FigureData;
use crate::model::{Engine, ModelKind, RustEngine};
use crate::opt::LrSchedule;
use crate::quant::CodecSpec;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One sub-plot: id (e.g. `fig1c`), title, and its curve grid.
#[derive(Debug, Clone)]
pub struct FigureSpec {
    pub id: String,
    pub title: String,
    pub configs: Vec<ExperimentConfig>,
}

/// Static model-zoo mirror of `python/compile/model.py` (cross-checked
/// against `artifacts/manifest.json` by an integration test).
pub fn zoo_kind(name: &str) -> Option<(ModelKind, usize, usize)> {
    // (kind, batch, eval_n)
    let k = match name {
        "logreg" => (ModelKind::LogReg { d: 784, l2: 0.05 }, 10, 10_000),
        "mlp92k" => (
            ModelKind::Mlp { layers: vec![3072, 29, 29, 29, 29, 10], l2: 0.0 },
            10,
            2048,
        ),
        "mlp248k" => (
            ModelKind::Mlp { layers: vec![3072, 76, 76, 76, 76, 10], l2: 0.0 },
            10,
            2048,
        ),
        "mlp_c100" => (ModelKind::Mlp { layers: vec![3072, 64, 100], l2: 0.0 }, 10, 2048),
        "mlp_fashion" => (ModelKind::Mlp { layers: vec![784, 128, 10], l2: 0.0 }, 10, 2048),
        "transformer" => (
            ModelKind::Transformer { vocab: 64, seq: 32, d_model: 64, n_layers: 2 },
            10,
            64,
        ),
        _ => return None,
    };
    Some(k)
}

fn quant_series(base: &ExperimentConfig, tau: usize, r: usize) -> Vec<ExperimentConfig> {
    let mut v: Vec<ExperimentConfig> = [1u32, 5, 10]
        .iter()
        .map(|&s| {
            base.clone()
                .with_tau(tau)
                .with_r(r)
                .with_codec(CodecSpec::qsgd(s))
                .with_name(format!("FedPAQ s={s}"))
        })
        .collect();
    v.push(
        base.clone()
            .with_tau(tau)
            .with_r(r)
            .with_codec(CodecSpec::Identity)
            .with_name("FedAvg (no quant)"),
    );
    v
}

fn r_series(base: &ExperimentConfig, s: u32, tau: usize, rs: &[usize]) -> Vec<ExperimentConfig> {
    rs.iter()
        .map(|&r| {
            base.clone()
                .with_tau(tau)
                .with_r(r)
                .with_codec(CodecSpec::qsgd(s))
                .with_name(format!("r={r}"))
        })
        .collect()
}

fn tau_series(base: &ExperimentConfig, s: u32, r: usize, taus: &[usize]) -> Vec<ExperimentConfig> {
    taus.iter()
        .map(|&tau| {
            base.clone()
                .with_tau(tau)
                .with_r(r)
                .with_codec(CodecSpec::qsgd(s))
                .with_name(format!("tau={tau}"))
        })
        .collect()
}

fn bench_series(
    base: &ExperimentConfig,
    fedpaq: (u32, usize, usize),
    fedavg: (usize, usize),
    qsgd_r: usize,
) -> Vec<ExperimentConfig> {
    let (s, r, tau) = fedpaq;
    vec![
        base.clone()
            .with_tau(tau)
            .with_r(r)
            .with_codec(CodecSpec::qsgd(s))
            .with_name("FedPAQ"),
        base.clone()
            .with_tau(fedavg.1)
            .with_r(fedavg.0)
            .with_codec(CodecSpec::Identity)
            .with_name("FedAvg"),
        base.clone()
            .with_tau(1)
            .with_r(qsgd_r)
            .with_codec(CodecSpec::qsgd(s))
            .with_name("QSGD"),
    ]
}

/// The standard 4-plot grid (s / r / τ / benchmarks) for one NN workload.
fn nn_grid(
    fig: &str,
    model: &str,
    dataset: DatasetKind,
    titles: &str,
    eta: f32,
) -> Vec<FigureSpec> {
    let base = ExperimentConfig {
        model: model.into(),
        dataset,
        lr: LrSchedule::Const { eta },
        ..ExperimentConfig::fig1_nn_base()
    };
    vec![
        FigureSpec {
            id: format!("{fig}a"),
            title: format!("{titles}: quantization levels (tau=2, r=25)"),
            configs: quant_series(&base, 2, 25),
        },
        FigureSpec {
            id: format!("{fig}b"),
            title: format!("{titles}: participation (s=1, tau=2)"),
            configs: r_series(&base, 1, 2, &[5, 10, 25, 50]),
        },
        FigureSpec {
            id: format!("{fig}c"),
            title: format!("{titles}: period length (s=1, r=25)"),
            configs: tau_series(&base, 1, 25, &[1, 2, 5, 10, 20, 50]),
        },
        FigureSpec {
            id: format!("{fig}d"),
            title: format!("{titles}: FedPAQ vs FedAvg vs QSGD"),
            configs: bench_series(&base, (1, 20, 10), (20, 10), 50),
        },
    ]
}

/// Every figure in the paper (Fig 1 top = fig1a–d, Fig 1 bottom =
/// fig1e–h, Figs 2–4 = fig2a–d …), in evaluation order.
pub fn all_figures() -> Vec<FigureSpec> {
    let mut out = Vec::new();
    // --- Fig 1 top: logistic regression on (synthetic) MNIST 0-vs-8.
    let base = ExperimentConfig::fig1_logreg_base();
    out.push(FigureSpec {
        id: "fig1a".into(),
        title: "LogReg/MNIST: quantization levels (tau=5, r=25)".into(),
        configs: quant_series(&base, 5, 25),
    });
    out.push(FigureSpec {
        id: "fig1b".into(),
        title: "LogReg/MNIST: participation (s=1, tau=5)".into(),
        configs: r_series(&base, 1, 5, &[5, 10, 25, 50]),
    });
    out.push(FigureSpec {
        id: "fig1c".into(),
        title: "LogReg/MNIST: period length (s=1, r=25)".into(),
        configs: tau_series(&base, 1, 25, &[1, 2, 5, 10, 20, 50]),
    });
    out.push(FigureSpec {
        id: "fig1d".into(),
        title: "LogReg/MNIST: FedPAQ vs FedAvg vs QSGD (r=n=50)".into(),
        configs: bench_series(&base, (1, 50, 2), (50, 2), 50),
    });
    // --- Fig 1 bottom: mlp92k on CIFAR-10 (ids fig1e..fig1h).
    let mut nn = nn_grid("fig1", "mlp92k", DatasetKind::Cifar10, "NN-92K/CIFAR-10", 0.25);
    for (spec, letter) in nn.iter_mut().zip(["e", "f", "g", "h"]) {
        spec.id = format!("fig1{letter}");
    }
    out.extend(nn);
    // --- Fig 2: mlp248k on CIFAR-10.
    out.extend(nn_grid("fig2", "mlp248k", DatasetKind::Cifar10, "NN-248K/CIFAR-10", 0.25));
    // --- Fig 3: one-hidden-layer on CIFAR-100.
    out.extend(nn_grid("fig3", "mlp_c100", DatasetKind::Cifar100, "NN/CIFAR-100", 0.25));
    // --- Fig 4: one-hidden-layer on Fashion-MNIST.
    out.extend(nn_grid("fig4", "mlp_fashion", DatasetKind::FashionMnist, "NN/Fashion-MNIST", 0.25));
    // --- Extension ablation (paper future work): statistical heterogeneity.
    // Dirichlet label skew on the Fashion workload; FedPAQ's local drift
    // grows as alpha shrinks, degrading the tau=10 operating point.
    let base = ExperimentConfig {
        model: "mlp_fashion".into(),
        dataset: DatasetKind::FashionMnist,
        lr: LrSchedule::Const { eta: 0.25 },
        ..ExperimentConfig::fig1_nn_base()
    };
    out.push(FigureSpec {
        id: "ext_noniid".into(),
        title: "EXT NN/Fashion-MNIST: label-skew ablation (s=1, tau=10, r=10)".into(),
        configs: vec![
            base.clone().with_tau(10).with_r(10).with_name("iid"),
            base.clone()
                .with_tau(10)
                .with_r(10)
                .with_partition(crate::data::PartitionKind::Dirichlet { alpha: 1.0 })
                .with_name("dirichlet a=1.0"),
            base.clone()
                .with_tau(10)
                .with_r(10)
                .with_partition(crate::data::PartitionKind::Dirichlet { alpha: 0.1 })
                .with_name("dirichlet a=0.1"),
        ],
    });
    // --- Extension: sync barrier vs buffered-async rounds on the Fig-1-top
    // setup (the §5 cost model's communication–computation tradeoff, now
    // with the straggler barrier removed). Smaller buffers commit sooner
    // per unit virtual time but average staler, noisier updates.
    let base = ExperimentConfig::fig1_logreg_base();
    let damped = crate::coordinator::StalenessRule::inverse();
    out.push(FigureSpec {
        id: "ext_async".into(),
        title: "EXT LogReg/MNIST: sync barrier vs buffered-async (s=1, tau=5, r=25)"
            .into(),
        configs: vec![
            base.clone().with_name("sync barrier"),
            base.clone().with_async(13, 8).with_name("async b=13"),
            base.clone().with_async(5, 8).with_name("async b=5"),
            base.clone()
                .with_async(5, 8)
                .with_staleness_rule(damped)
                .with_name(format!("async b=5 {}", damped.name())),
        ],
    });
    // --- Extension: the full codec family on one workload — loss vs
    // uploaded bits (CurvePoint.bits_up is the x-axis that matters here).
    // One curve per family member: the FedAvg baseline, fixed and
    // adaptive QSGD, both sparsifier families, and error-feedback
    // wrappers showing the memory correcting the sparsifiers' bias.
    let base = ExperimentConfig::fig1_logreg_base();
    out.push(FigureSpec {
        id: "ext_codecs".into(),
        title: "EXT LogReg/MNIST: codec family, loss vs uploaded bits \
                (tau=5, r=25)"
            .into(),
        configs: vec![
            base.clone().with_codec(CodecSpec::Identity).with_name("FedAvg (32b)"),
            base.clone().with_codec(CodecSpec::qsgd(1)).with_name("QSGD s=1"),
            base.clone()
                .with_codec(CodecSpec::Qsgd {
                    s: 4,
                    coding: crate::quant::Coding::Elias,
                })
                .with_name("QSGD s=4 elias"),
            base.clone().with_codec(CodecSpec::top_k(100)).with_name("top-k 10%"),
            base.clone().with_codec(CodecSpec::rand_k(100)).with_name("rand-k 10%"),
            base.clone()
                .with_codec(CodecSpec::adaptive(4))
                .with_name("adaptive 4b"),
            base.clone()
                .with_codec(CodecSpec::error_feedback(CodecSpec::top_k(100)))
                .with_name("ef+top-k 10%"),
            base.clone()
                .with_codec(CodecSpec::error_feedback(CodecSpec::rand_k(100)))
                .with_name("ef+rand-k 10%"),
        ],
    });
    // --- Extension: bidirectional compression — loss vs TOTAL traffic
    // (bits_up + bits_down). The uplink-only runs pay a dense 32-bit
    // broadcast per dispatch; the down_codec runs ship QAFeL-style
    // reference deltas instead. Covers both directions' codec pairings,
    // including an EF-wrapped downlink (the server-side residual stream).
    let base = ExperimentConfig::fig1_logreg_base();
    out.push(FigureSpec {
        id: "ext_bidir".into(),
        title: "EXT LogReg/MNIST: bidirectional compression, loss vs total \
                up+down bits (tau=5, r=25)"
            .into(),
        configs: vec![
            base.clone()
                .with_codec(CodecSpec::qsgd(4))
                .with_name("qsgd4 up / raw down"),
            base.clone()
                .with_codec(CodecSpec::qsgd(4))
                .with_down_codec(CodecSpec::qsgd(4))
                .with_name("qsgd4 up / qsgd4 down"),
            base.clone()
                .with_codec(CodecSpec::top_k(100))
                .with_down_codec(CodecSpec::qsgd(4))
                .with_name("top-k 10% up / qsgd4 down"),
            base.clone()
                .with_codec(CodecSpec::qsgd(4))
                .with_down_codec(CodecSpec::error_feedback(CodecSpec::top_k(100)))
                .with_name("qsgd4 up / ef+top-k down"),
            base.clone()
                .with_codec(CodecSpec::error_feedback(CodecSpec::rand_k(100)))
                .with_down_codec(CodecSpec::adaptive(4))
                .with_name("ef+rand-k up / adaptive4 down"),
        ],
    });
    // Coding ablation: QSGD Elias-omega wire vs the naive fixed-width wire
    // (same stochastic levels, different |Q(p,s)| on the time axis).
    let base = ExperimentConfig::fig1_nn_base();
    out.push(FigureSpec {
        id: "ext_coding".into(),
        title: "EXT NN-92K/CIFAR-10: Elias vs naive level coding (tau=10, r=20)".into(),
        configs: vec![
            base.clone()
                .with_tau(10)
                .with_r(20)
                .with_lr(LrSchedule::Const { eta: 0.25 })
                .with_codec(CodecSpec::Qsgd { s: 4, coding: crate::quant::Coding::Naive })
                .with_name("s=4 naive"),
            base.clone()
                .with_tau(10)
                .with_r(20)
                .with_lr(LrSchedule::Const { eta: 0.25 })
                .with_codec(CodecSpec::Qsgd { s: 4, coding: crate::quant::Coding::Elias })
                .with_name("s=4 elias"),
        ],
    });
    // --- Extension: cohort scale × straggler model — how many commits a
    // target loss costs as the cohort grows from 10^3 to 10^5 clients,
    // under the paper's shifted-exponential stragglers vs a mean-matched
    // heavy-tailed Pareto. O(active) machinery throughout: the active set
    // (r=64, b=16) is held fixed while n grows, shards wrap a capped
    // 16_384-sample dataset, and sampling/dispatch never materialize
    // O(n) state.
    let base = ExperimentConfig::fig1_logreg_base()
        .with_engine(EngineKind::Rust)
        .with_r(64)
        .with_tau(2)
        .with_async(16, 8);
    let mut configs = Vec::new();
    for &n in &[1_000usize, 10_000, 100_000] {
        for dist in [
            crate::simtime::StragglerDist::ShiftedExp,
            crate::simtime::StragglerDist::Pareto { alpha: 1.5 },
        ] {
            configs.push(
                ExperimentConfig {
                    n_nodes: n,
                    per_node: 32,
                    dataset_cap: 16_384,
                    ..base.clone().with_straggler(dist)
                }
                .with_name(format!("n={n} {}", dist.name())),
            );
        }
    }
    out.push(FigureSpec {
        id: "ext_scale".into(),
        title: "EXT LogReg/MNIST: cohort scale x straggler model, async \
                (s=1, tau=2, r=64, b=16)"
            .into(),
        configs,
    });
    out
}

/// Look one figure up by id.
pub fn figure(id: &str) -> Option<FigureSpec> {
    all_figures().into_iter().find(|f| f.id == id)
}

/// Engine cache: one engine per model name, shared across a figure's
/// configs (PJRT compilation happens once).
pub struct Runner {
    engine_kind: EngineKind,
    artifacts: PathBuf,
    client: Option<xla::PjRtClient>,
    engines: HashMap<String, Box<dyn Engine>>,
    /// Optional override: scale T for quick smoke runs.
    pub t_override: Option<usize>,
}

impl Runner {
    pub fn new(engine_kind: EngineKind, artifacts: impl Into<PathBuf>) -> Self {
        Runner {
            engine_kind,
            artifacts: artifacts.into(),
            client: None,
            engines: HashMap::new(),
            t_override: None,
        }
    }

    fn rust_engine(model: &str) -> crate::Result<Box<dyn Engine>> {
        let (kind, batch, eval_n) = zoo_kind(model)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
        Ok(Box::new(RustEngine::new(kind, batch, eval_n)?))
    }

    fn engine_for(&mut self, model: &str) -> crate::Result<&mut Box<dyn Engine>> {
        if !self.engines.contains_key(model) {
            let engine: Box<dyn Engine> = match self.engine_kind {
                EngineKind::Pjrt => {
                    if self.client.is_none() {
                        // No PJRT runtime on this machine (e.g. the
                        // vendored stub bindings): fall back to the
                        // pure-rust oracle, which computes identical
                        // math for the zoo models, instead of dying.
                        match crate::runtime::cpu_client() {
                            Ok(c) => self.client = Some(c),
                            Err(e) => {
                                eprintln!(
                                    "warning: PJRT unavailable ({e}); \
                                     falling back to --engine rust"
                                );
                                let engine = Self::rust_engine(model)?;
                                self.engines.insert(model.to_string(), engine);
                                return Ok(self.engines.get_mut(model).unwrap());
                            }
                        }
                    }
                    Box::new(crate::runtime::PjrtEngine::load(
                        self.client.as_ref().unwrap(),
                        &self.artifacts,
                        model,
                    )?)
                }
                EngineKind::Rust => Self::rust_engine(model)?,
            };
            self.engines.insert(model.to_string(), engine);
        }
        Ok(self.engines.get_mut(model).unwrap())
    }

    /// Run a single config to completion under operator run control:
    /// `ctrl` carries the JSONL event sink, checkpoint cadence, and an
    /// optional checkpoint to resume from (see
    /// [`crate::ops::RunControl`]). Callers without operator needs pass
    /// `RunControl::default()` — the former
    /// `run_config`/`run_config_controlled` pair collapsed into this one
    /// options-taking signature.
    pub fn run_config(
        &mut self,
        mut cfg: ExperimentConfig,
        ctrl: crate::ops::RunControl,
    ) -> crate::Result<crate::coordinator::RunResult> {
        if let Some(t) = self.t_override {
            cfg.t_total = t.max(cfg.tau);
        }
        cfg.engine = self.engine_kind.clone();
        let engine = self.engine_for(&cfg.model.clone())?;
        ServerBuilder::new(cfg)
            .engine(engine.as_mut())
            .control(ctrl)
            .build()?
            .run()
    }

    /// Run a whole figure, returning its curve bundle.
    pub fn run_figure(&mut self, spec: &FigureSpec) -> crate::Result<FigureData> {
        let mut fig = FigureData::new(spec.id.clone(), spec.title.clone());
        for cfg in &spec.configs {
            let label = cfg.name.clone();
            eprintln!("  [{}] running {label} ...", spec.id);
            let res = self.run_config(cfg.clone(), crate::ops::RunControl::default())?;
            fig.curves.push(res.curve);
        }
        Ok(fig)
    }

    /// Run + persist CSV under `out_dir`.
    pub fn run_and_save(
        &mut self,
        spec: &FigureSpec,
        out_dir: &Path,
    ) -> crate::Result<FigureData> {
        let fig = self.run_figure(spec)?;
        let path = fig.write_csv(out_dir)?;
        eprintln!("{}", fig.ascii_summary());
        eprintln!("  wrote {}", path.display());
        Ok(fig)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_figure_ids_unique_and_configs_valid() {
        let figs = all_figures();
        assert_eq!(figs.len(), 26); // 4 + 4 + 4*3 + 6 extensions
        let mut ids: Vec<_> = figs.iter().map(|f| f.id.clone()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 26);
        for f in &figs {
            assert!(!f.configs.is_empty(), "{} empty", f.id);
            for c in &f.configs {
                c.clone().validated().unwrap_or_else(|e| panic!("{}: {e}", f.id));
                assert!(zoo_kind(&c.model).is_some(), "unknown model {}", c.model);
            }
        }
    }

    #[test]
    fn figure_lookup() {
        assert!(figure("fig1c").is_some());
        assert!(figure("nope").is_none());
        let f = figure("fig1d").unwrap();
        let names: Vec<_> = f.configs.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(names, ["FedPAQ", "FedAvg", "QSGD"]);
        // QSGD is tau=1 by definition.
        assert_eq!(f.configs[2].tau, 1);
        // FedAvg is unquantized by definition.
        assert_eq!(f.configs[1].codec, CodecSpec::Identity);
    }

    #[test]
    fn ext_codecs_sweeps_every_family() {
        let f = figure("ext_codecs").unwrap();
        let families: std::collections::HashSet<&str> = f
            .configs
            .iter()
            .map(|c| c.codec.family())
            .collect();
        for fam in ["identity", "qsgd", "topk", "randk", "adaptive_qsgd", "error_feedback"]
        {
            assert!(families.contains(fam), "ext_codecs missing {fam}");
        }
    }

    #[test]
    fn ext_bidir_sweeps_codec_pairs_with_downlink() {
        let f = figure("ext_bidir").unwrap();
        assert!(f.configs.len() >= 4, "need >= 4 up/down pairs");
        // At least one uplink-only baseline and several compressed
        // downlinks, including a stateful (EF) one.
        assert!(f.configs.iter().any(|c| c.down_codec.is_none()));
        assert!(f.configs.iter().filter(|c| c.down_codec.is_some()).count() >= 3);
        assert!(f
            .configs
            .iter()
            .any(|c| matches!(&c.down_codec, Some(d) if d.is_stateful())));
        for c in &f.configs {
            if let Some(d) = &c.down_codec {
                assert!(d.rebuildable(), "{}: downlink spec must be rebuildable", c.name);
            }
        }
    }

    #[test]
    fn rust_runner_smoke_on_tiny_logreg() {
        let mut runner = Runner::new(EngineKind::Rust, "artifacts");
        runner.t_override = Some(10);
        let mut cfg = ExperimentConfig::fig1_logreg_base();
        cfg.n_nodes = 6;
        cfg.per_node = 30;
        cfg.r = 3;
        cfg.tau = 2;
        // eval_n for the rust logreg engine is 10_000 in the zoo; shrink
        // the run world instead by overriding eval via a smaller model? —
        // keep the world big enough for the slab:
        cfg.n_nodes = 50;
        cfg.per_node = 200;
        let res = runner.run_config(cfg, crate::ops::RunControl::default()).unwrap();
        assert!(res.curve.points.len() >= 2);
    }
}
