//! `fedpaq` — CLI launcher for the FedPAQ federated-learning runtime.
//!
//! ```text
//! fedpaq figure <id|all> [--out DIR] [--engine pjrt|rust] [--t N]
//! fedpaq train [--config FILE.json] [--model M] [--s S] [--tau T] ...
//! fedpaq leader [--bind ADDR] [--workers N] [--config FILE.json]
//! fedpaq edge [--connect ROOT] [--bind ADDR] [--workers K]
//! fedpaq worker [--connect ADDR]
//! fedpaq quantize-check [--s S] [--seed SEED]
//! fedpaq info
//! ```
//!
//! Argument parsing is hand-rolled (clap is unavailable offline); flags
//! are `--key value` pairs after the subcommand.

use fedpaq::config::{EngineKind, ExperimentConfig};
use fedpaq::data::DatasetKind;
use fedpaq::figures::{all_figures, figure, Runner};
use fedpaq::opt::LrSchedule;
use fedpaq::quant::{CodecSpec, Coding};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

const USAGE: &str = "\
fedpaq — FedPAQ (AISTATS 2020) reproduction

USAGE:
  fedpaq figure <id|all> [--out DIR] [--engine pjrt|rust] [--t N]
  fedpaq train [--config FILE.json] [--model NAME] [--dataset D] [--nodes N]
               [--per-node M] [--r R] [--tau TAU] [--t T] [--s S] [--elias]
               [--topk PERMILLE] [--rand-k PERMILLE] [--adaptive-bits B]
               [--ef] [--lr ETA] [--ratio X] [--seed SEED]
               [--engine pjrt|rust] [--agg-shards N] [--out-json FILE]
               [--async-rounds] [--buffer-size B] [--max-staleness S]
               [--staleness-rule uniform|polynomial] [--staleness-a A]
               [--down-s S] [--down-topk PERMILLE] [--down-rand-k PERMILLE]
               [--down-adaptive-bits B] [--down-elias] [--down-ef]
               [--straggler shifted_exp|pareto] [--pareto-alpha A]
               [--dataset-cap N]
  (--straggler picks the compute-time straggler model; pareto is the
   heavy-tail variant, mean-matched to shifted_exp, tail index
   --pareto-alpha, default 1.5; --dataset-cap N bounds the generated
   dataset at N samples — i.i.d. shards wrap around it, which is how
   million-client cohorts run in O(r + dataset) memory)
  (codec pick: --topk > --rand-k > --adaptive-bits > --s; --s 0 = identity;
   --elias selects Elias coding, and for --rand-k the explicit-index mode;
   --ef wraps the picked codec in per-node error feedback)
  (--down-* mirror the uplink flags but pick the server->client downlink
   codec — the broadcast ships compressed model deltas; no --down-* flag
   means a dense broadcast, and --down-s 0 = identity-coded deltas)
  (a leading flag implies `train`: `fedpaq --async-rounds --buffer-size 4`)
  fedpaq leader [--bind ADDR] [--workers N] [--config FILE.json] [--engine E]
                [--agg-shards N] [--out-json FILE]
                [--edge-leaders N] [--tree-summed]
  (an async_rounds config runs the buffered-async TcpAsync leader; others
   run the synchronous barrier. --edge-leaders N makes this the root of a
   two-level aggregation tree: N `fedpaq edge` processes connect here and
   workers connect to the edges — needs an async_rounds config. The
   default relay mode commits bit-identically to a flat run;
   --tree-summed re-encodes each cohort wave into one summed frame,
   reproducible per seed, degenerate knobs only — see docs/TOPOLOGY.md)
  fedpaq edge [--connect ROOT] [--bind ADDR] [--workers K]
              [--max-partials N] [--retry-secs S] [--events FILE|-]
  (edge leader for a tree run: dials the root, accepts its cohort of K
   workers, forwards dispatches down and partial updates up;
   --max-partials N exits cleanly after N partials, for churn tests)
  fedpaq worker [--connect ADDR] [--delay-ms N] [--retry-secs S]
                [--max-jobs N] [--events FILE|-]
  fedpaq quantize-check [--s S] [--seed SEED]
  fedpaq info

Run control (train and leader — see docs/OPERATIONS.md):
  --events FILE|-        append JSONL events to FILE (`-` = stderr)
  --checkpoint FILE      write a resumable checkpoint (atomically) to FILE
  --checkpoint-every N   ... every N commits (default 1)
  --stop-after K         checkpoint and exit cleanly after commit K
  --resume FILE          continue a run from a checkpoint; the resumed
                         RunResult is bit-identical to the uninterrupted run

Global: --artifacts DIR (default: artifacts)
";

/// Tiny `--key value` / `--flag` parser over the args after the subcommand.
struct Flags {
    map: HashMap<String, String>,
    positional: Vec<String>,
}

impl Flags {
    fn parse(args: &[String]) -> anyhow::Result<Self> {
        let mut map = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                // Boolean flags have no value or are followed by another --flag.
                let is_bool = matches!(
                    key,
                    "elias"
                        | "fast"
                        | "async-rounds"
                        | "ef"
                        | "down-elias"
                        | "down-ef"
                        | "tree-summed"
                );
                if is_bool {
                    map.insert(key.to_string(), "true".to_string());
                    i += 1;
                } else {
                    let v = args
                        .get(i + 1)
                        .ok_or_else(|| anyhow::anyhow!("flag --{key} needs a value"))?;
                    map.insert(key.to_string(), v.clone());
                    i += 2;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Flags { map, positional })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(String::as_str)
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn parse_num<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
            None => Ok(default),
        }
    }

    fn engine(&self) -> anyhow::Result<EngineKind> {
        match self.get_or("engine", "pjrt").as_str() {
            "pjrt" => Ok(EngineKind::Pjrt),
            "rust" => Ok(EngineKind::Rust),
            other => anyhow::bail!("--engine must be pjrt|rust, got {other}"),
        }
    }
}

/// Build the shared run-control knobs (`--events`, `--checkpoint`,
/// `--checkpoint-every`, `--stop-after`, `--resume`) for the train and
/// leader subcommands.
fn run_control(flags: &Flags) -> anyhow::Result<fedpaq::ops::RunControl> {
    let mut ctrl = fedpaq::ops::RunControl::default();
    if let Some(dest) = flags.get("events") {
        ctrl.events = if dest == "-" || dest == "stderr" {
            fedpaq::ops::EventSink::stderr()
        } else {
            fedpaq::ops::EventSink::to_file(Path::new(dest))?
        };
    }
    ctrl.checkpoint_path = flags.get("checkpoint").map(PathBuf::from);
    ctrl.checkpoint_every = flags.parse_num("checkpoint-every", 1usize)?;
    ctrl.stop_after = flags
        .get("stop-after")
        .map(|v| v.parse().map_err(|e| anyhow::anyhow!("--stop-after {v}: {e}")))
        .transpose()?;
    if let Some(path) = flags.get("resume") {
        let ck = fedpaq::ops::Checkpoint::load(Path::new(path))?;
        eprintln!(
            "resuming {} from {path} (next commit {})",
            ck.id(),
            ck.next_round
        );
        ctrl.resume = Some(ck);
    }
    Ok(ctrl)
}

/// Short human label for a codec spec (run names, figure curve labels).
fn codec_label(codec: &CodecSpec) -> String {
    let coded = |label: String, coding: &Coding| match coding {
        Coding::Naive => label,
        Coding::Elias => format!("{label}+elias"),
    };
    match codec {
        CodecSpec::Identity => "fedavg".to_string(),
        CodecSpec::Qsgd { s, coding } => coded(format!("s={s}"), coding),
        CodecSpec::TopK { k_permille, coding } => {
            coded(format!("topk={k_permille}"), coding)
        }
        CodecSpec::RandK { k_permille, seeded: true } => format!("randk={k_permille}"),
        CodecSpec::RandK { k_permille, seeded: false } => {
            format!("randk={k_permille}+elias")
        }
        CodecSpec::AdaptiveQsgd { bits_per_coord, coding } => {
            coded(format!("adaptive={bits_per_coord}b"), coding)
        }
        CodecSpec::ErrorFeedback { inner } => format!("ef+{}", codec_label(inner)),
        CodecSpec::External { id } => format!("ext={id}"),
    }
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(mut cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    // A leading flag implies the `train` subcommand, so e.g.
    // `fedpaq --async-rounds --buffer-size 4` just works. Help flags keep
    // their usual meaning.
    let flag_args: &[String] = if cmd.starts_with("--") && cmd != "--help" {
        cmd = "train".into();
        &argv
    } else {
        &argv[1..]
    };
    let flags = Flags::parse(flag_args)?;
    let artifacts = PathBuf::from(flags.get_or("artifacts", "artifacts"));

    match cmd.as_str() {
        "figure" => {
            let id = flags
                .positional
                .first()
                .ok_or_else(|| anyhow::anyhow!("figure needs an id or `all`"))?;
            let out = PathBuf::from(flags.get_or("out", "results"));
            let mut runner = Runner::new(flags.engine()?, &artifacts);
            if let Some(t) = flags.get("t") {
                runner.t_override = Some(t.parse()?);
            }
            let specs = if id == "all" {
                all_figures()
            } else {
                vec![figure(id).ok_or_else(|| anyhow::anyhow!("unknown figure {id}"))?]
            };
            for spec in &specs {
                eprintln!("=== {} — {}", spec.id, spec.title);
                runner.run_and_save(spec, &out)?;
            }
        }
        "train" => {
            let mut cfg = if let Some(path) = flags.get("config") {
                ExperimentConfig::from_json_file(Path::new(path))?
            } else {
                let model = flags.get_or("model", "logreg");
                let s: u32 = flags.parse_num("s", 1u32)?;
                let r: usize = flags.parse_num("r", 25usize)?;
                let tau: usize = flags.parse_num("tau", 5usize)?;
                let elias = flags.get("elias").is_some();
                let coding = if elias { Coding::Elias } else { Coding::Naive };
                // Codec selection: --topk wins, then --rand-k, then
                // --adaptive-bits, then --s 0 = identity (FedAvg),
                // otherwise QSGD at --s levels. --ef wraps the result in
                // per-node error feedback.
                let base_codec = if let Some(k) = flags.get("topk") {
                    CodecSpec::TopK {
                        k_permille: k
                            .parse()
                            .map_err(|e| anyhow::anyhow!("--topk {k}: {e}"))?,
                        coding,
                    }
                } else if let Some(k) = flags.get("rand-k") {
                    // --elias selects the explicit Elias-index fallback;
                    // the default seeded mode ships no index payload.
                    CodecSpec::RandK {
                        k_permille: k
                            .parse()
                            .map_err(|e| anyhow::anyhow!("--rand-k {k}: {e}"))?,
                        seeded: !elias,
                    }
                } else if let Some(b) = flags.get("adaptive-bits") {
                    CodecSpec::AdaptiveQsgd {
                        bits_per_coord: b
                            .parse()
                            .map_err(|e| anyhow::anyhow!("--adaptive-bits {b}: {e}"))?,
                        coding,
                    }
                } else if s == 0 {
                    CodecSpec::Identity
                } else {
                    CodecSpec::Qsgd { s, coding }
                };
                let codec = if flags.get("ef").is_some() {
                    CodecSpec::error_feedback(base_codec)
                } else {
                    base_codec
                };
                // Downlink pick mirrors the uplink precedence; with no
                // --down-* flag the broadcast stays dense (None).
                let down_elias = flags.get("down-elias").is_some();
                let down_coding = if down_elias { Coding::Elias } else { Coding::Naive };
                let down_base = if let Some(k) = flags.get("down-topk") {
                    Some(CodecSpec::TopK {
                        k_permille: k
                            .parse()
                            .map_err(|e| anyhow::anyhow!("--down-topk {k}: {e}"))?,
                        coding: down_coding,
                    })
                } else if let Some(k) = flags.get("down-rand-k") {
                    Some(CodecSpec::RandK {
                        k_permille: k
                            .parse()
                            .map_err(|e| anyhow::anyhow!("--down-rand-k {k}: {e}"))?,
                        seeded: !down_elias,
                    })
                } else if let Some(b) = flags.get("down-adaptive-bits") {
                    Some(CodecSpec::AdaptiveQsgd {
                        bits_per_coord: b
                            .parse()
                            .map_err(|e| anyhow::anyhow!("--down-adaptive-bits {b}: {e}"))?,
                        coding: down_coding,
                    })
                } else if let Some(s) = flags.get("down-s") {
                    let s: u32 = s
                        .parse()
                        .map_err(|e| anyhow::anyhow!("--down-s {s}: {e}"))?;
                    Some(if s == 0 {
                        CodecSpec::Identity
                    } else {
                        CodecSpec::Qsgd { s, coding: down_coding }
                    })
                } else {
                    None
                };
                let down_codec = match down_base {
                    Some(base) if flags.get("down-ef").is_some() => {
                        Some(CodecSpec::error_feedback(base))
                    }
                    None if flags.get("down-ef").is_some() => {
                        anyhow::bail!(
                            "--down-ef needs a downlink codec (--down-s/--down-topk/...)"
                        )
                    }
                    other => other,
                };
                let down_label = down_codec
                    .as_ref()
                    .map(|c| format!(" down={}", codec_label(c)))
                    .unwrap_or_default();
                let codec_label = codec_label(&codec);
                let async_rounds = flags.get("async-rounds").is_some();
                let buffer_size: usize = flags.parse_num("buffer-size", 0usize)?;
                let max_staleness: usize = flags.parse_num("max-staleness", 8usize)?;
                let staleness_rule = match flags.get_or("staleness-rule", "uniform").as_str()
                {
                    "uniform" => fedpaq::coordinator::StalenessRule::Uniform,
                    "polynomial" | "poly" => fedpaq::coordinator::StalenessRule::Polynomial {
                        a: flags.parse_num("staleness-a", 1.0f64)?,
                    },
                    other => anyhow::bail!(
                        "--staleness-rule must be uniform|polynomial, got {other}"
                    ),
                };
                let straggler = match flags.get_or("straggler", "shifted_exp").as_str() {
                    "shifted_exp" | "exp" => fedpaq::simtime::StragglerDist::ShiftedExp,
                    "pareto" => fedpaq::simtime::StragglerDist::Pareto {
                        alpha: flags.parse_num("pareto-alpha", 1.5f64)?,
                    },
                    other => anyhow::bail!(
                        "--straggler must be shifted_exp|pareto, got {other}"
                    ),
                };
                let mut cfg = ExperimentConfig {
                    name: String::new(),
                    model,
                    dataset: DatasetKind::parse(&flags.get_or("dataset", "mnist08"))?,
                    n_nodes: flags.parse_num("nodes", 50usize)?,
                    per_node: flags.parse_num("per-node", 200usize)?,
                    r,
                    tau,
                    t_total: flags.parse_num("t", 100usize)?,
                    codec,
                    lr: LrSchedule::Const { eta: flags.parse_num("lr", 0.1f32)? },
                    ratio: flags.parse_num("ratio", 100.0f64)?,
                    seed: flags.parse_num("seed", 42u64)?,
                    eval_every: flags.parse_num("eval-every", 1usize)?,
                    engine: flags.engine()?,
                    partition: match flags.get("dirichlet") {
                        Some(a) => fedpaq::data::PartitionKind::Dirichlet {
                            alpha: a.parse()?,
                        },
                        None => fedpaq::data::PartitionKind::Iid,
                    },
                    async_rounds,
                    buffer_size,
                    max_staleness,
                    staleness_rule,
                    agg_shards: 1,
                    down_codec,
                    straggler,
                    dataset_cap: flags.parse_num("dataset-cap", 0usize)?,
                }
                .validated()?;
                let async_label = if cfg.async_rounds {
                    format!(" async b={}", cfg.effective_buffer_size())
                } else {
                    String::new()
                };
                cfg.name = format!(
                    "{} {codec_label} r={r} tau={tau}{async_label}{down_label}",
                    cfg.model
                );
                cfg
            };
            // Shard count is an execution knob, not an experiment
            // parameter (results are bit-identical for every value), so
            // the flag also overrides config files.
            if let Some(v) = flags.get("agg-shards") {
                cfg.agg_shards = v
                    .parse()
                    .map_err(|e| anyhow::anyhow!("--agg-shards {v}: {e}"))?;
                cfg = cfg.validated()?;
            }
            let mut runner = Runner::new(cfg.engine.clone(), &artifacts);
            let res = runner.run_config(cfg.clone(), run_control(&flags)?)?;
            println!("run: {}", cfg.name);
            println!(
                "rounds: {}  total upload: {} bits  total download: {} bits",
                res.rounds.len(),
                res.total_bits,
                res.total_bits_down
            );
            for p in &res.curve.points {
                println!(
                    "  k={:<4} iter={:<5} time={:<12.3} loss={:.6}",
                    p.round, p.iterations, p.time, p.loss
                );
            }
            // Machine-readable RunResult dump (what the CI determinism
            // leg byte-diffs across seeds and --agg-shards values).
            // Written atomically so a concurrent reader never sees a
            // torn file.
            if let Some(path) = flags.get("out-json") {
                fedpaq::util::fsio::write_atomic_str(
                    Path::new(path),
                    &res.to_json().to_string_pretty(),
                )?;
                println!("wrote {path}");
            }
            if let Some(dir) = flags.get("out") {
                let mut fig = fedpaq::metrics::FigureData::new("train", &cfg.name);
                fig.curves.push(res.curve);
                let path = fig.write_csv(Path::new(dir))?;
                println!("wrote {}", path.display());
            }
        }
        "leader" => {
            let mut cfg = match flags.get("config") {
                Some(path) => ExperimentConfig::from_json_file(Path::new(path))?,
                None => ExperimentConfig::fig1_logreg_base(),
            }
            .with_engine(flags.engine()?);
            if let Some(v) = flags.get("agg-shards") {
                cfg.agg_shards = v
                    .parse()
                    .map_err(|e| anyhow::anyhow!("--agg-shards {v}: {e}"))?;
                cfg = cfg.validated()?;
            }
            let bind = flags.get_or("bind", "127.0.0.1:7070");
            let workers: usize = flags.parse_num("workers", 2usize)?;
            let edge_leaders: usize = flags.parse_num("edge-leaders", 0usize)?;
            let mut engine = fedpaq::net::worker::build_engine(&cfg, &artifacts)?;
            let res = if edge_leaders > 0 {
                fedpaq::net::run_leader_tree(
                    cfg,
                    &bind,
                    edge_leaders,
                    flags.get("tree-summed").is_some(),
                    engine.as_mut(),
                    &artifacts,
                    &run_control(&flags)?,
                )?
            } else {
                anyhow::ensure!(
                    flags.get("tree-summed").is_none(),
                    "--tree-summed needs --edge-leaders N"
                );
                fedpaq::net::run_leader(
                    cfg,
                    &bind,
                    workers,
                    engine.as_mut(),
                    &artifacts,
                    &run_control(&flags)?,
                )?
            };
            println!("distributed run complete: final loss {:?}", res.curve.final_loss());
            for p in &res.curve.points {
                println!("  k={:<4} wall={:<10.3}s loss={:.6}", p.round, p.time, p.loss);
            }
            // Same machine-readable RunResult dump the train subcommand
            // writes — the CI async-TCP leg extracts its time-free
            // portion (python/curve_extract.py) and byte-diffs it.
            if let Some(path) = flags.get("out-json") {
                fedpaq::util::fsio::write_atomic_str(
                    Path::new(path),
                    &res.to_json().to_string_pretty(),
                )?;
                println!("wrote {path}");
            }
        }
        "edge" => {
            let connect = flags.get_or("connect", "127.0.0.1:7070");
            let bind = flags.get_or("bind", "127.0.0.1:0");
            let events = match flags.get("events") {
                Some(dest) if dest == "-" || dest == "stderr" => {
                    fedpaq::ops::EventSink::stderr()
                }
                Some(dest) => fedpaq::ops::EventSink::to_file(Path::new(dest))?,
                None => fedpaq::ops::EventSink::null(),
            };
            let opts = fedpaq::net::EdgeOptions {
                workers: flags.parse_num("workers", 2usize)?,
                max_partials: flags
                    .get("max-partials")
                    .map(|v| {
                        v.parse::<u64>()
                            .map_err(|e| anyhow::anyhow!("--max-partials {v}: {e}"))
                    })
                    .transpose()?,
                events,
            };
            let retry_secs: u64 = flags.parse_num("retry-secs", 10u64)?;
            fedpaq::net::run_edge_retrying(
                &connect,
                &bind,
                opts,
                std::time::Duration::from_secs(retry_secs),
            )?;
        }
        "worker" => {
            let connect = flags.get_or("connect", "127.0.0.1:7070");
            let events = match flags.get("events") {
                Some(dest) if dest == "-" || dest == "stderr" => {
                    fedpaq::ops::EventSink::stderr()
                }
                Some(dest) => fedpaq::ops::EventSink::to_file(Path::new(dest))?,
                None => fedpaq::ops::EventSink::null(),
            };
            let opts = fedpaq::net::WorkerOptions {
                work_delay: flags
                    .get("delay-ms")
                    .map(|v| v.parse::<u64>().map(std::time::Duration::from_millis))
                    .transpose()
                    .map_err(|e| anyhow::anyhow!("--delay-ms: {e}"))?,
                max_jobs: flags
                    .get("max-jobs")
                    .map(|v| v.parse::<u64>().map_err(|e| anyhow::anyhow!("--max-jobs {v}: {e}")))
                    .transpose()?,
                events,
            };
            // Re-dial while the leader is still coming up (makes
            // `worker & worker & leader` launch scripts order-agnostic).
            let retry_secs: u64 = flags.parse_num("retry-secs", 10u64)?;
            fedpaq::net::run_worker_retrying(
                &connect,
                &artifacts,
                opts,
                std::time::Duration::from_secs(retry_secs),
            )?;
        }
        "quantize-check" => {
            let s: u32 = flags.parse_num("s", 4u32)?;
            let seed: u64 = flags.parse_num("seed", 123u64)?;
            let client = fedpaq::runtime::cpu_client()?;
            let kernel = fedpaq::runtime::QuantizeKernel::load(&client, &artifacts)?;
            let mut rng = fedpaq::util::rng::Rng::seed_from_u64(seed);
            let x: Vec<f32> = (0..kernel.p).map(|_| rng.gen_f32() * 2.0 - 1.0).collect();
            let u: Vec<f32> = (0..kernel.p).map(|_| rng.gen_f32()).collect();
            let kq = kernel.run(&x, &u, s as f32)?;
            // Reference levels computed the same way the rust codec does.
            let norm = fedpaq::quant::l2_norm(&x);
            let mut max_err = 0f32;
            for i in 0..kernel.p {
                let a = x[i].abs() / norm * s as f32;
                let lo = a.floor();
                let level = lo + (u[i] < a - lo) as u32 as f32;
                let want = norm * x[i].signum() * level / s as f32;
                max_err = max_err.max((want - kq[i]).abs());
            }
            println!(
                "pallas-vs-rust max abs err over {} coords: {max_err:e}",
                kernel.p
            );
            anyhow::ensure!(max_err < 1e-4, "kernel/codec mismatch");
            println!("quantize-check OK");
        }
        "perf-probe" => {
            // §Perf instrumentation: per-program PJRT dispatch+compute cost.
            let model = flags.get_or("model", "mlp92k");
            let iters: usize = flags.parse_num("iters", 50usize)?;
            let client = fedpaq::runtime::cpu_client()?;
            let mut eng = fedpaq::runtime::PjrtEngine::load(&client, &artifacts, &model)?;
            let (kind, batch, eval_n) = fedpaq::figures::zoo_kind(&model)
                .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
            let d = kind.d_in();
            let p = kind.param_count();
            let mut rng = fedpaq::util::rng::Rng::seed_from_u64(1);
            let params = {
                use fedpaq::model::Engine;
                eng.init_params()?
            };
            let mk_x = |rng: &mut fedpaq::util::rng::Rng, n: usize| -> Vec<f32> {
                (0..n * d).map(|_| rng.gen_f32() - 0.5).collect()
            };
            let float_labels = kind.float_labels();
            let yb_f: Vec<f32> = (0..batch).map(|_| rng.gen_bool(0.5) as u8 as f32).collect();
            let n_lab = if matches!(kind, fedpaq::model::ModelKind::Transformer { seq, .. } if seq > 0)
            {
                batch * d
            } else {
                batch
            };
            let yb_i: Vec<i32> = (0..n_lab).map(|_| rng.gen_range(0, 10) as i32).collect();
            let xb = mk_x(&mut rng, batch);
            use fedpaq::model::{Engine, LabelBatch};
            let yb = || {
                if float_labels { LabelBatch::F32(&yb_f) } else { LabelBatch::I32(&yb_i) }
            };
            // Warmup.
            let _ = eng.sgd_step(&params, &xb, yb(), 0.01)?;
            let t0 = std::time::Instant::now();
            let mut pcur = params.clone();
            for _ in 0..iters {
                pcur = eng.sgd_step(&pcur, &xb, yb(), 0.01)?;
            }
            let step_us = t0.elapsed().as_micros() as f64 / iters as f64;
            // Chained: tau steps with one host roundtrip.
            let tau = 10usize;
            let xs = mk_x(&mut rng, batch * tau);
            let ys_f: Vec<f32> = (0..batch * tau).map(|_| 0.0).collect();
            let ys_i: Vec<i32> = (0..n_lab * tau).map(|_| 0).collect();
            let ys = || {
                if float_labels { LabelBatch::F32(&ys_f) } else { LabelBatch::I32(&ys_i) }
            };
            let lrs = vec![0.01f32; tau];
            let _ = eng.local_sgd(&params, &xs, ys(), &lrs)?;
            let t0 = std::time::Instant::now();
            for _ in 0..iters.div_ceil(tau) {
                let _ = eng.local_sgd(&params, &xs, ys(), &lrs)?;
            }
            let chain_us =
                t0.elapsed().as_micros() as f64 / (iters.div_ceil(tau) * tau) as f64;
            // Eval (cached slab).
            let ex = mk_x(&mut rng, eval_n);
            let ey_f: Vec<f32> = (0..eval_n).map(|_| 1.0).collect();
            let ey_i: Vec<i32> = vec![
                0;
                if float_labels { 0 } else { eval_n * n_lab / batch }
            ];
            let ey = || {
                if float_labels { LabelBatch::F32(&ey_f) } else { LabelBatch::I32(&ey_i) }
            };
            let _ = eng.eval_loss_token(&params, 9, &ex, ey())?;
            let t0 = std::time::Instant::now();
            let evals = 10;
            for _ in 0..evals {
                let _ = eng.eval_loss_token(&params, 9, &ex, ey())?;
            }
            let eval_us = t0.elapsed().as_micros() as f64 / evals as f64;
            println!(
                "perf-probe {model}: p={p} B={batch} eval_n={eval_n}\n  \
                 sgd_step (host roundtrip each): {step_us:9.1} us/step\n  \
                 local_sgd chained tau=10:       {chain_us:9.1} us/step\n  \
                 eval_loss (cached slab):        {eval_us:9.1} us/eval\n  \
                 total execs this probe: {}",
                eng.exec_count
            );
        }
        "info" => {
            println!("models:");
            for name in
                ["logreg", "mlp92k", "mlp248k", "mlp_c100", "mlp_fashion", "transformer"]
            {
                if let Some((kind, batch, eval_n)) = fedpaq::figures::zoo_kind(name) {
                    println!(
                        "  {name:<12} p={:<8} batch={batch} eval_n={eval_n}",
                        kind.param_count()
                    );
                }
            }
            println!("figures:");
            for f in all_figures() {
                println!("  {:<7} {} ({} curves)", f.id, f.title, f.configs.len());
            }
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprint!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
