//! Learning-rate schedules used by the paper's two theorems.
//!
//! * Theorem 1 (strongly convex): `η_{k} = (4/μ) / (kτ + 1)` — decaying
//!   per *round* `k` with period `τ`.
//! * Theorem 2 (non-convex): constant `η = 1/(L√T)`.
//! * Experiments (§5): a constant stepsize whose coefficient is
//!   "finely tuned" — we expose `Const` for that.

/// Stepsize schedule `η_{k,t}` (paper uses per-round schedules, so `t` is
/// unused but kept in the signature for generality).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    /// Constant stepsize.
    Const { eta: f32 },
    /// Theorem-1 decay: `η_k = (4/μ)/(kτ + 1)`, capped at `eta_max` to
    /// respect the `k ≥ k0` warm-up condition without simulating k0 rounds.
    PolyDecay { mu: f32, tau: usize, eta_max: f32 },
    /// Theorem-2 constant: `η = 1/(L√T)`.
    NonConvex { l_smooth: f32, t_total: usize },
}

impl LrSchedule {
    /// Stepsize for local iteration `t` of round `k`.
    pub fn lr(&self, k: usize, _t: usize) -> f32 {
        match *self {
            LrSchedule::Const { eta } => eta,
            LrSchedule::PolyDecay { mu, tau, eta_max } => {
                let eta = (4.0 / mu) / ((k * tau + 1) as f32);
                eta.min(eta_max)
            }
            LrSchedule::NonConvex { l_smooth, t_total } => {
                1.0 / (l_smooth * (t_total as f32).sqrt())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_is_const() {
        let s = LrSchedule::Const { eta: 0.3 };
        assert_eq!(s.lr(0, 0), 0.3);
        assert_eq!(s.lr(99, 5), 0.3);
    }

    #[test]
    fn poly_decays_like_1_over_ktau() {
        let s = LrSchedule::PolyDecay { mu: 2.0, tau: 5, eta_max: 10.0 };
        // 4/μ = 2; at k=1: 2/6; at k=3: 2/16.
        assert!((s.lr(1, 0) - 2.0 / 6.0).abs() < 1e-7);
        assert!((s.lr(3, 0) - 2.0 / 16.0).abs() < 1e-7);
        // Cap applies at k=0: 4/μ/1 = 2 > eta_max? No (10) — so 2.0.
        assert!((s.lr(0, 0) - 2.0).abs() < 1e-7);
        let capped = LrSchedule::PolyDecay { mu: 2.0, tau: 5, eta_max: 0.5 };
        assert_eq!(capped.lr(0, 0), 0.5);
    }

    #[test]
    fn nonconvex_matches_formula() {
        let s = LrSchedule::NonConvex { l_smooth: 4.0, t_total: 100 };
        assert!((s.lr(7, 3) - 1.0 / 40.0).abs() < 1e-7);
    }
}
