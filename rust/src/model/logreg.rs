//! Pure-rust l2-regularized binary logistic regression.
//!
//! Matches `python/compile/model.py::loss_logreg` exactly:
//! `mean softplus(-(2y-1)(x·w + b)) + (l2/2)||w||²` (bias unregularized).
//! Used as the numerical oracle for the PJRT logreg artifacts and as the
//! strongly-convex testbed for the Theorem-1 checks (μ = l2).

/// Model hyper-parameters; params are flat `[w (d), b (1)]`.
#[derive(Debug, Clone, Copy)]
pub struct LogRegModel {
    pub d: usize,
    pub l2: f32,
}

impl LogRegModel {
    pub fn param_count(&self) -> usize {
        self.d + 1
    }

    /// Mean loss over a batch; `x` row-major `[n, d]`, `y ∈ {0,1}`.
    pub fn loss(&self, params: &[f32], x: &[f32], y: &[f32]) -> f32 {
        let n = y.len();
        debug_assert_eq!(x.len(), n * self.d);
        debug_assert_eq!(params.len(), self.d + 1);
        let (w, b) = (&params[..self.d], params[self.d]);
        let mut acc = 0f64;
        for i in 0..n {
            let z = dot(&x[i * self.d..(i + 1) * self.d], w) + b;
            let sgn = 2.0 * y[i] - 1.0;
            acc += softplus((-sgn * z) as f64);
        }
        let reg = 0.5 * self.l2 as f64 * dot(w, w) as f64;
        (acc / n as f64 + reg) as f32
    }

    /// Mean gradient over a batch (same layout as params).
    pub fn grad(&self, params: &[f32], x: &[f32], y: &[f32]) -> Vec<f32> {
        let n = y.len();
        let (w, b) = (&params[..self.d], params[self.d]);
        let mut g = vec![0f32; self.d + 1];
        for i in 0..n {
            let row = &x[i * self.d..(i + 1) * self.d];
            let z = dot(row, w) + b;
            let sgn = 2.0 * y[i] - 1.0;
            // d/dz softplus(-sgn z) = -sgn * sigmoid(-sgn z)
            let coef = -sgn * sigmoid(-sgn * z) / n as f32;
            for (gj, &xj) in g[..self.d].iter_mut().zip(row) {
                *gj += coef * xj;
            }
            g[self.d] += coef;
        }
        for (gj, &wj) in g[..self.d].iter_mut().zip(w) {
            *gj += self.l2 * wj;
        }
        g
    }

    /// Smoothness constant upper bound `L ≤ λ_max(XᵀX)/4n + l2`; we use the
    /// cheap bound `max_i ||x_i||²/4 + l2` for stepsize guards.
    pub fn smoothness_bound(&self, x: &[f32], n: usize) -> f32 {
        let mut max_sq = 0f32;
        for i in 0..n {
            let r = &x[i * self.d..(i + 1) * self.d];
            max_sq = max_sq.max(dot(r, r));
        }
        max_sq / 4.0 + self.l2
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f32;
    for (&x, &y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

#[inline]
fn softplus(z: f64) -> f64 {
    // log(1 + e^z), stable form.
    if z > 30.0 {
        z
    } else {
        z.max(0.0) + (1.0 + (-z.abs()).exp()).ln()
    }
}

#[inline]
fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> (LogRegModel, Vec<f32>, Vec<f32>, Vec<f32>) {
        let m = LogRegModel { d: 3, l2: 0.1 };
        let params = vec![0.2, -0.4, 0.7, 0.05];
        let x = vec![1.0, 0.5, -1.0, /* row2 */ -0.3, 0.8, 0.2];
        let y = vec![1.0, 0.0];
        (m, params, x, y)
    }

    #[test]
    fn zero_params_gives_ln2() {
        let m = LogRegModel { d: 4, l2: 0.0 };
        let p = vec![0.0; 5];
        let x = vec![0.3; 8];
        let y = vec![1.0, 0.0];
        let l = m.loss(&p, &x, &y);
        assert!((l - core::f32::consts::LN_2).abs() < 1e-6);
    }

    #[test]
    fn grad_matches_finite_difference() {
        let (m, params, x, y) = toy();
        let g = m.grad(&params, &x, &y);
        let eps = 1e-3f32;
        for j in 0..params.len() {
            let mut pp = params.clone();
            pp[j] += eps;
            let lp = m.loss(&pp, &x, &y);
            pp[j] -= 2.0 * eps;
            let lm = m.loss(&pp, &x, &y);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - g[j]).abs() < 2e-3,
                "param {j}: fd {fd} vs grad {}",
                g[j]
            );
        }
    }

    #[test]
    fn gd_descends_to_small_gradient() {
        let (m, mut p, x, y) = toy();
        let mut last = m.loss(&p, &x, &y);
        for _ in 0..500 {
            let g = m.grad(&p, &x, &y);
            for (pi, gi) in p.iter_mut().zip(&g) {
                *pi -= 0.5 * gi;
            }
            let l = m.loss(&p, &x, &y);
            assert!(l <= last + 1e-5);
            last = l;
        }
        let g = m.grad(&p, &x, &y);
        let gn: f32 = g.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(gn < 1e-3, "gradient norm {gn}");
    }
}
