//! Pure-rust ReLU MLP with softmax cross-entropy — the non-convex oracle.
//!
//! Mirrors `python/compile/model.py::loss_mlp` (same layer layout, same
//! flat parameter order: per layer `[W (fan_in×fan_out row-major), b]`).
//! Init differs from JAX (different RNG), so cross-engine tests compare
//! *math* (loss/grad at given params), not training trajectories.

use crate::util::rng::Rng;

/// Model hyper-parameters. `layers = [d_in, h1, ..., n_classes]`.
#[derive(Debug, Clone)]
pub struct MlpModel {
    pub layers: Vec<usize>,
    pub l2: f32,
}

/// He-normal init over the flat layout (deterministic in `seed`).
pub fn he_init(layers: &[usize], seed: u64) -> Vec<f32> {
    let mut rng = Rng::from_coords(seed, &[0x11e_1417]);
    let mut out = Vec::new();
    for w in layers.windows(2) {
        let (fi, fo) = (w[0], w[1]);
        let scale = (2.0 / fi as f32).sqrt();
        out.extend((0..fi * fo).map(|_| rng.gen_normal() * scale));
        out.extend(std::iter::repeat(0f32).take(fo));
    }
    out
}

impl MlpModel {
    pub fn param_count(&self) -> usize {
        self.layers.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
    }

    /// Forward pass storing post-activation values per layer (for backprop).
    /// Returns (activations per layer incl. input, logits).
    fn forward(&self, params: &[f32], x: &[f32], n: usize) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
        let mut off = 0usize;
        let last = self.layers.len() - 2;
        for (li, w) in self.layers.windows(2).enumerate() {
            let (fi, fo) = (w[0], w[1]);
            let wmat = &params[off..off + fi * fo];
            let bias = &params[off + fi * fo..off + fi * fo + fo];
            off += fi * fo + fo;
            let inp = acts.last().unwrap();
            let mut out = vec![0f32; n * fo];
            matmul_bias(inp, wmat, bias, &mut out, n, fi, fo);
            if li != last {
                for v in out.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            acts.push(out);
        }
        let logits = acts.pop().unwrap();
        (acts, logits)
    }

    /// Mean softmax-CE (+ l2) over a batch; `y` int class labels.
    pub fn loss(&self, params: &[f32], x: &[f32], y: &[i32]) -> f32 {
        let n = y.len();
        let (_, logits) = self.forward(params, x, n);
        let c = *self.layers.last().unwrap();
        let mut acc = 0f64;
        for i in 0..n {
            let row = &logits[i * c..(i + 1) * c];
            acc += (logsumexp(row) - row[y[i] as usize]) as f64;
        }
        let mut loss = (acc / n as f64) as f32;
        if self.l2 > 0.0 {
            let ss: f32 = params.iter().map(|v| v * v).sum();
            loss += 0.5 * self.l2 * ss;
        }
        loss
    }

    /// Mean gradient over a batch (flat layout, same as params).
    pub fn grad(&self, params: &[f32], x: &[f32], y: &[i32]) -> Vec<f32> {
        let n = y.len();
        let (acts, logits) = self.forward(params, x, n);
        let c = *self.layers.last().unwrap();
        // dL/dlogits = (softmax - onehot)/n
        let mut delta = vec![0f32; n * c];
        for i in 0..n {
            let row = &logits[i * c..(i + 1) * c];
            let lz = logsumexp(row);
            for j in 0..c {
                delta[i * c + j] = (row[j] - lz).exp() / n as f32;
            }
            delta[i * c + y[i] as usize] -= 1.0 / n as f32;
        }
        let mut grads = vec![0f32; self.param_count()];
        // Walk layers backwards; `delta` is dL/d(pre-activation of layer li).
        let mut offsets = Vec::new();
        {
            let mut off = 0;
            for w in self.layers.windows(2) {
                offsets.push(off);
                off += w[0] * w[1] + w[1];
            }
        }
        let nl = self.layers.len() - 1;
        for li in (0..nl).rev() {
            let (fi, fo) = (self.layers[li], self.layers[li + 1]);
            let off = offsets[li];
            let inp = &acts[li]; // [n, fi]
            // dW = inpᵀ · delta ; db = Σ_i delta
            {
                let (gw, gb) = grads[off..off + fi * fo + fo].split_at_mut(fi * fo);
                for i in 0..n {
                    let drow = &delta[i * fo..(i + 1) * fo];
                    let xrow = &inp[i * fi..(i + 1) * fi];
                    for a in 0..fi {
                        let xa = xrow[a];
                        if xa != 0.0 {
                            let gwrow = &mut gw[a * fo..(a + 1) * fo];
                            for (g, &d) in gwrow.iter_mut().zip(drow) {
                                *g += xa * d;
                            }
                        }
                    }
                    for (g, &d) in gb.iter_mut().zip(drow) {
                        *g += d;
                    }
                }
            }
            if li > 0 {
                // delta_prev = (delta · Wᵀ) ⊙ relu'(act_prev)
                let wmat = &params[off..off + fi * fo];
                let mut nd = vec![0f32; n * fi];
                for i in 0..n {
                    let drow = &delta[i * fo..(i + 1) * fo];
                    let ndrow = &mut nd[i * fi..(i + 1) * fi];
                    for a in 0..fi {
                        let wrow = &wmat[a * fo..(a + 1) * fo];
                        let mut acc = 0f32;
                        for (w, &d) in wrow.iter().zip(drow) {
                            acc += w * d;
                        }
                        ndrow[a] = acc;
                    }
                    let arow = &acts[li][i * fi..(i + 1) * fi];
                    for (v, &a) in ndrow.iter_mut().zip(arow) {
                        if a <= 0.0 {
                            *v = 0.0;
                        }
                    }
                }
                delta = nd;
            }
        }
        if self.l2 > 0.0 {
            for (g, &p) in grads.iter_mut().zip(params) {
                *g += self.l2 * p;
            }
        }
        grads
    }
}

/// `out[n, fo] = x[n, fi] · w[fi, fo] + b`, row-major.
fn matmul_bias(x: &[f32], w: &[f32], b: &[f32], out: &mut [f32], n: usize, fi: usize, fo: usize) {
    for i in 0..n {
        let orow = &mut out[i * fo..(i + 1) * fo];
        orow.copy_from_slice(b);
        let xrow = &x[i * fi..(i + 1) * fi];
        for a in 0..fi {
            let xa = xrow[a];
            if xa != 0.0 {
                let wrow = &w[a * fo..(a + 1) * fo];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xa * wv;
                }
            }
        }
    }
}

fn logsumexp(row: &[f32]) -> f32 {
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    m + row.iter().map(|&v| (v - m).exp()).sum::<f32>().ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> (MlpModel, Vec<f32>, Vec<f32>, Vec<i32>) {
        let m = MlpModel { layers: vec![4, 5, 3], l2: 0.01 };
        let p = he_init(&m.layers, 42);
        let x: Vec<f32> = (0..8).map(|i| ((i * 13 % 7) as f32 - 3.0) * 0.3).collect();
        let y = vec![0, 2];
        (m, p, x, y)
    }

    #[test]
    fn param_count_matches_layout() {
        let m = MlpModel { layers: vec![4, 5, 3], l2: 0.0 };
        assert_eq!(m.param_count(), 4 * 5 + 5 + 5 * 3 + 3);
        assert_eq!(he_init(&m.layers, 0).len(), m.param_count());
    }

    #[test]
    fn uniform_logits_loss_is_ln_c() {
        let m = MlpModel { layers: vec![3, 4], l2: 0.0 };
        let p = vec![0.0; m.param_count()];
        let x = vec![0.5; 6];
        let y = vec![1, 3];
        assert!((m.loss(&p, &x, &y) - (4f32).ln()).abs() < 1e-6);
    }

    #[test]
    fn grad_matches_finite_difference() {
        let (m, p, x, y) = toy();
        let g = m.grad(&p, &x, &y);
        let eps = 1e-2f32;
        // Spot-check a spread of parameter indices (full fd is O(p²)).
        for j in (0..p.len()).step_by(3) {
            let mut pp = p.clone();
            pp[j] += eps;
            let lp = m.loss(&pp, &x, &y);
            pp[j] -= 2.0 * eps;
            let lm = m.loss(&pp, &x, &y);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - g[j]).abs() < 5e-3,
                "param {j}: fd {fd} vs grad {}",
                g[j]
            );
        }
    }

    #[test]
    fn sgd_descends() {
        let (m, mut p, x, y) = toy();
        let l0 = m.loss(&p, &x, &y);
        for _ in 0..200 {
            let g = m.grad(&p, &x, &y);
            for (pi, gi) in p.iter_mut().zip(&g) {
                *pi -= 0.1 * gi;
            }
        }
        let l1 = m.loss(&p, &x, &y);
        assert!(l1 < l0 * 0.5, "{l0} -> {l1}");
    }
}
