//! Model abstractions + a pure-rust reference engine.
//!
//! The coordinator drives training through the [`Engine`] trait so the same
//! Algorithm-1 loop runs on either backend:
//!
//! * [`crate::runtime::PjrtEngine`] — the production path: AOT-lowered
//!   JAX/Pallas HLO executed via PJRT (python never runs).
//! * [`RustEngine`] (here) — a dependency-free reimplementation of the
//!   logreg/MLP forward+backward used as a numerical oracle in tests, for
//!   proptest (no PJRT startup cost), and as a fallback engine.
//!
//! Both engines share batch RNG and quantization codecs, so for equal
//! seeds they follow the same sample paths up to f32 round-off.

pub mod logreg;
pub mod mlp;

/// Structural description of a model variant (mirrors `python/compile/model.py`).
#[derive(Debug, Clone, PartialEq)]
pub enum ModelKind {
    /// l2-regularized binary logistic regression (strongly convex).
    LogReg { d: usize, l2: f32 },
    /// ReLU MLP with softmax cross-entropy; `layers = [d_in, ..., classes]`.
    Mlp { layers: Vec<usize>, l2: f32 },
    /// Tiny GPT (PJRT engine only).
    Transformer { vocab: usize, seq: usize, d_model: usize, n_layers: usize },
}

impl ModelKind {
    /// Total flat parameter count `p`.
    pub fn param_count(&self) -> usize {
        match self {
            ModelKind::LogReg { d, .. } => d + 1,
            ModelKind::Mlp { layers, .. } => layers
                .windows(2)
                .map(|w| w[0] * w[1] + w[1])
                .sum(),
            ModelKind::Transformer { vocab, seq, d_model, n_layers } => {
                let d = *d_model;
                let f = 4 * d;
                let per = 4 * d * d + 4 * d + d * f + f + f * d + d + 4 * d;
                vocab * d + seq * d + n_layers * per + 2 * d + d * vocab + vocab
            }
        }
    }

    /// Input feature dimension per sample (seq length for the LM).
    pub fn d_in(&self) -> usize {
        match self {
            ModelKind::LogReg { d, .. } => *d,
            ModelKind::Mlp { layers, .. } => layers[0],
            ModelKind::Transformer { seq, .. } => *seq,
        }
    }

    /// Whether labels are f32 (binary) or i32 (classes / tokens).
    pub fn float_labels(&self) -> bool {
        matches!(self, ModelKind::LogReg { .. })
    }
}

/// A minibatch of labels, borrowing from the dataset gather buffers.
#[derive(Debug, Clone, Copy)]
pub enum LabelBatch<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl LabelBatch<'_> {
    pub fn len(&self) -> usize {
        match self {
            LabelBatch::F32(v) => v.len(),
            LabelBatch::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Training backend: everything the FedPAQ loop needs from a model.
pub trait Engine {
    /// Model structure this engine is serving.
    fn kind(&self) -> &ModelKind;

    /// Flat parameter count.
    fn param_count(&self) -> usize {
        self.kind().param_count()
    }

    /// Minibatch size the step program was compiled for.
    fn batch(&self) -> usize;

    /// Initial parameter vector (deterministic; identical across engines).
    fn init_params(&mut self) -> crate::Result<Vec<f32>>;

    /// One SGD step on a `batch()`-sized minibatch; returns new params.
    fn sgd_step(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: LabelBatch<'_>,
        lr: f32,
    ) -> crate::Result<Vec<f32>>;

    /// Training loss on an eval slab of exactly `eval_n()` examples.
    fn eval_loss(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: LabelBatch<'_>,
    ) -> crate::Result<f32>;

    /// Eval-slab size the loss program was compiled for.
    fn eval_n(&self) -> usize;

    /// Run `lrs.len()` chained local SGD steps (Algorithm 1 lines 6–10).
    ///
    /// `xs` holds the τ minibatches back-to-back (`τ·B·d_in` floats) and
    /// `ys` the matching labels. The default implementation loops
    /// [`Engine::sgd_step`] on the host; `PjrtEngine` overrides it to keep
    /// the parameters on-device across all τ executions.
    fn local_sgd(
        &mut self,
        params: &[f32],
        xs: &[f32],
        ys: LabelBatch<'_>,
        lrs: &[f32],
    ) -> crate::Result<Vec<f32>> {
        let tau = lrs.len();
        let b = self.batch();
        let d = self.kind().d_in();
        anyhow::ensure!(xs.len() == tau * b * d, "xs: {} != {tau}x{b}x{d}", xs.len());
        let mut p = params.to_vec();
        for (t, &lr) in lrs.iter().enumerate() {
            let x = &xs[t * b * d..(t + 1) * b * d];
            p = match ys {
                LabelBatch::F32(v) => {
                    let per = v.len() / tau;
                    self.sgd_step(&p, x, LabelBatch::F32(&v[t * per..(t + 1) * per]), lr)?
                }
                LabelBatch::I32(v) => {
                    let per = v.len() / tau;
                    self.sgd_step(&p, x, LabelBatch::I32(&v[t * per..(t + 1) * per]), lr)?
                }
            };
        }
        Ok(p)
    }

    /// Loss evaluation where `token` identifies an immutable eval slab, so
    /// engines may cache the uploaded tensors across rounds.
    fn eval_loss_token(
        &mut self,
        params: &[f32],
        _token: u64,
        x: &[f32],
        y: LabelBatch<'_>,
    ) -> crate::Result<f32> {
        self.eval_loss(params, x, y)
    }

    /// Full-slab gradient, if this engine exports one (theory checks).
    fn grad(
        &mut self,
        _params: &[f32],
        _x: &[f32],
        _y: LabelBatch<'_>,
    ) -> crate::Result<Vec<f32>> {
        anyhow::bail!("engine does not export a gradient program")
    }
}

pub use logreg::LogRegModel;
pub use mlp::MlpModel;

/// Pure-rust engine over [`LogRegModel`] / [`MlpModel`].
#[derive(Debug, Clone)]
pub struct RustEngine {
    kind: ModelKind,
    batch: usize,
    eval_n: usize,
    seed: u64,
}

impl RustEngine {
    pub fn new(kind: ModelKind, batch: usize, eval_n: usize) -> crate::Result<Self> {
        if matches!(kind, ModelKind::Transformer { .. }) {
            anyhow::bail!("RustEngine does not implement the transformer; use PjrtEngine");
        }
        Ok(Self { kind, batch, eval_n, seed: 0 })
    }
}

impl Engine for RustEngine {
    fn kind(&self) -> &ModelKind {
        &self.kind
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn eval_n(&self) -> usize {
        self.eval_n
    }

    fn init_params(&mut self) -> crate::Result<Vec<f32>> {
        match &self.kind {
            ModelKind::LogReg { .. } => Ok(vec![0.0; self.kind.param_count()]),
            ModelKind::Mlp { layers, .. } => Ok(mlp::he_init(layers, self.seed)),
            _ => unreachable!(),
        }
    }

    fn sgd_step(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: LabelBatch<'_>,
        lr: f32,
    ) -> crate::Result<Vec<f32>> {
        let mut out = params.to_vec();
        match (&self.kind, y) {
            (ModelKind::LogReg { d, l2 }, LabelBatch::F32(y)) => {
                let m = LogRegModel { d: *d, l2: *l2 };
                let g = m.grad(params, x, y);
                for (p, gi) in out.iter_mut().zip(g) {
                    *p -= lr * gi;
                }
            }
            (ModelKind::Mlp { layers, l2 }, LabelBatch::I32(y)) => {
                let m = MlpModel { layers: layers.clone(), l2: *l2 };
                let g = m.grad(params, x, y);
                for (p, gi) in out.iter_mut().zip(g) {
                    *p -= lr * gi;
                }
            }
            _ => anyhow::bail!("label type does not match model kind"),
        }
        Ok(out)
    }

    fn eval_loss(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: LabelBatch<'_>,
    ) -> crate::Result<f32> {
        match (&self.kind, y) {
            (ModelKind::LogReg { d, l2 }, LabelBatch::F32(y)) => {
                Ok(LogRegModel { d: *d, l2: *l2 }.loss(params, x, y))
            }
            (ModelKind::Mlp { layers, l2 }, LabelBatch::I32(y)) => {
                Ok(MlpModel { layers: layers.clone(), l2: *l2 }.loss(params, x, y))
            }
            _ => anyhow::bail!("label type does not match model kind"),
        }
    }

    fn grad(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: LabelBatch<'_>,
    ) -> crate::Result<Vec<f32>> {
        match (&self.kind, y) {
            (ModelKind::LogReg { d, l2 }, LabelBatch::F32(y)) => {
                Ok(LogRegModel { d: *d, l2: *l2 }.grad(params, x, y))
            }
            (ModelKind::Mlp { layers, l2 }, LabelBatch::I32(y)) => {
                Ok(MlpModel { layers: layers.clone(), l2: *l2 }.grad(params, x, y))
            }
            _ => anyhow::bail!("label type does not match model kind"),
        }
    }
}
