//! Random-k sparsification ([`RandKCodec`]): keep `k` uniformly random
//! coordinates, scaled by `p/k` so the compressor is **unbiased**
//! (`E[Q(x)] = x`, Assumption 1 applies with `q = p/k − 1`).
//!
//! Two index codings share one value stream:
//!
//! * **seeded** (the default): the kept set is a deterministic function
//!   of a 64-bit `index_seed` drawn from the caller's quantizer RNG and
//!   written into the frame header — decode regenerates the identical
//!   set, so the wire carries **no index payload** at all
//!   (`64 + 32·k` bits, exactly);
//! * **explicit**: indices ship as Elias-ω delta codes over the
//!   ascending sequence, exactly like [`TopKCodec`](super::TopKCodec)'s
//!   Elias mode — the fallback when frames must be self-contained.
//!
//! Both modes select the same set for the same RNG state, so switching
//! the coding changes only the wire size, never the training trajectory.

use super::bitstream::BitWriter;
use super::{
    accumulate_one, check_accumulate, check_range, check_spec, sparse_decode_elias,
    sparse_encode_elias, sparse_scan_elias, CodecSpec, Encoded, FrameHeader, UpdateCodec,
};
use crate::util::rng::Rng;

/// Random-k sparsification keeping `max(1, p·k_permille/1000)` uniformly
/// random coordinates at full precision, scaled by `p/k` at decode.
#[derive(Debug, Clone, Copy)]
pub struct RandKCodec {
    pub k_permille: u16,
    /// `true`: regenerate indices from the frame-header seed (no index
    /// payload). `false`: explicit Elias-ω delta-coded indices.
    pub seeded: bool,
}

impl RandKCodec {
    /// Seeded random-k keeping `k_permille`/1000 of the coordinates.
    pub fn new(k_permille: u16) -> Self {
        RandKCodec { k_permille, seeded: true }
    }

    /// Number of kept coordinates for a length-`p` vector.
    pub fn k_of(&self, p: usize) -> usize {
        if p == 0 {
            0
        } else {
            (p * self.k_permille as usize / 1000).clamp(1, p)
        }
    }

    /// The unbiasing scale `p/k` applied to kept values at decode.
    fn scale(&self, p: usize) -> f32 {
        let k = self.k_of(p);
        if k == 0 {
            1.0
        } else {
            p as f32 / k as f32
        }
    }
}

/// The deterministic kept set for `(index_seed, p, k)`: `k` distinct
/// indices in `0..p`, ascending. Floyd's sampling (k RNG draws, exact
/// uniformity over k-subsets) with an order-independent final sort, so
/// encode and decode — possibly on different machines — regenerate the
/// identical set. This function IS the seeded wire contract: changing it
/// invalidates every in-flight seeded rand-k frame.
pub fn rand_k_indices(index_seed: u64, p: usize, k: usize) -> Vec<u32> {
    debug_assert!(k <= p);
    let mut rng = Rng::seed_from_u64(index_seed);
    let mut chosen = std::collections::HashSet::with_capacity(k);
    let mut out = Vec::with_capacity(k);
    for j in (p - k)..p {
        let t = rng.gen_below(j as u64 + 1) as u32;
        // Floyd: take t unless already taken, then take j itself.
        let pick = if chosen.insert(t) { t } else { j as u32 };
        if pick != t {
            chosen.insert(pick);
        }
        out.push(pick);
    }
    out.sort_unstable();
    out
}

impl UpdateCodec for RandKCodec {
    fn spec(&self) -> CodecSpec {
        CodecSpec::RandK { k_permille: self.k_permille, seeded: self.seeded }
    }

    fn encode(&self, x: &[f32], rng: &mut Rng) -> Encoded {
        let p = x.len();
        let k = self.k_of(p);
        // Both modes burn exactly one u64 of the caller's stream for the
        // index seed, so seeded and explicit encodes of the same state
        // keep identical downstream RNG positions (and identical sets).
        let index_seed = rng.next_u64();
        let idx = rand_k_indices(index_seed, p, k);
        let mut w = BitWriter::new();
        if self.seeded {
            w.write_bits(index_seed, 64);
            for &i in &idx {
                w.write_f32(x[i as usize]);
            }
        } else {
            // Explicit fallback: the same Elias delta-index pair stream
            // top-k's Elias mode speaks (shared implementation).
            sparse_encode_elias(&mut w, &idx, x);
        }
        Encoded { buf: w.finish(), p, spec: self.spec() }
    }

    fn decode_into(&self, enc: &Encoded, out: &mut Vec<f32>) -> crate::Result<()> {
        // One decode implementation: the full decode is the 0..p range,
        // so the range and full paths can never drift apart.
        self.decode_range(enc, 0, enc.p, out)
    }

    fn decode_range(
        &self,
        enc: &Encoded,
        lo: usize,
        hi: usize,
        out: &mut Vec<f32>,
    ) -> crate::Result<()> {
        check_spec(self.spec(), enc)?;
        check_range(enc.p, lo, hi)?;
        let p = enc.p;
        let k = self.k_of(p);
        let scale = self.scale(p);
        out.clear();
        out.resize(hi - lo, 0.0);
        if self.seeded {
            // Exact data-independent frame size: validate up front (the
            // truncated-frame contract), then the index set is known
            // before any value is read — binary-search the kept indices
            // falling in `lo..hi` and seek straight to their values.
            let expect = 64 + 32 * k as u64;
            anyhow::ensure!(
                enc.buf.len_bits() == expect,
                "rand-k frame truncated or oversized: {} bits, expected {expect} \
                 (k={k}, seeded indices)",
                enc.buf.len_bits()
            );
            let index_seed = enc.buf.reader().read_bits(64);
            let idx = rand_k_indices(index_seed, p, k);
            let j_lo = idx.partition_point(|&i| (i as usize) < lo);
            let j_hi = idx.partition_point(|&i| (i as usize) < hi);
            let mut r = enc.buf.reader_at(64 + 32 * j_lo as u64)?;
            for &i in &idx[j_lo..j_hi] {
                out[i as usize - lo] = scale * r.read_f32();
            }
        } else {
            // Explicit Elias indices: the shared full-stream scan (same
            // validation and truncation errors as top-k's Elias mode),
            // with the unbiasing scale applied to in-window values.
            sparse_decode_elias(enc, k, lo, hi, scale, out, "rand-k")?;
        }
        Ok(())
    }

    fn accumulate_range(
        &self,
        enc: &Encoded,
        lo: usize,
        hi: usize,
        weight: f64,
        sum: &mut [f64],
    ) -> crate::Result<()> {
        check_spec(self.spec(), enc)?;
        check_accumulate(enc.p, lo, hi, weight, sum.len())?;
        let p = enc.p;
        let k = self.k_of(p);
        let scale = self.scale(p);
        // Scatter-add straight into `sum`, skipping the implicit zeros —
        // bit-identical to the scratch path by the trait's
        // no-`-0.0`-accumulator guarantee. Reconstruction expressions are
        // verbatim those of `decode_range` (no 1.0-scale shortcut on the
        // seeded arm, because the decode path has none).
        if self.seeded {
            let expect = 64 + 32 * k as u64;
            anyhow::ensure!(
                enc.buf.len_bits() == expect,
                "rand-k frame truncated or oversized: {} bits, expected {expect} \
                 (k={k}, seeded indices)",
                enc.buf.len_bits()
            );
            let index_seed = enc.buf.reader().read_bits(64);
            let idx = rand_k_indices(index_seed, p, k);
            let j_lo = idx.partition_point(|&i| (i as usize) < lo);
            let j_hi = idx.partition_point(|&i| (i as usize) < hi);
            let mut r = enc.buf.reader_at(64 + 32 * j_lo as u64)?;
            for &i in &idx[j_lo..j_hi] {
                accumulate_one(&mut sum[i as usize - lo], scale * r.read_f32(), weight);
            }
            Ok(())
        } else {
            sparse_scan_elias(enc, k, scale, "rand-k", |i, v| {
                if i >= lo && i < hi {
                    accumulate_one(&mut sum[i - lo], v, weight);
                }
            })
        }
    }

    fn open_frame(&self, enc: &Encoded) -> crate::Result<FrameHeader> {
        check_spec(self.spec(), enc)?;
        if !self.seeded {
            // Explicit Elias streams are scanned sequentially; nothing a
            // header cache could save without decoding values too.
            return Ok(FrameHeader::Opaque);
        }
        let p = enc.p;
        let k = self.k_of(p);
        let expect = 64 + 32 * k as u64;
        anyhow::ensure!(
            enc.buf.len_bits() == expect,
            "rand-k frame truncated or oversized: {} bits, expected {expect} \
             (k={k}, seeded indices)",
            enc.buf.len_bits()
        );
        let index_seed = enc.buf.reader().read_bits(64);
        // The expensive part: Floyd sampling + sort, now once per upload
        // instead of once per shard range.
        Ok(FrameHeader::SparseIndices(rand_k_indices(index_seed, p, k)))
    }

    fn accumulate_range_cached(
        &self,
        enc: &Encoded,
        hdr: &FrameHeader,
        lo: usize,
        hi: usize,
        weight: f64,
        sum: &mut [f64],
    ) -> crate::Result<()> {
        let FrameHeader::SparseIndices(idx) = hdr else {
            return self.accumulate_range(enc, lo, hi, weight, sum);
        };
        // Same validation and arithmetic as `accumulate_range`'s seeded
        // arm, minus the per-range regeneration `open_frame` already did
        // (frame size was validated there; a forged handle still can't
        // overrun — `reader_at` bounds-checks the seek).
        check_spec(self.spec(), enc)?;
        check_accumulate(enc.p, lo, hi, weight, sum.len())?;
        let p = enc.p;
        let k = self.k_of(p);
        let scale = self.scale(p);
        anyhow::ensure!(
            idx.len() == k,
            "cached rand-k header holds {} indices; frame implies k={k}",
            idx.len()
        );
        let j_lo = idx.partition_point(|&i| (i as usize) < lo);
        let j_hi = idx.partition_point(|&i| (i as usize) < hi);
        let mut r = enc.buf.reader_at(64 + 32 * j_lo as u64)?;
        for &i in &idx[j_lo..j_hi] {
            accumulate_one(&mut sum[i as usize - lo], scale * r.read_f32(), weight);
        }
        Ok(())
    }

    fn analytic_bits(&self, p: usize) -> Option<u64> {
        if self.seeded {
            Some(64 + 32 * self.k_of(p) as u64)
        } else {
            // Elias index sizes depend on the (random) gaps.
            None
        }
    }

    /// `q = p/k − 1`: the exact Assumption-1 variance of the unbiased
    /// `(p/k)`-scaled random-k sparsifier (sampling without replacement),
    /// so the paper's Theorem 1/2 machinery applies directly.
    fn variance_q(&self, p: usize) -> f64 {
        let k = self.k_of(p);
        if p == 0 || k == 0 {
            0.0
        } else {
            p as f64 / k as f64 - 1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng(seed: u64) -> Rng {
        Rng::seed_from_u64(seed)
    }

    #[test]
    fn indices_deterministic_distinct_ascending_in_range() {
        for (p, k) in [(10, 3), (1, 1), (100, 100), (1000, 1), (257, 64)] {
            let a = rand_k_indices(7, p, k);
            let b = rand_k_indices(7, p, k);
            assert_eq!(a, b);
            assert_eq!(a.len(), k);
            for w in a.windows(2) {
                assert!(w[0] < w[1], "not strictly ascending: {a:?}");
            }
            assert!(a.iter().all(|&i| (i as usize) < p));
            if k < p {
                assert_ne!(a, rand_k_indices(8, p, k), "seed-insensitive");
            }
        }
    }

    #[test]
    fn index_selection_is_uniform_ish() {
        // Every coordinate should be kept with probability ~k/p.
        let (p, k, trials) = (50usize, 10usize, 4000);
        let mut counts = vec![0usize; p];
        for t in 0..trials {
            for i in rand_k_indices(t as u64, p, k) {
                counts[i as usize] += 1;
            }
        }
        let expect = trials * k / p; // 800
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (expect * 7 / 10..=expect * 13 / 10).contains(&c),
                "coord {i}: kept {c} of ~{expect}"
            );
        }
    }

    #[test]
    fn roundtrip_scales_kept_and_zeroes_rest() {
        let x: Vec<f32> = (0..200).map(|i| ((i as f32) * 0.3).sin() + 0.01).collect();
        for seeded in [true, false] {
            let q = RandKCodec { k_permille: 150, seeded };
            let k = q.k_of(x.len());
            assert_eq!(k, 30);
            let enc = q.encode(&x, &mut rng(1));
            let y = q.decode(&enc).unwrap();
            let scale = x.len() as f32 / k as f32;
            let kept: Vec<usize> = (0..x.len()).filter(|&i| y[i] != 0.0).collect();
            assert_eq!(kept.len(), k, "seeded={seeded}");
            for &i in &kept {
                assert_eq!(y[i], scale * x[i], "coord {i} seeded={seeded}");
            }
        }
    }

    #[test]
    fn seeded_and_explicit_keep_the_same_set_for_the_same_rng() {
        let x: Vec<f32> = (0..300).map(|i| (i as f32 * 0.11).cos()).collect();
        let s = RandKCodec { k_permille: 100, seeded: true };
        let e = RandKCodec { k_permille: 100, seeded: false };
        let es = s.encode(&x, &mut rng(5));
        let ee = e.encode(&x, &mut rng(5));
        assert_eq!(s.decode(&es).unwrap(), e.decode(&ee).unwrap());
        // The seeded wire is index-free: 64 + 32k bits exactly.
        assert_eq!(es.bits(), 64 + 32 * 30);
        assert_eq!(s.analytic_bits(300), Some(64 + 32 * 30));
        assert_eq!(e.analytic_bits(300), None);
    }

    #[test]
    fn unbiased_empirically() {
        let x: Vec<f32> = (0..40).map(|i| ((i as f32) * 0.37).sin()).collect();
        let q = RandKCodec::new(250); // k = 10 of 40
        let mut acc = vec![0f64; x.len()];
        let trials = 6000;
        let mut r = rng(9);
        for _ in 0..trials {
            for (a, v) in acc.iter_mut().zip(q.apply(&x, &mut r).unwrap().0) {
                *a += v as f64;
            }
        }
        for (i, (&xi, &ai)) in x.iter().zip(acc.iter()).enumerate() {
            let mean = ai / trials as f64;
            // sd of one sample ≈ |x_i|·sqrt(p/k−1) ≤ 2; 5σ/√trials bound.
            let tol = 5.0 * 2.0 / (trials as f64).sqrt();
            assert!(
                (mean - xi as f64).abs() < tol,
                "coord {i}: mean {mean} vs {xi} (tol {tol})"
            );
        }
    }

    #[test]
    fn variance_bound_holds_empirically() {
        let p = 64;
        let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.23).cos()).collect();
        let norm2 = x.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
        let q = RandKCodec::new(125); // k = 8, q = 7
        let bound = q.variance_q(p) * norm2;
        let mut err = 0.0f64;
        let trials = 3000;
        let mut r = rng(11);
        for _ in 0..trials {
            let y = q.apply(&x, &mut r).unwrap().0;
            err += x
                .iter()
                .zip(&y)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>();
        }
        let mean_err = err / trials as f64;
        assert!(
            mean_err <= bound * 1.05 + 1e-9,
            "measured {mean_err} > bound {bound}"
        );
    }

    #[test]
    fn truncated_frames_error_on_both_modes() {
        let x: Vec<f32> = (0..60).map(|i| i as f32 * 0.1 + 1.0).collect();
        for seeded in [true, false] {
            let q = RandKCodec { k_permille: 200, seeded };
            let empty = Encoded {
                buf: BitWriter::new().finish(),
                p: 60,
                spec: q.spec(),
            };
            assert!(q.decode(&empty).is_err(), "seeded={seeded}: empty accepted");
            let full = q.encode(&x, &mut rng(3));
            let mut w = BitWriter::new();
            let mut r = full.buf.reader();
            for _ in 0..full.buf.len_bits() / 2 {
                w.write_bit(r.read_bit());
            }
            let cut = Encoded { buf: w.finish(), p: 60, spec: q.spec() };
            assert!(q.decode(&cut).is_err(), "seeded={seeded}: truncated accepted");
        }
    }

    #[test]
    fn cached_accumulate_matches_plain_bit_for_bit() {
        let p = 233;
        let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.19).sin() * 2.0).collect();
        for seeded in [true, false] {
            let q = RandKCodec { k_permille: 300, seeded };
            let enc = q.encode(&x, &mut rng(21));
            let hdr = q.open_frame(&enc).unwrap();
            match (&hdr, seeded) {
                (FrameHeader::SparseIndices(idx), true) => assert_eq!(idx.len(), q.k_of(p)),
                (FrameHeader::Opaque, false) => {}
                _ => panic!("wrong header shape for seeded={seeded}"),
            }
            for (lo, hi) in [(0, p), (0, 0), (0, 1), (50, 121), (200, p)] {
                for w in [1.0f64, 0.625] {
                    let mut plain = vec![0f64; hi - lo];
                    let mut cached = vec![0f64; hi - lo];
                    q.accumulate_range(&enc, lo, hi, w, &mut plain).unwrap();
                    q.accumulate_range_cached(&enc, &hdr, lo, hi, w, &mut cached)
                        .unwrap();
                    let same =
                        plain.iter().zip(&cached).all(|(a, b)| a.to_bits() == b.to_bits());
                    assert!(same, "seeded={seeded} {lo}..{hi} w={w}");
                }
            }
        }
        // A truncated seeded frame must fail at open time, before any
        // shard thread touches it.
        let q = RandKCodec::new(300);
        let full = q.encode(&x, &mut rng(4));
        let mut w = BitWriter::new();
        let mut r = full.buf.reader();
        for _ in 0..full.buf.len_bits() / 2 {
            w.write_bit(r.read_bit());
        }
        let cut = Encoded { buf: w.finish(), p, spec: q.spec() };
        assert!(q.open_frame(&cut).is_err());
    }

    #[test]
    fn decode_range_matches_full_decode_slice() {
        let p = 233;
        let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.19).sin() * 2.0).collect();
        for seeded in [true, false] {
            let q = RandKCodec { k_permille: 300, seeded };
            let enc = q.encode(&x, &mut rng(21));
            let full = q.decode(&enc).unwrap();
            let mut out = Vec::new();
            for (lo, hi) in [(0, p), (0, 0), (p, p), (0, 1), (50, 121), (200, p)] {
                q.decode_range(&enc, lo, hi, &mut out).unwrap();
                assert_eq!(out, &full[lo..hi], "seeded={seeded} {lo}..{hi}");
            }
            assert!(q.decode_range(&enc, 0, p + 1, &mut out).is_err());
        }
    }
}
