//! Bit-level writer/reader used by the quantizer wire codecs.
//!
//! The FedPAQ evaluation charges communication time by the *exact* number
//! of uploaded bits (`r * |Q(p,s)| / BW`), so the codec must be bit-exact,
//! not an estimate. Bits are packed LSB-first into a `Vec<u64>`.

/// Append-only bit sink.
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    words: Vec<u64>,
    /// Number of valid bits in the stream.
    len: u64,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Total bits written so far.
    pub fn len_bits(&self) -> u64 {
        self.len
    }

    /// Write the low `n` bits of `v` (LSB-first), `n <= 64`.
    pub fn write_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        if n == 0 {
            return;
        }
        let v = if n == 64 { v } else { v & ((1u64 << n) - 1) };
        let bit_off = (self.len % 64) as u32;
        let word_idx = (self.len / 64) as usize;
        if word_idx >= self.words.len() {
            self.words.push(0);
        }
        self.words[word_idx] |= v << bit_off;
        if bit_off + n > 64 {
            self.words.push(v >> (64 - bit_off));
        }
        self.len += n as u64;
    }

    /// Write a single bit.
    pub fn write_bit(&mut self, b: bool) {
        self.write_bits(b as u64, 1);
    }

    /// Write a full f32 (32 bits, its IEEE-754 pattern).
    pub fn write_f32(&mut self, x: f32) {
        self.write_bits(x.to_bits() as u64, 32);
    }

    /// Finish and expose the packed words (plus the bit length).
    pub fn finish(self) -> BitBuf {
        BitBuf { words: self.words, len: self.len }
    }
}

/// An immutable packed bit buffer (what actually travels on the wire).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitBuf {
    words: Vec<u64>,
    len: u64,
}

impl BitBuf {
    pub fn len_bits(&self) -> u64 {
        self.len
    }

    /// The packed words (for wire serialization).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Rebuild from wire parts; validates the word count against `len`.
    pub fn from_parts(words: Vec<u64>, len: u64) -> crate::Result<Self> {
        anyhow::ensure!(
            words.len() as u64 == len.div_ceil(64),
            "bitbuf length mismatch: {} words for {len} bits",
            words.len()
        );
        Ok(BitBuf { words, len })
    }

    /// Wire size rounded up to whole bytes (what a socket would carry).
    pub fn len_bytes(&self) -> usize {
        self.len.div_ceil(8) as usize
    }

    pub fn reader(&self) -> BitReader<'_> {
        BitReader { buf: self, pos: 0 }
    }

    /// Reader positioned at an arbitrary bit offset — the random-access
    /// entry point fixed-width codecs use to decode a coordinate range
    /// without scanning the prefix ([`UpdateCodec::decode_range`]
    /// seeking).
    ///
    /// [`UpdateCodec::decode_range`]: crate::quant::UpdateCodec::decode_range
    pub fn reader_at(&self, bit: u64) -> crate::Result<BitReader<'_>> {
        anyhow::ensure!(
            bit <= self.len,
            "bit offset {bit} beyond stream length {}",
            self.len
        );
        Ok(BitReader { buf: self, pos: bit })
    }
}

/// Sequential bit reader over a [`BitBuf`].
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a BitBuf,
    pos: u64,
}

impl<'a> BitReader<'a> {
    /// Bits remaining.
    pub fn remaining(&self) -> u64 {
        self.buf.len - self.pos
    }

    /// Read the next `n` bits (LSB-first), `n <= 64`.
    pub fn read_bits(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 64);
        debug_assert!(self.pos + n as u64 <= self.buf.len, "bitstream underrun");
        if n == 0 {
            return 0;
        }
        let bit_off = (self.pos % 64) as u32;
        let word_idx = (self.pos / 64) as usize;
        let mut v = self.buf.words[word_idx] >> bit_off;
        if bit_off + n > 64 {
            v |= self.buf.words[word_idx + 1] << (64 - bit_off);
        }
        self.pos += n as u64;
        if n == 64 {
            v
        } else {
            v & ((1u64 << n) - 1)
        }
    }

    pub fn read_bit(&mut self) -> bool {
        self.read_bits(1) != 0
    }

    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read_bits(32) as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_mixed_widths() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_f32(core::f32::consts::PI);
        w.write_bit(true);
        w.write_bits(u64::MAX, 64);
        w.write_bits(0x1234, 16);
        let buf = w.finish();
        assert_eq!(buf.len_bits(), 3 + 32 + 1 + 64 + 16);
        let mut r = buf.reader();
        assert_eq!(r.read_bits(3), 0b101);
        assert_eq!(r.read_f32(), core::f32::consts::PI);
        assert!(r.read_bit());
        assert_eq!(r.read_bits(64), u64::MAX);
        assert_eq!(r.read_bits(16), 0x1234);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn word_boundary_crossing() {
        let mut w = BitWriter::new();
        for i in 0..100u64 {
            w.write_bits(i, 7);
        }
        let buf = w.finish();
        let mut r = buf.reader();
        for i in 0..100u64 {
            assert_eq!(r.read_bits(7), i & 0x7f);
        }
    }

    #[test]
    fn reader_at_matches_sequential_read() {
        let mut w = BitWriter::new();
        for i in 0..64u64 {
            w.write_bits(i * 2654435761, 13);
        }
        let buf = w.finish();
        for start in [0u64, 1, 13, 63, 64, 65, 13 * 37] {
            let mut seq = buf.reader();
            let mut burned = 0u64;
            while burned < start {
                let n = (start - burned).min(64) as u32;
                seq.read_bits(n);
                burned += n as u64;
            }
            let mut ra = buf.reader_at(start).unwrap();
            assert_eq!(ra.read_bits(13), seq.read_bits(13), "start {start}");
        }
        assert!(buf.reader_at(buf.len_bits() + 1).is_err());
    }

    #[test]
    fn empty_is_empty() {
        let buf = BitWriter::new().finish();
        assert_eq!(buf.len_bits(), 0);
        assert_eq!(buf.len_bytes(), 0);
    }
}
