//! Error feedback ([`ErrorFeedbackCodec`]): the EF-SGD / EF21-style
//! memory wrapper. Each node keeps a residual `e` of everything its
//! compressor has thrown away so far; round `t` compresses the
//! *corrected* update `x_t + e_{t-1}` and banks the new compression
//! error:
//!
//! ```text
//! c_t = x_t + e_{t-1}
//! enc = inner.encode(c_t)            (what travels)
//! e_t = c_t − inner.decode(enc)      (what the server missed)
//! ```
//!
//! Error feedback famously repairs *biased* compressors (top-k, rand-k
//! without scaling) — the compressed-away mass is not lost, only delayed
//! — and tightens variance for unbiased ones. For a lossless inner codec
//! the residual is exactly zero forever (pinned by a property test).
//!
//! One honest caveat under buffered-async rounds: the residual is
//! debited at **encode** time, assuming the server applies the upload.
//! An upload the [`CommitPlanner`](crate::coordinator::commit_loop)
//! later drops as too stale loses its mass outright — exactly as a
//! dropped upload does under *any* codec — rather than re-entering the
//! memory. EF protects against what the compressor throws away, not
//! against what the async protocol discards; `ServerBuilder` logs a
//! warning for the combination.
//!
//! ## Transparency
//!
//! The wrapper changes what is *encoded*, never the wire format: frames
//! carry the inner codec's [`CodecSpec`] tag ([`UpdateCodec::wire_spec`]
//! is the inner's), and every decode-side method (`decode_into`,
//! `decode_range`, `analytic_bits`, `variance_q`) delegates verbatim —
//! the server aggregates EF uploads exactly as it would the inner
//! codec's, sharded `decode_range` fast paths included.
//!
//! ## State ownership
//!
//! Residuals are per-node state behind interior mutability, keyed by the
//! `node` passed to [`UpdateCodec::encode_node`] (the module docs'
//! statefulness rules). In the in-process sim one instance holds every
//! node's residual; on a TCP cluster each worker process owns the
//! residuals of the nodes it serves — sound because the leaders pin
//! `node → worker` assignment by node id. [`UpdateCodec::reset_state`]
//! drops all residuals; the round engine calls it at run start and
//! workers call it on `Setup`.

use super::{CodecSpec, Encoded, UpdateCodec};
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::Mutex;

/// The node key [`UpdateCodec::encode`] (the node-less entry point) uses:
/// direct `encode` calls still get one coherent residual stream instead
/// of silently skipping the memory.
const ANON_NODE: usize = usize::MAX;

/// Stateful error-feedback wrapper around any [`UpdateCodec`].
///
/// Build directly over a concrete inner codec
/// (`ErrorFeedbackCodec::new(TopKCodec::new(100))`) or from a config
/// spec via [`CodecSpec::build`], which wraps a boxed inner.
#[derive(Debug)]
pub struct ErrorFeedbackCodec<C: UpdateCodec> {
    inner: C,
    /// node → residual memory (lazily sized to the node's first update).
    residuals: Mutex<HashMap<usize, Vec<f32>>>,
}

impl<C: UpdateCodec> ErrorFeedbackCodec<C> {
    pub fn new(inner: C) -> Self {
        ErrorFeedbackCodec { inner, residuals: Mutex::new(HashMap::new()) }
    }

    /// The wrapped codec.
    pub fn inner(&self) -> &C {
        &self.inner
    }

    /// A copy of `node`'s current residual memory (`None` before the
    /// node's first encode). Test/telemetry accessor.
    pub fn residual(&self, node: usize) -> Option<Vec<f32>> {
        self.residuals.lock().unwrap().get(&node).cloned()
    }
}

impl<C: UpdateCodec> UpdateCodec for ErrorFeedbackCodec<C> {
    fn spec(&self) -> CodecSpec {
        CodecSpec::ErrorFeedback { inner: Box::new(self.inner.spec()) }
    }

    /// EF is wire-transparent: frames carry the inner codec's tag.
    fn wire_spec(&self) -> CodecSpec {
        self.inner.wire_spec()
    }

    fn encode(&self, x: &[f32], rng: &mut Rng) -> Encoded {
        self.encode_node(ANON_NODE, x, rng)
    }

    fn encode_node(&self, node: usize, x: &[f32], rng: &mut Rng) -> Encoded {
        let mut map = self.residuals.lock().unwrap();
        let res = map.entry(node).or_insert_with(|| vec![0.0; x.len()]);
        // A dimension change mid-run means a different model: stale
        // memory is meaningless, start it over.
        if res.len() != x.len() {
            *res = vec![0.0; x.len()];
        }
        let corrected: Vec<f32> =
            x.iter().zip(res.iter()).map(|(&v, &e)| v + e).collect();
        let enc = self.inner.encode(&corrected, rng);
        let decoded = self
            .inner
            .decode(&enc)
            .expect("inner codec failed to decode its own encode");
        for ((e, &c), &d) in res.iter_mut().zip(&corrected).zip(&decoded) {
            *e = c - d;
        }
        enc
    }

    fn stateful(&self) -> bool {
        true
    }

    fn state_bytes(&self) -> u64 {
        let map = self.residuals.lock().unwrap();
        map.values().map(|v| (v.len() * 4) as u64).sum()
    }

    fn reset_state(&self) {
        self.residuals.lock().unwrap().clear();
        self.inner.reset_state();
    }

    /// Residuals in ascending node order (BTreeMap-style determinism
    /// over the HashMap), so two exports of identical memory are equal
    /// and checkpoint bytes are stable.
    fn state_export(&self) -> Vec<(u64, Vec<f32>)> {
        let map = self.residuals.lock().unwrap();
        let mut out: Vec<(u64, Vec<f32>)> =
            map.iter().map(|(&n, v)| (n as u64, v.clone())).collect();
        out.sort_unstable_by_key(|&(n, _)| n);
        out
    }

    fn state_import(&self, state: Vec<(u64, Vec<f32>)>) {
        let mut map = self.residuals.lock().unwrap();
        map.clear();
        for (node, res) in state {
            map.insert(node as usize, res);
        }
    }

    fn decode_into(&self, enc: &Encoded, out: &mut Vec<f32>) -> crate::Result<()> {
        self.inner.decode_into(enc, out)
    }

    fn decode_range(
        &self,
        enc: &Encoded,
        lo: usize,
        hi: usize,
        out: &mut Vec<f32>,
    ) -> crate::Result<()> {
        self.inner.decode_range(enc, lo, hi, out)
    }

    fn accumulate_range(
        &self,
        enc: &Encoded,
        lo: usize,
        hi: usize,
        weight: f64,
        sum: &mut [f64],
    ) -> crate::Result<()> {
        // Verbatim delegation: EF shapes what gets *encoded* (residual
        // carry-in), never how a frame decodes — the inner codec's fused
        // kernel is the right one bit for bit.
        self.inner.accumulate_range(enc, lo, hi, weight, sum)
    }

    fn analytic_bits(&self, p: usize) -> Option<u64> {
        self.inner.analytic_bits(p)
    }

    fn variance_q(&self, p: usize) -> f64 {
        self.inner.variance_q(p)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{IdentityCodec, QsgdCodec, TopKCodec};
    use super::*;

    fn rng(seed: u64) -> Rng {
        Rng::seed_from_u64(seed)
    }

    #[test]
    fn identity_inner_keeps_residuals_exactly_zero() {
        // Lossless inner ⇒ no memory, ever — bit-exact zeros.
        let q = ErrorFeedbackCodec::new(IdentityCodec);
        let mut r = rng(1);
        for round in 0..5 {
            for node in [0usize, 3, 7] {
                let x: Vec<f32> =
                    (0..33).map(|i| ((i + round * 7) as f32 * 0.3).sin()).collect();
                let enc = q.encode_node(node, &x, &mut r);
                assert_eq!(q.decode(&enc).unwrap(), x);
                let res = q.residual(node).unwrap();
                assert!(
                    res.iter().all(|&e| e == 0.0),
                    "round {round} node {node}: nonzero residual"
                );
            }
        }
    }

    #[test]
    fn residual_is_exactly_corrected_minus_decoded() {
        let q = ErrorFeedbackCodec::new(TopKCodec::new(300));
        let x1: Vec<f32> = (0..40).map(|i| (i as f32 * 0.7).sin() * 2.0).collect();
        let mut r = rng(2);
        let e1 = q.encode_node(5, &x1, &mut r);
        let d1 = q.decode(&e1).unwrap();
        let res1 = q.residual(5).unwrap();
        for i in 0..40 {
            assert_eq!(res1[i], x1[i] - d1[i], "coord {i} (round 1, e0 = 0)");
        }
        // Round 2 compresses x2 + res1 — the banked error is re-sent.
        let x2: Vec<f32> = (0..40).map(|i| (i as f32 * 0.3).cos()).collect();
        let e2 = q.encode_node(5, &x2, &mut r);
        let d2 = q.decode(&e2).unwrap();
        let res2 = q.residual(5).unwrap();
        for i in 0..40 {
            let corrected = x2[i] + res1[i];
            assert_eq!(res2[i], corrected - d2[i], "coord {i} (round 2)");
        }
    }

    #[test]
    fn nodes_have_independent_memory() {
        let q = ErrorFeedbackCodec::new(QsgdCodec::new(1));
        let x: Vec<f32> = (0..20).map(|i| i as f32 * 0.1).collect();
        let mut r = rng(3);
        let _ = q.encode_node(1, &x, &mut r);
        assert!(q.residual(1).is_some());
        assert!(q.residual(2).is_none());
        // state_bytes counts every node's residual; reset drops them all.
        let _ = q.encode_node(2, &x, &mut r);
        assert_eq!(q.state_bytes(), 2 * 20 * 4);
        q.reset_state();
        assert_eq!(q.state_bytes(), 0);
        assert!(q.residual(1).is_none());
    }

    #[test]
    fn state_export_import_roundtrips_and_resumes_identically() {
        let x: Vec<f32> = (0..24).map(|i| (i as f32 * 0.9).sin() * 3.0).collect();
        let a = ErrorFeedbackCodec::new(TopKCodec::new(250));
        let mut r = rng(7);
        for node in [4usize, 1, 9] {
            let _ = a.encode_node(node, &x, &mut r);
        }
        let snap = a.state_export();
        // Ascending node order, one entry per touched node.
        assert_eq!(snap.iter().map(|&(n, _)| n).collect::<Vec<_>>(), [1, 4, 9]);
        // A fresh codec importing the snapshot continues bit-identically
        // to the original on the same subsequent stream.
        let b = ErrorFeedbackCodec::new(TopKCodec::new(250));
        b.state_import(snap.clone());
        assert_eq!(b.state_export(), snap);
        let y: Vec<f32> = (0..24).map(|i| (i as f32 * 0.4).cos()).collect();
        let mut ra = rng(8);
        let mut rb = rng(8);
        for node in [1usize, 9, 4] {
            let ea = a.encode_node(node, &y, &mut ra);
            let eb = b.encode_node(node, &y, &mut rb);
            assert_eq!(a.decode(&ea).unwrap(), b.decode(&eb).unwrap(), "node {node}");
        }
        assert_eq!(a.state_export(), b.state_export());
        // Stateless codecs export nothing and ignore imports.
        let id = IdentityCodec;
        assert!(id.state_export().is_empty());
        id.state_import(vec![(0, vec![1.0])]);
        assert!(id.state_export().is_empty());
    }

    #[test]
    fn delegates_wire_spec_bits_variance_and_decode() {
        let inner = QsgdCodec::new(3);
        let q = ErrorFeedbackCodec::new(inner);
        assert_eq!(q.wire_spec(), inner.spec());
        assert_eq!(
            q.spec(),
            CodecSpec::ErrorFeedback { inner: Box::new(inner.spec()) }
        );
        assert_eq!(q.analytic_bits(500), inner.analytic_bits(500));
        assert_eq!(q.variance_q(500), inner.variance_q(500));
        assert!(q.stateful() && !inner.stateful());
        // Frames are inner-tagged and decodable by the bare inner codec.
        let x: Vec<f32> = (0..100).map(|i| (i as f32 * 0.2).sin()).collect();
        let enc = q.encode_node(0, &x, &mut rng(4));
        assert_eq!(enc.spec, inner.spec());
        assert_eq!(inner.decode(&enc).unwrap(), q.decode(&enc).unwrap());
    }

    #[test]
    fn ef_over_topk_recovers_dropped_mass_over_rounds() {
        // The EF motivation in one invariant: summing the decoded uploads
        // of a *constant* update stream converges toward the true sum —
        // the dropped coordinates surface in later rounds via the
        // residual — while bare top-k loses the same mass every round.
        let x: Vec<f32> = (0..32)
            .map(|i| if i < 4 { 10.0 } else { 0.5 + (i as f32) * 0.01 })
            .collect();
        let rounds = 100;
        let ef = ErrorFeedbackCodec::new(TopKCodec::new(125)); // k=4 of 32
        let bare = TopKCodec::new(125);
        let mut sum_ef = vec![0f64; 32];
        let mut sum_bare = vec![0f64; 32];
        let mut r = rng(5);
        for _ in 0..rounds {
            let ef_dec = ef.decode(&ef.encode_node(0, &x, &mut r)).unwrap();
            for (s, v) in sum_ef.iter_mut().zip(ef_dec) {
                *s += v as f64;
            }
            let bare_dec = bare.decode(&bare.encode(&x, &mut r)).unwrap();
            for (s, v) in sum_bare.iter_mut().zip(bare_dec) {
                *s += v as f64;
            }
        }
        let want: Vec<f64> = x.iter().map(|&v| v as f64 * rounds as f64).collect();
        let l2 = |a: &[f64], b: &[f64]| {
            a.iter()
                .zip(b)
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f64>()
                .sqrt()
        };
        let err_ef = l2(&sum_ef, &want);
        let err_bare = l2(&sum_bare, &want);
        // EF's total error equals the final residual norm (telescoping:
        // Σ decoded = Σ x − e_T), which stays bounded as rounds grow;
        // bare top-k drops the same mass every round, so its error grows
        // linearly in the round count.
        assert!(
            err_ef < err_bare / 3.0,
            "EF error {err_ef} not ≪ bare top-k error {err_bare}"
        );
    }
}
