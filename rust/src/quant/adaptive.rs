//! Adaptive-level QSGD ([`AdaptiveQsgdCodec`]): the level count `s` is
//! not a fixed dial but is derived per encode from a target upload
//! budget of `bits_per_coord` bits per coordinate — header included —
//! and written into the frame header, so every frame is self-describing.
//!
//! Under naive fixed-width coding a QSGD coordinate costs
//! `1 + ceil(log2(s+1))` bits, so `u` usable bits per coordinate afford
//! `s = 2^(u−1) − 1` levels (sign takes one bit, the rest address the
//! level). The codec charges the 64-bit header (`s` + norm) against the
//! budget *before* flooring to whole per-coordinate bits — strict
//! never-exceed accounting, so any nonzero header cost rounds one level
//! bit away (`u = b − 1` once `p > 64`, smaller still for short vectors,
//! which pay the header hardest — the "adaptive" in the name). `s = 1`
//! is the floor: a budget too small to afford even FedPAQ's 2-bit
//! coordinates still produces a valid (if slightly over-budget) frame
//! rather than failing.
//!
//! The quantization itself is exactly [`QsgdCodec`](super::QsgdCodec)'s
//! stochastic rounding at the derived `s` — literally the same code
//! (`qsgd_encode_body` / `qsgd_decode_range_body` in the parent module),
//! so grid, RNG consumption, and corrupt-frame handling cannot drift —
//! only the header (and the `s`-selection rule) differ.

use super::bitstream::BitWriter;
use super::{
    check_accumulate, check_range, check_spec, l2_norm, level_bits,
    qsgd_accumulate_range_body, qsgd_decode_range_body, qsgd_encode_body, Coding, CodecSpec,
    Encoded, UpdateCodec,
};
use crate::util::rng::Rng;

/// Frame header: `s` (32 bits) then the f32 norm.
const HEADER_BITS: u64 = 64;

/// QSGD with a per-encode level count chosen from a bit budget.
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveQsgdCodec {
    /// Target upload size in bits per coordinate, header included.
    pub bits_per_coord: u8,
    pub coding: Coding,
}

impl AdaptiveQsgdCodec {
    pub fn new(bits_per_coord: u8) -> Self {
        AdaptiveQsgdCodec { bits_per_coord, coding: Coding::Naive }
    }

    /// The level count a length-`p` encode uses: the largest `s` whose
    /// naive fixed-width cost fits the per-coordinate budget after the
    /// 64-bit header is amortized, floored at `s = 1`.
    pub fn s_for(&self, p: usize) -> u32 {
        if p == 0 {
            return 1;
        }
        let total = self.bits_per_coord as u64 * p as u64;
        let usable = total.saturating_sub(HEADER_BITS) / p as u64;
        // One bit goes to the sign; the rest address levels 0..=s.
        let lb = usable.saturating_sub(1).min(31) as u32;
        if lb == 0 {
            1
        } else {
            (1u32 << lb) - 1
        }
    }
}

impl UpdateCodec for AdaptiveQsgdCodec {
    fn spec(&self) -> CodecSpec {
        CodecSpec::AdaptiveQsgd {
            bits_per_coord: self.bits_per_coord,
            coding: self.coding,
        }
    }

    fn encode(&self, x: &[f32], rng: &mut Rng) -> Encoded {
        let s = self.s_for(x.len());
        let norm = l2_norm(x);
        let mut w = BitWriter::new();
        w.write_bits(s as u64, 32);
        w.write_f32(norm);
        qsgd_encode_body(&mut w, x, norm, s, self.coding, rng);
        Encoded { buf: w.finish(), p: x.len(), spec: self.spec() }
    }

    fn decode_into(&self, enc: &Encoded, out: &mut Vec<f32>) -> crate::Result<()> {
        // One decode implementation: the full decode is the 0..p range,
        // so the range and full paths can never drift apart.
        self.decode_range(enc, 0, enc.p, out)
    }

    fn decode_range(
        &self,
        enc: &Encoded,
        lo: usize,
        hi: usize,
        out: &mut Vec<f32>,
    ) -> crate::Result<()> {
        check_spec(self.spec(), enc)?;
        check_range(enc.p, lo, hi)?;
        anyhow::ensure!(
            enc.buf.len_bits() >= HEADER_BITS,
            "adaptive-QSGD frame truncated: {} bits, header needs {HEADER_BITS}",
            enc.buf.len_bits()
        );
        let mut hr = enc.buf.reader();
        let s = hr.read_bits(32) as u32;
        let norm = hr.read_f32();
        // The header's s must be the one this dial derives for p: a
        // mismatch means a corrupt frame or a forged header, either of
        // which would silently land decodes on the wrong grid.
        anyhow::ensure!(
            s == self.s_for(enc.p),
            "adaptive-QSGD header s={s} does not match the dial's s={} for \
             p={}",
            self.s_for(enc.p),
            enc.p
        );
        qsgd_decode_range_body(enc, HEADER_BITS, norm, s, self.coding, lo, hi, out)
    }

    fn accumulate_range(
        &self,
        enc: &Encoded,
        lo: usize,
        hi: usize,
        weight: f64,
        sum: &mut [f64],
    ) -> crate::Result<()> {
        check_spec(self.spec(), enc)?;
        check_accumulate(enc.p, lo, hi, weight, sum.len())?;
        anyhow::ensure!(
            enc.buf.len_bits() >= HEADER_BITS,
            "adaptive-QSGD frame truncated: {} bits, header needs {HEADER_BITS}",
            enc.buf.len_bits()
        );
        let mut hr = enc.buf.reader();
        let s = hr.read_bits(32) as u32;
        let norm = hr.read_f32();
        // Same forged-header rejection as `decode_range`.
        anyhow::ensure!(
            s == self.s_for(enc.p),
            "adaptive-QSGD header s={s} does not match the dial's s={} for \
             p={}",
            self.s_for(enc.p),
            enc.p
        );
        qsgd_accumulate_range_body(
            enc,
            HEADER_BITS,
            norm,
            s,
            self.coding,
            lo,
            hi,
            weight,
            sum,
        )
    }

    fn analytic_bits(&self, p: usize) -> Option<u64> {
        match self.coding {
            Coding::Naive => {
                Some(HEADER_BITS + p as u64 * (1 + level_bits(self.s_for(p)) as u64))
            }
            Coding::Elias => None,
        }
    }

    /// Assumption-1 variance at the level count a length-`p` encode
    /// derives: `min(p/s², √p/s)` — identical to fixed-`s` QSGD at
    /// `s = s_for(p)`.
    fn variance_q(&self, p: usize) -> f64 {
        let s = self.s_for(p) as f64;
        let p = p as f64;
        (p / (s * s)).min(p.sqrt() / s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng(seed: u64) -> Rng {
        Rng::seed_from_u64(seed)
    }

    #[test]
    fn level_choice_tracks_the_budget() {
        // Strict never-exceed accounting: the 64-bit header always costs
        // one whole per-coordinate bit after flooring (p > 64), so b
        // budget bits buy u = b-1 usable bits ⇒ s = 2^(b-2) - 1, floored
        // at s = 1.
        let p = 10_000;
        for (b, want_s) in [(2u8, 1u32), (3, 1), (4, 3), (5, 7), (8, 63)] {
            let q = AdaptiveQsgdCodec::new(b);
            assert_eq!(q.s_for(p), want_s, "b={b}");
        }
        // Tiny vectors pay the header: the same dial picks a smaller s.
        let q = AdaptiveQsgdCodec::new(4);
        assert!(q.s_for(20) < q.s_for(10_000));
        // The floor: s is never below 1.
        assert_eq!(AdaptiveQsgdCodec::new(2).s_for(3), 1);
    }

    #[test]
    fn frame_header_carries_s_and_bits_match_analytic() {
        let x: Vec<f32> = (0..1000).map(|i| ((i as f32) * 0.17).sin()).collect();
        for b in [3u8, 4, 6, 10] {
            let q = AdaptiveQsgdCodec::new(b);
            let enc = q.encode(&x, &mut rng(1));
            assert_eq!(
                enc.buf.reader().read_bits(32) as u32,
                q.s_for(1000),
                "b={b}"
            );
            assert_eq!(Some(enc.bits()), q.analytic_bits(1000), "b={b}");
            // Budget respected once the header is amortizable (p=1000).
            assert!(
                enc.bits() <= b as u64 * 1000,
                "b={b}: {} bits > budget {}",
                enc.bits(),
                b as u64 * 1000
            );
        }
    }

    #[test]
    fn decodes_on_the_derived_grid() {
        let x: Vec<f32> = (0..257).map(|i| ((i * 31) % 97) as f32 - 48.0).collect();
        for coding in [Coding::Naive, Coding::Elias] {
            let q = AdaptiveQsgdCodec { bits_per_coord: 5, coding };
            let s = q.s_for(x.len());
            let enc = q.encode(&x, &mut rng(2));
            let norm = l2_norm(&x);
            for (i, v) in q.decode(&enc).unwrap().iter().enumerate() {
                let lvl = v.abs() / norm * s as f32;
                assert!(
                    (lvl - lvl.round()).abs() < 1e-3,
                    "coord {i} level {lvl} off the s={s} grid ({coding:?})"
                );
                assert!(lvl.round() as u32 <= s);
            }
        }
    }

    #[test]
    fn matches_fixed_qsgd_at_the_derived_s() {
        // Same rng stream + same s ⇒ identical decoded values: the
        // adaptive codec IS QSGD once s is pinned.
        let x: Vec<f32> = (0..500).map(|i| ((i as f32) * 0.29).cos()).collect();
        let q = AdaptiveQsgdCodec::new(4);
        let s = q.s_for(x.len());
        let fixed = super::super::QsgdCodec::new(s);
        let ea = q.encode(&x, &mut rng(3));
        let ef = fixed.encode(&x, &mut rng(3));
        assert_eq!(q.decode(&ea).unwrap(), fixed.decode(&ef).unwrap());
    }

    #[test]
    fn forged_or_truncated_headers_are_rejected() {
        let x = vec![1.0f32; 64];
        let q = AdaptiveQsgdCodec::new(4);
        let enc = q.encode(&x, &mut rng(4));
        // Forge the header's s (keep everything else).
        let mut w = BitWriter::new();
        w.write_bits(u64::from(q.s_for(64)) + 1, 32);
        let mut r = enc.buf.reader();
        r.read_bits(32);
        for _ in 0..(enc.buf.len_bits() - 32) {
            w.write_bit(r.read_bit());
        }
        let forged = Encoded { buf: w.finish(), p: 64, spec: q.spec() };
        assert!(q.decode(&forged).is_err());
        // Truncated below the header.
        let mut w = BitWriter::new();
        w.write_bits(3, 20);
        let stub = Encoded { buf: w.finish(), p: 64, spec: q.spec() };
        assert!(q.decode(&stub).is_err());
        // Dial mismatch is a spec mismatch.
        assert!(AdaptiveQsgdCodec::new(6).decode(&enc).is_err());
    }

    #[test]
    fn decode_range_matches_full_decode_slice() {
        let p = 311;
        let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.41).sin() * 4.0).collect();
        for coding in [Coding::Naive, Coding::Elias] {
            let q = AdaptiveQsgdCodec { bits_per_coord: 4, coding };
            let enc = q.encode(&x, &mut rng(5));
            let full = q.decode(&enc).unwrap();
            let mut out = Vec::new();
            for (lo, hi) in [(0, p), (0, 0), (p, p), (7, 8), (100, 222), (250, p)] {
                q.decode_range(&enc, lo, hi, &mut out).unwrap();
                assert_eq!(out, &full[lo..hi], "{coding:?} {lo}..{hi}");
            }
        }
    }
}
