//! Pluggable update compression (paper §3.3) — the third FedPAQ module,
//! written as a **codec-author guide**: everything a new [`UpdateCodec`]
//! implementation must honor lives in this doc.
//!
//! ## The trait contract
//!
//! Every upload compressor implements the object-safe [`UpdateCodec`]
//! trait; the rest of the system — aggregation, transports, the cost
//! model — only ever sees `&dyn UpdateCodec`. A conforming codec must:
//!
//! 1. **Round-trip on its own grid.** `decode(encode(x))` succeeds and
//!    lands on the codec's reconstruction grid (exact for identity, the
//!    `norm·l/s` grid for QSGD-family codecs, exact-or-zero for
//!    sparsifiers). Encodes are deterministic in `(x, rng, per-node
//!    state)`: both execution modes (in-process sim and TCP workers)
//!    replay identical uploads from identical seeds.
//! 2. **Tag frames with a spec.** [`UpdateCodec::wire_spec`] (defaults to
//!    [`UpdateCodec::spec`]) is stamped on every [`Encoded`]; decodes
//!    reject mismatched tags instead of misreading bits. Transparent
//!    wrappers like [`ErrorFeedbackCodec`] stamp the *inner* codec's spec
//!    — their wire format IS the inner format — while `spec()` still
//!    names the wrapper for configs.
//! 3. **Account bits honestly.** [`UpdateCodec::analytic_bits`] returns
//!    the exact data-independent wire size for fixed-width codings and
//!    `None` when the size is data-dependent (Elias codings); the
//!    property suite asserts `encoded.bits()` matches.
//! 4. **Implement `decode_range` honestly.** Decoding `lo..hi` must be
//!    bit-identical to slicing a full decode — that is what sharded
//!    aggregation splits uploads on — and should *not* materialize all
//!    `p` coordinates: fixed-width codings seek straight to `lo`
//!    ([`bitstream::BitBuf::reader_at`]), Elias codings skip-scan the
//!    prefix without float reconstruction, sparsifiers filter their
//!    `(index, value)` stream or binary-search their known index set.
//!    The provided decode-then-slice default is correct but pays the
//!    full decode; only out-of-tree codecs should rely on it.
//! 5. **Reject corrupt frames identically on every path.** Truncated,
//!    empty, or non-canonical frames (non-ascending sparsifier indices,
//!    QSGD levels beyond `s`) return an explicit `Err` from *both*
//!    `decode_into` and every `decode_range`, on every coding — never a
//!    panic, never silently fabricated zeros (release builds do not
//!    bounds-check raw bit reads, so validate sizes up front or use
//!    [`elias::try_decode_omega`]). Validation extent: fixed-width
//!    codings check their exact data-independent frame size up front,
//!    so every range rejects a bad frame; variable-width codings check
//!    every bit and value bound they traverse (prefix skip + range)
//!    plus the trailing bits whenever the range reaches `p` (which
//!    `decode_into` always does); sparsifier scans validate the full
//!    stream from any range. A bad value hiding in an *untraversed*
//!    fixed-width field is caught by whichever decode touches it — the
//!    full decode always does.
//! 6. **Keep the fused accumulate bit-identical.** The aggregation hot
//!    path calls [`UpdateCodec::accumulate_range`]: decode `lo..hi` and
//!    add straight into per-coordinate f64 accumulators at a given
//!    weight, with no scratch buffer. The provided default (range
//!    decode + widening add) is correct for any codec; built-ins
//!    override it with fused kernels that must stay **bit-identical**
//!    to that scratch path — same reconstruction expressions, the same
//!    rejection surface as `decode_range`, one add per in-window
//!    coordinate, and the weight multiply *skipped* (not just exact) at
//!    `weight == 1.0`, matching the aggregator's uniform-mean loop.
//!    Sparsifiers may skip their implicit zeros outright because the
//!    accumulator contract forbids `-0.0` entries (see the trait docs).
//!    Pinned by `prop_accumulate_range_matches_decode_range_add`.
//!
//! ## Statefulness rules
//!
//! Codecs are `&self` and shared across nodes. A codec whose encode
//! depends on accumulated per-node memory (e.g. [`ErrorFeedbackCodec`]
//! residuals) must:
//!
//! * key its state by the `node` passed to [`UpdateCodec::encode_node`]
//!   (the entry point the round pipeline calls; stateless codecs keep the
//!   default, which ignores the node and calls `encode`), behind interior
//!   mutability;
//! * report `true` from [`UpdateCodec::stateful`] and its live memory
//!   from [`UpdateCodec::state_bytes`];
//! * drop all state in [`UpdateCodec::reset_state`] — the
//!   [`RoundEngine`](crate::coordinator::RoundEngine) calls it at run
//!   start, and TCP workers call it on `Setup`, so a reused instance
//!   never leaks one run's memory into the next.
//!
//! Decode stays stateless (the server side holds no per-node memory), so
//! statefulness never affects aggregation or `decode_range` sharding.
//! On TCP clusters each worker process owns the residuals of the nodes
//! it serves; the leaders pin `node → worker` assignment by node id
//! (see [`crate::net`]) so that ownership is stable across rounds.
//!
//! ## Built-in codecs
//!
//! * [`IdentityCodec`] — full-precision f32 uploads (the FedAvg baseline,
//!   `32·p` bits);
//! * [`QsgdCodec`] — the QSGD low-precision quantizer of paper Example 1,
//!   with either the paper's naive fixed-width level coding or QSGD's
//!   Elias-ω recursive coding;
//! * [`TopKCodec`] — magnitude top-k sparsification with index coding
//!   (fixed-width or Elias-ω delta-coded indices);
//! * [`RandKCodec`] — seeded random-k sparsification: the kept set is
//!   regenerated from a 64-bit frame-header seed, so the seeded mode
//!   ships **no index payload** (explicit Elias-ω delta indices as the
//!   fallback mode); decoded values are scaled by `p/k` so the codec is
//!   unbiased;
//! * [`AdaptiveQsgdCodec`] — QSGD whose level count is chosen per encode
//!   from a `bits_per_coord` budget, with the chosen `s` written into the
//!   frame header;
//! * [`ErrorFeedbackCodec`] — a stateful wrapper adding each round's
//!   compression error back into the node's next update (EF-SGD style
//!   residual memory).
//!
//! Configs and wire frames carry a [`CodecSpec`] — a small, serializable
//! tag naming a built-in codec ([`CodecSpec::build`] instantiates it,
//! recursively for wrappers). Custom codecs outside this module plug in
//! through `ServerBuilder::codec` without touching the coordinator; they
//! run on in-process transports (networked workers rebuild their codec
//! from the config's tagged spec, which only names built-ins).
//!
//! ## Wire formats (little-endian bit packing, see [`bitstream`])
//!
//! ```text
//! identity:  [ f32 ] * p
//! qsgd:      [ norm: f32 ]  then per coordinate i in 0..p:
//!   naive coding:  [ sign: 1 bit ][ level: ceil(log2(s+1)) bits ]
//!   elias coding:  [ sign: 1 bit ][ EliasOmega(level + 1) ]
//! top_k:     per kept coordinate (ascending index order):
//!   naive coding:  [ index: ceil(log2(p)) bits ][ value: f32 ]
//!   elias coding:  [ EliasOmega(index gap) ][ value: f32 ]
//! rand_k:
//!   seeded mode:   [ index_seed: 64 bits ] then [ value: f32 ] * k
//!                  (indices regenerated from the seed at decode)
//!   explicit mode: [ EliasOmega(index gap) ][ value: f32 ] * k
//! adaptive_qsgd: [ s: 32 bits ][ norm: f32 ] then per-coordinate
//!                sign+level exactly as qsgd at the header's s
//! error_feedback: the inner codec's format, unchanged
//! ```
//!
//! The dequantized QSGD coordinate is `norm * sign_i * level_i / s`,
//! exactly the value the L1 Pallas kernel produces — parity is enforced by
//! an integration test through the exported `quantize4096` artifact.
//!
//! ## How the CI conformance matrix picks codecs up
//!
//! The shared property suites (`rust/tests/prop_codecs.rs`,
//! `rust/tests/prop_invariants.rs`) iterate every built-in codec and
//! honor the `FEDPAQ_CODEC_FILTER` environment variable (a
//! comma-separated list of [`CodecSpec::family`] names, e.g.
//! `FEDPAQ_CODEC_FILTER=randk`): CI runs one test invocation per family,
//! so a broken codec fails its *own* job in the matrix instead of hiding
//! in one blob of test output. A new codec joins the matrix by (a)
//! returning a family name from `CodecSpec::family`, (b) appearing in the
//! suites' `all_codecs()` lists, and (c) being added to the
//! `codec-conformance` matrix in `.github/workflows/ci.yml`.

pub mod adaptive;
pub mod bitstream;
pub mod ef;
pub mod elias;
pub mod randk;

pub use adaptive::AdaptiveQsgdCodec;
pub use ef::ErrorFeedbackCodec;
pub use randk::RandKCodec;

use crate::util::rng::Rng;
use bitstream::{BitBuf, BitWriter};

/// Which level/index entropy coding a codec uses on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Coding {
    /// Fixed-width fields. For QSGD this is `1 + ceil(log2(s+1))`
    /// bits/coordinate — the paper's accounting (`s=1` → 2 bits vs `F=32`
    /// unquantized). For top-k it is `ceil(log2(p))` bits/index.
    #[default]
    Naive,
    /// Elias-ω recursive coding (QSGD §3.1) — shorter when most levels are
    /// zero (QSGD at large `s`) or indices are dense (top-k at large `k`).
    Elias,
}

/// Serializable description of a built-in codec: what configs and wire
/// frames carry, and what [`Encoded`] buffers are tagged with so a decode
/// against the wrong configuration is rejected instead of misread.
///
/// Not `Copy` (the [`CodecSpec::ErrorFeedback`] wrapper boxes its inner
/// spec); clone freely — the tag is a few bytes.
#[derive(Debug, Clone, PartialEq)]
pub enum CodecSpec {
    /// No compression (FedAvg baseline): full f32 upload.
    Identity,
    /// QSGD low-precision quantizer with `s` levels (paper Example 1).
    Qsgd { s: u32, coding: Coding },
    /// Keep the `max(1, p·k_permille/1000)` largest-magnitude coordinates.
    TopK { k_permille: u16, coding: Coding },
    /// Keep `max(1, p·k_permille/1000)` uniformly random coordinates,
    /// scaled by `p/k` (unbiased). `seeded` regenerates the index set
    /// from a 64-bit frame-header seed (no index payload on the wire);
    /// otherwise indices ship explicitly as Elias-ω delta codes.
    RandK { k_permille: u16, seeded: bool },
    /// QSGD whose level count is derived per encode from a target upload
    /// budget of `bits_per_coord` bits per coordinate (header included);
    /// the chosen `s` is written into the frame header.
    AdaptiveQsgd { bits_per_coord: u8, coding: Coding },
    /// Error-feedback wrapper: per-node residual memory added back into
    /// the next round's update before compressing with `inner`. The wire
    /// format — and every frame's tag — is the inner codec's.
    ErrorFeedback { inner: Box<CodecSpec> },
    /// An out-of-tree codec. Custom [`UpdateCodec`] impls return this
    /// from `spec()` with a stable, impl-chosen `id`, so their buffers
    /// are tagged distinctly — decode-mismatch checks still work —
    /// without impersonating a built-in. [`CodecSpec::build`] cannot
    /// rebuild one (the instance itself travels through
    /// `ServerBuilder::codec`, in-process only).
    External { id: u32 },
}

impl CodecSpec {
    /// QSGD with `s` levels and the paper's naive fixed-width accounting.
    pub fn qsgd(s: u32) -> Self {
        CodecSpec::Qsgd { s, coding: Coding::Naive }
    }

    /// Top-k sparsification keeping `k_permille`/1000 of the coordinates,
    /// with fixed-width index coding.
    pub fn top_k(k_permille: u16) -> Self {
        CodecSpec::TopK { k_permille, coding: Coding::Naive }
    }

    /// Seeded random-k sparsification keeping `k_permille`/1000 of the
    /// coordinates (no index payload on the wire).
    pub fn rand_k(k_permille: u16) -> Self {
        CodecSpec::RandK { k_permille, seeded: true }
    }

    /// Adaptive-level QSGD targeting `bits_per_coord` bits/coordinate,
    /// naive fixed-width level coding.
    pub fn adaptive(bits_per_coord: u8) -> Self {
        CodecSpec::AdaptiveQsgd { bits_per_coord, coding: Coding::Naive }
    }

    /// Error-feedback wrapper around `inner`.
    pub fn error_feedback(inner: CodecSpec) -> Self {
        CodecSpec::ErrorFeedback { inner: Box::new(inner) }
    }

    /// The codec family name — the unit of the CI conformance matrix
    /// (`FEDPAQ_CODEC_FILTER`, see the module docs) and of test/figure
    /// labels.
    pub fn family(&self) -> &'static str {
        match self {
            CodecSpec::Identity => "identity",
            CodecSpec::Qsgd { .. } => "qsgd",
            CodecSpec::TopK { .. } => "topk",
            CodecSpec::RandK { .. } => "randk",
            CodecSpec::AdaptiveQsgd { .. } => "adaptive_qsgd",
            CodecSpec::ErrorFeedback { .. } => "error_feedback",
            CodecSpec::External { .. } => "external",
        }
    }

    /// Whether [`CodecSpec::build`] can reconstruct this codec (i.e. the
    /// spec names built-ins all the way down). `false` exactly when an
    /// [`CodecSpec::External`] tag appears anywhere — networked
    /// transports, whose workers rebuild codecs from the broadcast
    /// config, refuse unrebuildable specs up front.
    pub fn rebuildable(&self) -> bool {
        match self {
            CodecSpec::External { .. } => false,
            CodecSpec::ErrorFeedback { inner } => inner.rebuildable(),
            _ => true,
        }
    }

    /// Whether the built codec keeps per-node state across rounds
    /// (see the module docs' statefulness rules).
    pub fn is_stateful(&self) -> bool {
        matches!(self, CodecSpec::ErrorFeedback { .. })
    }

    /// Instantiate the built-in codec this spec names (recursively for
    /// wrappers). Errors for [`CodecSpec::External`] — an external codec
    /// exists only as an instance and must be passed through
    /// `ServerBuilder::codec`.
    pub fn build(&self) -> crate::Result<Box<dyn UpdateCodec>> {
        Ok(match self {
            CodecSpec::Identity => Box::new(IdentityCodec),
            CodecSpec::Qsgd { s, coding } => {
                Box::new(QsgdCodec { s: *s, coding: *coding })
            }
            CodecSpec::TopK { k_permille, coding } => {
                Box::new(TopKCodec { k_permille: *k_permille, coding: *coding })
            }
            CodecSpec::RandK { k_permille, seeded } => {
                Box::new(RandKCodec { k_permille: *k_permille, seeded: *seeded })
            }
            CodecSpec::AdaptiveQsgd { bits_per_coord, coding } => {
                Box::new(AdaptiveQsgdCodec {
                    bits_per_coord: *bits_per_coord,
                    coding: *coding,
                })
            }
            CodecSpec::ErrorFeedback { inner } => {
                Box::new(ErrorFeedbackCodec::new(inner.build()?))
            }
            CodecSpec::External { id } => anyhow::bail!(
                "external codec id={id} cannot be rebuilt from its spec; \
                 pass the codec instance via ServerBuilder::codec (in-process only)"
            ),
        })
    }

    /// Variance/contraction parameter `q` of the codec (Assumption 1);
    /// convenience delegator to [`UpdateCodec::variance_q`]. `NaN` for
    /// [`CodecSpec::External`], whose behavior this crate cannot know.
    pub fn variance_q(&self, p: usize) -> f64 {
        match self.build() {
            Ok(codec) => codec.variance_q(p),
            Err(_) => f64::NAN,
        }
    }
}

/// Whether `family` is enabled under the `FEDPAQ_CODEC_FILTER`
/// environment variable (comma-separated [`CodecSpec::family`] names; an
/// unset or empty variable enables everything). The shared property
/// suites consult this so the CI conformance matrix can run one codec
/// family per job.
pub fn family_enabled(family: &str) -> bool {
    match std::env::var("FEDPAQ_CODEC_FILTER") {
        Ok(filter) if !filter.trim().is_empty() => filter
            .split(',')
            .any(|t| t.trim().eq_ignore_ascii_case(family)),
        _ => true,
    }
}

/// An upload compressor: everything the round pipeline needs from one.
///
/// Object-safe by design — aggregation and transports hold
/// `&dyn UpdateCodec` / `Box<dyn UpdateCodec>`, so new compressors
/// (sparsifiers, adaptive-level quantizers, entropy coders) plug in
/// without touching the coordinator. Implementations must be
/// deterministic given `(x, rng)` — both execution modes (in-process sim
/// and TCP) rely on replaying identical uploads from identical seeds.
pub trait UpdateCodec: std::fmt::Debug + Send + Sync {
    /// The serializable tag identifying this codec's configuration.
    fn spec(&self) -> CodecSpec;

    /// The tag stamped on encoded frames — what decodes verify. Equal to
    /// [`UpdateCodec::spec`] except for *transparent wrappers*
    /// ([`ErrorFeedbackCodec`]), whose frames are in the inner codec's
    /// wire format and carry the inner codec's tag.
    fn wire_spec(&self) -> CodecSpec {
        self.spec()
    }

    /// Compress and bit-pack `x` for the wire.
    fn encode(&self, x: &[f32], rng: &mut Rng) -> Encoded;

    /// Node-aware encode: the entry point the round pipeline calls
    /// (`coordinator::local::node_round`, on both the sim and the TCP
    /// worker). Stateless codecs keep this default, which ignores the
    /// node; stateful codecs ([`ErrorFeedbackCodec`]) key their per-node
    /// memory on it. See the module docs' statefulness rules.
    fn encode_node(&self, node: usize, x: &[f32], rng: &mut Rng) -> Encoded {
        let _ = node;
        self.encode(x, rng)
    }

    /// Whether [`UpdateCodec::encode_node`] consults accumulated
    /// per-node state (and so whether call *history* matters, not just
    /// the current arguments).
    fn stateful(&self) -> bool {
        false
    }

    /// Bytes of per-node state currently held across all nodes. Always
    /// `0` for stateless codecs.
    fn state_bytes(&self) -> u64 {
        0
    }

    /// Drop all per-node state, returning the codec to its
    /// freshly-constructed condition. Called by the round engine at run
    /// start and by TCP workers on `Setup`; a no-op for stateless
    /// codecs.
    fn reset_state(&self) {}

    /// Snapshot all per-node state as `(node, values)` pairs in
    /// ascending node order — what `ops` checkpoints persist so a
    /// resumed run continues with identical codec memory (EF residuals).
    /// Stateless codecs keep this default (empty).
    fn state_export(&self) -> Vec<(u64, Vec<f32>)> {
        Vec::new()
    }

    /// Replace all per-node state with a [`UpdateCodec::state_export`]
    /// snapshot (checkpoint resume). Stateless codecs keep this no-op
    /// default; implementations must accept their own export verbatim
    /// (`state_import(state_export())` is an identity).
    fn state_import(&self, state: Vec<(u64, Vec<f32>)>) {
        let _ = state;
    }

    /// Decode an upload into `out` (cleared and refilled to `enc.p`
    /// values). Rejects buffers produced by a different codec config.
    ///
    /// Takes a caller-owned buffer so the aggregation hot path can reuse
    /// one scratch allocation across all uploads of a run.
    fn decode_into(&self, enc: &Encoded, out: &mut Vec<f32>) -> crate::Result<()>;

    /// Exact upload size in bits for a length-`p` vector, when it is
    /// data-independent (fixed-width codings). `None` for data-dependent
    /// sizes (Elias codings) — use the encoded buffer's true
    /// [`Encoded::bits`] there.
    fn analytic_bits(&self, p: usize) -> Option<u64>;

    /// Variance parameter `q` from Assumption 1: `E‖Q(x)−x‖² ≤ q‖x‖²`.
    /// For QSGD this is `min(p/s², √p/s)`; for the identity `0`. Biased
    /// contractions (top-k) report their worst-case contraction factor
    /// `1 − k/p` here, which bounds the same error ratio.
    fn variance_q(&self, p: usize) -> f64;

    /// Decode only coordinates `lo..hi` of `enc` into `out` (cleared and
    /// refilled to exactly `hi − lo` values), **bit-identical** to slicing
    /// a full [`UpdateCodec::decode_into`] result at `lo..hi`.
    ///
    /// This is the seam sharded aggregation
    /// ([`Aggregator::push_batch`](crate::coordinator::aggregate::Aggregator::push_batch))
    /// splits uploads on: disjoint ranges of one `Encoded` buffer are
    /// decoded concurrently, one per shard thread, so the built-in
    /// overrides avoid materializing all `p` coordinates per shard —
    /// fixed-width codings seek straight to `lo`, Elias codings skip-scan
    /// the prefix without the float reconstruction, and top-k streams
    /// filter their sparse `(index, value)` pairs against the range.
    ///
    /// The provided default decodes everything and copies the slice out:
    /// correct for any codec (it is the only behavior available for
    /// out-of-tree [`CodecSpec::External`] impls that don't override),
    /// just without the partial-decode savings.
    fn decode_range(
        &self,
        enc: &Encoded,
        lo: usize,
        hi: usize,
        out: &mut Vec<f32>,
    ) -> crate::Result<()> {
        check_range(enc.p, lo, hi)?;
        let mut full = Vec::with_capacity(enc.p);
        self.decode_into(enc, &mut full)?;
        out.clear();
        out.extend_from_slice(&full[lo..hi]);
        Ok(())
    }

    /// Decode coordinates `lo..hi` of `enc` and accumulate them into
    /// `sum` (length exactly `hi − lo`, `sum[j] += weight ·
    /// decoded[lo + j]` with the product taken in f64), fused so the
    /// aggregation hot path needs no scratch `Vec<f32>` per upload.
    ///
    /// The provided default — [`UpdateCodec::decode_range`] into a
    /// temporary, then a widening add — is correct for any codec and is
    /// the behavioral spec every override must match **bit-identically**:
    ///
    /// - same decoded value per coordinate (use the same reconstruction
    ///   expressions as the decode path, in the same order);
    /// - one `+=` per coordinate of the window, in ascending coordinate
    ///   order, each a single f64 add of `weight * v as f64` (or of
    ///   `v as f64` alone when `weight == 1.0` — the multiply must be
    ///   *skipped*, not merely exact, to match the aggregator's
    ///   historical unweighted loop);
    /// - same rejection surface as `decode_range` (corrupt frames, spec
    ///   mismatches, bad ranges), plus: `sum.len() != hi − lo`,
    ///   non-finite or non-positive `weight`. Argument rejections and
    ///   data-independent frame-size checks happen before the first add;
    ///   variable-width corruption detected mid-stream may leave a
    ///   partial contribution, exactly as
    ///   [`Aggregator::push_batch`](crate::coordinator::aggregate::Aggregator::push_batch)
    ///   already documents for decode failures — every error is fatal to
    ///   the run.
    ///
    /// Sparse codecs (top-k, rand-k) may skip the `+= 0.0` for
    /// coordinates outside their support *only* because callers
    /// guarantee no `sum` entry is `-0.0`: the
    /// [`Aggregator`](crate::coordinator::aggregate::Aggregator)
    /// accumulators start at `+0.0` and round-to-nearest addition
    /// from `+0.0` can never
    /// produce `-0.0`, and for any `x != -0.0`, `x + 0.0` is bitwise
    /// `x`. (A `-0.0` entry would flip to `+0.0` under the scratch
    /// path but survive under a skipping kernel.)
    fn accumulate_range(
        &self,
        enc: &Encoded,
        lo: usize,
        hi: usize,
        weight: f64,
        sum: &mut [f64],
    ) -> crate::Result<()> {
        check_accumulate(enc.p, lo, hi, weight, sum.len())?;
        let mut scratch = Vec::with_capacity(hi - lo);
        self.decode_range(enc, lo, hi, &mut scratch)?;
        accumulate_slice(sum, &scratch, weight);
        Ok(())
    }

    /// Parse `enc`'s frame header once, returning reusable per-frame
    /// state for [`UpdateCodec::accumulate_range_cached`]. The sharded
    /// aggregator opens every upload of a commit batch exactly once and
    /// hands the same handle to all shard threads, so per-range kernel
    /// calls stop re-reading — or, for seeded sparsifiers, regenerating
    /// — the header once per range. The default returns
    /// [`FrameHeader::Opaque`]: correct for every codec, no caching.
    ///
    /// Overrides must perform the data-independent frame validation of
    /// their `accumulate_range` here (spec match, frame-size checks), so
    /// a corrupt frame fails at open time rather than per shard.
    fn open_frame(&self, enc: &Encoded) -> crate::Result<FrameHeader> {
        let _ = enc;
        Ok(FrameHeader::Opaque)
    }

    /// [`UpdateCodec::accumulate_range`] with a header handle from
    /// [`UpdateCodec::open_frame`] on the **same** frame. Must be
    /// bit-identical to `accumulate_range` for every `(enc, hdr)` pair
    /// that `open_frame(enc)` can produce — the cache may only save
    /// work, never change an add or its order. The default ignores the
    /// handle and takes the plain path, so codecs without a header fast
    /// path stay correct for free.
    fn accumulate_range_cached(
        &self,
        enc: &Encoded,
        hdr: &FrameHeader,
        lo: usize,
        hi: usize,
        weight: f64,
        sum: &mut [f64],
    ) -> crate::Result<()> {
        let _ = hdr;
        self.accumulate_range(enc, lo, hi, weight, sum)
    }

    /// Decode into a fresh vector (allocating convenience wrapper).
    fn decode(&self, enc: &Encoded) -> crate::Result<Vec<f32>> {
        let mut out = Vec::new();
        self.decode_into(enc, &mut out)?;
        Ok(out)
    }

    /// Compression noise injection without the wire — `decode(encode(x))`
    /// plus the exact wire bit count. Both execution modes share the same
    /// codec, so results are identical for equal seeds whether or not the
    /// bytes actually travel.
    fn apply(&self, x: &[f32], rng: &mut Rng) -> crate::Result<(Vec<f32>, u64)> {
        let enc = self.encode(x, rng);
        let bits = enc.bits();
        Ok((self.decode(&enc)?, bits))
    }
}

/// Full delegation for boxed codecs, so wrappers generic over
/// `C: UpdateCodec` (e.g. [`ErrorFeedbackCodec`]) can hold a
/// `Box<dyn UpdateCodec>` built from a [`CodecSpec`]. Every method —
/// including the defaulted ones — forwards to the boxed impl, so a
/// built-in's `decode_range` seek/skip fast path and statefulness
/// semantics survive the indirection.
impl UpdateCodec for Box<dyn UpdateCodec> {
    fn spec(&self) -> CodecSpec {
        (**self).spec()
    }

    fn wire_spec(&self) -> CodecSpec {
        (**self).wire_spec()
    }

    fn encode(&self, x: &[f32], rng: &mut Rng) -> Encoded {
        (**self).encode(x, rng)
    }

    fn encode_node(&self, node: usize, x: &[f32], rng: &mut Rng) -> Encoded {
        (**self).encode_node(node, x, rng)
    }

    fn stateful(&self) -> bool {
        (**self).stateful()
    }

    fn state_bytes(&self) -> u64 {
        (**self).state_bytes()
    }

    fn reset_state(&self) {
        (**self).reset_state()
    }

    fn state_export(&self) -> Vec<(u64, Vec<f32>)> {
        (**self).state_export()
    }

    fn state_import(&self, state: Vec<(u64, Vec<f32>)>) {
        (**self).state_import(state)
    }

    fn decode_into(&self, enc: &Encoded, out: &mut Vec<f32>) -> crate::Result<()> {
        (**self).decode_into(enc, out)
    }

    fn decode_range(
        &self,
        enc: &Encoded,
        lo: usize,
        hi: usize,
        out: &mut Vec<f32>,
    ) -> crate::Result<()> {
        (**self).decode_range(enc, lo, hi, out)
    }

    fn accumulate_range(
        &self,
        enc: &Encoded,
        lo: usize,
        hi: usize,
        weight: f64,
        sum: &mut [f64],
    ) -> crate::Result<()> {
        (**self).accumulate_range(enc, lo, hi, weight, sum)
    }

    fn open_frame(&self, enc: &Encoded) -> crate::Result<FrameHeader> {
        (**self).open_frame(enc)
    }

    fn accumulate_range_cached(
        &self,
        enc: &Encoded,
        hdr: &FrameHeader,
        lo: usize,
        hi: usize,
        weight: f64,
        sum: &mut [f64],
    ) -> crate::Result<()> {
        (**self).accumulate_range_cached(enc, hdr, lo, hi, weight, sum)
    }

    fn analytic_bits(&self, p: usize) -> Option<u64> {
        (**self).analytic_bits(p)
    }

    fn variance_q(&self, p: usize) -> f64 {
        (**self).variance_q(p)
    }
}

/// Reusable per-frame state parsed once by [`UpdateCodec::open_frame`]
/// and consumed by every shard-range call of
/// [`UpdateCodec::accumulate_range_cached`] on the same frame.
#[derive(Debug, Clone)]
pub enum FrameHeader {
    /// No cached state — the cached accumulate falls back to the plain
    /// per-range path. What the default `open_frame` returns.
    Opaque,
    /// The frame's kept coordinate indices, ascending. Seeded rand-k
    /// regenerates its Floyd sample once per upload here instead of
    /// once per shard range.
    SparseIndices(Vec<u32>),
}

/// A compressed, bit-packed model update as it travels to the server.
#[derive(Debug, Clone)]
pub struct Encoded {
    pub buf: BitBuf,
    /// Number of coordinates.
    pub p: usize,
    /// Codec configuration that produced this buffer (checked at decode).
    pub spec: CodecSpec,
}

impl Encoded {
    pub fn bits(&self) -> u64 {
        self.buf.len_bits()
    }
}

// ---------------- identity ----------------

/// Full-precision passthrough: the FedAvg baseline.
#[derive(Debug, Clone, Copy, Default)]
pub struct IdentityCodec;

impl UpdateCodec for IdentityCodec {
    fn spec(&self) -> CodecSpec {
        CodecSpec::Identity
    }

    fn encode(&self, x: &[f32], _rng: &mut Rng) -> Encoded {
        let mut w = BitWriter::new();
        for &v in x {
            w.write_f32(v);
        }
        Encoded { buf: w.finish(), p: x.len(), spec: self.spec() }
    }

    fn decode_into(&self, enc: &Encoded, out: &mut Vec<f32>) -> crate::Result<()> {
        // One decode implementation: the full decode is the 0..p range,
        // so the range and full paths can never drift apart.
        self.decode_range(enc, 0, enc.p, out)
    }

    fn decode_range(
        &self,
        enc: &Encoded,
        lo: usize,
        hi: usize,
        out: &mut Vec<f32>,
    ) -> crate::Result<()> {
        check_spec(self.spec(), enc)?;
        check_range(enc.p, lo, hi)?;
        identity_check_frame(enc)?;
        // Fixed-width stream: coordinate i lives at bit 32·i exactly.
        let mut r = enc.buf.reader_at(32 * lo as u64)?;
        out.clear();
        out.reserve(hi - lo);
        for _ in lo..hi {
            out.push(r.read_f32());
        }
        Ok(())
    }

    fn accumulate_range(
        &self,
        enc: &Encoded,
        lo: usize,
        hi: usize,
        weight: f64,
        sum: &mut [f64],
    ) -> crate::Result<()> {
        check_spec(self.spec(), enc)?;
        check_accumulate(enc.p, lo, hi, weight, sum.len())?;
        identity_check_frame(enc)?;
        // Fused word-level kernel: coordinate i is the low (even i) or
        // high (odd i) 32 bits of packed word i/2, so the body streams
        // two coordinates per u64 load with no BitReader per-call
        // overhead and no scratch buffer. Values are bit-identical to
        // `read_f32` — both are `f32::from_bits` of the same 32 bits.
        let words = enc.buf.words();
        let mut i = lo;
        // Head: an odd `lo` starts mid-word.
        if i < hi && i % 2 == 1 {
            let v = f32::from_bits((words[i / 2] >> 32) as u32);
            accumulate_one(&mut sum[i - lo], v, weight);
            i += 1;
        }
        // Body: two-wide, weight branch hoisted out of the loop.
        if weight == 1.0 {
            while i + 1 < hi {
                let w = words[i / 2];
                sum[i - lo] += f32::from_bits(w as u32) as f64;
                sum[i + 1 - lo] += f32::from_bits((w >> 32) as u32) as f64;
                i += 2;
            }
        } else {
            while i + 1 < hi {
                let w = words[i / 2];
                sum[i - lo] += weight * f32::from_bits(w as u32) as f64;
                sum[i + 1 - lo] += weight * f32::from_bits((w >> 32) as u32) as f64;
                i += 2;
            }
        }
        // Tail: an odd remaining count ends mid-word.
        if i < hi {
            let v = f32::from_bits(words[i / 2] as u32);
            accumulate_one(&mut sum[i - lo], v, weight);
        }
        Ok(())
    }

    fn analytic_bits(&self, p: usize) -> Option<u64> {
        Some(32 * p as u64)
    }

    fn variance_q(&self, _p: usize) -> f64 {
        0.0
    }
}

/// Exact data-independent frame size for the identity coding, checked up
/// front so every range (and the fused accumulate) rejects a truncated or
/// oversized frame per module-doc contract item 5.
fn identity_check_frame(enc: &Encoded) -> crate::Result<()> {
    let expect = 32 * enc.p as u64;
    anyhow::ensure!(
        enc.buf.len_bits() == expect,
        "identity frame truncated or oversized: {} bits, expected {expect}",
        enc.buf.len_bits()
    );
    Ok(())
}

// ---------------- QSGD ----------------

/// QSGD low-precision quantizer with `s` levels (paper Example 1).
#[derive(Debug, Clone, Copy)]
pub struct QsgdCodec {
    pub s: u32,
    pub coding: Coding,
}

impl QsgdCodec {
    pub fn new(s: u32) -> Self {
        QsgdCodec { s, coding: Coding::Naive }
    }
}

/// Shared QSGD-family encode body ([`QsgdCodec`] and
/// [`AdaptiveQsgdCodec`]): the per-coordinate stochastic rounding and
/// sign+level emission, appended after whatever header the caller has
/// already written. One implementation, so the two codecs' quantization
/// grids and RNG consumption can never drift apart.
pub(crate) fn qsgd_encode_body(
    w: &mut BitWriter,
    x: &[f32],
    norm: f32,
    s: u32,
    coding: Coding,
    rng: &mut Rng,
) {
    assert!(s >= 1, "QSGD needs at least one level");
    let nb = level_bits(s);
    let sf = s as f32;
    for &v in x {
        let sign = v < 0.0;
        let level = if norm > 0.0 {
            let a = v.abs() / norm * sf; // in [0, s]
            let lo = a.floor();
            let up = rng.gen_f32() < (a - lo);
            (lo as u32 + up as u32).min(s)
        } else {
            0
        };
        w.write_bit(sign);
        match coding {
            Coding::Naive => w.write_bits(level as u64, nb),
            Coding::Elias => elias::encode_omega(w, level as u64 + 1),
        }
    }
}

/// Shared QSGD-family range-decode body: seek (fixed-width) or checked
/// skip-scan (Elias) past `header_bits` plus `lo` coordinates, then
/// reconstruct `lo..hi` at `norm`/`s`. Corrupt-frame handling per the
/// module-doc contract: the naive coding validates its exact
/// data-independent frame size up front (so every range rejects a
/// truncated/oversized frame), the Elias coding checks every bit it
/// traverses plus the trailing bits whenever the range reaches `p`, and
/// every level a path *reads* — the Elias prefix skip included — is
/// bounded by `s` (a valid encode never emits one beyond it; the naive
/// seek path reads only the requested range, so a bad level hiding in
/// an untraversed fixed-width field is caught by whichever decode
/// touches it, `decode_into` always being one).
#[allow(clippy::too_many_arguments)]
pub(crate) fn qsgd_decode_range_body(
    enc: &Encoded,
    header_bits: u64,
    norm: f32,
    s: u32,
    coding: Coding,
    lo: usize,
    hi: usize,
    out: &mut Vec<f32>,
) -> crate::Result<()> {
    let nb = level_bits(s);
    let sf = s as f32;
    let mut r = match coding {
        // Fixed-width fields: coordinate i starts at bit
        // header + i·(1 + nb) — seek straight there.
        Coding::Naive => {
            let expect = header_bits + enc.p as u64 * (1 + nb as u64);
            anyhow::ensure!(
                enc.buf.len_bits() == expect,
                "QSGD frame truncated or oversized: {} bits, expected {expect}",
                enc.buf.len_bits()
            );
            enc.buf.reader_at(header_bits + lo as u64 * (1 + nb as u64))?
        }
        // Variable-width codes can't be addressed, but the prefix can
        // be *skipped*: advance through the first `lo` codes without
        // reconstructing any float (the scan is pure checked bit reads —
        // the level bound costs nothing extra, so the skipped prefix is
        // validated as strictly as the decoded range).
        Coding::Elias => {
            let mut r = enc.buf.reader_at(header_bits)?;
            for _ in 0..lo {
                anyhow::ensure!(
                    r.remaining() >= 1,
                    "QSGD frame truncated in the skipped prefix"
                );
                r.read_bit();
                let level = elias::try_decode_omega(&mut r)? - 1;
                anyhow::ensure!(
                    level <= s as u64,
                    "QSGD level {level} beyond s={s}: corrupt frame"
                );
            }
            r
        }
    };
    out.clear();
    out.reserve(hi - lo);
    for _ in lo..hi {
        let (sign, level) = match coding {
            Coding::Naive => (r.read_bit(), r.read_bits(nb)),
            Coding::Elias => {
                anyhow::ensure!(
                    r.remaining() >= 1,
                    "QSGD frame truncated mid-coordinate"
                );
                let sign = r.read_bit();
                (sign, elias::try_decode_omega(&mut r)? - 1)
            }
        };
        anyhow::ensure!(
            level <= s as u64,
            "QSGD level {level} beyond s={s}: corrupt frame"
        );
        let mag = norm * level as f32 / sf;
        out.push(if sign { -mag } else { mag });
    }
    // A range that reaches the end has traversed the whole level stream,
    // so trailing garbage is detectable (the naive coding's exact-size
    // check already covers it for every range).
    if coding == Coding::Elias && hi == enc.p {
        anyhow::ensure!(
            r.remaining() == 0,
            "QSGD frame truncated or oversized: {} trailing bits",
            r.remaining()
        );
    }
    Ok(())
}

/// Largest level count served by the stack reconstruction table in
/// [`qsgd_accumulate_range_body`]; `s >= QSGD_LUT_MAX` falls back to the
/// per-coordinate division (identical expression, identical bits).
pub(crate) const QSGD_LUT_MAX: usize = 256;

/// Shared QSGD-family fused accumulate body: the
/// [`UpdateCodec::accumulate_range`] counterpart of
/// [`qsgd_decode_range_body`], with the same validation surface and the
/// same reconstruction expression `norm * level as f32 / s as f32` —
/// precomputed into a stack table for small `s` (the common case), so
/// the naive coding's hot loop is one combined sign+level bit read and
/// one table lookup per coordinate: no scratch buffer, no per-coordinate
/// division, no second reader call.
#[allow(clippy::too_many_arguments)]
pub(crate) fn qsgd_accumulate_range_body(
    enc: &Encoded,
    header_bits: u64,
    norm: f32,
    s: u32,
    coding: Coding,
    lo: usize,
    hi: usize,
    weight: f64,
    sum: &mut [f64],
) -> crate::Result<()> {
    let nb = level_bits(s);
    let sf = s as f32;
    // Reconstruction table: lut[l] is bit-identical to the decode path's
    // `norm * l as f32 / sf` because it is that expression. Stack-only —
    // a heap table would cost an allocation per upload.
    let mut lut = [0.0f32; QSGD_LUT_MAX];
    let lut_len = (s as usize + 1).min(QSGD_LUT_MAX);
    for (l, slot) in lut.iter_mut().enumerate().take(lut_len) {
        *slot = norm * l as f32 / sf;
    }
    let lut = &lut[..lut_len];
    match coding {
        Coding::Naive => {
            let expect = header_bits + enc.p as u64 * (1 + nb as u64);
            anyhow::ensure!(
                enc.buf.len_bits() == expect,
                "QSGD frame truncated or oversized: {} bits, expected {expect}",
                enc.buf.len_bits()
            );
            let mut r = enc.buf.reader_at(header_bits + lo as u64 * (1 + nb as u64))?;
            for acc in sum.iter_mut() {
                // Sign is written first, so LSB-first packing puts it in
                // bit 0 of a combined (1 + nb)-bit read; the level is the
                // remaining high bits.
                let field = r.read_bits(1 + nb);
                let sign = field & 1 == 1;
                let level = (field >> 1) as usize;
                // The table lookup doubles as the `level <= s` bound for
                // tabulated levels.
                let mag = match lut.get(level) {
                    Some(&m) => m,
                    None => {
                        anyhow::ensure!(
                            level as u64 <= s as u64,
                            "QSGD level {level} beyond s={s}: corrupt frame"
                        );
                        norm * level as f32 / sf
                    }
                };
                accumulate_one(acc, if sign { -mag } else { mag }, weight);
            }
        }
        Coding::Elias => {
            // Same checked skip-scan as the decode body: every traversed
            // bit and level bound is validated identically.
            let mut r = enc.buf.reader_at(header_bits)?;
            for _ in 0..lo {
                anyhow::ensure!(
                    r.remaining() >= 1,
                    "QSGD frame truncated in the skipped prefix"
                );
                r.read_bit();
                let level = elias::try_decode_omega(&mut r)? - 1;
                anyhow::ensure!(
                    level <= s as u64,
                    "QSGD level {level} beyond s={s}: corrupt frame"
                );
            }
            for acc in sum.iter_mut() {
                anyhow::ensure!(
                    r.remaining() >= 1,
                    "QSGD frame truncated mid-coordinate"
                );
                let sign = r.read_bit();
                let level = elias::try_decode_omega(&mut r)? - 1;
                let mag = match lut.get(level as usize) {
                    Some(&m) => m,
                    None => {
                        anyhow::ensure!(
                            level <= s as u64,
                            "QSGD level {level} beyond s={s}: corrupt frame"
                        );
                        norm * level as f32 / sf
                    }
                };
                accumulate_one(acc, if sign { -mag } else { mag }, weight);
            }
            if hi == enc.p {
                anyhow::ensure!(
                    r.remaining() == 0,
                    "QSGD frame truncated or oversized: {} trailing bits",
                    r.remaining()
                );
            }
        }
    }
    Ok(())
}

impl UpdateCodec for QsgdCodec {
    fn spec(&self) -> CodecSpec {
        CodecSpec::Qsgd { s: self.s, coding: self.coding }
    }

    fn encode(&self, x: &[f32], rng: &mut Rng) -> Encoded {
        let norm = l2_norm(x);
        let mut w = BitWriter::new();
        w.write_f32(norm);
        qsgd_encode_body(&mut w, x, norm, self.s, self.coding, rng);
        Encoded { buf: w.finish(), p: x.len(), spec: self.spec() }
    }

    fn decode_into(&self, enc: &Encoded, out: &mut Vec<f32>) -> crate::Result<()> {
        // One decode implementation: the full decode is the 0..p range,
        // so the range and full paths can never drift apart.
        self.decode_range(enc, 0, enc.p, out)
    }

    fn decode_range(
        &self,
        enc: &Encoded,
        lo: usize,
        hi: usize,
        out: &mut Vec<f32>,
    ) -> crate::Result<()> {
        check_spec(self.spec(), enc)?;
        check_range(enc.p, lo, hi)?;
        anyhow::ensure!(
            enc.buf.len_bits() >= 32,
            "QSGD frame truncated: missing norm header"
        );
        let norm = enc.buf.reader().read_f32();
        qsgd_decode_range_body(enc, 32, norm, self.s, self.coding, lo, hi, out)
    }

    fn accumulate_range(
        &self,
        enc: &Encoded,
        lo: usize,
        hi: usize,
        weight: f64,
        sum: &mut [f64],
    ) -> crate::Result<()> {
        check_spec(self.spec(), enc)?;
        check_accumulate(enc.p, lo, hi, weight, sum.len())?;
        anyhow::ensure!(
            enc.buf.len_bits() >= 32,
            "QSGD frame truncated: missing norm header"
        );
        let norm = enc.buf.reader().read_f32();
        qsgd_accumulate_range_body(enc, 32, norm, self.s, self.coding, lo, hi, weight, sum)
    }

    fn analytic_bits(&self, p: usize) -> Option<u64> {
        match self.coding {
            Coding::Naive => Some(32 + (p as u64) * (1 + level_bits(self.s) as u64)),
            Coding::Elias => None,
        }
    }

    fn variance_q(&self, p: usize) -> f64 {
        let p = p as f64;
        let s = self.s as f64;
        (p / (s * s)).min(p.sqrt() / s)
    }
}

// ---------------- top-k sparsification ----------------

/// Magnitude top-k sparsification: keep the `k = max(1, p·k_permille/1000)`
/// largest-|·| coordinates at full precision, drop the rest.
///
/// A *biased* contraction (`E‖Q(x)−x‖² ≤ (1−k/p)‖x‖²`), deterministic
/// given `x` (ties broken toward the lower index). Index coding is either
/// fixed-width `ceil(log2 p)` bits or Elias-ω over ascending index gaps.
#[derive(Debug, Clone, Copy)]
pub struct TopKCodec {
    pub k_permille: u16,
    pub coding: Coding,
}

impl TopKCodec {
    pub fn new(k_permille: u16) -> Self {
        TopKCodec { k_permille, coding: Coding::Naive }
    }

    /// Number of kept coordinates for a length-`p` vector.
    pub fn k_of(&self, p: usize) -> usize {
        if p == 0 {
            0
        } else {
            (p * self.k_permille as usize / 1000).clamp(1, p)
        }
    }
}

/// Fixed-width bits needed to address a coordinate in `0..p`.
fn index_bits(p: usize) -> u32 {
    if p <= 1 {
        0
    } else {
        64 - ((p - 1) as u64).leading_zeros()
    }
}

/// Shared sparse-stream wire logic, encode side: `(Elias-ω delta index,
/// f32 value)` pairs over an ascending `idx` set — the format
/// [`TopKCodec`]'s Elias mode and [`RandKCodec`]'s explicit mode both
/// speak, implemented once so their index coding cannot drift.
pub(crate) fn sparse_encode_elias(w: &mut BitWriter, idx: &[u32], x: &[f32]) {
    // Gaps are >= 1: first gap is index+1, then deltas of a strictly
    // ascending sequence.
    let mut prev: u64 = 0;
    for (j, &i) in idx.iter().enumerate() {
        let gap = if j == 0 { i as u64 + 1 } else { i as u64 - prev };
        elias::encode_omega(w, gap);
        prev = i as u64;
        w.write_f32(x[i as usize]);
    }
}

/// Shared sparse-stream scan: validate and walk all `k` Elias-delta
/// `(index, value)` pairs (k ≪ p, and the full scan preserves the
/// ascending/unique/in-range/truncation validation for *every* caller),
/// calling `visit(i, v)` for each pair with `v` already scaled. Both the
/// range decode ([`sparse_decode_elias`]) and the fused accumulate
/// kernels drive this one scan, so their validation and reconstruction
/// cannot drift. `what` names the codec in errors.
pub(crate) fn sparse_scan_elias(
    enc: &Encoded,
    k: usize,
    scale: f32,
    what: &str,
    mut visit: impl FnMut(usize, f32),
) -> crate::Result<()> {
    let p = enc.p;
    let mut r = enc.buf.reader();
    let mut prev: u64 = 0;
    for j in 0..k {
        let gap = elias::try_decode_omega(&mut r).map_err(|e| {
            anyhow::anyhow!(
                "{what} frame truncated or oversized: {e} (k={k}, Elias indices)"
            )
        })?;
        let i = if j == 0 { gap - 1 } else { prev + gap };
        // The wire contract is strictly ascending unique indices;
        // enforcing it rejects corrupt frames that would otherwise
        // silently overwrite coordinates.
        anyhow::ensure!(
            j == 0 || i > prev,
            "{what} indices not strictly ascending ({i} after {prev})"
        );
        prev = i;
        let i = i as usize;
        anyhow::ensure!(i < p, "{what} index {i} out of range 0..{p}");
        anyhow::ensure!(
            r.remaining() >= 32,
            "{what} frame truncated or oversized: value {j} of {k} cut short"
        );
        let v = r.read_f32();
        // Exact-1.0 fast path: unscaled codecs (top-k) reproduce the
        // stored bit pattern verbatim, NaN payloads included.
        visit(i, if scale == 1.0 { v } else { scale * v });
    }
    anyhow::ensure!(
        r.remaining() == 0,
        "{what} frame truncated or oversized: {} trailing bits after {k} pairs",
        r.remaining()
    );
    Ok(())
}

/// Shared sparse-stream decode over [`sparse_scan_elias`]: place the
/// in-window values into `out` (length `hi − lo`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn sparse_decode_elias(
    enc: &Encoded,
    k: usize,
    lo: usize,
    hi: usize,
    scale: f32,
    out: &mut [f32],
    what: &str,
) -> crate::Result<()> {
    debug_assert_eq!(out.len(), hi - lo);
    sparse_scan_elias(enc, k, scale, what, |i, v| {
        if i >= lo && i < hi {
            out[i - lo] = v;
        }
    })
}

/// Shared top-k fixed-width-index scan: validate the exact frame size and
/// walk all `k` `(index, value)` pairs, calling `visit(i, v)` for each —
/// the naive-coding counterpart of [`sparse_scan_elias`], shared by
/// [`TopKCodec`]'s range decode and fused accumulate.
pub(crate) fn topk_scan_naive(
    enc: &Encoded,
    k: usize,
    mut visit: impl FnMut(usize, f32),
) -> crate::Result<()> {
    let p = enc.p;
    let nb = index_bits(p);
    // Exact data-independent frame size, checked up front.
    let expect = k as u64 * (nb as u64 + 32);
    anyhow::ensure!(
        enc.buf.len_bits() == expect,
        "top-k frame truncated or oversized: {} bits, expected \
         {expect} (k={k}, fixed-width indices)",
        enc.buf.len_bits()
    );
    let mut r = enc.buf.reader();
    let mut prev: u64 = 0;
    for j in 0..k {
        let i = r.read_bits(nb);
        // Strictly ascending unique indices — same wire
        // contract the Elias path enforces.
        anyhow::ensure!(
            j == 0 || i > prev,
            "top-k indices not strictly ascending ({i} after {prev})"
        );
        prev = i;
        let i = i as usize;
        anyhow::ensure!(i < p, "top-k index {i} out of range 0..{p}");
        visit(i, r.read_f32());
    }
    Ok(())
}

impl UpdateCodec for TopKCodec {
    fn spec(&self) -> CodecSpec {
        CodecSpec::TopK { k_permille: self.k_permille, coding: self.coding }
    }

    fn encode(&self, x: &[f32], _rng: &mut Rng) -> Encoded {
        let p = x.len();
        let k = self.k_of(p);
        let mut order: Vec<u32> = (0..p as u32).collect();
        if k < p {
            // Partial select: |x| descending, index ascending on ties, so
            // the kept set is deterministic across runs and platforms.
            order.select_nth_unstable_by(k, |&a, &b| {
                x[b as usize]
                    .abs()
                    .total_cmp(&x[a as usize].abs())
                    .then(a.cmp(&b))
            });
        }
        order.truncate(k);
        order.sort_unstable();
        let mut w = BitWriter::new();
        match self.coding {
            Coding::Naive => {
                let nb = index_bits(p);
                for &i in &order {
                    w.write_bits(i as u64, nb);
                    w.write_f32(x[i as usize]);
                }
            }
            Coding::Elias => sparse_encode_elias(&mut w, &order, x),
        }
        Encoded { buf: w.finish(), p, spec: self.spec() }
    }

    fn decode_into(&self, enc: &Encoded, out: &mut Vec<f32>) -> crate::Result<()> {
        // One decode implementation: the full decode is the 0..p range,
        // so the range and full paths can never drift apart.
        self.decode_range(enc, 0, enc.p, out)
    }

    fn decode_range(
        &self,
        enc: &Encoded,
        lo: usize,
        hi: usize,
        out: &mut Vec<f32>,
    ) -> crate::Result<()> {
        check_spec(self.spec(), enc)?;
        check_range(enc.p, lo, hi)?;
        let k = self.k_of(enc.p);
        out.clear();
        out.resize(hi - lo, 0.0);
        // The stream is k sparse (index, value) pairs in ascending index
        // order: scan them all (k ≪ p), keep the ones inside `lo..hi`.
        // The full-stream scan preserves the ascending/unique/in-range/
        // truncation validation for every range, so a corrupt upload is
        // rejected identically whichever entry point sees it — both
        // codings now drive the shared scans (`topk_scan_naive`,
        // `sparse_decode_elias`) the fused accumulate also uses.
        match self.coding {
            Coding::Naive => topk_scan_naive(enc, k, |i, v| {
                if i >= lo && i < hi {
                    out[i - lo] = v;
                }
            })?,
            Coding::Elias => sparse_decode_elias(enc, k, lo, hi, 1.0, out, "top-k")?,
        }
        Ok(())
    }

    fn accumulate_range(
        &self,
        enc: &Encoded,
        lo: usize,
        hi: usize,
        weight: f64,
        sum: &mut [f64],
    ) -> crate::Result<()> {
        check_spec(self.spec(), enc)?;
        check_accumulate(enc.p, lo, hi, weight, sum.len())?;
        let k = self.k_of(enc.p);
        // Scatter-add the in-window pairs straight into `sum`. Skipping
        // the implicit zeros is bit-identical to the scratch path by the
        // trait's no-`-0.0`-accumulator guarantee.
        match self.coding {
            Coding::Naive => topk_scan_naive(enc, k, |i, v| {
                if i >= lo && i < hi {
                    accumulate_one(&mut sum[i - lo], v, weight);
                }
            }),
            Coding::Elias => sparse_scan_elias(enc, k, 1.0, "top-k", |i, v| {
                if i >= lo && i < hi {
                    accumulate_one(&mut sum[i - lo], v, weight);
                }
            }),
        }
    }

    fn analytic_bits(&self, p: usize) -> Option<u64> {
        match self.coding {
            Coding::Naive => {
                Some(self.k_of(p) as u64 * (index_bits(p) as u64 + 32))
            }
            Coding::Elias => None,
        }
    }

    /// Worst-case contraction factor `1 − k/p`, NOT an Assumption-1
    /// certificate: top-k is biased (`E[Q(x)] ≠ x`), so the paper's
    /// Theorem 1/2 machinery — which additionally assumes unbiasedness —
    /// does not apply to this codec even though the error-ratio bound
    /// `‖Q(x)−x‖² ≤ (1−k/p)‖x‖²` holds deterministically.
    fn variance_q(&self, p: usize) -> f64 {
        if p == 0 {
            0.0
        } else {
            1.0 - self.k_of(p) as f64 / p as f64
        }
    }
}

// ---------------- shared helpers ----------------

/// Validate a [`UpdateCodec::decode_range`] request against the upload's
/// coordinate count.
pub(crate) fn check_range(p: usize, lo: usize, hi: usize) -> crate::Result<()> {
    anyhow::ensure!(
        lo <= hi && hi <= p,
        "decode_range {lo}..{hi} invalid for a {p}-coordinate upload"
    );
    Ok(())
}

pub(crate) fn check_spec(expect: CodecSpec, enc: &Encoded) -> crate::Result<()> {
    anyhow::ensure!(
        enc.spec == expect,
        "decoding with a mismatched codec config: buffer is {:?}, codec is {:?}",
        enc.spec,
        expect
    );
    Ok(())
}

/// Validate an [`UpdateCodec::accumulate_range`] request: the range
/// itself, the accumulator length, and the weight (same bounds and
/// message the [`Aggregator`](crate::coordinator::aggregate::Aggregator)
/// enforces, so the two layers can never disagree on a weight's
/// validity).
pub(crate) fn check_accumulate(
    p: usize,
    lo: usize,
    hi: usize,
    weight: f64,
    sum_len: usize,
) -> crate::Result<()> {
    check_range(p, lo, hi)?;
    anyhow::ensure!(
        sum_len == hi - lo,
        "accumulate_range {lo}..{hi} into a {sum_len}-element accumulator"
    );
    anyhow::ensure!(
        weight.is_finite() && weight > 0.0,
        "aggregation weight must be finite and positive, got {weight}"
    );
    Ok(())
}

/// One fused accumulation step: `*acc += weight * v` in f64, with the
/// multiply skipped (not just exact) at `weight == 1.0` so the uniform
/// path stays bit-identical to the historical unweighted mean.
#[inline]
pub(crate) fn accumulate_one(acc: &mut f64, v: f32, weight: f64) {
    if weight == 1.0 {
        *acc += v as f64;
    } else {
        *acc += v as f64 * weight;
    }
}

/// Widening add of a decoded slice into f64 accumulators — the scratch
/// half of the [`UpdateCodec::accumulate_range`] default, with the
/// weight branch hoisted out of the loop.
pub(crate) fn accumulate_slice(sum: &mut [f64], dec: &[f32], weight: f64) {
    debug_assert_eq!(sum.len(), dec.len());
    if weight == 1.0 {
        for (acc, &v) in sum.iter_mut().zip(dec) {
            *acc += v as f64;
        }
    } else {
        for (acc, &v) in sum.iter_mut().zip(dec) {
            *acc += v as f64 * weight;
        }
    }
}

/// Fixed-width bits needed for a QSGD level in `0..=s`.
pub fn level_bits(s: u32) -> u32 {
    32 - s.leading_zeros() // ceil(log2(s+1)) for s >= 1
}

/// l2 norm with f64 accumulation (bit-stable across call sites).
pub fn l2_norm(x: &[f32]) -> f32 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng(seed: u64) -> Rng {
        Rng::seed_from_u64(seed)
    }

    #[test]
    fn identity_roundtrip_exact() {
        let x: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.3).collect();
        let q = IdentityCodec;
        let (y, bits) = q.apply(&x, &mut rng(0)).unwrap();
        assert_eq!(x, y);
        assert_eq!(bits, 3200);
        assert_eq!(q.analytic_bits(100), Some(3200));
        assert_eq!(q.variance_q(100), 0.0);
    }

    #[test]
    fn qsgd_levels_on_grid() {
        // Every decoded magnitude must be norm * l / s for integer l <= s.
        let x: Vec<f32> = (0..257).map(|i| ((i * 37) % 101) as f32 - 50.0).collect();
        for s in [1u32, 2, 5, 10, 64] {
            let q = QsgdCodec::new(s);
            let enc = q.encode(&x, &mut rng(1));
            let norm = l2_norm(&x);
            for (i, v) in q.decode(&enc).unwrap().iter().enumerate() {
                let lvl = v.abs() / norm * s as f32;
                assert!(
                    (lvl - lvl.round()).abs() < 1e-4,
                    "coord {i} level {lvl} not integral (s={s})"
                );
                assert!(lvl.round() as u32 <= s);
            }
        }
    }

    #[test]
    fn qsgd_bit_accounting_naive() {
        let x = vec![0.5f32; 1000];
        for s in [1u32, 3, 10, 100] {
            let q = QsgdCodec::new(s);
            let enc = q.encode(&x, &mut rng(2));
            assert_eq!(Some(enc.bits()), q.analytic_bits(1000), "s={s}");
        }
        // s=1 → 2 bits/coord + 32-bit norm.
        assert_eq!(QsgdCodec::new(1).analytic_bits(1000), Some(32 + 2000));
    }

    #[test]
    fn qsgd_unbiased_empirically() {
        let x: Vec<f32> = (0..64).map(|i| ((i as f32) * 0.17).sin()).collect();
        let q = QsgdCodec::new(2);
        let mut acc = vec![0f64; x.len()];
        let trials = 4000;
        let mut r = rng(3);
        for _ in 0..trials {
            for (a, v) in acc.iter_mut().zip(q.apply(&x, &mut r).unwrap().0) {
                *a += v as f64;
            }
        }
        let norm = l2_norm(&x) as f64;
        for (i, (&xi, &ai)) in x.iter().zip(acc.iter()).enumerate() {
            let mean = ai / trials as f64;
            // CLT tolerance: sd of one sample ≤ norm/s; 5σ/√trials bound.
            let tol = 5.0 * (norm / 2.0) / (trials as f64).sqrt();
            assert!(
                (mean - xi as f64).abs() < tol,
                "coord {i}: mean {mean} vs {xi} (tol {tol})"
            );
        }
    }

    #[test]
    fn qsgd_variance_bound_holds() {
        // E||Q(x)-x||^2 <= q ||x||^2 with q = min(p/s^2, sqrt(p)/s).
        let p = 128;
        let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.31).cos()).collect();
        let norm2 = (l2_norm(&x) as f64).powi(2);
        for s in [1u32, 4, 16] {
            let q = QsgdCodec::new(s);
            let bound = q.variance_q(p) * norm2;
            let mut err = 0.0f64;
            let trials = 2000;
            let mut r = rng(4);
            for _ in 0..trials {
                let y = q.apply(&x, &mut r).unwrap().0;
                err += x
                    .iter()
                    .zip(&y)
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum::<f64>();
            }
            let mean_err = err / trials as f64;
            assert!(
                mean_err <= bound * 1.05 + 1e-9,
                "s={s}: measured {mean_err} > bound {bound}"
            );
        }
    }

    #[test]
    fn elias_coding_roundtrip_and_smaller_when_sparse() {
        // A peaked vector has mostly level-0 coords at high s: Elias wins.
        let mut x = vec![1e-4f32; 4096];
        x[0] = 10.0;
        let naive = QsgdCodec { s: 64, coding: Coding::Naive };
        let elias_q = QsgdCodec { s: 64, coding: Coding::Elias };
        let en = naive.encode(&x, &mut rng(5));
        let ee = elias_q.encode(&x, &mut rng(5));
        assert!(ee.bits() < en.bits(), "{} !< {}", ee.bits(), en.bits());
        // And both decode to on-grid values of the same norm scale.
        let dn = naive.decode(&en).unwrap();
        let de = elias_q.decode(&ee).unwrap();
        assert_eq!(dn.len(), de.len());
    }

    #[test]
    fn zero_vector_is_exact() {
        let x = vec![0f32; 57];
        let q = QsgdCodec::new(4);
        let (y, _) = q.apply(&x, &mut rng(6)).unwrap();
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn decode_mismatch_is_rejected() {
        let x = vec![1f32; 8];
        let enc = QsgdCodec::new(2).encode(&x, &mut rng(7));
        assert!(QsgdCodec::new(3).decode(&enc).is_err());
        assert!(IdentityCodec.decode(&enc).is_err());
        assert!(TopKCodec::new(500).decode(&enc).is_err());
    }

    #[test]
    fn top_k_keeps_largest_and_zeroes_rest() {
        let x: Vec<f32> = (0..40).map(|i| ((i as f32) * 0.7).sin() * i as f32).collect();
        for coding in [Coding::Naive, Coding::Elias] {
            let q = TopKCodec { k_permille: 250, coding };
            let k = q.k_of(x.len());
            assert_eq!(k, 10);
            let enc = q.encode(&x, &mut rng(8));
            let y = q.decode(&enc).unwrap();
            assert_eq!(y.len(), x.len());
            let kept: Vec<usize> =
                (0..x.len()).filter(|&i| y[i] != 0.0).collect();
            assert!(kept.len() <= k);
            // Kept values are exact copies.
            for &i in &kept {
                assert_eq!(y[i], x[i], "coord {i}");
            }
            // Every kept magnitude >= every dropped magnitude.
            let min_kept = kept
                .iter()
                .map(|&i| x[i].abs())
                .fold(f32::INFINITY, f32::min);
            for i in 0..x.len() {
                if y[i] == 0.0 {
                    assert!(
                        x[i].abs() <= min_kept,
                        "dropped {i} (|{}|) beats kept min {min_kept}",
                        x[i]
                    );
                }
            }
        }
    }

    #[test]
    fn top_k_bit_accounting_naive() {
        let x: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.13).cos()).collect();
        let q = TopKCodec::new(100); // k = 100 of 1000
        let enc = q.encode(&x, &mut rng(9));
        // 10 index bits + 32 value bits per kept coordinate.
        assert_eq!(enc.bits(), 100 * 42);
        assert_eq!(q.analytic_bits(1000), Some(100 * 42));
        // Elias size is data-dependent.
        assert_eq!(
            TopKCodec { k_permille: 100, coding: Coding::Elias }.analytic_bits(1000),
            None
        );
    }

    #[test]
    fn top_k_variance_is_contraction_factor() {
        let q = TopKCodec::new(250);
        assert!((q.variance_q(1000) - 0.75).abs() < 1e-12);
        assert_eq!(IdentityCodec.variance_q(1000), 0.0);
    }

    #[test]
    fn spec_build_roundtrips() {
        for spec in [
            CodecSpec::Identity,
            CodecSpec::qsgd(3),
            CodecSpec::Qsgd { s: 7, coding: Coding::Elias },
            CodecSpec::top_k(125),
            CodecSpec::TopK { k_permille: 50, coding: Coding::Elias },
            CodecSpec::rand_k(100),
            CodecSpec::RandK { k_permille: 250, seeded: false },
            CodecSpec::adaptive(4),
            CodecSpec::AdaptiveQsgd { bits_per_coord: 6, coding: Coding::Elias },
            CodecSpec::error_feedback(CodecSpec::qsgd(2)),
            CodecSpec::error_feedback(CodecSpec::rand_k(100)),
        ] {
            assert_eq!(spec.build().unwrap().spec(), spec);
        }
    }

    #[test]
    fn spec_families_and_rebuildability() {
        assert_eq!(CodecSpec::Identity.family(), "identity");
        assert_eq!(CodecSpec::qsgd(1).family(), "qsgd");
        assert_eq!(CodecSpec::top_k(10).family(), "topk");
        assert_eq!(CodecSpec::rand_k(10).family(), "randk");
        assert_eq!(CodecSpec::adaptive(4).family(), "adaptive_qsgd");
        let ef = CodecSpec::error_feedback(CodecSpec::qsgd(1));
        assert_eq!(ef.family(), "error_feedback");
        assert!(ef.is_stateful() && ef.rebuildable());
        assert!(!CodecSpec::qsgd(1).is_stateful());
        assert!(!CodecSpec::External { id: 3 }.rebuildable());
        assert!(
            !CodecSpec::error_feedback(CodecSpec::External { id: 3 }).rebuildable()
        );
        // An EF spec wrapping External cannot build (no inner instance).
        assert!(CodecSpec::error_feedback(CodecSpec::External { id: 3 })
            .build()
            .is_err());
    }

    #[test]
    fn qsgd_truncated_or_forged_frames_are_rejected_on_both_codings() {
        // The shared qsgd_decode_range_body contract (also covering
        // AdaptiveQsgdCodec): truncated, padded, and beyond-s-level
        // frames are explicit errors, not fabricated values — release
        // builds don't bounds-assert raw bit reads, so the unchecked
        // decoder used to read zero padding and "succeed".
        let x: Vec<f32> = (0..50).map(|i| (i as f32 * 0.3).sin()).collect();
        for coding in [Coding::Naive, Coding::Elias] {
            let q = QsgdCodec { s: 5, coding };
            let full = q.encode(&x, &mut rng(21));
            // Empty frame claiming 50 coordinates.
            let empty = Encoded { buf: BitWriter::new().finish(), p: 50, spec: q.spec() };
            assert!(q.decode(&empty).is_err(), "{coding:?}: empty accepted");
            // Truncated mid-stream.
            let mut w = BitWriter::new();
            let mut r = full.buf.reader();
            for _ in 0..full.buf.len_bits() / 2 {
                w.write_bit(r.read_bit());
            }
            let cut = Encoded { buf: w.finish(), p: 50, spec: q.spec() };
            assert!(q.decode(&cut).is_err(), "{coding:?}: truncated accepted");
            // Trailing garbage past the last coordinate.
            let mut w = BitWriter::new();
            let mut r = full.buf.reader();
            for _ in 0..full.buf.len_bits() {
                w.write_bit(r.read_bit());
            }
            w.write_bit(true);
            let padded = Encoded { buf: w.finish(), p: 50, spec: q.spec() };
            assert!(q.decode(&padded).is_err(), "{coding:?}: trailing accepted");
        }
        // An Elias code claiming a level beyond s is rejected, not scaled
        // into a giant magnitude.
        let q = QsgdCodec { s: 2, coding: Coding::Elias };
        let mut w = BitWriter::new();
        w.write_f32(1.0);
        for _ in 0..3 {
            w.write_bit(false);
            elias::encode_omega(&mut w, 9); // level 8 > s=2
        }
        let forged = Encoded { buf: w.finish(), p: 3, spec: q.spec() };
        assert!(q.decode(&forged).is_err(), "beyond-s level accepted");
    }

    #[test]
    fn top_k_truncated_frames_error_identically_on_both_codings() {
        // Regression: the fixed-width path used to validate nothing about
        // the frame size while the Elias path read fabricated zero bits
        // past the end — empty/truncated frames must be an explicit Err
        // (never a panic, never silent zeros) on BOTH index codings.
        let x: Vec<f32> = (0..40).map(|i| (i as f32 * 0.7).sin() + 1.0).collect();
        for coding in [Coding::Naive, Coding::Elias] {
            let q = TopKCodec { k_permille: 250, coding };
            // Empty frame claiming p=40 coordinates.
            let empty = Encoded { buf: BitWriter::new().finish(), p: 40, spec: q.spec() };
            assert!(q.decode(&empty).is_err(), "{coding:?}: empty accepted");
            let mut out = Vec::new();
            assert!(q.decode_range(&empty, 0, 40, &mut out).is_err());
            assert!(q.decode_range(&empty, 0, 0, &mut out).is_err(), "{coding:?}");
            // Frame truncated mid-stream: cut the real encode in half.
            let full = q.encode(&x, &mut rng(13));
            let mut w = BitWriter::new();
            let mut r = full.buf.reader();
            for _ in 0..full.buf.len_bits() / 2 {
                w.write_bit(r.read_bit());
            }
            let cut = Encoded { buf: w.finish(), p: 40, spec: q.spec() };
            assert!(q.decode(&cut).is_err(), "{coding:?}: truncated accepted");
            // Frame with trailing garbage bits.
            let mut w = BitWriter::new();
            let mut r = full.buf.reader();
            for _ in 0..full.buf.len_bits() {
                w.write_bit(r.read_bit());
            }
            w.write_bits(0b101, 3);
            let padded = Encoded { buf: w.finish(), p: 40, spec: q.spec() };
            assert!(q.decode(&padded).is_err(), "{coding:?}: trailing accepted");
        }
    }

    #[test]
    fn external_spec_is_distinct_and_not_buildable() {
        // A custom codec tags itself External{id}: mismatch checks hold
        // against every built-in, and the spec cannot silently rebuild
        // into something else.
        let ext = CodecSpec::External { id: 7 };
        assert!(ext.build().is_err());
        assert!(ext.variance_q(100).is_nan());
        assert_ne!(ext, CodecSpec::Identity);
        assert_ne!(ext, CodecSpec::External { id: 8 });
    }

    #[test]
    fn decode_range_matches_full_decode_slice() {
        // Every built-in codec/coding, a spread of split points including
        // the empty and full ranges and word-boundary-unfriendly offsets.
        let p = 257;
        let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.37).sin() * 3.0).collect();
        let codecs: Vec<Box<dyn UpdateCodec>> = vec![
            Box::new(IdentityCodec),
            Box::new(QsgdCodec { s: 1, coding: Coding::Naive }),
            Box::new(QsgdCodec { s: 5, coding: Coding::Naive }),
            Box::new(QsgdCodec { s: 5, coding: Coding::Elias }),
            Box::new(TopKCodec { k_permille: 200, coding: Coding::Naive }),
            Box::new(TopKCodec { k_permille: 200, coding: Coding::Elias }),
            Box::new(RandKCodec { k_permille: 200, seeded: true }),
            Box::new(RandKCodec { k_permille: 200, seeded: false }),
            Box::new(AdaptiveQsgdCodec { bits_per_coord: 4, coding: Coding::Naive }),
            Box::new(AdaptiveQsgdCodec { bits_per_coord: 5, coding: Coding::Elias }),
            CodecSpec::error_feedback(CodecSpec::qsgd(3)).build().unwrap(),
        ];
        for q in &codecs {
            let enc = q.encode(&x, &mut rng(11));
            let full = q.decode(&enc).unwrap();
            let mut out = Vec::new();
            for (lo, hi) in [(0, p), (0, 0), (p, p), (0, 1), (63, 129), (200, p), (7, 8)] {
                q.decode_range(&enc, lo, hi, &mut out)
                    .unwrap_or_else(|e| panic!("{:?} {lo}..{hi}: {e}", q.spec()));
                assert_eq!(out.len(), hi - lo, "{:?} {lo}..{hi}", q.spec());
                assert_eq!(out, &full[lo..hi], "{:?} {lo}..{hi}", q.spec());
            }
            // Out-of-range and inverted requests are rejected.
            assert!(q.decode_range(&enc, 0, p + 1, &mut out).is_err());
            assert!(q.decode_range(&enc, 5, 4, &mut out).is_err());
            // Mismatched codec configs are rejected through this entry too.
            assert!(QsgdCodec::new(9).decode_range(&enc, 0, 1, &mut out).is_err());
        }
    }

    #[test]
    fn top_k_decode_rejects_duplicate_indices() {
        // Hand-craft a naive-coded frame carrying the same index twice.
        let q = TopKCodec::new(500); // k = 2 of 4
        let mut w = BitWriter::new();
        let nb = index_bits(4);
        w.write_bits(1, nb);
        w.write_f32(1.5);
        w.write_bits(1, nb); // duplicate index
        w.write_f32(-2.5);
        let enc = Encoded { buf: w.finish(), p: 4, spec: q.spec() };
        assert!(q.decode(&enc).is_err());
    }

    #[test]
    fn identity_accumulate_handles_odd_ranges_and_weights() {
        // The word-level kernel has head/body/tail cases keyed to range
        // parity — exercise every alignment against the scratch path.
        let p = 11;
        let x: Vec<f32> = (0..p).map(|i| (i as f32 - 5.0) * 0.75).collect();
        let q = IdentityCodec;
        let enc = q.encode(&x, &mut rng(21));
        for (lo, hi) in [(0, p), (0, 0), (1, p), (1, p - 1), (2, 3), (3, 4), (p, p)] {
            for weight in [1.0f64, 0.5, 0.3] {
                let mut fused: Vec<f64> = (0..hi - lo).map(|i| i as f64 * 0.25).collect();
                let mut want = fused.clone();
                q.accumulate_range(&enc, lo, hi, weight, &mut fused).unwrap();
                let mut dec = Vec::new();
                q.decode_range(&enc, lo, hi, &mut dec).unwrap();
                accumulate_slice(&mut want, &dec, weight);
                for (j, (f, w)) in fused.iter().zip(&want).enumerate() {
                    assert_eq!(
                        f.to_bits(),
                        w.to_bits(),
                        "{lo}..{hi} w={weight} coord {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn qsgd_accumulate_beyond_the_level_table_matches_decode() {
        // s values straddling QSGD_LUT_MAX force both the table hit and
        // the division fallback through the same reconstruction bits.
        let p = 64;
        let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.29).cos() * 2.0).collect();
        for s in [255u32, 256, 1000] {
            for coding in [Coding::Naive, Coding::Elias] {
                let q = QsgdCodec { s, coding };
                let enc = q.encode(&x, &mut rng(22));
                let dec = q.decode(&enc).unwrap();
                let mut fused = vec![0.0f64; p];
                q.accumulate_range(&enc, 0, p, 1.0, &mut fused).unwrap();
                for (j, (f, &v)) in fused.iter().zip(&dec).enumerate() {
                    assert_eq!(
                        f.to_bits(),
                        (v as f64).to_bits(),
                        "s={s} {coding:?} coord {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn accumulate_range_rejects_bad_args_and_truncated_frames() {
        let p = 32;
        let x: Vec<f32> = (0..p).map(|i| (i as f32 * 0.11).sin()).collect();
        let q = QsgdCodec::new(4);
        let enc = q.encode(&x, &mut rng(23));
        let mut sum = vec![0.0f64; p];
        // Accumulator length must be exactly hi - lo.
        assert!(q.accumulate_range(&enc, 0, p, 1.0, &mut sum[..p - 1]).is_err());
        assert!(q.accumulate_range(&enc, 1, p, 1.0, &mut sum).is_err());
        // Bad ranges and weights, same surface as the aggregator.
        assert!(q.accumulate_range(&enc, 0, p + 1, 1.0, &mut sum).is_err());
        assert!(q.accumulate_range(&enc, 5, 4, 1.0, &mut [0.0; 0][..]).is_err());
        for w in [0.0f64, -1.0, f64::NAN, f64::INFINITY] {
            assert!(q.accumulate_range(&enc, 0, p, w, &mut sum).is_err(), "{w}");
        }
        // Spec mismatch and truncation reject exactly like decode_range.
        assert!(QsgdCodec::new(5).accumulate_range(&enc, 0, p, 1.0, &mut sum).is_err());
        let mut w = BitWriter::new();
        let mut r = enc.buf.reader();
        for _ in 0..enc.buf.len_bits() / 2 {
            w.write_bit(r.read_bit());
        }
        let cut = Encoded { buf: w.finish(), p, spec: q.spec() };
        assert!(q.accumulate_range(&cut, 0, p, 1.0, &mut sum).is_err());
        // Identity's fused path got a frame-size check too.
        let id = IdentityCodec;
        let good = id.encode(&x, &mut rng(24));
        let short = Encoded { buf: BitWriter::new().finish(), p, spec: id.spec() };
        assert!(id.accumulate_range(&short, 0, p, 1.0, &mut sum).is_err());
        assert!(id.decode_range(&short, 0, 0, &mut Vec::new()).is_err());
        assert!(id.accumulate_range(&good, 0, p, 1.0, &mut sum).is_ok());
    }
}
