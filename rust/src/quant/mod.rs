//! Quantized message passing (paper §3.3) — the third FedPAQ module.
//!
//! Implements the QSGD low-precision quantizer of Example 1 with a
//! bit-exact wire codec, so the §5 cost model can charge the *actual*
//! number of uploaded bits `|Q(p, s)|`, plus the identity codec used by
//! the FedAvg baseline (full-precision uploads, `32·p` bits).
//!
//! Wire format (little-endian bit packing, see [`bitstream`]):
//!
//! ```text
//! [ norm: f32 ]  then per coordinate i in 0..p:
//!   naive coding:  [ sign: 1 bit ][ level: ceil(log2(s+1)) bits ]
//!   elias coding:  [ sign: 1 bit ][ EliasOmega(level + 1) ]
//! ```
//!
//! The dequantized coordinate is `norm * sign_i * level_i / s`, exactly the
//! value the L1 Pallas kernel produces — parity is enforced by an
//! integration test through the exported `quantize4096` artifact.

pub mod bitstream;
pub mod elias;

use bitstream::{BitBuf, BitWriter};
use crate::util::rng::Rng;

/// Which level-entropy coding the QSGD codec uses on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Coding {
    /// Fixed-width levels: `1 + ceil(log2(s+1))` bits/coordinate. This is
    /// the paper's accounting (`s=1` → 2 bits vs `F=32` unquantized).
    #[default]
    Naive,
    /// QSGD's Elias-ω recursive coding of `level+1` — shorter when most
    /// levels are zero (large `s`, sparse-ish updates).
    Elias,
}

/// Quantizer configuration: what a node applies to `x_{k,τ} − x_k`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Quantizer {
    /// No quantization (FedAvg baseline): full f32 upload.
    Identity,
    /// QSGD low-precision quantizer with `s` levels (paper Example 1).
    Qsgd { s: u32, coding: Coding },
}

impl Quantizer {
    /// QSGD with `s` levels and the paper's naive fixed-width accounting.
    pub fn qsgd(s: u32) -> Self {
        Quantizer::Qsgd { s, coding: Coding::Naive }
    }

    /// Variance parameter `q` from Assumption 1:
    /// `E||Q(x)−x||² ≤ q‖x‖²` with `q = min(p/s², √p/s)` for QSGD and
    /// `q = 0` for the identity.
    pub fn variance_q(&self, p: usize) -> f64 {
        match *self {
            Quantizer::Identity => 0.0,
            Quantizer::Qsgd { s, .. } => {
                let p = p as f64;
                let s = s as f64;
                (p / (s * s)).min(p.sqrt() / s)
            }
        }
    }

    /// Analytic upload size in bits for a length-`p` vector under the
    /// *naive* coding (Elias size is data-dependent; use the encoded
    /// buffer's true length for that).
    pub fn upload_bits(&self, p: usize) -> u64 {
        match *self {
            Quantizer::Identity => 32 * p as u64,
            Quantizer::Qsgd { s, .. } => {
                32 + (p as u64) * (1 + level_bits(s) as u64)
            }
        }
    }

    /// Quantize and encode `x` to the wire. Returns the encoded buffer.
    pub fn encode(&self, x: &[f32], rng: &mut Rng) -> Encoded {
        match *self {
            Quantizer::Identity => {
                let mut w = BitWriter::new();
                for &v in x {
                    w.write_f32(v);
                }
                Encoded { buf: w.finish(), p: x.len(), quantizer: *self }
            }
            Quantizer::Qsgd { s, coding } => encode_qsgd(x, s, coding, rng),
        }
    }

    /// Decode an upload back to a dense f32 vector.
    pub fn decode(&self, enc: &Encoded) -> Vec<f32> {
        assert_eq!(
            enc.quantizer, *self,
            "decoding with a mismatched quantizer config"
        );
        match *self {
            Quantizer::Identity => {
                let mut r = enc.buf.reader();
                (0..enc.p).map(|_| r.read_f32()).collect()
            }
            Quantizer::Qsgd { s, coding } => decode_qsgd(enc, s, coding),
        }
    }

    /// Convenience: quantization noise injection without the wire —
    /// `decode(encode(x))`. The sim engine uses this in-process, the TCP
    /// mode ships the [`Encoded`] bytes instead; both paths share the
    /// exact same codec so results are identical for equal seeds.
    pub fn apply(&self, x: &[f32], rng: &mut Rng) -> (Vec<f32>, u64) {
        let enc = self.encode(x, rng);
        let bits = enc.buf.len_bits();
        (self.decode(&enc), bits)
    }
}

/// Fixed-width bits needed for a level in `0..=s`.
pub fn level_bits(s: u32) -> u32 {
    32 - s.leading_zeros() // ceil(log2(s+1)) for s >= 1
}

/// A quantized, encoded model update as it travels to the server.
#[derive(Debug, Clone)]
pub struct Encoded {
    pub buf: BitBuf,
    /// Number of coordinates.
    pub p: usize,
    /// Codec that produced this buffer (checked at decode time).
    pub quantizer: Quantizer,
}

impl Encoded {
    pub fn bits(&self) -> u64 {
        self.buf.len_bits()
    }
}

fn encode_qsgd(x: &[f32], s: u32, coding: Coding, rng: &mut Rng) -> Encoded {
    assert!(s >= 1, "QSGD needs at least one level");
    let norm = l2_norm(x);
    let mut w = BitWriter::new();
    w.write_f32(norm);
    let nb = level_bits(s);
    let sf = s as f32;
    for &v in x {
        let sign = v < 0.0;
        let level = if norm > 0.0 {
            let a = v.abs() / norm * sf; // in [0, s]
            let lo = a.floor();
            let up = rng.gen_f32() < (a - lo);
            (lo as u32 + up as u32).min(s)
        } else {
            0
        };
        w.write_bit(sign);
        match coding {
            Coding::Naive => w.write_bits(level as u64, nb),
            Coding::Elias => elias::encode_omega(&mut w, level as u64 + 1),
        }
    }
    Encoded { buf: w.finish(), p: x.len(), quantizer: Quantizer::Qsgd { s, coding } }
}

fn decode_qsgd(enc: &Encoded, s: u32, coding: Coding) -> Vec<f32> {
    let mut r = enc.buf.reader();
    let norm = r.read_f32();
    let nb = level_bits(s);
    let sf = s as f32;
    let mut out = Vec::with_capacity(enc.p);
    for _ in 0..enc.p {
        let sign = r.read_bit();
        let level = match coding {
            Coding::Naive => r.read_bits(nb),
            Coding::Elias => elias::decode_omega(&mut r) - 1,
        } as f32;
        let mag = norm * level / sf;
        out.push(if sign { -mag } else { mag });
    }
    out
}

/// l2 norm with f64 accumulation (bit-stable across call sites).
pub fn l2_norm(x: &[f32]) -> f32 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng(seed: u64) -> Rng {
        Rng::seed_from_u64(seed)
    }

    #[test]
    fn identity_roundtrip_exact() {
        let x: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.3).collect();
        let q = Quantizer::Identity;
        let (y, bits) = q.apply(&x, &mut rng(0));
        assert_eq!(x, y);
        assert_eq!(bits, 3200);
        assert_eq!(q.variance_q(100), 0.0);
    }

    #[test]
    fn qsgd_levels_on_grid() {
        // Every decoded magnitude must be norm * l / s for integer l <= s.
        let x: Vec<f32> = (0..257).map(|i| ((i * 37) % 101) as f32 - 50.0).collect();
        for s in [1u32, 2, 5, 10, 64] {
            let q = Quantizer::qsgd(s);
            let enc = q.encode(&x, &mut rng(1));
            let norm = l2_norm(&x);
            for (i, v) in q.decode(&enc).iter().enumerate() {
                let lvl = v.abs() / norm * s as f32;
                assert!(
                    (lvl - lvl.round()).abs() < 1e-4,
                    "coord {i} level {lvl} not integral (s={s})"
                );
                assert!(lvl.round() as u32 <= s);
            }
        }
    }

    #[test]
    fn qsgd_bit_accounting_naive() {
        let x = vec![0.5f32; 1000];
        for s in [1u32, 3, 10, 100] {
            let q = Quantizer::qsgd(s);
            let enc = q.encode(&x, &mut rng(2));
            assert_eq!(enc.bits(), q.upload_bits(1000), "s={s}");
        }
        // s=1 → 2 bits/coord + 32-bit norm.
        assert_eq!(Quantizer::qsgd(1).upload_bits(1000), 32 + 2000);
    }

    #[test]
    fn qsgd_unbiased_empirically() {
        let x: Vec<f32> = (0..64).map(|i| ((i as f32) * 0.17).sin()).collect();
        let q = Quantizer::qsgd(2);
        let mut acc = vec![0f64; x.len()];
        let trials = 4000;
        let mut r = rng(3);
        for _ in 0..trials {
            for (a, v) in acc.iter_mut().zip(q.apply(&x, &mut r).0) {
                *a += v as f64;
            }
        }
        let norm = l2_norm(&x) as f64;
        for (i, (&xi, &ai)) in x.iter().zip(acc.iter()).enumerate() {
            let mean = ai / trials as f64;
            // CLT tolerance: sd of one sample ≤ norm/s; 5σ/√trials bound.
            let tol = 5.0 * (norm / 2.0) / (trials as f64).sqrt();
            assert!(
                (mean - xi as f64).abs() < tol,
                "coord {i}: mean {mean} vs {xi} (tol {tol})"
            );
        }
    }

    #[test]
    fn qsgd_variance_bound_holds() {
        // E||Q(x)-x||^2 <= q ||x||^2 with q = min(p/s^2, sqrt(p)/s).
        let p = 128;
        let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.31).cos()).collect();
        let norm2 = (l2_norm(&x) as f64).powi(2);
        for s in [1u32, 4, 16] {
            let q = Quantizer::qsgd(s);
            let bound = q.variance_q(p) * norm2;
            let mut err = 0.0f64;
            let trials = 2000;
            let mut r = rng(4);
            for _ in 0..trials {
                let y = q.apply(&x, &mut r).0;
                err += x
                    .iter()
                    .zip(&y)
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum::<f64>();
            }
            let mean_err = err / trials as f64;
            assert!(
                mean_err <= bound * 1.05 + 1e-9,
                "s={s}: measured {mean_err} > bound {bound}"
            );
        }
    }

    #[test]
    fn elias_coding_roundtrip_and_smaller_when_sparse() {
        // A peaked vector has mostly level-0 coords at high s: Elias wins.
        let mut x = vec![1e-4f32; 4096];
        x[0] = 10.0;
        let naive = Quantizer::Qsgd { s: 64, coding: Coding::Naive };
        let elias = Quantizer::Qsgd { s: 64, coding: Coding::Elias };
        let en = naive.encode(&x, &mut rng(5));
        let ee = elias.encode(&x, &mut rng(5));
        assert!(ee.bits() < en.bits(), "{} !< {}", ee.bits(), en.bits());
        // And both decode to on-grid values of the same norm scale.
        let dn = naive.decode(&en);
        let de = elias.decode(&ee);
        assert_eq!(dn.len(), de.len());
    }

    #[test]
    fn zero_vector_is_exact() {
        let x = vec![0f32; 57];
        let q = Quantizer::qsgd(4);
        let (y, _) = q.apply(&x, &mut rng(6));
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic(expected = "mismatched quantizer")]
    fn decode_mismatch_panics() {
        let x = vec![1f32; 8];
        let enc = Quantizer::qsgd(2).encode(&x, &mut rng(7));
        Quantizer::qsgd(3).decode(&enc);
    }
}
