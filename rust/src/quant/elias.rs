//! Elias-ω (omega) universal integer coding.
//!
//! QSGD (Alistarh et al., 2017 — the quantizer FedPAQ's Example 1 is taken
//! from) encodes the integer quantization levels with Elias recursive
//! coding, which is what makes the `s = √p` regime pay `O(√p log p)` bits.
//! We implement Elias-ω for positive integers (level 0 is mapped to 1,
//! i.e. `encode(v+1)`), matching the QSGD paper's `Elias(k)` usage.

use super::bitstream::{BitReader, BitWriter};

/// Append the Elias-ω code of `n >= 1` to the writer.
///
/// Encoding (classic recursive construction): start with a terminal `0`;
/// while `n > 1`, prepend the binary representation of `n` and set
/// `n = floor(log2 n)`.
pub fn encode_omega(w: &mut BitWriter, mut n: u64) {
    assert!(n >= 1, "Elias-omega encodes positive integers");
    // Build groups back-to-front, then emit front-to-back.
    let mut groups: Vec<(u64, u32)> = Vec::new();
    while n > 1 {
        let width = 64 - n.leading_zeros(); // bits in binary repr of n
        groups.push((n, width));
        n = (width - 1) as u64;
    }
    for &(v, width) in groups.iter().rev() {
        // MSB-first emission of the binary representation.
        for i in (0..width).rev() {
            w.write_bit((v >> i) & 1 == 1);
        }
    }
    w.write_bit(false); // terminal 0
}

/// Decode one Elias-ω integer.
pub fn decode_omega(r: &mut BitReader<'_>) -> u64 {
    let mut n: u64 = 1;
    loop {
        if !r.read_bit() {
            return n;
        }
        // The bit we just read is the leading 1 of an (n+1)-bit group.
        let mut v: u64 = 1;
        for _ in 0..n {
            v = (v << 1) | r.read_bit() as u64;
        }
        n = v;
    }
}

/// [`decode_omega`] with underrun checking: a truncated or empty stream
/// returns an explicit error instead of reading past the end (release
/// builds have no bounds assertion on [`BitReader::read_bits`], so the
/// unchecked decoder would read zero padding and fabricate a value).
/// The sparsifier index decoders use this so a corrupt frame is rejected
/// identically on every coding path.
pub fn try_decode_omega(r: &mut BitReader<'_>) -> crate::Result<u64> {
    let mut n: u64 = 1;
    loop {
        anyhow::ensure!(r.remaining() >= 1, "Elias-omega code truncated");
        if !r.read_bit() {
            return Ok(n);
        }
        // A group longer than 63 bits cannot encode a u64 value; a claim
        // of one is frame corruption (and would overflow the shift below).
        anyhow::ensure!(n < 64, "Elias-omega group of {n} bits is corrupt");
        anyhow::ensure!(r.remaining() >= n, "Elias-omega code truncated");
        let mut v: u64 = 1;
        for _ in 0..n {
            v = (v << 1) | r.read_bit() as u64;
        }
        n = v;
    }
}

/// Bit length of the Elias-ω code of `n` (without encoding).
pub fn omega_len(mut n: u64) -> u64 {
    assert!(n >= 1);
    let mut bits = 1; // terminal 0
    while n > 1 {
        let width = (64 - n.leading_zeros()) as u64;
        bits += width;
        n = width - 1;
    }
    bits
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_small() {
        let mut w = BitWriter::new();
        for n in 1..=300u64 {
            encode_omega(&mut w, n);
        }
        let buf = w.finish();
        let mut r = buf.reader();
        for n in 1..=300u64 {
            assert_eq!(decode_omega(&mut r), n, "value {n}");
        }
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn roundtrip_large_and_lengths() {
        let vals = [1u64, 2, 3, 7, 8, 100, 1_000, 65_536, u32::MAX as u64, 1 << 40];
        let mut w = BitWriter::new();
        let mut expect = 0;
        for &v in &vals {
            encode_omega(&mut w, v);
            expect += omega_len(v);
        }
        let buf = w.finish();
        assert_eq!(buf.len_bits(), expect);
        let mut r = buf.reader();
        for &v in &vals {
            assert_eq!(decode_omega(&mut r), v);
        }
    }

    #[test]
    fn try_decode_matches_unchecked_and_rejects_truncation() {
        let vals = [1u64, 2, 5, 100, 65_536, 1 << 40];
        let mut w = BitWriter::new();
        for &v in &vals {
            encode_omega(&mut w, v);
        }
        let buf = w.finish();
        let mut r = buf.reader();
        for &v in &vals {
            assert_eq!(try_decode_omega(&mut r).unwrap(), v);
        }
        // Empty stream: explicit error, not a fabricated value.
        let empty = BitWriter::new().finish();
        assert!(try_decode_omega(&mut empty.reader()).is_err());
        // Truncated mid-code: drop the terminal bit of a long code.
        let mut w = BitWriter::new();
        encode_omega(&mut w, 100_000);
        let full = w.finish();
        let mut w = BitWriter::new();
        let mut r = full.reader();
        for _ in 0..full.len_bits() - 1 {
            w.write_bit(r.read_bit());
        }
        let cut = w.finish();
        assert!(try_decode_omega(&mut cut.reader()).is_err());
    }

    #[test]
    fn known_codes() {
        // Classic table: 1 -> "0", 2 -> "10 0", 3 -> "11 0", 4 -> "10 100 0"
        assert_eq!(omega_len(1), 1);
        assert_eq!(omega_len(2), 3);
        assert_eq!(omega_len(3), 3);
        assert_eq!(omega_len(4), 6);
        assert_eq!(omega_len(16), 11);
    }
}
