//! The [`RoundEngine`]: one implementation of the per-commit FedPAQ
//! protocol, generic over [`Transport`] and [`UpdateCodec`].
//!
//! Each engine iteration is one **server commit**: sample `S_k` →
//! `transport.round()` returns a
//! [`RoundOutcome`](super::transport::RoundOutcome) (the committed
//! uploads, stamped with their origin version) → decode + aggregate under
//! the config's [`StalenessRule`](super::aggregate::StalenessRule)
//! weights → apply the weighted-mean update → advance the clock →
//! evaluate on the [`EvalSlab`] schedule.
//!
//! The engine no longer assumes one commit = one full barrier:
//!
//! * **Barrier transports** ([`InProcess`](super::InProcess),
//!   [`crate::net::Tcp`]) return the whole sampled round at staleness 0
//!   with no self-reported timing; the engine charges the §5 barrier
//!   model (straggler max + serialized uplink) or wall-clock, exactly as
//!   the synchronous protocol prescribes.
//! * **Buffered-async transports** (the
//!   [`CommitPlanner`](super::commit_loop::CommitPlanner)-driven
//!   [`super::AsyncSim`] and [`crate::net::TcpAsync`]) return each
//!   commit's buffer with per-upload staleness; simulated ones also
//!   report their own [`CommitTiming`](super::transport::CommitTiming),
//!   which the engine charges instead of a barrier (networked ones fall
//!   through to wall-clock).
//!
//! A commit that yields zero uploads is *not* fatal: it is logged,
//! charged zero time, and the model carries over unchanged. The built-in
//! transports never produce one — they error out (barrier) or block until
//! the buffer fills (async) — so this skip path is the seam for custom
//! transports that drop failed nodes outright.

use super::aggregate::{Aggregator, ShardPlan};
use super::local::OwnedLabels;
use super::sampler;
use super::transport::{RoundCtx, Transport};
use crate::config::ExperimentConfig;
use crate::data::{FederatedDataset, Labels, Partition};
use crate::metrics::{Curve, CurvePoint};
use crate::model::Engine;
use crate::quant::UpdateCodec;
use crate::simtime::{CostModel, VirtualClock};
use std::sync::Arc;
use std::time::Instant;

/// Regenerate the seeded federated world for `cfg`: the (process-cached)
/// dataset and its node partition. Single source of truth shared by the
/// eval slab and the in-process transport, so the loss is always
/// evaluated against exactly the shards the nodes train on.
pub(crate) fn build_world(
    cfg: &ExperimentConfig,
    engine: &mut dyn Engine,
) -> crate::Result<(Arc<FederatedDataset>, Partition)> {
    let n_samples = cfg.n_nodes * cfg.per_node;
    let data = crate::data::cached_generate(cfg.dataset, cfg.seed, n_samples);
    anyhow::ensure!(
        data.dim == engine.kind().d_in(),
        "dataset dim {} != model d_in {}",
        data.dim,
        engine.kind().d_in()
    );
    let partition =
        Partition::build(cfg.partition, &data, cfg.n_nodes, cfg.per_node, cfg.seed);
    Ok((data, partition))
}

/// Per-round timing/traffic record, plus the async protocol's per-commit
/// telemetry (identically zero on barrier transports).
#[derive(Debug, Clone, Copy)]
pub struct RoundStats {
    pub round: usize,
    pub compute_time: f64,
    pub comm_time: f64,
    pub bits_up: u64,
    /// Stale uploads dropped (and re-dispatched) between the previous
    /// commit and this one.
    pub dropped: u64,
    /// Largest staleness stamp among this commit's uploads.
    pub staleness_max: usize,
    /// Mean staleness over this commit's uploads (0 for an empty commit).
    pub staleness_mean: f64,
}

/// Output of a full training run.
#[derive(Debug)]
pub struct RunResult {
    /// Loss-vs-time curve (the paper's plotted series).
    pub curve: Curve,
    /// Final server model.
    pub params: Vec<f32>,
    /// Per-round stats.
    pub rounds: Vec<RoundStats>,
    /// Total uploaded bits over the run.
    pub total_bits: u64,
}

impl RunResult {
    /// Machine-readable dump of the whole run: curve, per-round stats,
    /// total traffic and the full final model (f32 → f64 is exact, so the
    /// parameters survive the JSON round-trip bit-for-bit).
    ///
    /// For virtual-time transports the output is a deterministic function
    /// of `(config, seed)` — the CI determinism leg diffs two of these
    /// byte-for-byte, including across `--agg-shards` values. Networked
    /// runs carry wall-clock `time`/`compute_time` fields; CI strips
    /// those with `python/curve_extract.py` before diffing, so the
    /// loss/bits/params portion is still comparable byte-for-byte.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let points = self
            .curve
            .points
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("round", Json::num(p.round as f64)),
                    ("iterations", Json::num(p.iterations as f64)),
                    ("time", Json::num(p.time)),
                    ("bits_up", Json::num(p.bits_up as f64)),
                    ("loss", Json::num(p.loss)),
                ])
            })
            .collect();
        let rounds = self
            .rounds
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("round", Json::num(r.round as f64)),
                    ("compute_time", Json::num(r.compute_time)),
                    ("comm_time", Json::num(r.comm_time)),
                    ("bits_up", Json::num(r.bits_up as f64)),
                    ("dropped", Json::num(r.dropped as f64)),
                    ("staleness_max", Json::num(r.staleness_max as f64)),
                    ("staleness_mean", Json::num(r.staleness_mean)),
                ])
            })
            .collect();
        Json::obj(vec![
            (
                "curve",
                Json::obj(vec![
                    ("label", Json::str(&self.curve.label)),
                    ("points", Json::Arr(points)),
                ]),
            ),
            ("rounds", Json::Arr(rounds)),
            ("total_bits", Json::num(self.total_bits as f64)),
            (
                "params",
                Json::Arr(self.params.iter().map(|&v| Json::num(v as f64)).collect()),
            ),
        ])
    }
}

/// The fixed evaluation slab: the first `eval_n` assigned samples
/// (partition order is already a seeded shuffle). For logreg `eval_n` is
/// the full training set, matching the paper's "training loss" axis
/// exactly; for the NNs it is a fixed 2048-sample estimate (DESIGN.md §4).
///
/// Shared by every execution mode, so the sim server and the TCP leader
/// evaluate the identical loss.
#[derive(Debug)]
pub struct EvalSlab {
    x: Vec<f32>,
    y: OwnedLabels,
    token: u64,
}

impl EvalSlab {
    /// Build the slab for `cfg`, regenerating the seeded world.
    pub fn build(cfg: &ExperimentConfig, engine: &mut dyn Engine) -> crate::Result<Self> {
        let (data, partition) = build_world(cfg, engine)?;
        Self::from_world(cfg, engine, &data, &partition)
    }

    /// Build the slab from an already-constructed world (what
    /// `ServerBuilder` uses so the world is built once per run).
    pub fn from_world(
        cfg: &ExperimentConfig,
        engine: &mut dyn Engine,
        data: &FederatedDataset,
        partition: &Partition,
    ) -> crate::Result<Self> {
        let eval_n = engine.eval_n();
        let all = partition.all_indices();
        anyhow::ensure!(all.len() >= eval_n, "eval slab larger than dataset");
        let idx = &all[..eval_n];
        let mut x = Vec::new();
        data.gather_features(idx, &mut x);
        let y = match &data.labels {
            Labels::Float(_) => {
                let mut y = Vec::new();
                data.gather_labels_f32(idx, &mut y);
                OwnedLabels::F32(y)
            }
            Labels::Int(_) => {
                let mut y = Vec::new();
                data.gather_labels_i32(idx, &mut y);
                OwnedLabels::I32(y)
            }
        };
        let token = cfg.seed ^ 0xe7a1_0000 ^ ((eval_n as u64) << 32);
        Ok(EvalSlab { x, y, token })
    }

    /// Evaluate the training loss at `params` (engines may cache the
    /// uploaded slab tensors across calls via the token).
    pub fn eval(&self, engine: &mut dyn Engine, params: &[f32]) -> crate::Result<f64> {
        Ok(engine.eval_loss_token(params, self.token, &self.x, self.y.as_batch())? as f64)
    }
}

/// Time accounting: the §5 virtual-time model for simulated transports,
/// real wall-clock for networked ones.
enum Timing {
    Virtual { cost: CostModel, clock: VirtualClock },
    Wall { t0: Instant },
}

/// The per-round protocol, composed from pluggable parts.
///
/// Built directly or via
/// [`ServerBuilder`](super::server::ServerBuilder); `run` is
/// deterministic in `(cfg.seed, codec, transport)` — for the built-in
/// transports equal seeds reproduce bit-identical models.
pub struct RoundEngine {
    codec: Box<dyn UpdateCodec>,
    transport: Box<dyn Transport>,
}

impl RoundEngine {
    pub fn new(codec: Box<dyn UpdateCodec>, transport: Box<dyn Transport>) -> Self {
        RoundEngine { codec, transport }
    }

    pub fn codec(&self) -> &dyn UpdateCodec {
        self.codec.as_ref()
    }

    /// Drive the full K-round protocol for a *validated* `cfg`, recording
    /// the loss curve through `slab` on `cfg.eval_every`'s schedule.
    pub fn run(
        &mut self,
        cfg: &ExperimentConfig,
        engine: &mut dyn Engine,
        slab: &EvalSlab,
    ) -> crate::Result<RunResult> {
        self.transport.setup(cfg, engine)?;
        // Stateful codecs (error feedback) carry per-node memory; a run
        // starts from zero residuals even when the codec instance is
        // reused across runs (the trait's reset semantics).
        self.codec.reset_state();
        let mut params = engine.init_params()?;
        let p = params.len();
        let rounds = cfg.rounds();
        let mut timing = if self.transport.virtual_time() {
            Timing::Virtual {
                cost: CostModel::with_ratio(cfg.ratio, p, cfg.seed),
                clock: VirtualClock::new(),
            }
        } else {
            Timing::Wall { t0: Instant::now() }
        };
        let mut curve = Curve::new(cfg.name.clone());
        let mut stats = Vec::with_capacity(rounds);
        let mut total_bits = 0u64;
        let mut agg = Aggregator::new(p);
        // One shard plan for the whole run; `cfg.agg_shards == 1` is the
        // historical single-threaded accumulation, larger values fan the
        // f64 accumulate/apply across scoped threads with bit-identical
        // results (the aggregate module's determinism contract). Every
        // transport — InProcess, AsyncSim, and the net::Tcp leader —
        // funnels through this one path.
        let plan = ShardPlan::new(p, cfg.agg_shards);

        // Round-0 point: initial loss at time 0.
        let loss0 = slab.eval(engine, &params)?;
        curve.push(CurvePoint { round: 0, iterations: 0, time: 0.0, bits_up: 0, loss: loss0 });

        for k in 0..rounds {
            let round_t0 = Instant::now();
            let nodes = sampler::sample_nodes(cfg.n_nodes, cfg.r, cfg.seed, k);
            let lrs: Vec<f32> = (0..cfg.tau).map(|t| cfg.lr.lr(k, t)).collect();
            let ctx = RoundCtx { round: k, nodes: &nodes, params: &params, lrs: &lrs };
            let outcome = self.transport.round(&ctx, self.codec.as_ref(), engine)?;
            agg.reset();
            let batch: Vec<(&crate::quant::Encoded, f64)> = outcome
                .uploads
                .iter()
                .map(|u| (&u.enc, cfg.staleness_rule.weight(u.staleness)))
                .collect();
            agg.push_batch(self.codec.as_ref(), &batch, &plan)?;
            let bits: u64 = agg.upload_bits().iter().sum();
            let (compute_time, comm_time) = match (&mut timing, outcome.timing) {
                // The transport ran its own (virtual) event clock for
                // this commit — charge its figures verbatim.
                (Timing::Virtual { clock, .. }, Some(t)) => {
                    clock.advance(t.compute_time + t.comm_time);
                    (t.compute_time, t.comm_time)
                }
                // Barrier commit under the §5 model: the round waits for
                // the slowest sampled node, then uploads serialize.
                (Timing::Virtual { cost, clock }, None) => {
                    let (ct, mt) = if agg.count() > 0 {
                        (
                            cost.round_compute_time(&nodes, k, cfg.tau, engine.batch()),
                            cost.round_comm_time(agg.upload_bits()),
                        )
                    } else {
                        (0.0, 0.0)
                    };
                    clock.advance(ct + mt);
                    (ct, mt)
                }
                (Timing::Wall { .. }, _) => {
                    let ct = if agg.count() > 0 {
                        round_t0.elapsed().as_secs_f64()
                    } else {
                        0.0
                    };
                    (ct, 0.0)
                }
            };
            if agg.count() > 0 {
                agg.apply_sharded(&mut params, &plan)?;
            } else {
                eprintln!(
                    "[{}] round {k}: no uploads from {} sampled nodes — skipping",
                    self.transport.name(),
                    nodes.len()
                );
            }
            total_bits += bits;
            // Async-protocol telemetry: staleness stamps come with the
            // uploads, drop counts with the outcome. Barrier transports
            // report all zeros (every upload is staleness 0, none drop).
            let staleness_max =
                outcome.uploads.iter().map(|u| u.staleness).max().unwrap_or(0);
            let staleness_mean = if outcome.uploads.is_empty() {
                0.0
            } else {
                outcome.uploads.iter().map(|u| u.staleness as f64).sum::<f64>()
                    / outcome.uploads.len() as f64
            };
            stats.push(RoundStats {
                round: k,
                compute_time,
                comm_time,
                bits_up: bits,
                dropped: outcome.dropped,
                staleness_max,
                staleness_mean,
            });

            if (k + 1) % cfg.eval_every == 0 || k + 1 == rounds {
                let loss = slab.eval(engine, &params)?;
                let time = match &timing {
                    Timing::Virtual { clock, .. } => clock.now(),
                    Timing::Wall { t0 } => t0.elapsed().as_secs_f64(),
                };
                curve.push(CurvePoint {
                    round: k + 1,
                    iterations: (k + 1) * cfg.tau,
                    time,
                    bits_up: total_bits,
                    loss,
                });
            }
        }
        self.transport.shutdown()?;
        Ok(RunResult { curve, params, rounds: stats, total_bits })
    }
}
