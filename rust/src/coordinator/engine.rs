//! The [`RoundEngine`]: one implementation of the per-commit FedPAQ
//! protocol, generic over [`Transport`] and [`UpdateCodec`].
//!
//! Each engine iteration is one **server commit**: sample `S_k` →
//! `transport.round()` returns a
//! [`RoundOutcome`](super::transport::RoundOutcome) (the committed
//! uploads, stamped with their origin version) → decode + aggregate under
//! the config's [`StalenessRule`](super::aggregate::StalenessRule)
//! weights → apply the weighted-mean update → advance the clock →
//! evaluate on the [`EvalSlab`] schedule.
//!
//! The engine no longer assumes one commit = one full barrier:
//!
//! * **Barrier transports** ([`InProcess`](super::InProcess),
//!   [`crate::net::Tcp`]) return the whole sampled round at staleness 0
//!   with no self-reported timing; the engine charges the §5 barrier
//!   model (straggler max + serialized uplink) or wall-clock, exactly as
//!   the synchronous protocol prescribes.
//! * **Buffered-async transports** (the
//!   [`CommitPlanner`](super::commit_loop::CommitPlanner)-driven
//!   [`super::AsyncSim`] and [`crate::net::TcpAsync`]) return each
//!   commit's buffer with per-upload staleness; simulated ones also
//!   report their own [`CommitTiming`](super::transport::CommitTiming),
//!   which the engine charges instead of a barrier (networked ones fall
//!   through to wall-clock).
//!
//! A commit that yields zero uploads is *not* fatal: it is logged,
//! charged zero time, and the model carries over unchanged. The built-in
//! transports never produce one — they error out (barrier) or block until
//! the buffer fills (async) — so this skip path is the seam for custom
//! transports that drop failed nodes outright.

use super::aggregate::{Aggregator, ShardPlan};
use super::downlink::DownlinkEncoder;
use super::local::OwnedLabels;
use super::sampler;
use super::transport::{ModelFrame, RoundCtx, Transport};
use crate::config::ExperimentConfig;
use crate::data::{FederatedDataset, Labels, Partition};
use crate::metrics::{Curve, CurvePoint};
use crate::model::Engine;
use crate::quant::UpdateCodec;
use crate::simtime::{CostModel, VirtualClock};
use std::sync::Arc;
use std::time::Instant;

/// Regenerate the seeded federated world for `cfg`: the (process-cached)
/// dataset and its node partition. Single source of truth shared by the
/// eval slab and the in-process transport, so the loss is always
/// evaluated against exactly the shards the nodes train on.
pub(crate) fn build_world(
    cfg: &ExperimentConfig,
    engine: &mut dyn Engine,
) -> crate::Result<(Arc<FederatedDataset>, Partition)> {
    let n_samples = cfg.n_samples();
    let data = crate::data::cached_generate(cfg.dataset, cfg.seed, n_samples);
    anyhow::ensure!(
        data.dim == engine.kind().d_in(),
        "dataset dim {} != model d_in {}",
        data.dim,
        engine.kind().d_in()
    );
    let partition =
        Partition::build(cfg.partition, &data, cfg.n_nodes, cfg.per_node, cfg.seed);
    Ok((data, partition))
}

/// Per-round timing/traffic record, plus the async protocol's per-commit
/// telemetry (identically zero on barrier transports).
#[derive(Debug, Clone, Copy)]
pub struct RoundStats {
    pub round: usize,
    pub compute_time: f64,
    pub comm_time: f64,
    pub bits_up: u64,
    /// Downlink bits charged for this commit's dispatches: the delta
    /// chain links each dispatched node was missing (down codec set), or
    /// one dense `32·p` model per dispatch (raw downlink). Per-node
    /// accounting — see `docs/PROTOCOL.md`.
    pub bits_down: u64,
    /// Edge→root uplink bits for this commit on hierarchical transports
    /// (`bits_up` is then the worker→edge hop). Identically 0 on flat
    /// topologies — see `docs/TOPOLOGY.md`.
    pub bits_edge_to_root: u64,
    /// Stale uploads dropped (and re-dispatched) between the previous
    /// commit and this one.
    pub dropped: u64,
    /// Largest staleness stamp among this commit's uploads.
    pub staleness_max: usize,
    /// Mean staleness over this commit's uploads (0 for an empty commit).
    pub staleness_mean: f64,
}

/// Self-description block attached to every [`RunResult`]: everything an
/// operator needs to know *which* run produced a result file without
/// hunting for the config that launched it.
#[derive(Debug, Clone)]
pub struct RunMeta {
    /// Master seed of the run.
    pub seed: u64,
    /// The config's tagged codec spec, as its canonical JSON.
    pub codec: crate::util::json::Json,
    /// The config's downlink codec spec as canonical JSON (`null` when
    /// the broadcast is raw f32).
    pub down_codec: crate::util::json::Json,
    /// [`ExperimentConfig::config_hash`] — the run-identity key shared
    /// with checkpoints.
    pub config_hash: u64,
    /// Wire-protocol version of this build
    /// ([`crate::net::proto::PROTO_VERSION`]).
    pub proto_version: u32,
    /// Checkpoint id this run resumed from, if any (`None` for a fresh
    /// run; serialized as JSON `null` so the field is always present —
    /// CI's byte-diff strips the line either way).
    pub resumed_from: Option<String>,
}

/// Output of a full training run.
#[derive(Debug)]
pub struct RunResult {
    /// Loss-vs-time curve (the paper's plotted series).
    pub curve: Curve,
    /// Final server model.
    pub params: Vec<f32>,
    /// Per-round stats.
    pub rounds: Vec<RoundStats>,
    /// Total uploaded bits over the run.
    pub total_bits: u64,
    /// Total downlink (broadcast) bits over the run — the other half of
    /// the communication bill, per-node accounting.
    pub total_bits_down: u64,
    /// Total edge→root uplink bits over the run (0 on flat topologies):
    /// the second hop of the split `bits_up` accounting on aggregation
    /// trees.
    pub total_bits_edge_to_root: u64,
    /// Run self-description (seed, codec, config hash, provenance).
    pub meta: RunMeta,
}

impl RunResult {
    /// Machine-readable dump of the whole run: curve, per-round stats,
    /// total traffic and the full final model (f32 → f64 is exact, so the
    /// parameters survive the JSON round-trip bit-for-bit).
    ///
    /// For virtual-time transports the output is a deterministic function
    /// of `(config, seed)` — the CI determinism leg diffs two of these
    /// byte-for-byte, including across `--agg-shards` values. Networked
    /// runs carry wall-clock `time`/`compute_time` fields; CI strips
    /// those with `python/curve_extract.py` before diffing, so the
    /// loss/bits/params portion is still comparable byte-for-byte.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let points = self
            .curve
            .points
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("round", Json::num(p.round as f64)),
                    ("iterations", Json::num(p.iterations as f64)),
                    ("time", Json::num(p.time)),
                    ("bits_up", Json::num(p.bits_up as f64)),
                    ("bits_down", Json::num(p.bits_down as f64)),
                    ("bits_edge_to_root", Json::num(p.bits_edge_to_root as f64)),
                    ("loss", Json::num(p.loss)),
                ])
            })
            .collect();
        let rounds = self
            .rounds
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("round", Json::num(r.round as f64)),
                    ("compute_time", Json::num(r.compute_time)),
                    ("comm_time", Json::num(r.comm_time)),
                    ("bits_up", Json::num(r.bits_up as f64)),
                    ("bits_down", Json::num(r.bits_down as f64)),
                    ("bits_edge_to_root", Json::num(r.bits_edge_to_root as f64)),
                    ("dropped", Json::num(r.dropped as f64)),
                    ("staleness_max", Json::num(r.staleness_max as f64)),
                    ("staleness_mean", Json::num(r.staleness_mean)),
                ])
            })
            .collect();
        let meta = Json::obj(vec![
            // Hash and seed are u64: decimal/hex strings, same convention
            // as config JSON (f64 can't carry them exactly).
            ("codec", self.meta.codec.clone()),
            (
                "config_hash",
                Json::str(format!("{:016x}", self.meta.config_hash)),
            ),
            ("down_codec", self.meta.down_codec.clone()),
            ("proto_version", Json::num(self.meta.proto_version as f64)),
            (
                "resumed_from",
                match &self.meta.resumed_from {
                    Some(id) => Json::str(id.as_str()),
                    None => Json::Null,
                },
            ),
            ("seed", Json::str(self.meta.seed.to_string())),
        ]);
        Json::obj(vec![
            (
                "curve",
                Json::obj(vec![
                    ("label", Json::str(&self.curve.label)),
                    ("points", Json::Arr(points)),
                ]),
            ),
            ("meta", meta),
            ("rounds", Json::Arr(rounds)),
            ("total_bits", Json::num(self.total_bits as f64)),
            ("total_bits_down", Json::num(self.total_bits_down as f64)),
            (
                "total_bits_edge_to_root",
                Json::num(self.total_bits_edge_to_root as f64),
            ),
            (
                "params",
                Json::Arr(self.params.iter().map(|&v| Json::num(v as f64)).collect()),
            ),
        ])
    }
}

/// The fixed evaluation slab: the first `eval_n` assigned samples
/// (partition order is already a seeded shuffle). For logreg `eval_n` is
/// the full training set, matching the paper's "training loss" axis
/// exactly; for the NNs it is a fixed 2048-sample estimate (DESIGN.md §4).
///
/// Shared by every execution mode, so the sim server and the TCP leader
/// evaluate the identical loss.
#[derive(Debug)]
pub struct EvalSlab {
    x: Vec<f32>,
    y: OwnedLabels,
    token: u64,
}

impl EvalSlab {
    /// Build the slab for `cfg`, regenerating the seeded world.
    pub fn build(cfg: &ExperimentConfig, engine: &mut dyn Engine) -> crate::Result<Self> {
        let (data, partition) = build_world(cfg, engine)?;
        Self::from_world(cfg, engine, &data, &partition)
    }

    /// Build the slab from an already-constructed world (what
    /// `ServerBuilder` uses so the world is built once per run).
    pub fn from_world(
        cfg: &ExperimentConfig,
        engine: &mut dyn Engine,
        data: &FederatedDataset,
        partition: &Partition,
    ) -> crate::Result<Self> {
        let eval_n = engine.eval_n();
        anyhow::ensure!(
            partition.assigned() >= eval_n && data.n_samples >= eval_n,
            "eval slab larger than dataset"
        );
        // Lazy prefix of the assignment — O(eval_n), never O(n_nodes).
        let idx: Vec<usize> = partition.eval_indices(eval_n);
        let mut x = Vec::new();
        data.gather_features(&idx, &mut x);
        let y = match &data.labels {
            Labels::Float(_) => {
                let mut y = Vec::new();
                data.gather_labels_f32(&idx, &mut y);
                OwnedLabels::F32(y)
            }
            Labels::Int(_) => {
                let mut y = Vec::new();
                data.gather_labels_i32(&idx, &mut y);
                OwnedLabels::I32(y)
            }
        };
        let token = cfg.seed ^ 0xe7a1_0000 ^ ((eval_n as u64) << 32);
        Ok(EvalSlab { x, y, token })
    }

    /// Evaluate the training loss at `params` (engines may cache the
    /// uploaded slab tensors across calls via the token).
    pub fn eval(&self, engine: &mut dyn Engine, params: &[f32]) -> crate::Result<f64> {
        Ok(engine.eval_loss_token(params, self.token, &self.x, self.y.as_batch())? as f64)
    }
}

/// Time accounting: the §5 virtual-time model for simulated transports,
/// real wall-clock for networked ones.
enum Timing {
    Virtual { cost: CostModel, clock: VirtualClock },
    Wall { t0: Instant },
}

/// The per-round protocol, composed from pluggable parts.
///
/// Built directly or via
/// [`ServerBuilder`](super::server::ServerBuilder); `run` is
/// deterministic in `(cfg.seed, codec, transport)` — for the built-in
/// transports equal seeds reproduce bit-identical models.
pub struct RoundEngine {
    codec: Box<dyn UpdateCodec>,
    transport: Box<dyn Transport>,
}

impl RoundEngine {
    pub fn new(codec: Box<dyn UpdateCodec>, transport: Box<dyn Transport>) -> Self {
        RoundEngine { codec, transport }
    }

    pub fn codec(&self) -> &dyn UpdateCodec {
        self.codec.as_ref()
    }

    /// Drive the full K-round protocol for a *validated* `cfg`, recording
    /// the loss curve through `slab` on `cfg.eval_every`'s schedule.
    ///
    /// `ctrl` carries the operator controls (structured events, periodic
    /// atomic checkpoints, forced early stop, resume); pass
    /// `&RunControl::default()` for a plain run. This is the single
    /// entry point — the former `run`/`run_controlled` pair collapsed
    /// into one options-taking signature.
    ///
    /// The resume contract is **bit-identity**: a run checkpointed at
    /// commit `K` and resumed produces the same `RunResult` (curve,
    /// stats, params, total bits — everything but the `resumed_from`
    /// provenance field) as the run that was never interrupted, because
    /// the checkpoint restores every piece of cross-commit state: model,
    /// history, virtual clock, codec residuals, downlink reference, and
    /// the async planner with its in-flight jobs. CI enforces this with
    /// byte-diffs.
    pub fn run(
        &mut self,
        cfg: &ExperimentConfig,
        engine: &mut dyn Engine,
        slab: &EvalSlab,
        ctrl: &crate::ops::RunControl,
    ) -> crate::Result<RunResult> {
        use crate::util::json::Json;
        let events = ctrl.events.with_seed(cfg.seed);
        self.transport.set_events(events.clone());
        self.transport.setup(cfg, engine)?;
        let cfg_json = cfg.to_json();
        let meta = RunMeta {
            seed: cfg.seed,
            codec: cfg_json.get("codec").cloned().unwrap_or(Json::Null),
            down_codec: cfg_json.get("down_codec").cloned().unwrap_or(Json::Null),
            config_hash: cfg.config_hash(),
            proto_version: crate::net::proto::PROTO_VERSION,
            resumed_from: ctrl.resume.as_ref().map(|ck| ck.id()),
        };
        let rounds = cfg.rounds();
        let p = engine.kind().param_count();
        // The downlink encoder (QAFeL hidden state) lives run-long so the
        // reference model and per-node chain accounting persist across
        // commits; raw-f32 broadcast when the config has no down codec.
        let mut downlink = match &cfg.down_codec {
            Some(spec) => Some(DownlinkEncoder::new(spec.build()?, cfg.seed, cfg.n_nodes)),
            None => None,
        };
        let mut curve;
        let mut stats;
        let mut total_bits;
        let mut total_bits_down;
        let mut total_bits_edge;
        let mut params;
        let start_k;
        let mut timing = if self.transport.virtual_time() {
            Timing::Virtual {
                cost: CostModel::with_ratio(cfg.ratio, p, cfg.seed)
                    .with_dist(cfg.straggler),
                clock: VirtualClock::new(),
            }
        } else {
            Timing::Wall { t0: Instant::now() }
        };
        if let Some(ck) = &ctrl.resume {
            // Continue mid-run: every piece of cross-commit state comes
            // from the checkpoint; round 0 init and eval are skipped
            // (the restored curve already holds them).
            ck.check_config(cfg)?;
            anyhow::ensure!(
                ck.params.len() == p,
                "checkpoint params have {} coords, the model expects {p}",
                ck.params.len(),
            );
            anyhow::ensure!(
                ck.next_round <= rounds,
                "checkpoint is at commit {} but the config only runs {rounds}",
                ck.next_round,
            );
            params = ck.params.clone();
            curve = Curve::new(ck.curve_label.clone());
            curve.points = ck.curve.clone();
            stats = ck.stats.clone();
            total_bits = ck.total_bits;
            total_bits_down = ck.total_bits_down;
            total_bits_edge = ck.total_bits_edge_to_root;
            start_k = ck.next_round;
            if let Timing::Virtual { clock, .. } = &mut timing {
                clock.advance(ck.clock_now);
            }
            self.codec.reset_state();
            self.codec.state_import(ck.codec_state.clone());
            match &mut downlink {
                Some(d) => d.state_import(
                    ck.down_reference.clone(),
                    ck.down_link_bits.clone(),
                    ck.down_last.clone(),
                    ck.down_codec_state.clone(),
                )?,
                None => anyhow::ensure!(
                    ck.down_reference.is_empty() && ck.down_link_bits.is_empty(),
                    "checkpoint {} carries downlink state but the config has \
                     no down_codec",
                    ck.id(),
                ),
            }
            match ck.transport.clone() {
                Some(ts) => self.transport.restore_state(ts)?,
                None => anyhow::ensure!(
                    !self.transport.buffered_async(),
                    "checkpoint {} holds no async protocol state but transport \
                     '{}' needs one",
                    ck.id(),
                    self.transport.name(),
                ),
            }
        } else {
            // Stateful codecs (error feedback) carry per-node memory; a
            // fresh run starts from zero residuals even when the codec
            // instance is reused across runs (the trait's reset
            // semantics).
            self.codec.reset_state();
            params = engine.init_params()?;
            anyhow::ensure!(params.len() == p, "engine param count mismatch");
            curve = Curve::new(cfg.name.clone());
            stats = Vec::with_capacity(rounds);
            total_bits = 0u64;
            total_bits_down = 0u64;
            total_bits_edge = 0u64;
            start_k = 0;
            // Round-0 point: initial loss at time 0.
            let loss0 = slab.eval(engine, &params)?;
            curve.push(CurvePoint {
                round: 0,
                iterations: 0,
                time: 0.0,
                bits_up: 0,
                bits_down: 0,
                bits_edge_to_root: 0,
                loss: loss0,
            });
        }
        events.emit(
            "run_started",
            vec![
                ("config_hash", Json::str(format!("{:016x}", meta.config_hash))),
                ("resumed_from", match &meta.resumed_from {
                    Some(id) => Json::str(id.as_str()),
                    None => Json::Null,
                }),
                ("round_start", Json::num(start_k as f64)),
                ("rounds", Json::num(rounds as f64)),
                ("transport", Json::str(self.transport.name())),
            ],
        );
        let mut agg = Aggregator::new(p);
        // One shard plan for the whole run; `cfg.agg_shards == 1` is the
        // historical single-threaded accumulation, larger values fan the
        // f64 accumulate/apply across scoped threads with bit-identical
        // results (the aggregate module's determinism contract). Either
        // way each upload streams through the fused scratch-free
        // `UpdateCodec::accumulate_range` kernels. Every transport —
        // InProcess, AsyncSim, and the net::Tcp leader — funnels through
        // this one path.
        let plan = ShardPlan::new(p, cfg.agg_shards);

        for k in start_k..rounds {
            let round_t0 = Instant::now();
            let nodes = sampler::sample_nodes(cfg.n_nodes, cfg.r, cfg.seed, k);
            let lrs: Vec<f32> = (0..cfg.tau).map(|t| cfg.lr.lr(k, t)).collect();
            // Build this version's broadcast frame. Under a down codec
            // the dispatched nodes train on the shared reference `ref(k)`
            // — not the exact `x_k` they never see — and their uplink
            // deltas are relative to it; the aggregate still applies to
            // the server's exact model (QAFeL).
            let frame = match &mut downlink {
                Some(d) => d.begin_round(k, &params)?,
                None => ModelFrame::raw(k, params.clone()),
            };
            let ctx = RoundCtx { round: k, nodes: &nodes, frame: &frame, lrs: &lrs };
            let outcome = self.transport.round(&ctx, self.codec.as_ref(), engine)?;
            // Downlink bits, per dispatch: the chain links the node was
            // missing (down codec), or one dense model — `32·p`, except
            // the free out-of-band version 0 — on the raw broadcast.
            let bits_down: u64 = match &mut downlink {
                Some(d) => outcome
                    .dispatches
                    .iter()
                    .map(|&(node, v)| d.dispatch_bits(node, v))
                    .sum(),
                None => outcome.dispatches.iter().filter(|&&(_, v)| v > 0).count()
                    as u64
                    * 32
                    * p as u64,
            };
            agg.reset();
            // `mass` is 1.0 on every flat transport; hierarchical summed
            // partials carry their cohort size so the weighted-mean
            // normalizer matches the flat topology exactly.
            let batch: Vec<(&crate::quant::Encoded, f64, f64)> = outcome
                .uploads
                .iter()
                .map(|u| (&u.enc, cfg.staleness_rule.weight(u.staleness), u.mass))
                .collect();
            agg.push_batch_scaled(self.codec.as_ref(), &batch, &plan)?;
            // Split uplink accounting: hierarchical transports report the
            // worker→edge and edge→root hops themselves (the aggregated
            // frames at the root are not what the workers sent); flat
            // transports charge the aggregator's ledger as the single hop.
            let (bits, bits_edge): (u64, u64) = match outcome.uplink_bits {
                Some((up, edge)) => (up, edge),
                None => (agg.upload_bits().iter().sum(), 0),
            };
            let (compute_time, comm_time) = match (&mut timing, outcome.timing) {
                // The transport ran its own (virtual) event clock for
                // this commit — charge its figures verbatim.
                (Timing::Virtual { clock, .. }, Some(t)) => {
                    clock.advance(t.compute_time + t.comm_time);
                    (t.compute_time, t.comm_time)
                }
                // Barrier commit under the §5 model: the round waits for
                // the slowest sampled node, then uploads serialize.
                (Timing::Virtual { cost, clock }, None) => {
                    let (ct, mt) = if agg.count() > 0 {
                        (
                            cost.round_compute_time(&nodes, k, cfg.tau, engine.batch()),
                            cost.round_comm_time(agg.upload_bits()),
                        )
                    } else {
                        (0.0, 0.0)
                    };
                    clock.advance(ct + mt);
                    (ct, mt)
                }
                (Timing::Wall { .. }, _) => {
                    let ct = if agg.count() > 0 {
                        round_t0.elapsed().as_secs_f64()
                    } else {
                        0.0
                    };
                    (ct, 0.0)
                }
            };
            if agg.count() > 0 {
                agg.apply_sharded(&mut params, &plan)?;
            } else {
                eprintln!(
                    "[{}] round {k}: no uploads from {} sampled nodes — skipping",
                    self.transport.name(),
                    nodes.len()
                );
            }
            total_bits += bits;
            total_bits_down += bits_down;
            total_bits_edge += bits_edge;
            // Async-protocol telemetry: staleness stamps come with the
            // uploads, drop counts with the outcome. Barrier transports
            // report all zeros (every upload is staleness 0, none drop).
            let staleness_max =
                outcome.uploads.iter().map(|u| u.staleness).max().unwrap_or(0);
            let staleness_mean = if outcome.uploads.is_empty() {
                0.0
            } else {
                outcome.uploads.iter().map(|u| u.staleness as f64).sum::<f64>()
                    / outcome.uploads.len() as f64
            };
            stats.push(RoundStats {
                round: k,
                compute_time,
                comm_time,
                bits_up: bits,
                bits_down,
                bits_edge_to_root: bits_edge,
                dropped: outcome.dropped,
                staleness_max,
                staleness_mean,
            });

            if (k + 1) % cfg.eval_every == 0 || k + 1 == rounds {
                let loss = slab.eval(engine, &params)?;
                let time = match &timing {
                    Timing::Virtual { clock, .. } => clock.now(),
                    Timing::Wall { t0 } => t0.elapsed().as_secs_f64(),
                };
                curve.push(CurvePoint {
                    round: k + 1,
                    iterations: (k + 1) * cfg.tau,
                    time,
                    bits_up: total_bits,
                    bits_down: total_bits_down,
                    bits_edge_to_root: total_bits_edge,
                    loss,
                });
            }

            let completed = k + 1;
            let t_now = match &timing {
                Timing::Virtual { clock, .. } => clock.now(),
                Timing::Wall { t0 } => t0.elapsed().as_secs_f64(),
            };
            events.emit(
                "commit",
                vec![
                    ("bits", Json::num(bits as f64)),
                    ("bits_down", Json::num(bits_down as f64)),
                    ("bits_edge_to_root", Json::num(bits_edge as f64)),
                    ("dropped", Json::num(outcome.dropped as f64)),
                    ("staleness_max", Json::num(staleness_max as f64)),
                    ("t", Json::num(t_now)),
                    ("uploads", Json::num(outcome.uploads.len() as f64)),
                    ("version", Json::num(completed as f64)),
                ],
            );
            // Checkpoint after the eval point so a resumed curve carries
            // this commit's measurement.
            if let Some(path) =
                ctrl.checkpoint_path.as_ref().filter(|_| ctrl.checkpoint_due(completed))
            {
                let (down_reference, down_link_bits, down_last, down_codec_state) =
                    match &downlink {
                        Some(d) => d.state_export(),
                        None => (Vec::new(), Vec::new(), Vec::new(), Vec::new()),
                    };
                let ck = crate::ops::Checkpoint {
                    config_hash: meta.config_hash,
                    seed: cfg.seed,
                    next_round: completed,
                    total_bits,
                    total_bits_down,
                    total_bits_edge_to_root: total_bits_edge,
                    clock_now: match &timing {
                        Timing::Virtual { clock, .. } => clock.now(),
                        // Wall-clock time restarts on resume; see
                        // docs/OPERATIONS.md.
                        Timing::Wall { .. } => 0.0,
                    },
                    params: params.clone(),
                    curve_label: curve.label.clone(),
                    curve: curve.points.clone(),
                    stats: stats.clone(),
                    codec_state: self.codec.state_export(),
                    down_reference,
                    down_link_bits,
                    down_last,
                    down_codec_state,
                    rng_states: Vec::new(),
                    transport: self.transport.export_state()?,
                };
                ck.write_atomic(path)?;
                events.emit(
                    "checkpoint_written",
                    vec![
                        ("id", Json::str(ck.id())),
                        ("path", Json::str(path.display().to_string())),
                        ("round", Json::num(completed as f64)),
                    ],
                );
            }
            if ctrl.stop_due(completed) {
                eprintln!(
                    "[{}] stop-after {completed}: checkpointed, exiting cleanly",
                    self.transport.name()
                );
                break;
            }
        }
        self.transport.shutdown()?;
        events.emit(
            "run_finished",
            vec![
                ("rounds_done", Json::num(stats.len() as f64)),
                ("total_bits", Json::num(total_bits as f64)),
                ("total_bits_down", Json::num(total_bits_down as f64)),
                ("total_bits_edge_to_root", Json::num(total_bits_edge as f64)),
            ],
        );
        Ok(RunResult {
            curve,
            params,
            rounds: stats,
            total_bits,
            total_bits_down,
            total_bits_edge_to_root: total_bits_edge,
            meta,
        })
    }
}
