//! The [`Transport`] seam: how local work gets executed and how uploads
//! come back to the server — *including when* they come back.
//!
//! The [`RoundEngine`](super::RoundEngine) drives the FedPAQ protocol
//! (`sample → local work → aggregate → apply`) against this trait. One
//! engine call = one **server commit**, but what a commit waits for is the
//! transport's choice:
//!
//! * **barrier transports** ([`InProcess`] here, [`crate::net::Tcp`] over
//!   sockets) run every sampled node to completion and return the full
//!   round's uploads, all staleness 0 — the paper's synchronous
//!   Algorithm 1;
//! * **buffered-async transports** ([`super::AsyncSim`] on the virtual
//!   clock, [`crate::net::TcpAsync`] on real sockets — both driven by the
//!   shared [`CommitPlanner`](super::commit_loop::CommitPlanner) commit
//!   core) keep nodes training across commits and return a batch as soon
//!   as `buffer_size` uploads have arrived; stragglers' uploads surface
//!   in later commits carrying a positive staleness.
//!
//! To make both expressible, `round` returns a [`RoundOutcome`]: uploads
//! stamped with the server version they trained on, plus (for transports
//! that manage their own event clock) the per-commit virtual-time charge.
//! Barrier transports use [`RoundOutcome::barrier`] and let the engine
//! charge the §5 barrier cost model exactly as before.
//!
//! A transport is handed the *leader-local* engine: in-process transports
//! reuse it to run the sampled nodes' local SGD; networked transports
//! ignore it (their workers own engines in other processes).

use super::local::{self, GatherBufs};
use crate::config::ExperimentConfig;
use crate::data::{BatchSampler, FederatedDataset, Partition};
use crate::model::Engine;
use crate::quant::{Encoded, UpdateCodec};
use std::sync::Arc;

/// One broadcastable model version — the unit every transport ships to
/// its nodes, replacing the ad-hoc `(Vec<f32>, version)` tuples the
/// broadcast paths used to pass around.
///
/// `params` is always the dense model clients must *train on*: the exact
/// `x_k` when the downlink is raw, or the shared reference `ref(k)` when
/// a `down_codec` is set (see [`super::downlink`]). `link` additionally
/// carries the newest delta-chain link in compressed form, so networked
/// transports can ship a chain suffix instead of the dense vector.
#[derive(Debug, Clone)]
pub struct ModelFrame {
    /// Server version `k` this frame broadcasts.
    pub version: usize,
    /// Dense broadcast model (`x_k` raw, `ref(k)` under a down codec).
    pub params: Vec<f32>,
    /// The encoded chain link `ref(k−1) → ref(k)`. `None` when the
    /// downlink is raw, and at version 0 (the initial model is shipped
    /// out of band / as a raw re-base).
    pub link: Option<Encoded>,
}

impl ModelFrame {
    /// A raw (uncompressed-downlink) frame.
    pub fn raw(version: usize, params: Vec<f32>) -> Self {
        ModelFrame { version, params, link: None }
    }
}

/// Everything a transport needs to execute one commit's worth of work.
#[derive(Debug, Clone, Copy)]
pub struct RoundCtx<'a> {
    /// Server version `k` (one per commit; for barrier transports this is
    /// exactly the paper's round index). Always equals `frame.version`.
    pub round: usize,
    /// The sampled candidate set `S_k`, in sampling order. Barrier
    /// transports run all of it; buffered-async transports dispatch a
    /// prefix as their refill wave.
    pub nodes: &'a [usize],
    /// The broadcast model for this version (what dispatched nodes train
    /// on, plus the compressed chain link when the downlink is encoded).
    pub frame: &'a ModelFrame,
    /// Per-local-step stepsizes for work dispatched at this version.
    pub lrs: &'a [f32],
}

/// One node upload as it reaches the server, stamped with its origin.
#[derive(Debug)]
pub struct Upload {
    /// The virtual node that produced it.
    pub node: usize,
    /// Server version whose model the node trained on.
    pub origin_round: usize,
    /// Versions committed since dispatch: `commit_round − origin_round`.
    /// Always 0 on barrier transports.
    pub staleness: usize,
    /// The encoded model delta.
    pub enc: Encoded,
    /// How many node updates `enc` stands for in the weighted mean.
    /// `1.0` everywhere except hierarchical transports, where a summed
    /// edge partial carries its whole cohort: the aggregator adds the
    /// frame once at the staleness weight but grows the normalizer by
    /// `weight · mass` (see `docs/TOPOLOGY.md` for the algebra).
    pub mass: f64,
}

/// Virtual-time charge for one commit, reported by transports that run
/// their own event clock (e.g. [`super::AsyncSim`], where a commit's wait
/// is "until the buffer fills", not "until the slowest sampled node").
#[derive(Debug, Clone, Copy)]
pub struct CommitTiming {
    /// Time from the previous commit until the committing upload arrived.
    pub compute_time: f64,
    /// Uplink serialization time of the committed batch.
    pub comm_time: f64,
}

/// What one `Transport::round` call hands back to the engine.
#[derive(Debug)]
pub struct RoundOutcome {
    /// The committed uploads, in the order they must be aggregated.
    pub uploads: Vec<Upload>,
    /// `Some` when the transport owns virtual-time accounting for this
    /// commit; `None` lets the engine charge the §5 barrier model
    /// (simulated transports) or wall-clock (networked ones).
    pub timing: Option<CommitTiming>,
    /// Stale uploads dropped (and re-dispatched) since the previous
    /// commit — per-commit telemetry surfaced in
    /// [`RoundStats`](super::engine::RoundStats). Always 0 on barrier
    /// transports.
    pub dropped: u64,
    /// Every `(node, version)` dispatch performed during this call, in
    /// dispatch order — the engine charges downlink bits per dispatch
    /// (the chain links, or the dense model when the downlink is raw).
    /// Barrier transports dispatch each sampled node once at
    /// `ctx.round`; buffered-async transports also list their planner
    /// re-dispatches.
    pub dispatches: Vec<(usize, usize)>,
    /// Split uplink accounting for hierarchical transports:
    /// `(bits_worker_to_edge, bits_edge_to_root)`. `None` (every flat
    /// transport) lets the engine charge the aggregator's ledger sum as
    /// the single-hop `bits_up` with a zero edge→root component.
    pub uplink_bits: Option<(u64, u64)>,
}

impl RoundOutcome {
    /// Wrap a full barrier round's uploads (in `ctx.nodes` order, one per
    /// sampled node): staleness 0, engine-side timing, no drops.
    pub fn barrier(ctx: &RoundCtx<'_>, encs: Vec<Encoded>) -> Self {
        debug_assert_eq!(encs.len(), ctx.nodes.len());
        let uploads = ctx
            .nodes
            .iter()
            .zip(encs)
            .map(|(&node, enc)| Upload {
                node,
                origin_round: ctx.round,
                staleness: 0,
                enc,
                mass: 1.0,
            })
            .collect();
        let dispatches = ctx.nodes.iter().map(|&node| (node, ctx.round)).collect();
        RoundOutcome { uploads, timing: None, dropped: 0, dispatches, uplink_bits: None }
    }
}

/// How the round pipeline reaches its nodes.
///
/// Barrier implementations must return uploads **in `ctx.nodes` order** —
/// the engine aggregates in the returned order, and node order is what
/// makes the in-process and distributed paths produce bit-identical
/// models for equal seeds. Buffered-async implementations return commit
/// batches in their own canonical order (see [`super::AsyncSim`]).
pub trait Transport {
    /// Human label for logs.
    fn name(&self) -> &'static str;

    /// Whether round results are charged to the paper's §5 virtual cost
    /// model (simulated transports) or to real wall-clock time.
    fn virtual_time(&self) -> bool;

    /// Whether this transport's remote ends rebuild their codec from the
    /// broadcast config (networked transports) rather than sharing the
    /// leader's codec instance. When `true`, `ServerBuilder` rejects
    /// codec-instance overrides — a trait object cannot travel to the
    /// workers, so the config's tagged spec is the only source of truth.
    fn rebuilds_codec_from_config(&self) -> bool {
        false
    }

    /// Whether this transport implements the buffered-async commit
    /// protocol (`cfg.async_rounds`). Barrier transports return `false`;
    /// `ServerBuilder` refuses to pair an async-rounds config with a
    /// transport that would silently run full barriers instead.
    fn buffered_async(&self) -> bool {
        false
    }

    /// Build per-run state (worlds, connections) before round 0.
    fn setup(
        &mut self,
        cfg: &ExperimentConfig,
        engine: &mut dyn Engine,
    ) -> crate::Result<()>;

    /// Execute the work for one server commit and return the committed
    /// uploads (plus self-managed timing, if any).
    fn round(
        &mut self,
        ctx: &RoundCtx<'_>,
        codec: &dyn UpdateCodec,
        engine: &mut dyn Engine,
    ) -> crate::Result<RoundOutcome>;

    /// Tear down after the last round.
    fn shutdown(&mut self) -> crate::Result<()> {
        Ok(())
    }

    /// Give the transport a structured-event destination (see
    /// [`crate::ops::EventSink`]). Transports without protocol decisions
    /// of their own ignore it.
    fn set_events(&mut self, events: crate::ops::EventSink) {
        let _ = events;
    }

    /// Export transport-owned protocol state for a checkpoint. Barrier
    /// transports hold none (`Ok(None)`); buffered-async transports
    /// return their planner snapshot (and, for the simulator, in-flight
    /// jobs).
    fn export_state(&self) -> crate::Result<Option<crate::ops::TransportState>> {
        Ok(None)
    }

    /// Restore protocol state from a checkpoint, called after `setup`
    /// and before the first resumed round. The default refuses: a
    /// checkpoint carrying async state cannot resume on a transport that
    /// does not know how to rebuild it.
    fn restore_state(&mut self, state: crate::ops::TransportState) -> crate::Result<()> {
        let _ = state;
        anyhow::bail!(
            "transport '{}' cannot restore checkpointed async protocol state",
            self.name()
        )
    }
}

/// The synchronous simulation path: every sampled virtual node runs
/// sequentially on the leader's own engine, the commit waits for all of
/// them (a full barrier), and time is charged to the §5 cost model.
#[derive(Debug, Default)]
pub struct InProcess {
    /// Pre-built dataset/partition (from `engine::build_world` on the
    /// same config this transport will be set up with), so a run shares
    /// one world between eval slab and training instead of building two.
    preset: Option<(Arc<FederatedDataset>, Partition)>,
    world: Option<World>,
    bufs: GatherBufs,
}

/// Per-run simulated federated world, shared by the in-process transports
/// ([`InProcess`] and [`super::AsyncSim`]).
#[derive(Debug)]
pub(crate) struct World {
    pub(crate) cfg: ExperimentConfig,
    pub(crate) data: Arc<FederatedDataset>,
    pub(crate) partition: Partition,
    pub(crate) sampler: BatchSampler,
}

impl World {
    /// Build from a preset world (if any) or regenerate from the config.
    pub(crate) fn build(
        preset: Option<(Arc<FederatedDataset>, Partition)>,
        cfg: &ExperimentConfig,
        engine: &mut dyn Engine,
    ) -> crate::Result<Self> {
        let (data, partition) = match preset {
            Some(world) => world,
            None => super::engine::build_world(cfg, engine)?,
        };
        let sampler = BatchSampler::new(cfg.seed, engine.batch());
        Ok(World { cfg: cfg.clone(), data, partition, sampler })
    }

    /// Run node `node`'s τ local steps at server version `round` on model
    /// `params`, returning the encoded upload.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn node_round(
        &self,
        codec: &dyn UpdateCodec,
        engine: &mut dyn Engine,
        node: usize,
        round: usize,
        params: &[f32],
        lrs: &[f32],
        bufs: &mut GatherBufs,
    ) -> crate::Result<Encoded> {
        local::node_round(
            &self.cfg,
            codec,
            engine,
            &self.data,
            self.partition.shard(node),
            &self.sampler,
            node,
            round,
            params,
            lrs,
            bufs,
        )
    }
}

impl InProcess {
    pub fn new() -> Self {
        Self::default()
    }

    /// Seed the transport with an already-built world. Must come from
    /// [`build_world`](super::engine::build_world) on the same config
    /// later passed to `setup` — `ServerBuilder` uses this to construct
    /// the federated world exactly once per run.
    pub fn with_world(data: Arc<FederatedDataset>, partition: Partition) -> Self {
        InProcess { preset: Some((data, partition)), ..Self::default() }
    }
}

impl Transport for InProcess {
    fn name(&self) -> &'static str {
        "in-process"
    }

    fn virtual_time(&self) -> bool {
        true
    }

    fn setup(
        &mut self,
        cfg: &ExperimentConfig,
        engine: &mut dyn Engine,
    ) -> crate::Result<()> {
        self.world = Some(World::build(self.preset.take(), cfg, engine)?);
        Ok(())
    }

    fn round(
        &mut self,
        ctx: &RoundCtx<'_>,
        codec: &dyn UpdateCodec,
        engine: &mut dyn Engine,
    ) -> crate::Result<RoundOutcome> {
        let w = self
            .world
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("InProcess::round before setup"))?;
        let mut uploads = Vec::with_capacity(ctx.nodes.len());
        for &node in ctx.nodes {
            uploads.push(w.node_round(
                codec,
                engine,
                node,
                ctx.round,
                &ctx.frame.params,
                ctx.lrs,
                &mut self.bufs,
            )?);
        }
        Ok(RoundOutcome::barrier(ctx, uploads))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::RustEngine;
    use crate::opt::LrSchedule;
    use crate::quant::CodecSpec;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            name: "transport-test".into(),
            model: "logreg".into(),
            dataset: crate::data::DatasetKind::Mnist08,
            n_nodes: 4,
            per_node: 30,
            r: 2,
            tau: 2,
            t_total: 4,
            codec: CodecSpec::qsgd(2),
            down_codec: None,
            lr: LrSchedule::Const { eta: 0.3 },
            ratio: 100.0,
            seed: 9,
            eval_every: 1,
            engine: crate::config::EngineKind::Rust,
            partition: crate::data::PartitionKind::Iid,
            async_rounds: false,
            buffer_size: 0,
            max_staleness: 8,
            staleness_rule: Default::default(),
            agg_shards: 1,
            straggler: Default::default(),
            dataset_cap: 0,
        }
    }

    #[test]
    fn in_process_rounds_are_deterministic_and_node_ordered() {
        let cfg = tiny_cfg();
        let codec = cfg.codec.build().unwrap();
        let mut engine =
            RustEngine::new(crate::model::ModelKind::LogReg { d: 784, l2: 0.05 }, 10, 120)
                .unwrap();
        let params = engine.init_params().unwrap();
        let frame = ModelFrame::raw(0, params.clone());
        let run_once = |engine: &mut RustEngine| {
            let mut t = InProcess::new();
            t.setup(&cfg, engine).unwrap();
            let ctx = RoundCtx { round: 0, nodes: &[2, 0], frame: &frame, lrs: &[0.3, 0.3] };
            t.round(&ctx, codec.as_ref(), engine).unwrap()
        };
        let a = run_once(&mut engine);
        let b = run_once(&mut engine);
        assert_eq!(a.uploads.len(), 2);
        assert!(a.timing.is_none(), "barrier transports use engine timing");
        for (x, y) in a.uploads.iter().zip(&b.uploads) {
            assert_eq!(x.node, y.node);
            assert_eq!(x.staleness, 0);
            assert_eq!(x.origin_round, 0);
            assert_eq!(x.enc.buf.words(), y.enc.buf.words());
            assert_eq!(x.enc.bits(), y.enc.bits());
        }
        // Node order preserved (the bit-stability contract).
        assert_eq!(a.uploads[0].node, 2);
        assert_eq!(a.uploads[1].node, 0);
        // Barrier rounds dispatch each sampled node once, at this round.
        assert_eq!(a.dispatches, vec![(2, 0), (0, 0)]);
    }

    #[test]
    fn round_before_setup_errors() {
        let cfg = tiny_cfg();
        let codec = cfg.codec.build().unwrap();
        let mut engine =
            RustEngine::new(crate::model::ModelKind::LogReg { d: 784, l2: 0.05 }, 10, 120)
                .unwrap();
        let frame = ModelFrame::raw(0, vec![0f32; 785]);
        let ctx = RoundCtx { round: 0, nodes: &[0], frame: &frame, lrs: &[0.1] };
        let mut t = InProcess::new();
        assert!(t.round(&ctx, codec.as_ref(), &mut engine).is_err());
    }
}
