//! The [`Transport`] seam: how a round's local work gets executed and how
//! its uploads come back.
//!
//! The [`RoundEngine`](super::RoundEngine) drives the FedPAQ protocol
//! (`sample → local work → aggregate → apply`) against this trait, so the
//! same round logic runs in-process (the simulation path, with §5 virtual
//! time) or across real sockets ([`crate::net::Tcp`], with wall-clock
//! time) — the duplicated loops the coordinator and net layers used to
//! carry are gone.
//!
//! A transport is handed the *leader-local* engine: in-process transports
//! reuse it to run the sampled nodes' local SGD; networked transports
//! ignore it (their workers own engines in other processes).

use super::local::{self, GatherBufs};
use crate::config::ExperimentConfig;
use crate::data::{BatchSampler, FederatedDataset, Partition};
use crate::model::Engine;
use crate::quant::{Encoded, UpdateCodec};
use std::sync::Arc;

/// Everything a transport needs to execute one round.
#[derive(Debug, Clone, Copy)]
pub struct RoundCtx<'a> {
    /// Round index `k`.
    pub round: usize,
    /// The sampled participant set `S_k`, in sampling order.
    pub nodes: &'a [usize],
    /// Current global model `x_k` to broadcast.
    pub params: &'a [f32],
    /// Per-local-step stepsizes for this round.
    pub lrs: &'a [f32],
}

/// How the round pipeline reaches its nodes.
///
/// Implementations must return uploads **in `ctx.nodes` order** — the
/// engine aggregates in node order so the in-process and distributed
/// paths produce bit-identical models for equal seeds.
pub trait Transport {
    /// Human label for logs.
    fn name(&self) -> &'static str;

    /// Whether round results are charged to the paper's §5 virtual cost
    /// model (simulated transports) or to real wall-clock time.
    fn virtual_time(&self) -> bool;

    /// Whether this transport's remote ends rebuild their codec from the
    /// broadcast config (networked transports) rather than sharing the
    /// leader's codec instance. When `true`, `ServerBuilder` rejects
    /// codec-instance overrides — a trait object cannot travel to the
    /// workers, so the config's tagged spec is the only source of truth.
    fn rebuilds_codec_from_config(&self) -> bool {
        false
    }

    /// Build per-run state (worlds, connections) before round 0.
    fn setup(
        &mut self,
        cfg: &ExperimentConfig,
        engine: &mut dyn Engine,
    ) -> crate::Result<()>;

    /// Execute one round's local work on every node in `ctx.nodes`,
    /// returning their encoded uploads in node order.
    fn round(
        &mut self,
        ctx: &RoundCtx<'_>,
        codec: &dyn UpdateCodec,
        engine: &mut dyn Engine,
    ) -> crate::Result<Vec<Encoded>>;

    /// Tear down after the last round.
    fn shutdown(&mut self) -> crate::Result<()> {
        Ok(())
    }
}

/// Today's simulation path: every virtual node runs sequentially on the
/// leader's own engine, and time is charged to the §5 cost model.
#[derive(Debug, Default)]
pub struct InProcess {
    /// Pre-built dataset/partition (from `engine::build_world` on the
    /// same config this transport will be set up with), so a run shares
    /// one world between eval slab and training instead of building two.
    preset: Option<(Arc<FederatedDataset>, Partition)>,
    world: Option<World>,
    bufs: GatherBufs,
}

#[derive(Debug)]
struct World {
    cfg: ExperimentConfig,
    data: Arc<FederatedDataset>,
    partition: Partition,
    sampler: BatchSampler,
}

impl InProcess {
    pub fn new() -> Self {
        Self::default()
    }

    /// Seed the transport with an already-built world. Must come from
    /// [`build_world`](super::engine::build_world) on the same config
    /// later passed to `setup` — `ServerBuilder` uses this to construct
    /// the federated world exactly once per run.
    pub fn with_world(data: Arc<FederatedDataset>, partition: Partition) -> Self {
        InProcess { preset: Some((data, partition)), ..Self::default() }
    }
}

impl Transport for InProcess {
    fn name(&self) -> &'static str {
        "in-process"
    }

    fn virtual_time(&self) -> bool {
        true
    }

    fn setup(
        &mut self,
        cfg: &ExperimentConfig,
        engine: &mut dyn Engine,
    ) -> crate::Result<()> {
        let (data, partition) = match self.preset.take() {
            Some(world) => world,
            None => super::engine::build_world(cfg, engine)?,
        };
        let sampler = BatchSampler::new(cfg.seed, engine.batch());
        self.world = Some(World { cfg: cfg.clone(), data, partition, sampler });
        Ok(())
    }

    fn round(
        &mut self,
        ctx: &RoundCtx<'_>,
        codec: &dyn UpdateCodec,
        engine: &mut dyn Engine,
    ) -> crate::Result<Vec<Encoded>> {
        let w = self
            .world
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("InProcess::round before setup"))?;
        let mut uploads = Vec::with_capacity(ctx.nodes.len());
        for &node in ctx.nodes {
            uploads.push(local::node_round(
                &w.cfg,
                codec,
                engine,
                &w.data,
                w.partition.shard(node),
                &w.sampler,
                node,
                ctx.round,
                ctx.params,
                ctx.lrs,
                &mut self.bufs,
            )?);
        }
        Ok(uploads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::RustEngine;
    use crate::opt::LrSchedule;
    use crate::quant::CodecSpec;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            name: "transport-test".into(),
            model: "logreg".into(),
            dataset: crate::data::DatasetKind::Mnist08,
            n_nodes: 4,
            per_node: 30,
            r: 2,
            tau: 2,
            t_total: 4,
            codec: CodecSpec::qsgd(2),
            lr: LrSchedule::Const { eta: 0.3 },
            ratio: 100.0,
            seed: 9,
            eval_every: 1,
            engine: crate::config::EngineKind::Rust,
            partition: crate::data::PartitionKind::Iid,
        }
    }

    #[test]
    fn in_process_rounds_are_deterministic_and_node_ordered() {
        let cfg = tiny_cfg();
        let codec = cfg.codec.build().unwrap();
        let mut engine =
            RustEngine::new(crate::model::ModelKind::LogReg { d: 784, l2: 0.05 }, 10, 120)
                .unwrap();
        let params = engine.init_params().unwrap();
        let run_once = |engine: &mut RustEngine| {
            let mut t = InProcess::new();
            t.setup(&cfg, engine).unwrap();
            let ctx = RoundCtx { round: 0, nodes: &[2, 0], params: &params, lrs: &[0.3, 0.3] };
            t.round(&ctx, codec.as_ref(), engine).unwrap()
        };
        let a = run_once(&mut engine);
        let b = run_once(&mut engine);
        assert_eq!(a.len(), 2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.buf.words(), y.buf.words());
            assert_eq!(x.bits(), y.bits());
        }
    }

    #[test]
    fn round_before_setup_errors() {
        let cfg = tiny_cfg();
        let codec = cfg.codec.build().unwrap();
        let mut engine =
            RustEngine::new(crate::model::ModelKind::LogReg { d: 784, l2: 0.05 }, 10, 120)
                .unwrap();
        let params = vec![0f32; 785];
        let ctx = RoundCtx { round: 0, nodes: &[0], params: &params, lrs: &[0.1] };
        let mut t = InProcess::new();
        assert!(t.round(&ctx, codec.as_ref(), &mut engine).is_err());
    }
}
