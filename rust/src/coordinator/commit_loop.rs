//! The event-driven commit core: [`CommitPlanner`], one buffered-async
//! state machine shared by every async transport.
//!
//! PR 2 introduced FedBuff-style buffered commits, but the protocol logic
//! (buffer fill, staleness caps, straggler re-dispatch, the
//! never-duplicate-`(node, version)` invariant) lived inside the
//! [`AsyncSim`](super::AsyncSim) discrete-event simulator, welded to its
//! virtual clock. This module lifts that logic into a **pure, seeded
//! state machine** with no notion of time at all: the planner consumes
//! *events* — an upload arrived, capacity freed up — and emits
//! *decisions* — dispatch a node, drop a stale upload, commit a batch.
//! What "arrival" means (a virtual completion time popped from a heap, a
//! frame read off a TCP socket) is the transport's business:
//!
//! ```text
//!   AsyncSim (§5 virtual clock)  ─┐
//!                                 ├──▶ CommitPlanner ──▶ Decisions
//!   net::TcpAsync (real sockets) ─┘        (pure)
//! ```
//!
//! Because the planner is deterministic in `(seed, event sequence)`, the
//! simulator reproduces its pre-refactor runs bit-for-bit (pinned by
//! `rust/tests/async_rounds.rs`), and the TCP leader inherits exactly the
//! same protocol semantics — including the degeneration to the
//! synchronous barrier at `buffer_size == r, max_staleness == 0`.
//!
//! ## Protocol invariants (enforced here, property-tested in
//! `rust/tests/prop_commit_planner.rs`)
//!
//! * **No duplicate jobs.** A `(node, version)` pair is dispatched at most
//!   once — a duplicate would replay identical RNG streams and
//!   double-count that node's update. Re-dispatch after a stale drop
//!   skips nodes that already hold a live job at the current version.
//! * **Full commits.** Every [`Decision::Commit`] carries exactly
//!   `buffer_size` uploads; only an explicit [`CommitPlanner::drain`]
//!   (the final drain) may surface fewer.
//! * **Staleness cap.** No upload with `staleness > max_staleness` is
//!   ever committed — it is dropped at arrival and its capacity
//!   immediately re-dispatched on the current model, keeping `r` jobs in
//!   flight at every instant.
//! * **Canonical batch order.** Commit batches sort by
//!   `(origin version, dispatch slot)`, so a full-barrier buffer is
//!   exactly `S_k` in sampling order — the bit-stability anchor for the
//!   synchronous degeneration.

use super::transport::Upload;
use crate::config::ExperimentConfig;
use crate::quant::Encoded;
use crate::util::rng::Rng;

/// What the outside world tells the planner.
#[derive(Debug)]
pub enum PlannerEvent {
    /// A dispatched job's upload reached the server. `version` is the
    /// server version whose model the node trained on (stamped on the
    /// dispatch).
    UploadArrived { node: usize, version: usize, enc: Encoded },
    /// One unit of in-flight capacity was lost outside the planner's own
    /// drop path — a transport lost the worker holding job
    /// `(node, version)` and its upload can never arrive. The planner
    /// retires that job (so `in_flight` stays truthful for drain logic)
    /// and answers with a replacement [`Decision::Dispatch`] at the
    /// current version. Because the lost upload was never delivered, the
    /// replacement draw may legitimately re-pick the same node — the
    /// no-duplicate invariant is about jobs that can still be counted,
    /// and the retired one cannot.
    CapacityFreed { node: usize, version: usize },
}

/// What the planner tells the transport to do.
#[derive(Debug)]
pub enum Decision {
    /// Run node `node` on the version-`version` model. `slot` is the
    /// job's position in the canonical batch order (wave jobs get their
    /// sampling-order index; re-dispatched jobs sort behind every wave
    /// job of the same version) — virtual-time transports also use it as
    /// a deterministic tie-break for simultaneous arrivals.
    Dispatch { node: usize, version: usize, slot: usize },
    /// An upload exceeded `max_staleness` and was discarded (a
    /// replacement `Dispatch` follows in the same decision batch).
    Drop { node: usize, staleness: usize },
    /// `buffer_size` uploads are in: commit them (in the returned order)
    /// and bump the server version. `dropped` counts stale drops since
    /// the previous commit (per-commit telemetry for
    /// [`RoundStats`](super::engine::RoundStats)).
    Commit { uploads: Vec<Upload>, dropped: u64 },
}

/// A dispatched job the planner is still waiting on.
#[derive(Debug, Clone, Copy)]
struct JobKey {
    node: usize,
    version: usize,
    slot: usize,
}

/// An arrived upload waiting for the buffer to fill.
#[derive(Debug)]
struct Buffered {
    node: usize,
    version: usize,
    slot: usize,
    enc: Encoded,
}

/// A complete, serializable snapshot of a [`CommitPlanner`] — what
/// `ops` checkpoints persist so a resumed run continues the protocol
/// mid-stream with identical decisions. [`CommitPlanner::export_state`]
/// produces one; [`CommitPlanner::from_state`] rebuilds the planner
/// (export → rebuild → export is an identity, property-tested in
/// `rust/tests/ops_checkpoint.rs`).
#[derive(Debug, Clone)]
pub struct PlannerState {
    pub seed: u64,
    pub n_nodes: usize,
    pub buffer_size: usize,
    pub max_staleness: usize,
    pub version: usize,
    pub wave_len: usize,
    pub awaiting_wave: bool,
    /// `(node, version, slot)` of every dispatched-but-unarrived job.
    pub in_flight: Vec<(usize, usize, usize)>,
    /// `(node, version, slot, enc)` of every arrived-but-uncommitted
    /// upload, in arrival order.
    pub buffer: Vec<(usize, usize, usize, Encoded)>,
    pub dropped_total: u64,
    pub dropped_since_commit: u64,
    /// Re-dispatch RNG stream position (the only cross-commit RNG state
    /// the protocol owns — every other stream is keyed by structural
    /// coordinates and needs no position tracking).
    pub redispatches: u64,
}

/// The transport-agnostic buffered-commit state machine. See the module
/// docs for the protocol it enforces.
#[derive(Debug)]
pub struct CommitPlanner {
    seed: u64,
    n_nodes: usize,
    buffer_size: usize,
    max_staleness: usize,
    /// Server version = commits so far.
    version: usize,
    /// Sampled-set size of the current version (slot base for
    /// re-dispatches). Always `r` with the built-in sampler.
    wave_len: usize,
    /// `begin_version` pending for the current version?
    awaiting_wave: bool,
    in_flight: Vec<JobKey>,
    buffer: Vec<Buffered>,
    dropped_total: u64,
    dropped_since_commit: u64,
    /// Stream counter for re-dispatch node draws after a drop.
    redispatches: u64,
}

impl CommitPlanner {
    /// Build from a validated experiment config (resolves
    /// `effective_buffer_size`).
    pub fn new(cfg: &ExperimentConfig) -> crate::Result<Self> {
        Self::from_parts(
            cfg.seed,
            cfg.n_nodes,
            cfg.r,
            cfg.effective_buffer_size(),
            cfg.max_staleness,
        )
    }

    /// Build from raw protocol knobs (what the property tests use).
    pub fn from_parts(
        seed: u64,
        n_nodes: usize,
        r: usize,
        buffer_size: usize,
        max_staleness: usize,
    ) -> crate::Result<Self> {
        anyhow::ensure!(
            (1..=r).contains(&buffer_size),
            "buffer_size {} must be in 1..=r={}",
            buffer_size,
            r
        );
        anyhow::ensure!(r <= n_nodes, "r={r} must be <= n_nodes={n_nodes}");
        Ok(CommitPlanner {
            seed,
            n_nodes,
            buffer_size,
            max_staleness,
            version: 0,
            wave_len: 0,
            awaiting_wave: true,
            in_flight: Vec::new(),
            buffer: Vec::new(),
            dropped_total: 0,
            dropped_since_commit: 0,
            redispatches: 0,
        })
    }

    /// Server version (= commits so far).
    pub fn version(&self) -> usize {
        self.version
    }

    /// Jobs dispatched but not yet arrived.
    pub fn in_flight(&self) -> usize {
        self.in_flight.len()
    }

    /// Uploads arrived but not yet committed.
    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    /// Total stale uploads dropped so far in this run.
    pub fn dropped(&self) -> u64 {
        self.dropped_total
    }

    /// The resolved commit threshold.
    pub fn buffer_size(&self) -> usize {
        self.buffer_size
    }

    /// `(node, version, slot)` of every dispatched-but-unarrived job.
    /// Transports use this to retire a dead worker's jobs
    /// ([`PlannerEvent::CapacityFreed`]) and to re-send in-flight work
    /// after a checkpoint resume.
    pub fn in_flight_jobs(&self) -> Vec<(usize, usize, usize)> {
        self.in_flight
            .iter()
            .map(|j| (j.node, j.version, j.slot))
            .collect()
    }

    /// Snapshot the complete planner state (see [`PlannerState`]).
    pub fn export_state(&self) -> PlannerState {
        PlannerState {
            seed: self.seed,
            n_nodes: self.n_nodes,
            buffer_size: self.buffer_size,
            max_staleness: self.max_staleness,
            version: self.version,
            wave_len: self.wave_len,
            awaiting_wave: self.awaiting_wave,
            in_flight: self.in_flight_jobs(),
            buffer: self
                .buffer
                .iter()
                .map(|b| (b.node, b.version, b.slot, b.enc.clone()))
                .collect(),
            dropped_total: self.dropped_total,
            dropped_since_commit: self.dropped_since_commit,
            redispatches: self.redispatches,
        }
    }

    /// Rebuild a planner mid-stream from an [`CommitPlanner::export_state`]
    /// snapshot: the restored planner emits the identical continuation of
    /// decisions for the identical continuation of events.
    pub fn from_state(st: PlannerState) -> crate::Result<Self> {
        anyhow::ensure!(
            st.buffer_size >= 1 && st.n_nodes >= 1,
            "planner state has degenerate knobs (buffer_size={}, n_nodes={})",
            st.buffer_size,
            st.n_nodes
        );
        anyhow::ensure!(
            st.buffer.len() < st.buffer_size,
            "planner state buffers {} uploads at threshold {} — a full \
             buffer must have committed before the snapshot",
            st.buffer.len(),
            st.buffer_size
        );
        Ok(CommitPlanner {
            seed: st.seed,
            n_nodes: st.n_nodes,
            buffer_size: st.buffer_size,
            max_staleness: st.max_staleness,
            version: st.version,
            wave_len: st.wave_len,
            awaiting_wave: st.awaiting_wave,
            in_flight: st
                .in_flight
                .into_iter()
                .map(|(node, version, slot)| JobKey { node, version, slot })
                .collect(),
            buffer: st
                .buffer
                .into_iter()
                .map(|(node, version, slot, enc)| Buffered { node, version, slot, enc })
                .collect(),
            dropped_total: st.dropped_total,
            dropped_since_commit: st.dropped_since_commit,
            redispatches: st.redispatches,
        })
    }

    /// Start the current version's refill wave over the sampled set
    /// `sampled` (in sampling order): the whole set at version 0 (`r`
    /// jobs in flight from the first instant), then `buffer_size` jobs
    /// per commit — exactly what the previous commit consumed — so `r`
    /// jobs stay in flight at every instant. Returns the wave's
    /// [`Decision::Dispatch`]es; call exactly once per version.
    pub fn begin_version(&mut self, sampled: &[usize]) -> crate::Result<Vec<Decision>> {
        anyhow::ensure!(
            self.awaiting_wave,
            "begin_version called twice for version {}",
            self.version
        );
        let wave = if self.version == 0 { sampled.len() } else { self.buffer_size };
        anyhow::ensure!(wave <= sampled.len(), "sampled set smaller than wave");
        self.wave_len = sampled.len();
        let mut decisions = Vec::with_capacity(wave);
        for (slot, &node) in sampled[..wave].iter().enumerate() {
            anyhow::ensure!(
                !self.live_at(node, self.version),
                "duplicate (node={node}, version={}) job in refill wave",
                self.version
            );
            self.in_flight.push(JobKey { node, version: self.version, slot });
            decisions.push(Decision::Dispatch { node, version: self.version, slot });
        }
        self.awaiting_wave = false;
        Ok(decisions)
    }

    /// Feed one event; returns the decisions it triggers, in execution
    /// order (a stale arrival yields `[Drop, Dispatch]`; a buffer-filling
    /// arrival yields `[Commit]`).
    pub fn on_event(&mut self, event: PlannerEvent) -> crate::Result<Vec<Decision>> {
        match event {
            PlannerEvent::UploadArrived { node, version, enc } => {
                self.on_upload(node, version, enc)
            }
            PlannerEvent::CapacityFreed { node, version } => {
                self.retire(node, version)?;
                Ok(vec![self.redispatch()?])
            }
        }
    }

    /// Remove a dispatched-but-undelivered job from the in-flight set
    /// (the `CapacityFreed` path); errors if no such job is live.
    fn retire(&mut self, node: usize, version: usize) -> crate::Result<usize> {
        let idx = self
            .in_flight
            .iter()
            .position(|j| j.node == node && j.version == version)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "CapacityFreed for a job that is not in flight \
                     (node={node}, version={version})"
                )
            })?;
        Ok(self.in_flight.swap_remove(idx).slot)
    }

    /// Final drain: surface whatever is buffered (fewer than
    /// `buffer_size` uploads) without bumping the version. The
    /// [`RoundEngine`](super::RoundEngine) never needs this — commits
    /// consume exact buffers — but custom drivers that stop mid-buffer
    /// use it to not lose arrived work.
    pub fn drain(&mut self) -> Vec<Upload> {
        let mut batch = std::mem::take(&mut self.buffer);
        batch.sort_by(|a, b| a.version.cmp(&b.version).then(a.slot.cmp(&b.slot)));
        batch
            .into_iter()
            .map(|b| Upload {
                node: b.node,
                origin_round: b.version,
                staleness: self.version - b.version,
                enc: b.enc,
                mass: 1.0,
            })
            .collect()
    }

    fn live_at(&self, node: usize, version: usize) -> bool {
        self.in_flight
            .iter()
            .any(|j| j.node == node && j.version == version)
            || self
                .buffer
                .iter()
                .any(|b| b.node == node && b.version == version)
    }

    fn on_upload(
        &mut self,
        node: usize,
        version: usize,
        enc: Encoded,
    ) -> crate::Result<Vec<Decision>> {
        let idx = self
            .in_flight
            .iter()
            .position(|j| j.node == node && j.version == version)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "upload for unknown or already-arrived job (node={node}, \
                     version={version}) — the (node, version) invariant forbids \
                     duplicates"
                )
            })?;
        let slot = self.in_flight.swap_remove(idx).slot;
        let staleness = self.version.checked_sub(version).ok_or_else(|| {
            anyhow::anyhow!(
                "upload from future version {version} at server version {}",
                self.version
            )
        })?;
        if staleness > self.max_staleness {
            // Too stale: discard, re-dispatch the freed capacity on the
            // current model. The transport executes the replacement at
            // the drop's arrival instant (or immediately, on real
            // sockets), keeping r jobs in flight.
            self.dropped_total += 1;
            self.dropped_since_commit += 1;
            return Ok(vec![Decision::Drop { node, staleness }, self.redispatch()?]);
        }
        self.buffer.push(Buffered { node, version, slot, enc });
        if self.buffer.len() < self.buffer_size {
            return Ok(Vec::new());
        }
        // Commit: canonical aggregation order is (origin version, slot) —
        // for a full-barrier buffer this is exactly S_k in sampling order.
        let mut batch = std::mem::take(&mut self.buffer);
        batch.sort_by(|a, b| a.version.cmp(&b.version).then(a.slot.cmp(&b.slot)));
        let commit_version = self.version;
        let uploads = batch
            .into_iter()
            .map(|b| Upload {
                node: b.node,
                origin_round: b.version,
                staleness: commit_version - b.version,
                enc: b.enc,
                mass: 1.0,
            })
            .collect();
        self.version += 1;
        self.awaiting_wave = true;
        let dropped = self.dropped_since_commit;
        self.dropped_since_commit = 0;
        Ok(vec![Decision::Commit { uploads, dropped }])
    }

    /// Pick a replacement node for one freed unit of capacity. The node
    /// draw comes from a dedicated deterministic stream keyed off the run
    /// seed; nodes that already hold a live job at the current version
    /// are skipped (the no-duplicate invariant). A free node always
    /// exists on the built-in transports: at most `r − 1` jobs are live
    /// at this point and `r ≤ n`.
    fn redispatch(&mut self) -> crate::Result<Decision> {
        let mut rng = Rng::from_coords(self.seed, &[5, self.redispatches]);
        self.redispatches += 1;
        let start = rng.gen_range(0, self.n_nodes);
        let node = (0..self.n_nodes)
            .map(|i| (start + i) % self.n_nodes)
            .find(|&cand| !self.live_at(cand, self.version))
            .ok_or_else(|| {
                anyhow::anyhow!("no free node to re-dispatch after stale drop")
            })?;
        // Slots after the wave keep replacement uploads ordered
        // deterministically behind the wave's in any later batch.
        let slot = self.wave_len + self.redispatches as usize;
        self.in_flight.push(JobKey { node, version: self.version, slot });
        Ok(Decision::Dispatch { node, version: self.version, slot })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{CodecSpec, UpdateCodec};

    fn enc() -> Encoded {
        let codec = CodecSpec::qsgd(1).build().unwrap();
        codec.encode(&[0.25, -0.5, 1.0, 0.125], &mut Rng::seed_from_u64(7))
    }

    fn planner(r: usize, b: usize, max_s: usize) -> CommitPlanner {
        CommitPlanner::from_parts(9, 8, r, b, max_s).unwrap()
    }

    #[test]
    fn wave_zero_dispatches_full_set_then_buffer_size_refills() {
        let mut p = planner(4, 2, 8);
        let d0 = p.begin_version(&[0, 1, 2, 3]).unwrap();
        assert_eq!(d0.len(), 4);
        assert_eq!(p.in_flight(), 4);
        // Two arrivals commit; refill wave is buffer_size jobs.
        assert!(p.on_event(PlannerEvent::UploadArrived { node: 0, version: 0, enc: enc() })
            .unwrap()
            .is_empty());
        let out = p
            .on_event(PlannerEvent::UploadArrived { node: 1, version: 0, enc: enc() })
            .unwrap();
        assert!(matches!(&out[..], [Decision::Commit { uploads, dropped: 0 }]
            if uploads.len() == 2));
        assert_eq!(p.version(), 1);
        let d1 = p.begin_version(&[4, 5, 6, 7]).unwrap();
        assert_eq!(d1.len(), 2);
        assert_eq!(p.in_flight(), 4, "r jobs stay in flight");
    }

    #[test]
    fn duplicate_arrival_is_rejected() {
        let mut p = planner(2, 2, 8);
        p.begin_version(&[3, 5]).unwrap();
        p.on_event(PlannerEvent::UploadArrived { node: 3, version: 0, enc: enc() })
            .unwrap();
        let err = p
            .on_event(PlannerEvent::UploadArrived { node: 3, version: 0, enc: enc() })
            .unwrap_err();
        assert!(err.to_string().contains("invariant"), "{err}");
    }

    #[test]
    fn stale_upload_drops_and_redispatches_at_current_version() {
        let mut p = planner(2, 1, 0);
        p.begin_version(&[0, 1]).unwrap();
        // First arrival commits (buffer 1); node 1's job is now stale.
        let out = p
            .on_event(PlannerEvent::UploadArrived { node: 0, version: 0, enc: enc() })
            .unwrap();
        assert!(matches!(&out[..], [Decision::Commit { .. }]));
        p.begin_version(&[2, 3]).unwrap();
        let out = p
            .on_event(PlannerEvent::UploadArrived { node: 1, version: 0, enc: enc() })
            .unwrap();
        match &out[..] {
            [Decision::Drop { node: 1, staleness: 1 }, Decision::Dispatch { version: 1, .. }] => {}
            other => panic!("unexpected decisions {other:?}"),
        }
        assert_eq!(p.dropped(), 1);
    }

    #[test]
    fn begin_version_twice_is_rejected() {
        let mut p = planner(2, 2, 8);
        p.begin_version(&[0, 1]).unwrap();
        assert!(p.begin_version(&[0, 1]).is_err());
    }

    #[test]
    fn exported_state_resumes_with_identical_decisions() {
        // Drive a planner mid-protocol, snapshot it, rebuild, then feed
        // both the identical continuation: decisions must match exactly.
        let mut a = planner(4, 2, 1);
        a.begin_version(&[0, 1, 2, 3]).unwrap();
        a.on_event(PlannerEvent::UploadArrived { node: 1, version: 0, enc: enc() })
            .unwrap();
        a.on_event(PlannerEvent::UploadArrived { node: 3, version: 0, enc: enc() })
            .unwrap();
        a.begin_version(&[4, 5, 6, 7]).unwrap();
        let snap = a.export_state();
        let mut b = CommitPlanner::from_state(snap.clone()).unwrap();
        assert_eq!(b.version(), a.version());
        assert_eq!(b.in_flight_jobs(), a.in_flight_jobs());
        let continuation = |p: &mut CommitPlanner| -> Vec<String> {
            let mut log = Vec::new();
            for (node, version) in [(0usize, 0usize), (4, 1), (2, 0)] {
                for d in p
                    .on_event(PlannerEvent::UploadArrived { node, version, enc: enc() })
                    .unwrap()
                {
                    log.push(format!("{d:?}").split('{').next().unwrap().to_string());
                }
                log.push(format!("v={} inflight={}", p.version(), p.in_flight()));
            }
            log
        };
        assert_eq!(continuation(&mut a), continuation(&mut b));
        assert_eq!(a.dropped(), b.dropped());
        // A snapshot claiming a full (uncommitted) buffer is corrupt.
        let mut bad = snap;
        bad.buffer = vec![
            (0, 0, 0, enc()),
            (1, 0, 1, enc()),
        ];
        assert!(CommitPlanner::from_state(bad).is_err());
    }

    #[test]
    fn drain_surfaces_partial_buffer_without_version_bump() {
        let mut p = planner(4, 3, 8);
        p.begin_version(&[0, 1, 2, 3]).unwrap();
        p.on_event(PlannerEvent::UploadArrived { node: 2, version: 0, enc: enc() })
            .unwrap();
        let drained = p.drain();
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].node, 2);
        assert_eq!(p.version(), 0);
        assert_eq!(p.buffered(), 0);
    }
}
