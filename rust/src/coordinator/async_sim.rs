//! [`AsyncSim`]: the buffered-async (FedBuff-style) simulated transport —
//! a **virtual-time event source** over the shared
//! [`CommitPlanner`](super::commit_loop::CommitPlanner) commit core.
//!
//! The synchronous [`InProcess`](super::InProcess) barrier charges every
//! round the *slowest* sampled node's compute time — one straggler stalls
//! all of `S_k`, exactly the systems bottleneck FedPAQ's partial
//! participation is meant to relieve. The buffered-async protocol removes
//! the barrier; since the refactor onto the event-driven commit core, this
//! module owns only the *time* half of it:
//!
//! * Every dispatched node finishes its τ local steps at its own
//!   [`CostModel::node_compute_time`] draw; uploads land in a server-side
//!   queue ordered by **virtual completion time** (a discrete-event
//!   simulation over the §5 cost model).
//! * Each arrival is fed to the [`CommitPlanner`] as an
//!   [`UploadArrived`](super::commit_loop::PlannerEvent) event; the
//!   planner owns every protocol decision — when to commit
//!   (`buffer_size` uploads in), what to drop (`staleness >
//!   max_staleness`), and which node to re-dispatch on freed capacity,
//!   never duplicating a `(node, version)` job. This transport merely
//!   executes the returned [`Decision`]s on the virtual clock.
//! * Committed batches are averaged under the config's
//!   [`StalenessRule`](super::aggregate::StalenessRule) by the engine.
//!
//! The same planner drives [`crate::net::TcpAsync`] over real sockets —
//! identical protocol, real arrival order, wall-clock time.
//!
//! ## Time accounting
//!
//! Per commit the transport reports `compute_time` = (arrival of the
//! buffer-filling upload) − (previous commit, post-uplink) and
//! `comm_time` = Σ committed bits / BW (the batch serializes through the
//! base station exactly as in §5). Dropped-stale uploads are charged no
//! uplink time — the simulation models them as discarded, a deliberate
//! simplification documented here so the tradeoff curves read correctly.
//!
//! ## Exact synchronous degeneration
//!
//! With `buffer_size == |S_k|` and `max_staleness == 0`, every commit
//! waits for exactly the wave it dispatched, the trigger arrival is the
//! wave's straggler (`max` over `S_k`), the batch sorts back into
//! sampling order, and every weight is 1 — the run is **bit-identical**
//! to [`InProcess`](super::InProcess) (asserted by
//! `rust/tests/async_rounds.rs`, which also pins this refactor to the
//! pre-planner RunResults).
//!
//! ## O(active) scaling contract
//!
//! Per-round cost is a function of the *active* set (`r` in-flight jobs
//! plus the commit batch), never of the cohort size `n_nodes`: the
//! in-flight queue is an indexed [`EventQueue`] (binary heap keyed on the
//! total order `(finish, version, slot, node)` — pop order bit-identical
//! to the historical linear scan, pinned by
//! `rust/tests/prop_event_queue.rs`), node sampling is Floyd's O(r)
//! algorithm, shards are arithmetic ranges and straggler draws are pure
//! functions of `(seed, node, version)`. Resident state is O(r + dataset)
//! — with `dataset_cap` set, 10^6–10^7-client cohorts fit in memory. See
//! `docs/OPERATIONS.md` § "Scaling to millions of simulated clients".

use super::commit_loop::{CommitPlanner, Decision, PlannerEvent};
use super::local::GatherBufs;
use super::transport::{CommitTiming, ModelFrame, RoundCtx, RoundOutcome, Transport, World};
use crate::config::ExperimentConfig;
use crate::data::{FederatedDataset, Partition};
use crate::model::Engine;
use crate::quant::{Encoded, UpdateCodec};
use crate::simtime::{CostModel, EventKey, EventQueue};
use std::sync::Arc;

/// The buffered-async simulated transport. See the module docs.
#[derive(Debug, Default)]
pub struct AsyncSim {
    preset: Option<(Arc<FederatedDataset>, Partition)>,
    world: Option<World>,
    bufs: GatherBufs,
    cost: Option<CostModel>,
    /// Virtual clock: time of the last commit, uplink included.
    now: f64,
    planner: Option<CommitPlanner>,
    /// In-flight jobs, indexed by arrival key: each entry is the upload
    /// `enc`, already computed at dispatch (the *result* depends only on
    /// the dispatch model/seeds; only its arrival time is simulated).
    /// `slot` in the key is the planner's canonical batch position,
    /// reused as the deterministic arrival tie-break.
    jobs: EventQueue<Encoded>,
    /// `(node, version)` dispatches performed during the current `round`
    /// call, in dispatch order — handed to the engine in the commit's
    /// [`RoundOutcome`] for downlink-bits accounting.
    dispatched: Vec<(usize, usize)>,
    events: crate::ops::EventSink,
}

impl AsyncSim {
    pub fn new() -> Self {
        Self::default()
    }

    /// Seed with an already-built world (same contract as
    /// [`InProcess::with_world`](super::InProcess::with_world)).
    pub fn with_world(data: Arc<FederatedDataset>, partition: Partition) -> Self {
        AsyncSim { preset: Some((data, partition)), ..Self::default() }
    }

    /// Total stale uploads dropped so far in this run.
    pub fn dropped(&self) -> u64 {
        self.planner.as_ref().map_or(0, CommitPlanner::dropped)
    }

    /// Execute one planner `Dispatch` decision on the virtual clock: run
    /// the node's local work now (the upload is a pure function of the
    /// dispatch model/seeds) and schedule its arrival at `at + compute`.
    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        &mut self,
        codec: &dyn UpdateCodec,
        engine: &mut dyn Engine,
        node: usize,
        version: usize,
        slot: usize,
        at: f64,
        ctx: &RoundCtx<'_>,
    ) -> crate::Result<()> {
        let w = self.world.as_ref().expect("dispatch before setup");
        let cost = self.cost.as_ref().expect("dispatch before setup");
        let enc = w.node_round(
            codec,
            engine,
            node,
            version,
            &ctx.frame.params,
            ctx.lrs,
            &mut self.bufs,
        )?;
        let finish = at + cost.node_compute_time(node, version, w.cfg.tau, engine.batch());
        self.events.emit(
            "job_dispatched",
            vec![
                ("finish", crate::util::json::Json::num(finish)),
                ("node", crate::util::json::Json::num(node as f64)),
                ("t", crate::util::json::Json::num(at)),
                ("version", crate::util::json::Json::num(version as f64)),
            ],
        );
        self.jobs.push(EventKey { finish, version, slot, node }, enc);
        self.dispatched.push((node, version));
        Ok(())
    }
}

impl Transport for AsyncSim {
    fn name(&self) -> &'static str {
        "async-sim"
    }

    fn virtual_time(&self) -> bool {
        true
    }

    fn buffered_async(&self) -> bool {
        true
    }

    fn setup(
        &mut self,
        cfg: &ExperimentConfig,
        engine: &mut dyn Engine,
    ) -> crate::Result<()> {
        self.world = Some(World::build(self.preset.take(), cfg, engine)?);
        // Same cost model the engine builds for barrier transports: equal
        // seeds draw identical per-(node, version) straggler times.
        let p = engine.kind().param_count();
        self.cost =
            Some(CostModel::with_ratio(cfg.ratio, p, cfg.seed).with_dist(cfg.straggler));
        self.planner = Some(CommitPlanner::new(cfg)?);
        self.now = 0.0;
        self.jobs.clear();
        Ok(())
    }

    fn round(
        &mut self,
        ctx: &RoundCtx<'_>,
        codec: &dyn UpdateCodec,
        engine: &mut dyn Engine,
    ) -> crate::Result<RoundOutcome> {
        anyhow::ensure!(self.world.is_some(), "AsyncSim::round before setup");
        let planner = self.planner.as_mut().expect("planner built in setup");
        anyhow::ensure!(
            ctx.round == planner.version(),
            "AsyncSim expects sequential rounds: got {} at version {}",
            ctx.round,
            planner.version()
        );
        // Refill wave at the current model (planner decides its size:
        // the whole sampled set at version 0, then `buffer_size` jobs per
        // commit, keeping r jobs in flight).
        self.dispatched.clear();
        let wave = planner.begin_version(ctx.nodes)?;
        let now = self.now;
        for d in wave {
            match d {
                Decision::Dispatch { node, version, slot } => {
                    self.dispatch(codec, engine, node, version, slot, now, ctx)?
                }
                other => anyhow::bail!("unexpected wave decision {other:?}"),
            }
        }

        // Discrete-event loop: absorb arrivals until the planner commits.
        // The queue pops the minimum `(finish, version, slot, node)` —
        // total order, so event processing is deterministic even under
        // exact time ties.
        loop {
            let (key, enc) = self
                .jobs
                .pop()
                .ok_or_else(|| anyhow::anyhow!("async sim starved: no jobs in flight"))?;
            let arrival = key.finish;
            self.events.emit(
                "upload_arrived",
                vec![
                    ("node", crate::util::json::Json::num(key.node as f64)),
                    ("t", crate::util::json::Json::num(arrival)),
                    ("version", crate::util::json::Json::num(key.version as f64)),
                ],
            );
            let decisions =
                self.planner.as_mut().unwrap().on_event(PlannerEvent::UploadArrived {
                    node: key.node,
                    version: key.version,
                    enc,
                })?;
            for d in decisions {
                match d {
                    // Discarded stale upload: charged no uplink time (see
                    // the module docs); its replacement dispatches at the
                    // drop's arrival instant.
                    Decision::Drop { node, staleness } => {
                        self.events.emit(
                            "upload_dropped",
                            vec![
                                ("node", crate::util::json::Json::num(node as f64)),
                                (
                                    "staleness",
                                    crate::util::json::Json::num(staleness as f64),
                                ),
                                ("t", crate::util::json::Json::num(arrival)),
                            ],
                        );
                    }
                    Decision::Dispatch { node, version, slot } => {
                        self.dispatch(codec, engine, node, version, slot, arrival, ctx)?
                    }
                    Decision::Commit { uploads, dropped } => {
                        let cost = self.cost.as_ref().unwrap();
                        let comm_time = cost.round_comm_time(
                            &uploads.iter().map(|u| u.enc.bits()).collect::<Vec<_>>(),
                        );
                        // Arrivals can predate the previous commit's
                        // uplink completing (they were in flight during
                        // it): the clock stays monotone.
                        let commit_start = arrival.max(self.now);
                        let compute_time = commit_start - self.now;
                        self.now = commit_start + comm_time;
                        return Ok(RoundOutcome {
                            uploads,
                            timing: Some(CommitTiming { compute_time, comm_time }),
                            dropped,
                            dispatches: std::mem::take(&mut self.dispatched),
                            uplink_bits: None,
                        });
                    }
                }
            }
        }
    }

    fn shutdown(&mut self) -> crate::Result<()> {
        // Structured counterpart of the stderr note below, so operators
        // tailing the JSONL event stream see the run-total drop count
        // without scraping stderr.
        self.events.emit(
            "transport_shutdown",
            vec![(
                "dropped_total",
                crate::util::json::Json::num(self.dropped() as f64),
            )],
        );
        if self.dropped() > 0 {
            eprintln!(
                "[async-sim] run complete: {} stale upload(s) dropped",
                self.dropped()
            );
        }
        self.jobs.clear();
        Ok(())
    }

    fn set_events(&mut self, events: crate::ops::EventSink) {
        self.events = events;
    }

    /// Full async snapshot: planner, clock, and every in-flight job with
    /// its already-computed upload — the upload is a pure function of the
    /// dispatch-time model, which no longer exists after a resume, so the
    /// bytes themselves are checkpointed. This is what makes simulator
    /// resume *fully general*: any post-commit instant is resumable
    /// bit-identically, stragglers in flight and all.
    fn export_state(&self) -> crate::Result<Option<crate::ops::TransportState>> {
        let planner = self
            .planner
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("AsyncSim::export_state before setup"))?;
        // Canonical ordering: jobs serialize sorted by the event-queue
        // key, so two equivalent sims (e.g. either side of a kill/resume,
        // or heap internals permuted by a different insertion history)
        // always produce byte-identical checkpoints.
        let jobs = self
            .jobs
            .sorted()
            .into_iter()
            .map(|(key, enc)| crate::ops::JobState {
                node: key.node,
                version: key.version,
                slot: key.slot,
                finish: key.finish,
                enc: enc.clone(),
            })
            .collect();
        Ok(Some(crate::ops::TransportState::Async {
            planner: planner.export_state(),
            now: self.now,
            jobs,
        }))
    }

    fn restore_state(
        &mut self,
        state: crate::ops::TransportState,
    ) -> crate::Result<()> {
        anyhow::ensure!(self.world.is_some(), "AsyncSim::restore_state before setup");
        let crate::ops::TransportState::Async { planner, now, jobs } = state else {
            anyhow::bail!(
                "checkpoint holds tree-transport state; resume it with a tree \
                 leader (--edge-leaders), not the simulator"
            );
        };
        self.planner = Some(CommitPlanner::from_state(planner)?);
        self.now = now;
        self.jobs.clear();
        for j in jobs {
            self.jobs.push(
                EventKey { finish: j.finish, version: j.version, slot: j.slot, node: j.node },
                j.enc,
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelKind, RustEngine};
    use crate::opt::LrSchedule;
    use crate::quant::CodecSpec;

    fn async_cfg() -> ExperimentConfig {
        ExperimentConfig {
            name: "async-test".into(),
            model: "logreg".into(),
            dataset: crate::data::DatasetKind::Mnist08,
            n_nodes: 8,
            per_node: 40,
            r: 4,
            tau: 2,
            t_total: 8,
            codec: CodecSpec::qsgd(2),
            down_codec: None,
            lr: LrSchedule::Const { eta: 0.3 },
            ratio: 100.0,
            seed: 11,
            eval_every: 1,
            engine: crate::config::EngineKind::Rust,
            partition: crate::data::PartitionKind::Iid,
            async_rounds: true,
            buffer_size: 2,
            max_staleness: 4,
            staleness_rule: Default::default(),
            agg_shards: 1,
            straggler: Default::default(),
            dataset_cap: 0,
        }
    }

    fn engine() -> RustEngine {
        RustEngine::new(ModelKind::LogReg { d: 784, l2: 0.05 }, 10, 320).unwrap()
    }

    #[test]
    fn commits_fill_the_buffer_and_report_monotone_time() {
        let cfg = async_cfg();
        let codec = cfg.codec.build().unwrap();
        let mut eng = engine();
        let params = eng.init_params().unwrap();
        let mut t = AsyncSim::new();
        t.setup(&cfg, &mut eng).unwrap();
        let mut clock = 0.0;
        for k in 0..4 {
            let nodes = crate::coordinator::sampler::sample_nodes(
                cfg.n_nodes, cfg.r, cfg.seed, k,
            );
            let lrs = vec![0.3f32; cfg.tau];
            let frame = ModelFrame::raw(k, params.clone());
            let ctx = RoundCtx { round: k, nodes: &nodes, frame: &frame, lrs: &lrs };
            let out = t.round(&ctx, codec.as_ref(), &mut eng).unwrap();
            assert_eq!(out.uploads.len(), 2, "commit k={k}");
            // Every dispatch of this commit is reported, at this version.
            assert!(!out.dispatches.is_empty() || k > 0);
            assert!(out.dispatches.iter().all(|&(_, v)| v == k));
            let timing = out.timing.expect("async sim owns its timing");
            assert!(timing.compute_time >= 0.0 && timing.comm_time > 0.0);
            clock += timing.compute_time + timing.comm_time;
            for u in &out.uploads {
                assert!(u.staleness <= cfg.max_staleness);
                assert_eq!(u.staleness, k - u.origin_round);
            }
        }
        assert!(clock > 0.0);
        // Steady state: r jobs in flight after every commit+refill cycle
        // (wave 0 dispatched r, each commit consumed and refilled b).
        assert_eq!(t.jobs.len(), cfg.r - cfg.buffer_size);
        t.shutdown().unwrap();
    }

    #[test]
    fn non_sequential_round_is_rejected() {
        let cfg = async_cfg();
        let codec = cfg.codec.build().unwrap();
        let mut eng = engine();
        let params = eng.init_params().unwrap();
        let mut t = AsyncSim::new();
        t.setup(&cfg, &mut eng).unwrap();
        let nodes = vec![0, 1, 2, 3];
        let lrs = vec![0.3f32; cfg.tau];
        let frame = ModelFrame::raw(3, params.clone());
        let ctx = RoundCtx { round: 3, nodes: &nodes, frame: &frame, lrs: &lrs };
        assert!(t.round(&ctx, codec.as_ref(), &mut eng).is_err());
    }

    #[test]
    fn zero_staleness_cap_drops_and_redispatches() {
        // b < r with max_staleness = 0: the leftover wave-0 stragglers
        // must be dropped at their (stale) arrival and replaced, and the
        // run must keep committing.
        let cfg = ExperimentConfig { max_staleness: 0, ..async_cfg() };
        let codec = cfg.codec.build().unwrap();
        let mut eng = engine();
        let params = eng.init_params().unwrap();
        let mut t = AsyncSim::new();
        t.setup(&cfg, &mut eng).unwrap();
        let lrs = vec![0.3f32; cfg.tau];
        let mut committed = std::collections::HashSet::new();
        let mut dropped_seen = 0;
        for k in 0..4 {
            let nodes = crate::coordinator::sampler::sample_nodes(
                cfg.n_nodes, cfg.r, cfg.seed, k,
            );
            let frame = ModelFrame::raw(k, params.clone());
            let ctx = RoundCtx { round: k, nodes: &nodes, frame: &frame, lrs: &lrs };
            let out = t.round(&ctx, codec.as_ref(), &mut eng).unwrap();
            assert_eq!(out.uploads.len(), cfg.buffer_size);
            assert!(out.uploads.iter().all(|u| u.staleness == 0));
            dropped_seen += out.dropped;
            for u in &out.uploads {
                // No (node, version) pair may ever be aggregated twice —
                // re-dispatch must skip nodes already holding a job at
                // the current version.
                assert!(
                    committed.insert((u.node, u.origin_round)),
                    "duplicate upload for node {} at version {}",
                    u.node,
                    u.origin_round
                );
            }
        }
        assert!(t.dropped() > 0, "wave-0 stragglers should have been dropped");
        // Per-commit telemetry sums to the run total.
        assert_eq!(dropped_seen, t.dropped());
    }
}
