//! [`AsyncSim`]: the buffered-async (FedBuff-style) simulated transport.
//!
//! The synchronous [`InProcess`](super::InProcess) barrier charges every
//! round the *slowest* sampled node's compute time — one straggler stalls
//! all of `S_k`, exactly the systems bottleneck FedPAQ's partial
//! participation is meant to relieve. `AsyncSim` removes the barrier:
//!
//! * Every dispatched node finishes its τ local steps at its own
//!   [`CostModel::node_compute_time`] draw; uploads land in a server-side
//!   buffer ordered by **virtual completion time** (a discrete-event
//!   simulation over the §5 cost model).
//! * The server **commits** — averages the buffer into the model and bumps
//!   its version `k` — as soon as [`buffer_size`](ExperimentConfig::buffer_size)
//!   uploads arrive. Stragglers keep running across commits; their uploads
//!   surface in later commit batches carrying `staleness = k − k_origin`.
//! * Uploads staler than [`max_staleness`](ExperimentConfig::max_staleness)
//!   are dropped at arrival (the node is immediately re-dispatched on the
//!   current model, keeping `r` jobs in flight), and committed batches are
//!   averaged under the config's
//!   [`StalenessRule`](super::aggregate::StalenessRule) by the engine.
//!
//! ## Scheduling model
//!
//! Version 0 dispatches the full sampled set `S_0` (`r` jobs). Each commit
//! consumes exactly `buffer_size` uploads and refills the same number of
//! jobs — the first `buffer_size` entries of `S_{k+1}` (a partial
//! Fisher–Yates prefix, itself a uniform sample) — so exactly `r` jobs are
//! in flight at every instant, matching FedBuff's concurrency parameter
//! `M_c = r`. A virtual node sampled into overlapping waves holds several
//! outstanding jobs; each job's batch/quantizer RNG streams are keyed by
//! `(seed, node, version)`, the same coordinates the synchronous path
//! uses for round `k`.
//!
//! ## Time accounting
//!
//! Per commit the transport reports `compute_time` = (arrival of the
//! buffer-filling upload) − (previous commit, post-uplink) and
//! `comm_time` = Σ committed bits / BW (the batch serializes through the
//! base station exactly as in §5). Dropped-stale uploads are charged no
//! uplink time — the simulation models them as discarded, a deliberate
//! simplification documented here so the tradeoff curves read correctly.
//!
//! ## Exact synchronous degeneration
//!
//! With `buffer_size == |S_k|` and `max_staleness == 0`, every commit
//! waits for exactly the wave it dispatched, the trigger arrival is the
//! wave's straggler (`max` over `S_k`), the batch sorts back into
//! sampling order, and every weight is 1 — the run is **bit-identical**
//! to [`InProcess`](super::InProcess) (asserted by
//! `rust/tests/async_rounds.rs`).

use super::local::GatherBufs;
use super::transport::{CommitTiming, RoundCtx, RoundOutcome, Transport, Upload, World};
use crate::config::ExperimentConfig;
use crate::data::{FederatedDataset, Partition};
use crate::model::Engine;
use crate::quant::{Encoded, UpdateCodec};
use crate::simtime::CostModel;
use crate::util::rng::Rng;
use std::sync::Arc;

/// One in-flight node job: dispatched at server version `origin_round`,
/// finishing at virtual time `finish` with upload `enc` already computed
/// (the *result* depends only on the dispatch model/seeds; only its
/// arrival time is simulated).
#[derive(Debug)]
struct Job {
    node: usize,
    origin_round: usize,
    /// Position within its dispatch wave — the canonical aggregation
    /// order inside a commit batch (sampling order, so the synchronous
    /// degeneration aggregates bit-identically to `InProcess`).
    slot: usize,
    finish: f64,
    enc: Encoded,
}

/// The buffered-async simulated transport. See the module docs.
#[derive(Debug, Default)]
pub struct AsyncSim {
    preset: Option<(Arc<FederatedDataset>, Partition)>,
    world: Option<World>,
    bufs: GatherBufs,
    cost: Option<CostModel>,
    /// Virtual clock: time of the last commit, uplink included.
    now: f64,
    /// Server version = commits so far; mirrors the engine's round index.
    version: usize,
    in_flight: Vec<Job>,
    /// Resolved commit threshold (`cfg.effective_buffer_size()`).
    buffer_size: usize,
    max_staleness: usize,
    /// Stale uploads dropped so far (visible in logs at shutdown).
    dropped: u64,
    /// Stream counter for re-dispatch node draws after a drop.
    redispatches: u64,
}

impl AsyncSim {
    pub fn new() -> Self {
        Self::default()
    }

    /// Seed with an already-built world (same contract as
    /// [`InProcess::with_world`](super::InProcess::with_world)).
    pub fn with_world(data: Arc<FederatedDataset>, partition: Partition) -> Self {
        AsyncSim { preset: Some((data, partition)), ..Self::default() }
    }

    /// Total stale uploads dropped so far in this run.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    fn dispatch(
        &mut self,
        codec: &dyn UpdateCodec,
        engine: &mut dyn Engine,
        node: usize,
        slot: usize,
        at: f64,
        ctx: &RoundCtx<'_>,
    ) -> crate::Result<()> {
        let w = self.world.as_ref().expect("dispatch before setup");
        let cost = self.cost.as_ref().expect("dispatch before setup");
        let enc = w.node_round(
            codec,
            engine,
            node,
            ctx.round,
            ctx.params,
            ctx.lrs,
            &mut self.bufs,
        )?;
        let finish =
            at + cost.node_compute_time(node, ctx.round, w.cfg.tau, engine.batch());
        self.in_flight.push(Job {
            node,
            origin_round: ctx.round,
            slot,
            finish,
            enc,
        });
        Ok(())
    }

    /// Pop the next upload to arrive: minimum `(finish, origin, slot,
    /// node)` — total order, so event processing is deterministic even
    /// under exact time ties.
    fn pop_next(&mut self) -> Option<Job> {
        let idx = self
            .in_flight
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.finish
                    .total_cmp(&b.finish)
                    .then(a.origin_round.cmp(&b.origin_round))
                    .then(a.slot.cmp(&b.slot))
                    .then(a.node.cmp(&b.node))
            })
            .map(|(i, _)| i)?;
        Some(self.in_flight.swap_remove(idx))
    }
}

impl Transport for AsyncSim {
    fn name(&self) -> &'static str {
        "async-sim"
    }

    fn virtual_time(&self) -> bool {
        true
    }

    fn buffered_async(&self) -> bool {
        true
    }

    fn setup(
        &mut self,
        cfg: &ExperimentConfig,
        engine: &mut dyn Engine,
    ) -> crate::Result<()> {
        self.world = Some(World::build(self.preset.take(), cfg, engine)?);
        // Same cost model the engine builds for barrier transports: equal
        // seeds draw identical per-(node, version) straggler times.
        let p = engine.kind().param_count();
        self.cost = Some(CostModel::with_ratio(cfg.ratio, p, cfg.seed));
        self.buffer_size = cfg.effective_buffer_size();
        anyhow::ensure!(
            (1..=cfg.r).contains(&self.buffer_size),
            "buffer_size {} must be in 1..=r={}",
            self.buffer_size,
            cfg.r
        );
        self.max_staleness = cfg.max_staleness;
        self.now = 0.0;
        self.version = 0;
        self.in_flight.clear();
        self.dropped = 0;
        self.redispatches = 0;
        Ok(())
    }

    fn round(
        &mut self,
        ctx: &RoundCtx<'_>,
        codec: &dyn UpdateCodec,
        engine: &mut dyn Engine,
    ) -> crate::Result<RoundOutcome> {
        anyhow::ensure!(self.world.is_some(), "AsyncSim::round before setup");
        anyhow::ensure!(
            ctx.round == self.version,
            "AsyncSim expects sequential rounds: got {} at version {}",
            ctx.round,
            self.version
        );
        // Refill wave at the current model: the whole sampled set at
        // version 0, then `buffer_size` jobs per commit (exactly what the
        // previous commit consumed), keeping r jobs in flight.
        let wave = if ctx.round == 0 {
            ctx.nodes.len()
        } else {
            self.buffer_size
        };
        anyhow::ensure!(wave <= ctx.nodes.len(), "sampled set smaller than wave");
        let now = self.now;
        for (slot, &node) in ctx.nodes[..wave].iter().enumerate() {
            self.dispatch(codec, engine, node, slot, now, ctx)?;
        }
        let n_nodes = self.world.as_ref().unwrap().cfg.n_nodes;
        let seed = self.world.as_ref().unwrap().cfg.seed;

        // Discrete-event loop: absorb arrivals until the buffer fills.
        let mut buffer: Vec<Job> = Vec::with_capacity(self.buffer_size);
        let commit_arrival;
        loop {
            let job = self
                .pop_next()
                .ok_or_else(|| anyhow::anyhow!("async sim starved: no jobs in flight"))?;
            let staleness = ctx.round - job.origin_round;
            if staleness > self.max_staleness {
                // Too stale: discard, re-dispatch the freed capacity on
                // the current model at the arrival instant. The node draw
                // comes from a dedicated deterministic stream; nodes that
                // already hold a job at this version are skipped (a
                // duplicate `(node, version)` job would replay identical
                // RNG streams and double-count that node's update). A
                // free node always exists: at most `r − 1` jobs are live
                // at this point and `r ≤ n`.
                self.dropped += 1;
                let mut rng = Rng::from_coords(seed, &[5, self.redispatches]);
                self.redispatches += 1;
                let start = rng.gen_range(0, n_nodes);
                let node = (0..n_nodes)
                    .map(|i| (start + i) % n_nodes)
                    .find(|&cand| {
                        !self
                            .in_flight
                            .iter()
                            .chain(buffer.iter())
                            .any(|j| j.node == cand && j.origin_round == ctx.round)
                    })
                    .ok_or_else(|| {
                        anyhow::anyhow!("no free node to re-dispatch after stale drop")
                    })?;
                // Slots after the wave keep replacement uploads ordered
                // deterministically behind the wave's in any later batch.
                let slot = ctx.nodes.len() + self.redispatches as usize;
                let at = job.finish;
                self.dispatch(codec, engine, node, slot, at, ctx)?;
                continue;
            }
            let finish = job.finish;
            buffer.push(job);
            if buffer.len() == self.buffer_size {
                commit_arrival = finish;
                break;
            }
        }

        // Commit: canonical aggregation order is (origin version, slot) —
        // for a full-barrier buffer this is exactly S_k in sampling order.
        buffer.sort_by(|a, b| {
            a.origin_round.cmp(&b.origin_round).then(a.slot.cmp(&b.slot))
        });
        let cost = self.cost.as_ref().unwrap();
        let comm_time = cost
            .round_comm_time(&buffer.iter().map(|j| j.enc.bits()).collect::<Vec<_>>());
        // Arrivals can predate the previous commit's uplink completing
        // (they were in flight during it): the clock stays monotone.
        let commit_start = commit_arrival.max(self.now);
        let compute_time = commit_start - self.now;
        self.now = commit_start + comm_time;
        self.version += 1;
        let uploads = buffer
            .into_iter()
            .map(|j| Upload {
                node: j.node,
                origin_round: j.origin_round,
                staleness: ctx.round - j.origin_round,
                enc: j.enc,
            })
            .collect();
        Ok(RoundOutcome {
            uploads,
            timing: Some(CommitTiming { compute_time, comm_time }),
        })
    }

    fn shutdown(&mut self) -> crate::Result<()> {
        if self.dropped > 0 {
            eprintln!(
                "[async-sim] run complete: {} stale upload(s) dropped (max_staleness={})",
                self.dropped, self.max_staleness
            );
        }
        self.in_flight.clear();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelKind, RustEngine};
    use crate::opt::LrSchedule;
    use crate::quant::CodecSpec;

    fn async_cfg() -> ExperimentConfig {
        ExperimentConfig {
            name: "async-test".into(),
            model: "logreg".into(),
            dataset: crate::data::DatasetKind::Mnist08,
            n_nodes: 8,
            per_node: 40,
            r: 4,
            tau: 2,
            t_total: 8,
            codec: CodecSpec::qsgd(2),
            lr: LrSchedule::Const { eta: 0.3 },
            ratio: 100.0,
            seed: 11,
            eval_every: 1,
            engine: crate::config::EngineKind::Rust,
            partition: crate::data::PartitionKind::Iid,
            async_rounds: true,
            buffer_size: 2,
            max_staleness: 4,
            staleness_rule: Default::default(),
            agg_shards: 1,
        }
    }

    fn engine() -> RustEngine {
        RustEngine::new(ModelKind::LogReg { d: 784, l2: 0.05 }, 10, 320).unwrap()
    }

    #[test]
    fn commits_fill_the_buffer_and_report_monotone_time() {
        let cfg = async_cfg();
        let codec = cfg.codec.build().unwrap();
        let mut eng = engine();
        let params = eng.init_params().unwrap();
        let mut t = AsyncSim::new();
        t.setup(&cfg, &mut eng).unwrap();
        let mut clock = 0.0;
        for k in 0..4 {
            let nodes = crate::coordinator::sampler::sample_nodes(
                cfg.n_nodes, cfg.r, cfg.seed, k,
            );
            let lrs = vec![0.3f32; cfg.tau];
            let ctx = RoundCtx { round: k, nodes: &nodes, params: &params, lrs: &lrs };
            let out = t.round(&ctx, codec.as_ref(), &mut eng).unwrap();
            assert_eq!(out.uploads.len(), 2, "commit k={k}");
            let timing = out.timing.expect("async sim owns its timing");
            assert!(timing.compute_time >= 0.0 && timing.comm_time > 0.0);
            clock += timing.compute_time + timing.comm_time;
            for u in &out.uploads {
                assert!(u.staleness <= cfg.max_staleness);
                assert_eq!(u.staleness, k - u.origin_round);
            }
        }
        assert!(clock > 0.0);
        // Steady state: r jobs in flight after every commit+refill cycle
        // (wave 0 dispatched r, each commit consumed and refilled b).
        assert_eq!(t.in_flight.len(), cfg.r - cfg.buffer_size);
        t.shutdown().unwrap();
    }

    #[test]
    fn non_sequential_round_is_rejected() {
        let cfg = async_cfg();
        let codec = cfg.codec.build().unwrap();
        let mut eng = engine();
        let params = eng.init_params().unwrap();
        let mut t = AsyncSim::new();
        t.setup(&cfg, &mut eng).unwrap();
        let nodes = vec![0, 1, 2, 3];
        let lrs = vec![0.3f32; cfg.tau];
        let ctx = RoundCtx { round: 3, nodes: &nodes, params: &params, lrs: &lrs };
        assert!(t.round(&ctx, codec.as_ref(), &mut eng).is_err());
    }

    #[test]
    fn zero_staleness_cap_drops_and_redispatches() {
        // b < r with max_staleness = 0: the leftover wave-0 stragglers
        // must be dropped at their (stale) arrival and replaced, and the
        // run must keep committing.
        let cfg = ExperimentConfig { max_staleness: 0, ..async_cfg() };
        let codec = cfg.codec.build().unwrap();
        let mut eng = engine();
        let params = eng.init_params().unwrap();
        let mut t = AsyncSim::new();
        t.setup(&cfg, &mut eng).unwrap();
        let lrs = vec![0.3f32; cfg.tau];
        let mut committed = std::collections::HashSet::new();
        for k in 0..4 {
            let nodes = crate::coordinator::sampler::sample_nodes(
                cfg.n_nodes, cfg.r, cfg.seed, k,
            );
            let ctx = RoundCtx { round: k, nodes: &nodes, params: &params, lrs: &lrs };
            let out = t.round(&ctx, codec.as_ref(), &mut eng).unwrap();
            assert_eq!(out.uploads.len(), cfg.buffer_size);
            assert!(out.uploads.iter().all(|u| u.staleness == 0));
            for u in &out.uploads {
                // No (node, version) pair may ever be aggregated twice —
                // re-dispatch must skip nodes already holding a job at
                // the current version.
                assert!(
                    committed.insert((u.node, u.origin_round)),
                    "duplicate upload for node {} at version {}",
                    u.node,
                    u.origin_round
                );
            }
        }
        assert!(t.dropped() > 0, "wave-0 stragglers should have been dropped");
    }
}
