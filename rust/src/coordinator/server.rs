//! The FedPAQ parameter server: a thin composition of the pluggable round
//! pipeline (codec × transport × engine), plus the [`ServerBuilder`] that
//! assembles it.
//!
//! `Server::new(cfg, engine)` keeps the historical one-call path (codec
//! from the config, in-process transport, §5 virtual time) and is
//! bit-for-bit identical to the pre-trait monolithic loop for equal
//! seeds. Every part can be swapped:
//!
//! ```ignore
//! let mut server = ServerBuilder::new(cfg)
//!     .engine(&mut engine)
//!     .codec(TopKCodec::new(100))      // any UpdateCodec impl
//!     .transport(InProcess::new())     // or net::Tcp, or your own
//!     .build()?;
//! let result = server.run()?;
//! ```

use super::async_sim::AsyncSim;
use super::engine::{EvalSlab, RoundEngine, RunResult};
use super::transport::{InProcess, Transport};
use crate::config::ExperimentConfig;
use crate::model::Engine;
use crate::quant::UpdateCodec;

/// Assembles a [`Server`] from config + engine + optional overrides.
pub struct ServerBuilder<'e> {
    cfg: ExperimentConfig,
    engine: Option<&'e mut dyn Engine>,
    codec: Option<Box<dyn UpdateCodec>>,
    transport: Option<Box<dyn Transport>>,
    control: crate::ops::RunControl,
}

impl<'e> ServerBuilder<'e> {
    pub fn new(cfg: ExperimentConfig) -> Self {
        ServerBuilder {
            cfg,
            engine: None,
            codec: None,
            transport: None,
            control: crate::ops::RunControl::default(),
        }
    }

    /// Operator controls — event sink, checkpoint cadence, forced stop,
    /// resume (see [`crate::ops::RunControl`]). Default: none of it.
    pub fn control(mut self, control: crate::ops::RunControl) -> Self {
        self.control = control;
        self
    }

    /// The engine evaluating the loss — and, for in-process transports,
    /// running the nodes' local SGD. Required.
    pub fn engine(mut self, engine: &'e mut dyn Engine) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Override the upload codec (default: built from `cfg.codec`).
    ///
    /// The config's `codec` field is rewritten to the override's
    /// [`UpdateCodec::spec`] at build time so `Server::config()` stays
    /// consistent with what actually runs. Overrides are an
    /// **in-process seam**: networked transports broadcast the config
    /// to workers, which rebuild their codec from the tagged spec — an
    /// arbitrary trait object cannot travel that way, so `build()`
    /// rejects the combination. To change codecs on a distributed run,
    /// set `cfg.codec` to a built-in spec instead.
    pub fn codec(mut self, codec: impl UpdateCodec + 'static) -> Self {
        self.codec = Some(Box::new(codec));
        self
    }

    /// Boxed-codec variant of [`ServerBuilder::codec`].
    pub fn codec_boxed(mut self, codec: Box<dyn UpdateCodec>) -> Self {
        self.codec = Some(codec);
        self
    }

    /// Compress the server→client broadcast with `spec` (sets
    /// `cfg.down_codec`): the engine ships per-commit deltas against a
    /// shared reference model instead of raw f32 — see
    /// [`super::downlink`]. Only rebuildable specs are accepted
    /// (validation runs at `build()`), since every receiver reconstructs
    /// the codec from the config.
    pub fn down_codec(mut self, spec: crate::quant::CodecSpec) -> Self {
        self.cfg.down_codec = Some(spec);
        self
    }

    /// Override the transport (default: [`InProcess`], or
    /// [`AsyncSim`] when `cfg.async_rounds` is set).
    ///
    /// The default transport shares the federated world `build()`
    /// constructs for the eval slab. An explicitly passed
    /// [`InProcess::new()`] rebuilds its own in `setup` (the dataset
    /// itself comes from the process-global cache either way); pass
    /// [`InProcess::with_world`] / [`AsyncSim::with_world`] to share one.
    pub fn transport(mut self, transport: impl Transport + 'static) -> Self {
        self.transport = Some(Box::new(transport));
        self
    }

    /// Boxed-transport variant of [`ServerBuilder::transport`].
    pub fn transport_boxed(mut self, transport: Box<dyn Transport>) -> Self {
        self.transport = Some(transport);
        self
    }

    /// Validate the config, build the federated world once, and assemble
    /// the eval slab + round engine from it.
    pub fn build(self) -> crate::Result<Server<'e>> {
        let mut cfg = self.cfg;
        if let Some(codec) = &self.codec {
            cfg.codec = codec.spec();
        }
        let cfg = cfg.validated()?;
        let engine = self
            .engine
            .ok_or_else(|| anyhow::anyhow!("ServerBuilder needs an engine"))?;
        // One world per run: the eval slab borrows it, and the default
        // in-process transport takes ownership instead of rebuilding it.
        let (data, partition) = super::engine::build_world(&cfg, engine)?;
        let slab = EvalSlab::from_world(&cfg, engine, &data, &partition)?;
        let transport = match self.transport {
            Some(t) => t,
            None if cfg.async_rounds => {
                Box::new(AsyncSim::with_world(data, partition)) as Box<dyn Transport>
            }
            None => Box::new(InProcess::with_world(data, partition)) as Box<dyn Transport>,
        };
        // An async-rounds config on a barrier transport would silently
        // run the synchronous protocol while claiming FedBuff semantics;
        // refuse the pairing instead.
        anyhow::ensure!(
            !cfg.async_rounds || transport.buffered_async(),
            "cfg.async_rounds is set but the {} transport runs full barriers — \
             use AsyncSim / net::TcpAsync (or drop the explicit transport \
             override)",
            transport.name()
        );
        // A codec override is a local trait object; transports whose
        // remote ends rebuild codecs from the broadcast config cannot
        // carry it, so workers would encode with a different codec than
        // the leader decodes with. Fail fast instead.
        anyhow::ensure!(
            self.codec.is_none() || !transport.rebuilds_codec_from_config(),
            "codec overrides are in-process only — the {} transport rebuilds \
             its codec from cfg.codec; set a built-in spec there instead",
            transport.name()
        );
        // The same transports need cfg.codec itself to be rebuildable by
        // their workers: an External tag (anywhere, including inside an
        // error-feedback wrapper) names an instance that cannot travel.
        // Reject at build time with the policy named, instead of letting
        // every worker fail at Setup.
        anyhow::ensure!(
            !transport.rebuilds_codec_from_config() || cfg.codec.rebuildable(),
            "cfg.codec {:?} contains an external codec, which the {} \
             transport's workers cannot rebuild from the broadcast config — \
             use a built-in spec (external codecs are in-process only)",
            cfg.codec,
            transport.name()
        );
        // Stateful codecs compose with buffered-async rounds, but with a
        // semantic caveat worth surfacing: error-feedback residuals are
        // debited at encode time, so an upload later dropped as too
        // stale loses its mass outright (as any codec's dropped upload
        // does) instead of being re-sent through the memory.
        if cfg.async_rounds && cfg.codec.is_stateful() && cfg.effective_buffer_size() < cfg.r
        {
            eprintln!(
                "warning: stateful codec {:?} under buffered-async rounds — \
                 residual memory debited for uploads dropped past \
                 max_staleness={} is lost, not re-sent",
                cfg.codec, cfg.max_staleness
            );
        }
        let codec = match self.codec {
            Some(codec) => codec,
            None => cfg.codec.build()?,
        };
        Ok(Server {
            cfg,
            engine,
            slab,
            rounds: RoundEngine::new(codec, transport),
            control: self.control,
        })
    }
}

/// The parameter server driving one experiment on one engine.
pub struct Server<'e> {
    cfg: ExperimentConfig,
    engine: &'e mut dyn Engine,
    slab: EvalSlab,
    rounds: RoundEngine,
    control: crate::ops::RunControl,
}

impl<'e> Server<'e> {
    /// Historical one-call construction: codec from the config, in-process
    /// transport. Equivalent to
    /// `ServerBuilder::new(cfg).engine(engine).build()`.
    pub fn new(cfg: ExperimentConfig, engine: &'e mut dyn Engine) -> crate::Result<Self> {
        ServerBuilder::new(cfg).engine(engine).build()
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// The codec uploads go through on this server.
    pub fn codec(&self) -> &dyn UpdateCodec {
        self.rounds.codec()
    }

    /// Evaluate the training loss at `params`.
    pub fn eval(&mut self, params: &[f32]) -> crate::Result<f64> {
        self.slab.eval(self.engine, params)
    }

    /// Run the full K-round protocol; records the loss curve. Honors
    /// whatever [`crate::ops::RunControl`] the builder carried (none by
    /// default).
    pub fn run(&mut self) -> crate::Result<RunResult> {
        self.rounds
            .run(&self.cfg, self.engine, &self.slab, &self.control)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;
    use crate::coordinator::transport::InProcess;
    use crate::model::{ModelKind, RustEngine};
    use crate::quant::{CodecSpec, Coding, QsgdCodec, TopKCodec};

    fn small_cfg() -> ExperimentConfig {
        ExperimentConfig {
            name: "test".into(),
            model: "logreg".into(),
            dataset: crate::data::DatasetKind::Mnist08,
            n_nodes: 8,
            per_node: 40,
            r: 4,
            tau: 3,
            t_total: 30,
            codec: CodecSpec::qsgd(2),
            lr: crate::opt::LrSchedule::Const { eta: 0.5 },
            ratio: 100.0,
            seed: 3,
            eval_every: 2,
            engine: EngineKind::Rust,
            partition: crate::data::PartitionKind::Iid,
            async_rounds: false,
            buffer_size: 0,
            max_staleness: 8,
            staleness_rule: Default::default(),
            agg_shards: 1,
            down_codec: None,
            straggler: Default::default(),
            dataset_cap: 0,
        }
    }

    fn engine() -> RustEngine {
        RustEngine::new(ModelKind::LogReg { d: 784, l2: 0.05 }, 10, 320).unwrap()
    }

    #[test]
    fn loss_decreases_and_times_monotone() {
        let mut eng = engine();
        let mut srv = Server::new(small_cfg(), &mut eng).unwrap();
        let res = srv.run().unwrap();
        let first = res.curve.points.first().unwrap();
        let last = res.curve.points.last().unwrap();
        assert!(last.loss < first.loss * 0.8, "{} -> {}", first.loss, last.loss);
        let mut t = -1.0;
        for p in &res.curve.points {
            assert!(p.time > t || (p.round == 0 && p.time == 0.0));
            t = p.time;
        }
        assert_eq!(res.rounds.len(), 10);
        assert!(res.total_bits > 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed: u64| {
            let mut eng = engine();
            let cfg = small_cfg().with_seed(seed);
            Server::new(cfg, &mut eng).unwrap().run().unwrap()
        };
        let a = run(5);
        let b = run(5);
        assert_eq!(a.params, b.params);
        assert_eq!(a.total_bits, b.total_bits);
        let c = run(6);
        assert_ne!(a.params, c.params);
    }

    #[test]
    fn builder_with_explicit_parts_matches_default_path() {
        // The pluggable pipeline must reproduce the one-call path
        // bit-for-bit for the same codec/transport choices.
        let mut e1 = engine();
        let a = Server::new(small_cfg(), &mut e1).unwrap().run().unwrap();
        let mut e2 = engine();
        let b = ServerBuilder::new(small_cfg())
            .engine(&mut e2)
            .codec(QsgdCodec { s: 2, coding: Coding::Naive })
            .transport(InProcess::new())
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(a.params, b.params);
        assert_eq!(a.total_bits, b.total_bits);
    }

    #[test]
    fn builder_codec_override_rewrites_config_spec() {
        // A networked transport broadcasts the *config* to its workers,
        // so an overridden codec must be reflected there too.
        let mut eng = engine();
        let srv = ServerBuilder::new(small_cfg())
            .engine(&mut eng)
            .codec(TopKCodec::new(200))
            .build()
            .unwrap();
        assert_eq!(srv.config().codec, CodecSpec::top_k(200));
        assert_eq!(srv.codec().spec(), CodecSpec::top_k(200));
    }

    #[test]
    fn async_config_gets_async_transport_and_rejects_barrier_override() {
        // Default transport selection follows cfg.async_rounds …
        let mut eng = engine();
        let cfg = small_cfg().with_async(2, 8);
        let res = Server::new(cfg.clone(), &mut eng).unwrap().run().unwrap();
        assert_eq!(res.rounds.len(), 10);
        // … and a barrier transport explicitly paired with an async
        // config is refused instead of silently running barriers.
        let mut eng2 = engine();
        let err = ServerBuilder::new(cfg)
            .engine(&mut eng2)
            .transport(InProcess::new())
            .build();
        assert!(err.is_err());
    }

    #[test]
    fn sharded_run_is_bit_identical_to_single_shard() {
        // cfg.agg_shards is a pure throughput knob: the full protocol —
        // losses, virtual times, bits, final model — must not move by a
        // single bit when the accumulation fans out across threads.
        let run = |shards: usize| {
            let mut eng = engine();
            let cfg = small_cfg().with_agg_shards(shards);
            Server::new(cfg, &mut eng).unwrap().run().unwrap()
        };
        let a = run(1);
        for shards in [2usize, 4, 7] {
            let b = run(shards);
            assert_eq!(a.params, b.params, "shards={shards}");
            assert_eq!(a.total_bits, b.total_bits);
            assert_eq!(a.curve.points.len(), b.curve.points.len());
            for (x, y) in a.curve.points.iter().zip(&b.curve.points) {
                assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "shards={shards}");
                assert_eq!(x.time.to_bits(), y.time.to_bits(), "shards={shards}");
            }
        }
    }

    #[test]
    fn unrebuildable_codec_rejected_on_rebuilding_transports() {
        // Tcp rebuilds codecs from the broadcast config on the workers;
        // an External tag (bare or EF-wrapped via codec override) names
        // an instance that cannot travel. build() must fail fast —
        // before any socket work (Tcp connects in setup, not new).
        let mut eng = engine();
        let cfg = small_cfg().with_codec(CodecSpec::External { id: 7 });
        let err = ServerBuilder::new(cfg)
            .engine(&mut eng)
            .transport(crate::net::Tcp::new("127.0.0.1:0", 1))
            .build();
        assert!(err.is_err());
        // The same spec on an in-process transport fails too — but only
        // because External has no instance to build, which is the
        // historical behavior (overrides via .codec() still work there).
        let mut eng2 = engine();
        let cfg = small_cfg().with_codec(CodecSpec::External { id: 7 });
        assert!(ServerBuilder::new(cfg).engine(&mut eng2).build().is_err());
    }

    #[test]
    fn stateful_codec_runs_and_shards_bit_identically() {
        // EF(rand-k) through the whole pipeline: per-node residual state
        // in the sim, sharded aggregation decoding ranges through the
        // wrapper. Loss must decrease and agg_shards must stay a pure
        // throughput knob.
        let ef = CodecSpec::error_feedback(CodecSpec::rand_k(200));
        let run = |shards: usize| {
            let mut eng = engine();
            let cfg = small_cfg().with_codec(ef.clone()).with_agg_shards(shards);
            Server::new(cfg, &mut eng).unwrap().run().unwrap()
        };
        let a = run(1);
        let first = a.curve.points.first().unwrap().loss;
        let last = a.curve.points.last().unwrap().loss;
        assert!(last < first * 0.9, "EF(rand-k) did not train: {first} -> {last}");
        let b = run(4);
        assert_eq!(a.params, b.params);
        assert_eq!(a.total_bits, b.total_bits);
        // And repeat runs are bit-identical (the determinism the CI
        // codec leg byte-diffs).
        let c = run(1);
        assert_eq!(a.params, c.params);
    }

    #[test]
    fn downlink_compression_trains_and_splits_the_bit_account() {
        // down_codec end-to-end through the default in-process pipeline:
        // the run still trains (clients learn from the QAFeL reference,
        // not the exact server model), the download side of the bill is
        // reported, compressed broadcast is much cheaper than dense, and
        // repeat runs are bit-identical.
        let run = |down: Option<CodecSpec>| {
            let mut eng = engine();
            let mut cfg = small_cfg();
            cfg.down_codec = down;
            Server::new(cfg, &mut eng).unwrap().run().unwrap()
        };
        let raw = run(None);
        assert!(raw.total_bits_down > 0, "raw broadcasts must be billed");
        let qd = run(Some(CodecSpec::qsgd(4)));
        assert!(qd.total_bits_down > 0);
        assert!(
            qd.total_bits_down < raw.total_bits_down / 2,
            "compressed downlink {} vs dense {}",
            qd.total_bits_down,
            raw.total_bits_down
        );
        let first = qd.curve.points.first().unwrap().loss;
        let last = qd.curve.points.last().unwrap().loss;
        assert!(last < first * 0.9, "did not train: {first} -> {last}");
        let qd2 = run(Some(CodecSpec::qsgd(4)));
        assert_eq!(qd.params, qd2.params);
        assert_eq!(qd.total_bits_down, qd2.total_bits_down);
        // Uplink accounting is independent of the downlink codec.
        assert_eq!(qd.total_bits, qd2.total_bits);
    }

    #[test]
    fn quantized_uploads_cost_fewer_bits_than_fedavg() {
        let bits_of = |c: CodecSpec| {
            let mut eng = engine();
            let cfg = small_cfg().with_codec(c);
            Server::new(cfg, &mut eng).unwrap().run().unwrap().total_bits
        };
        let fedavg = bits_of(CodecSpec::Identity);
        let fedpaq = bits_of(CodecSpec::qsgd(1));
        assert!(
            (fedpaq as f64) < (fedavg as f64) / 10.0,
            "fedpaq {fedpaq} vs fedavg {fedavg}"
        );
    }

    #[test]
    fn top_k_trains_to_decreasing_loss_with_fewer_bits_than_fedavg() {
        let run = |c: CodecSpec| {
            let mut eng = engine();
            let cfg = small_cfg().with_codec(c);
            Server::new(cfg, &mut eng).unwrap().run().unwrap()
        };
        let topk = run(CodecSpec::top_k(200)); // keep 20% of coordinates
        let first = topk.curve.points.first().unwrap().loss;
        let last = topk.curve.points.last().unwrap().loss;
        assert!(last < first * 0.95, "top-k loss did not decrease: {first} -> {last}");
        let fedavg = run(CodecSpec::Identity);
        assert!(
            (topk.total_bits as f64) < (fedavg.total_bits as f64) / 2.0,
            "top-k {} vs fedavg {}",
            topk.total_bits,
            fedavg.total_bits
        );
    }

    #[test]
    fn fedavg_tau1_full_part_is_parallel_sgd() {
        use crate::coordinator::local;
        use crate::data::{BatchSampler, FederatedDataset, Partition};
        // With identity uploads, tau=1, r=n the update must equal the
        // average of the r single-step SGD updates — check one round by
        // replaying it manually.
        let cfg = ExperimentConfig {
            r: 8,
            tau: 1,
            t_total: 1,
            codec: CodecSpec::Identity,
            ..small_cfg()
        };
        let mut eng = engine();
        let mut srv = Server::new(cfg.clone(), &mut eng).unwrap();
        let res = srv.run().unwrap();

        // Manual replay.
        let mut eng2 = engine();
        let data = FederatedDataset::generate(cfg.dataset, cfg.seed, 320);
        let part = Partition::iid(320, 8, 40);
        let sampler = BatchSampler::new(cfg.seed, 10);
        let p0 = eng2.init_params().unwrap();
        let mut mean = vec![0f64; p0.len()];
        for node in 0..8 {
            let mut bufs = local::GatherBufs::default();
            let labels =
                local::gather_local_batches(&data, part.shard(node), &sampler, node, 0, 1, &mut bufs);
            let p1 = eng2
                .local_sgd(&p0, &bufs.x, labels.as_batch(), &[cfg.lr.lr(0, 0)])
                .unwrap();
            for (m, (&a, &b)) in mean.iter_mut().zip(p1.iter().zip(&p0)) {
                *m += (a - b) as f64;
            }
        }
        for (i, (&got, &init)) in res.params.iter().zip(&p0).enumerate() {
            let want = init as f64 + mean[i] / 8.0;
            assert!(
                (got as f64 - want).abs() < 1e-5,
                "param {i}: {got} vs {want}"
            );
        }
    }
}
