//! The FedPAQ parameter server: Algorithm 1 + the §5 virtual-time model.

use super::{aggregate::Aggregator, local, sampler};
use crate::config::ExperimentConfig;
use crate::data::{BatchSampler, FederatedDataset, Labels, Partition};
use crate::metrics::{Curve, CurvePoint};
use crate::model::{Engine, LabelBatch};
use crate::simtime::{CostModel, VirtualClock};

/// Per-round timing/traffic record.
#[derive(Debug, Clone, Copy)]
pub struct RoundStats {
    pub round: usize,
    pub compute_time: f64,
    pub comm_time: f64,
    pub bits_up: u64,
}

/// Output of a full training run.
#[derive(Debug)]
pub struct RunResult {
    /// Loss-vs-virtual-time curve (the paper's plotted series).
    pub curve: Curve,
    /// Final server model.
    pub params: Vec<f32>,
    /// Per-round stats.
    pub rounds: Vec<RoundStats>,
    /// Total uploaded bits over the run.
    pub total_bits: u64,
}

/// The parameter server driving one experiment on one engine.
pub struct Server<'e> {
    cfg: ExperimentConfig,
    engine: &'e mut dyn Engine,
    data: std::sync::Arc<FederatedDataset>,
    partition: Partition,
    sampler: BatchSampler,
    cost: CostModel,
    eval_x: Vec<f32>,
    eval_y: OwnedEval,
    eval_token: u64,
}

#[derive(Debug)]
enum OwnedEval {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl OwnedEval {
    fn as_batch(&self) -> LabelBatch<'_> {
        match self {
            OwnedEval::F32(v) => LabelBatch::F32(v),
            OwnedEval::I32(v) => LabelBatch::I32(v),
        }
    }
}

impl<'e> Server<'e> {
    /// Build the federated world for `cfg` and bind it to `engine`.
    pub fn new(cfg: ExperimentConfig, engine: &'e mut dyn Engine) -> crate::Result<Self> {
        let cfg = cfg.validated()?;
        let n_samples = cfg.n_nodes * cfg.per_node;
        let data = crate::data::cached_generate(cfg.dataset, cfg.seed, n_samples);
        anyhow::ensure!(
            data.dim == engine.kind().d_in(),
            "dataset dim {} != model d_in {}",
            data.dim,
            engine.kind().d_in()
        );
        let partition =
            Partition::build(cfg.partition, &data, cfg.n_nodes, cfg.per_node, cfg.seed);
        let sampler = BatchSampler::new(cfg.seed, engine.batch());
        let p = engine.param_count();
        let cost = CostModel::with_ratio(cfg.ratio, p, cfg.seed);

        // Fixed eval slab: the first eval_n assigned samples (partition
        // order is already a seeded shuffle). For logreg eval_n == the full
        // training set, matching the paper's "training loss" axis exactly;
        // for the NNs it is a fixed 2048-sample estimate (DESIGN.md §4).
        let eval_n = engine.eval_n();
        let all = partition.all_indices();
        anyhow::ensure!(all.len() >= eval_n, "eval slab larger than dataset");
        let idx = &all[..eval_n];
        let mut eval_x = Vec::new();
        data.gather_features(idx, &mut eval_x);
        let eval_y = match &data.labels {
            Labels::Float(_) => {
                let mut y = Vec::new();
                data.gather_labels_f32(idx, &mut y);
                OwnedEval::F32(y)
            }
            Labels::Int(_) => {
                let mut y = Vec::new();
                data.gather_labels_i32(idx, &mut y);
                OwnedEval::I32(y)
            }
        };
        let eval_token = cfg.seed ^ 0xe7a1_0000 ^ (eval_n as u64) << 32;
        Ok(Server { cfg, engine, data, partition, sampler, cost, eval_x, eval_y, eval_token })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }

    /// Evaluate the training loss at `params`.
    pub fn eval(&mut self, params: &[f32]) -> crate::Result<f64> {
        Ok(self
            .engine
            .eval_loss_token(params, self.eval_token, &self.eval_x, self.eval_y.as_batch())?
            as f64)
    }

    /// Run the full K-round protocol; records the loss curve.
    pub fn run(&mut self) -> crate::Result<RunResult> {
        let mut params = self.engine.init_params()?;
        let p = params.len();
        let rounds = self.cfg.rounds();
        let mut clock = VirtualClock::new();
        let mut curve = Curve::new(self.cfg.name.clone());
        let mut stats = Vec::with_capacity(rounds);
        let mut total_bits = 0u64;
        let mut bufs = local::GatherBufs::default();

        // Round-0 point: initial loss at time 0.
        let loss0 = self.eval(&params)?;
        curve.push(CurvePoint { round: 0, iterations: 0, time: 0.0, bits_up: 0, loss: loss0 });

        for k in 0..rounds {
            let nodes = sampler::sample_nodes(self.cfg.n_nodes, self.cfg.r, self.cfg.seed, k);
            let lrs: Vec<f32> =
                (0..self.cfg.tau).map(|t| self.cfg.lr.lr(k, t)).collect();
            let mut agg = Aggregator::new(self.cfg.quantizer, p);
            for &node in &nodes {
                let enc = local::node_round(
                    &self.cfg,
                    self.engine,
                    &self.data,
                    self.partition.shard(node),
                    &self.sampler,
                    node,
                    k,
                    &params,
                    &lrs,
                    &mut bufs,
                )?;
                agg.push(&enc);
            }
            let bits: u64 = agg.upload_bits().iter().sum();
            let compute_time =
                self.cost
                    .round_compute_time(&nodes, k, self.cfg.tau, self.engine.batch());
            let comm_time = self.cost.round_comm_time(agg.upload_bits());
            agg.apply(&mut params);
            clock.advance(compute_time + comm_time);
            total_bits += bits;
            stats.push(RoundStats { round: k, compute_time, comm_time, bits_up: bits });

            if (k + 1) % self.cfg.eval_every == 0 || k + 1 == rounds {
                let loss = self.eval(&params)?;
                curve.push(CurvePoint {
                    round: k + 1,
                    iterations: (k + 1) * self.cfg.tau,
                    time: clock.now(),
                    bits_up: total_bits,
                    loss,
                });
            }
        }
        Ok(RunResult { curve, params, rounds: stats, total_bits })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;
    use crate::model::{ModelKind, RustEngine};
    use crate::quant::Quantizer;

    fn small_cfg() -> ExperimentConfig {
        ExperimentConfig {
            name: "test".into(),
            model: "logreg".into(),
            dataset: crate::data::DatasetKind::Mnist08,
            n_nodes: 8,
            per_node: 40,
            r: 4,
            tau: 3,
            t_total: 30,
            quantizer: Quantizer::qsgd(2),
            lr: crate::opt::LrSchedule::Const { eta: 0.5 },
            ratio: 100.0,
            seed: 3,
            eval_every: 2,
            engine: EngineKind::Rust,
            partition: crate::data::PartitionKind::Iid,
        }
    }

    fn engine() -> RustEngine {
        RustEngine::new(ModelKind::LogReg { d: 784, l2: 0.05 }, 10, 320).unwrap()
    }

    #[test]
    fn loss_decreases_and_times_monotone() {
        let mut eng = engine();
        let mut srv = Server::new(small_cfg(), &mut eng).unwrap();
        let res = srv.run().unwrap();
        let first = res.curve.points.first().unwrap();
        let last = res.curve.points.last().unwrap();
        assert!(last.loss < first.loss * 0.8, "{} -> {}", first.loss, last.loss);
        let mut t = -1.0;
        for p in &res.curve.points {
            assert!(p.time > t || (p.round == 0 && p.time == 0.0));
            t = p.time;
        }
        assert_eq!(res.rounds.len(), 10);
        assert!(res.total_bits > 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed: u64| {
            let mut eng = engine();
            let cfg = small_cfg().with_seed(seed);
            Server::new(cfg, &mut eng).unwrap().run().unwrap()
        };
        let a = run(5);
        let b = run(5);
        assert_eq!(a.params, b.params);
        assert_eq!(a.total_bits, b.total_bits);
        let c = run(6);
        assert_ne!(a.params, c.params);
    }

    #[test]
    fn quantized_uploads_cost_fewer_bits_than_fedavg() {
        let bits_of = |q: Quantizer| {
            let mut eng = engine();
            let cfg = small_cfg().with_quantizer(q);
            Server::new(cfg, &mut eng).unwrap().run().unwrap().total_bits
        };
        let fedavg = bits_of(Quantizer::Identity);
        let fedpaq = bits_of(Quantizer::qsgd(1));
        assert!(
            (fedpaq as f64) < (fedavg as f64) / 10.0,
            "fedpaq {fedpaq} vs fedavg {fedavg}"
        );
    }

    #[test]
    fn fedavg_tau1_full_part_is_parallel_sgd() {
        // With identity quantization, tau=1, r=n the update must equal the
        // average of the r single-step SGD updates — check one round by
        // replaying it manually.
        let cfg = ExperimentConfig {
            r: 8,
            tau: 1,
            t_total: 1,
            quantizer: Quantizer::Identity,
            ..small_cfg()
        };
        let mut eng = engine();
        let mut srv = Server::new(cfg.clone(), &mut eng).unwrap();
        let res = srv.run().unwrap();

        // Manual replay.
        let mut eng2 = engine();
        let data = FederatedDataset::generate(cfg.dataset, cfg.seed, 320);
        let part = Partition::iid(320, 8, 40, cfg.seed);
        let sampler = BatchSampler::new(cfg.seed, 10);
        let p0 = eng2.init_params().unwrap();
        let mut mean = vec![0f64; p0.len()];
        for node in 0..8 {
            let mut bufs = local::GatherBufs::default();
            let labels =
                local::gather_local_batches(&data, part.shard(node), &sampler, node, 0, 1, &mut bufs);
            let p1 = eng2
                .local_sgd(&p0, &bufs.x, labels.as_batch(), &[cfg.lr.lr(0, 0)])
                .unwrap();
            for (m, (&a, &b)) in mean.iter_mut().zip(p1.iter().zip(&p0)) {
                *m += (a - b) as f64;
            }
        }
        for (i, (&got, &init)) in res.params.iter().zip(&p0).enumerate() {
            let want = init as f64 + mean[i] / 8.0;
            assert!(
                (got as f64 - want).abs() < 1e-5,
                "param {i}: {got} vs {want}"
            );
        }
    }
}
