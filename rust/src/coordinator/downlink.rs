//! Server → node downlink compression (QAFeL-style hidden state).
//!
//! FedPAQ compresses the uplink; at scale the broadcast of raw f32
//! models is the larger aggregate bill. This module adds the symmetric
//! downlink seam, following Zakerinia et al. (2206.10032): the server
//! keeps a *reference* model (the QAFeL hidden state) that every client
//! can reconstruct exactly, and each broadcast ships only the encoded
//! delta between the new model version and that reference:
//!
//! ```text
//! ref(0)   = x_0                      (initial model, shipped out of band)
//! link_k   = encode(x_k − ref(k−1))   (k ≥ 1, RNG stream [7, k])
//! ref(k)   = ref(k−1) + decode(link_k)
//! ```
//!
//! Clients train **from `ref(k)`**, not from the exact `x_k` they never
//! see; their uploaded deltas are relative to `ref(k)`, and the server
//! applies the aggregated delta to its exact `x_k` as usual. Because
//! `decode` is deterministic, a client holding `ref(v)` reaches `ref(N)`
//! bit-exactly by applying the chain `link_{v+1} … link_N` — the
//! interaction with buffered-async staleness reduces to shipping the
//! right chain suffix (or a raw re-base of the reference for fresh and
//! rejoined workers; see `net::transport`).
//!
//! One [`DownlinkEncoder`] per run lives inside the round engine; the
//! same chain-application arithmetic ([`apply_link`]) runs on TCP
//! workers, so the simulated and real clusters reconstruct bit-identical
//! references. Encoding uses the node-less [`UpdateCodec::encode`] entry
//! point: the downlink has exactly one logical stream, so a stateful
//! error-feedback wrapper keeps one server-side residual (its anonymous
//! node slot) and its frames stay decodable by any client.

use crate::quant::{Encoded, UpdateCodec};
use crate::util::rng::Rng;

use super::transport::ModelFrame;

/// Downlink encoder RNG stream for `(seed, version)` — coordinate prefix
/// `7`, disjoint from the quantizer (`3`) and planner re-dispatch (`5`)
/// streams.
pub fn downlink_rng(seed: u64, version: usize) -> Rng {
    Rng::from_coords(seed, &[7, version as u64])
}

/// Apply one decoded chain link to a reference model in place
/// (`reference[i] += decode(enc)[i]`).
///
/// This is the *only* arithmetic that advances a reference, shared by
/// the server-side [`DownlinkEncoder`] and the TCP worker's
/// reconstruction, so both sides stay bit-identical by construction.
pub fn apply_link(
    codec: &dyn UpdateCodec,
    enc: &Encoded,
    reference: &mut [f32],
    scratch: &mut Vec<f32>,
) -> crate::Result<()> {
    codec.decode_into(enc, scratch)?;
    anyhow::ensure!(
        scratch.len() == reference.len(),
        "downlink chain link decodes to {} coords, reference has {}",
        scratch.len(),
        reference.len()
    );
    for (r, d) in reference.iter_mut().zip(scratch.iter()) {
        *r += *d;
    }
    Ok(())
}

/// Server-side downlink state: the shared reference model, the per-link
/// bit sizes (for the up/down accounting split), and each node's last
/// known reference version.
///
/// Owned by the round engine; checkpointed in full (reference, link
/// bits, per-node versions, codec state) so `--resume` continues the
/// chain bit-identically.
#[derive(Debug)]
pub struct DownlinkEncoder {
    codec: Box<dyn UpdateCodec>,
    seed: u64,
    reference: Vec<f32>,
    /// `link_bits[k]` = exact wire bits of `link_k`; entry 0 is always 0
    /// (version 0 is the out-of-band initial model, never a link).
    link_bits: Vec<u64>,
    /// Per-node version whose reference the node currently holds. Starts
    /// at 0: every node knows `x_0`.
    last: Vec<u64>,
    scratch: Vec<f32>,
}

impl DownlinkEncoder {
    pub fn new(codec: Box<dyn UpdateCodec>, seed: u64, n_nodes: usize) -> Self {
        codec.reset_state();
        DownlinkEncoder {
            codec,
            seed,
            reference: Vec::new(),
            link_bits: Vec::new(),
            last: vec![0; n_nodes],
            scratch: Vec::new(),
        }
    }

    pub fn codec(&self) -> &dyn UpdateCodec {
        self.codec.as_ref()
    }

    /// The current reference model `ref(k)`.
    pub fn reference(&self) -> &[f32] {
        &self.reference
    }

    /// Build the broadcast frame for `version` from the server's exact
    /// model. Version 0 adopts `params` as `ref(0)` (no link); each later
    /// version encodes `x_k − ref(k−1)`, advances the reference by the
    /// *decoded* link, and remembers the link's bit size.
    pub fn begin_round(&mut self, version: usize, params: &[f32]) -> crate::Result<ModelFrame> {
        if version == 0 {
            anyhow::ensure!(
                self.link_bits.is_empty(),
                "downlink encoder already started (have {} links)",
                self.link_bits.len().saturating_sub(1)
            );
            self.reference = params.to_vec();
            self.link_bits.push(0);
            return Ok(ModelFrame {
                version: 0,
                params: self.reference.clone(),
                link: None,
            });
        }
        anyhow::ensure!(
            self.link_bits.len() == version,
            "downlink encoder at version {} asked to encode version {version}",
            self.link_bits.len().saturating_sub(1)
        );
        anyhow::ensure!(
            params.len() == self.reference.len(),
            "model has {} coords, downlink reference has {}",
            params.len(),
            self.reference.len()
        );
        let delta: Vec<f32> = params
            .iter()
            .zip(self.reference.iter())
            .map(|(&x, &r)| x - r)
            .collect();
        let mut rng = downlink_rng(self.seed, version);
        let enc = self.codec.encode(&delta, &mut rng);
        apply_link(self.codec.as_ref(), &enc, &mut self.reference, &mut self.scratch)?;
        self.link_bits.push(enc.bits());
        Ok(ModelFrame {
            version,
            params: self.reference.clone(),
            link: Some(enc),
        })
    }

    /// Downlink bits a dispatch of `node` at `version` costs: the sum of
    /// the chain links `(last_v, version]` the node still needs.
    /// Advances the node's bookkeeping — per-*node* accounting, the cost
    /// model's unit (a transport fanning several nodes into one worker
    /// socket ships fewer wire bytes; see `docs/PROTOCOL.md`).
    pub fn dispatch_bits(&mut self, node: usize, version: usize) -> u64 {
        let have = self.last[node];
        let bits = ((have as usize + 1)..=version)
            .map(|k| self.link_bits[k])
            .sum();
        self.last[node] = self.last[node].max(version as u64);
        bits
    }

    /// Snapshot for checkpoints: `(reference, link_bits, last, codec
    /// state)`.
    #[allow(clippy::type_complexity)]
    pub fn state_export(&self) -> (Vec<f32>, Vec<u64>, Vec<u64>, Vec<(u64, Vec<f32>)>) {
        (
            self.reference.clone(),
            self.link_bits.clone(),
            self.last.clone(),
            self.codec.state_export(),
        )
    }

    /// Restore a [`DownlinkEncoder::state_export`] snapshot (resume).
    pub fn state_import(
        &mut self,
        reference: Vec<f32>,
        link_bits: Vec<u64>,
        last: Vec<u64>,
        codec_state: Vec<(u64, Vec<f32>)>,
    ) -> crate::Result<()> {
        anyhow::ensure!(
            last.len() == self.last.len(),
            "downlink snapshot covers {} nodes, config has {}",
            last.len(),
            self.last.len()
        );
        anyhow::ensure!(
            !link_bits.is_empty(),
            "downlink snapshot has no link-bit history (not even version 0)"
        );
        self.reference = reference;
        self.link_bits = link_bits;
        self.last = last;
        self.codec.reset_state();
        self.codec.state_import(codec_state);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::CodecSpec;

    fn walk(p: usize, steps: usize, seed: u64) -> Vec<Vec<f32>> {
        // A deterministic pseudo-random trajectory of model versions.
        let mut rng = Rng::from_coords(seed, &[99]);
        let mut x: Vec<f32> = (0..p).map(|_| rng.gen_f32() - 0.5).collect();
        let mut out = vec![x.clone()];
        for _ in 0..steps {
            for v in x.iter_mut() {
                *v += 0.1 * (rng.gen_f32() - 0.5);
            }
            out.push(x.clone());
        }
        out
    }

    #[test]
    fn client_chain_reconstruction_matches_reference() {
        let versions = walk(64, 6, 5);
        let mut down =
            DownlinkEncoder::new(CodecSpec::qsgd(4).build().unwrap(), 5, 4);
        let client_codec = CodecSpec::qsgd(4).build().unwrap();
        let mut frames = Vec::new();
        for (k, x) in versions.iter().enumerate() {
            frames.push(down.begin_round(k, x).unwrap());
        }
        // A client that held ref(v) reaches ref(N) by applying the chain.
        let mut scratch = Vec::new();
        for v in 0..versions.len() {
            let mut client = frames[v].params.clone();
            for frame in &frames[v + 1..] {
                apply_link(
                    client_codec.as_ref(),
                    frame.link.as_ref().unwrap(),
                    &mut client,
                    &mut scratch,
                )
                .unwrap();
            }
            assert_eq!(client, down.reference(), "chain from v={v} diverged");
        }
    }

    #[test]
    fn dispatch_bits_sums_exactly_the_missing_links() {
        let versions = walk(32, 3, 9);
        let mut down =
            DownlinkEncoder::new(CodecSpec::qsgd(2).build().unwrap(), 9, 3);
        let mut bits = Vec::new();
        for (k, x) in versions.iter().enumerate() {
            let f = down.begin_round(k, x).unwrap();
            bits.push(f.link.map_or(0, |l| l.bits()));
        }
        // Node 0 dispatched every version: pays each link once.
        for k in 0..=3 {
            assert_eq!(down.dispatch_bits(0, k), bits[k]);
        }
        // Node 1 never dispatched until version 3: pays the whole chain.
        assert_eq!(down.dispatch_bits(1, 3), bits[1] + bits[2] + bits[3]);
        // Re-dispatch at a version already held is free.
        assert_eq!(down.dispatch_bits(1, 3), 0);
        // Version 0 is the out-of-band initial model: free.
        assert_eq!(down.dispatch_bits(2, 0), 0);
    }

    #[test]
    fn state_roundtrip_resumes_the_chain_bit_identically() {
        let versions = walk(48, 5, 13);
        let spec = CodecSpec::error_feedback(CodecSpec::top_k(250));
        let mut a = DownlinkEncoder::new(spec.build().unwrap(), 13, 2);
        for (k, x) in versions.iter().take(3).enumerate() {
            a.begin_round(k, x).unwrap();
        }
        a.dispatch_bits(0, 2);
        let (r, lb, last, cs) = a.state_export();
        let mut b = DownlinkEncoder::new(spec.build().unwrap(), 13, 2);
        b.state_import(r, lb, last, cs).unwrap();
        for (k, x) in versions.iter().enumerate().skip(3) {
            let fa = a.begin_round(k, x).unwrap();
            let fb = b.begin_round(k, x).unwrap();
            assert_eq!(fa.params, fb.params);
            assert_eq!(
                fa.link.as_ref().map(|l| l.bits()),
                fb.link.as_ref().map(|l| l.bits())
            );
        }
        assert_eq!(a.reference(), b.reference());
        assert_eq!(a.dispatch_bits(0, 5), b.dispatch_bits(0, 5));
    }

    #[test]
    fn out_of_order_versions_rejected() {
        let versions = walk(16, 2, 1);
        let mut down =
            DownlinkEncoder::new(CodecSpec::qsgd(2).build().unwrap(), 1, 2);
        down.begin_round(0, &versions[0]).unwrap();
        assert!(down.begin_round(2, &versions[2]).is_err());
        assert!(down.begin_round(0, &versions[0]).is_err());
        down.begin_round(1, &versions[1]).unwrap();
    }
}
