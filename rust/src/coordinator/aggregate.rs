//! Server-side aggregation (Algorithm 1 line 13), staleness-aware:
//! `x_{k+1} = x_k + (1/Σw_i) Σ_{i∈B_k} w_i · Q(x_{·,τ}^{(i)} − x_·)`.
//!
//! For the synchronous barrier transports every upload in the batch `B_k`
//! was trained on the current model (`staleness 0`, weight 1), and the
//! rule above reduces exactly to the paper's uniform mean. Buffered-async
//! transports ([`AsyncSim`](super::AsyncSim)) commit batches that mix
//! uploads born at older server versions; a [`StalenessRule`] damps their
//! contribution.

use crate::quant::{Encoded, UpdateCodec};

/// How an upload's aggregation weight decays with its staleness `s`
/// (the number of server versions committed since the upload's model was
/// broadcast). Serialized in [`ExperimentConfig`](crate::config::ExperimentConfig)
/// as `staleness_rule`.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum StalenessRule {
    /// `w(s) = 1`: plain FedBuff mean over the committed buffer.
    #[default]
    Uniform,
    /// `w(s) = (1+s)^{-a}`: polynomial damping; `a = 1` is the classic
    /// `1/(1+s)` rule, `a = 0.5` the FedBuff paper's square-root variant.
    Polynomial { a: f64 },
}

impl StalenessRule {
    /// `1/(1+s)` damping (`Polynomial` with `a = 1`).
    pub fn inverse() -> Self {
        StalenessRule::Polynomial { a: 1.0 }
    }

    /// Aggregation weight for staleness `s`. Always exactly `1.0` at
    /// `s = 0`, so fresh uploads aggregate bit-identically to the
    /// synchronous uniform mean under every rule.
    pub fn weight(&self, s: usize) -> f64 {
        match *self {
            StalenessRule::Uniform => 1.0,
            StalenessRule::Polynomial { a } => {
                if s == 0 {
                    1.0
                } else {
                    (1.0 + s as f64).powf(-a)
                }
            }
        }
    }

    /// Human label (figure curve names, logs).
    pub fn name(&self) -> String {
        match *self {
            StalenessRule::Uniform => "uniform".into(),
            StalenessRule::Polynomial { a } => format!("poly(a={a})"),
        }
    }
}

/// Streaming weighted aggregator: decodes each upload and accumulates
/// `Σ w_i · Δ_i` in f64 (bit-stable regardless of arrival order is NOT
/// promised — floating addition — but f64 accumulation keeps the error
/// ≪ f32 eps; transports that reorder uploads canonicalize the batch
/// order themselves).
///
/// Designed to live for a whole run: [`Aggregator::reset`] rewinds it for
/// the next round while keeping the `sum` and decode-scratch allocations,
/// so the per-upload hot path ([`Aggregator::push`]) allocates nothing.
///
/// Every public entry point ([`push`](Aggregator::push),
/// [`push_weighted`](Aggregator::push_weighted),
/// [`push_decoded`](Aggregator::push_decoded)) funnels through one
/// internal accumulation path, so `count`, `weight_sum` and the
/// per-upload `upload_bits` record can never drift apart from what
/// [`apply`](Aggregator::apply) divides by.
#[derive(Debug)]
pub struct Aggregator {
    sum: Vec<f64>,
    count: usize,
    weight_sum: f64,
    bits: Vec<u64>,
    /// Reused decode buffer: one allocation per run, not per upload.
    scratch: Vec<f32>,
}

impl Aggregator {
    pub fn new(p: usize) -> Self {
        Aggregator {
            sum: vec![0.0; p],
            count: 0,
            weight_sum: 0.0,
            bits: Vec::new(),
            scratch: Vec::new(),
        }
    }

    /// Rewind for the next round, keeping all allocations.
    pub fn reset(&mut self) {
        self.sum.iter_mut().for_each(|s| *s = 0.0);
        self.count = 0;
        self.weight_sum = 0.0;
        self.bits.clear();
    }

    /// The single accumulation path: absorb `dec` with weight `weight`,
    /// recording `bits` uplink bits. Everything that mutates the running
    /// mean goes through here — the debug assertion pins the invariant
    /// that one upload contributes exactly one entry to every ledger.
    fn absorb(&mut self, dec: &[f32], bits: u64, weight: f64) -> crate::Result<()> {
        anyhow::ensure!(
            dec.len() == self.sum.len(),
            "upload dimension mismatch: {} != {}",
            dec.len(),
            self.sum.len()
        );
        anyhow::ensure!(
            weight.is_finite() && weight > 0.0,
            "aggregation weight must be finite and positive, got {weight}"
        );
        if weight == 1.0 {
            // Keep the uniform path bit-identical to the historical
            // unweighted mean (multiplying by 1.0 is exact, but skipping
            // the multiply entirely makes the intent auditable).
            for (s, &v) in self.sum.iter_mut().zip(dec) {
                *s += v as f64;
            }
        } else {
            for (s, &v) in self.sum.iter_mut().zip(dec) {
                *s += v as f64 * weight;
            }
        }
        self.bits.push(bits);
        self.count += 1;
        self.weight_sum += weight;
        debug_assert_eq!(
            self.bits.len(),
            self.count,
            "aggregator ledgers out of sync"
        );
        Ok(())
    }

    /// Decode and absorb one node's upload at weight 1 (allocation-free:
    /// decodes into the internal scratch buffer via
    /// [`UpdateCodec::decode_into`]).
    pub fn push(&mut self, codec: &dyn UpdateCodec, enc: &Encoded) -> crate::Result<()> {
        self.push_weighted(codec, enc, 1.0)
    }

    /// Decode and absorb one upload at an explicit staleness weight
    /// (see [`StalenessRule::weight`]).
    pub fn push_weighted(
        &mut self,
        codec: &dyn UpdateCodec,
        enc: &Encoded,
        weight: f64,
    ) -> crate::Result<()> {
        anyhow::ensure!(
            enc.p == self.sum.len(),
            "upload dimension mismatch: {} != {}",
            enc.p,
            self.sum.len()
        );
        codec.decode_into(enc, &mut self.scratch)?;
        // Move scratch out to appease the borrow checker without copying.
        let scratch = std::mem::take(&mut self.scratch);
        let r = self.absorb(&scratch, enc.bits(), weight);
        self.scratch = scratch;
        r
    }

    /// Absorb an already-decoded update at weight 1, skipping the wire
    /// decode — for embedders and custom transports whose uploads arrive
    /// dequantized (the arithmetic result is identical by construction
    /// when the decoded values come from the same codec). Funnels through
    /// the same internal path as [`Aggregator::push`], so mixing the two
    /// on one batch keeps `count`/`weight_sum`/`upload_bits` consistent
    /// with what [`Aggregator::apply`] divides by.
    pub fn push_decoded(&mut self, dec: &[f32], bits: u64) {
        self.absorb(dec, bits, 1.0)
            .expect("push_decoded: dimension mismatch");
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Sum of the absorbed weights (the normalizer [`Aggregator::apply`]
    /// divides by). Equals `count` when every push was weight-1.
    pub fn weight_sum(&self) -> f64 {
        self.weight_sum
    }

    /// Per-upload bit sizes (for the §5 communication-time model).
    pub fn upload_bits(&self) -> &[u64] {
        &self.bits
    }

    /// Apply the weighted-mean update to `params`. Errors (instead of
    /// panicking) when no uploads arrived, so a round where every sampled
    /// node failed cannot abort a long run — the engine skips it instead.
    pub fn apply(&mut self, params: &mut [f32]) -> crate::Result<()> {
        anyhow::ensure!(self.count > 0, "no uploads to aggregate");
        debug_assert_eq!(self.bits.len(), self.count, "aggregator ledgers out of sync");
        let inv = 1.0 / self.weight_sum;
        for (p, &s) in params.iter_mut().zip(&self.sum) {
            *p = (*p as f64 + s * inv) as f32;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{IdentityCodec, QsgdCodec, UpdateCodec};
    use crate::util::rng::Rng;

    #[test]
    fn identity_aggregation_is_mean() {
        let q = IdentityCodec;
        let mut agg = Aggregator::new(3);
        let mut rng = Rng::seed_from_u64(0);
        agg.push(&q, &q.encode(&[1.0, 2.0, 3.0], &mut rng)).unwrap();
        agg.push(&q, &q.encode(&[3.0, 0.0, -1.0], &mut rng)).unwrap();
        let mut params = vec![10.0f32, 10.0, 10.0];
        agg.apply(&mut params).unwrap();
        assert_eq!(params, vec![12.0, 11.0, 11.0]);
    }

    #[test]
    fn weighted_aggregation_is_weighted_mean() {
        let q = IdentityCodec;
        let mut agg = Aggregator::new(1);
        let mut rng = Rng::seed_from_u64(0);
        // weight 1 on 4.0, weight 0.5 on 1.0: (4 + 0.5) / 1.5 = 3.0
        agg.push_weighted(&q, &q.encode(&[4.0], &mut rng), 1.0).unwrap();
        agg.push_weighted(&q, &q.encode(&[1.0], &mut rng), 0.5).unwrap();
        assert_eq!(agg.weight_sum(), 1.5);
        let mut params = vec![0.0f32];
        agg.apply(&mut params).unwrap();
        assert!((params[0] - 3.0).abs() < 1e-6, "{}", params[0]);
    }

    #[test]
    fn unit_weights_match_legacy_uniform_mean_bitwise() {
        let q = QsgdCodec::new(2);
        let xs = [vec![0.5f32, -1.5, 2.0, 0.0], vec![1.0f32, 0.25, -0.125, 3.0]];
        let mut a = Aggregator::new(4);
        let mut b = Aggregator::new(4);
        for (i, x) in xs.iter().enumerate() {
            let enc = q.encode(x, &mut Rng::seed_from_u64(i as u64));
            a.push(&q, &enc).unwrap();
            b.push_weighted(&q, &enc, 1.0).unwrap();
        }
        let mut pa = vec![7.0f32; 4];
        let mut pb = vec![7.0f32; 4];
        a.apply(&mut pa).unwrap();
        b.apply(&mut pb).unwrap();
        assert_eq!(pa, pb);
    }

    #[test]
    fn push_decoded_matches_push() {
        let q = QsgdCodec::new(2);
        let x = vec![0.5f32, -1.5, 2.0, 0.0];
        let mut rng1 = Rng::seed_from_u64(7);
        let mut rng2 = Rng::seed_from_u64(7);
        let enc = q.encode(&x, &mut rng1);
        let (dec, bits) = q.apply(&x, &mut rng2).unwrap();
        let mut a = Aggregator::new(4);
        a.push(&q, &enc).unwrap();
        let mut b = Aggregator::new(4);
        b.push_decoded(&dec, bits);
        let mut pa = vec![0f32; 4];
        let mut pb = vec![0f32; 4];
        a.apply(&mut pa).unwrap();
        b.apply(&mut pb).unwrap();
        assert_eq!(pa, pb);
    }

    #[test]
    fn mixed_push_and_push_decoded_stay_consistent() {
        // The regression the single-path refactor pins down: mixing entry
        // points must keep count/weight_sum/bits in lockstep, so apply
        // divides by exactly the number of absorbed uploads.
        let q = IdentityCodec;
        let mut rng = Rng::seed_from_u64(3);
        let mut agg = Aggregator::new(2);
        agg.push(&q, &q.encode(&[2.0, 4.0], &mut rng)).unwrap();
        agg.push_decoded(&[4.0, 8.0], 64);
        assert_eq!(agg.count(), 2);
        assert_eq!(agg.weight_sum(), 2.0);
        assert_eq!(agg.upload_bits().len(), 2);
        let mut params = vec![0.0f32, 0.0];
        agg.apply(&mut params).unwrap();
        assert_eq!(params, vec![3.0, 6.0]);
    }

    #[test]
    fn non_positive_or_non_finite_weights_rejected() {
        let q = IdentityCodec;
        let mut rng = Rng::seed_from_u64(0);
        let mut agg = Aggregator::new(1);
        let enc = q.encode(&[1.0], &mut rng);
        assert!(agg.push_weighted(&q, &enc, 0.0).is_err());
        assert!(agg.push_weighted(&q, &enc, -1.0).is_err());
        assert!(agg.push_weighted(&q, &enc, f64::NAN).is_err());
        assert_eq!(agg.count(), 0);
        assert!(agg.upload_bits().is_empty());
    }

    #[test]
    fn empty_apply_is_an_error_not_a_panic() {
        let mut agg = Aggregator::new(2);
        assert!(agg.apply(&mut [0.0, 0.0]).is_err());
    }

    #[test]
    fn reset_reuses_allocations_across_rounds() {
        let q = QsgdCodec::new(1);
        let x = vec![0.25f32; 64];
        let mut rng = Rng::seed_from_u64(1);
        let mut agg = Aggregator::new(64);
        let mut first = vec![0f32; 64];
        agg.push(&q, &q.encode(&x, &mut rng)).unwrap();
        agg.apply(&mut first).unwrap();
        agg.reset();
        assert_eq!(agg.count(), 0);
        assert_eq!(agg.weight_sum(), 0.0);
        assert!(agg.upload_bits().is_empty());
        let mut again = vec![0f32; 64];
        let mut rng2 = Rng::seed_from_u64(1);
        agg.push(&q, &q.encode(&x, &mut rng2)).unwrap();
        agg.apply(&mut again).unwrap();
        assert_eq!(first, again);
    }

    #[test]
    fn mismatched_codec_push_is_rejected() {
        let enc = QsgdCodec::new(2).encode(&[1.0f32; 8], &mut Rng::seed_from_u64(2));
        let mut agg = Aggregator::new(8);
        assert!(agg.push(&QsgdCodec::new(3), &enc).is_err());
        assert_eq!(agg.count(), 0);
    }

    #[test]
    fn staleness_rules_weight_as_documented() {
        assert_eq!(StalenessRule::Uniform.weight(0), 1.0);
        assert_eq!(StalenessRule::Uniform.weight(100), 1.0);
        let inv = StalenessRule::inverse();
        assert_eq!(inv.weight(0), 1.0);
        assert!((inv.weight(1) - 0.5).abs() < 1e-12);
        assert!((inv.weight(3) - 0.25).abs() < 1e-12);
        let sqrt = StalenessRule::Polynomial { a: 0.5 };
        assert_eq!(sqrt.weight(0), 1.0);
        assert!((sqrt.weight(3) - 0.5).abs() < 1e-12);
        // Monotone non-increasing in s for every rule.
        for rule in [StalenessRule::Uniform, inv, sqrt] {
            for s in 0..20 {
                assert!(rule.weight(s + 1) <= rule.weight(s));
                assert!(rule.weight(s) > 0.0);
            }
        }
    }
}
