//! Server-side aggregation (Algorithm 1 line 13):
//! `x_{k+1} = x_k + (1/r) Σ_{i∈S_k} Q(x_{k,τ}^{(i)} − x_k)`.

use crate::quant::{Encoded, UpdateCodec};

/// Streaming aggregator: decodes each upload and accumulates the mean
/// update in f64 (bit-stable regardless of arrival order is NOT promised —
/// floating addition — but f64 accumulation keeps the error ≪ f32 eps).
///
/// Designed to live for a whole run: [`Aggregator::reset`] rewinds it for
/// the next round while keeping the `sum` and decode-scratch allocations,
/// so the per-upload hot path ([`Aggregator::push`]) allocates nothing.
#[derive(Debug)]
pub struct Aggregator {
    sum: Vec<f64>,
    count: usize,
    bits: Vec<u64>,
    /// Reused decode buffer: one allocation per run, not per upload.
    scratch: Vec<f32>,
}

impl Aggregator {
    pub fn new(p: usize) -> Self {
        Aggregator { sum: vec![0.0; p], count: 0, bits: Vec::new(), scratch: Vec::new() }
    }

    /// Rewind for the next round, keeping all allocations.
    pub fn reset(&mut self) {
        self.sum.iter_mut().for_each(|s| *s = 0.0);
        self.count = 0;
        self.bits.clear();
    }

    /// Decode and absorb one node's upload (allocation-free: decodes into
    /// the internal scratch buffer via [`UpdateCodec::decode_into`]).
    pub fn push(&mut self, codec: &dyn UpdateCodec, enc: &Encoded) -> crate::Result<()> {
        anyhow::ensure!(
            enc.p == self.sum.len(),
            "upload dimension mismatch: {} != {}",
            enc.p,
            self.sum.len()
        );
        codec.decode_into(enc, &mut self.scratch)?;
        for (s, &v) in self.sum.iter_mut().zip(&self.scratch) {
            *s += v as f64;
        }
        self.bits.push(enc.bits());
        self.count += 1;
        Ok(())
    }

    /// Absorb an already-decoded update, skipping the wire decode — for
    /// embedders and custom transports whose uploads arrive dequantized
    /// (the arithmetic result is identical by construction when the
    /// decoded values come from the same codec). The built-in round
    /// pipeline always carries [`Encoded`] buffers and uses
    /// [`Aggregator::push`].
    pub fn push_decoded(&mut self, dec: &[f32], bits: u64) {
        assert_eq!(dec.len(), self.sum.len());
        for (s, &v) in self.sum.iter_mut().zip(dec) {
            *s += v as f64;
        }
        self.bits.push(bits);
        self.count += 1;
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Per-upload bit sizes (for the §5 communication-time model).
    pub fn upload_bits(&self) -> &[u64] {
        &self.bits
    }

    /// Apply the averaged update to `params`. Errors (instead of
    /// panicking) when no uploads arrived, so a round where every sampled
    /// node failed cannot abort a long run — the engine skips it instead.
    pub fn apply(&mut self, params: &mut [f32]) -> crate::Result<()> {
        anyhow::ensure!(self.count > 0, "no uploads to aggregate");
        let inv = 1.0 / self.count as f64;
        for (p, &s) in params.iter_mut().zip(&self.sum) {
            *p = (*p as f64 + s * inv) as f32;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{IdentityCodec, QsgdCodec, UpdateCodec};
    use crate::util::rng::Rng;

    #[test]
    fn identity_aggregation_is_mean() {
        let q = IdentityCodec;
        let mut agg = Aggregator::new(3);
        let mut rng = Rng::seed_from_u64(0);
        agg.push(&q, &q.encode(&[1.0, 2.0, 3.0], &mut rng)).unwrap();
        agg.push(&q, &q.encode(&[3.0, 0.0, -1.0], &mut rng)).unwrap();
        let mut params = vec![10.0f32, 10.0, 10.0];
        agg.apply(&mut params).unwrap();
        assert_eq!(params, vec![12.0, 11.0, 11.0]);
    }

    #[test]
    fn push_decoded_matches_push() {
        let q = QsgdCodec::new(2);
        let x = vec![0.5f32, -1.5, 2.0, 0.0];
        let mut rng1 = Rng::seed_from_u64(7);
        let mut rng2 = Rng::seed_from_u64(7);
        let enc = q.encode(&x, &mut rng1);
        let (dec, bits) = q.apply(&x, &mut rng2).unwrap();
        let mut a = Aggregator::new(4);
        a.push(&q, &enc).unwrap();
        let mut b = Aggregator::new(4);
        b.push_decoded(&dec, bits);
        let mut pa = vec![0f32; 4];
        let mut pb = vec![0f32; 4];
        a.apply(&mut pa).unwrap();
        b.apply(&mut pb).unwrap();
        assert_eq!(pa, pb);
    }

    #[test]
    fn empty_apply_is_an_error_not_a_panic() {
        let mut agg = Aggregator::new(2);
        assert!(agg.apply(&mut [0.0, 0.0]).is_err());
    }

    #[test]
    fn reset_reuses_allocations_across_rounds() {
        let q = QsgdCodec::new(1);
        let x = vec![0.25f32; 64];
        let mut rng = Rng::seed_from_u64(1);
        let mut agg = Aggregator::new(64);
        let mut first = vec![0f32; 64];
        agg.push(&q, &q.encode(&x, &mut rng)).unwrap();
        agg.apply(&mut first).unwrap();
        agg.reset();
        assert_eq!(agg.count(), 0);
        assert!(agg.upload_bits().is_empty());
        let mut again = vec![0f32; 64];
        let mut rng2 = Rng::seed_from_u64(1);
        agg.push(&q, &q.encode(&x, &mut rng2)).unwrap();
        agg.apply(&mut again).unwrap();
        assert_eq!(first, again);
    }

    #[test]
    fn mismatched_codec_push_is_rejected() {
        let enc = QsgdCodec::new(2).encode(&[1.0f32; 8], &mut Rng::seed_from_u64(2));
        let mut agg = Aggregator::new(8);
        assert!(agg.push(&QsgdCodec::new(3), &enc).is_err());
        assert_eq!(agg.count(), 0);
    }
}
