//! Server-side aggregation (Algorithm 1 line 13):
//! `x_{k+1} = x_k + (1/r) Σ_{i∈S_k} Q(x_{k,τ}^{(i)} − x_k)`.

use crate::quant::{Encoded, Quantizer};

/// Streaming aggregator: decodes each upload and accumulates the mean
/// update in f64 (bit-stable regardless of arrival order is NOT promised —
/// floating addition — but f64 accumulation keeps the error ≪ f32 eps).
#[derive(Debug)]
pub struct Aggregator {
    quantizer: Quantizer,
    sum: Vec<f64>,
    count: usize,
    bits: Vec<u64>,
}

impl Aggregator {
    pub fn new(quantizer: Quantizer, p: usize) -> Self {
        Aggregator { quantizer, sum: vec![0.0; p], count: 0, bits: Vec::new() }
    }

    /// Decode and absorb one node's upload.
    pub fn push(&mut self, enc: &Encoded) {
        assert_eq!(enc.p, self.sum.len(), "upload dimension mismatch");
        let dec = self.quantizer.decode(enc);
        for (s, v) in self.sum.iter_mut().zip(dec) {
            *s += v as f64;
        }
        self.bits.push(enc.bits());
        self.count += 1;
    }

    /// Absorb an already-decoded update (in-process fast path: skips the
    /// wire encode/decode *arithmetic result is identical by construction*
    /// because the decoded values come from the same codec).
    pub fn push_decoded(&mut self, dec: &[f32], bits: u64) {
        assert_eq!(dec.len(), self.sum.len());
        for (s, &v) in self.sum.iter_mut().zip(dec) {
            *s += v as f64;
        }
        self.bits.push(bits);
        self.count += 1;
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Per-upload bit sizes (for the §5 communication-time model).
    pub fn upload_bits(&self) -> &[u64] {
        &self.bits
    }

    /// Apply the averaged update to `params`, consuming the aggregator.
    pub fn apply(self, params: &mut [f32]) {
        assert!(self.count > 0, "no uploads to aggregate");
        let inv = 1.0 / self.count as f64;
        for (p, s) in params.iter_mut().zip(self.sum) {
            *p = (*p as f64 + s * inv) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identity_aggregation_is_mean() {
        let q = Quantizer::Identity;
        let mut agg = Aggregator::new(q, 3);
        let mut rng = Rng::seed_from_u64(0);
        agg.push(&q.encode(&[1.0, 2.0, 3.0], &mut rng));
        agg.push(&q.encode(&[3.0, 0.0, -1.0], &mut rng));
        let mut params = vec![10.0f32, 10.0, 10.0];
        agg.apply(&mut params);
        assert_eq!(params, vec![12.0, 11.0, 11.0]);
    }

    #[test]
    fn push_decoded_matches_push() {
        let q = Quantizer::qsgd(2);
        let x = vec![0.5f32, -1.5, 2.0, 0.0];
        let mut rng1 = Rng::seed_from_u64(7);
        let mut rng2 = Rng::seed_from_u64(7);
        let enc = q.encode(&x, &mut rng1);
        let (dec, bits) = q.apply(&x, &mut rng2);
        let mut a = Aggregator::new(q, 4);
        a.push(&enc);
        let mut b = Aggregator::new(q, 4);
        b.push_decoded(&dec, bits);
        let mut pa = vec![0f32; 4];
        let mut pb = vec![0f32; 4];
        a.apply(&mut pa);
        b.apply(&mut pb);
        assert_eq!(pa, pb);
    }

    #[test]
    #[should_panic(expected = "no uploads")]
    fn empty_apply_panics() {
        Aggregator::new(Quantizer::Identity, 2).apply(&mut [0.0, 0.0]);
    }
}
