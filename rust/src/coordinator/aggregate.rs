//! Server-side aggregation (Algorithm 1 line 13), staleness-aware and
//! shardable:
//! `x_{k+1} = x_k + (1/Σw_i) Σ_{i∈B_k} w_i · Q(x_{·,τ}^{(i)} − x_·)`.
//!
//! For the synchronous barrier transports every upload in the batch `B_k`
//! was trained on the current model (`staleness 0`, weight 1), and the
//! rule above reduces exactly to the paper's uniform mean. Buffered-async
//! transports ([`AsyncSim`](super::AsyncSim)) commit batches that mix
//! uploads born at older server versions; a [`StalenessRule`] damps their
//! contribution.
//!
//! ## Sharded accumulation and the determinism contract
//!
//! Every upload of a round funnels through this accumulator, so at
//! multi-million-parameter scale the f64 accumulation is the server's
//! wall-clock bottleneck once uplinks are compressed. [`ShardPlan`]
//! splits the parameter vector into disjoint contiguous ranges and
//! [`Aggregator::push_batch`] / [`Aggregator::apply_sharded`] drive one
//! scoped thread per range (`std::thread::scope` — no runtime, no extra
//! dependencies). Each shard owns `sum[lo..hi]` exclusively and replays
//! the committed uploads **in batch order** over only its range via the
//! fused
//! [`UpdateCodec::accumulate_range`](crate::quant::UpdateCodec::accumulate_range)
//! kernels: each upload's `lo..hi` window streams straight into the f64
//! accumulators, with no per-upload scratch `Vec<f32>` anywhere on the
//! hot path (the kernels are pinned bit-identical to the old
//! decode-then-add loop by `prop_accumulate_range_matches_decode_range_add`,
//! so swapping them in changed no bit of any run).
//!
//! **Determinism is a contract, not a hope:** for a fixed batch, the
//! additions landing on any single element `sum[i]` happen in exactly the
//! same order for *every* shard count — batch order, the same order the
//! sequential single-shard loop uses. Floating-point addition is
//! non-associative across *elements*, but no cross-element reassociation
//! ever occurs: shard boundaries only partition the index space, they
//! never reorder a given element's addition chain. Hence `--agg-shards N`
//! produces bit-identical models to `--agg-shards 1` for all `N` (pinned
//! by `prop_sharded_aggregation_bit_identical_to_single_shard` in
//! `rust/tests/prop_invariants.rs` and by the CI determinism leg), and
//! shard count is a pure throughput knob — free to differ between the
//! machine that trained a run and the machine that replays it.
//!
//! The fused kernels extend the same contract one level down: within an
//! upload's window each coordinate receives exactly one f64 add of
//! `weight · v` (the multiply skipped entirely at `weight == 1.0`,
//! preserving the historical unweighted mean bitwise), sparse codecs may
//! skip their implicit zeros because these accumulators never hold
//! `-0.0` (they start at `+0.0`, and round-to-nearest addition cannot
//! produce `-0.0` from it), and the `sum[i]` addition chain remains
//! batch-ordered for every shard count.
//!
//! The ledger invariants (`count`, `weight_sum`, one `upload_bits` entry
//! per absorbed upload) are enforced with real `Err`s in release builds:
//! a miscounted round aborts loudly instead of silently corrupting a
//! long run.

use crate::quant::{accumulate_slice, Encoded, FrameHeader, UpdateCodec};

/// Disjoint contiguous parameter ranges for sharded accumulation: `k`
/// near-equal ranges covering `0..p` (the first `p mod k` ranges are one
/// element longer). Built once per run from `cfg.agg_shards` and reused
/// every round.
///
/// The requested shard count is clamped to `1..=max(p, 1)` — more shards
/// than parameters would only spawn idle threads.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    /// Range boundaries: `bounds[i]..bounds[i+1]` is shard `i`;
    /// `bounds[0] == 0`, `bounds.last() == p`.
    bounds: Vec<usize>,
}

impl ShardPlan {
    pub fn new(p: usize, shards: usize) -> Self {
        let shards = shards.clamp(1, p.max(1));
        let (base, extra) = (p / shards, p % shards);
        let mut bounds = Vec::with_capacity(shards + 1);
        let mut at = 0;
        bounds.push(at);
        for i in 0..shards {
            at += base + usize::from(i < extra);
            bounds.push(at);
        }
        debug_assert_eq!(at, p);
        ShardPlan { bounds }
    }

    /// Number of shards (≥ 1).
    pub fn shards(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Total parameter count covered.
    pub fn p(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    /// Half-open range `[lo, hi)` of shard `i`.
    pub fn range(&self, i: usize) -> (usize, usize) {
        (self.bounds[i], self.bounds[i + 1])
    }

    /// All shard ranges in order.
    pub fn ranges(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.bounds.windows(2).map(|w| (w[0], w[1]))
    }
}

/// How an upload's aggregation weight decays with its staleness `s`
/// (the number of server versions committed since the upload's model was
/// broadcast). Serialized in [`ExperimentConfig`](crate::config::ExperimentConfig)
/// as `staleness_rule`.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum StalenessRule {
    /// `w(s) = 1`: plain FedBuff mean over the committed buffer.
    #[default]
    Uniform,
    /// `w(s) = (1+s)^{-a}`: polynomial damping; `a = 1` is the classic
    /// `1/(1+s)` rule, `a = 0.5` the FedBuff paper's square-root variant.
    Polynomial { a: f64 },
}

impl StalenessRule {
    /// `1/(1+s)` damping (`Polynomial` with `a = 1`).
    pub fn inverse() -> Self {
        StalenessRule::Polynomial { a: 1.0 }
    }

    /// Aggregation weight for staleness `s`. Always exactly `1.0` at
    /// `s = 0`, so fresh uploads aggregate bit-identically to the
    /// synchronous uniform mean under every rule.
    pub fn weight(&self, s: usize) -> f64 {
        match *self {
            StalenessRule::Uniform => 1.0,
            StalenessRule::Polynomial { a } => {
                if s == 0 {
                    1.0
                } else {
                    (1.0 + s as f64).powf(-a)
                }
            }
        }
    }

    /// Human label (figure curve names, logs).
    pub fn name(&self) -> String {
        match *self {
            StalenessRule::Uniform => "uniform".into(),
            StalenessRule::Polynomial { a } => format!("poly(a={a})"),
        }
    }
}

/// Streaming weighted aggregator: decodes each upload and accumulates
/// `Σ w_i · Δ_i` in f64 (bit-stable regardless of arrival order is NOT
/// promised — floating addition — but f64 accumulation keeps the error
/// ≪ f32 eps; transports that reorder uploads canonicalize the batch
/// order themselves).
///
/// Designed to live for a whole run: [`Aggregator::reset`] rewinds it for
/// the next round while keeping the `sum` allocation; the per-upload hot
/// path ([`Aggregator::push`]) streams each frame straight into the f64
/// accumulators through the fused [`UpdateCodec::accumulate_range`]
/// kernels, so it allocates nothing and materializes no scratch decode.
///
/// Every public entry point ([`push`](Aggregator::push),
/// [`push_weighted`](Aggregator::push_weighted),
/// [`push_decoded`](Aggregator::push_decoded)) funnels through one
/// internal accumulation path, so `count`, `weight_sum` and the
/// per-upload `upload_bits` record can never drift apart from what
/// [`apply`](Aggregator::apply) divides by — and the drift checks are
/// real `Err`s in release builds, not just debug assertions.
///
/// [`Aggregator::push_batch`] is the batched, shardable entry point the
/// round engine uses: sequential and bit-identical at one shard,
/// fanned across scoped threads (one per [`ShardPlan`] range) above
/// that. See the module docs for the shard-boundary determinism
/// contract.
#[derive(Debug)]
pub struct Aggregator {
    sum: Vec<f64>,
    count: usize,
    weight_sum: f64,
    bits: Vec<u64>,
}

impl Aggregator {
    pub fn new(p: usize) -> Self {
        Aggregator { sum: vec![0.0; p], count: 0, weight_sum: 0.0, bits: Vec::new() }
    }

    /// Rewind for the next round, keeping all allocations.
    pub fn reset(&mut self) {
        self.sum.iter_mut().for_each(|s| *s = 0.0);
        self.count = 0;
        self.weight_sum = 0.0;
        self.bits.clear();
    }

    /// The decoded-slice accumulation path: absorb `dec` with weight
    /// `weight`, recording `bits` uplink bits — the arithmetic the fused
    /// wire path ([`Aggregator::push_weighted`] via
    /// [`UpdateCodec::accumulate_range`]) reproduces bit for bit
    /// (`accumulate_slice` is the same weight-branched widening add the
    /// kernels fuse). The ledger check pins the invariant that one upload
    /// contributes exactly one entry to every ledger.
    fn absorb(&mut self, dec: &[f32], bits: u64, weight: f64) -> crate::Result<()> {
        anyhow::ensure!(
            dec.len() == self.sum.len(),
            "upload dimension mismatch: {} != {}",
            dec.len(),
            self.sum.len()
        );
        anyhow::ensure!(
            weight.is_finite() && weight > 0.0,
            "aggregation weight must be finite and positive, got {weight}"
        );
        accumulate_slice(&mut self.sum, dec, weight);
        self.ledger(bits, weight)
    }

    /// Advance the ledgers for one absorbed upload, enforcing their
    /// lockstep. Drift here would mean `apply` divides by a normalizer
    /// that doesn't match the absorbed uploads — a silent corruption in
    /// a long run. Checked in release builds, not just debug.
    fn ledger(&mut self, bits: u64, weight: f64) -> crate::Result<()> {
        self.bits.push(bits);
        self.count += 1;
        self.weight_sum += weight;
        anyhow::ensure!(
            self.bits.len() == self.count,
            "aggregator ledgers out of sync: {} bit records for {} uploads",
            self.bits.len(),
            self.count
        );
        Ok(())
    }

    /// Absorb a whole commit batch, sharding the f64 accumulation across
    /// `plan`'s parameter ranges on scoped threads.
    ///
    /// **Bit-identical to the sequential path for every shard count**:
    /// each shard replays the uploads in batch order over only its own
    /// `sum[lo..hi]` (streaming just that window through the fused
    /// [`UpdateCodec::accumulate_range`] kernel), so the additions
    /// landing on any single element happen in exactly the order the
    /// single-shard loop would perform them — see the module docs for
    /// the full contract.
    ///
    /// Dimensions and weights are validated up front on every path, so a
    /// malformed batch absorbs nothing. A *decode* failure mid-batch (a
    /// corrupt frame that passes the cheap checks) still errors, but
    /// leaves the aggregator partially updated — partial sums on the
    /// sharded path, fully-absorbed earlier uploads (sums *and* ledgers)
    /// on the sequential one — so the caller must
    /// [`reset`](Aggregator::reset) before reusing the aggregator after
    /// any error. The round engine never does: it treats every
    /// aggregation error as fatal to the run.
    pub fn push_batch(
        &mut self,
        codec: &dyn UpdateCodec,
        batch: &[(&Encoded, f64)],
        plan: &ShardPlan,
    ) -> crate::Result<()> {
        // Delegation at mass 1 is bitwise free: `w * 1.0 == w` exactly,
        // so every flat transport aggregates unchanged.
        let scaled: Vec<(&Encoded, f64, f64)> =
            batch.iter().map(|&(enc, w)| (enc, w, 1.0)).collect();
        self.push_batch_scaled(codec, &scaled, plan)
    }

    /// [`Aggregator::push_batch`] with a per-upload **mass**: each batch
    /// entry is `(enc, scale, mass)`, accumulated as `scale · Δ` but
    /// counted in the normalizer as `scale · mass`. A flat upload has
    /// mass 1; a tree edge-leader's *summed* partial carries its whole
    /// cohort pre-summed inside one frame, so it accumulates once but
    /// must normalize as `cohort_size` uploads — mass is that count
    /// (see [`crate::net::TcpTree`]).
    ///
    /// Frame headers are parsed **once per upload** via
    /// [`UpdateCodec::open_frame`] and shared across all shard threads
    /// through [`UpdateCodec::accumulate_range_cached`] — previously each
    /// shard re-read every upload's header, an O(shards × uploads)
    /// redundancy. The cached kernels are pinned bit-identical to the
    /// plain ones, so the shard-count determinism contract is untouched.
    pub fn push_batch_scaled(
        &mut self,
        codec: &dyn UpdateCodec,
        batch: &[(&Encoded, f64, f64)],
        plan: &ShardPlan,
    ) -> crate::Result<()> {
        anyhow::ensure!(
            plan.p() == self.sum.len(),
            "shard plan covers {} parameters, aggregator holds {}",
            plan.p(),
            self.sum.len()
        );
        // Validate the whole batch before absorbing anything, on both the
        // sequential and the sharded path, so a malformed upload anywhere
        // in the batch cannot leave a half-absorbed commit behind.
        for &(enc, scale, mass) in batch {
            anyhow::ensure!(
                enc.p == self.sum.len(),
                "upload dimension mismatch: {} != {}",
                enc.p,
                self.sum.len()
            );
            anyhow::ensure!(
                scale.is_finite() && scale > 0.0,
                "aggregation weight must be finite and positive, got {scale}"
            );
            anyhow::ensure!(
                mass.is_finite() && mass > 0.0,
                "aggregation mass must be finite and positive, got {mass}"
            );
            let w = scale * mass;
            anyhow::ensure!(
                w.is_finite() && w > 0.0,
                "aggregation weight·mass must stay finite and positive, got {w}"
            );
        }
        if plan.shards() == 1 || batch.is_empty() {
            // The historical streaming path (also the hot path for tiny
            // models, where thread spawns would dominate).
            for &(enc, scale, mass) in batch {
                codec.accumulate_range(enc, 0, enc.p, scale, &mut self.sum)?;
                self.ledger(enc.bits(), scale * mass)?;
            }
            return Ok(());
        }
        // Parse each upload's frame header exactly once, up front; shard
        // threads then accumulate against the shared cache.
        let headers: Vec<FrameHeader> = batch
            .iter()
            .map(|&(enc, _, _)| codec.open_frame(enc))
            .collect::<crate::Result<_>>()?;
        let headers = &headers;
        // Slice `sum` into the plan's disjoint ranges so each scoped
        // thread owns its shard exclusively.
        let mut shards: Vec<((usize, usize), &mut [f64])> = Vec::with_capacity(plan.shards());
        let mut rest: &mut [f64] = &mut self.sum;
        for (lo, hi) in plan.ranges() {
            let (head, tail) = rest.split_at_mut(hi - lo);
            shards.push(((lo, hi), head));
            rest = tail;
        }
        std::thread::scope(|s| {
            let handles: Vec<_> = shards
                .into_iter()
                .map(|((lo, hi), shard)| {
                    s.spawn(move || -> crate::Result<()> {
                        for (&(enc, scale, _), hdr) in batch.iter().zip(headers) {
                            // Fused kernel: the upload's window streams
                            // straight into this shard's accumulators —
                            // no scratch decode, bit-identical to one.
                            codec.accumulate_range_cached(enc, hdr, lo, hi, scale, shard)?;
                        }
                        Ok(())
                    })
                })
                .collect();
            for h in handles {
                h.join()
                    .map_err(|_| anyhow::anyhow!("aggregation shard thread panicked"))??;
            }
            Ok::<(), anyhow::Error>(())
        })?;
        // Ledgers advance in batch order — identical to the sequential
        // path (weight_sum is an f64 sum, so order matters for bit
        // reproducibility too).
        for &(enc, scale, mass) in batch {
            self.ledger(enc.bits(), scale * mass)?;
        }
        Ok(())
    }

    /// Decode and absorb one node's upload at weight 1 (allocation-free:
    /// streams the frame into the accumulators via the fused
    /// [`UpdateCodec::accumulate_range`] kernel).
    pub fn push(&mut self, codec: &dyn UpdateCodec, enc: &Encoded) -> crate::Result<()> {
        self.push_weighted(codec, enc, 1.0)
    }

    /// Decode and absorb one upload at an explicit staleness weight
    /// (see [`StalenessRule::weight`]).
    pub fn push_weighted(
        &mut self,
        codec: &dyn UpdateCodec,
        enc: &Encoded,
        weight: f64,
    ) -> crate::Result<()> {
        // Explicit dimension check first: a shorter upload must not
        // silently accumulate into a prefix of the model.
        anyhow::ensure!(
            enc.p == self.sum.len(),
            "upload dimension mismatch: {} != {}",
            enc.p,
            self.sum.len()
        );
        codec.accumulate_range(enc, 0, enc.p, weight, &mut self.sum)?;
        self.ledger(enc.bits(), weight)
    }

    /// Absorb an already-decoded update at weight 1, skipping the wire
    /// decode — for embedders and custom transports whose uploads arrive
    /// dequantized (the arithmetic result is identical by construction
    /// when the decoded values come from the same codec). Funnels through
    /// the same internal path as [`Aggregator::push`], so mixing the two
    /// on one batch keeps `count`/`weight_sum`/`upload_bits` consistent
    /// with what [`Aggregator::apply`] divides by.
    pub fn push_decoded(&mut self, dec: &[f32], bits: u64) {
        self.absorb(dec, bits, 1.0)
            .expect("push_decoded: dimension mismatch");
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Sum of the absorbed weights (the normalizer [`Aggregator::apply`]
    /// divides by). Equals `count` when every push was weight-1.
    pub fn weight_sum(&self) -> f64 {
        self.weight_sum
    }

    /// Per-upload bit sizes (for the §5 communication-time model).
    pub fn upload_bits(&self) -> &[u64] {
        &self.bits
    }

    /// Apply the weighted-mean update to `params`. Errors (instead of
    /// panicking) when no uploads arrived, so a round where every sampled
    /// node failed cannot abort a long run — the engine skips it instead.
    pub fn apply(&mut self, params: &mut [f32]) -> crate::Result<()> {
        self.apply_sharded(params, &ShardPlan::new(self.sum.len(), 1))
    }

    /// Sharded [`Aggregator::apply`]: the elementwise
    /// `params[i] += sum[i]/Σw` update split across `plan`'s ranges on
    /// scoped threads. Purely elementwise, so bit-identical for every
    /// shard count by construction.
    pub fn apply_sharded(&mut self, params: &mut [f32], plan: &ShardPlan) -> crate::Result<()> {
        anyhow::ensure!(self.count > 0, "no uploads to aggregate");
        // Ledger drift checks run in release builds too: dividing by a
        // normalizer that doesn't match the absorbed uploads would
        // silently corrupt a long run.
        anyhow::ensure!(
            self.bits.len() == self.count,
            "aggregator ledgers out of sync: {} bit records for {} uploads",
            self.bits.len(),
            self.count
        );
        anyhow::ensure!(
            self.weight_sum.is_finite() && self.weight_sum > 0.0,
            "aggregator weight_sum drifted to {} over {} uploads",
            self.weight_sum,
            self.count
        );
        anyhow::ensure!(
            params.len() == self.sum.len(),
            "apply dimension mismatch: {} params, {} accumulated",
            params.len(),
            self.sum.len()
        );
        anyhow::ensure!(
            plan.p() == self.sum.len(),
            "shard plan covers {} parameters, aggregator holds {}",
            plan.p(),
            self.sum.len()
        );
        let inv = 1.0 / self.weight_sum;
        if plan.shards() == 1 {
            for (p, &s) in params.iter_mut().zip(&self.sum) {
                *p = (*p as f64 + s * inv) as f32;
            }
            return Ok(());
        }
        std::thread::scope(|scope| {
            let mut params_rest: &mut [f32] = params;
            let mut sum_rest: &[f64] = &self.sum;
            for (lo, hi) in plan.ranges() {
                let (p_head, p_tail) = params_rest.split_at_mut(hi - lo);
                let (s_head, s_tail) = sum_rest.split_at(hi - lo);
                params_rest = p_tail;
                sum_rest = s_tail;
                scope.spawn(move || {
                    for (p, &s) in p_head.iter_mut().zip(s_head) {
                        *p = (*p as f64 + s * inv) as f32;
                    }
                });
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{IdentityCodec, QsgdCodec, UpdateCodec};
    use crate::util::rng::Rng;

    #[test]
    fn identity_aggregation_is_mean() {
        let q = IdentityCodec;
        let mut agg = Aggregator::new(3);
        let mut rng = Rng::seed_from_u64(0);
        agg.push(&q, &q.encode(&[1.0, 2.0, 3.0], &mut rng)).unwrap();
        agg.push(&q, &q.encode(&[3.0, 0.0, -1.0], &mut rng)).unwrap();
        let mut params = vec![10.0f32, 10.0, 10.0];
        agg.apply(&mut params).unwrap();
        assert_eq!(params, vec![12.0, 11.0, 11.0]);
    }

    #[test]
    fn weighted_aggregation_is_weighted_mean() {
        let q = IdentityCodec;
        let mut agg = Aggregator::new(1);
        let mut rng = Rng::seed_from_u64(0);
        // weight 1 on 4.0, weight 0.5 on 1.0: (4 + 0.5) / 1.5 = 3.0
        agg.push_weighted(&q, &q.encode(&[4.0], &mut rng), 1.0).unwrap();
        agg.push_weighted(&q, &q.encode(&[1.0], &mut rng), 0.5).unwrap();
        assert_eq!(agg.weight_sum(), 1.5);
        let mut params = vec![0.0f32];
        agg.apply(&mut params).unwrap();
        assert!((params[0] - 3.0).abs() < 1e-6, "{}", params[0]);
    }

    #[test]
    fn unit_weights_match_legacy_uniform_mean_bitwise() {
        let q = QsgdCodec::new(2);
        let xs = [vec![0.5f32, -1.5, 2.0, 0.0], vec![1.0f32, 0.25, -0.125, 3.0]];
        let mut a = Aggregator::new(4);
        let mut b = Aggregator::new(4);
        for (i, x) in xs.iter().enumerate() {
            let enc = q.encode(x, &mut Rng::seed_from_u64(i as u64));
            a.push(&q, &enc).unwrap();
            b.push_weighted(&q, &enc, 1.0).unwrap();
        }
        let mut pa = vec![7.0f32; 4];
        let mut pb = vec![7.0f32; 4];
        a.apply(&mut pa).unwrap();
        b.apply(&mut pb).unwrap();
        assert_eq!(pa, pb);
    }

    #[test]
    fn push_decoded_matches_push() {
        let q = QsgdCodec::new(2);
        let x = vec![0.5f32, -1.5, 2.0, 0.0];
        let mut rng1 = Rng::seed_from_u64(7);
        let mut rng2 = Rng::seed_from_u64(7);
        let enc = q.encode(&x, &mut rng1);
        let (dec, bits) = q.apply(&x, &mut rng2).unwrap();
        let mut a = Aggregator::new(4);
        a.push(&q, &enc).unwrap();
        let mut b = Aggregator::new(4);
        b.push_decoded(&dec, bits);
        let mut pa = vec![0f32; 4];
        let mut pb = vec![0f32; 4];
        a.apply(&mut pa).unwrap();
        b.apply(&mut pb).unwrap();
        assert_eq!(pa, pb);
    }

    #[test]
    fn mixed_push_and_push_decoded_stay_consistent() {
        // The regression the single-path refactor pins down: mixing entry
        // points must keep count/weight_sum/bits in lockstep, so apply
        // divides by exactly the number of absorbed uploads.
        let q = IdentityCodec;
        let mut rng = Rng::seed_from_u64(3);
        let mut agg = Aggregator::new(2);
        agg.push(&q, &q.encode(&[2.0, 4.0], &mut rng)).unwrap();
        agg.push_decoded(&[4.0, 8.0], 64);
        assert_eq!(agg.count(), 2);
        assert_eq!(agg.weight_sum(), 2.0);
        assert_eq!(agg.upload_bits().len(), 2);
        let mut params = vec![0.0f32, 0.0];
        agg.apply(&mut params).unwrap();
        assert_eq!(params, vec![3.0, 6.0]);
    }

    #[test]
    fn non_positive_or_non_finite_weights_rejected() {
        let q = IdentityCodec;
        let mut rng = Rng::seed_from_u64(0);
        let mut agg = Aggregator::new(1);
        let enc = q.encode(&[1.0], &mut rng);
        assert!(agg.push_weighted(&q, &enc, 0.0).is_err());
        assert!(agg.push_weighted(&q, &enc, -1.0).is_err());
        assert!(agg.push_weighted(&q, &enc, f64::NAN).is_err());
        assert_eq!(agg.count(), 0);
        assert!(agg.upload_bits().is_empty());
    }

    #[test]
    fn empty_apply_is_an_error_not_a_panic() {
        let mut agg = Aggregator::new(2);
        assert!(agg.apply(&mut [0.0, 0.0]).is_err());
    }

    #[test]
    fn reset_reuses_allocations_across_rounds() {
        let q = QsgdCodec::new(1);
        let x = vec![0.25f32; 64];
        let mut rng = Rng::seed_from_u64(1);
        let mut agg = Aggregator::new(64);
        let mut first = vec![0f32; 64];
        agg.push(&q, &q.encode(&x, &mut rng)).unwrap();
        agg.apply(&mut first).unwrap();
        agg.reset();
        assert_eq!(agg.count(), 0);
        assert_eq!(agg.weight_sum(), 0.0);
        assert!(agg.upload_bits().is_empty());
        let mut again = vec![0f32; 64];
        let mut rng2 = Rng::seed_from_u64(1);
        agg.push(&q, &q.encode(&x, &mut rng2)).unwrap();
        agg.apply(&mut again).unwrap();
        assert_eq!(first, again);
    }

    #[test]
    fn mismatched_codec_push_is_rejected() {
        let enc = QsgdCodec::new(2).encode(&[1.0f32; 8], &mut Rng::seed_from_u64(2));
        let mut agg = Aggregator::new(8);
        assert!(agg.push(&QsgdCodec::new(3), &enc).is_err());
        assert_eq!(agg.count(), 0);
    }

    #[test]
    fn shard_plan_partitions_exactly() {
        for (p, shards) in [(10, 3), (1, 1), (7, 7), (7, 100), (0, 4), (1000, 16)] {
            let plan = ShardPlan::new(p, shards);
            assert!(plan.shards() >= 1);
            assert!(plan.shards() <= shards.max(1));
            assert_eq!(plan.p(), p);
            let mut at = 0;
            let mut sizes = Vec::new();
            for (lo, hi) in plan.ranges() {
                assert_eq!(lo, at, "p={p} shards={shards}");
                assert!(hi >= lo);
                sizes.push(hi - lo);
                at = hi;
            }
            assert_eq!(at, p);
            // Near-equal: sizes differ by at most one.
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "p={p} shards={shards}: {sizes:?}");
        }
        // Degenerate zero-shard request clamps to one shard.
        assert_eq!(ShardPlan::new(10, 0).shards(), 1);
    }

    #[test]
    fn push_batch_matches_sequential_for_every_shard_count() {
        let q = QsgdCodec::new(2);
        let p = 103; // deliberately not divisible by the shard counts
        let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.21).sin()).collect();
        let mut rng = Rng::seed_from_u64(5);
        let encs: Vec<_> = (0..5).map(|_| q.encode(&x, &mut rng)).collect();
        let weights = [1.0, 0.5, 1.0, 0.25, 1.0];
        let batch: Vec<(&crate::quant::Encoded, f64)> = encs.iter().zip(weights).collect();

        let mut reference = Aggregator::new(p);
        for &(enc, w) in &batch {
            reference.push_weighted(&q, enc, w).unwrap();
        }
        let mut want = vec![0.5f32; p];
        reference.apply(&mut want).unwrap();

        for shards in [1usize, 2, 3, 7, 16, 103, 500] {
            let plan = ShardPlan::new(p, shards);
            let mut agg = Aggregator::new(p);
            agg.push_batch(&q, &batch, &plan).unwrap();
            assert_eq!(agg.count(), reference.count());
            assert_eq!(agg.upload_bits(), reference.upload_bits());
            assert_eq!(
                agg.weight_sum().to_bits(),
                reference.weight_sum().to_bits(),
                "shards={shards}"
            );
            let mut got = vec![0.5f32; p];
            agg.apply_sharded(&mut got, &plan).unwrap();
            assert_eq!(got, want, "shards={shards} not bit-identical");
        }
    }

    #[test]
    fn push_batch_scaled_mass_scales_normalizer_not_sum() {
        // One summed frame carrying a 3-upload cohort (mass 3) must equal
        // three weight-1 pushes of the same per-upload mean: the sum gets
        // one `scale · Δ` add, the normalizer gets `scale · mass`.
        let q = IdentityCodec;
        let mut rng = Rng::seed_from_u64(11);
        let summed = q.encode(&[6.0, -3.0], &mut rng); // Σ of a 3-cohort
        let plan = ShardPlan::new(2, 1);
        let mut agg = Aggregator::new(2);
        agg.push_batch_scaled(&q, &[(&summed, 1.0, 3.0)], &plan).unwrap();
        assert_eq!(agg.count(), 1);
        assert_eq!(agg.weight_sum(), 3.0);
        let mut params = [0.0f32, 0.0];
        agg.apply(&mut params).unwrap();
        assert_eq!(params, [2.0, -1.0]);
    }

    #[test]
    fn push_batch_scaled_mass_one_matches_push_batch_bitwise() {
        let q = QsgdCodec::new(2);
        let p = 57;
        let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.37).cos()).collect();
        let mut rng = Rng::seed_from_u64(12);
        let encs: Vec<_> = (0..4).map(|_| q.encode(&x, &mut rng)).collect();
        let weights = [1.0, 0.5, 0.25, 1.0];
        for shards in [1usize, 3] {
            let plan = ShardPlan::new(p, shards);
            let mut a = Aggregator::new(p);
            let batch: Vec<(&Encoded, f64)> = encs.iter().zip(weights).collect();
            a.push_batch(&q, &batch, &plan).unwrap();
            let mut b = Aggregator::new(p);
            let scaled: Vec<(&Encoded, f64, f64)> =
                encs.iter().zip(weights).map(|(e, w)| (e, w, 1.0)).collect();
            b.push_batch_scaled(&q, &scaled, &plan).unwrap();
            assert_eq!(a.weight_sum().to_bits(), b.weight_sum().to_bits());
            let (mut pa, mut pb) = (vec![0.5f32; p], vec![0.5f32; p]);
            a.apply_sharded(&mut pa, &plan).unwrap();
            b.apply_sharded(&mut pb, &plan).unwrap();
            assert_eq!(pa, pb, "shards={shards}");
        }
    }

    #[test]
    fn push_batch_header_cache_matches_sequential_for_sparse_codec() {
        // Seeded rand-k is the codec whose open_frame does real work
        // (index regeneration); the cached sharded path must stay
        // bit-identical to the sequential one.
        use crate::quant::RandKCodec;
        let q = RandKCodec::new(250);
        let p = 103;
        let x: Vec<f32> = (0..p).map(|i| ((i as f32) * 0.13).sin()).collect();
        let mut rng = Rng::seed_from_u64(13);
        let encs: Vec<_> = (0..5).map(|_| q.encode(&x, &mut rng)).collect();
        let batch: Vec<(&Encoded, f64)> = encs.iter().map(|e| (e, 1.0)).collect();
        let mut reference = Aggregator::new(p);
        reference
            .push_batch(&q, &batch, &ShardPlan::new(p, 1))
            .unwrap();
        let mut want = vec![0.25f32; p];
        reference.apply(&mut want).unwrap();
        for shards in [2usize, 7, 103] {
            let plan = ShardPlan::new(p, shards);
            let mut agg = Aggregator::new(p);
            agg.push_batch(&q, &batch, &plan).unwrap();
            let mut got = vec![0.25f32; p];
            agg.apply_sharded(&mut got, &plan).unwrap();
            assert_eq!(got, want, "shards={shards} not bit-identical");
        }
    }

    #[test]
    fn push_batch_scaled_rejects_bad_mass() {
        let q = IdentityCodec;
        let mut rng = Rng::seed_from_u64(14);
        let enc = q.encode(&[1.0], &mut rng);
        let plan = ShardPlan::new(1, 1);
        for mass in [0.0, -2.0, f64::NAN, f64::INFINITY] {
            let mut agg = Aggregator::new(1);
            assert!(agg
                .push_batch_scaled(&q, &[(&enc, 1.0, mass)], &plan)
                .is_err());
            assert_eq!(agg.count(), 0, "mass={mass}");
        }
    }

    #[test]
    fn push_batch_rejects_bad_uploads_without_absorbing_any() {
        let q = IdentityCodec;
        let mut rng = Rng::seed_from_u64(8);
        let good = q.encode(&[1.0, 2.0], &mut rng);
        let wrong_dim = q.encode(&[1.0, 2.0, 3.0], &mut rng);
        // Validation is up-front on BOTH the sequential (1-shard) and the
        // sharded path: a bad upload anywhere in the batch absorbs
        // nothing, even when a good upload precedes it.
        for shards in [1usize, 2] {
            let plan = ShardPlan::new(2, shards);
            let mut agg = Aggregator::new(2);
            assert!(agg
                .push_batch(&q, &[(&good, 1.0), (&wrong_dim, 1.0)], &plan)
                .is_err());
            assert!(agg
                .push_batch(&q, &[(&good, 1.0), (&good, 0.0)], &plan)
                .is_err());
            assert!(agg.push_batch(&q, &[(&good, f64::NAN)], &plan).is_err());
            assert_eq!(agg.count(), 0, "shards={shards}");
            assert_eq!(agg.weight_sum(), 0.0, "shards={shards}");
            assert!(agg.upload_bits().is_empty(), "shards={shards}");
            let mut params = [9.0f32, 9.0];
            assert!(agg.apply_sharded(&mut params, &plan).is_err());
            assert_eq!(params, [9.0, 9.0], "shards={shards}: sum leaked into apply");
            // Plan/aggregator size mismatch is rejected too.
            assert!(agg
                .push_batch(&q, &[(&good, 1.0)], &ShardPlan::new(3, shards))
                .is_err());
        }
    }

    #[test]
    fn apply_sharded_rejects_mismatched_params_or_plan() {
        let q = IdentityCodec;
        let mut rng = Rng::seed_from_u64(9);
        let mut agg = Aggregator::new(3);
        agg.push(&q, &q.encode(&[1.0, 2.0, 3.0], &mut rng)).unwrap();
        let plan = ShardPlan::new(3, 2);
        assert!(agg.apply_sharded(&mut [0.0, 0.0], &plan).is_err());
        assert!(agg
            .apply_sharded(&mut [0.0, 0.0, 0.0], &ShardPlan::new(4, 2))
            .is_err());
        let mut ok = [0.0, 0.0, 0.0];
        agg.apply_sharded(&mut ok, &plan).unwrap();
        assert_eq!(ok, [1.0, 2.0, 3.0]);
    }

    #[test]
    fn staleness_rules_weight_as_documented() {
        assert_eq!(StalenessRule::Uniform.weight(0), 1.0);
        assert_eq!(StalenessRule::Uniform.weight(100), 1.0);
        let inv = StalenessRule::inverse();
        assert_eq!(inv.weight(0), 1.0);
        assert!((inv.weight(1) - 0.5).abs() < 1e-12);
        assert!((inv.weight(3) - 0.25).abs() < 1e-12);
        let sqrt = StalenessRule::Polynomial { a: 0.5 };
        assert_eq!(sqrt.weight(0), 1.0);
        assert!((sqrt.weight(3) - 0.5).abs() < 1e-12);
        // Monotone non-increasing in s for every rule.
        for rule in [StalenessRule::Uniform, inv, sqrt] {
            for s in 0..20 {
                assert!(rule.weight(s + 1) <= rule.weight(s));
                assert!(rule.weight(s) > 0.0);
            }
        }
    }
}
