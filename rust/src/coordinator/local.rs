//! Node-side local training (Algorithm 1 lines 4–11), shared by the sim
//! engine and the TCP worker: gather the τ minibatches from the node's
//! shard, run the engine's chained local SGD, quantize the model delta.

use crate::config::ExperimentConfig;
use crate::data::{BatchSampler, FederatedDataset, Shard};
use crate::model::{Engine, LabelBatch};
use crate::quant::{Encoded, UpdateCodec};

/// Owned label storage for gathered batches.
#[derive(Debug, Clone)]
pub enum OwnedLabels {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl OwnedLabels {
    pub fn as_batch(&self) -> LabelBatch<'_> {
        match self {
            OwnedLabels::F32(v) => LabelBatch::F32(v),
            OwnedLabels::I32(v) => LabelBatch::I32(v),
        }
    }
}

/// Reusable gather buffers (allocation-free hot loop).
#[derive(Debug, Default)]
pub struct GatherBufs {
    pub idx: Vec<usize>,
    pub x: Vec<f32>,
    pub y_f32: Vec<f32>,
    pub y_i32: Vec<i32>,
}

/// Gather the τ minibatches node `node` uses in round `round`.
///
/// Returns `(xs, ys)` with `xs` holding `τ·B` feature rows back-to-back.
/// Batch indices are deterministic in `(seed, node, round, step)` so every
/// engine resamples identical batches.
pub fn gather_local_batches(
    data: &FederatedDataset,
    shard: Shard<'_>,
    sampler: &BatchSampler,
    node: usize,
    round: usize,
    tau: usize,
    bufs: &mut GatherBufs,
) -> OwnedLabels {
    let b = sampler.batch_size();
    bufs.idx.resize(b, 0);
    bufs.x.clear();
    bufs.y_f32.clear();
    bufs.y_i32.clear();
    let float_labels = matches!(data.labels, crate::data::Labels::Float(_));
    let mut xtmp = Vec::new();
    let mut ytmp_f = Vec::new();
    let mut ytmp_i = Vec::new();
    for t in 0..tau {
        sampler.sample_into(node, round, t, shard.len(), &mut bufs.idx);
        // Map shard-relative indices to dataset indices.
        let abs: Vec<usize> = bufs.idx.iter().map(|&i| shard.get(i)).collect();
        data.gather_features(&abs, &mut xtmp);
        bufs.x.extend_from_slice(&xtmp);
        if float_labels {
            data.gather_labels_f32(&abs, &mut ytmp_f);
            bufs.y_f32.extend_from_slice(&ytmp_f);
        } else {
            data.gather_labels_i32(&abs, &mut ytmp_i);
            bufs.y_i32.extend_from_slice(&ytmp_i);
        }
    }
    if float_labels {
        OwnedLabels::F32(bufs.y_f32.clone())
    } else {
        OwnedLabels::I32(bufs.y_i32.clone())
    }
}

/// Full node round: local SGD then compress-and-encode the delta through
/// the run's [`UpdateCodec`] — via [`UpdateCodec::encode_node`], so
/// stateful codecs (error feedback) key their per-node memory correctly
/// on both execution modes: the sim funnels every node through one codec
/// instance here, and the TCP worker calls the same function with its
/// own per-process instance (node → worker assignment is pinned by node
/// id, so a node's residual stream never splits across workers).
///
/// Returns the encoded upload (and its exact bit size via `enc.bits()`).
#[allow(clippy::too_many_arguments)]
pub fn node_round(
    cfg: &ExperimentConfig,
    codec: &dyn UpdateCodec,
    engine: &mut dyn Engine,
    data: &FederatedDataset,
    shard: Shard<'_>,
    sampler: &BatchSampler,
    node: usize,
    round: usize,
    global_params: &[f32],
    lrs: &[f32],
    bufs: &mut GatherBufs,
) -> crate::Result<Encoded> {
    let labels = gather_local_batches(data, shard, sampler, node, round, cfg.tau, bufs);
    let new_params = engine.local_sgd(global_params, &bufs.x, labels.as_batch(), lrs)?;
    let delta: Vec<f32> = new_params
        .iter()
        .zip(global_params)
        .map(|(&a, &b)| a - b)
        .collect();
    let mut qrng = quant_rng(cfg.seed, node, round);
    Ok(codec.encode_node(node, &delta, &mut qrng))
}

/// Quantizer RNG stream for `(seed, node, round)` — shared with the TCP
/// worker so both execution modes produce identical uploads.
pub fn quant_rng(seed: u64, node: usize, round: usize) -> crate::util::rng::Rng {
    crate::util::rng::Rng::from_coords(seed, &[3, node as u64, round as u64])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetKind, Partition};

    #[test]
    fn gather_shapes_and_determinism() {
        let data = FederatedDataset::generate(DatasetKind::Mnist08, 1, 1000);
        let part = Partition::iid(1000, 10, 100);
        let sampler = BatchSampler::new(1, 10);
        let mut b1 = GatherBufs::default();
        let mut b2 = GatherBufs::default();
        let y1 = gather_local_batches(&data, part.shard(3), &sampler, 3, 7, 5, &mut b1);
        let y2 = gather_local_batches(&data, part.shard(3), &sampler, 3, 7, 5, &mut b2);
        assert_eq!(b1.x.len(), 5 * 10 * 784);
        assert_eq!(b1.x, b2.x);
        match (y1, y2) {
            (OwnedLabels::F32(a), OwnedLabels::F32(b)) => assert_eq!(a, b),
            _ => panic!("expected float labels"),
        }
    }

    #[test]
    fn gather_uses_only_own_shard() {
        let data = FederatedDataset::generate(DatasetKind::Mnist08, 2, 200);
        let part = Partition::iid(200, 4, 50);
        let sampler = BatchSampler::new(2, 10);
        let mut bufs = GatherBufs::default();
        gather_local_batches(&data, part.shard(0), &sampler, 0, 0, 3, &mut bufs);
        // Every gathered row must match a row of shard 0.
        for row_i in 0..30 {
            let row = &bufs.x[row_i * 784..(row_i + 1) * 784];
            let found = part
                .shard(0)
                .iter()
                .any(|abs| data.row(abs) == row);
            assert!(found, "row {row_i} not from shard 0");
        }
    }
}
