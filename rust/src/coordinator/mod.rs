//! Layer-3 coordinator: the FedPAQ training protocol (paper Algorithm 1).
//!
//! The [`Server`] owns the global model and drives `K = T/τ` rounds:
//!
//! 1. sample `r` of `n` nodes uniformly without replacement ([`sampler`]);
//! 2. broadcast the current model `x_k` to the sampled nodes;
//! 3. each node runs `τ` local SGD steps on its own shard ([`local`]);
//! 4. each node uploads `Q(x_{k,τ}^{(i)} − x_k)` ([`crate::quant`]);
//! 5. server sets `x_{k+1} = x_k + (1/r) Σ Q(Δ_i)` ([`aggregate`]);
//! 6. the virtual clock advances by the round's straggler-compute plus
//!    serialized-upload time ([`crate::simtime`]).
//!
//! Baselines fall out of the same loop: **FedAvg** = identity quantizer,
//! **QSGD** = `τ = 1`, vanilla parallel SGD = both.

pub mod aggregate;
pub mod local;
pub mod sampler;
pub mod server;

pub use server::{RoundStats, RunResult, Server};
