//! Layer-3 coordinator: the FedPAQ training protocol (paper Algorithm 1)
//! as a *composition of pluggable parts*.
//!
//! One server **commit** of the protocol is
//!
//! 1. sample `r` of `n` nodes uniformly without replacement ([`sampler`]);
//! 2. broadcast the current model `x_k` to the dispatched nodes;
//! 3. each node runs `τ` local SGD steps on its own shard ([`local`]);
//! 4. each node uploads `Q(x_{k,τ}^{(i)} − x_k)` compressed by an
//!    [`UpdateCodec`](crate::quant::UpdateCodec);
//! 5. server sets `x_{k+1} = x_k + (1/Σw) Σ w_s · Q(Δ_i)` ([`aggregate`],
//!    with `w_s` a per-upload staleness weight — identically 1 on the
//!    synchronous path, matching the paper exactly);
//! 6. the clock advances — §5 virtual time ([`crate::simtime`]) for
//!    simulated transports, wall-clock for networked ones.
//!
//! The pieces compose through three seams:
//!
//! * **[`transport::Transport`]** — *where and when* steps 2–4 execute.
//!   The transports split along the sync/async axis:
//!
//!   | transport | protocol | time axis |
//!   |---|---|---|
//!   | [`transport::InProcess`] | synchronous barrier (Algorithm 1) | §5 virtual |
//!   | [`crate::net::Tcp`] | synchronous barrier, worker processes | wall-clock |
//!   | [`async_sim::AsyncSim`] | buffered async (FedBuff-style) | §5 virtual, event-driven |
//!   | [`crate::net::TcpAsync`] | buffered async, worker processes | wall-clock, event-driven |
//!
//!   The barrier transports wait for every sampled node, so a commit *is*
//!   a round of Algorithm 1; equal seeds give bit-identical models
//!   in-process or over sockets.
//! * **[`commit_loop::CommitPlanner`]** — *what the buffered-async
//!   protocol decides*. A pure, seeded state machine consuming events
//!   (upload arrived, capacity freed) and emitting decisions (dispatch,
//!   drop, commit): it owns the buffer threshold, the `max_staleness`
//!   cap with straggler re-dispatch, and the
//!   never-duplicate-`(node, version)` invariant. `AsyncSim` feeds it
//!   virtual-completion-time arrivals, `net::TcpAsync` feeds it real
//!   socket arrivals — one implementation of the commit rules for both,
//!   and both degenerate bit-exactly to their barrier twins at
//!   `buffer_size == r`, `max_staleness == 0`.
//! * **[`crate::quant::UpdateCodec`]** — *how* step 4 compresses uploads.
//!
//! [`engine::RoundEngine`] drives the per-commit loop (and surfaces the
//! async drop/staleness telemetry in
//! [`RoundStats`](engine::RoundStats));
//! [`server::ServerBuilder`] assembles `config × engine × codec ×
//! transport` (picking `AsyncSim` automatically when
//! `cfg.async_rounds` is set) and [`server::Server`] keeps the
//! historical one-call entry point.
//!
//! Step 2's broadcast has its own optional codec seam: with
//! `cfg.down_codec` set, the server ships compressed deltas against a
//! shared reference model instead of raw f32 ([`downlink`],
//! QAFeL-style), and each commit's [`transport::ModelFrame`] carries the
//! newest chain link alongside the dense reference.
//!
//! Baselines fall out of the same pipeline: **FedAvg** = identity codec,
//! **QSGD** = `τ = 1`, vanilla parallel SGD = both, **FedBuff** =
//! `async_rounds` + identity codec.

pub mod aggregate;
pub mod async_sim;
pub mod commit_loop;
pub mod downlink;
pub mod engine;
pub mod local;
pub mod sampler;
pub mod server;
pub mod transport;

pub use aggregate::{Aggregator, ShardPlan, StalenessRule};
pub use async_sim::AsyncSim;
pub use commit_loop::{CommitPlanner, Decision, PlannerEvent, PlannerState};
pub use downlink::DownlinkEncoder;
pub use engine::{EvalSlab, RoundEngine, RoundStats, RunMeta, RunResult};
pub use server::{Server, ServerBuilder};
pub use transport::{
    CommitTiming, InProcess, ModelFrame, RoundCtx, RoundOutcome, Transport, Upload,
};
