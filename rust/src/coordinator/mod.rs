//! Layer-3 coordinator: the FedPAQ training protocol (paper Algorithm 1)
//! as a *composition of pluggable parts*.
//!
//! One round of the protocol is
//!
//! 1. sample `r` of `n` nodes uniformly without replacement ([`sampler`]);
//! 2. broadcast the current model `x_k` to the sampled nodes;
//! 3. each node runs `τ` local SGD steps on its own shard ([`local`]);
//! 4. each node uploads `Q(x_{k,τ}^{(i)} − x_k)` compressed by an
//!    [`UpdateCodec`](crate::quant::UpdateCodec);
//! 5. server sets `x_{k+1} = x_k + (1/r) Σ Q(Δ_i)` ([`aggregate`]);
//! 6. the clock advances — §5 virtual time ([`crate::simtime`]) for
//!    simulated transports, wall-clock for networked ones.
//!
//! The pieces compose through two seams:
//!
//! * **[`transport::Transport`]** — *where* steps 2–4 execute:
//!   [`transport::InProcess`] runs every virtual node on the leader's own
//!   engine (the simulation path), [`crate::net::Tcp`] fans the same work
//!   out to worker processes over sockets. Same codecs, same RNG streams:
//!   equal seeds give bit-identical models either way.
//! * **[`crate::quant::UpdateCodec`]** — *how* step 4 compresses uploads.
//!
//! [`engine::RoundEngine`] drives the loop; [`server::ServerBuilder`]
//! assembles `config × engine × codec × transport` and
//! [`server::Server`] keeps the historical one-call entry point.
//!
//! Baselines fall out of the same pipeline: **FedAvg** = identity codec,
//! **QSGD** = `τ = 1`, vanilla parallel SGD = both.

pub mod aggregate;
pub mod engine;
pub mod local;
pub mod sampler;
pub mod server;
pub mod transport;

pub use engine::{EvalSlab, RoundEngine, RoundStats, RunResult};
pub use server::{Server, ServerBuilder};
pub use transport::{InProcess, RoundCtx, Transport};
