//! Partial node participation (paper §3.2): per round, `r` of `n` nodes
//! are sampled uniformly without replacement — `Pr[S_k] = 1/C(n,r)`.

use crate::util::rng::Rng;

/// Sample the participant set `S_k` for round `round`.
///
/// Deterministic in `(seed, round)`; partial Fisher–Yates, O(n) time.
pub fn sample_nodes(n: usize, r: usize, seed: u64, round: usize) -> Vec<usize> {
    assert!(r >= 1 && r <= n, "r={r} out of 1..={n}");
    let mut rng = rng_for(seed, round);
    let mut pool: Vec<usize> = (0..n).collect();
    for i in 0..r {
        let j = rng.gen_range(i, n);
        pool.swap(i, j);
    }
    pool.truncate(r);
    pool
}

fn rng_for(seed: u64, round: usize) -> Rng {
    Rng::from_coords(seed, &[2, round as u64])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn distinct_and_in_range() {
        for round in 0..50 {
            let s = sample_nodes(50, 25, 7, round);
            assert_eq!(s.len(), 25);
            let set: HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 25, "duplicates in round {round}");
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn full_participation_is_everyone() {
        let mut s = sample_nodes(10, 10, 3, 0);
        s.sort_unstable();
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_and_varies_by_round() {
        assert_eq!(sample_nodes(50, 5, 1, 2), sample_nodes(50, 5, 1, 2));
        assert_ne!(sample_nodes(50, 5, 1, 2), sample_nodes(50, 5, 1, 3));
    }

    #[test]
    fn marginal_inclusion_is_uniform() {
        // Each node should appear in ≈ rounds*r/n samples.
        let (n, r, rounds) = (20usize, 5usize, 4000usize);
        let mut counts = vec![0usize; n];
        for k in 0..rounds {
            for i in sample_nodes(n, r, 99, k) {
                counts[i] += 1;
            }
        }
        let expect = rounds * r / n; // 1000
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect as f64).abs() < 0.15 * expect as f64,
                "node {i}: {c} vs {expect}"
            );
        }
    }
}
