//! Partial node participation (paper §3.2): per round, `r` of `n` nodes
//! are sampled uniformly without replacement — `Pr[S_k] = 1/C(n,r)`.
//!
//! Cost is O(r) time and memory, independent of the cohort size `n`
//! (Floyd's algorithm) — a 10^7-client cohort samples its wave without
//! ever materializing O(n) state, part of the simulator's O(active)
//! contract. Note the historical implementation was a partial
//! Fisher–Yates over a full `(0..n)` pool: the *distribution* is the
//! same, but the concrete sets drawn from a given seed differ, which is
//! why `ops::CHECKPOINT_VERSION` was bumped when Floyd sampling landed
//! (a pre-bump checkpoint would resume onto different cohorts).

use crate::util::rng::Rng;

/// Sample the participant set `S_k` for round `round`.
///
/// Deterministic in `(seed, round)`; Floyd's algorithm, O(r) time.
pub fn sample_nodes(n: usize, r: usize, seed: u64, round: usize) -> Vec<usize> {
    assert!(r >= 1 && r <= n, "r={r} out of 1..={n}");
    let mut rng = rng_for(seed, round);
    let mut seen = std::collections::HashSet::with_capacity(r);
    let mut out = Vec::with_capacity(r);
    for j in (n - r)..n {
        let t = rng.gen_range(0, j + 1);
        // t already chosen ⇒ take j instead (j is new by construction):
        // this is what makes every r-subset equally likely.
        let pick = if seen.insert(t) {
            t
        } else {
            seen.insert(j);
            j
        };
        out.push(pick);
    }
    out
}

fn rng_for(seed: u64, round: usize) -> Rng {
    Rng::from_coords(seed, &[2, round as u64])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn distinct_and_in_range() {
        for round in 0..50 {
            let s = sample_nodes(50, 25, 7, round);
            assert_eq!(s.len(), 25);
            let set: HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 25, "duplicates in round {round}");
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn full_participation_is_everyone() {
        let mut s = sample_nodes(10, 10, 3, 0);
        s.sort_unstable();
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_and_varies_by_round() {
        assert_eq!(sample_nodes(50, 5, 1, 2), sample_nodes(50, 5, 1, 2));
        assert_ne!(sample_nodes(50, 5, 1, 2), sample_nodes(50, 5, 1, 3));
    }

    #[test]
    fn marginal_inclusion_is_uniform() {
        // Each node should appear in ≈ rounds*r/n samples.
        let (n, r, rounds) = (20usize, 5usize, 4000usize);
        let mut counts = vec![0usize; n];
        for k in 0..rounds {
            for i in sample_nodes(n, r, 99, k) {
                counts[i] += 1;
            }
        }
        let expect = rounds * r / n; // 1000
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect as f64).abs() < 0.15 * expect as f64,
                "node {i}: {c} vs {expect}"
            );
        }
    }
}
