//! # FedPAQ — communication-efficient federated learning
//!
//! Production-grade reproduction of *FedPAQ: A Communication-Efficient
//! Federated Learning Method with Periodic Averaging and Quantization*
//! (Reisizadeh, Mokhtari, Hassani, Jadbabaie, Pedarsani — AISTATS 2020).
//!
//! ## Composable round pipeline
//!
//! A training run is a composition of four pluggable parts, assembled by
//! [`coordinator::ServerBuilder`] and driven by one shared
//! [`coordinator::RoundEngine`] loop (`sample → local work → aggregate →
//! apply`):
//!
//! * **[`config::ExperimentConfig`]** — the experiment: model, data, the
//!   FedPAQ knobs `(n, r, τ)`, seeds, and a tagged codec spec
//!   ([`quant::CodecSpec`]). JSON in, JSON out; a config + seed fully
//!   determines the run.
//! * **[`model::Engine`]** — who does the math:
//!   [`runtime::PjrtEngine`] (AOT-lowered JAX/Pallas HLO via PJRT) or the
//!   pure-rust [`model::RustEngine`] oracle.
//! * **[`quant::UpdateCodec`]** — how uploads are compressed: identity
//!   (FedAvg), QSGD with naive or Elias-ω level coding (the paper),
//!   adaptive-level QSGD driven by a bits-per-coordinate budget, top-k
//!   and seeded random-k sparsification (the latter ships no index
//!   payload), a stateful per-node error-feedback wrapper
//!   ([`quant::ErrorFeedbackCodec`]), or any external impl of the trait
//!   (external impls run in-process; distributed workers rebuild codecs
//!   from the config's tagged spec — node → worker assignment is pinned
//!   by node id so worker-side codec state stays coherent). The `quant`
//!   module doc is the codec-author guide; a CI conformance matrix runs
//!   the shared property suites once per codec family
//!   (`FEDPAQ_CODEC_FILTER`), and per-codec encode/decode throughput is
//!   bench-gated (`BENCH_codecs.json` vs `rust/benches/baseline/`).
//! * **[`coordinator::Transport`]** — where *and when* node work runs.
//!   Synchronous barriers: [`coordinator::InProcess`] (the simulation
//!   path, time charged to the paper's §5 virtual cost model) or
//!   [`net::Tcp`] (real worker processes over sockets, wall-clock time) —
//!   same codecs, same RNG streams, equal seeds give bit-identical models
//!   either way. Buffered async: one event-driven commit core — the pure,
//!   seeded [`coordinator::commit_loop::CommitPlanner`] — commits as soon
//!   as `cfg.buffer_size` uploads arrive; stragglers land in later
//!   commits, damped by the config's [`coordinator::StalenessRule`], and
//!   uploads staler than `cfg.max_staleness` are dropped and their
//!   capacity re-dispatched. [`coordinator::AsyncSim`] feeds the planner
//!   virtual-completion-time arrivals (FedBuff-style simulation);
//!   [`net::TcpAsync`] feeds it real socket arrivals, so the same
//!   staleness-aware protocol runs barrier-free on a live cluster. At
//!   `buffer_size == r`, `max_staleness == 0` both reproduce their
//!   synchronous twins bit-exactly.
//!
//! ## Sharded aggregation
//!
//! The server-side accumulation — the one place every upload of a round
//! funnels through — shards across disjoint parameter ranges on scoped
//! threads when `cfg.agg_shards > 1` (CLI: `--agg-shards N`). Each shard
//! decodes only its own coordinate range of every upload through
//! [`quant::UpdateCodec::decode_range`] and replays the batch in order,
//! so results are **bit-identical for every shard count** — see the
//! [`coordinator::aggregate`] module docs for the determinism contract.
//! All four transports (`InProcess`, `AsyncSim`, `net::Tcp`,
//! `net::TcpAsync`) reuse the
//! one sharded path inside [`coordinator::RoundEngine`]. The
//! ≥1M-parameter `aggregate` micro-bench publishes its throughput as
//! `BENCH_aggregate.json` on every CI push, gated against
//! `rust/benches/baseline/` by `python/bench_check.py`.
//!
//! ```ignore
//! let mut engine = RustEngine::new(kind, batch, eval_n)?;
//! let result = ServerBuilder::new(cfg)
//!     .engine(&mut engine)
//!     .codec(TopKCodec::new(100))   // optional override of cfg.codec (in-process
//!     .transport(InProcess::new())  //  transports; for net::Tcp::new(addr, n),
//!     .build()?                     //  set cfg.codec to a built-in spec instead)
//!     .run()?;
//!
//! // Buffered-async rounds: set the config knobs and the builder picks
//! // the AsyncSim transport automatically (see configs/async_fedbuff_logreg.json).
//! let cfg = cfg.with_async(4, 8)    // buffer_size, max_staleness
//!     .with_staleness_rule(StalenessRule::inverse()); // w(s) = 1/(1+s)
//! let result = ServerBuilder::new(cfg).engine(&mut engine).build()?.run()?;
//! ```
//!
//! ## Bidirectional compression (see `docs/PROTOCOL.md`)
//!
//! The same [`quant::UpdateCodec`] trait drives both wire directions.
//! `cfg.down_codec` (CLI: `--down-s`/`--down-topk`/... mirroring the
//! uplink flags) compresses the server→client broadcast as a chain of
//! encoded model *deltas* against a shared reference model the server
//! maintains ([`coordinator::DownlinkEncoder`], QAFeL-style hidden
//! state): round 0 ships dense and seeds the reference, every later
//! round ships `encode(x_k − ref)` and advances the reference by its own
//! decode, so server and every client hold bit-identical references
//! without ever re-sending the dense model. Download traffic is billed
//! per virtual node from the per-version link sizes
//! ([`metrics::CurvePoint::bits_down`], `RunResult::total_bits_down`),
//! identically across all four transports; on real sockets the leader
//! ships each worker only the links it is missing and re-bases dead or
//! late-joining workers with a dense frame ([`net::proto::ModelPayload`],
//! wire protocol v3).
//!
//! ## Operable runs (see `docs/OPERATIONS.md`)
//!
//! The [`ops`] layer makes long runs killable and watchable:
//! [`ops::Checkpoint`] snapshots model, history, codec residuals and the
//! full async-planner state to an atomically-written versioned file
//! (`--checkpoint FILE --checkpoint-every N`), and `--resume FILE`
//! continues a run **byte-identically** to its uninterrupted twin — CI
//! kills and resumes runs and diffs the result JSONs. Every protocol
//! decision (dispatch, arrival, drop, commit, worker churn) streams to a
//! JSONL [`ops::EventSink`] (`--events FILE`) with a documented stable
//! schema; [`net::TcpAsync`] tolerates workers joining or dying mid-run,
//! retiring a dead worker's in-flight jobs through the planner instead of
//! hanging.
//!
//! ## Three-layer architecture (see `DESIGN.md`)
//!
//! * **Layer 3 (this crate)** — the federated coordinator: node sampling,
//!   periodic averaging rounds, pluggable update compression, the paper's
//!   §5 communication/computation cost model, baselines (FedAvg, QSGD), a
//!   real TCP leader/worker mode, and the figure-regeneration harness.
//! * **Layer 2** — JAX model programs (`python/compile/model.py`), AOT
//!   lowered once to HLO text and executed here through PJRT
//!   ([`runtime`]); python never runs on the training path.
//! * **Layer 1** — Pallas kernels (dense matmul + the QSGD quantizer)
//!   called from the L2 programs.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod figures;
pub mod metrics;
pub mod model;
pub mod net;
pub mod ops;
pub mod opt;
pub mod quant;
pub mod runtime;
pub mod simtime;
pub mod theory;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Bits used by an *unquantized* f32 coordinate on the wire (paper §5: `F`).
pub const FLOAT_BITS: u64 = 32;
