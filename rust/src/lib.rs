//! # FedPAQ — communication-efficient federated learning
//!
//! Production-grade reproduction of *FedPAQ: A Communication-Efficient
//! Federated Learning Method with Periodic Averaging and Quantization*
//! (Reisizadeh, Mokhtari, Hassani, Jadbabaie, Pedarsani — AISTATS 2020).
//!
//! Three-layer architecture (see `DESIGN.md`):
//!
//! * **Layer 3 (this crate)** — the federated coordinator: node sampling,
//!   periodic averaging rounds, quantized message passing, the paper's §5
//!   communication/computation cost model, baselines (FedAvg, QSGD), a real
//!   TCP leader/worker mode, and the figure-regeneration harness.
//! * **Layer 2** — JAX model programs (`python/compile/model.py`), AOT
//!   lowered once to HLO text and executed here through PJRT
//!   ([`runtime`]); python never runs on the training path.
//! * **Layer 1** — Pallas kernels (dense matmul + the QSGD quantizer)
//!   called from the L2 programs.
//!
//! The crate is usable as a library: build a [`config::ExperimentConfig`],
//! construct an engine ([`runtime::PjrtEngine`] or the pure-rust
//! [`model::RustEngine`]), and drive [`coordinator::Server`].

pub mod config;
pub mod coordinator;
pub mod data;
pub mod figures;
pub mod metrics;
pub mod model;
pub mod net;
pub mod opt;
pub mod quant;
pub mod runtime;
pub mod simtime;
pub mod theory;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Bits used by an *unquantized* f32 coordinate on the wire (paper §5: `F`).
pub const FLOAT_BITS: u64 = 32;
