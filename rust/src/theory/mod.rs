//! The paper's convergence-theory constants (Theorems 1 and 2).
//!
//! Everything here is a direct transcription of the formulas in §4 /
//! supplementary §7–8, used by `examples/theory_check.rs` to overlay the
//! predicted rates on measured optimality gaps, and by the test suite to
//! sanity-check monotonicities (e.g. more participation ⇒ smaller
//! constants; finer quantization ⇒ smaller `q`).

/// Problem-level constants the bounds are expressed in.
#[derive(Debug, Clone, Copy)]
pub struct ProblemConsts {
    /// Smoothness `L` (Assumption 2).
    pub l_smooth: f64,
    /// Strong convexity `μ` (Assumption 4; only for Theorem 1).
    pub mu: f64,
    /// Stochastic-gradient variance `σ²` (Assumption 3).
    pub sigma2: f64,
    /// Quantizer variance parameter `q` (Assumption 1).
    pub q: f64,
    /// Total nodes `n` and per-round participants `r`.
    pub n: usize,
    pub r: usize,
}

impl ProblemConsts {
    fn part(&self) -> f64 {
        // The recurring participation factor (n-r)/(r(n-1)); 0 when r=n.
        let (n, r) = (self.n as f64, self.r as f64);
        if self.n == 1 {
            0.0
        } else {
            (n - r) / (r * (n - 1.0))
        }
    }

    /// `B1 = 2L²( q/n + 4(1+q)(n−r)/(r(n−1)) )` — eq. (10).
    pub fn b1(&self) -> f64 {
        2.0 * self.l_smooth.powi(2)
            * (self.q / self.n as f64 + 4.0 * (1.0 + self.q) * self.part())
    }

    /// `B2 = q/n + 4(1+q)(n−r)/(r(n−1))` — eq. (15).
    pub fn b2(&self) -> f64 {
        self.q / self.n as f64 + 4.0 * (1.0 + self.q) * self.part()
    }

    /// Theorem-1 constants `C1, C2, C3` — eq. (13).
    pub fn c123(&self) -> (f64, f64, f64) {
        let (n, _) = (self.n as f64, self.r as f64);
        let e = std::f64::consts::E;
        let mu2 = self.mu * self.mu;
        let part = self.part() * n; // n(n−r)/(r(n−1))
        let c1 = 16.0 * self.sigma2 / (mu2 * n)
            * (1.0 + 2.0 * self.q + 8.0 * (1.0 + self.q) * part);
        let c2 = 16.0 * e * self.l_smooth.powi(2) * self.sigma2 / (mu2 * n);
        let c3 = 256.0 * e * self.l_smooth.powi(2) * self.sigma2 / (mu2 * mu2 * n)
            * (n + 2.0 * self.q + 8.0 * (1.0 + self.q) * part);
        (c1, c2, c3)
    }

    /// Theorem-2 constants `N1, N2`.
    pub fn n12(&self) -> (f64, f64) {
        let n = self.n as f64;
        let part = self.part() * n;
        let n1 = (1.0 + self.q) * self.sigma2 / n * (1.0 + part);
        let n2 = self.sigma2 / n * (n + 1.0);
        (n1, n2)
    }

    /// Theorem-1 warm-up threshold `k0` — eq. (11).
    pub fn k0(&self, tau: usize) -> usize {
        let mu2 = self.mu * self.mu;
        let cands = [
            self.l_smooth / self.mu,
            4.0 * (self.b1() / mu2 + 1.0),
            1.0 / tau as f64,
            4.0 * self.n as f64 / (mu2 * tau as f64),
        ];
        let m = cands.iter().cloned().fold(0.0f64, f64::max);
        (4.0 * m).ceil() as usize
    }

    /// Theorem-1 bound on `E‖x_k − x*‖²` given the gap at `k0` — eq. (12).
    pub fn thm1_bound(&self, tau: usize, k: usize, k0: usize, gap_k0: f64) -> f64 {
        let (c1, c2, c3) = self.c123();
        let kt = (k * tau + 1) as f64;
        let k0t = (k0 * tau + 1) as f64;
        let tm1 = (tau as f64) - 1.0;
        (k0t / kt).powi(2) * gap_k0
            + c1 * tau as f64 / kt
            + c2 * tm1 * tm1 / kt
            + c3 * tm1 / (kt * kt)
    }

    /// Theorem-2 bound on the averaged squared gradient norm — eq. (17).
    pub fn thm2_bound(&self, tau: usize, t_total: usize, f0_minus_fstar: f64) -> f64 {
        let (n1, n2) = self.n12();
        let t = t_total as f64;
        2.0 * self.l_smooth * f0_minus_fstar / t.sqrt()
            + n1 / t.sqrt()
            + n2 * ((tau as f64) - 1.0) / t
    }

    /// Maximum period allowed by Theorem 2's condition (16).
    pub fn thm2_tau_max(&self, t_total: usize) -> f64 {
        let b2 = self.b2();
        ((b2 * b2 + 0.8).sqrt() - b2) / 8.0 * (t_total as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ProblemConsts {
        ProblemConsts { l_smooth: 2.0, mu: 0.5, sigma2: 1.0, q: 1.0, n: 50, r: 25 }
    }

    #[test]
    fn full_participation_zeroes_the_sampling_term() {
        let mut c = base();
        c.r = 50;
        // B1 reduces to 2L² q/n; B2 to q/n.
        assert!((c.b1() - 2.0 * 4.0 * (1.0 / 50.0)).abs() < 1e-12);
        assert!((c.b2() - 1.0 / 50.0).abs() < 1e-12);
    }

    #[test]
    fn no_quantization_recovers_sampling_only() {
        let mut c = base();
        c.q = 0.0;
        c.r = 50;
        assert_eq!(c.b1(), 0.0);
        assert_eq!(c.b2(), 0.0);
    }

    #[test]
    fn constants_monotone_in_participation() {
        // Fewer participants ⇒ larger constants (more variance).
        let mut lo = base();
        lo.r = 10;
        let mut hi = base();
        hi.r = 40;
        assert!(lo.b1() > hi.b1());
        assert!(lo.b2() > hi.b2());
        assert!(lo.c123().0 > hi.c123().0);
        assert!(lo.n12().0 > hi.n12().0);
    }

    #[test]
    fn thm1_bound_decreases_in_k() {
        let c = base();
        let k0 = c.k0(5);
        let b_near = c.thm1_bound(5, k0 + 10, k0, 1.0);
        let b_far = c.thm1_bound(5, k0 + 1000, k0, 1.0);
        assert!(b_far < b_near);
    }

    #[test]
    fn thm1_tau1_kills_tau_terms() {
        let c = base();
        let (c1, _, _) = c.c123();
        let k = 100;
        let b = c.thm1_bound(1, k, 0, 0.0);
        let expect = c1 / (k as f64 + 1.0);
        assert!((b - expect).abs() < 1e-12);
    }

    #[test]
    fn thm2_tau_max_scales_sqrt_t() {
        let c = base();
        let t1 = c.thm2_tau_max(100);
        let t2 = c.thm2_tau_max(10_000);
        assert!((t2 / t1 - 10.0).abs() < 1e-9);
    }

    #[test]
    fn k0_respects_all_lower_bounds() {
        let c = base();
        let k0 = c.k0(5) as f64;
        assert!(k0 >= 4.0 * c.l_smooth / c.mu);
        assert!(k0 >= 16.0 * (c.b1() / (c.mu * c.mu) + 1.0));
    }
}
