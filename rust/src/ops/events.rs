//! [`EventSink`]: the JSONL structured-event bus.
//!
//! One sink instance is threaded (by cheap clone — the writer is shared
//! behind an `Arc<Mutex>`) through the
//! [`RoundEngine`](crate::coordinator::RoundEngine), the async
//! transports' [`CommitPlanner`](crate::coordinator::CommitPlanner)
//! decision points, both TCP leaders, and the worker's reconnect loop.
//! Every `emit` appends exactly one compact JSON object per line and
//! flushes, so a tail of the file is always valid JSONL even if the
//! process is killed mid-run — which is the whole point: the event log
//! is the operator's live view of a run that may die at any commit.
//!
//! ## Schema (stable — see `docs/OPERATIONS.md` for the full table)
//!
//! Common fields on every event:
//!
//! * `"event"` — the kind tag (`run_started`, `job_dispatched`,
//!   `upload_arrived`, `upload_dropped`, `commit`,
//!   `checkpoint_written`, `worker_joined`, `worker_left`,
//!   `worker_reconnecting`, `run_finished`);
//! * `"seed"` — the run's master seed as a decimal **string** (u64
//!   exceeds f64's exact-integer range, same convention as config JSON);
//! * `"ts_ms"` — wall-clock Unix milliseconds at emission.
//!
//! Per-event fields carry the protocol coordinates (`version`, `node`,
//! `slot`, `staleness`, …) and, where the transport owns a clock, the
//! **virtual** time `t` (wall-clock transports report elapsed seconds).
//! Keys are emitted in sorted order (the JSON module's object ordering),
//! so lines are byte-stable for equal field sets modulo `ts_ms`.
//!
//! A default-constructed sink is **null**: `emit` is a no-op and
//! `is_active` is `false`, so instrumented code paths cost one branch
//! when no `--events` destination is configured.

use crate::util::json::Json;
use std::io::Write;
use std::sync::{Arc, Mutex};

/// Shared JSONL event writer. Clones write to the same destination; the
/// seed stamp is per-clone (see [`EventSink::with_seed`]) so one process
/// driving several runs labels each run's events correctly.
#[derive(Clone, Default)]
pub struct EventSink {
    out: Option<Arc<Mutex<Box<dyn Write + Send>>>>,
    seed: u64,
}

impl std::fmt::Debug for EventSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EventSink")
            .field("active", &self.out.is_some())
            .field("seed", &self.seed)
            .finish()
    }
}

impl EventSink {
    /// The inert sink: `emit` does nothing. Same as `Default`.
    pub fn null() -> Self {
        Self::default()
    }

    /// Emit events to standard error (interleaves with the human log;
    /// every event line is still a self-contained JSON object).
    pub fn stderr() -> Self {
        Self::to_writer(Box::new(std::io::stderr()))
    }

    /// Emit events to `path`, appending. The file is created (with
    /// parent directories) on construction so a run that dies before its
    /// first event still leaves an empty log rather than nothing.
    pub fn to_file(path: &std::path::Path) -> crate::Result<Self> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| anyhow::anyhow!("create {}: {e}", parent.display()))?;
            }
        }
        let f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| anyhow::anyhow!("open events file {}: {e}", path.display()))?;
        Ok(Self::to_writer(Box::new(f)))
    }

    /// Emit events into any writer (what tests use to capture lines).
    pub fn to_writer(w: Box<dyn Write + Send>) -> Self {
        EventSink { out: Some(Arc::new(Mutex::new(w))), seed: 0 }
    }

    /// A clone of this sink stamping `seed` on every event it emits.
    /// The underlying writer stays shared.
    pub fn with_seed(&self, seed: u64) -> Self {
        EventSink { out: self.out.clone(), seed }
    }

    /// Whether events actually go anywhere. Instrumentation may use this
    /// to skip building expensive field sets.
    pub fn is_active(&self) -> bool {
        self.out.is_some()
    }

    /// Append one event line: `kind` plus the common fields plus
    /// `fields`. Write errors are swallowed deliberately — observability
    /// must never kill a training run — but the line is flushed so a
    /// subsequent process kill cannot truncate it.
    pub fn emit(&self, kind: &str, fields: Vec<(&str, Json)>) {
        let Some(out) = &self.out else { return };
        let ts_ms = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        let mut all = vec![
            ("event", Json::str(kind)),
            ("seed", Json::str(self.seed.to_string())),
            ("ts_ms", Json::num(ts_ms as f64)),
        ];
        all.extend(fields);
        let line = Json::obj(all).to_string_compact();
        if let Ok(mut w) = out.lock() {
            let _ = writeln!(w, "{line}");
            let _ = w.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A Write handle into a shared byte buffer, so the test can read
    /// back what the sink wrote.
    #[derive(Clone)]
    struct Shared(Arc<Mutex<Vec<u8>>>);

    impl Write for Shared {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn null_sink_is_inert() {
        let sink = EventSink::null();
        assert!(!sink.is_active());
        sink.emit("run_started", vec![("version", Json::num(0.0))]);
    }

    #[test]
    fn emits_one_parseable_json_line_per_event_with_common_fields() {
        let buf = Shared(Arc::new(Mutex::new(Vec::new())));
        let sink = EventSink::to_writer(Box::new(buf.clone())).with_seed(42);
        assert!(sink.is_active());
        sink.emit("commit", vec![("version", Json::num(3.0)), ("bits", Json::num(128.0))]);
        sink.emit("run_finished", vec![]);
        let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("event").and_then(Json::as_str), Some("commit"));
        assert_eq!(first.get("seed").and_then(Json::as_str), Some("42"));
        assert_eq!(first.get("version").and_then(Json::as_usize), Some(3));
        assert!(first.get("ts_ms").is_some());
        let second = Json::parse(lines[1]).unwrap();
        assert_eq!(second.get("event").and_then(Json::as_str), Some("run_finished"));
    }

    #[test]
    fn clones_share_the_writer_and_seed_is_per_clone() {
        let buf = Shared(Arc::new(Mutex::new(Vec::new())));
        let a = EventSink::to_writer(Box::new(buf.clone())).with_seed(1);
        let b = a.with_seed(2);
        a.emit("worker_joined", vec![]);
        b.emit("worker_left", vec![]);
        let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        let seeds: Vec<String> = text
            .lines()
            .map(|l| {
                Json::parse(l)
                    .unwrap()
                    .get("seed")
                    .and_then(Json::as_str)
                    .unwrap()
                    .to_string()
            })
            .collect();
        assert_eq!(seeds, ["1", "2"]);
    }
}
