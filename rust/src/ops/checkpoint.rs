//! The versioned, self-describing checkpoint format behind
//! `fedpaq train --resume` / `fedpaq leader --resume`.
//!
//! A [`Checkpoint`] captures **everything** the run loop needs to
//! continue bit-identically from commit `next_round`:
//!
//! * the server model `x_k` and the full curve/stats history so far
//!   (so a resumed [`RunResult`](crate::coordinator::RunResult) carries
//!   the uninterrupted run's complete record);
//! * the virtual clock and cumulative upload **and download** bits;
//! * the per-node codec state (error-feedback residuals, via
//!   [`UpdateCodec::state_export`](crate::quant::UpdateCodec::state_export));
//! * the downlink compression state when `cfg.down_codec` is set
//!   ([`DownlinkEncoder::state_export`](crate::coordinator::DownlinkEncoder::state_export)):
//!   the shared reference model, the per-version link-bit ledger, the
//!   per-node last-shipped versions and the downlink codec's own state
//!   (EF residual) — everything needed for the resumed broadcast chain
//!   to stay bit-identical;
//! * the transport's protocol state: the full
//!   [`CommitPlanner`](crate::coordinator::CommitPlanner) snapshot
//!   ([`PlannerState`]) plus, for the virtual-time simulator, the
//!   in-flight jobs with their already-computed uploads and completion
//!   times;
//! * a table of explicit RNG stream positions. Today every RNG stream in
//!   the tree is keyed by `(seed, structural coordinates)` and needs no
//!   position (the one cross-commit counter, the planner's re-dispatch
//!   stream, travels inside [`PlannerState`]); the table exists so a
//!   future stateful stream has a format slot without a version bump.
//!
//! ## Binary layout (format version 4)
//!
//! Little-endian, written with the same hand-rolled `Buf`/`Cursor`
//! primitives as the wire protocol ([`crate::net::proto`]):
//!
//! ```text
//! "FPQC" magic · u32 format version · u64 config_hash · u64 seed
//! · u64 next_round · u64 total_bits · u64 total_bits_down
//! · u64 total_bits_edge_to_root · f64 clock_now
//! · params f32s · curve label + points · round stats
//! · codec state (node, residuals) pairs
//! · downlink reference f32s · link-bit ledger u64s · per-node last u64s
//! · downlink codec state (node, residuals) pairs
//! · rng table (key, [u64;4]) pairs
//! · transport tag (0 = none, 1 = async planner + jobs,
//!   2 = tree planner)
//! ```
//!
//! Version 2 added the bidirectional-compression fields:
//! `total_bits_down`, the `bits_down` column inside curve points and
//! round stats, and the four downlink-state sections. v1 checkpoints
//! are rejected with an explicit version error — they predate the
//! downlink seam and cannot resume a bidirectional run faithfully.
//!
//! Version 3 (this layout) changed no bytes on the wire, but was bumped
//! because two *semantic* contracts moved underneath the format: node
//! sampling switched from partial Fisher–Yates to Floyd's O(r) algorithm
//! (same distribution, different concrete cohorts per seed — a v2
//! checkpoint would resume onto different sampled sets than the run that
//! wrote it), and the config grew the `straggler`/`dataset_cap` scale
//! knobs (which feed `config_hash`). In-flight jobs now also serialize
//! in canonical event-queue order (`(finish, version, slot, node)`)
//! rather than arrival-vector order, so checkpoint bytes are independent
//! of the queue's internal layout.
//!
//! Version 4 added the hierarchical-aggregation fields:
//! `total_bits_edge_to_root`, the `bits_edge_to_root` column inside
//! curve points and round stats (the split per-hop uplink accounting),
//! and the `Tree` transport tag capturing a tree root's planner
//! snapshot. As with the flat TCP transports, edge-leader in-flight
//! state lives in other processes, so tree checkpoints are only
//! resumable when quiescent — the edge partial buffers are empty at
//! every commit boundary in degenerate mode (see `docs/TOPOLOGY.md`).
//!
//! Decoding rejects wrong magic, unknown format versions, truncation
//! (every read is bounds-checked) and trailing bytes — the same
//! corrupt-frame policy as the codec layer. Writes go through
//! [`crate::util::fsio::write_atomic`], so a checkpoint file on disk is
//! always complete: a kill mid-write leaves the previous checkpoint, not
//! half a new one.
//!
//! Resume additionally validates `config_hash` against the config of the
//! resuming process ([`ExperimentConfig::config_hash`]), so a checkpoint
//! can never silently continue a *different* experiment.

use crate::config::ExperimentConfig;
use crate::coordinator::commit_loop::PlannerState;
use crate::coordinator::engine::RoundStats;
use crate::metrics::CurvePoint;
use crate::net::proto::{read_encoded, write_encoded, Buf, Cursor};
use crate::quant::Encoded;
use std::path::Path;

/// Current checkpoint format version (bumped on layout changes; decode
/// rejects versions it does not know).
pub const CHECKPOINT_VERSION: u32 = 4;

const MAGIC: &[u8; 4] = b"FPQC";

/// One in-flight virtual-time job, checkpointed with its already-computed
/// upload: the upload is a pure function of the dispatch-time model and
/// seeds, which no longer exist after a resume, so the bytes themselves
/// must travel.
#[derive(Debug, Clone)]
pub struct JobState {
    pub node: usize,
    pub version: usize,
    pub slot: usize,
    /// Virtual completion time of the job.
    pub finish: f64,
    pub enc: Encoded,
}

/// Transport-owned protocol state inside a checkpoint.
#[derive(Debug, Clone)]
pub enum TransportState {
    /// Buffered-async state: the planner snapshot plus (for the
    /// simulator) the in-flight jobs and the transport clock. Real-socket
    /// transports leave `jobs` empty — their in-flight work lives in
    /// worker processes and is only resumable from a quiescent
    /// checkpoint (see [`crate::net::TcpAsync`]).
    Async { planner: PlannerState, now: f64, jobs: Vec<JobState> },
    /// Hierarchical-tree root state: the planner snapshot. Edge-leader
    /// partial buffers live in edge processes and drain to empty at
    /// every commit boundary under the degenerate knobs, so — like the
    /// flat socket transport — a tree checkpoint is only resumable when
    /// quiescent ([`crate::net::TcpTree`] enforces it).
    Tree { planner: PlannerState },
}

/// A complete run snapshot. See the module docs for the format contract.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// [`ExperimentConfig::config_hash`] of the run that wrote this.
    pub config_hash: u64,
    /// The run's master seed (duplicated out of the config for
    /// self-description — event logs and checkpoints agree on the key).
    pub seed: u64,
    /// The next commit index to execute: `next_round` commits are
    /// already folded into `params`/`curve`/`stats`.
    pub next_round: usize,
    pub total_bits: u64,
    /// Cumulative downlink (broadcast) bits; 0 for runs that predate or
    /// never enable the downlink seam.
    pub total_bits_down: u64,
    /// Cumulative edge→root uplink bits; 0 on flat topologies.
    pub total_bits_edge_to_root: u64,
    /// Virtual clock at the checkpoint (0 for wall-clock transports,
    /// whose time axis restarts on resume).
    pub clock_now: f64,
    pub params: Vec<f32>,
    pub curve_label: String,
    pub curve: Vec<CurvePoint>,
    pub stats: Vec<RoundStats>,
    /// Per-node codec state (EF residuals), from
    /// [`UpdateCodec::state_export`](crate::quant::UpdateCodec::state_export).
    pub codec_state: Vec<(u64, Vec<f32>)>,
    /// Downlink shared reference model; empty when `down_codec` is off.
    pub down_reference: Vec<f32>,
    /// Per-version downlink link bits (`[0]` is the free version-0
    /// adoption); empty when `down_codec` is off.
    pub down_link_bits: Vec<u64>,
    /// Per-node last version whose links were billed; empty when
    /// `down_codec` is off.
    pub down_last: Vec<u64>,
    /// Downlink codec state (the server-side EF residual stream), from
    /// [`DownlinkEncoder::state_export`](crate::coordinator::DownlinkEncoder::state_export).
    pub down_codec_state: Vec<(u64, Vec<f32>)>,
    /// Explicit RNG stream positions (stream key → xoshiro256++ state).
    /// Empty today — see the module docs.
    pub rng_states: Vec<(u64, [u64; 4])>,
    pub transport: Option<TransportState>,
}

impl Checkpoint {
    /// Stable identifier embedded in RunResult meta blocks:
    /// `ck-<config_hash hex>-<next_round>`.
    pub fn id(&self) -> String {
        format!("ck-{:016x}-{}", self.config_hash, self.next_round)
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut b = Buf::new();
        for &m in MAGIC {
            b.u8(m);
        }
        b.u32(CHECKPOINT_VERSION);
        b.u64(self.config_hash);
        b.u64(self.seed);
        b.u64(self.next_round as u64);
        b.u64(self.total_bits);
        b.u64(self.total_bits_down);
        b.u64(self.total_bits_edge_to_root);
        b.f64(self.clock_now);
        b.f32s(&self.params);
        b.string(&self.curve_label);
        b.u64(self.curve.len() as u64);
        for p in &self.curve {
            b.u64(p.round as u64);
            b.u64(p.iterations as u64);
            b.f64(p.time);
            b.u64(p.bits_up);
            b.u64(p.bits_down);
            b.u64(p.bits_edge_to_root);
            b.f64(p.loss);
        }
        b.u64(self.stats.len() as u64);
        for s in &self.stats {
            b.u64(s.round as u64);
            b.f64(s.compute_time);
            b.f64(s.comm_time);
            b.u64(s.bits_up);
            b.u64(s.bits_down);
            b.u64(s.bits_edge_to_root);
            b.u64(s.dropped);
            b.u64(s.staleness_max as u64);
            b.f64(s.staleness_mean);
        }
        b.u64(self.codec_state.len() as u64);
        for (node, res) in &self.codec_state {
            b.u64(*node);
            b.f32s(res);
        }
        b.f32s(&self.down_reference);
        b.u64(self.down_link_bits.len() as u64);
        for &bits in &self.down_link_bits {
            b.u64(bits);
        }
        b.u64(self.down_last.len() as u64);
        for &last in &self.down_last {
            b.u64(last);
        }
        b.u64(self.down_codec_state.len() as u64);
        for (node, res) in &self.down_codec_state {
            b.u64(*node);
            b.f32s(res);
        }
        b.u64(self.rng_states.len() as u64);
        for (key, s) in &self.rng_states {
            b.u64(*key);
            for &w in s {
                b.u64(w);
            }
        }
        match &self.transport {
            None => b.u8(0),
            Some(TransportState::Async { planner, now, jobs }) => {
                b.u8(1);
                write_planner(&mut b, planner);
                b.f64(*now);
                b.u64(jobs.len() as u64);
                for j in jobs {
                    b.u64(j.node as u64);
                    b.u64(j.version as u64);
                    b.u64(j.slot as u64);
                    b.f64(j.finish);
                    write_encoded(&mut b, &j.enc);
                }
            }
            Some(TransportState::Tree { planner }) => {
                b.u8(2);
                write_planner(&mut b, planner);
            }
        }
        b.0
    }

    pub fn decode(bytes: &[u8]) -> crate::Result<Self> {
        let mut c = Cursor::new(bytes);
        let magic = c.take(4)?;
        anyhow::ensure!(
            magic == &MAGIC[..],
            "not a fedpaq checkpoint (bad magic {magic:02x?})"
        );
        let version = c.u32()?;
        anyhow::ensure!(
            version == CHECKPOINT_VERSION,
            "checkpoint format v{version} is not supported by this build \
             (expected v{CHECKPOINT_VERSION})"
        );
        let config_hash = c.u64()?;
        let seed = c.u64()?;
        let next_round = c.u64()? as usize;
        let total_bits = c.u64()?;
        let total_bits_down = c.u64()?;
        let total_bits_edge_to_root = c.u64()?;
        let clock_now = c.f64()?;
        let params = c.f32s()?;
        let curve_label = c.string()?;
        let count = c.u64()?;
        let n_curve = read_count(&c, count, 56)?;
        let mut curve = Vec::with_capacity(n_curve);
        for _ in 0..n_curve {
            curve.push(CurvePoint {
                round: c.u64()? as usize,
                iterations: c.u64()? as usize,
                time: c.f64()?,
                bits_up: c.u64()?,
                bits_down: c.u64()?,
                bits_edge_to_root: c.u64()?,
                loss: c.f64()?,
            });
        }
        let count = c.u64()?;
        let n_stats = read_count(&c, count, 72)?;
        let mut stats = Vec::with_capacity(n_stats);
        for _ in 0..n_stats {
            stats.push(RoundStats {
                round: c.u64()? as usize,
                compute_time: c.f64()?,
                comm_time: c.f64()?,
                bits_up: c.u64()?,
                bits_down: c.u64()?,
                bits_edge_to_root: c.u64()?,
                dropped: c.u64()?,
                staleness_max: c.u64()? as usize,
                staleness_mean: c.f64()?,
            });
        }
        let count = c.u64()?;
        let n_codec = read_count(&c, count, 16)?;
        let mut codec_state = Vec::with_capacity(n_codec);
        for _ in 0..n_codec {
            let node = c.u64()?;
            codec_state.push((node, c.f32s()?));
        }
        let down_reference = c.f32s()?;
        let count = c.u64()?;
        let n_links = read_count(&c, count, 8)?;
        let mut down_link_bits = Vec::with_capacity(n_links);
        for _ in 0..n_links {
            down_link_bits.push(c.u64()?);
        }
        let count = c.u64()?;
        let n_last = read_count(&c, count, 8)?;
        let mut down_last = Vec::with_capacity(n_last);
        for _ in 0..n_last {
            down_last.push(c.u64()?);
        }
        let count = c.u64()?;
        let n_down_codec = read_count(&c, count, 16)?;
        let mut down_codec_state = Vec::with_capacity(n_down_codec);
        for _ in 0..n_down_codec {
            let node = c.u64()?;
            down_codec_state.push((node, c.f32s()?));
        }
        let count = c.u64()?;
        let n_rng = read_count(&c, count, 40)?;
        let mut rng_states = Vec::with_capacity(n_rng);
        for _ in 0..n_rng {
            let key = c.u64()?;
            let mut s = [0u64; 4];
            for w in &mut s {
                *w = c.u64()?;
            }
            rng_states.push((key, s));
        }
        let transport = match c.u8()? {
            0 => None,
            1 => {
                let planner = read_planner(&mut c)?;
                let now = c.f64()?;
                let count = c.u64()?;
                let n_jobs = read_count(&c, count, 32)?;
                let mut jobs = Vec::with_capacity(n_jobs);
                for _ in 0..n_jobs {
                    jobs.push(JobState {
                        node: c.u64()? as usize,
                        version: c.u64()? as usize,
                        slot: c.u64()? as usize,
                        finish: c.f64()?,
                        enc: read_encoded(&mut c)?,
                    });
                }
                Some(TransportState::Async { planner, now, jobs })
            }
            2 => Some(TransportState::Tree { planner: read_planner(&mut c)? }),
            x => anyhow::bail!("bad checkpoint transport tag {x}"),
        };
        anyhow::ensure!(
            c.pos() == c.len(),
            "trailing bytes in checkpoint ({} of {} consumed)",
            c.pos(),
            c.len()
        );
        Ok(Checkpoint {
            config_hash,
            seed,
            next_round,
            total_bits,
            total_bits_down,
            total_bits_edge_to_root,
            clock_now,
            params,
            curve_label,
            curve,
            stats,
            codec_state,
            down_reference,
            down_link_bits,
            down_last,
            down_codec_state,
            rng_states,
            transport,
        })
    }

    /// Atomically persist to `path` (temp + rename via
    /// [`crate::util::fsio::write_atomic`]).
    pub fn write_atomic(&self, path: &Path) -> crate::Result<()> {
        crate::util::fsio::write_atomic(path, &self.encode())
    }

    /// Load and decode a checkpoint file.
    pub fn load(path: &Path) -> crate::Result<Self> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("read checkpoint {}: {e}", path.display()))?;
        Self::decode(&bytes)
            .map_err(|e| anyhow::anyhow!("checkpoint {}: {e}", path.display()))
    }

    /// Reject resuming under a different experiment: the hash covers the
    /// full config JSON, so any drift (codec, seeds, knobs) is caught
    /// before a single round runs.
    pub fn check_config(&self, cfg: &ExperimentConfig) -> crate::Result<()> {
        let have = cfg.config_hash();
        anyhow::ensure!(
            self.config_hash == have,
            "checkpoint {} was written by a different config \
             (hash {:016x}, this run {:016x}) — resume requires the \
             identical experiment",
            self.id(),
            self.config_hash,
            have
        );
        Ok(())
    }
}

/// Bounds-check an element count against the buffer that must still
/// contain `count * min_bytes` bytes, so a corrupt length prefix fails
/// with a clear error instead of a giant allocation.
fn read_count(c: &Cursor<'_>, count: u64, min_bytes: usize) -> crate::Result<usize> {
    let n = count as usize;
    anyhow::ensure!(
        count <= (c.len() as u64) && n.saturating_mul(min_bytes) <= c.len(),
        "corrupt checkpoint: element count {count} exceeds buffer size {}",
        c.len()
    );
    Ok(n)
}

fn write_planner(b: &mut Buf, p: &PlannerState) {
    b.u64(p.seed);
    b.u64(p.n_nodes as u64);
    b.u64(p.buffer_size as u64);
    b.u64(p.max_staleness as u64);
    b.u64(p.version as u64);
    b.u64(p.wave_len as u64);
    b.u8(p.awaiting_wave as u8);
    b.u64(p.in_flight.len() as u64);
    for &(node, version, slot) in &p.in_flight {
        b.u64(node as u64);
        b.u64(version as u64);
        b.u64(slot as u64);
    }
    b.u64(p.buffer.len() as u64);
    for (node, version, slot, enc) in &p.buffer {
        b.u64(*node as u64);
        b.u64(*version as u64);
        b.u64(*slot as u64);
        write_encoded(b, enc);
    }
    b.u64(p.dropped_total);
    b.u64(p.dropped_since_commit);
    b.u64(p.redispatches);
}

fn read_planner(c: &mut Cursor<'_>) -> crate::Result<PlannerState> {
    let seed = c.u64()?;
    let n_nodes = c.u64()? as usize;
    let buffer_size = c.u64()? as usize;
    let max_staleness = c.u64()? as usize;
    let version = c.u64()? as usize;
    let wave_len = c.u64()? as usize;
    let awaiting_wave = match c.u8()? {
        0 => false,
        1 => true,
        x => anyhow::bail!("bad planner bool byte {x}"),
    };
    let count = c.u64()?;
    let n_in_flight = read_count(c, count, 24)?;
    let mut in_flight = Vec::with_capacity(n_in_flight);
    for _ in 0..n_in_flight {
        in_flight.push((c.u64()? as usize, c.u64()? as usize, c.u64()? as usize));
    }
    let count = c.u64()?;
    let n_buffer = read_count(c, count, 24)?;
    let mut buffer = Vec::with_capacity(n_buffer);
    for _ in 0..n_buffer {
        let node = c.u64()? as usize;
        let v = c.u64()? as usize;
        let slot = c.u64()? as usize;
        buffer.push((node, v, slot, read_encoded(c)?));
    }
    Ok(PlannerState {
        seed,
        n_nodes,
        buffer_size,
        max_staleness,
        version,
        wave_len,
        awaiting_wave,
        in_flight,
        buffer,
        dropped_total: c.u64()?,
        dropped_since_commit: c.u64()?,
        redispatches: c.u64()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::CodecSpec;
    use crate::util::rng::Rng;

    fn enc(seed: u64) -> Encoded {
        let codec = CodecSpec::qsgd(2).build().unwrap();
        let mut rng = Rng::seed_from_u64(seed);
        let v: Vec<f32> = (0..16).map(|_| rng.gen_f32() - 0.5).collect();
        codec.encode(&v, &mut rng)
    }

    fn sample() -> Checkpoint {
        Checkpoint {
            config_hash: 0xdead_beef_cafe_f00d,
            seed: 42,
            next_round: 7,
            total_bits: 123_456,
            total_bits_down: 77_000,
            total_bits_edge_to_root: 9_900,
            clock_now: 98.25,
            params: vec![1.0, -0.5, 0.25, 3.5e-8],
            curve_label: "fedbuff logreg".into(),
            curve: vec![
                CurvePoint {
                    round: 0,
                    iterations: 0,
                    time: 0.0,
                    bits_up: 0,
                    bits_down: 0,
                    bits_edge_to_root: 0,
                    loss: 0.9,
                },
                CurvePoint {
                    round: 7,
                    iterations: 35,
                    time: 98.25,
                    bits_up: 123_456,
                    bits_down: 77_000,
                    bits_edge_to_root: 9_900,
                    loss: 0.31,
                },
            ],
            stats: vec![RoundStats {
                round: 6,
                compute_time: 4.5,
                comm_time: 1.25,
                bits_up: 2048,
                bits_down: 512,
                bits_edge_to_root: 1024,
                dropped: 1,
                staleness_max: 3,
                staleness_mean: 0.75,
            }],
            codec_state: vec![(3, vec![0.5, -0.5]), (11, vec![1.0])],
            down_reference: vec![0.125, -2.0, 0.0, 1.5],
            down_link_bits: vec![0, 640, 720, 704, 696, 700, 698],
            down_last: vec![6, 4, 6, 0, 5],
            down_codec_state: vec![(u64::MAX, vec![0.01, -0.02])],
            rng_states: vec![(9, [1, 2, 3, u64::MAX])],
            transport: Some(TransportState::Async {
                planner: PlannerState {
                    seed: 42,
                    n_nodes: 50,
                    buffer_size: 4,
                    max_staleness: 8,
                    version: 7,
                    wave_len: 25,
                    awaiting_wave: true,
                    in_flight: vec![(1, 6, 2), (9, 7, 0)],
                    buffer: vec![(4, 7, 1, enc(5))],
                    dropped_total: 3,
                    dropped_since_commit: 1,
                    redispatches: 3,
                },
                now: 98.25,
                jobs: vec![JobState {
                    node: 1,
                    version: 6,
                    slot: 2,
                    finish: 101.5,
                    enc: enc(8),
                }],
            }),
        }
    }

    fn assert_checkpoints_equal(a: &Checkpoint, b: &Checkpoint) {
        assert_eq!(a.config_hash, b.config_hash);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.next_round, b.next_round);
        assert_eq!(a.total_bits, b.total_bits);
        assert_eq!(a.total_bits_down, b.total_bits_down);
        assert_eq!(a.total_bits_edge_to_root, b.total_bits_edge_to_root);
        assert_eq!(a.clock_now.to_bits(), b.clock_now.to_bits());
        assert_eq!(a.params, b.params);
        assert_eq!(a.curve_label, b.curve_label);
        assert_eq!(a.curve, b.curve);
        assert_eq!(a.stats.len(), b.stats.len());
        for (x, y) in a.stats.iter().zip(&b.stats) {
            assert_eq!(x.round, y.round);
            assert_eq!(x.compute_time.to_bits(), y.compute_time.to_bits());
            assert_eq!(x.bits_up, y.bits_up);
            assert_eq!(x.dropped, y.dropped);
        }
        assert_eq!(a.codec_state, b.codec_state);
        assert_eq!(a.down_reference, b.down_reference);
        assert_eq!(a.down_link_bits, b.down_link_bits);
        assert_eq!(a.down_last, b.down_last);
        assert_eq!(a.down_codec_state, b.down_codec_state);
        assert_eq!(a.rng_states, b.rng_states);
        // Re-encode equality covers the transport state bit-for-bit.
        assert_eq!(a.encode(), b.encode());
    }

    #[test]
    fn encode_decode_roundtrips() {
        let ck = sample();
        let back = Checkpoint::decode(&ck.encode()).unwrap();
        assert_checkpoints_equal(&ck, &back);
        assert_eq!(ck.id(), "ck-deadbeefcafef00d-7");
    }

    #[test]
    fn no_transport_state_roundtrips() {
        let ck = Checkpoint { transport: None, ..sample() };
        let back = Checkpoint::decode(&ck.encode()).unwrap();
        assert!(back.transport.is_none());
        assert_eq!(ck.encode(), back.encode());
    }

    #[test]
    fn tree_transport_state_roundtrips() {
        let planner = match sample().transport {
            Some(TransportState::Async { planner, .. }) => planner,
            _ => unreachable!(),
        };
        let ck = Checkpoint {
            transport: Some(TransportState::Tree { planner }),
            ..sample()
        };
        let back = Checkpoint::decode(&ck.encode()).unwrap();
        assert!(matches!(back.transport, Some(TransportState::Tree { .. })));
        assert_eq!(ck.encode(), back.encode());
    }

    #[test]
    fn bad_magic_and_version_are_rejected() {
        let mut bytes = sample().encode();
        bytes[0] = b'X';
        let err = Checkpoint::decode(&bytes).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");
        let mut bytes = sample().encode();
        bytes[4] = 99;
        let err = Checkpoint::decode(&bytes).unwrap_err();
        assert!(err.to_string().contains("format v99"), "{err}");
    }

    #[test]
    fn truncation_and_trailing_bytes_are_rejected() {
        let bytes = sample().encode();
        // Every strict prefix must fail loudly, never panic or succeed.
        for cut in [8, 20, bytes.len() / 2, bytes.len() - 1] {
            assert!(Checkpoint::decode(&bytes[..cut]).is_err(), "cut={cut}");
        }
        let mut padded = bytes.clone();
        padded.push(0);
        let err = Checkpoint::decode(&padded).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");
    }

    #[test]
    fn corrupt_count_fails_without_huge_allocation() {
        let ck = Checkpoint {
            transport: None,
            codec_state: vec![],
            rng_states: vec![],
            curve: vec![],
            stats: vec![],
            ..sample()
        };
        let mut bytes = ck.encode();
        // The curve-count u64 sits right after the fixed header + params
        // + label; smash it to u64::MAX and expect a clean error.
        let off = 4 + 4 + 8 * 6 + 8 // header (incl. both bit totals)
            + 8 + 4 * ck.params.len() // params
            + 4 + ck.curve_label.len(); // label
        bytes[off..off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = Checkpoint::decode(&bytes).unwrap_err();
        assert!(err.to_string().contains("element count"), "{err}");
    }

    #[test]
    fn config_hash_mismatch_is_rejected() {
        let cfg = ExperimentConfig::fig1_logreg_base();
        let ck = Checkpoint { config_hash: cfg.config_hash(), ..sample() };
        ck.check_config(&cfg).unwrap();
        let other = cfg.clone().with_seed(7);
        let err = ck.check_config(&other).unwrap_err();
        assert!(err.to_string().contains("different config"), "{err}");
    }

    #[test]
    fn atomic_write_and_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("fedpaq-ck-{}", std::process::id()));
        let path = dir.join("run.ck");
        let ck = sample();
        ck.write_atomic(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.encode(), back.encode());
        std::fs::remove_dir_all(&dir).ok();
    }
}
