//! Operator-grade run control: checkpoint/resume, the JSONL event bus,
//! and the knobs that thread them through a run.
//!
//! This layer exists so a long federated run is *operable*: it can be
//! watched (every protocol decision lands on the [`EventSink`] as one
//! JSON line), killed (checkpoints are written atomically every
//! `checkpoint_every` commits, so the newest complete one always
//! survives), and resumed (`--resume FILE` continues such that the final
//! [`RunResult`](crate::coordinator::RunResult) is **byte-identical** to
//! the uninterrupted run — CI diffs the two JSONs).
//!
//! The pieces:
//!
//! * [`checkpoint`] — the versioned binary snapshot format
//!   ([`Checkpoint`]) covering model, history, codec residuals, planner
//!   state and in-flight jobs; see its module docs for the layout and
//!   `docs/OPERATIONS.md` for the operator-facing contract.
//! * [`events`] — the [`EventSink`] JSONL bus and its stable schema.
//! * [`RunControl`] — the bundle of operator knobs the
//!   [`RoundEngine`](crate::coordinator::RoundEngine) consumes. The
//!   default value is "no ops": null sink, no checkpoints, run to the
//!   configured horizon — the zero-cost path every pre-existing caller
//!   gets implicitly.

pub mod checkpoint;
pub mod events;

pub use checkpoint::{Checkpoint, JobState, TransportState, CHECKPOINT_VERSION};
pub use events::EventSink;

use std::path::PathBuf;

/// Operator knobs for one run, consumed by
/// [`RoundEngine::run`](crate::coordinator::RoundEngine::run).
#[derive(Debug, Default)]
pub struct RunControl {
    /// Structured-event destination (null by default).
    pub events: EventSink,
    /// Where to write checkpoints. `None` disables checkpointing even if
    /// `checkpoint_every` is set.
    pub checkpoint_path: Option<PathBuf>,
    /// Write a checkpoint after every N commits (0 = only the forced
    /// `stop_after` checkpoint, if any).
    pub checkpoint_every: usize,
    /// Stop cleanly after this many commits, forcing a final checkpoint
    /// to `checkpoint_path` first — the "kill" half of the kill/resume
    /// determinism tests, without OS signals.
    pub stop_after: Option<usize>,
    /// Resume from this snapshot instead of initializing fresh state.
    pub resume: Option<Checkpoint>,
}

impl RunControl {
    /// Whether a checkpoint should be written after commit `k + 1` of
    /// the run (`k` is the zero-based commit index just executed).
    pub fn checkpoint_due(&self, completed: usize) -> bool {
        self.checkpoint_path.is_some()
            && ((self.checkpoint_every > 0 && completed % self.checkpoint_every == 0)
                || self.stop_after == Some(completed))
    }

    /// Whether the run should stop cleanly after `completed` commits.
    pub fn stop_due(&self, completed: usize) -> bool {
        self.stop_after == Some(completed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_control_is_inert() {
        let ctrl = RunControl::default();
        assert!(!ctrl.events.is_active());
        for k in 1..=10 {
            assert!(!ctrl.checkpoint_due(k));
            assert!(!ctrl.stop_due(k));
        }
    }

    #[test]
    fn checkpoint_cadence_and_forced_stop() {
        let ctrl = RunControl {
            checkpoint_path: Some(PathBuf::from("/tmp/run.ck")),
            checkpoint_every: 3,
            stop_after: Some(7),
            ..Default::default()
        };
        let due: Vec<usize> = (1..=10).filter(|&k| ctrl.checkpoint_due(k)).collect();
        assert_eq!(due, vec![3, 6, 7, 9]);
        assert!(ctrl.stop_due(7));
        assert!(!ctrl.stop_due(6));
        // Without a path, nothing is ever due.
        let no_path = RunControl { checkpoint_path: None, ..ctrl };
        assert!((1..=10).all(|k| !no_path.checkpoint_due(k)));
    }
}
