//! Real distributed mode: a TCP leader/worker runtime for FedPAQ.
//!
//! The round loop is NOT duplicated here: [`Tcp`] implements the
//! coordinator's [`Transport`](crate::coordinator::Transport) seam, and
//! [`run_leader`] drives the shared
//! [`RoundEngine`](crate::coordinator::RoundEngine) through it. The
//! simulation engine models time; this module actually *distributes* the
//! protocol across processes, with the exact same codecs and RNG streams,
//! so the aggregated models match the sim bit-for-bit for equal
//! configs/seeds (modulo float summation order, which we fix by
//! aggregating uploads in node order).
//!
//! Protocol (length-prefixed hand-rolled binary frames over TCP, see [`proto`]):
//!
//! ```text
//! worker -> leader   Join
//! leader -> worker   Setup { cfg }           once, after all workers join
//! leader -> worker   Work { round, node, params, lrs }   r msgs per round
//! worker -> leader   Update { round, node, enc }
//! leader -> worker   Shutdown
//! ```
//!
//! Each worker impersonates the *virtual nodes* assigned to it (the paper's
//! `n` is decoupled from the number of worker processes), regenerates its
//! shard locally from the seeded config, builds its codec from the
//! config's tagged spec, and never sees other shards.

pub mod leader;
pub mod proto;
pub mod transport;
pub mod worker;

pub use leader::run_leader;
pub use transport::Tcp;
pub use worker::run_worker;
