//! Real distributed mode: TCP leader/worker runtimes for FedPAQ.
//!
//! The round loop is NOT duplicated here: both networked leaders
//! implement the coordinator's
//! [`Transport`](crate::coordinator::Transport) seam, and [`run_leader`]
//! drives the shared [`RoundEngine`](crate::coordinator::RoundEngine)
//! through whichever one the config's round protocol selects:
//!
//! * [`Tcp`] — the synchronous barrier (paper Algorithm 1): one commit
//!   waits for every sampled node's upload; aggregation in node order
//!   makes a distributed run **bit-identical** to the in-process
//!   simulation for equal configs/seeds.
//! * [`TcpAsync`] (`cfg.async_rounds`) — the buffered-async protocol on
//!   real sockets: the leader keeps `r` jobs in flight, stamps each
//!   dispatch with its model version, commits as soon as `buffer_size`
//!   uploads land, and drops/re-dispatches uploads past `max_staleness`.
//!   Every protocol decision is delegated to the event-driven
//!   [`CommitPlanner`](crate::coordinator::commit_loop::CommitPlanner) —
//!   the same state machine behind the
//!   [`AsyncSim`](crate::coordinator::AsyncSim) simulation — so sim and
//!   cluster share one implementation of the commit rules, and the
//!   degenerate `buffer_size == r, max_staleness == 0` cluster run
//!   reproduces the barrier run bit-for-bit.
//!
//! Protocol (length-prefixed hand-rolled binary frames over TCP,
//! explicitly versioned — see [`proto`]):
//!
//! ```text
//! worker -> leader   Join { proto }
//! leader -> worker   Setup { proto, cfg }     once, after all workers join
//! leader -> worker   Work { version, node, payload, lrs }
//! worker -> leader   Update { version, node, enc, compute_ms, decode_ms }
//! leader -> worker   Shutdown
//! ```
//!
//! Every dispatch/upload carries the server **model version** it belongs
//! to; staleness is leader-side bookkeeping (`commit − version`).
//! `payload` ships the model either dense (`Raw`) or — with
//! `cfg.down_codec` set — as a compressed delta chain the worker applies
//! to its reconstructed reference ([`proto::ModelPayload`], wire v3; the
//! full frame catalogue lives in `docs/PROTOCOL.md`). Mixed-version
//! clusters are rejected at the handshake with a clear protocol-version
//! error ([`proto::PROTO_VERSION`]).
//!
//! Each worker impersonates the *virtual nodes* assigned to it (the
//! paper's `n` is decoupled from the number of worker processes),
//! regenerates its shard locally from the seeded config, builds its codec
//! from the config's tagged spec, and never sees other shards.
//!
//! ## Worker churn (async leader only)
//!
//! [`TcpAsync`] tolerates membership changes mid-run: a worker that dies
//! (read error / EOF / failed write) has its in-flight jobs retired back
//! to the planner as freed capacity and re-dispatched to survivors, and
//! its nodes re-pinned deterministically; a worker that connects after
//! the run started completes the full handshake and becomes a
//! re-pinning target. Both edges are reported on the JSONL event bus
//! (`worker_left` / `worker_joined` — see `docs/OPERATIONS.md`). The
//! barrier [`Tcp`] leader keeps its all-or-nothing semantics: a lost
//! worker is a hard error. Worker-side, [`run_worker_retrying`] re-dials
//! a missing leader with capped exponential backoff and deterministic
//! jitter, and `WorkerOptions::max_jobs` injects a clean mid-run death
//! for churn tests.

//! ## Two-level aggregation trees (wire v4)
//!
//! [`TcpTree`] generalizes the async leader into the root of a
//! two-level tree: **edge leaders** ([`run_edge_retrying`], the `fedpaq
//! edge` subcommand) each own a pinned cohort of ordinary workers and
//! stream [`proto::ToLeader::PartialUpdate`] frames upward — either
//! relayed verbatim (the identity re-encode, bit-identical to a flat
//! run) or summed and re-encoded through the run's own codec
//! ([`tree::partial_reencode`], reproducible per seed). The root drives
//! the same unchanged `CommitPlanner`; `bits_up` splits into
//! worker→edge and edge→root hops. `docs/TOPOLOGY.md` covers roles,
//! pinning, weighting, and failure semantics.

pub mod leader;
pub mod proto;
pub mod transport;
pub mod tree;
pub mod worker;

pub use leader::{run_leader, run_leader_tree};
pub use transport::{Tcp, TcpAsync};
pub use tree::{partial_reencode, run_edge_retrying, EdgeOptions, TcpTree};
pub use worker::{run_worker, run_worker_retrying, run_worker_with, WorkerOptions};
