//! The networked [`Transport`]: leader-side fan-out/fan-in over TCP.
//!
//! Wraps the [`proto`](super::proto) wire protocol behind the
//! coordinator's [`Transport`] seam, so the exact same
//! [`RoundEngine`](crate::coordinator::RoundEngine) loop that drives the
//! in-process simulation also drives a real worker cluster — no
//! duplicated round logic.
//!
//! Fan-out/fan-in is pipelined with blocking sockets: all `Work` frames
//! for a round are written first (worker processes run concurrently), then
//! updates are collected. There is no deadlock cycle — a worker always
//! drains its request before producing its (small) reply, and replies park
//! in kernel socket buffers until the leader reads them.

use super::proto::{recv_to_leader, send_to_worker, ToLeader, ToWorker};
use crate::config::ExperimentConfig;
use crate::coordinator::{RoundCtx, RoundOutcome, Transport};
use crate::model::Engine;
use crate::quant::{Encoded, UpdateCodec};
use std::net::{TcpListener, TcpStream};

struct WorkerConn {
    rd: TcpStream,
    wr: TcpStream,
}

fn accept_worker(listener: &TcpListener) -> crate::Result<WorkerConn> {
    let (stream, peer) = listener.accept()?;
    stream.set_nodelay(true)?;
    let mut rd = stream.try_clone()?;
    let join = recv_to_leader(&mut rd)?;
    anyhow::ensure!(matches!(join, ToLeader::Join), "expected Join from {peer}");
    eprintln!("leader: worker joined from {peer}");
    Ok(WorkerConn { rd, wr: stream })
}

/// Leader half of the TCP execution mode: accepts `n_workers` workers on
/// `bind`, broadcasts the config, then round-robins the sampled virtual
/// nodes across them each round. Rounds are charged wall-clock time.
pub struct Tcp {
    bind: String,
    n_workers: usize,
    workers: Vec<WorkerConn>,
}

impl Tcp {
    pub fn new(bind: impl Into<String>, n_workers: usize) -> Self {
        Tcp { bind: bind.into(), n_workers, workers: Vec::new() }
    }
}

impl Transport for Tcp {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn virtual_time(&self) -> bool {
        false
    }

    fn rebuilds_codec_from_config(&self) -> bool {
        true
    }

    fn setup(
        &mut self,
        cfg: &ExperimentConfig,
        _engine: &mut dyn Engine,
    ) -> crate::Result<()> {
        anyhow::ensure!(self.n_workers >= 1, "need at least one worker");
        let listener = TcpListener::bind(&self.bind)?;
        eprintln!("leader: listening on {}", listener.local_addr()?);
        self.workers.clear();
        for _ in 0..self.n_workers {
            self.workers.push(accept_worker(&listener)?);
        }
        // Broadcast setup; await Ready from everyone (engines compile now).
        for w in self.workers.iter_mut() {
            send_to_worker(&mut w.wr, &ToWorker::Setup { cfg: cfg.clone() })?;
        }
        for w in self.workers.iter_mut() {
            let msg = recv_to_leader(&mut w.rd)?;
            anyhow::ensure!(matches!(msg, ToLeader::Ready), "expected Ready");
        }
        eprintln!("leader: {} workers ready", self.n_workers);
        Ok(())
    }

    fn round(
        &mut self,
        ctx: &RoundCtx<'_>,
        _codec: &dyn UpdateCodec,
        _engine: &mut dyn Engine,
    ) -> crate::Result<RoundOutcome> {
        anyhow::ensure!(!self.workers.is_empty(), "Tcp::round before setup");
        // Fan the r virtual nodes out round-robin across workers.
        for (j, &node) in ctx.nodes.iter().enumerate() {
            let w = &mut self.workers[j % self.n_workers];
            send_to_worker(
                &mut w.wr,
                &ToWorker::Work {
                    round: ctx.round as u64,
                    node: node as u64,
                    params: ctx.params.to_vec(),
                    lrs: ctx.lrs.to_vec(),
                },
            )?;
        }
        // Collect all updates; return them in *node order* for bit-stable
        // parity with the in-process transport.
        let mut updates: Vec<Option<Encoded>> = vec![None; ctx.nodes.len()];
        for (j, _) in ctx.nodes.iter().enumerate() {
            let w = &mut self.workers[j % self.n_workers];
            match recv_to_leader(&mut w.rd)? {
                ToLeader::Update { round, node, enc } => {
                    anyhow::ensure!(round as usize == ctx.round, "round mismatch");
                    let pos = ctx
                        .nodes
                        .iter()
                        .position(|&n| n == node as usize)
                        .ok_or_else(|| anyhow::anyhow!("unknown node {node}"))?;
                    anyhow::ensure!(
                        updates[pos].is_none(),
                        "duplicate update for node {node}"
                    );
                    updates[pos] = Some(enc);
                }
                other => anyhow::bail!("unexpected message {other:?}"),
            }
        }
        let uploads: Vec<Encoded> = updates.into_iter().flatten().collect();
        anyhow::ensure!(uploads.len() == ctx.nodes.len(), "missing updates");
        // A TCP round is a full barrier: every upload is staleness 0 and
        // the engine charges wall-clock time.
        Ok(RoundOutcome::barrier(ctx, uploads))
    }

    fn shutdown(&mut self) -> crate::Result<()> {
        for w in self.workers.iter_mut() {
            send_to_worker(&mut w.wr, &ToWorker::Shutdown)?;
        }
        Ok(())
    }
}
