//! The networked [`Transport`]s: leader-side fan-out/fan-in over TCP.
//!
//! Wraps the [`proto`](super::proto) wire protocol behind the
//! coordinator's [`Transport`] seam, so the exact same
//! [`RoundEngine`](crate::coordinator::RoundEngine) loop that drives the
//! in-process simulation also drives a real worker cluster — no
//! duplicated round logic. Two leaders share the plumbing:
//!
//! * [`Tcp`] — the synchronous barrier: every commit fans out all of
//!   `S_k`, waits for every upload, and aggregates in node order
//!   (bit-identical to the in-process sim for equal seeds).
//! * [`TcpAsync`] — the buffered-async protocol on real sockets: the
//!   leader keeps `r` jobs in flight, commits as soon as `buffer_size`
//!   uploads land, stamps stragglers with their staleness and
//!   re-dispatches drops — every protocol decision delegated to the same
//!   [`CommitPlanner`](crate::coordinator::commit_loop::CommitPlanner)
//!   that drives [`AsyncSim`](crate::coordinator::AsyncSim), so there is
//!   exactly one implementation of the buffer/staleness/re-dispatch
//!   rules in the tree.
//!
//! Barrier fan-out/fan-in is pipelined with blocking sockets: all `Work`
//! frames for a round are written first (worker processes run
//! concurrently), then updates are collected. There is no deadlock cycle
//! — a worker always drains its request before producing its (small)
//! reply, and replies park in kernel socket buffers until the leader
//! reads them. The async leader instead moves each connection's read
//! half onto a reader thread feeding one mpsc channel, so uploads are
//! consumed in true arrival order across workers — the real-socket
//! analogue of `AsyncSim`'s virtual-completion-time queue.
//!
//! ## Node → worker assignment is pinned by node id
//!
//! Both leaders dispatch virtual node `i`'s work to worker
//! `i % n_workers` — a *stable* assignment across rounds, never a
//! positional or round-robin rotation. Stateless codecs cannot tell the
//! difference (every upload is a pure function of `(seed, node,
//! version)`), but stateful codecs keep per-node memory on the worker
//! side ([`ErrorFeedbackCodec`](crate::quant::ErrorFeedbackCodec)
//! residuals, keyed by node inside each worker's codec instance): pinning
//! guarantees one worker owns a given node's entire residual stream, so
//! a distributed error-feedback run reproduces the in-process simulation
//! bit-for-bit instead of fragmenting memory across processes.

use super::proto::{
    recv_to_leader, send_to_worker, ModelPayload, ToLeader, ToWorker, PROTO_VERSION,
};
use crate::config::ExperimentConfig;
use crate::coordinator::commit_loop::{CommitPlanner, Decision, PlannerEvent};
use crate::coordinator::{ModelFrame, RoundCtx, RoundOutcome, Transport};
use crate::model::Engine;
use crate::ops::EventSink;
use crate::quant::{Encoded, UpdateCodec};
use crate::util::json::Json;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

struct WorkerConn {
    rd: TcpStream,
    wr: TcpStream,
    peer: String,
}

fn accept_worker(listener: &TcpListener) -> crate::Result<WorkerConn> {
    let (stream, peer) = listener.accept()?;
    stream.set_nodelay(true)?;
    let mut rd = stream.try_clone()?;
    match recv_to_leader(&mut rd)? {
        ToLeader::Join { proto } => anyhow::ensure!(
            proto == PROTO_VERSION,
            "worker at {peer} speaks wire-protocol v{proto}; this leader \
             requires v{PROTO_VERSION} — rebuild so leader and workers match"
        ),
        other => anyhow::bail!("expected Join from {peer}, got {other:?}"),
    }
    eprintln!("leader: worker joined from {peer}");
    Ok(WorkerConn { rd, wr: stream, peer: peer.to_string() })
}

/// `Setup`/`Ready` half of the handshake (engines compile now).
fn setup_worker(w: &mut WorkerConn, cfg: &ExperimentConfig) -> crate::Result<()> {
    send_to_worker(
        &mut w.wr,
        &ToWorker::Setup { proto: PROTO_VERSION, cfg: cfg.clone() },
    )?;
    let msg = recv_to_leader(&mut w.rd)?;
    anyhow::ensure!(matches!(msg, ToLeader::Ready), "expected Ready");
    Ok(())
}

/// Accept `n_workers` workers on `bind`, run the `Join`/`Setup`/`Ready`
/// handshake, and hand back the ready connections plus the (still-open)
/// listener. Shared by both leaders; the async leader keeps the listener
/// to admit mid-run joiners, the barrier leader drops it.
fn accept_cluster(
    bind: &str,
    n_workers: usize,
    cfg: &ExperimentConfig,
    events: &EventSink,
) -> crate::Result<(Vec<WorkerConn>, TcpListener)> {
    anyhow::ensure!(n_workers >= 1, "need at least one worker");
    let listener = TcpListener::bind(bind)?;
    eprintln!("leader: listening on {}", listener.local_addr()?);
    let mut workers = Vec::with_capacity(n_workers);
    for _ in 0..n_workers {
        workers.push(accept_worker(&listener)?);
    }
    // Broadcast setup; await Ready from everyone.
    for w in workers.iter_mut() {
        send_to_worker(
            &mut w.wr,
            &ToWorker::Setup { proto: PROTO_VERSION, cfg: cfg.clone() },
        )?;
    }
    for w in workers.iter_mut() {
        let msg = recv_to_leader(&mut w.rd)?;
        anyhow::ensure!(matches!(msg, ToLeader::Ready), "expected Ready");
    }
    for (i, w) in workers.iter().enumerate() {
        events.emit(
            "worker_joined",
            vec![
                ("peer", Json::str(w.peer.as_str())),
                ("worker", Json::num(i as f64)),
            ],
        );
    }
    eprintln!("leader: {n_workers} workers ready");
    Ok((workers, listener))
}

/// Leader-side downlink shipping state, shared by both TCP leaders.
///
/// With `cfg.down_codec` set, the engine's per-round [`ModelFrame`]s
/// carry one new chain link each (the compressed delta
/// `x_k − reference_{k−1}`); the shipper keeps the observed link
/// history plus each worker's last fully-shipped version and picks the
/// cheapest correct [`ModelPayload`] per dispatch:
///
/// * a worker that has never seen a model (fresh, rejoined, or
///   post-resume) gets the dense `Raw` vector — a deterministic
///   re-base, after which it rides the chain;
/// * a worker already at the current version gets an empty chain
///   ("you are current");
/// * otherwise the worker gets exactly the links `(last, current]`.
///
/// Note wire traffic is per *worker connection* while the engine's
/// `bits_down` accounting is per *virtual node* (the paper's cost
/// model) — see `docs/PROTOCOL.md` for why the two intentionally
/// differ.
struct DownlinkShipper {
    enabled: bool,
    /// Version of `links[0]`; meaningful once `links` is non-empty.
    /// After `--resume` the history restarts at the resume round, so
    /// this is not always 1.
    first: usize,
    /// Contiguous link history: `links[i]` belongs to version
    /// `first + i`.
    links: Vec<Encoded>,
    /// Per-worker last model version fully shipped; `None` until the
    /// worker's first dispatch (and again never reset — a *new* worker
    /// index gets a fresh `None` slot instead).
    last_sent: Vec<Option<usize>>,
}

impl DownlinkShipper {
    fn new(enabled: bool, n_workers: usize) -> Self {
        DownlinkShipper { enabled, first: 0, links: Vec::new(), last_sent: vec![None; n_workers] }
    }

    /// Record the newest chain link from this round's frame (no-op for
    /// raw frames — version 0, or downlink compression off).
    fn observe(&mut self, frame: &ModelFrame) -> crate::Result<()> {
        let Some(enc) = &frame.link else { return Ok(()) };
        if self.links.is_empty() {
            self.first = frame.version;
        } else {
            anyhow::ensure!(
                frame.version == self.first + self.links.len(),
                "non-contiguous downlink history: version {} after {} links from {}",
                frame.version,
                self.links.len(),
                self.first
            );
        }
        self.links.push(enc.clone());
        Ok(())
    }

    /// Pick the payload for dispatching `frame` to worker `w` and
    /// advance that worker's shipped version.
    fn payload_for(&mut self, w: usize, frame: &ModelFrame) -> ModelPayload {
        if w >= self.last_sent.len() {
            // Mid-run joiners get fresh slots.
            self.last_sent.resize(w + 1, None);
        }
        if !self.enabled {
            return ModelPayload::Raw(frame.params.clone());
        }
        let cur = frame.version;
        let have = self.last_sent[w];
        self.last_sent[w] = Some(cur);
        match have {
            Some(v) if v == cur => {
                ModelPayload::Chain { base_version: cur as u64, links: Vec::new() }
            }
            Some(v)
                if v < cur
                    && !self.links.is_empty()
                    && self.first <= v + 1
                    && self.first + self.links.len() > cur =>
            {
                ModelPayload::Chain {
                    base_version: v as u64,
                    links: self.links[v + 1 - self.first..=cur - self.first].to_vec(),
                }
            }
            // Fresh worker, or a gap the history cannot bridge: dense
            // re-base.
            _ => ModelPayload::Raw(frame.params.clone()),
        }
    }
}

/// Leader half of the synchronous TCP execution mode: accepts `n_workers`
/// workers on `bind`, broadcasts the config, then round-robins the
/// sampled virtual nodes across them each round. Rounds are charged
/// wall-clock time.
pub struct Tcp {
    bind: String,
    n_workers: usize,
    workers: Vec<WorkerConn>,
    shipper: DownlinkShipper,
    events: EventSink,
}

impl Tcp {
    pub fn new(bind: impl Into<String>, n_workers: usize) -> Self {
        Tcp {
            bind: bind.into(),
            n_workers,
            workers: Vec::new(),
            shipper: DownlinkShipper::new(false, 0),
            events: EventSink::null(),
        }
    }
}

impl Transport for Tcp {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn virtual_time(&self) -> bool {
        false
    }

    fn rebuilds_codec_from_config(&self) -> bool {
        true
    }

    fn set_events(&mut self, events: EventSink) {
        self.events = events;
    }

    fn setup(
        &mut self,
        cfg: &ExperimentConfig,
        _engine: &mut dyn Engine,
    ) -> crate::Result<()> {
        // The barrier leader admits no mid-run joiners: drop the listener.
        let (workers, _listener) =
            accept_cluster(&self.bind, self.n_workers, cfg, &self.events)?;
        self.workers = workers;
        self.shipper = DownlinkShipper::new(cfg.down_codec.is_some(), self.n_workers);
        Ok(())
    }

    fn round(
        &mut self,
        ctx: &RoundCtx<'_>,
        _codec: &dyn UpdateCodec,
        _engine: &mut dyn Engine,
    ) -> crate::Result<RoundOutcome> {
        anyhow::ensure!(!self.workers.is_empty(), "Tcp::round before setup");
        self.shipper.observe(ctx.frame)?;
        // Fan the r virtual nodes out by their *stable* assignment
        // (node % n_workers — see the module docs): per-round counts can
        // skew, but a node's stateful codec memory always lives on one
        // worker.
        let mut counts = vec![0usize; self.n_workers];
        for &node in ctx.nodes {
            let wi = node % self.n_workers;
            counts[wi] += 1;
            let payload = self.shipper.payload_for(wi, ctx.frame);
            let w = &mut self.workers[wi];
            send_to_worker(
                &mut w.wr,
                &ToWorker::Work {
                    version: ctx.round as u64,
                    node: node as u64,
                    payload,
                    lrs: ctx.lrs.to_vec(),
                },
            )?;
        }
        // Collect each worker's replies (answered in its dispatch order);
        // return them in *node order* for bit-stable parity with the
        // in-process transport.
        let mut updates: Vec<Option<Encoded>> = vec![None; ctx.nodes.len()];
        for (wi, &count) in counts.iter().enumerate() {
            for _ in 0..count {
                let w = &mut self.workers[wi];
                match recv_to_leader(&mut w.rd)? {
                    ToLeader::Update { version, node, enc, .. } => {
                        anyhow::ensure!(version as usize == ctx.round, "round mismatch");
                        let pos = ctx
                            .nodes
                            .iter()
                            .position(|&n| n == node as usize)
                            .ok_or_else(|| anyhow::anyhow!("unknown node {node}"))?;
                        anyhow::ensure!(
                            updates[pos].is_none(),
                            "duplicate update for node {node}"
                        );
                        updates[pos] = Some(enc);
                    }
                    other => anyhow::bail!("unexpected message {other:?}"),
                }
            }
        }
        let uploads: Vec<Encoded> = updates.into_iter().flatten().collect();
        anyhow::ensure!(uploads.len() == ctx.nodes.len(), "missing updates");
        // A TCP round is a full barrier: every upload is staleness 0 and
        // the engine charges wall-clock time.
        Ok(RoundOutcome::barrier(ctx, uploads))
    }

    fn shutdown(&mut self) -> crate::Result<()> {
        for w in self.workers.iter_mut() {
            send_to_worker(&mut w.wr, &ToWorker::Shutdown)?;
        }
        Ok(())
    }
}

/// Leader half of the **buffered-async** TCP execution mode: no global
/// barrier. Dispatches are stamped with the model version they broadcast;
/// uploads are consumed in true cross-worker arrival order (per-connection
/// reader threads feeding one channel) and fed to the shared
/// [`CommitPlanner`], which decides when to commit, what to drop as too
/// stale, and which node to re-dispatch on the freed capacity. With
/// `buffer_size == r` and `max_staleness == 0` every commit waits for its
/// whole wave and sorts back into sampling order, so the committed model
/// sequence is bit-identical to the barrier [`Tcp`] run — asserted by
/// `rust/tests/tcp_async.rs` and the CI async-TCP determinism leg.
pub struct TcpAsync {
    bind: String,
    n_workers: usize,
    /// Write halves, indexed by worker; `None` once a worker is dead.
    /// Read halves live on the reader threads after setup. Mid-run
    /// joiners append, so the vector can outgrow `n_workers`.
    writers: Vec<Option<TcpStream>>,
    /// Liveness per worker index. A worker leaves exactly once: the flag
    /// makes duplicate death reports (write failure racing reader EOF)
    /// idempotent.
    alive: Vec<bool>,
    /// Virtual node → worker index. Pinned to `node % n_workers` (see the
    /// module docs) until the assigned worker dies, then deterministically
    /// re-pinned to the next live index.
    assign: Vec<usize>,
    /// Jobs dispatched and not yet arrived: `(node, version, worker)` —
    /// the worker each job was *actually sent to*, which is what death
    /// retirement must key on.
    pending: Vec<(usize, usize, usize)>,
    /// Every `(node, version)` dispatched since the last commit — the
    /// engine bills downlink bits off this list (mirrors `AsyncSim`).
    dispatched: Vec<(usize, usize)>,
    /// Raw-vs-chain payload selection per worker.
    shipper: DownlinkShipper,
    arrivals: Option<Receiver<(usize, FromWorker)>>,
    /// Kept to hand clones to reader threads for mid-run joiners, and to
    /// report write-path deaths through the same channel as read-path
    /// ones. Dropped at shutdown so `recv` can disconnect.
    arrivals_tx: Option<Sender<(usize, FromWorker)>>,
    /// Handshaken mid-run joiners, shipped over from the accept thread.
    joins: Option<Receiver<WorkerConn>>,
    accept_stop: Option<Arc<AtomicBool>>,
    accept_thread: Option<JoinHandle<()>>,
    readers: Vec<JoinHandle<()>>,
    planner: Option<CommitPlanner>,
    events: EventSink,
}

/// What a per-connection reader thread feeds the leader: a wire message,
/// or the news that the connection died (read error / EOF).
enum FromWorker {
    Msg(ToLeader),
    Dead(String),
}

/// Full `Join`/`Setup`/`Ready` handshake for a worker connecting after
/// the run has started.
fn handshake_joiner(
    stream: TcpStream,
    peer: std::net::SocketAddr,
    cfg: &ExperimentConfig,
) -> crate::Result<WorkerConn> {
    // The listener is non-blocking (the accept thread polls it); the
    // handshake itself must block.
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true)?;
    let rd = stream.try_clone()?;
    let mut conn = WorkerConn { rd, wr: stream, peer: peer.to_string() };
    match recv_to_leader(&mut conn.rd)? {
        ToLeader::Join { proto } => anyhow::ensure!(
            proto == PROTO_VERSION,
            "worker at {peer} speaks wire-protocol v{proto}; this leader \
             requires v{PROTO_VERSION} — rebuild so leader and workers match"
        ),
        other => anyhow::bail!("expected Join from {peer}, got {other:?}"),
    }
    setup_worker(&mut conn, cfg)?;
    Ok(conn)
}

impl TcpAsync {
    pub fn new(bind: impl Into<String>, n_workers: usize) -> Self {
        TcpAsync {
            bind: bind.into(),
            n_workers,
            writers: Vec::new(),
            alive: Vec::new(),
            assign: Vec::new(),
            pending: Vec::new(),
            dispatched: Vec::new(),
            shipper: DownlinkShipper::new(false, 0),
            arrivals: None,
            arrivals_tx: None,
            joins: None,
            accept_stop: None,
            accept_thread: None,
            readers: Vec::new(),
            planner: None,
            events: EventSink::null(),
        }
    }

    /// Total stale uploads dropped so far in this run.
    pub fn dropped(&self) -> u64 {
        self.planner.as_ref().map_or(0, CommitPlanner::dropped)
    }

    /// Spawn the reader thread for worker `idx`: forwards every wire
    /// message tagged with the worker index, then a final `Dead` when the
    /// socket errors or closes. After a clean shutdown the leader has
    /// already dropped the receiver, so the sends fail silently and the
    /// thread just ends.
    fn spawn_reader(&mut self, idx: usize, mut rd: TcpStream) {
        let tx = self
            .arrivals_tx
            .as_ref()
            .expect("spawn_reader before setup")
            .clone();
        self.readers.push(std::thread::spawn(move || loop {
            match recv_to_leader(&mut rd) {
                Ok(msg) => {
                    if tx.send((idx, FromWorker::Msg(msg))).is_err() {
                        return;
                    }
                }
                Err(e) => {
                    let _ = tx.send((idx, FromWorker::Dead(e.to_string())));
                    return;
                }
            }
        }));
    }

    /// Integrate any workers that completed the mid-run handshake since
    /// the last check. Joiners get the next free index; existing node
    /// pins are untouched (a joiner only picks up nodes when a pinned
    /// worker later dies), so a join alone never perturbs the protocol
    /// stream — bit-identity with the undisturbed run is preserved.
    fn absorb_joins(&mut self) {
        let joined: Vec<WorkerConn> = match &self.joins {
            Some(rx) => rx.try_iter().collect(),
            None => Vec::new(),
        };
        for conn in joined {
            let idx = self.writers.len();
            let WorkerConn { rd, wr, peer } = conn;
            self.writers.push(Some(wr));
            self.alive.push(true);
            self.spawn_reader(idx, rd);
            self.events.emit(
                "worker_joined",
                vec![
                    ("peer", Json::str(peer.as_str())),
                    ("worker", Json::num(idx as f64)),
                ],
            );
            eprintln!("leader: worker {idx} joined mid-run from {peer}");
        }
    }

    /// The worker that should run `node`: its pin if alive, else the
    /// next live index scanning forward (deterministic, and re-pinned so
    /// the node's future jobs stay on one worker).
    fn worker_for(&mut self, node: usize) -> crate::Result<usize> {
        let pinned = self.assign[node];
        if self.alive.get(pinned).copied().unwrap_or(false) {
            return Ok(pinned);
        }
        let n = self.writers.len();
        for off in 1..=n {
            let cand = (pinned + off) % n;
            if self.alive[cand] {
                self.assign[node] = cand;
                return Ok(cand);
            }
        }
        anyhow::bail!("no live workers remain to run node {node}")
    }

    /// Execute one planner `Dispatch` decision: send the current model to
    /// the node's assigned worker (a worker's jobs queue in its socket
    /// and run serially, which keeps any stateful codec memory for its
    /// nodes in one process). A failed write is reported through the
    /// arrivals channel as a death — the same path a reader-thread EOF
    /// takes — so retirement and re-dispatch happen in exactly one place.
    fn dispatch(
        &mut self,
        node: usize,
        version: usize,
        ctx: &RoundCtx<'_>,
    ) -> crate::Result<()> {
        // Every dispatch happens at the planner's current version, which
        // is the model the engine handed us this round; a delta chain
        // built against any other version would reconstruct the wrong
        // model on the worker.
        anyhow::ensure!(
            version == ctx.frame.version,
            "async dispatch at version {version} but the round's model frame \
             is version {}",
            ctx.frame.version
        );
        let w = self.worker_for(node)?;
        self.pending.push((node, version, w));
        self.dispatched.push((node, version));
        let payload = self.shipper.payload_for(w, ctx.frame);
        let frame = ToWorker::Work {
            version: version as u64,
            node: node as u64,
            payload,
            lrs: ctx.lrs.to_vec(),
        };
        let wr = self.writers[w].as_mut().expect("live worker has a writer");
        match send_to_worker(wr, &frame) {
            Ok(()) => {
                self.events.emit(
                    "job_dispatched",
                    vec![
                        ("node", Json::num(node as f64)),
                        ("version", Json::num(version as f64)),
                        ("worker", Json::num(w as f64)),
                    ],
                );
            }
            Err(e) => {
                if let Some(tx) = &self.arrivals_tx {
                    let _ = tx.send((w, FromWorker::Dead(format!("write failed: {e}"))));
                }
            }
        }
        Ok(())
    }

    /// Retire a dead worker: mark it gone, give every job it still held
    /// back to the planner as freed capacity, and return the planner's
    /// replacement dispatches. Idempotent — a second report for the same
    /// worker is a no-op.
    fn handle_dead(&mut self, w: usize, reason: &str) -> crate::Result<Vec<Decision>> {
        if !self.alive.get(w).copied().unwrap_or(false) {
            return Ok(Vec::new());
        }
        self.alive[w] = false;
        self.writers[w] = None;
        let lost: Vec<(usize, usize)> = self
            .pending
            .iter()
            .filter(|&&(_, _, pw)| pw == w)
            .map(|&(n, v, _)| (n, v))
            .collect();
        self.pending.retain(|&(_, _, pw)| pw != w);
        self.events.emit(
            "worker_left",
            vec![
                ("jobs_retired", Json::num(lost.len() as f64)),
                ("reason", Json::str(reason)),
                ("worker", Json::num(w as f64)),
            ],
        );
        eprintln!(
            "leader: worker {w} left ({reason}); retiring {} in-flight job(s)",
            lost.len()
        );
        anyhow::ensure!(
            self.alive.iter().any(|&a| a),
            "all workers are gone; cannot continue the run"
        );
        let planner = self.planner.as_mut().unwrap();
        let mut decisions = Vec::new();
        for (node, version) in lost {
            decisions.extend(planner.on_event(PlannerEvent::CapacityFreed { node, version })?);
        }
        Ok(decisions)
    }

    /// Block until the next tagged message arrives on any connection.
    fn next_event(&mut self) -> crate::Result<(usize, FromWorker)> {
        let rx = self
            .arrivals
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("TcpAsync used before setup"))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("all worker connections closed"))
    }
}

impl Transport for TcpAsync {
    fn name(&self) -> &'static str {
        "tcp-async"
    }

    fn virtual_time(&self) -> bool {
        false
    }

    fn rebuilds_codec_from_config(&self) -> bool {
        true
    }

    fn buffered_async(&self) -> bool {
        true
    }

    fn set_events(&mut self, events: EventSink) {
        self.events = events;
    }

    fn setup(
        &mut self,
        cfg: &ExperimentConfig,
        _engine: &mut dyn Engine,
    ) -> crate::Result<()> {
        let (workers, listener) =
            accept_cluster(&self.bind, self.n_workers, cfg, &self.events)?;
        self.planner = Some(CommitPlanner::new(cfg)?);
        self.assign = (0..cfg.n_nodes).map(|n| n % self.n_workers).collect();
        self.pending.clear();
        self.dispatched.clear();
        self.shipper = DownlinkShipper::new(cfg.down_codec.is_some(), self.n_workers);
        self.writers.clear();
        self.alive.clear();
        self.readers.clear();
        // One reader thread per connection, all feeding one channel: the
        // leader sees uploads in real arrival order across workers,
        // tagged with the worker index so a death can be attributed.
        let (tx, rx) = channel();
        self.arrivals_tx = Some(tx);
        self.arrivals = Some(rx);
        for conn in workers {
            let idx = self.writers.len();
            let WorkerConn { rd, wr, .. } = conn;
            self.writers.push(Some(wr));
            self.alive.push(true);
            self.spawn_reader(idx, rd);
        }
        // Keep listening: a replacement worker may join mid-run. The
        // accept thread polls a non-blocking listener (so it can see the
        // stop flag at shutdown), runs the full handshake, and ships the
        // ready connection over for the leader to absorb between events.
        // A joiner that fails its handshake is simply dropped.
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let (join_tx, join_rx) = channel();
        self.accept_stop = Some(Arc::clone(&stop));
        self.joins = Some(join_rx);
        let cfg = cfg.clone();
        self.accept_thread = Some(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(25));
                    }
                    Err(_) => return,
                    Ok((stream, peer)) => {
                        if let Ok(conn) = handshake_joiner(stream, peer, &cfg) {
                            if join_tx.send(conn).is_err() {
                                return;
                            }
                        }
                    }
                }
            }
        }));
        Ok(())
    }

    fn round(
        &mut self,
        ctx: &RoundCtx<'_>,
        _codec: &dyn UpdateCodec,
        _engine: &mut dyn Engine,
    ) -> crate::Result<RoundOutcome> {
        anyhow::ensure!(!self.writers.is_empty(), "TcpAsync::round before setup");
        {
            let planner = self.planner.as_mut().unwrap();
            anyhow::ensure!(
                ctx.round == planner.version(),
                "TcpAsync expects sequential rounds: got {} at version {}",
                ctx.round,
                planner.version()
            );
        }
        self.absorb_joins();
        self.shipper.observe(ctx.frame)?;
        self.dispatched.clear();
        // Refill wave at the current model (the whole sampled set at
        // version 0, then `buffer_size` jobs per commit) — exactly r jobs
        // in flight at every instant. Decisions are queued and drained in
        // planner order; a death mid-round splices its replacement
        // dispatches into the same queue.
        let mut queue: std::collections::VecDeque<Decision> =
            self.planner.as_mut().unwrap().begin_version(ctx.nodes)?.into();
        loop {
            while let Some(d) = queue.pop_front() {
                match d {
                    Decision::Dispatch { node, version, .. } => {
                        self.dispatch(node, version, ctx)?
                    }
                    Decision::Drop { node, staleness } => {
                        self.events.emit(
                            "upload_dropped",
                            vec![
                                ("node", Json::num(node as f64)),
                                ("staleness", Json::num(staleness as f64)),
                            ],
                        );
                        eprintln!(
                            "[tcp-async] commit {}: dropped node {node} upload \
                             (staleness {staleness})",
                            ctx.round
                        );
                    }
                    Decision::Commit { uploads, dropped } => {
                        return Ok(RoundOutcome {
                            uploads,
                            timing: None,
                            dropped,
                            dispatches: std::mem::take(&mut self.dispatched),
                            uplink_bits: None,
                        });
                    }
                }
            }
            let (w, msg) = self.next_event()?;
            self.absorb_joins();
            match msg {
                FromWorker::Dead(reason) => {
                    queue.extend(self.handle_dead(w, &reason)?);
                }
                FromWorker::Msg(ToLeader::Update { version, node, enc, compute_ms, decode_ms }) => {
                    let (node, version) = (node as usize, version as usize);
                    let pos = self
                        .pending
                        .iter()
                        .position(|&(n, v, _)| n == node && v == version);
                    let Some(pos) = pos else {
                        // A straggler from a worker already declared dead:
                        // its job was retired and re-dispatched, so this
                        // upload no longer has a slot.
                        eprintln!(
                            "[tcp-async] ignoring late upload (node {node}, \
                             version {version}) from a retired job"
                        );
                        continue;
                    };
                    self.pending.swap_remove(pos);
                    self.events.emit(
                        "upload_arrived",
                        vec![
                            ("compute_ms", Json::num(compute_ms)),
                            ("decode_ms", Json::num(decode_ms)),
                            ("node", Json::num(node as f64)),
                            ("version", Json::num(version as f64)),
                            ("worker", Json::num(w as f64)),
                        ],
                    );
                    queue.extend(self.planner.as_mut().unwrap().on_event(
                        PlannerEvent::UploadArrived { node, version, enc },
                    )?);
                }
                FromWorker::Msg(other) => anyhow::bail!("unexpected message {other:?}"),
            }
        }
    }

    fn shutdown(&mut self) -> crate::Result<()> {
        // Stop admitting joiners first.
        if let Some(stop) = self.accept_stop.take() {
            stop.store(true, Ordering::Relaxed);
        }
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        self.joins = None;
        // Drain the straggler jobs still in flight (workers always finish
        // a dispatched Work before reading Shutdown), discard their
        // uploads, then release everyone. Tear-down is best-effort: a
        // dead connection mid-drain must not leave the healthy workers
        // blocked in recv or the reader threads unjoined — every step
        // still runs, and the first error is reported at the end.
        let dropped = self.planner.as_ref().map_or(0, CommitPlanner::dropped);
        let mut first_err: Option<anyhow::Error> = None;
        while !self.pending.is_empty() {
            match self.next_event() {
                Ok((w, FromWorker::Dead(reason))) => {
                    if self.alive.get(w).copied().unwrap_or(false) {
                        self.alive[w] = false;
                        self.writers[w] = None;
                        let lost =
                            self.pending.iter().filter(|&&(_, _, pw)| pw == w).count();
                        self.pending.retain(|&(_, _, pw)| pw != w);
                        self.events.emit(
                            "worker_left",
                            vec![
                                ("jobs_retired", Json::num(lost as f64)),
                                ("reason", Json::str(reason.as_str())),
                                ("worker", Json::num(w as f64)),
                            ],
                        );
                        eprintln!(
                            "leader: worker {w} left during drain ({reason}); \
                             discarding {lost} in-flight job(s)"
                        );
                    }
                }
                Ok((_, FromWorker::Msg(ToLeader::Update { version, node, .. }))) => {
                    let (node, version) = (node as usize, version as usize);
                    if let Some(pos) = self
                        .pending
                        .iter()
                        .position(|&(n, v, _)| n == node && v == version)
                    {
                        self.pending.swap_remove(pos);
                    }
                }
                Ok((_, FromWorker::Msg(other))) => {
                    first_err
                        .get_or_insert_with(|| anyhow::anyhow!("unexpected message {other:?}"));
                    break;
                }
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        if dropped > 0 {
            eprintln!("[tcp-async] run complete: {dropped} stale upload(s) dropped");
        }
        for w in self.writers.iter_mut().flatten() {
            if let Err(e) = send_to_worker(w, &ToWorker::Shutdown) {
                first_err.get_or_insert(e);
            }
        }
        // Dropping both channel ends lets reader threads exit as soon as
        // their socket closes; join to not leak threads past the run.
        self.arrivals_tx = None;
        self.arrivals = None;
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn export_state(&self) -> crate::Result<Option<crate::ops::TransportState>> {
        let planner = self
            .planner
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("TcpAsync::export_state before setup"))?;
        // Real in-flight jobs live in worker processes and cannot be
        // serialized; the planner snapshot records them, and
        // `restore_state` insists the snapshot be quiescent.
        Ok(Some(crate::ops::TransportState::Async {
            planner: planner.export_state(),
            now: 0.0,
            jobs: Vec::new(),
        }))
    }

    fn restore_state(&mut self, state: crate::ops::TransportState) -> crate::Result<()> {
        anyhow::ensure!(!self.writers.is_empty(), "TcpAsync::restore_state before setup");
        let crate::ops::TransportState::Async { planner, now: _, jobs } = state else {
            anyhow::bail!(
                "checkpoint holds tree-transport state; resume it with a tree \
                 leader (--edge-leaders), not a flat tcp-async leader"
            );
        };
        anyhow::ensure!(
            jobs.is_empty() && planner.in_flight.is_empty() && planner.buffer.is_empty(),
            "tcp-async can only resume from a quiescent checkpoint (no in-flight \
             jobs or buffered uploads): in-flight model state lives in worker \
             processes and cannot be recreated. Run with buffer_size == r and \
             max_staleness == 0 (where every commit quiesces), or resume this \
             checkpoint in the simulator instead"
        );
        self.planner = Some(CommitPlanner::from_state(planner)?);
        Ok(())
    }
}
