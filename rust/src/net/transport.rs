//! The networked [`Transport`]s: leader-side fan-out/fan-in over TCP.
//!
//! Wraps the [`proto`](super::proto) wire protocol behind the
//! coordinator's [`Transport`] seam, so the exact same
//! [`RoundEngine`](crate::coordinator::RoundEngine) loop that drives the
//! in-process simulation also drives a real worker cluster — no
//! duplicated round logic. Two leaders share the plumbing:
//!
//! * [`Tcp`] — the synchronous barrier: every commit fans out all of
//!   `S_k`, waits for every upload, and aggregates in node order
//!   (bit-identical to the in-process sim for equal seeds).
//! * [`TcpAsync`] — the buffered-async protocol on real sockets: the
//!   leader keeps `r` jobs in flight, commits as soon as `buffer_size`
//!   uploads land, stamps stragglers with their staleness and
//!   re-dispatches drops — every protocol decision delegated to the same
//!   [`CommitPlanner`](crate::coordinator::commit_loop::CommitPlanner)
//!   that drives [`AsyncSim`](crate::coordinator::AsyncSim), so there is
//!   exactly one implementation of the buffer/staleness/re-dispatch
//!   rules in the tree.
//!
//! Barrier fan-out/fan-in is pipelined with blocking sockets: all `Work`
//! frames for a round are written first (worker processes run
//! concurrently), then updates are collected. There is no deadlock cycle
//! — a worker always drains its request before producing its (small)
//! reply, and replies park in kernel socket buffers until the leader
//! reads them. The async leader instead moves each connection's read
//! half onto a reader thread feeding one mpsc channel, so uploads are
//! consumed in true arrival order across workers — the real-socket
//! analogue of `AsyncSim`'s virtual-completion-time queue.
//!
//! ## Node → worker assignment is pinned by node id
//!
//! Both leaders dispatch virtual node `i`'s work to worker
//! `i % n_workers` — a *stable* assignment across rounds, never a
//! positional or round-robin rotation. Stateless codecs cannot tell the
//! difference (every upload is a pure function of `(seed, node,
//! version)`), but stateful codecs keep per-node memory on the worker
//! side ([`ErrorFeedbackCodec`](crate::quant::ErrorFeedbackCodec)
//! residuals, keyed by node inside each worker's codec instance): pinning
//! guarantees one worker owns a given node's entire residual stream, so
//! a distributed error-feedback run reproduces the in-process simulation
//! bit-for-bit instead of fragmenting memory across processes.

use super::proto::{
    recv_to_leader, send_to_worker, ToLeader, ToWorker, PROTO_VERSION,
};
use crate::config::ExperimentConfig;
use crate::coordinator::commit_loop::{CommitPlanner, Decision, PlannerEvent};
use crate::coordinator::{RoundCtx, RoundOutcome, Transport};
use crate::model::Engine;
use crate::quant::{Encoded, UpdateCodec};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver};
use std::thread::JoinHandle;

struct WorkerConn {
    rd: TcpStream,
    wr: TcpStream,
}

fn accept_worker(listener: &TcpListener) -> crate::Result<WorkerConn> {
    let (stream, peer) = listener.accept()?;
    stream.set_nodelay(true)?;
    let mut rd = stream.try_clone()?;
    match recv_to_leader(&mut rd)? {
        ToLeader::Join { proto } => anyhow::ensure!(
            proto == PROTO_VERSION,
            "worker at {peer} speaks wire-protocol v{proto}; this leader \
             requires v{PROTO_VERSION} — rebuild so leader and workers match"
        ),
        other => anyhow::bail!("expected Join from {peer}, got {other:?}"),
    }
    eprintln!("leader: worker joined from {peer}");
    Ok(WorkerConn { rd, wr: stream })
}

/// Accept `n_workers` workers on `bind`, run the `Join`/`Setup`/`Ready`
/// handshake, and hand back the ready connections. Shared by both
/// leaders.
fn accept_cluster(
    bind: &str,
    n_workers: usize,
    cfg: &ExperimentConfig,
) -> crate::Result<Vec<WorkerConn>> {
    anyhow::ensure!(n_workers >= 1, "need at least one worker");
    let listener = TcpListener::bind(bind)?;
    eprintln!("leader: listening on {}", listener.local_addr()?);
    let mut workers = Vec::with_capacity(n_workers);
    for _ in 0..n_workers {
        workers.push(accept_worker(&listener)?);
    }
    // Broadcast setup; await Ready from everyone (engines compile now).
    for w in workers.iter_mut() {
        send_to_worker(
            &mut w.wr,
            &ToWorker::Setup { proto: PROTO_VERSION, cfg: cfg.clone() },
        )?;
    }
    for w in workers.iter_mut() {
        let msg = recv_to_leader(&mut w.rd)?;
        anyhow::ensure!(matches!(msg, ToLeader::Ready), "expected Ready");
    }
    eprintln!("leader: {n_workers} workers ready");
    Ok(workers)
}

/// Leader half of the synchronous TCP execution mode: accepts `n_workers`
/// workers on `bind`, broadcasts the config, then round-robins the
/// sampled virtual nodes across them each round. Rounds are charged
/// wall-clock time.
pub struct Tcp {
    bind: String,
    n_workers: usize,
    workers: Vec<WorkerConn>,
}

impl Tcp {
    pub fn new(bind: impl Into<String>, n_workers: usize) -> Self {
        Tcp { bind: bind.into(), n_workers, workers: Vec::new() }
    }
}

impl Transport for Tcp {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn virtual_time(&self) -> bool {
        false
    }

    fn rebuilds_codec_from_config(&self) -> bool {
        true
    }

    fn setup(
        &mut self,
        cfg: &ExperimentConfig,
        _engine: &mut dyn Engine,
    ) -> crate::Result<()> {
        self.workers = accept_cluster(&self.bind, self.n_workers, cfg)?;
        Ok(())
    }

    fn round(
        &mut self,
        ctx: &RoundCtx<'_>,
        _codec: &dyn UpdateCodec,
        _engine: &mut dyn Engine,
    ) -> crate::Result<RoundOutcome> {
        anyhow::ensure!(!self.workers.is_empty(), "Tcp::round before setup");
        // Fan the r virtual nodes out by their *stable* assignment
        // (node % n_workers — see the module docs): per-round counts can
        // skew, but a node's stateful codec memory always lives on one
        // worker.
        let mut counts = vec![0usize; self.n_workers];
        for &node in ctx.nodes {
            counts[node % self.n_workers] += 1;
            let w = &mut self.workers[node % self.n_workers];
            send_to_worker(
                &mut w.wr,
                &ToWorker::Work {
                    version: ctx.round as u64,
                    node: node as u64,
                    params: ctx.params.to_vec(),
                    lrs: ctx.lrs.to_vec(),
                },
            )?;
        }
        // Collect each worker's replies (answered in its dispatch order);
        // return them in *node order* for bit-stable parity with the
        // in-process transport.
        let mut updates: Vec<Option<Encoded>> = vec![None; ctx.nodes.len()];
        for (wi, &count) in counts.iter().enumerate() {
            for _ in 0..count {
                let w = &mut self.workers[wi];
                match recv_to_leader(&mut w.rd)? {
                    ToLeader::Update { version, node, enc } => {
                        anyhow::ensure!(version as usize == ctx.round, "round mismatch");
                        let pos = ctx
                            .nodes
                            .iter()
                            .position(|&n| n == node as usize)
                            .ok_or_else(|| anyhow::anyhow!("unknown node {node}"))?;
                        anyhow::ensure!(
                            updates[pos].is_none(),
                            "duplicate update for node {node}"
                        );
                        updates[pos] = Some(enc);
                    }
                    other => anyhow::bail!("unexpected message {other:?}"),
                }
            }
        }
        let uploads: Vec<Encoded> = updates.into_iter().flatten().collect();
        anyhow::ensure!(uploads.len() == ctx.nodes.len(), "missing updates");
        // A TCP round is a full barrier: every upload is staleness 0 and
        // the engine charges wall-clock time.
        Ok(RoundOutcome::barrier(ctx, uploads))
    }

    fn shutdown(&mut self) -> crate::Result<()> {
        for w in self.workers.iter_mut() {
            send_to_worker(&mut w.wr, &ToWorker::Shutdown)?;
        }
        Ok(())
    }
}

/// Leader half of the **buffered-async** TCP execution mode: no global
/// barrier. Dispatches are stamped with the model version they broadcast;
/// uploads are consumed in true cross-worker arrival order (per-connection
/// reader threads feeding one channel) and fed to the shared
/// [`CommitPlanner`], which decides when to commit, what to drop as too
/// stale, and which node to re-dispatch on the freed capacity. With
/// `buffer_size == r` and `max_staleness == 0` every commit waits for its
/// whole wave and sorts back into sampling order, so the committed model
/// sequence is bit-identical to the barrier [`Tcp`] run — asserted by
/// `rust/tests/tcp_async.rs` and the CI async-TCP determinism leg.
pub struct TcpAsync {
    bind: String,
    n_workers: usize,
    /// Write halves, indexed by worker; read halves live on the reader
    /// threads after setup.
    writers: Vec<TcpStream>,
    arrivals: Option<Receiver<crate::Result<ToLeader>>>,
    readers: Vec<JoinHandle<()>>,
    planner: Option<CommitPlanner>,
}

impl TcpAsync {
    pub fn new(bind: impl Into<String>, n_workers: usize) -> Self {
        TcpAsync {
            bind: bind.into(),
            n_workers,
            writers: Vec::new(),
            arrivals: None,
            readers: Vec::new(),
            planner: None,
        }
    }

    /// Total stale uploads dropped so far in this run.
    pub fn dropped(&self) -> u64 {
        self.planner.as_ref().map_or(0, CommitPlanner::dropped)
    }

    /// Execute one planner `Dispatch` decision: send the current model to
    /// the node's pinned worker (`node % n_workers` — see the module
    /// docs; a worker's jobs queue in its socket and run serially, which
    /// keeps any stateful codec memory for its nodes in one process).
    fn dispatch(
        &mut self,
        node: usize,
        version: usize,
        ctx: &RoundCtx<'_>,
    ) -> crate::Result<()> {
        let w = node % self.n_workers;
        send_to_worker(
            &mut self.writers[w],
            &ToWorker::Work {
                version: version as u64,
                node: node as u64,
                params: ctx.params.to_vec(),
                lrs: ctx.lrs.to_vec(),
            },
        )
    }

    /// Block until the next upload arrives on any connection.
    fn next_upload(&mut self) -> crate::Result<(usize, usize, Encoded)> {
        let rx = self
            .arrivals
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("TcpAsync used before setup"))?;
        let msg = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("all worker connections closed"))??;
        match msg {
            ToLeader::Update { version, node, enc } => {
                Ok((node as usize, version as usize, enc))
            }
            other => anyhow::bail!("unexpected message {other:?}"),
        }
    }
}

impl Transport for TcpAsync {
    fn name(&self) -> &'static str {
        "tcp-async"
    }

    fn virtual_time(&self) -> bool {
        false
    }

    fn rebuilds_codec_from_config(&self) -> bool {
        true
    }

    fn buffered_async(&self) -> bool {
        true
    }

    fn setup(
        &mut self,
        cfg: &ExperimentConfig,
        _engine: &mut dyn Engine,
    ) -> crate::Result<()> {
        let workers = accept_cluster(&self.bind, self.n_workers, cfg)?;
        self.planner = Some(CommitPlanner::new(cfg)?);
        self.writers.clear();
        self.readers.clear();
        // One reader thread per connection, all feeding one channel: the
        // leader sees uploads in real arrival order across workers. A
        // read error is forwarded once and the thread exits; after a
        // clean shutdown the leader has already dropped the receiver, so
        // the forward fails silently and the thread just ends.
        let (tx, rx) = channel();
        for conn in workers {
            let WorkerConn { mut rd, wr } = conn;
            self.writers.push(wr);
            let tx = tx.clone();
            self.readers.push(std::thread::spawn(move || loop {
                match recv_to_leader(&mut rd) {
                    Ok(msg) => {
                        if tx.send(Ok(msg)).is_err() {
                            return;
                        }
                    }
                    Err(e) => {
                        let _ = tx.send(Err(e));
                        return;
                    }
                }
            }));
        }
        self.arrivals = Some(rx);
        Ok(())
    }

    fn round(
        &mut self,
        ctx: &RoundCtx<'_>,
        _codec: &dyn UpdateCodec,
        _engine: &mut dyn Engine,
    ) -> crate::Result<RoundOutcome> {
        anyhow::ensure!(!self.writers.is_empty(), "TcpAsync::round before setup");
        {
            let planner = self.planner.as_mut().unwrap();
            anyhow::ensure!(
                ctx.round == planner.version(),
                "TcpAsync expects sequential rounds: got {} at version {}",
                ctx.round,
                planner.version()
            );
        }
        // Refill wave at the current model (the whole sampled set at
        // version 0, then `buffer_size` jobs per commit) — exactly r jobs
        // in flight at every instant.
        let wave = self.planner.as_mut().unwrap().begin_version(ctx.nodes)?;
        for d in wave {
            match d {
                Decision::Dispatch { node, version, .. } => {
                    self.dispatch(node, version, ctx)?
                }
                other => anyhow::bail!("unexpected wave decision {other:?}"),
            }
        }
        // Event loop: absorb socket arrivals until the planner commits.
        loop {
            let (node, version, enc) = self.next_upload()?;
            let decisions = self
                .planner
                .as_mut()
                .unwrap()
                .on_event(PlannerEvent::UploadArrived { node, version, enc })?;
            for d in decisions {
                match d {
                    Decision::Drop { node, staleness } => {
                        eprintln!(
                            "[tcp-async] commit {}: dropped node {node} upload \
                             (staleness {staleness})",
                            ctx.round
                        );
                    }
                    Decision::Dispatch { node, version, .. } => {
                        self.dispatch(node, version, ctx)?
                    }
                    Decision::Commit { uploads, dropped } => {
                        return Ok(RoundOutcome { uploads, timing: None, dropped });
                    }
                }
            }
        }
    }

    fn shutdown(&mut self) -> crate::Result<()> {
        // Drain the straggler jobs still in flight (workers always finish
        // a dispatched Work before reading Shutdown), discard their
        // uploads, then release everyone. Tear-down is best-effort: a
        // dead connection mid-drain must not leave the healthy workers
        // blocked in recv or the reader threads unjoined — every step
        // still runs, and the first error is reported at the end.
        let (pending, dropped) = self
            .planner
            .as_ref()
            .map_or((0, 0), |p| (p.in_flight(), p.dropped()));
        let mut first_err = None;
        for _ in 0..pending {
            if let Err(e) = self.next_upload() {
                first_err = Some(e);
                break;
            }
        }
        if dropped > 0 {
            eprintln!("[tcp-async] run complete: {dropped} stale upload(s) dropped");
        }
        for w in self.writers.iter_mut() {
            if let Err(e) = send_to_worker(w, &ToWorker::Shutdown) {
                first_err.get_or_insert(e);
            }
        }
        // Dropping the receiver lets reader threads exit as soon as their
        // socket closes; join to not leak threads past the run.
        self.arrivals = None;
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}
