//! Leader entry point: the distributed protocol is just the shared
//! [`RoundEngine`](crate::coordinator::RoundEngine) driven through the
//! [`Tcp`](super::Tcp) transport — the round loop itself lives in
//! `coordinator::engine`, identical to the simulation path. That
//! includes sharded aggregation: `cfg.agg_shards > 1` fans the leader's
//! accumulate/apply across scoped threads with bit-identical results
//! (the `coordinator::aggregate` determinism contract), so a distributed
//! run and its simulated replay can use different shard counts freely.

use super::transport::Tcp;
use crate::config::ExperimentConfig;
use crate::coordinator::{EvalSlab, RoundEngine, RunResult};
use crate::model::Engine;
use std::path::Path;

/// Run the distributed protocol with `n_workers` workers expected on
/// `bind`. The leader also evaluates the loss curve locally on `engine`.
///
/// Returns a [`RunResult`] whose `time` axis is real elapsed seconds.
pub fn run_leader(
    cfg: ExperimentConfig,
    bind: &str,
    n_workers: usize,
    engine: &mut dyn Engine,
    _artifacts: &Path,
) -> crate::Result<RunResult> {
    let cfg = cfg.validated()?;
    // The TCP transport is a barrier protocol; buffered-async rounds are
    // simulation-only for now (ROADMAP: async over real sockets).
    anyhow::ensure!(
        !cfg.async_rounds,
        "async_rounds is not supported by the TCP leader — run `fedpaq train` \
         (the async simulation) or clear the flag"
    );
    let slab = EvalSlab::build(&cfg, engine)?;
    let mut rounds =
        RoundEngine::new(cfg.codec.build()?, Box::new(Tcp::new(bind, n_workers)));
    rounds.run(&cfg, engine, &slab)
}
