//! Leader entry point: the distributed protocol is just the shared
//! [`RoundEngine`](crate::coordinator::RoundEngine) driven through a
//! networked transport — the round loop itself lives in
//! `coordinator::engine`, identical to the simulation path. That
//! includes sharded aggregation: `cfg.agg_shards > 1` fans the leader's
//! accumulate/apply across scoped threads with bit-identical results
//! (the `coordinator::aggregate` determinism contract), so a distributed
//! run and its simulated replay can use different shard counts freely.
//!
//! The config's round protocol picks the transport: synchronous configs
//! run the barrier [`Tcp`](super::Tcp), `cfg.async_rounds` configs run
//! the buffered-async [`TcpAsync`](super::TcpAsync) — the same
//! [`CommitPlanner`](crate::coordinator::commit_loop::CommitPlanner)
//! semantics as the `AsyncSim` simulation, on real sockets.

use super::transport::{Tcp, TcpAsync};
use super::tree::TcpTree;
use crate::config::ExperimentConfig;
use crate::coordinator::{EvalSlab, RoundEngine, RunResult, Transport};
use crate::model::Engine;
use crate::ops::RunControl;
use std::path::Path;

/// Run the distributed protocol with `n_workers` workers expected on
/// `bind`, under operator run control: `ctrl` carries the JSONL event
/// sink, the checkpoint cadence, and an optional checkpoint to resume
/// from (`fedpaq leader --resume` — note the async leader only resumes
/// *quiescent* checkpoints, see [`crate::ops::checkpoint`]). Callers
/// without operator needs pass `&RunControl::default()` — the former
/// `run_leader`/`run_leader_controlled` pair collapsed into this one
/// options-taking signature.
///
/// The leader also evaluates the loss curve locally on `engine`.
/// Returns a [`RunResult`] whose `time` axis is real elapsed seconds.
pub fn run_leader(
    cfg: ExperimentConfig,
    bind: &str,
    n_workers: usize,
    engine: &mut dyn Engine,
    _artifacts: &Path,
    ctrl: &RunControl,
) -> crate::Result<RunResult> {
    let cfg = cfg.validated()?;
    let slab = EvalSlab::build(&cfg, engine)?;
    let transport: Box<dyn Transport> = if cfg.async_rounds {
        Box::new(TcpAsync::new(bind, n_workers))
    } else {
        Box::new(Tcp::new(bind, n_workers))
    };
    let mut rounds = RoundEngine::new(cfg.codec.build()?, transport);
    rounds.run(&cfg, engine, &slab, ctrl)
}

/// Run the distributed protocol as the **root of a two-level
/// aggregation tree** (`fedpaq leader --edge-leaders N`): `n_edges`
/// edge-leader processes connect on `bind` (workers connect to the
/// edges, not here). Requires an async-rounds config —
/// [`TcpTree`](super::TcpTree) rejects barrier configs at setup.
/// `summed` selects lossy partial-aggregate re-encoding at the edges
/// (`--tree-summed`, degenerate knobs only) instead of the default
/// bit-identical relay; see `docs/TOPOLOGY.md`.
pub fn run_leader_tree(
    cfg: ExperimentConfig,
    bind: &str,
    n_edges: usize,
    summed: bool,
    engine: &mut dyn Engine,
    _artifacts: &Path,
    ctrl: &RunControl,
) -> crate::Result<RunResult> {
    let cfg = cfg.validated()?;
    let slab = EvalSlab::build(&cfg, engine)?;
    let transport: Box<dyn Transport> = Box::new(TcpTree::new(bind, n_edges, summed));
    let mut rounds = RoundEngine::new(cfg.codec.build()?, transport);
    rounds.run(&cfg, engine, &slab, ctrl)
}
