//! Leader process: accepts workers, broadcasts the config, then drives the
//! FedPAQ rounds over TCP, measuring *real* wall-clock per round.
//!
//! Fan-out/fan-in is pipelined with blocking sockets: all `Work` frames
//! for a round are written first (worker processes run concurrently), then
//! updates are collected. There is no deadlock cycle — a worker always
//! drains its request before producing its (small) reply, and replies park
//! in kernel socket buffers until the leader reads them.

use super::proto::{recv_to_leader, send_to_worker, ToLeader, ToWorker};
use crate::config::ExperimentConfig;
use crate::coordinator::{aggregate::Aggregator, sampler, RoundStats, RunResult};
use crate::data::{Labels, Partition};
use crate::metrics::{Curve, CurvePoint};
use crate::model::{Engine, LabelBatch};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::time::Instant;

struct WorkerConn {
    rd: TcpStream,
    wr: TcpStream,
}

fn accept_worker(listener: &TcpListener) -> crate::Result<WorkerConn> {
    let (stream, peer) = listener.accept()?;
    stream.set_nodelay(true)?;
    let mut rd = stream.try_clone()?;
    let join = recv_to_leader(&mut rd)?;
    anyhow::ensure!(matches!(join, ToLeader::Join), "expected Join from {peer}");
    eprintln!("leader: worker joined from {peer}");
    Ok(WorkerConn { rd, wr: stream })
}

/// Run the distributed protocol with `n_workers` workers expected on
/// `bind`. The leader also evaluates the loss curve locally on `engine`.
///
/// Returns a [`RunResult`] whose `time` axis is real elapsed seconds.
pub fn run_leader(
    cfg: ExperimentConfig,
    bind: &str,
    n_workers: usize,
    engine: &mut dyn Engine,
    _artifacts: &Path,
) -> crate::Result<RunResult> {
    let cfg = cfg.validated()?;
    anyhow::ensure!(n_workers >= 1, "need at least one worker");
    let listener = TcpListener::bind(bind)?;
    eprintln!("leader: listening on {}", listener.local_addr()?);
    let mut workers = Vec::with_capacity(n_workers);
    for _ in 0..n_workers {
        workers.push(accept_worker(&listener)?);
    }
    // Broadcast setup; await Ready from everyone (engines compile now).
    for w in workers.iter_mut() {
        send_to_worker(&mut w.wr, &ToWorker::Setup { cfg: cfg.clone() })?;
    }
    for w in workers.iter_mut() {
        let msg = recv_to_leader(&mut w.rd)?;
        anyhow::ensure!(matches!(msg, ToLeader::Ready), "expected Ready");
    }
    eprintln!("leader: {n_workers} workers ready");

    // Local eval world (same construction as the sim server).
    let n_samples = cfg.n_nodes * cfg.per_node;
    let data = crate::data::cached_generate(cfg.dataset, cfg.seed, n_samples);
    let partition = Partition::build(cfg.partition, &data, cfg.n_nodes, cfg.per_node, cfg.seed);
    let eval_n = engine.eval_n();
    let all = partition.all_indices();
    anyhow::ensure!(all.len() >= eval_n, "eval slab larger than dataset");
    let idx = &all[..eval_n];
    let mut eval_x = Vec::new();
    data.gather_features(idx, &mut eval_x);
    let mut eval_f = Vec::new();
    let mut eval_i = Vec::new();
    let float_labels = matches!(data.labels, Labels::Float(_));
    if float_labels {
        data.gather_labels_f32(idx, &mut eval_f);
    } else {
        data.gather_labels_i32(idx, &mut eval_i);
    }

    let mut params = engine.init_params()?;
    let p = params.len();
    let rounds = cfg.rounds();
    let mut curve = Curve::new(cfg.name.clone());
    let mut stats = Vec::new();
    let mut total_bits = 0u64;
    let t0 = Instant::now();
    let eval = |engine: &mut dyn Engine, params: &[f32]| -> crate::Result<f64> {
        let y = if float_labels { LabelBatch::F32(&eval_f) } else { LabelBatch::I32(&eval_i) };
        Ok(engine.eval_loss_token(params, 1, &eval_x, y)? as f64)
    };
    let loss0 = eval(engine, &params)?;
    curve.push(CurvePoint { round: 0, iterations: 0, time: 0.0, bits_up: 0, loss: loss0 });

    for k in 0..rounds {
        let round_t0 = Instant::now();
        let nodes = sampler::sample_nodes(cfg.n_nodes, cfg.r, cfg.seed, k);
        let lrs: Vec<f32> = (0..cfg.tau).map(|t| cfg.lr.lr(k, t)).collect();
        // Fan the r virtual nodes out round-robin across workers.
        for (j, &node) in nodes.iter().enumerate() {
            let w = &mut workers[j % n_workers];
            send_to_worker(
                &mut w.wr,
                &ToWorker::Work {
                    round: k as u64,
                    node: node as u64,
                    params: params.clone(),
                    lrs: lrs.clone(),
                },
            )?;
        }
        // Collect all updates; aggregate in *node order* for bit-stable
        // parity with the sim engine.
        let mut updates: Vec<Option<crate::quant::Encoded>> = vec![None; nodes.len()];
        for (j, _) in nodes.iter().enumerate() {
            let w = &mut workers[j % n_workers];
            match recv_to_leader(&mut w.rd)? {
                ToLeader::Update { round, node, enc } => {
                    anyhow::ensure!(round as usize == k, "round mismatch");
                    let pos = nodes
                        .iter()
                        .position(|&n| n == node as usize)
                        .ok_or_else(|| anyhow::anyhow!("unknown node {node}"))?;
                    updates[pos] = Some(enc);
                }
                other => anyhow::bail!("unexpected message {other:?}"),
            }
        }
        let mut agg = Aggregator::new(cfg.quantizer, p);
        for enc in updates.iter().flatten() {
            agg.push(enc);
        }
        anyhow::ensure!(agg.count() == nodes.len(), "missing updates");
        let bits: u64 = agg.upload_bits().iter().sum();
        total_bits += bits;
        agg.apply(&mut params);
        let dt = round_t0.elapsed().as_secs_f64();
        stats.push(RoundStats { round: k, compute_time: dt, comm_time: 0.0, bits_up: bits });
        if (k + 1) % cfg.eval_every == 0 || k + 1 == rounds {
            let loss = eval(engine, &params)?;
            curve.push(CurvePoint {
                round: k + 1,
                iterations: (k + 1) * cfg.tau,
                time: t0.elapsed().as_secs_f64(),
                bits_up: total_bits,
                loss,
            });
        }
    }
    for w in workers.iter_mut() {
        send_to_worker(&mut w.wr, &ToWorker::Shutdown)?;
    }
    Ok(RunResult { curve, params, rounds: stats, total_bits })
}
