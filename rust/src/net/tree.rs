//! Two-level aggregation tree: the root [`TcpTree`] transport and the
//! edge-leader process ([`run_edge_retrying`]).
//!
//! A tree run has three roles. **Workers** are completely unchanged —
//! they dial an edge exactly as they would dial a flat leader and speak
//! the same `Join`/`Setup`/`Work`/`Update` protocol. **Edge leaders**
//! dial the root, accept a pinned cohort of workers, forward the root's
//! dispatches downward, and stream [`ToLeader::PartialUpdate`] frames
//! upward. The **root** runs the same buffered-async
//! [`CommitPlanner`](crate::coordinator::commit_loop::CommitPlanner)
//! loop as the flat [`TcpAsync`](super::TcpAsync) leader — every
//! commit/drop/re-dispatch rule has exactly one implementation.
//!
//! ## Relay vs summed partials
//!
//! * **Relay** (the default): the edge forwards each worker frame
//!   verbatim, one single-contrib partial per upload, in arrival order.
//!   This is the identity re-encode — the root sees exactly the frames
//!   a flat leader would see, so a degenerate-knob relay tree commits
//!   **bit-identically** to the flat sim and the flat `TcpAsync`
//!   cluster, for any edge count.
//! * **Summed** (`--tree-summed`): the edge buffers its cohort's wave,
//!   decodes the frames, sums them coordinate-wise in f64, casts to f32
//!   once, and re-encodes **one** frame through the run's own codec
//!   ([`partial_reencode`]) — the bandwidth-saving mode. A summed
//!   partial can never be bit-identical to the flat run (the f32 cast
//!   and edge-local addition order differ); it promises repeat-run
//!   byte-reproducibility instead, and the root therefore only accepts
//!   it under the degenerate knobs (`buffer_size == r`,
//!   `max_staleness == 0`, stateless codec) where the flush boundary is
//!   a full wave. The flush itself is closed by an explicit
//!   [`ToWorker::FlushPartial`] marker from the root, never by socket
//!   timing. Re-encode randomness comes from the dedicated
//!   `(seed, TREE_STREAM, edge_slot, version)` RNG stream, disjoint
//!   from every worker stream.
//!
//! ## Pinning and weighting
//!
//! Virtual node `i` is pinned to edge slot `i % n_edges` (re-pinned
//! forward-scan on edge death, mirroring the flat leader's worker
//! pinning); inside an edge's cohort of `K` workers the node runs on
//! worker `(i / n_edges) % K`, a stable pure function of the node id,
//! so stateful codec memory stays in one process. A summed partial
//! reaches the aggregator as one [`Upload`] whose `mass` is the cohort
//! size: the sum enters once at the staleness weight `w`, and the
//! normalizer grows by `w · mass` — the same weighted mean the flat run
//! computes, up to f32 rounding (`docs/TOPOLOGY.md` has the algebra).
//!
//! ## Failure domains
//!
//! An edge owns its cohort: a worker death inside an edge kills that
//! edge (its partial stream can no longer be trusted to drain), and the
//! root retires the dead edge's in-flight jobs through the planner's
//! `CapacityFreed` path — surviving edges absorb the re-pinned nodes.
//! The run fails only when no live edges remain. The root emits
//! `edge_joined` / `edge_left` / `partial_committed` on the event bus.
//!
//! ## Split uplink accounting
//!
//! The tree splits `bits_up` into two hops: worker→edge (the sum of
//! contrib frame bits) and edge→root (relay: the same frames again;
//! summed: the one re-encoded frame per partial). Both window counters
//! accumulate at arrival and are handed to the engine at commit — in
//! degenerate mode (what CI byte-diffs) that equals the committed
//! uploads' bits exactly; otherwise it is truthful wire accounting
//! (bits that traveled, including uploads later dropped as stale).

use super::proto::{
    recv_to_leader, recv_to_worker, send_to_leader, send_to_worker, Contrib, ModelPayload,
    PartialPayload, ToLeader, ToWorker, PROTO_VERSION,
};
use crate::config::ExperimentConfig;
use crate::coordinator::commit_loop::{CommitPlanner, Decision, PlannerEvent};
use crate::coordinator::{RoundCtx, RoundOutcome, Transport, Upload};
use crate::model::Engine;
use crate::ops::EventSink;
use crate::quant::{bitstream::BitBuf, Encoded, UpdateCodec};
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::collections::{BTreeSet, HashMap};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Duration;

/// RNG stream id for edge-side partial re-encodes, disjoint from every
/// other stream family in the tree (worker encode streams key on
/// `(seed, node, version)` coordinates; sim streams use ids 0–5, 7, 99).
pub(crate) const TREE_STREAM: u64 = 8;

/// Sum `encs` coordinate-wise (f64 accumulation, one f32 cast) and
/// re-encode the sum through `codec` — the edge half of summed-mode
/// partial aggregation. Returns the frame plus its mass (the cohort
/// size, what the root's [`Upload::mass`] carries). Deterministic for a
/// fixed `rng` stream; public so property tests can pin that contract
/// per codec family.
pub fn partial_reencode(
    codec: &dyn UpdateCodec,
    encs: &[Encoded],
    p: usize,
    rng: &mut Rng,
) -> crate::Result<(Encoded, f64)> {
    anyhow::ensure!(!encs.is_empty(), "cannot re-encode an empty partial");
    let mut sum = vec![0f64; p];
    for enc in encs {
        anyhow::ensure!(
            enc.p == p,
            "partial mixes frame widths: {} vs {p}",
            enc.p
        );
        codec.accumulate_range(enc, 0, p, 1.0, &mut sum)?;
    }
    let x: Vec<f32> = sum.iter().map(|&v| v as f32).collect();
    Ok((codec.encode(&x, rng), encs.len() as f64))
}

/// What a per-edge reader thread feeds the root: a wire message, or the
/// news that the edge connection died.
enum FromEdge {
    Msg(ToLeader),
    Dead(String),
}

/// Root of a two-level aggregation tree: accepts `n_edges` edge leaders
/// on `bind`, then drives the shared [`CommitPlanner`] against their
/// partial-update streams. See the module docs for the relay/summed
/// contract.
pub struct TcpTree {
    bind: String,
    n_edges: usize,
    summed: bool,
    /// Write halves, indexed by edge slot; `None` once an edge is dead.
    writers: Vec<Option<TcpStream>>,
    alive: Vec<bool>,
    /// Virtual node → edge slot. Pinned to `node % n_edges` until the
    /// pinned edge dies, then re-pinned forward-scan.
    assign: Vec<usize>,
    /// Jobs dispatched and not yet arrived: `(node, version, edge)`.
    pending: Vec<(usize, usize, usize)>,
    /// Every `(node, version)` dispatch since the last commit — downlink
    /// bit accounting, mirroring the flat leaders.
    dispatched: Vec<(usize, usize)>,
    arrivals: Option<Receiver<(usize, FromEdge)>>,
    arrivals_tx: Option<Sender<(usize, FromEdge)>>,
    readers: Vec<JoinHandle<()>>,
    planner: Option<CommitPlanner>,
    /// Summed frames awaiting their commit, each with the cohort size it
    /// must commit as one unit with. Slots are `take`n at commit; the
    /// spent `None`s are O(rounds · edges) bookkeeping, not frame data.
    partial_store: Vec<Option<(Encoded, usize)>>,
    /// `(node, version)` → index into `partial_store`.
    store_of: HashMap<(usize, usize), usize>,
    /// Window counters for the split uplink accounting: accumulated at
    /// arrival, taken at commit.
    win_bits_up: u64,
    win_bits_edge: u64,
    events: EventSink,
}

impl TcpTree {
    pub fn new(bind: impl Into<String>, n_edges: usize, summed: bool) -> Self {
        TcpTree {
            bind: bind.into(),
            n_edges,
            summed,
            writers: Vec::new(),
            alive: Vec::new(),
            assign: Vec::new(),
            pending: Vec::new(),
            dispatched: Vec::new(),
            arrivals: None,
            arrivals_tx: None,
            readers: Vec::new(),
            planner: None,
            partial_store: Vec::new(),
            store_of: HashMap::new(),
            win_bits_up: 0,
            win_bits_edge: 0,
            events: EventSink::null(),
        }
    }

    /// Total stale uploads dropped so far in this run.
    pub fn dropped(&self) -> u64 {
        self.planner.as_ref().map_or(0, CommitPlanner::dropped)
    }

    fn spawn_reader(&mut self, idx: usize, mut rd: TcpStream) {
        let tx = self
            .arrivals_tx
            .as_ref()
            .expect("spawn_reader before setup")
            .clone();
        self.readers.push(std::thread::spawn(move || loop {
            match recv_to_leader(&mut rd) {
                Ok(msg) => {
                    if tx.send((idx, FromEdge::Msg(msg))).is_err() {
                        return;
                    }
                }
                Err(e) => {
                    let _ = tx.send((idx, FromEdge::Dead(e.to_string())));
                    return;
                }
            }
        }));
    }

    /// The edge that should run `node`: its pin if alive, else the next
    /// live slot scanning forward (deterministic re-pin).
    fn edge_for(&mut self, node: usize) -> crate::Result<usize> {
        let pinned = self.assign[node];
        if self.alive.get(pinned).copied().unwrap_or(false) {
            return Ok(pinned);
        }
        let n = self.writers.len();
        for off in 1..=n {
            let cand = (pinned + off) % n;
            if self.alive[cand] {
                self.assign[node] = cand;
                return Ok(cand);
            }
        }
        anyhow::bail!("no live edge leaders remain to run node {node}")
    }

    /// Execute one planner `Dispatch`: ship the current model to the
    /// node's edge. Returns the edge slot (for wave-marker bursts). A
    /// failed write is reported through the arrivals channel as a death.
    fn dispatch(
        &mut self,
        node: usize,
        version: usize,
        ctx: &RoundCtx<'_>,
    ) -> crate::Result<usize> {
        anyhow::ensure!(
            version == ctx.frame.version,
            "tree dispatch at version {version} but the round's model frame \
             is version {}",
            ctx.frame.version
        );
        let e = self.edge_for(node)?;
        self.pending.push((node, version, e));
        self.dispatched.push((node, version));
        let frame = ToWorker::Work {
            version: version as u64,
            node: node as u64,
            // Tree setups reject down_codec configs, so the model always
            // ships dense.
            payload: ModelPayload::Raw(ctx.frame.params.clone()),
            lrs: ctx.lrs.to_vec(),
        };
        let wr = self.writers[e].as_mut().expect("live edge has a writer");
        match send_to_worker(wr, &frame) {
            Ok(()) => {
                self.events.emit(
                    "job_dispatched",
                    vec![
                        ("edge", Json::num(e as f64)),
                        ("node", Json::num(node as f64)),
                        ("version", Json::num(version as f64)),
                    ],
                );
            }
            Err(err) => {
                if let Some(tx) = &self.arrivals_tx {
                    let _ = tx.send((e, FromEdge::Dead(format!("write failed: {err}"))));
                }
            }
        }
        Ok(e)
    }

    /// Retire a dead edge: mark it gone, hand every job it held back to
    /// the planner as freed capacity, return the replacement dispatches.
    /// Idempotent per edge.
    fn handle_dead(&mut self, e: usize, reason: &str) -> crate::Result<Vec<Decision>> {
        if !self.alive.get(e).copied().unwrap_or(false) {
            return Ok(Vec::new());
        }
        self.alive[e] = false;
        self.writers[e] = None;
        let lost: Vec<(usize, usize)> = self
            .pending
            .iter()
            .filter(|&&(_, _, pe)| pe == e)
            .map(|&(n, v, _)| (n, v))
            .collect();
        self.pending.retain(|&(_, _, pe)| pe != e);
        self.events.emit(
            "edge_left",
            vec![
                ("edge", Json::num(e as f64)),
                ("jobs_retired", Json::num(lost.len() as f64)),
                ("reason", Json::str(reason)),
            ],
        );
        eprintln!(
            "leader: edge {e} left ({reason}); retiring {} in-flight job(s)",
            lost.len()
        );
        anyhow::ensure!(
            self.alive.iter().any(|&a| a),
            "all edge leaders are gone; cannot continue the run"
        );
        let planner = self.planner.as_mut().unwrap();
        let mut decisions = Vec::new();
        for (node, version) in lost {
            decisions.extend(planner.on_event(PlannerEvent::CapacityFreed { node, version })?);
        }
        Ok(decisions)
    }

    fn next_event(&mut self) -> crate::Result<(usize, FromEdge)> {
        let rx = self
            .arrivals
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("TcpTree used before setup"))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("all edge connections closed"))
    }

    /// Absorb one `PartialUpdate` from edge `e` into the planner,
    /// returning its decisions.
    fn on_partial(
        &mut self,
        e: usize,
        edge_slot: u64,
        weight: f64,
        contribs: Vec<Contrib>,
        payload: PartialPayload,
    ) -> crate::Result<Vec<Decision>> {
        anyhow::ensure!(
            edge_slot as usize == e,
            "partial stamped edge {edge_slot} arrived on connection {e}"
        );
        let mut out = Vec::new();
        match payload {
            PartialPayload::Relay(frames) => {
                anyhow::ensure!(
                    !self.summed,
                    "edge {e} sent a relay partial to a summed-mode root"
                );
                for (k, enc) in contribs.iter().zip(frames) {
                    let (node, version) = (k.node as usize, k.version as usize);
                    let pos = self
                        .pending
                        .iter()
                        .position(|&(n, v, _)| n == node && v == version);
                    let Some(pos) = pos else {
                        // A straggler relayed by an edge whose job was
                        // already retired and re-dispatched elsewhere.
                        eprintln!(
                            "[tcp-tree] ignoring late upload (node {node}, \
                             version {version}) from a retired job"
                        );
                        continue;
                    };
                    self.pending.swap_remove(pos);
                    self.win_bits_up += k.bits;
                    // Relay forwards the same frame on the second hop.
                    self.win_bits_edge += k.bits;
                    self.events.emit(
                        "upload_arrived",
                        vec![
                            ("compute_ms", Json::num(k.compute_ms)),
                            ("decode_ms", Json::num(k.decode_ms)),
                            ("edge", Json::num(e as f64)),
                            ("node", Json::num(node as f64)),
                            ("version", Json::num(version as f64)),
                        ],
                    );
                    out.extend(self.planner.as_mut().unwrap().on_event(
                        PlannerEvent::UploadArrived { node, version, enc },
                    )?);
                }
            }
            PartialPayload::Summed(frame) => {
                anyhow::ensure!(
                    self.summed,
                    "edge {e} sent a summed partial to a relay-mode root"
                );
                anyhow::ensure!(!contribs.is_empty(), "summed partial with no contribs");
                anyhow::ensure!(
                    weight == contribs.len() as f64,
                    "summed partial weight {weight} disagrees with its {} contribs",
                    contribs.len()
                );
                let id = self.partial_store.len();
                let version = contribs[0].version;
                self.win_bits_edge += frame.bits();
                for k in &contribs {
                    anyhow::ensure!(
                        k.version == version,
                        "summed partial mixes versions {version} and {}",
                        k.version
                    );
                    let (node, version) = (k.node as usize, k.version as usize);
                    // Summed mode runs degenerate knobs with whole-cohort
                    // failure domains: every contrib must still be a live
                    // job, or the frame's sum no longer matches any
                    // committable unit.
                    let pos = self
                        .pending
                        .iter()
                        .position(|&(n, v, _)| n == node && v == version)
                        .ok_or_else(|| {
                            anyhow::anyhow!(
                                "summed partial from edge {e} contains \
                                 (node {node}, version {version}) with no \
                                 pending dispatch"
                            )
                        })?;
                    self.pending.swap_remove(pos);
                    self.store_of.insert((node, version), id);
                    self.win_bits_up += k.bits;
                    self.events.emit(
                        "upload_arrived",
                        vec![
                            ("compute_ms", Json::num(k.compute_ms)),
                            ("decode_ms", Json::num(k.decode_ms)),
                            ("edge", Json::num(e as f64)),
                            ("node", Json::num(node as f64)),
                            ("version", Json::num(version as f64)),
                        ],
                    );
                    // The planner tracks arrival order and staleness; the
                    // actual frame is regrouped in at commit, so it sees a
                    // zero-length stub carrying the right (p, spec).
                    let stub = Encoded {
                        buf: BitBuf::from_parts(Vec::new(), 0)?,
                        p: frame.p,
                        spec: frame.spec.clone(),
                    };
                    out.extend(self.planner.as_mut().unwrap().on_event(
                        PlannerEvent::UploadArrived { node, version, enc: stub },
                    )?);
                }
                self.events.emit(
                    "partial_committed",
                    vec![
                        ("bits", Json::num(frame.bits() as f64)),
                        ("contribs", Json::num(contribs.len() as f64)),
                        ("edge", Json::num(e as f64)),
                        ("version", Json::num(version as f64)),
                    ],
                );
                self.partial_store.push(Some((frame, contribs.len())));
            }
        }
        Ok(out)
    }

    /// Replace a summed-mode commit batch's stub uploads with one
    /// cohort-mass upload per stored partial, preserving the batch's
    /// first-occurrence order.
    fn regroup(&mut self, uploads: Vec<Upload>) -> crate::Result<Vec<Upload>> {
        let mut order: Vec<usize> = Vec::new();
        let mut groups: HashMap<usize, Vec<Upload>> = HashMap::new();
        for u in uploads {
            let id = self
                .store_of
                .remove(&(u.node, u.origin_round))
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "committed upload (node {}, version {}) has no stored \
                         summed partial",
                        u.node,
                        u.origin_round
                    )
                })?;
            if !groups.contains_key(&id) {
                order.push(id);
            }
            groups.entry(id).or_default().push(u);
        }
        let mut out = Vec::with_capacity(order.len());
        for id in order {
            let members = groups.remove(&id).unwrap();
            let (frame, expected) = self.partial_store[id]
                .take()
                .ok_or_else(|| anyhow::anyhow!("stored partial {id} consumed twice"))?;
            anyhow::ensure!(
                members.len() == expected,
                "summed partial splits across commits: {} of {expected} \
                 contribs committed together",
                members.len()
            );
            let first = &members[0];
            out.push(Upload {
                node: first.node,
                origin_round: first.origin_round,
                staleness: first.staleness,
                enc: frame,
                mass: members.len() as f64,
            });
        }
        Ok(out)
    }
}

impl Transport for TcpTree {
    fn name(&self) -> &'static str {
        "tcp-tree"
    }

    fn virtual_time(&self) -> bool {
        false
    }

    fn rebuilds_codec_from_config(&self) -> bool {
        true
    }

    fn buffered_async(&self) -> bool {
        true
    }

    fn set_events(&mut self, events: EventSink) {
        self.events = events;
    }

    fn setup(
        &mut self,
        cfg: &ExperimentConfig,
        _engine: &mut dyn Engine,
    ) -> crate::Result<()> {
        anyhow::ensure!(self.n_edges >= 1, "need at least one edge leader");
        anyhow::ensure!(
            cfg.async_rounds,
            "the tree leader runs the buffered-async protocol; set \
             async_rounds in the config"
        );
        anyhow::ensure!(
            cfg.down_codec.is_none(),
            "tree topologies ship raw models only: a downlink delta chain \
             would need per-edge reference tracking — unset down_codec"
        );
        if self.summed {
            anyhow::ensure!(
                cfg.max_staleness == 0 && cfg.effective_buffer_size() == cfg.r,
                "summed partials require the degenerate full-wave knobs \
                 (buffer_size == r == {}, max_staleness == 0): a summed frame \
                 commits as one unit, so every cohort upload must land in the \
                 same commit",
                cfg.r
            );
            anyhow::ensure!(
                !cfg.codec.is_stateful(),
                "summed partials cannot re-encode through a stateful codec: \
                 the edge-side re-encode would fork the per-node residual \
                 streams"
            );
        }
        let listener = TcpListener::bind(&self.bind)?;
        eprintln!("leader: listening on {}", listener.local_addr()?);
        // Fixed edge membership: accept exactly n_edges, slot = join
        // order, then drop the listener (no mid-run edge joins — a lost
        // edge's nodes re-pin to survivors instead).
        let mut conns = Vec::with_capacity(self.n_edges);
        for slot in 0..self.n_edges {
            let (stream, peer) = listener.accept()?;
            stream.set_nodelay(true)?;
            let mut rd = stream.try_clone()?;
            let workers = match recv_to_leader(&mut rd)? {
                ToLeader::EdgeJoin { proto, workers } => {
                    anyhow::ensure!(
                        proto == PROTO_VERSION,
                        "edge at {peer} speaks wire-protocol v{proto}; this \
                         leader requires v{PROTO_VERSION} — rebuild so root \
                         and edges match"
                    );
                    workers
                }
                other => anyhow::bail!("expected EdgeJoin from {peer}, got {other:?}"),
            };
            let mut wr = stream;
            send_to_worker(
                &mut wr,
                &ToWorker::EdgeSetup {
                    proto: PROTO_VERSION,
                    cfg: cfg.clone(),
                    edge_slot: slot as u64,
                    n_edges: self.n_edges as u64,
                    summed: self.summed,
                },
            )?;
            eprintln!("leader: edge {slot} joined from {peer} ({workers} worker(s))");
            conns.push((rd, wr, peer.to_string(), workers));
        }
        // Ready arrives once an edge's own cohort has handshaken.
        for (rd, _, peer, _) in conns.iter_mut() {
            let msg = recv_to_leader(rd)?;
            anyhow::ensure!(
                matches!(msg, ToLeader::Ready),
                "expected Ready from edge at {peer}"
            );
        }
        for (slot, (_, _, peer, workers)) in conns.iter().enumerate() {
            self.events.emit(
                "edge_joined",
                vec![
                    ("edge", Json::num(slot as f64)),
                    ("peer", Json::str(peer.as_str())),
                    ("workers", Json::num(*workers as f64)),
                ],
            );
        }
        eprintln!("leader: {} edge leader(s) ready", self.n_edges);
        self.planner = Some(CommitPlanner::new(cfg)?);
        self.assign = (0..cfg.n_nodes).map(|n| n % self.n_edges).collect();
        self.pending.clear();
        self.dispatched.clear();
        self.partial_store.clear();
        self.store_of.clear();
        self.win_bits_up = 0;
        self.win_bits_edge = 0;
        self.writers.clear();
        self.alive.clear();
        self.readers.clear();
        let (tx, rx) = channel();
        self.arrivals_tx = Some(tx);
        self.arrivals = Some(rx);
        for (rd, wr, _, _) in conns {
            let idx = self.writers.len();
            self.writers.push(Some(wr));
            self.alive.push(true);
            self.spawn_reader(idx, rd);
        }
        Ok(())
    }

    fn round(
        &mut self,
        ctx: &RoundCtx<'_>,
        _codec: &dyn UpdateCodec,
        _engine: &mut dyn Engine,
    ) -> crate::Result<RoundOutcome> {
        anyhow::ensure!(!self.writers.is_empty(), "TcpTree::round before setup");
        {
            let planner = self.planner.as_mut().unwrap();
            anyhow::ensure!(
                ctx.round == planner.version(),
                "TcpTree expects sequential rounds: got {} at version {}",
                ctx.round,
                planner.version()
            );
        }
        self.dispatched.clear();
        let mut queue: std::collections::VecDeque<Decision> =
            self.planner.as_mut().unwrap().begin_version(ctx.nodes)?.into();
        // Edges dispatched to since the last wave marker (summed mode).
        let mut burst: BTreeSet<usize> = BTreeSet::new();
        loop {
            while let Some(d) = queue.pop_front() {
                match d {
                    Decision::Dispatch { node, version, .. } => {
                        let e = self.dispatch(node, version, ctx)?;
                        burst.insert(e);
                    }
                    Decision::Drop { node, staleness } => {
                        self.events.emit(
                            "upload_dropped",
                            vec![
                                ("node", Json::num(node as f64)),
                                ("staleness", Json::num(staleness as f64)),
                            ],
                        );
                        eprintln!(
                            "[tcp-tree] commit {}: dropped node {node} upload \
                             (staleness {staleness})",
                            ctx.round
                        );
                    }
                    Decision::Commit { uploads, dropped } => {
                        let uploads = if self.summed {
                            self.regroup(uploads)?
                        } else {
                            uploads
                        };
                        return Ok(RoundOutcome {
                            uploads,
                            timing: None,
                            dropped,
                            dispatches: std::mem::take(&mut self.dispatched),
                            uplink_bits: Some((
                                std::mem::take(&mut self.win_bits_up),
                                std::mem::take(&mut self.win_bits_edge),
                            )),
                        });
                    }
                }
            }
            // About to block: close the dispatch burst. Summed edges must
            // only flush at marker boundaries (a timing-dependent flush
            // would split partials non-reproducibly); relay edges forward
            // per-upload and need no markers.
            if self.summed {
                for e in std::mem::take(&mut burst) {
                    if let Some(wr) = self.writers.get_mut(e).and_then(|w| w.as_mut()) {
                        if let Err(err) = send_to_worker(wr, &ToWorker::FlushPartial) {
                            if let Some(tx) = &self.arrivals_tx {
                                let _ = tx
                                    .send((e, FromEdge::Dead(format!("write failed: {err}"))));
                            }
                        }
                    }
                }
            } else {
                burst.clear();
            }
            let (e, msg) = self.next_event()?;
            match msg {
                FromEdge::Dead(reason) => {
                    queue.extend(self.handle_dead(e, &reason)?);
                }
                FromEdge::Msg(ToLeader::PartialUpdate {
                    edge_slot,
                    weight,
                    contribs,
                    payload,
                }) => {
                    queue.extend(self.on_partial(e, edge_slot, weight, contribs, payload)?);
                }
                FromEdge::Msg(other) => anyhow::bail!("unexpected message {other:?}"),
            }
        }
    }

    fn shutdown(&mut self) -> crate::Result<()> {
        // Drain straggler jobs still in flight (relay mode can end a run
        // with re-dispatched jobs unanswered), then release the edges —
        // they forward Shutdown to their cohorts. Best-effort: the first
        // error is reported after every step has run.
        let dropped = self.planner.as_ref().map_or(0, CommitPlanner::dropped);
        let mut first_err: Option<anyhow::Error> = None;
        while !self.pending.is_empty() {
            match self.next_event() {
                Ok((e, FromEdge::Dead(reason))) => {
                    if self.alive.get(e).copied().unwrap_or(false) {
                        self.alive[e] = false;
                        self.writers[e] = None;
                        let lost =
                            self.pending.iter().filter(|&&(_, _, pe)| pe == e).count();
                        self.pending.retain(|&(_, _, pe)| pe != e);
                        self.events.emit(
                            "edge_left",
                            vec![
                                ("edge", Json::num(e as f64)),
                                ("jobs_retired", Json::num(lost as f64)),
                                ("reason", Json::str(reason.as_str())),
                            ],
                        );
                        eprintln!(
                            "leader: edge {e} left during drain ({reason}); \
                             discarding {lost} in-flight job(s)"
                        );
                    }
                }
                Ok((_, FromEdge::Msg(ToLeader::PartialUpdate { contribs, .. }))) => {
                    for k in &contribs {
                        let (node, version) = (k.node as usize, k.version as usize);
                        if let Some(pos) = self
                            .pending
                            .iter()
                            .position(|&(n, v, _)| n == node && v == version)
                        {
                            self.pending.swap_remove(pos);
                        }
                    }
                }
                Ok((_, FromEdge::Msg(other))) => {
                    first_err
                        .get_or_insert_with(|| anyhow::anyhow!("unexpected message {other:?}"));
                    break;
                }
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        if dropped > 0 {
            eprintln!("[tcp-tree] run complete: {dropped} stale upload(s) dropped");
        }
        for w in self.writers.iter_mut().flatten() {
            if let Err(e) = send_to_worker(w, &ToWorker::Shutdown) {
                first_err.get_or_insert(e);
            }
        }
        self.arrivals_tx = None;
        self.arrivals = None;
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn export_state(&self) -> crate::Result<Option<crate::ops::TransportState>> {
        let planner = self
            .planner
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("TcpTree::export_state before setup"))?;
        // In-flight jobs live in worker processes behind the edges and
        // cannot be serialized; restore_state insists on quiescence.
        Ok(Some(crate::ops::TransportState::Tree { planner: planner.export_state() }))
    }

    fn restore_state(&mut self, state: crate::ops::TransportState) -> crate::Result<()> {
        anyhow::ensure!(!self.writers.is_empty(), "TcpTree::restore_state before setup");
        let crate::ops::TransportState::Tree { planner } = state else {
            anyhow::bail!(
                "checkpoint holds flat async-transport state; resume it with a \
                 flat leader (no --edge-leaders) or the simulator, not a tree \
                 leader"
            );
        };
        anyhow::ensure!(
            planner.in_flight.is_empty() && planner.buffer.is_empty(),
            "the tree leader can only resume from a quiescent checkpoint (no \
             in-flight jobs or buffered uploads): in-flight model state lives \
             in worker processes and cannot be recreated. Run with \
             buffer_size == r and max_staleness == 0 (where every commit \
             quiesces), or resume this checkpoint in the simulator instead"
        );
        self.planner = Some(CommitPlanner::from_state(planner)?);
        Ok(())
    }
}

// ---------------- the edge-leader process ----------------

/// Knobs for [`run_edge_retrying`].
#[derive(Debug, Default)]
pub struct EdgeOptions {
    /// Cohort size: how many workers this edge accepts before reporting
    /// Ready upstream.
    pub workers: usize,
    /// Exit cleanly after sending this many partials (after forwarding
    /// Shutdown to the cohort) — a deterministic edge-death injector for
    /// churn tests (`fedpaq edge --max-partials N`).
    pub max_partials: Option<u64>,
    /// Where reconnect attempts are reported. Null by default.
    pub events: EventSink,
}

/// What the edge's reader threads feed its main loop.
enum EdgeEvent {
    Root(ToWorker),
    RootDead(String),
    Worker(usize, ToLeader),
    WorkerDead(usize, String),
}

/// Dial `addr`, retrying transient failures until `retry_for` elapses —
/// the same backoff/jitter policy as
/// [`run_worker_retrying`](super::worker::run_worker_retrying), reported
/// as `edge_reconnecting` events.
fn dial_retrying(
    addr: &str,
    events: &EventSink,
    retry_for: Duration,
) -> crate::Result<TcpStream> {
    let transient = |e: &std::io::Error| {
        matches!(
            e.kind(),
            std::io::ErrorKind::ConnectionRefused
                | std::io::ErrorKind::ConnectionReset
                | std::io::ErrorKind::ConnectionAborted
                | std::io::ErrorKind::TimedOut
        )
    };
    let jitter_of = |attempt: u32| {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in addr.bytes().chain(attempt.to_le_bytes()) {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    };
    let deadline = std::time::Instant::now() + retry_for;
    let mut attempt: u32 = 0;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) if transient(&e) => {
                anyhow::ensure!(
                    std::time::Instant::now() < deadline,
                    "connect {addr}: retry budget ({retry_for:?}) exhausted \
                     after {attempt} attempt(s): {e}"
                );
                let base = 100u64.saturating_mul(1u64 << attempt.min(10)).min(5_000);
                let delay_ms = base + jitter_of(attempt) % (base / 4 + 1);
                events.emit(
                    "edge_reconnecting",
                    vec![
                        ("attempt", Json::num(attempt as f64)),
                        ("delay_ms", Json::num(delay_ms as f64)),
                        ("error", Json::str(e.to_string())),
                    ],
                );
                eprintln!("edge: root {addr} not reachable ({e}); retrying in {delay_ms}ms");
                std::thread::sleep(Duration::from_millis(delay_ms));
                attempt += 1;
            }
            Err(e) => return Err(anyhow::anyhow!("connect {addr}: {e}")),
        }
    }
}

/// Edge-leader main loop: dial the root at `connect` (retrying while it
/// is not yet listening), accept `opts.workers` workers on `bind`,
/// then forward dispatches down and partials up until the root sends
/// Shutdown. See the module docs for the relay/summed flush rules.
pub fn run_edge_retrying(
    connect: &str,
    bind: &str,
    opts: EdgeOptions,
    retry_for: Duration,
) -> crate::Result<()> {
    anyhow::ensure!(opts.workers >= 1, "need at least one worker per edge");
    let root = dial_retrying(connect, &opts.events, retry_for)?;
    root.set_nodelay(true)?;
    let root_rd = root.try_clone()?;
    let mut root_wr = root;
    send_to_leader(
        &mut root_wr,
        &ToLeader::EdgeJoin { proto: PROTO_VERSION, workers: opts.workers as u64 },
    )?;
    let (cfg, edge_slot, n_edges, summed) = {
        let mut rd = root_rd.try_clone()?;
        match recv_to_worker(&mut rd)? {
            ToWorker::EdgeSetup { proto, cfg, edge_slot, n_edges, summed } => {
                anyhow::ensure!(
                    proto == PROTO_VERSION,
                    "root speaks wire-protocol v{proto}; this edge requires \
                     v{PROTO_VERSION} — rebuild so root and edges match"
                );
                (cfg, edge_slot, n_edges as usize, summed)
            }
            other => anyhow::bail!("expected EdgeSetup from the root, got {other:?}"),
        }
    };
    // The summed re-encode runs through the run's own codec family,
    // rebuilt from the broadcast spec like any worker's. Relay edges
    // never decode — frames pass through untouched.
    let codec: Option<Box<dyn UpdateCodec>> = if summed {
        let c = cfg.codec.build()?;
        c.reset_state();
        Some(c)
    } else {
        None
    };
    // Accept the cohort (Join/Setup/Ready, mirroring a flat leader).
    let listener = TcpListener::bind(bind)?;
    eprintln!("edge: listening on {}", listener.local_addr()?);
    let k = opts.workers;
    let mut cohort = Vec::with_capacity(k);
    for _ in 0..k {
        let (stream, peer) = listener.accept()?;
        stream.set_nodelay(true)?;
        let mut rd = stream.try_clone()?;
        match recv_to_leader(&mut rd)? {
            ToLeader::Join { proto } => anyhow::ensure!(
                proto == PROTO_VERSION,
                "worker at {peer} speaks wire-protocol v{proto}; this edge \
                 requires v{PROTO_VERSION} — rebuild so edge and workers match"
            ),
            other => anyhow::bail!("expected Join from {peer}, got {other:?}"),
        }
        eprintln!("edge {edge_slot}: worker joined from {peer}");
        cohort.push((rd, stream));
    }
    for (_, wr) in cohort.iter_mut() {
        send_to_worker(wr, &ToWorker::Setup { proto: PROTO_VERSION, cfg: cfg.clone() })?;
    }
    for (rd, _) in cohort.iter_mut() {
        let msg = recv_to_leader(rd)?;
        anyhow::ensure!(matches!(msg, ToLeader::Ready), "expected Ready");
    }
    send_to_leader(&mut root_wr, &ToLeader::Ready)?;
    eprintln!("edge {edge_slot}: {k} worker(s) ready");

    // One reader thread per socket (root + each worker), all feeding one
    // channel — the edge's main loop must never block on one peer while
    // another has traffic.
    let (tx, rx) = channel::<EdgeEvent>();
    let mut reader_handles = Vec::with_capacity(k + 1);
    {
        let tx = tx.clone();
        let mut rd = root_rd;
        reader_handles.push(std::thread::spawn(move || loop {
            match recv_to_worker(&mut rd) {
                Ok(msg) => {
                    if tx.send(EdgeEvent::Root(msg)).is_err() {
                        return;
                    }
                }
                Err(e) => {
                    let _ = tx.send(EdgeEvent::RootDead(e.to_string()));
                    return;
                }
            }
        }));
    }
    let mut worker_wrs: Vec<TcpStream> = Vec::with_capacity(k);
    for (wi, (mut rd, wr)) in cohort.into_iter().enumerate() {
        worker_wrs.push(wr);
        let tx = tx.clone();
        reader_handles.push(std::thread::spawn(move || loop {
            match recv_to_leader(&mut rd) {
                Ok(msg) => {
                    if tx.send(EdgeEvent::Worker(wi, msg)).is_err() {
                        return;
                    }
                }
                Err(e) => {
                    let _ = tx.send(EdgeEvent::WorkerDead(wi, e.to_string()));
                    return;
                }
            }
        }));
    }
    drop(tx);

    // Main loop state. `outstanding` counts forwarded-but-unanswered
    // dispatches; summed mode flushes when a root marker has closed the
    // wave AND the cohort has drained.
    let mut outstanding: usize = 0;
    let mut wave_closed = false;
    let mut buffered: Vec<(u64, u64, Encoded, f64, f64)> = Vec::new();
    let mut partials_sent: u64 = 0;
    let finish = |worker_wrs: &mut [TcpStream]| -> crate::Result<()> {
        for wr in worker_wrs.iter_mut() {
            send_to_worker(wr, &ToWorker::Shutdown)?;
        }
        Ok(())
    };
    loop {
        // Summed flush: the wave is closed and every forwarded job has
        // answered. Sorted by (version, node) — the canonical contrib
        // order the wire format documents.
        if summed && wave_closed && outstanding == 0 {
            wave_closed = false;
            if !buffered.is_empty() {
                buffered.sort_by_key(|&(v, n, ..)| (v, n));
                let version = buffered[0].0;
                anyhow::ensure!(
                    buffered.iter().all(|&(v, ..)| v == version),
                    "summed flush mixes model versions (degenerate knobs \
                     should make waves single-version)"
                );
                let contribs: Vec<Contrib> = buffered
                    .iter()
                    .map(|(v, n, enc, compute_ms, decode_ms)| Contrib {
                        node: *n,
                        version: *v,
                        bits: enc.bits(),
                        compute_ms: *compute_ms,
                        decode_ms: *decode_ms,
                    })
                    .collect();
                let frames: Vec<Encoded> =
                    buffered.drain(..).map(|(_, _, enc, _, _)| enc).collect();
                let p = frames[0].p;
                let mut rng =
                    Rng::from_coords(cfg.seed, &[TREE_STREAM, edge_slot, version]);
                let (frame, weight) = partial_reencode(
                    codec.as_ref().expect("summed edge has a codec").as_ref(),
                    &frames,
                    p,
                    &mut rng,
                )?;
                send_to_leader(
                    &mut root_wr,
                    &ToLeader::PartialUpdate {
                        edge_slot,
                        weight,
                        contribs,
                        payload: PartialPayload::Summed(frame),
                    },
                )?;
                partials_sent += 1;
                if opts.max_partials.is_some_and(|cap| partials_sent >= cap) {
                    eprintln!("edge {edge_slot}: reached --max-partials {partials_sent}; exiting");
                    return finish(&mut worker_wrs);
                }
            }
        }
        let ev = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("edge {edge_slot}: all connections closed"))?;
        match ev {
            EdgeEvent::Root(ToWorker::Work { version, node, payload, lrs }) => {
                // Stable cohort-local pinning: nodes on this edge are a
                // residue class mod n_edges, so dividing out the edge
                // count spreads them evenly over the K workers.
                let wi = (node as usize / n_edges) % k;
                send_to_worker(
                    &mut worker_wrs[wi],
                    &ToWorker::Work { version, node, payload, lrs },
                )?;
                outstanding += 1;
            }
            EdgeEvent::Root(ToWorker::FlushPartial) => {
                anyhow::ensure!(
                    summed,
                    "root sent a FlushPartial marker to a relay-mode edge"
                );
                wave_closed = true;
            }
            EdgeEvent::Root(ToWorker::Shutdown) => {
                eprintln!("edge {edge_slot}: shutdown");
                return finish(&mut worker_wrs);
            }
            EdgeEvent::Root(other) => {
                anyhow::bail!("unexpected message from root: {other:?}")
            }
            EdgeEvent::RootDead(reason) => {
                anyhow::bail!("edge {edge_slot}: root connection lost: {reason}")
            }
            EdgeEvent::Worker(_, ToLeader::Update { version, node, enc, compute_ms, decode_ms }) => {
                outstanding = outstanding
                    .checked_sub(1)
                    .ok_or_else(|| anyhow::anyhow!("update with no outstanding dispatch"))?;
                if summed {
                    buffered.push((version, node, enc, compute_ms, decode_ms));
                } else {
                    // Relay: forward immediately as a one-contrib partial,
                    // preserving true arrival order for the root's planner
                    // exactly like a flat async leader.
                    let contrib = Contrib {
                        node,
                        version,
                        bits: enc.bits(),
                        compute_ms,
                        decode_ms,
                    };
                    send_to_leader(
                        &mut root_wr,
                        &ToLeader::PartialUpdate {
                            edge_slot,
                            weight: 1.0,
                            contribs: vec![contrib],
                            payload: PartialPayload::Relay(vec![enc]),
                        },
                    )?;
                    partials_sent += 1;
                    if opts.max_partials.is_some_and(|cap| partials_sent >= cap) {
                        eprintln!(
                            "edge {edge_slot}: reached --max-partials {partials_sent}; exiting"
                        );
                        return finish(&mut worker_wrs);
                    }
                }
            }
            EdgeEvent::Worker(wi, other) => {
                anyhow::bail!("unexpected message from worker {wi}: {other:?}")
            }
            EdgeEvent::WorkerDead(wi, reason) => {
                // The whole cohort is this edge's failure domain: give up
                // so the root retires and re-pins every node we own.
                anyhow::bail!(
                    "edge {edge_slot}: worker {wi} died ({reason}); \
                     surrendering the cohort to the root"
                )
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::CodecSpec;

    #[test]
    fn partial_reencode_identity_matches_f64_sum_cast() {
        let codec = CodecSpec::Identity.build().unwrap();
        let a = vec![1.5f32, -2.25, 0.125, 1e-7];
        let b = vec![0.5f32, 0.75, -0.125, 3e-7];
        let mut rng = Rng::seed_from_u64(0);
        let encs = vec![codec.encode(&a, &mut rng), codec.encode(&b, &mut rng)];
        let (frame, mass) =
            partial_reencode(codec.as_ref(), &encs, 4, &mut Rng::seed_from_u64(1)).unwrap();
        assert_eq!(mass, 2.0);
        let expect: Vec<f32> = a
            .iter()
            .zip(&b)
            .map(|(&x, &y)| (x as f64 + y as f64) as f32)
            .collect();
        let got = codec.decode(&frame).unwrap();
        for (g, e) in got.iter().zip(&expect) {
            assert_eq!(g.to_bits(), e.to_bits());
        }
    }

    #[test]
    fn partial_reencode_is_deterministic_per_rng_stream() {
        let codec = CodecSpec::qsgd(2).build().unwrap();
        let xs: Vec<Vec<f32>> = (0..3)
            .map(|i| (0..64).map(|j| ((i * 64 + j) as f32 * 0.17).sin()).collect())
            .collect();
        let encs: Vec<Encoded> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| codec.encode(x, &mut Rng::seed_from_u64(i as u64)))
            .collect();
        let run = || {
            let mut rng = Rng::from_coords(33, &[TREE_STREAM, 1, 4]);
            partial_reencode(codec.as_ref(), &encs, 64, &mut rng).unwrap()
        };
        let (fa, wa) = run();
        let (fb, wb) = run();
        assert_eq!(wa, wb);
        assert_eq!(fa.buf.words(), fb.buf.words());
        assert_eq!(fa.bits(), fb.bits());
    }

    #[test]
    fn partial_reencode_rejects_empty_and_mixed_widths() {
        let codec = CodecSpec::Identity.build().unwrap();
        let mut rng = Rng::seed_from_u64(0);
        assert!(partial_reencode(codec.as_ref(), &[], 4, &mut rng).is_err());
        let encs = vec![
            codec.encode(&[1.0, 2.0], &mut rng),
            codec.encode(&[1.0, 2.0, 3.0], &mut rng),
        ];
        assert!(partial_reencode(codec.as_ref(), &encs, 2, &mut rng).is_err());
    }
}
