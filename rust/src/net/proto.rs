//! Wire protocol: u32-LE length prefix + a hand-rolled binary codec
//! (no serde in this environment — every message knows how to write and
//! read itself; layouts are versioned by a magic byte per variant plus
//! an explicit [`PROTO_VERSION`] carried in the handshake).
//!
//! Layout conventions: little-endian throughout; `str` = u32 len + UTF-8;
//! `vec<T>` = u64 len + elements; f32 slices are bulk-copied.
//!
//! ## Versioning
//!
//! v2 (the buffered-async protocol) stamped every dispatch and upload
//! with the server **model version** it belongs to — the coordinate the
//! [`CommitPlanner`](crate::coordinator::commit_loop::CommitPlanner)
//! derives staleness from (`staleness = commit version − origin
//! version`). v3 (the bidirectional-compression protocol) additionally
//! lets a `Work` dispatch carry its model as either a dense raw vector
//! or a **compressed delta chain** against the worker's last
//! reconstructed reference ([`ModelPayload`]), and `Update` frames echo
//! worker-side decode/compute timings for the event bus. v4 (the
//! hierarchical-aggregation protocol) adds the edge-leader role: an
//! edge joins the root with [`ToLeader::EdgeJoin`], receives a
//! [`ToWorker::EdgeSetup`] naming its slot, and streams
//! [`ToLeader::PartialUpdate`] frames upstream — each carrying the
//! contributing `(node, version)` list, per-contrib bit/timing
//! accounting, the summed weight, and either the relayed worker frames
//! or one re-encoded partial sum (see `docs/TOPOLOGY.md`). All v3
//! frame layouts are unchanged; v3 binaries are rejected by the
//! in-band `proto` field at the handshake. The v1 and v2 layouts used
//! different variant tags; decoding one here fails with an explicit
//! protocol-version error (not a byte-soup "truncated frame"), so a
//! mixed-version cluster is rejected at the handshake instead of
//! silently corrupting a run. See `docs/PROTOCOL.md` for the full
//! frame catalogue.

use crate::config::ExperimentConfig;
use crate::quant::{bitstream::BitBuf, CodecSpec, Coding, Encoded};
use std::io::{Read, Write};

/// Hard cap on frame size (a full-precision 248K-param upload is ~1 MiB;
/// generous headroom for bigger models).
pub const MAX_FRAME: u32 = 256 * 1024 * 1024;

/// Wire protocol version. Bumped to 2 when dispatches/uploads gained
/// model-version stamps (the buffered-async protocol), to 3 when
/// dispatches gained delta-chain model payloads and uploads gained
/// worker timing (the bidirectional-compression protocol), and to 4
/// when the edge-leader role landed (`EdgeJoin`/`EdgeSetup`/
/// `PartialUpdate` frames for two-level aggregation trees). v1/v2
/// peers are rejected by retired tag values, v3 peers by the in-band
/// `proto` field, both with a clear error at the `Join`/`Setup`
/// handshake.
pub const PROTO_VERSION: u32 = 4;

/// The error both ends raise when an older-protocol frame shows up.
fn protocol_version_error(v: u32, what: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "peer sent a wire-protocol v{v} {what} frame; this build speaks \
         v{PROTO_VERSION}, which adds edge-leader partial-aggregate frames \
         on top of the v3 payload/timing layouts — upgrade the older binary \
         (leader, edges, and workers must match)"
    )
}

/// How a `Work` dispatch ships its model (wire v3).
///
/// `Raw` is the pre-bidirectional shape: the dense f32 model. `Chain`
/// is the compressed-downlink shape: the ordered per-version delta
/// links `(base_version, version]`, each one
/// `encode(x_k − reference_{k−1})` from the server's
/// [`DownlinkEncoder`](crate::coordinator::DownlinkEncoder); the worker
/// applies them in order to its reconstructed reference at
/// `base_version`. An empty chain means "you are already at `version`".
#[derive(Debug, Clone)]
pub enum ModelPayload {
    Raw(Vec<f32>),
    Chain { base_version: u64, links: Vec<Encoded> },
}

/// One worker upload folded into a [`ToLeader::PartialUpdate`]: the
/// `(node, version)` coordinate the root's
/// [`CommitPlanner`](crate::coordinator::commit_loop::CommitPlanner)
/// keys staleness on, the worker frame's uplink bit count (the
/// worker→edge hop of the split accounting), and the worker timings
/// the root re-emits on its `upload_arrived` events.
#[derive(Debug, Clone, PartialEq)]
pub struct Contrib {
    pub node: u64,
    pub version: u64,
    pub bits: u64,
    pub compute_ms: f64,
    pub decode_ms: f64,
}

/// How a [`ToLeader::PartialUpdate`] ships its cohort's updates.
///
/// `Relay` forwards the original worker frames **verbatim** (one per
/// contrib, same order) — the identity re-encode, bit-exact against a
/// flat topology. `Summed` carries one re-encoded frame holding the
/// unweighted coordinate-wise sum of the cohort's decoded updates —
/// the bandwidth-saving mode; the root feeds it to the aggregator once
/// at the partial's summed weight (see `docs/TOPOLOGY.md` for the
/// weighting math).
#[derive(Debug, Clone)]
pub enum PartialPayload {
    Relay(Vec<Encoded>),
    Summed(Encoded),
}

/// Leader → worker messages.
#[derive(Debug, Clone)]
pub enum ToWorker {
    /// World description; the worker builds its engine + data from this.
    /// Carries the leader's [`PROTO_VERSION`] so the worker can refuse a
    /// mismatched leader with a clear error.
    Setup { proto: u32, cfg: ExperimentConfig },
    /// Run virtual node `node` from the server model at `version`,
    /// shipped as a raw vector or delta chain ([`ModelPayload`]). On
    /// barrier transports `version` is the round index; on
    /// buffered-async transports it is the commit count at dispatch time
    /// (what staleness is measured against). Either way it keys the
    /// node's per-`(seed, node, version)` RNG streams.
    Work { version: u64, node: u64, payload: ModelPayload, lrs: Vec<f32> },
    /// Clean shutdown.
    Shutdown,
    /// Root → edge-leader handshake reply (wire v4): the config the
    /// edge relays to its own workers, the edge's join-order `slot`
    /// (its identity in events and re-encode RNG streams), the total
    /// edge count (for node→edge pinning), and whether the edge must
    /// send `Summed` partials instead of `Relay` ones.
    EdgeSetup { proto: u32, cfg: ExperimentConfig, edge_slot: u64, n_edges: u64, summed: bool },
    /// Root → edge wave marker (wire v4, summed mode only): every
    /// `Work` dispatch sent to this edge so far belongs to a closed
    /// burst — once they have all been answered, flush the buffered
    /// cohort uploads as one `Summed` partial. Without the marker the
    /// flush boundary would depend on socket timing (how many dispatches
    /// happened to be in flight when the cohort drained), which would
    /// break summed-mode repeat-run reproducibility.
    FlushPartial,
}

/// Worker → leader messages.
#[derive(Debug, Clone)]
pub enum ToLeader {
    /// Initial handshake, carrying the worker's [`PROTO_VERSION`].
    Join { proto: u32 },
    /// Setup acknowledged (engine compiled, data generated).
    Ready,
    /// One node's quantized upload, echoing the model `version` it was
    /// dispatched at (the leader stamps `staleness = commit − version`)
    /// plus the worker-side wall-clock cost of the job: `decode_ms`
    /// (reconstructing the model from its payload) and `compute_ms`
    /// (local training + uplink encode), surfaced on the event bus.
    Update { version: u64, node: u64, enc: Encoded, compute_ms: f64, decode_ms: f64 },
    /// Edge-leader → root handshake (wire v4): this peer is an edge
    /// leader that will accept `workers` workers of its own and stream
    /// partial aggregates upstream.
    EdgeJoin { proto: u32, workers: u64 },
    /// One flushed partial aggregate from edge `edge_slot` (wire v4):
    /// the contributing uploads (sorted by `(version, node)`), the
    /// summed staleness weight `weight` (cohort size at staleness 0),
    /// and the payload — relayed frames or one re-encoded sum.
    PartialUpdate { edge_slot: u64, weight: f64, contribs: Vec<Contrib>, payload: PartialPayload },
}

// ---------------- primitive writers/readers ----------------
//
// Shared beyond the socket protocol: `ops::checkpoint` serializes its
// on-disk format with the same primitives (pub(crate) for that reason),
// so checkpoints and wire frames can never disagree on layout
// conventions.

pub struct Buf(pub Vec<u8>);

impl Buf {
    pub(crate) fn new() -> Self {
        Buf(Vec::new())
    }

    pub(crate) fn u8(&mut self, v: u8) {
        self.0.push(v);
    }

    pub(crate) fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn f64(&mut self, v: f64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn f32(&mut self, v: f32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn string(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }

    pub(crate) fn f32s(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f32(x);
        }
    }

    pub(crate) fn u64s(&mut self, v: &[u64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.u64(x);
        }
    }
}

pub struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(b: &'a [u8]) -> Self {
        Cursor { b, i: 0 }
    }

    pub(crate) fn take(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        anyhow::ensure!(self.i + n <= self.b.len(), "truncated frame");
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    /// Bytes consumed so far (exhaustion checks at decode boundaries).
    pub(crate) fn pos(&self) -> usize {
        self.i
    }

    /// Total bytes in the underlying buffer.
    pub(crate) fn len(&self) -> usize {
        self.b.len()
    }

    pub(crate) fn u8(&mut self) -> crate::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> crate::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> crate::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> crate::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f32(&mut self) -> crate::Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn string(&mut self) -> crate::Result<String> {
        let n = self.u32()? as usize;
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }

    pub(crate) fn f32s(&mut self) -> crate::Result<Vec<f32>> {
        let n = self.u64()? as usize;
        anyhow::ensure!(n * 4 <= self.b.len(), "oversized f32 vec");
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32()?);
        }
        Ok(v)
    }

    pub(crate) fn u64s(&mut self) -> crate::Result<Vec<u64>> {
        let n = self.u64()? as usize;
        anyhow::ensure!(n * 8 <= self.b.len(), "oversized u64 vec");
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u64()?);
        }
        Ok(v)
    }
}

// ---------------- domain codecs ----------------

fn coding_tag(coding: &Coding) -> u8 {
    match coding {
        Coding::Naive => 0,
        Coding::Elias => 1,
    }
}

fn read_coding(c: &mut Cursor<'_>) -> crate::Result<Coding> {
    Ok(match c.u8()? {
        0 => Coding::Naive,
        1 => Coding::Elias,
        x => anyhow::bail!("bad coding tag {x}"),
    })
}

fn write_spec(b: &mut Buf, spec: &CodecSpec) {
    match spec {
        CodecSpec::Identity => b.u8(0),
        CodecSpec::Qsgd { s, coding } => {
            b.u8(1);
            b.u32(*s);
            b.u8(coding_tag(coding));
        }
        CodecSpec::TopK { k_permille, coding } => {
            b.u8(2);
            b.u32(*k_permille as u32);
            b.u8(coding_tag(coding));
        }
        CodecSpec::External { id } => {
            b.u8(3);
            b.u32(*id);
        }
        CodecSpec::RandK { k_permille, seeded } => {
            b.u8(4);
            b.u32(*k_permille as u32);
            b.u8(*seeded as u8);
        }
        CodecSpec::AdaptiveQsgd { bits_per_coord, coding } => {
            b.u8(5);
            b.u8(*bits_per_coord);
            b.u8(coding_tag(coding));
        }
        CodecSpec::ErrorFeedback { inner } => {
            b.u8(6);
            write_spec(b, inner);
        }
    }
}

fn read_spec(c: &mut Cursor<'_>) -> crate::Result<CodecSpec> {
    read_spec_depth(c, 0)
}

fn read_spec_depth(c: &mut Cursor<'_>, depth: usize) -> crate::Result<CodecSpec> {
    // Wrapper tags recurse; configs allow exactly one nesting level
    // (depth 1 = the inside of one wrapper), so anything deeper on the
    // wire is a malformed or adversarial frame.
    anyhow::ensure!(depth <= 1, "codec spec nested deeper than the protocol allows");
    Ok(match c.u8()? {
        0 => CodecSpec::Identity,
        1 => {
            let s = c.u32()?;
            CodecSpec::Qsgd { s, coding: read_coding(c)? }
        }
        2 => {
            let k = c.u32()?;
            anyhow::ensure!(k <= 1000, "bad top-k permille {k}");
            CodecSpec::TopK { k_permille: k as u16, coding: read_coding(c)? }
        }
        3 => CodecSpec::External { id: c.u32()? },
        4 => {
            let k = c.u32()?;
            anyhow::ensure!(k <= 1000, "bad rand-k permille {k}");
            let seeded = match c.u8()? {
                0 => false,
                1 => true,
                x => anyhow::bail!("bad rand-k seeded flag {x}"),
            };
            CodecSpec::RandK { k_permille: k as u16, seeded }
        }
        5 => {
            // Same bounds config validation enforces (2..=32): a forged
            // or corrupt byte fails here with a parse error instead of
            // surfacing later as a confusing decode-side mismatch.
            let b = c.u8()?;
            anyhow::ensure!(
                (2..=32).contains(&b),
                "bad adaptive-QSGD bits_per_coord {b}"
            );
            CodecSpec::AdaptiveQsgd { bits_per_coord: b, coding: read_coding(c)? }
        }
        6 => CodecSpec::ErrorFeedback {
            inner: Box::new(read_spec_depth(c, depth + 1)?),
        },
        x => anyhow::bail!("bad codec tag {x}"),
    })
}

pub(crate) fn write_encoded(b: &mut Buf, e: &Encoded) {
    write_spec(b, &e.spec);
    b.u64(e.p as u64);
    b.u64(e.buf.len_bits());
    b.u64s(e.buf.words());
}

pub(crate) fn read_encoded(c: &mut Cursor<'_>) -> crate::Result<Encoded> {
    let spec = read_spec(c)?;
    let p = c.u64()? as usize;
    let len = c.u64()?;
    let words = c.u64s()?;
    Ok(Encoded { buf: BitBuf::from_parts(words, len)?, p, spec })
}

// Variant tags. v1 used 0=Setup/Join, 1=Work (2=Update on ToLeader);
// v2 used 3=Setup/Join, 4=Work/Update. v3 retired both generations'
// tag values so an older frame is recognized — and rejected with a
// protocol-version error — instead of being misparsed. `Ready` and
// `Shutdown` kept their layouts (a bare tag byte) across all versions.
const TAG_SHUTDOWN: u8 = 2;
const TAG_SETUP_V3: u8 = 5;
const TAG_WORK_V3: u8 = 6;
const TAG_READY: u8 = 1;
const TAG_JOIN_V3: u8 = 5;
const TAG_UPDATE_V3: u8 = 6;
// v4 additions (edge-leader role). The v3 layouts above are unchanged
// — a v3 binary is caught by the in-band `proto` field check at the
// handshake, not by retired tags.
const TAG_EDGE_SETUP_V4: u8 = 7;
const TAG_FLUSH_V4: u8 = 8;
const TAG_EDGE_JOIN_V4: u8 = 7;
const TAG_PARTIAL_V4: u8 = 8;

// Payload tags inside a v4 PartialUpdate frame.
const PARTIAL_RELAY: u8 = 0;
const PARTIAL_SUMMED: u8 = 1;

// Payload tags inside a v3 Work frame.
const PAYLOAD_RAW: u8 = 0;
const PAYLOAD_CHAIN: u8 = 1;

fn write_payload(b: &mut Buf, payload: &ModelPayload) {
    match payload {
        ModelPayload::Raw(params) => {
            b.u8(PAYLOAD_RAW);
            b.f32s(params);
        }
        ModelPayload::Chain { base_version, links } => {
            b.u8(PAYLOAD_CHAIN);
            b.u64(*base_version);
            b.u64(links.len() as u64);
            for enc in links {
                write_encoded(b, enc);
            }
        }
    }
}

fn read_payload(c: &mut Cursor<'_>) -> crate::Result<ModelPayload> {
    Ok(match c.u8()? {
        PAYLOAD_RAW => ModelPayload::Raw(c.f32s()?),
        PAYLOAD_CHAIN => {
            let base_version = c.u64()?;
            let n = c.u64()? as usize;
            // Each link is at least a spec byte + two u64 headers.
            anyhow::ensure!(n.saturating_mul(17) <= c.len(), "oversized link chain");
            let mut links = Vec::with_capacity(n);
            for _ in 0..n {
                links.push(read_encoded(c)?);
            }
            ModelPayload::Chain { base_version, links }
        }
        x => anyhow::bail!("bad model-payload tag {x}"),
    })
}

impl ToWorker {
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Buf::new();
        match self {
            ToWorker::Setup { proto, cfg } => {
                b.u8(TAG_SETUP_V3);
                b.u32(*proto);
                b.string(&cfg.to_json().to_string_pretty());
            }
            ToWorker::Work { version, node, payload, lrs } => {
                b.u8(TAG_WORK_V3);
                b.u64(*version);
                b.u64(*node);
                write_payload(&mut b, payload);
                b.f32s(lrs);
            }
            ToWorker::Shutdown => b.u8(TAG_SHUTDOWN),
            ToWorker::EdgeSetup { proto, cfg, edge_slot, n_edges, summed } => {
                b.u8(TAG_EDGE_SETUP_V4);
                b.u32(*proto);
                b.string(&cfg.to_json().to_string_pretty());
                b.u64(*edge_slot);
                b.u64(*n_edges);
                b.u8(*summed as u8);
            }
            ToWorker::FlushPartial => b.u8(TAG_FLUSH_V4),
        }
        b.0
    }

    pub fn decode(bytes: &[u8]) -> crate::Result<Self> {
        let mut c = Cursor::new(bytes);
        let msg = match c.u8()? {
            0 => return Err(protocol_version_error(1, "Setup")),
            1 => return Err(protocol_version_error(1, "Work")),
            3 => return Err(protocol_version_error(2, "Setup")),
            4 => return Err(protocol_version_error(2, "Work")),
            TAG_SETUP_V3 => {
                let proto = c.u32()?;
                let text = c.string()?;
                let cfg =
                    ExperimentConfig::from_json(&crate::util::json::Json::parse(&text)?)?;
                ToWorker::Setup { proto, cfg }
            }
            TAG_WORK_V3 => ToWorker::Work {
                version: c.u64()?,
                node: c.u64()?,
                payload: read_payload(&mut c)?,
                lrs: c.f32s()?,
            },
            TAG_SHUTDOWN => ToWorker::Shutdown,
            TAG_EDGE_SETUP_V4 => {
                let proto = c.u32()?;
                let text = c.string()?;
                let cfg =
                    ExperimentConfig::from_json(&crate::util::json::Json::parse(&text)?)?;
                let edge_slot = c.u64()?;
                let n_edges = c.u64()?;
                let summed = match c.u8()? {
                    0 => false,
                    1 => true,
                    x => anyhow::bail!("bad edge-setup summed flag {x}"),
                };
                ToWorker::EdgeSetup { proto, cfg, edge_slot, n_edges, summed }
            }
            TAG_FLUSH_V4 => ToWorker::FlushPartial,
            x => anyhow::bail!("bad ToWorker tag {x}"),
        };
        anyhow::ensure!(c.i == bytes.len(), "trailing bytes in frame");
        Ok(msg)
    }
}

impl ToLeader {
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Buf::new();
        match self {
            ToLeader::Join { proto } => {
                b.u8(TAG_JOIN_V3);
                b.u32(*proto);
            }
            ToLeader::Ready => b.u8(TAG_READY),
            ToLeader::Update { version, node, enc, compute_ms, decode_ms } => {
                b.u8(TAG_UPDATE_V3);
                b.u64(*version);
                b.u64(*node);
                write_encoded(&mut b, enc);
                b.f64(*compute_ms);
                b.f64(*decode_ms);
            }
            ToLeader::EdgeJoin { proto, workers } => {
                b.u8(TAG_EDGE_JOIN_V4);
                b.u32(*proto);
                b.u64(*workers);
            }
            ToLeader::PartialUpdate { edge_slot, weight, contribs, payload } => {
                b.u8(TAG_PARTIAL_V4);
                b.u64(*edge_slot);
                b.f64(*weight);
                b.u64(contribs.len() as u64);
                for k in contribs {
                    b.u64(k.node);
                    b.u64(k.version);
                    b.u64(k.bits);
                    b.f64(k.compute_ms);
                    b.f64(k.decode_ms);
                }
                match payload {
                    PartialPayload::Relay(encs) => {
                        b.u8(PARTIAL_RELAY);
                        b.u64(encs.len() as u64);
                        for enc in encs {
                            write_encoded(&mut b, enc);
                        }
                    }
                    PartialPayload::Summed(enc) => {
                        b.u8(PARTIAL_SUMMED);
                        write_encoded(&mut b, enc);
                    }
                }
            }
        }
        b.0
    }

    pub fn decode(bytes: &[u8]) -> crate::Result<Self> {
        let mut c = Cursor::new(bytes);
        let msg = match c.u8()? {
            0 => return Err(protocol_version_error(1, "Join")),
            2 => return Err(protocol_version_error(1, "Update")),
            3 => return Err(protocol_version_error(2, "Join")),
            4 => return Err(protocol_version_error(2, "Update")),
            TAG_JOIN_V3 => ToLeader::Join { proto: c.u32()? },
            TAG_READY => ToLeader::Ready,
            TAG_UPDATE_V3 => ToLeader::Update {
                version: c.u64()?,
                node: c.u64()?,
                enc: read_encoded(&mut c)?,
                compute_ms: c.f64()?,
                decode_ms: c.f64()?,
            },
            TAG_EDGE_JOIN_V4 => {
                ToLeader::EdgeJoin { proto: c.u32()?, workers: c.u64()? }
            }
            TAG_PARTIAL_V4 => {
                let edge_slot = c.u64()?;
                let weight = c.f64()?;
                let n = c.u64()? as usize;
                // Each contrib is exactly 40 bytes on the wire.
                anyhow::ensure!(n.saturating_mul(40) <= c.len(), "oversized contrib list");
                let mut contribs = Vec::with_capacity(n);
                for _ in 0..n {
                    contribs.push(Contrib {
                        node: c.u64()?,
                        version: c.u64()?,
                        bits: c.u64()?,
                        compute_ms: c.f64()?,
                        decode_ms: c.f64()?,
                    });
                }
                let payload = match c.u8()? {
                    PARTIAL_RELAY => {
                        let m = c.u64()? as usize;
                        anyhow::ensure!(
                            m == contribs.len(),
                            "relay partial carries {m} frames for {} contribs",
                            contribs.len()
                        );
                        let mut encs = Vec::with_capacity(m);
                        for _ in 0..m {
                            encs.push(read_encoded(&mut c)?);
                        }
                        PartialPayload::Relay(encs)
                    }
                    PARTIAL_SUMMED => PartialPayload::Summed(read_encoded(&mut c)?),
                    x => anyhow::bail!("bad partial-payload tag {x}"),
                };
                ToLeader::PartialUpdate { edge_slot, weight, contribs, payload }
            }
            x => anyhow::bail!("bad ToLeader tag {x}"),
        };
        anyhow::ensure!(c.i == bytes.len(), "trailing bytes in frame");
        Ok(msg)
    }
}

// ---------------- framing over blocking streams ----------------

/// Write one length-prefixed frame.
pub fn send_frame<W: Write>(w: &mut W, payload: &[u8]) -> crate::Result<()> {
    anyhow::ensure!(payload.len() as u64 <= MAX_FRAME as u64, "frame too large");
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame.
pub fn recv_frame<R: Read>(r: &mut R) -> crate::Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len);
    anyhow::ensure!(len <= MAX_FRAME, "oversized frame {len}");
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

pub fn send_to_worker<W: Write>(w: &mut W, msg: &ToWorker) -> crate::Result<()> {
    send_frame(w, &msg.encode())
}

pub fn recv_to_worker<R: Read>(r: &mut R) -> crate::Result<ToWorker> {
    ToWorker::decode(&recv_frame(r)?)
}

pub fn send_to_leader<W: Write>(w: &mut W, msg: &ToLeader) -> crate::Result<()> {
    send_frame(w, &msg.encode())
}

pub fn recv_to_leader<R: Read>(r: &mut R) -> crate::Result<ToLeader> {
    ToLeader::decode(&recv_frame(r)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{TopKCodec, UpdateCodec};
    use crate::util::rng::Rng;

    #[test]
    fn work_roundtrip() {
        let msg = ToWorker::Work {
            version: 3,
            node: 17,
            payload: ModelPayload::Raw(vec![1.0, -2.5, 3.25]),
            lrs: vec![0.1, 0.1],
        };
        match ToWorker::decode(&msg.encode()).unwrap() {
            ToWorker::Work { version, node, payload, lrs } => {
                assert_eq!((version, node), (3, 17));
                match payload {
                    ModelPayload::Raw(params) => {
                        assert_eq!(params, vec![1.0, -2.5, 3.25])
                    }
                    _ => panic!("expected raw payload"),
                }
                assert_eq!(lrs, vec![0.1, 0.1]);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn chain_work_roundtrip_preserves_every_link() {
        let q = CodecSpec::qsgd(3).build().unwrap();
        let links: Vec<Encoded> = (0..3)
            .map(|i| {
                let x: Vec<f32> = (0..64).map(|j| ((i * 64 + j) as f32 * 0.11).sin()).collect();
                q.encode(&x, &mut Rng::seed_from_u64(i as u64))
            })
            .collect();
        let decoded_before: Vec<Vec<f32>> =
            links.iter().map(|e| q.decode(e).unwrap()).collect();
        let msg = ToWorker::Work {
            version: 9,
            node: 4,
            payload: ModelPayload::Chain { base_version: 6, links },
            lrs: vec![0.05],
        };
        match ToWorker::decode(&msg.encode()).unwrap() {
            ToWorker::Work { version, payload, .. } => {
                assert_eq!(version, 9);
                match payload {
                    ModelPayload::Chain { base_version, links } => {
                        assert_eq!(base_version, 6);
                        assert_eq!(links.len(), 3);
                        for (enc, before) in links.iter().zip(&decoded_before) {
                            assert_eq!(&q.decode(enc).unwrap(), before);
                        }
                    }
                    _ => panic!("expected chain payload"),
                }
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn empty_chain_roundtrips() {
        // An empty chain is the "you are current" dispatch — the worker
        // reuses its reconstructed reference without any decode work.
        let msg = ToWorker::Work {
            version: 5,
            node: 0,
            payload: ModelPayload::Chain { base_version: 5, links: vec![] },
            lrs: vec![0.1],
        };
        match ToWorker::decode(&msg.encode()).unwrap() {
            ToWorker::Work { payload: ModelPayload::Chain { base_version, links }, .. } => {
                assert_eq!(base_version, 5);
                assert!(links.is_empty());
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn setup_roundtrip_carries_config_and_proto() {
        let cfg = ExperimentConfig::fig1_nn_base().with_tau(7);
        let msg = ToWorker::Setup { proto: PROTO_VERSION, cfg: cfg.clone() };
        match ToWorker::decode(&msg.encode()).unwrap() {
            ToWorker::Setup { proto, cfg: back } => {
                assert_eq!(proto, PROTO_VERSION);
                assert_eq!(cfg, back);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn join_roundtrip_carries_proto() {
        let msg = ToLeader::Join { proto: PROTO_VERSION };
        match ToLeader::decode(&msg.encode()).unwrap() {
            ToLeader::Join { proto } => assert_eq!(proto, PROTO_VERSION),
            _ => panic!(),
        }
    }

    #[test]
    fn old_protocol_frames_fail_with_a_version_error() {
        // v1 tag values: ToWorker 0=Setup, 1=Work; ToLeader 0=Join,
        // 2=Update. v2 tag values: 3=Setup/Join, 4=Work/Update. Each
        // must name the protocol mismatch, not garble.
        for (bytes, decode_leader, gen) in [
            (vec![0u8], false, "v1"),
            (vec![1u8, 0, 0, 0, 0, 0, 0, 0, 0], false, "v1"),
            (vec![0u8], true, "v1"),
            (vec![2u8, 9, 9], true, "v1"),
            (vec![3u8, 2, 0, 0, 0], false, "v2"),
            (vec![4u8, 0, 0, 0, 0, 0, 0, 0, 0], false, "v2"),
            (vec![3u8, 2, 0, 0, 0], true, "v2"),
            (vec![4u8, 9, 9], true, "v2"),
        ] {
            let err = if decode_leader {
                ToLeader::decode(&bytes).unwrap_err().to_string()
            } else {
                ToWorker::decode(&bytes).unwrap_err().to_string()
            };
            assert!(
                err.contains(&format!("wire-protocol {gen}")) && err.contains("v4"),
                "unhelpful error: {err}"
            );
        }
    }

    #[test]
    fn update_roundtrip_preserves_bits() {
        let q = CodecSpec::qsgd(3).build().unwrap();
        let x: Vec<f32> = (0..300).map(|i| (i as f32 * 0.7).sin()).collect();
        let enc = q.encode(&x, &mut Rng::seed_from_u64(1));
        let dec_before = q.decode(&enc).unwrap();
        let msg =
            ToLeader::Update { version: 9, node: 4, enc, compute_ms: 12.5, decode_ms: 0.75 };
        match ToLeader::decode(&msg.encode()).unwrap() {
            ToLeader::Update { version, node, enc, compute_ms, decode_ms } => {
                assert_eq!((version, node), (9, 4));
                assert_eq!(q.decode(&enc).unwrap(), dec_before);
                assert_eq!(compute_ms.to_bits(), 12.5f64.to_bits());
                assert_eq!(decode_ms.to_bits(), 0.75f64.to_bits());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn new_codec_specs_roundtrip_on_the_wire() {
        // RandK / AdaptiveQsgd / EF-wrapped tags survive the frame codec
        // byte-exactly (EF frames are inner-tagged — what travels in an
        // Update — but Setup configs carry the wrapper spec via JSON, and
        // write_spec/read_spec must handle both shapes).
        for spec in [
            CodecSpec::rand_k(100),
            CodecSpec::RandK { k_permille: 250, seeded: false },
            CodecSpec::adaptive(4),
            CodecSpec::AdaptiveQsgd { bits_per_coord: 6, coding: Coding::Elias },
            CodecSpec::error_feedback(CodecSpec::rand_k(50)),
        ] {
            let mut b = Buf::new();
            write_spec(&mut b, &spec);
            let back = read_spec(&mut Cursor::new(&b.0)).unwrap();
            assert_eq!(back, spec);
        }
        // Exactly one wrapper level is the policy (matching config
        // validation): a doubly-nested EF spec is rejected at depth 2,
        // not merely at some absurd depth.
        let double = CodecSpec::error_feedback(CodecSpec::error_feedback(
            CodecSpec::qsgd(1),
        ));
        let mut b = Buf::new();
        write_spec(&mut b, &double);
        assert!(read_spec(&mut Cursor::new(&b.0)).is_err());
    }

    #[test]
    fn top_k_update_roundtrips_with_spec() {
        let q = TopKCodec::new(250);
        let x: Vec<f32> = (0..96).map(|i| (i as f32 * 0.3).cos()).collect();
        let enc = q.encode(&x, &mut Rng::seed_from_u64(2));
        let dec_before = q.decode(&enc).unwrap();
        let msg =
            ToLeader::Update { version: 1, node: 2, enc, compute_ms: 0.0, decode_ms: 0.0 };
        match ToLeader::decode(&msg.encode()).unwrap() {
            ToLeader::Update { enc, .. } => {
                assert_eq!(enc.spec, q.spec());
                assert_eq!(q.decode(&enc).unwrap(), dec_before);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn framing_over_a_pipe() {
        // In-memory "stream" via Vec<u8>.
        let q = CodecSpec::qsgd(1).build().unwrap();
        let mut wire = Vec::new();
        for i in 0..5u64 {
            send_frame(&mut wire, &ToLeader::Update {
                version: i,
                node: i * 2,
                enc: q.encode(&[0.5; 16], &mut Rng::seed_from_u64(i)),
                compute_ms: i as f64,
                decode_ms: 0.0,
            }
            .encode())
            .unwrap();
        }
        let mut rd = &wire[..];
        for i in 0..5u64 {
            match recv_to_leader(&mut rd).unwrap() {
                ToLeader::Update { version, node, .. } => {
                    assert_eq!(version, i);
                    assert_eq!(node, i * 2);
                }
                _ => panic!(),
            }
        }
    }

    #[test]
    fn decode_rejects_trailing_garbage() {
        let mut bytes = ToLeader::Join { proto: PROTO_VERSION }.encode();
        bytes.push(0xff);
        assert!(ToLeader::decode(&bytes).is_err());
    }

    #[test]
    fn flush_partial_roundtrips() {
        match ToWorker::decode(&ToWorker::FlushPartial.encode()).unwrap() {
            ToWorker::FlushPartial => {}
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn edge_join_and_setup_roundtrip() {
        let msg = ToLeader::EdgeJoin { proto: PROTO_VERSION, workers: 3 };
        match ToLeader::decode(&msg.encode()).unwrap() {
            ToLeader::EdgeJoin { proto, workers } => {
                assert_eq!((proto, workers), (PROTO_VERSION, 3));
            }
            _ => panic!("wrong variant"),
        }
        let cfg = ExperimentConfig::fig1_nn_base().with_tau(3);
        let msg = ToWorker::EdgeSetup {
            proto: PROTO_VERSION,
            cfg: cfg.clone(),
            edge_slot: 1,
            n_edges: 2,
            summed: true,
        };
        match ToWorker::decode(&msg.encode()).unwrap() {
            ToWorker::EdgeSetup { proto, cfg: back, edge_slot, n_edges, summed } => {
                assert_eq!(proto, PROTO_VERSION);
                assert_eq!(cfg, back);
                assert_eq!((edge_slot, n_edges, summed), (1, 2, true));
            }
            _ => panic!("wrong variant"),
        }
    }

    fn sample_contribs() -> Vec<Contrib> {
        vec![
            Contrib { node: 2, version: 7, bits: 320, compute_ms: 1.5, decode_ms: 0.25 },
            Contrib { node: 9, version: 7, bits: 480, compute_ms: 2.0, decode_ms: 0.5 },
        ]
    }

    #[test]
    fn relay_partial_roundtrips_frames_verbatim() {
        let q = CodecSpec::qsgd(2).build().unwrap();
        let encs: Vec<Encoded> = (0..2u64)
            .map(|i| {
                let x: Vec<f32> = (0..48).map(|j| ((i * 48 + j) as f32 * 0.2).sin()).collect();
                q.encode(&x, &mut Rng::seed_from_u64(i))
            })
            .collect();
        let words_before: Vec<Vec<u64>> =
            encs.iter().map(|e| e.buf.words().to_vec()).collect();
        let msg = ToLeader::PartialUpdate {
            edge_slot: 1,
            weight: 2.0,
            contribs: sample_contribs(),
            payload: PartialPayload::Relay(encs),
        };
        match ToLeader::decode(&msg.encode()).unwrap() {
            ToLeader::PartialUpdate { edge_slot, weight, contribs, payload } => {
                assert_eq!(edge_slot, 1);
                assert_eq!(weight.to_bits(), 2.0f64.to_bits());
                assert_eq!(contribs, sample_contribs());
                match payload {
                    PartialPayload::Relay(back) => {
                        assert_eq!(back.len(), 2);
                        for (enc, words) in back.iter().zip(&words_before) {
                            assert_eq!(enc.buf.words(), &words[..]);
                        }
                    }
                    _ => panic!("expected relay payload"),
                }
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn summed_partial_roundtrips() {
        let q = CodecSpec::Identity.build().unwrap();
        let enc = q.encode(&[1.0, -2.0, 0.5], &mut Rng::seed_from_u64(0));
        let words_before = enc.buf.words().to_vec();
        let msg = ToLeader::PartialUpdate {
            edge_slot: 0,
            weight: 2.0,
            contribs: sample_contribs(),
            payload: PartialPayload::Summed(enc),
        };
        match ToLeader::decode(&msg.encode()).unwrap() {
            ToLeader::PartialUpdate { payload: PartialPayload::Summed(back), .. } => {
                assert_eq!(back.buf.words(), &words_before[..]);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn relay_partial_rejects_frame_contrib_mismatch() {
        // A relay payload must carry exactly one frame per contrib —
        // a mismatched count is a malformed frame, not a surprise at
        // aggregation time.
        let q = CodecSpec::qsgd(1).build().unwrap();
        let enc = q.encode(&[0.5; 16], &mut Rng::seed_from_u64(3));
        let msg = ToLeader::PartialUpdate {
            edge_slot: 0,
            weight: 2.0,
            contribs: sample_contribs(), // two contribs, one frame
            payload: PartialPayload::Relay(vec![enc]),
        };
        let err = ToLeader::decode(&msg.encode()).unwrap_err().to_string();
        assert!(err.contains("relay partial"), "unhelpful error: {err}");
    }
}
