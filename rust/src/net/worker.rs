//! Worker process: connects to the leader, builds its world from the
//! `Setup` config, then services `Work` requests until `Shutdown`.
//! Blocking I/O — each worker is its own OS process with its own PJRT
//! client, so there is nothing to multiplex inside one worker.
//!
//! The worker is **protocol-agnostic about rounds**: every `Work` frame
//! carries the model version its params belong to, and the worker keys
//! its RNG streams off `(seed, node, version)` — identical under the
//! barrier leader (version = round index) and the buffered-async leader
//! (version = commit count at dispatch). Staleness is entirely the
//! leader's bookkeeping; a straggling worker just answers late and the
//! [`CommitPlanner`](crate::coordinator::commit_loop::CommitPlanner)
//! stamps or drops the upload on arrival.
//!
//! The worker **owns the per-node codec state** of the nodes it serves:
//! its codec instance is rebuilt from the `Setup` config's tagged spec
//! and explicitly reset (the
//! [`UpdateCodec::reset_state`](crate::quant::UpdateCodec::reset_state)
//! semantics), then lives across `Work` requests — so a stateful codec's
//! memory (e.g. [`ErrorFeedbackCodec`](crate::quant::ErrorFeedbackCodec)
//! residuals, keyed by node id inside the instance) accumulates exactly
//! as in the simulation. This is sound because both leaders pin node →
//! worker assignment by node id (see [`super::transport`]): a node's
//! whole residual stream stays in one process.

use super::proto::{
    recv_to_worker, send_to_leader, ModelPayload, ToLeader, ToWorker, PROTO_VERSION,
};
use crate::config::{EngineKind, ExperimentConfig};
use crate::coordinator::downlink::apply_link;
use crate::coordinator::local::{self, GatherBufs};
use crate::data::{BatchSampler, FederatedDataset, Partition};
use crate::figures::zoo_kind;
use crate::model::{Engine, RustEngine};
use std::net::TcpStream;
use std::path::Path;
use std::time::Duration;

/// Build the engine a worker (or leader) uses for `cfg`.
pub fn build_engine(
    cfg: &ExperimentConfig,
    artifacts: &Path,
) -> crate::Result<Box<dyn Engine>> {
    Ok(match cfg.engine {
        EngineKind::Pjrt => {
            let client = crate::runtime::cpu_client()?;
            Box::new(crate::runtime::PjrtEngine::load(&client, artifacts, &cfg.model)?)
        }
        EngineKind::Rust => {
            let (kind, batch, eval_n) = zoo_kind(&cfg.model)
                .ok_or_else(|| anyhow::anyhow!("unknown model {}", cfg.model))?;
            Box::new(RustEngine::new(kind, batch, eval_n)?)
        }
    })
}

/// Knobs for [`run_worker_with`].
#[derive(Debug, Clone, Default)]
pub struct WorkerOptions {
    /// Sleep this long before computing each `Work` request — a
    /// deterministic straggler injector for async-protocol tests and
    /// heterogeneity experiments (`fedpaq worker --delay-ms N`). The
    /// upload *content* is unaffected (it depends only on seeds), only
    /// its arrival time.
    pub work_delay: Option<Duration>,
    /// Exit cleanly (closing the connection) after answering this many
    /// `Work` requests — a deterministic worker-death injector for churn
    /// tests (`fedpaq worker --max-jobs N`). The buffered-async leader
    /// sees the close, retires the worker's remaining jobs, and
    /// re-dispatches them; the barrier leader treats it as a hard error.
    pub max_jobs: Option<u64>,
    /// Where [`run_worker_retrying`]'s reconnect attempts are reported
    /// (the `worker_reconnecting` event). Null by default.
    pub events: crate::ops::EventSink,
}

/// Worker main loop with default options. Returns after a clean
/// `Shutdown`.
pub fn run_worker(addr: &str, artifacts: &Path) -> crate::Result<()> {
    run_worker_with(addr, artifacts, WorkerOptions::default())
}

/// Worker main loop. Returns after a clean `Shutdown`.
pub fn run_worker_with(
    addr: &str,
    artifacts: &Path,
    opts: WorkerOptions,
) -> crate::Result<()> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    serve(stream, artifacts, opts)
}

/// [`run_worker_with`], but keep re-dialing a not-yet-listening leader
/// until `retry_for` elapses — the one retry implementation shared by
/// the CLI, tests and launch scripts, keyed on the *dial* failing
/// (structurally, not by error-message matching). Errors after the
/// connection is established are never retried.
///
/// Attempts back off exponentially (100 ms doubling to a 5 s cap) with
/// a deterministic jitter hashed from `(addr, attempt)`, so a fleet of
/// workers pointed at one reborn leader de-synchronizes its dials
/// without any shared randomness. Each sleep emits a
/// `worker_reconnecting` event on `opts.events`; exhausting `retry_for`
/// is a clear error naming the budget spent.
pub fn run_worker_retrying(
    addr: &str,
    artifacts: &Path,
    opts: WorkerOptions,
    retry_for: Duration,
) -> crate::Result<()> {
    // Only transient dial failures are worth retrying — a leader that is
    // not (yet) accepting. Permanent errors (bad address, unresolvable
    // host) surface on the first attempt instead of burning the window.
    let transient = |e: &std::io::Error| {
        matches!(
            e.kind(),
            std::io::ErrorKind::ConnectionRefused
                | std::io::ErrorKind::ConnectionReset
                | std::io::ErrorKind::ConnectionAborted
                | std::io::ErrorKind::TimedOut
        )
    };
    // FNV-1a over (addr, attempt): stable per worker invocation, different
    // across addresses and attempts — jitter without an RNG dependency.
    let jitter_of = |attempt: u32| {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in addr.bytes().chain(attempt.to_le_bytes()) {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    };
    let deadline = std::time::Instant::now() + retry_for;
    let mut attempt: u32 = 0;
    let stream = loop {
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(e) if transient(&e) => {
                anyhow::ensure!(
                    std::time::Instant::now() < deadline,
                    "connect {addr}: retry budget ({retry_for:?}) exhausted \
                     after {attempt} attempt(s): {e}"
                );
                // 100ms, 200ms, ... capped at 5s, plus up to +25% jitter.
                let base = 100u64.saturating_mul(1u64 << attempt.min(10)).min(5_000);
                let delay_ms = base + jitter_of(attempt) % (base / 4 + 1);
                opts.events.emit(
                    "worker_reconnecting",
                    vec![
                        ("attempt", crate::util::json::Json::num(attempt as f64)),
                        ("delay_ms", crate::util::json::Json::num(delay_ms as f64)),
                        ("error", crate::util::json::Json::str(e.to_string())),
                    ],
                );
                eprintln!("worker: leader {addr} not reachable ({e}); retrying in {delay_ms}ms");
                std::thread::sleep(Duration::from_millis(delay_ms));
                attempt += 1;
            }
            Err(e) => return Err(anyhow::anyhow!("connect {addr}: {e}")),
        }
    };
    serve(stream, artifacts, opts)
}

/// The post-connect worker protocol loop.
fn serve(stream: TcpStream, artifacts: &Path, opts: WorkerOptions) -> crate::Result<()> {
    stream.set_nodelay(true)?;
    let mut rd = stream.try_clone()?;
    let mut wr = stream;
    send_to_leader(&mut wr, &ToLeader::Join { proto: PROTO_VERSION })?;

    // World state, built on Setup. The codecs are instantiated once from
    // the config's tagged specs and reused for every Work request; the
    // last tuple slot is the downlink codec (None when the run ships raw
    // models).
    #[allow(clippy::type_complexity)]
    let mut world: Option<(
        ExperimentConfig,
        Box<dyn crate::quant::UpdateCodec>,
        Box<dyn Engine>,
        FederatedDataset,
        Partition,
        BatchSampler,
        Option<Box<dyn crate::quant::UpdateCodec>>,
    )> = None;
    // The reconstructed reference model and its version — the worker's
    // half of the QAFeL hidden state. Adopted whole from a Raw payload,
    // advanced link-by-link from Chain payloads with the same
    // [`apply_link`] arithmetic the leader used, so both sides agree
    // bit-for-bit.
    let mut reference: Option<(Vec<f32>, u64)> = None;
    let mut chain_scratch: Vec<f32> = Vec::new();
    let mut bufs = GatherBufs::default();
    let mut jobs_done: u64 = 0;

    loop {
        let msg = recv_to_worker(&mut rd)?;
        match msg {
            ToWorker::Setup { proto, cfg } => {
                anyhow::ensure!(
                    proto == PROTO_VERSION,
                    "leader speaks wire-protocol v{proto}; this worker requires \
                     v{PROTO_VERSION} — rebuild so leader and workers match"
                );
                let engine = build_engine(&cfg, artifacts)?;
                let codec = cfg.codec.build()?;
                // A run starts with no per-node codec memory — explicit,
                // even though the instance is fresh, because this is the
                // worker-side half of the trait's reset contract (the
                // leader-side half runs in RoundEngine::run).
                codec.reset_state();
                // Decode-side downlink codec. Chains arrive as
                // wire-transparent frames, so decoding never touches
                // stateful memory — the instance exists to own the
                // decode tables, not residuals.
                let down_codec = match &cfg.down_codec {
                    Some(spec) => {
                        let c = spec.build()?;
                        c.reset_state();
                        Some(c)
                    }
                    None => None,
                };
                reference = None;
                // Must agree with the sim engine's `build_world` on the
                // (possibly capped) dataset size — cross-transport
                // bit-equality depends on it.
                let n_samples = cfg.n_samples();
                let data = FederatedDataset::generate(cfg.dataset, cfg.seed, n_samples);
                let partition =
                    Partition::build(cfg.partition, &data, cfg.n_nodes, cfg.per_node, cfg.seed);
                let sampler = BatchSampler::new(cfg.seed, engine.batch());
                world = Some((cfg, codec, engine, data, partition, sampler, down_codec));
                send_to_leader(&mut wr, &ToLeader::Ready)?;
            }
            ToWorker::Work { version, node, payload, lrs } => {
                if let Some(delay) = opts.work_delay {
                    std::thread::sleep(delay);
                }
                let (cfg, codec, engine, data, partition, sampler, down_codec) = world
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("Work before Setup"))?;
                let decode_start = std::time::Instant::now();
                match payload {
                    ModelPayload::Raw(params) => reference = Some((params, version)),
                    ModelPayload::Chain { base_version, links } => {
                        let down = down_codec.as_ref().ok_or_else(|| {
                            anyhow::anyhow!(
                                "leader sent a delta chain but the config has no down_codec"
                            )
                        })?;
                        let (ref_params, ref_version) =
                            reference.as_mut().ok_or_else(|| {
                                anyhow::anyhow!(
                                    "delta chain before any raw model: nothing to apply it to"
                                )
                            })?;
                        anyhow::ensure!(
                            *ref_version == base_version,
                            "delta chain based at version {base_version} but this \
                             worker's reference is at version {ref_version}"
                        );
                        for enc in &links {
                            apply_link(down.as_ref(), enc, ref_params, &mut chain_scratch)?;
                        }
                        *ref_version = base_version + links.len() as u64;
                    }
                }
                let (params, ref_version) = reference
                    .as_ref()
                    .map(|(p, v)| (p.as_slice(), *v))
                    .expect("reference set by payload handling");
                anyhow::ensure!(
                    ref_version == version,
                    "payload reconstructed version {ref_version}, dispatch says {version}"
                );
                let decode_ms = decode_start.elapsed().as_secs_f64() * 1e3;
                let compute_start = std::time::Instant::now();
                let enc = local::node_round(
                    cfg,
                    codec.as_ref(),
                    engine.as_mut(),
                    data,
                    partition.shard(node as usize),
                    sampler,
                    node as usize,
                    version as usize,
                    params,
                    &lrs,
                    &mut bufs,
                )?;
                let compute_ms = compute_start.elapsed().as_secs_f64() * 1e3;
                send_to_leader(
                    &mut wr,
                    &ToLeader::Update { version, node, enc, compute_ms, decode_ms },
                )?;
                opts.events.emit(
                    "job_completed",
                    vec![
                        ("compute_ms", crate::util::json::Json::num(compute_ms)),
                        ("decode_ms", crate::util::json::Json::num(decode_ms)),
                        ("node", crate::util::json::Json::num(node as f64)),
                        ("version", crate::util::json::Json::num(version as f64)),
                    ],
                );
                jobs_done += 1;
                if opts.max_jobs.is_some_and(|cap| jobs_done >= cap) {
                    // Deterministic death injection: close the connection
                    // and let the leader's churn handling take over.
                    eprintln!("worker: reached --max-jobs {jobs_done}; exiting");
                    return Ok(());
                }
            }
            ToWorker::Shutdown => return Ok(()),
            // Edge-leader frames (EdgeSetup / FlushPartial) are root →
            // edge traffic; a worker receiving one means someone pointed
            // an edge connection's frames at a worker loop.
            other => anyhow::bail!("unexpected message for a worker: {other:?}"),
        }
    }
}
