//! Worker process: connects to the leader, builds its world from the
//! `Setup` config, then services `Work` requests until `Shutdown`.
//! Blocking I/O — each worker is its own OS process with its own PJRT
//! client, so there is nothing to multiplex inside one worker.

use super::proto::{recv_to_worker, send_to_leader, ToLeader, ToWorker};
use crate::config::{EngineKind, ExperimentConfig};
use crate::coordinator::local::{self, GatherBufs};
use crate::data::{BatchSampler, FederatedDataset, Partition};
use crate::figures::zoo_kind;
use crate::model::{Engine, RustEngine};
use std::net::TcpStream;
use std::path::Path;

/// Build the engine a worker (or leader) uses for `cfg`.
pub fn build_engine(
    cfg: &ExperimentConfig,
    artifacts: &Path,
) -> crate::Result<Box<dyn Engine>> {
    Ok(match cfg.engine {
        EngineKind::Pjrt => {
            let client = crate::runtime::cpu_client()?;
            Box::new(crate::runtime::PjrtEngine::load(&client, artifacts, &cfg.model)?)
        }
        EngineKind::Rust => {
            let (kind, batch, eval_n) = zoo_kind(&cfg.model)
                .ok_or_else(|| anyhow::anyhow!("unknown model {}", cfg.model))?;
            Box::new(RustEngine::new(kind, batch, eval_n)?)
        }
    })
}

/// Worker main loop. Returns after a clean `Shutdown`.
pub fn run_worker(addr: &str, artifacts: &Path) -> crate::Result<()> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    stream.set_nodelay(true)?;
    let mut rd = stream.try_clone()?;
    let mut wr = stream;
    send_to_leader(&mut wr, &ToLeader::Join)?;

    // World state, built on Setup. The codec is instantiated once from
    // the config's tagged spec and reused for every Work request.
    #[allow(clippy::type_complexity)]
    let mut world: Option<(
        ExperimentConfig,
        Box<dyn crate::quant::UpdateCodec>,
        Box<dyn Engine>,
        FederatedDataset,
        Partition,
        BatchSampler,
    )> = None;
    let mut bufs = GatherBufs::default();

    loop {
        let msg = recv_to_worker(&mut rd)?;
        match msg {
            ToWorker::Setup { cfg } => {
                let engine = build_engine(&cfg, artifacts)?;
                let codec = cfg.codec.build()?;
                let n_samples = cfg.n_nodes * cfg.per_node;
                let data = FederatedDataset::generate(cfg.dataset, cfg.seed, n_samples);
                let partition =
                    Partition::build(cfg.partition, &data, cfg.n_nodes, cfg.per_node, cfg.seed);
                let sampler = BatchSampler::new(cfg.seed, engine.batch());
                world = Some((cfg, codec, engine, data, partition, sampler));
                send_to_leader(&mut wr, &ToLeader::Ready)?;
            }
            ToWorker::Work { round, node, params, lrs } => {
                let (cfg, codec, engine, data, partition, sampler) = world
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("Work before Setup"))?;
                let enc = local::node_round(
                    cfg,
                    codec.as_ref(),
                    engine.as_mut(),
                    data,
                    partition.shard(node as usize),
                    sampler,
                    node as usize,
                    round as usize,
                    &params,
                    &lrs,
                    &mut bufs,
                )?;
                send_to_leader(&mut wr, &ToLeader::Update { round, node, enc })?;
            }
            ToWorker::Shutdown => return Ok(()),
        }
    }
}
