//! The paper's §5 cost model: virtual training-time accounting.
//!
//! Per communication round with `r` participants, period `τ`, batch `B`,
//! model dimension `p` and quantizer upload size `|Q(p,s)|` bits:
//!
//! * **computation**: each node needs a shifted-exponential time
//!   `τ·B·shift + Exp(mean = τ·B/scale)`; the round waits for the
//!   *slowest* of the `r` sampled nodes (stragglers!).
//! * **communication**: `r · |Q(p,s)| / BW` — uploads are serialized
//!   through the base station's bandwidth `BW`.
//!
//! The ratio `C_comm/C_comp = (p·F/BW) / (shift + 1/scale)` calibrates how
//! communication-bound the deployment is (paper: 100 for logreg/MNIST,
//! 1000 for the CIFAR networks).

use crate::util::rng::Rng;

/// Cost-model parameters (paper §5 "Communication/Computation time").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// Deterministic per-gradient compute time component.
    pub shift: f64,
    /// Exponential rate: the random component of one gradient has mean `1/scale`.
    pub scale: f64,
    /// Uplink bandwidth in bits per virtual-time unit.
    pub bandwidth: f64,
    /// RNG seed for the straggler draws.
    pub seed: u64,
}

impl CostModel {
    /// Mean computation time of ONE gradient: `shift + 1/scale`.
    pub fn c_comp(&self) -> f64 {
        self.shift + 1.0 / self.scale
    }

    /// Communication time of one *unquantized* length-`p` vector: `pF/BW`.
    pub fn c_comm(&self, p: usize) -> f64 {
        (p as u64 * crate::FLOAT_BITS) as f64 / self.bandwidth
    }

    /// The paper's ratio for a given model dimension.
    pub fn ratio(&self, p: usize) -> f64 {
        self.c_comm(p) / self.c_comp()
    }

    /// Build a model achieving `ratio = C_comm/C_comp` for dimension `p`,
    /// with `shift = 0.5`, `scale = 2` (so `C_comp = 1`).
    pub fn with_ratio(ratio: f64, p: usize, seed: u64) -> Self {
        let shift = 0.5;
        let scale = 2.0;
        let c_comp = shift + 1.0 / scale; // = 1
        let bandwidth = (p as u64 * crate::FLOAT_BITS) as f64 / (ratio * c_comp);
        CostModel { shift, scale, bandwidth, seed }
    }

    /// Computation time for node `node` in round `k`: `τ·B` gradients of
    /// shifted-exponential cost. Deterministic in `(seed, node, round)`.
    pub fn node_compute_time(&self, node: usize, round: usize, tau: usize, batch: usize) -> f64 {
        let work = (tau * batch) as f64;
        let mut rng = self.rng_for(node, round);
        let u: f64 = (1.0 - rng.gen_f64()).max(1e-12); // in (0, 1]
        // Exp with mean work/scale.
        let exp = -u.ln() * work / self.scale;
        work * self.shift + exp
    }

    /// Round computation time = max over the sampled nodes (stragglers).
    pub fn round_compute_time(&self, nodes: &[usize], round: usize, tau: usize, batch: usize) -> f64 {
        nodes
            .iter()
            .map(|&i| self.node_compute_time(i, round, tau, batch))
            .fold(0.0, f64::max)
    }

    /// Round communication time for `uploads` of given bit sizes
    /// (serialized through the shared uplink): `Σ bits / BW`.
    pub fn round_comm_time(&self, upload_bits: &[u64]) -> f64 {
        upload_bits.iter().map(|&b| b as f64).sum::<f64>() / self.bandwidth
    }

    fn rng_for(&self, node: usize, round: usize) -> Rng {
        Rng::from_coords(self.seed, &[4, node as u64, round as u64])
    }
}

/// Monotone virtual clock accumulating round times.
#[derive(Debug, Clone, Copy, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance by `dt ≥ 0` and return the new time.
    pub fn advance(&mut self, dt: f64) -> f64 {
        assert!(dt >= 0.0 && dt.is_finite(), "bad time step {dt}");
        self.now += dt;
        self.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_calibration() {
        for &(ratio, p) in &[(100.0, 785usize), (1000.0, 92027)] {
            let cm = CostModel::with_ratio(ratio, p, 0);
            assert!((cm.ratio(p) - ratio).abs() / ratio < 1e-12);
            assert!((cm.c_comp() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn compute_time_has_shift_floor_and_mean() {
        let cm = CostModel::with_ratio(100.0, 785, 1);
        let (tau, b) = (5usize, 10usize);
        let floor = (tau * b) as f64 * cm.shift;
        let mut acc = 0.0;
        let n = 4000;
        for round in 0..n {
            let t = cm.node_compute_time(0, round, tau, b);
            assert!(t >= floor);
            acc += t;
        }
        let mean = acc / n as f64;
        let expect = (tau * b) as f64 * (cm.shift + 1.0 / cm.scale);
        assert!(
            (mean - expect).abs() / expect < 0.05,
            "mean {mean} vs {expect}"
        );
    }

    #[test]
    fn straggler_max_dominates() {
        let cm = CostModel::with_ratio(100.0, 785, 2);
        let nodes: Vec<usize> = (0..20).collect();
        let t = cm.round_compute_time(&nodes, 3, 5, 10);
        for &n in &nodes {
            assert!(t >= cm.node_compute_time(n, 3, 5, 10));
        }
    }

    #[test]
    fn comm_time_linear_in_bits() {
        let cm = CostModel { shift: 0.5, scale: 2.0, bandwidth: 1000.0, seed: 0 };
        assert_eq!(cm.round_comm_time(&[500, 500]), 1.0);
        assert_eq!(cm.round_comm_time(&[]), 0.0);
    }

    #[test]
    fn clock_monotone() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        c.advance(0.0);
        assert_eq!(c.now(), 1.5);
    }

    #[test]
    #[should_panic(expected = "bad time step")]
    fn clock_rejects_negative() {
        VirtualClock::new().advance(-1.0);
    }
}
