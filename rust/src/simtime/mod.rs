//! The paper's §5 cost model: virtual training-time accounting.
//!
//! Per communication round with `r` participants, period `τ`, batch `B`,
//! model dimension `p` and quantizer upload size `|Q(p,s)|` bits:
//!
//! * **computation**: each node needs a shifted-exponential time
//!   `τ·B·shift + Exp(mean = τ·B/scale)`; the round waits for the
//!   *slowest* of the `r` sampled nodes (stragglers!).
//! * **communication**: `r · |Q(p,s)| / BW` — uploads are serialized
//!   through the base station's bandwidth `BW`.
//!
//! The ratio `C_comm/C_comp = (p·F/BW) / (shift + 1/scale)` calibrates how
//! communication-bound the deployment is (paper: 100 for logreg/MNIST,
//! 1000 for the CIFAR networks).
//!
//! Two scale-oriented pieces live here as well:
//!
//! * [`StragglerDist`] makes the *random* component of a node's compute
//!   time pluggable (`shifted_exp` is the paper's model; `pareto` is a
//!   mean-matched heavy tail for million-client heterogeneity studies).
//!   Draws stay pure functions of `(seed, node, round)` — no per-node
//!   state exists, which is half of the simulator's O(active) memory
//!   contract.
//! * [`EventQueue`] is the indexed min-queue `AsyncSim` pops arrivals
//!   from: O(log in-flight) per event instead of the historical linear
//!   scan, same total order ([`EventKey`]) bit for bit.

use crate::util::rng::Rng;

/// The distribution of the random component of a node's compute time.
///
/// Every variant consumes the **same single uniform draw** from the
/// `(seed, [4, node, round])` stream, so switching distributions never
/// shifts any other RNG coordinate, and `ShiftedExp` remains
/// bit-identical to the historical draws.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum StragglerDist {
    /// The paper's §5 model: `work·shift + Exp(mean work/scale)`.
    #[default]
    ShiftedExp,
    /// Heavy-tailed Pareto with tail index `alpha > 1` (finite mean),
    /// mean-matched to the exponential component via
    /// `x_m = work/scale · (alpha−1)/alpha` — average cost is unchanged,
    /// only tail mass moves, so rounds-vs-straggler-model sweeps compare
    /// like with like.
    Pareto {
        /// Tail index; smaller ⇒ heavier tail. Must be finite and > 1.
        alpha: f64,
    },
}

impl StragglerDist {
    /// Short stable name (config JSON tag / figure labels).
    pub fn name(&self) -> &'static str {
        match self {
            StragglerDist::ShiftedExp => "shifted_exp",
            StragglerDist::Pareto { .. } => "pareto",
        }
    }
}

/// Cost-model parameters (paper §5 "Communication/Computation time").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// Deterministic per-gradient compute time component.
    pub shift: f64,
    /// Exponential rate: the random component of one gradient has mean `1/scale`.
    pub scale: f64,
    /// Uplink bandwidth in bits per virtual-time unit.
    pub bandwidth: f64,
    /// RNG seed for the straggler draws.
    pub seed: u64,
    /// Distribution of the random compute-time component.
    pub dist: StragglerDist,
}

impl CostModel {
    /// Mean computation time of ONE gradient: `shift + 1/scale`.
    pub fn c_comp(&self) -> f64 {
        self.shift + 1.0 / self.scale
    }

    /// Communication time of one *unquantized* length-`p` vector: `pF/BW`.
    pub fn c_comm(&self, p: usize) -> f64 {
        (p as u64 * crate::FLOAT_BITS) as f64 / self.bandwidth
    }

    /// The paper's ratio for a given model dimension.
    pub fn ratio(&self, p: usize) -> f64 {
        self.c_comm(p) / self.c_comp()
    }

    /// Build a model achieving `ratio = C_comm/C_comp` for dimension `p`,
    /// with `shift = 0.5`, `scale = 2` (so `C_comp = 1`).
    pub fn with_ratio(ratio: f64, p: usize, seed: u64) -> Self {
        let shift = 0.5;
        let scale = 2.0;
        let c_comp = shift + 1.0 / scale; // = 1
        let bandwidth = (p as u64 * crate::FLOAT_BITS) as f64 / (ratio * c_comp);
        CostModel { shift, scale, bandwidth, seed, dist: StragglerDist::ShiftedExp }
    }

    /// Replace the straggler distribution, keeping calibration and seed.
    pub fn with_dist(self, dist: StragglerDist) -> Self {
        CostModel { dist, ..self }
    }

    /// Computation time for node `node` in round `k`: `τ·B` gradients of
    /// `shift`-floored random cost under [`CostModel::dist`].
    /// Deterministic in `(seed, node, round)` — a pure function, so no
    /// per-node state is ever resident.
    pub fn node_compute_time(&self, node: usize, round: usize, tau: usize, batch: usize) -> f64 {
        let work = (tau * batch) as f64;
        let mut rng = self.rng_for(node, round);
        let u: f64 = (1.0 - rng.gen_f64()).max(1e-12); // in (0, 1]
        let random = match self.dist {
            // Exp with mean work/scale (inverse-CDF on the shared draw).
            StragglerDist::ShiftedExp => -u.ln() * work / self.scale,
            // Pareto(x_m, alpha) with x_m mean-matched to the Exp branch:
            // E = x_m·alpha/(alpha−1) = work/scale.
            StragglerDist::Pareto { alpha } => {
                let xm = work / self.scale * (alpha - 1.0) / alpha;
                xm * u.powf(-1.0 / alpha)
            }
        };
        work * self.shift + random
    }

    /// Round computation time = max over the sampled nodes (stragglers).
    pub fn round_compute_time(&self, nodes: &[usize], round: usize, tau: usize, batch: usize) -> f64 {
        nodes
            .iter()
            .map(|&i| self.node_compute_time(i, round, tau, batch))
            .fold(0.0, f64::max)
    }

    /// Round communication time for `uploads` of given bit sizes
    /// (serialized through the shared uplink): `Σ bits / BW`.
    pub fn round_comm_time(&self, upload_bits: &[u64]) -> f64 {
        upload_bits.iter().map(|&b| b as f64).sum::<f64>() / self.bandwidth
    }

    fn rng_for(&self, node: usize, round: usize) -> Rng {
        Rng::from_coords(self.seed, &[4, node as u64, round as u64])
    }
}

/// Monotone virtual clock accumulating round times.
#[derive(Debug, Clone, Copy, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance by `dt ≥ 0` and return the new time.
    pub fn advance(&mut self, dt: f64) -> f64 {
        assert!(dt >= 0.0 && dt.is_finite(), "bad time step {dt}");
        self.now += dt;
        self.now
    }
}

/// Total order on simulated arrivals: earliest `finish` first
/// (`f64::total_cmp`), exact-time ties broken by `(version, slot, node)`
/// — the same order the historical O(in-flight) linear scan in
/// `AsyncSim::pop_next` produced, so the heap swap moves no event by
/// construction (pinned against a scan reference by
/// `rust/tests/prop_event_queue.rs`).
#[derive(Debug, Clone, Copy)]
pub struct EventKey {
    /// Virtual arrival time.
    pub finish: f64,
    /// Model version the job was dispatched at.
    pub version: usize,
    /// The planner's canonical batch position (deterministic tie-break).
    pub slot: usize,
    /// Node id (final tie-break; unique per in-flight job).
    pub node: usize,
}

impl PartialEq for EventKey {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for EventKey {}

impl Ord for EventKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.finish
            .total_cmp(&other.finish)
            .then(self.version.cmp(&other.version))
            .then(self.slot.cmp(&other.slot))
            .then(self.node.cmp(&other.node))
    }
}

impl PartialOrd for EventKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Indexed min-queue over [`EventKey`]: `pop` returns the globally next
/// arrival in O(log k) for k queued events, replacing an O(k)-per-pop
/// linear scan. Entries compare by key alone; `AsyncSim` keys are unique
/// (one in-flight job per `(node, version)`), and entries with fully
/// equal keys pop in an unspecified order among themselves.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: std::collections::BinaryHeap<std::cmp::Reverse<Entry<T>>>,
}

#[derive(Debug)]
struct Entry<T> {
    key: EventKey,
    item: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}

impl<T> Eq for Entry<T> {}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(&other.key)
    }
}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue { heap: std::collections::BinaryHeap::new() }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Queue `item` for arrival at `key`.
    pub fn push(&mut self, key: EventKey, item: T) {
        self.heap.push(std::cmp::Reverse(Entry { key, item }));
    }

    /// Remove and return the earliest entry in [`EventKey`] order.
    pub fn pop(&mut self) -> Option<(EventKey, T)> {
        self.heap.pop().map(|std::cmp::Reverse(e)| (e.key, e.item))
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }

    /// Key-sorted snapshot of every queued entry (O(k log k)) — the
    /// canonical order for serialization, independent of the heap's
    /// internal layout (checkpoint bytes must not depend on insertion
    /// history).
    pub fn sorted(&self) -> Vec<(EventKey, &T)> {
        let mut v: Vec<_> =
            self.heap.iter().map(|std::cmp::Reverse(e)| (e.key, &e.item)).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_calibration() {
        for &(ratio, p) in &[(100.0, 785usize), (1000.0, 92027)] {
            let cm = CostModel::with_ratio(ratio, p, 0);
            assert!((cm.ratio(p) - ratio).abs() / ratio < 1e-12);
            assert!((cm.c_comp() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn compute_time_has_shift_floor_and_mean() {
        let cm = CostModel::with_ratio(100.0, 785, 1);
        let (tau, b) = (5usize, 10usize);
        let floor = (tau * b) as f64 * cm.shift;
        let mut acc = 0.0;
        let n = 4000;
        for round in 0..n {
            let t = cm.node_compute_time(0, round, tau, b);
            assert!(t >= floor);
            acc += t;
        }
        let mean = acc / n as f64;
        let expect = (tau * b) as f64 * (cm.shift + 1.0 / cm.scale);
        assert!(
            (mean - expect).abs() / expect < 0.05,
            "mean {mean} vs {expect}"
        );
    }

    #[test]
    fn straggler_max_dominates() {
        let cm = CostModel::with_ratio(100.0, 785, 2);
        let nodes: Vec<usize> = (0..20).collect();
        let t = cm.round_compute_time(&nodes, 3, 5, 10);
        for &n in &nodes {
            assert!(t >= cm.node_compute_time(n, 3, 5, 10));
        }
    }

    #[test]
    fn comm_time_linear_in_bits() {
        let cm = CostModel {
            shift: 0.5,
            scale: 2.0,
            bandwidth: 1000.0,
            seed: 0,
            dist: StragglerDist::ShiftedExp,
        };
        assert_eq!(cm.round_comm_time(&[500, 500]), 1.0);
        assert_eq!(cm.round_comm_time(&[]), 0.0);
    }

    #[test]
    fn pareto_is_mean_matched_and_heavier_tailed() {
        let exp = CostModel::with_ratio(100.0, 785, 5);
        let par = exp.with_dist(StragglerDist::Pareto { alpha: 1.5 });
        let (tau, b) = (5usize, 10usize);
        let floor = (tau * b) as f64 * par.shift;
        let n = 20_000;
        let (mut acc, mut p99_exp, mut p99_par) = (0.0, Vec::new(), Vec::new());
        for round in 0..n {
            let t = par.node_compute_time(0, round, tau, b);
            assert!(t >= floor, "Pareto draw under the shift floor");
            acc += t;
            p99_par.push(t);
            p99_exp.push(exp.node_compute_time(0, round, tau, b));
        }
        // Mean-matched to the shifted-exp model. alpha=1.5 has infinite
        // variance, so the sample mean converges slowly — assert a wide
        // sanity band, not a tight tolerance (the draws are seeded, but a
        // tight band would encode one lucky sample, not the property).
        let mean = acc / n as f64;
        let expect = (tau * b) as f64 * (par.shift + 1.0 / par.scale);
        assert!(mean > 0.6 * expect && mean < 2.0 * expect, "mean {mean} vs {expect}");
        // ... but with far more tail mass: the p99.9 straggler is worse.
        let q = |v: &mut Vec<f64>| {
            v.sort_by(f64::total_cmp);
            v[(n as f64 * 0.999) as usize]
        };
        assert!(q(&mut p99_par) > 1.5 * q(&mut p99_exp), "Pareto tail not heavier");
        // Deterministic in (seed, node, round), like every cost draw.
        assert_eq!(
            par.node_compute_time(3, 7, tau, b).to_bits(),
            par.node_compute_time(3, 7, tau, b).to_bits()
        );
    }

    #[test]
    fn event_queue_pops_in_key_order_and_sorts_canonically() {
        let key = |finish, version, slot, node| EventKey { finish, version, slot, node };
        let mut q = EventQueue::new();
        for (i, k) in [
            key(2.0, 0, 1, 4),
            key(1.0, 1, 0, 2),
            key(1.0, 0, 3, 7), // same finish, earlier version
            key(1.0, 0, 3, 5), // full tie down to node
            key(0.5, 9, 9, 9),
        ]
        .into_iter()
        .enumerate()
        {
            q.push(k, i);
        }
        assert_eq!(q.len(), 5);
        // sorted() is a non-destructive view in the same total order.
        let order: Vec<usize> = q.sorted().iter().map(|&(_, &i)| i).collect();
        assert_eq!(order, vec![4, 3, 2, 1, 0]);
        let mut popped = Vec::new();
        while let Some((_, i)) = q.pop() {
            popped.push(i);
        }
        assert_eq!(popped, order);
        assert!(q.is_empty());
    }

    #[test]
    fn clock_monotone() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        c.advance(0.0);
        assert_eq!(c.now(), 1.5);
    }

    #[test]
    #[should_panic(expected = "bad time step")]
    fn clock_rejects_negative() {
        VirtualClock::new().advance(-1.0);
    }
}
