//! `artifacts/manifest.json` — what the AOT pass compiled, so the runtime
//! can validate buffers against the baked shapes before executing.
//! Parsed with the in-tree JSON module (`util::json`).

use crate::model::ModelKind;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// Top-level manifest written by `python -m compile.aot`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Minibatch size every `_step` program was compiled for.
    pub batch: usize,
    pub models: BTreeMap<String, ModelEntry>,
    pub quantizer: Option<QuantEntry>,
}

/// One model's compiled metadata.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub kind: String,
    pub param_count: usize,
    pub batch: usize,
    pub eval_n: usize,
    pub d_in: Option<usize>,
    pub n_classes: Option<usize>,
    pub layers: Option<Vec<usize>>,
    pub l2: Option<f32>,
    pub vocab: Option<usize>,
    pub seq: Option<usize>,
    pub d_model: Option<usize>,
    pub n_layers: Option<usize>,
    pub programs: Vec<String>,
}

/// The standalone Pallas-quantizer artifact.
#[derive(Debug, Clone)]
pub struct QuantEntry {
    pub name: String,
    pub p: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> crate::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} ({e}); run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> crate::Result<Self> {
        let j = Json::parse(text)?;
        let batch = j.req_usize("batch")?;
        let mut models = BTreeMap::new();
        for (name, m) in j
            .req("models")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("models is not an object"))?
        {
            models.insert(name.clone(), ModelEntry::from_json(m)?);
        }
        let quantizer = match j.get("quantizer") {
            Some(q) if *q != Json::Null => Some(QuantEntry {
                name: q.req_str("name")?.to_string(),
                p: q.req_usize("p")?,
            }),
            _ => None,
        };
        Ok(Manifest { batch, models, quantizer })
    }
}

impl ModelEntry {
    fn from_json(m: &Json) -> crate::Result<Self> {
        let opt_usize = |k: &str| m.get(k).and_then(Json::as_usize);
        Ok(ModelEntry {
            kind: m.req_str("kind")?.to_string(),
            param_count: m.req_usize("param_count")?,
            batch: m.req_usize("batch")?,
            eval_n: m.req_usize("eval_n")?,
            d_in: opt_usize("d_in"),
            n_classes: opt_usize("n_classes"),
            layers: m.get("layers").and_then(Json::as_arr).map(|a| {
                a.iter().filter_map(Json::as_usize).collect::<Vec<_>>()
            }),
            l2: m.get("l2").and_then(Json::as_f64).map(|x| x as f32),
            vocab: opt_usize("vocab"),
            seq: opt_usize("seq"),
            d_model: opt_usize("d_model"),
            n_layers: opt_usize("n_layers"),
            programs: m
                .get("programs")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
                .unwrap_or_default(),
        })
    }

    /// Structural [`ModelKind`] for this entry; cross-checks param counts.
    pub fn to_kind(&self) -> crate::Result<ModelKind> {
        let kind = match self.kind.as_str() {
            "logreg" => ModelKind::LogReg {
                d: self.d_in.ok_or_else(|| anyhow::anyhow!("logreg missing d_in"))?,
                l2: self.l2.unwrap_or(0.0),
            },
            "mlp" => ModelKind::Mlp {
                layers: self
                    .layers
                    .clone()
                    .ok_or_else(|| anyhow::anyhow!("mlp missing layers"))?,
                l2: self.l2.unwrap_or(0.0),
            },
            "transformer" => ModelKind::Transformer {
                vocab: self.vocab.ok_or_else(|| anyhow::anyhow!("missing vocab"))?,
                seq: self.seq.ok_or_else(|| anyhow::anyhow!("missing seq"))?,
                d_model: self.d_model.ok_or_else(|| anyhow::anyhow!("missing d_model"))?,
                n_layers: self.n_layers.ok_or_else(|| anyhow::anyhow!("missing n_layers"))?,
            },
            other => anyhow::bail!("unknown model kind {other:?}"),
        };
        anyhow::ensure!(
            kind.param_count() == self.param_count,
            "manifest param_count {} != computed {} — manifest/runtime drift",
            self.param_count,
            kind.param_count()
        );
        Ok(kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_inline_manifest() {
        let text = r#"{
          "batch": 10,
          "models": {
            "logreg": {"kind": "logreg", "param_count": 785, "batch": 10,
                       "eval_n": 10000, "d_in": 784, "n_classes": 2,
                       "l2": 0.05, "label_dtype": "f32",
                       "programs": ["logreg_step", "logreg_loss"]},
            "mlp": {"kind": "mlp", "param_count": 49, "batch": 10,
                    "eval_n": 16, "d_in": 4, "layers": [4, 5, 4], "l2": 0.0}
          },
          "quantizer": {"name": "quantize4096", "p": 4096}
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.batch, 10);
        let lr = &m.models["logreg"];
        assert_eq!(lr.to_kind().unwrap().param_count(), 785);
        assert_eq!(lr.programs.len(), 2);
        let mlp = &m.models["mlp"];
        assert_eq!(mlp.to_kind().unwrap().param_count(), 4 * 5 + 5 + 5 * 4 + 4);
        assert_eq!(m.quantizer.as_ref().unwrap().p, 4096);
    }

    #[test]
    fn parses_real_manifest_when_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.models.contains_key("logreg"));
        for (name, entry) in &m.models {
            let kind = entry.to_kind().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(kind.param_count(), entry.param_count, "{name}");
        }
    }

    #[test]
    fn kind_param_count_mismatch_rejected() {
        let text = r#"{"batch": 10, "models": {"bad": {"kind": "logreg",
          "param_count": 999, "batch": 10, "eval_n": 1, "d_in": 784, "l2": 0}}}"#;
        let m = Manifest::parse(text).unwrap();
        assert!(m.models["bad"].to_kind().is_err());
    }
}
