//! PJRT runtime: load AOT-lowered HLO text and run it from the hot path.
//!
//! This wraps the `xla` crate (PJRT C API) exactly as the reference at
//! `/opt/xla-example/load_hlo/`: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//!
//! Perf-relevant design (see EXPERIMENTS.md §Perf):
//!
//! * every exported program has an **untupled** root, so an output buffer
//!   feeds the next `execute_b` call directly — the τ local SGD steps of a
//!   node chain on-device with zero host round-trips;
//! * the eval slab (up to 2048×3072 f32 ≈ 24 MiB) is uploaded **once** per
//!   run and reused across every round's loss evaluation;
//! * executables are compiled once per process and cached per model.

pub mod manifest;

pub use manifest::{Manifest, ModelEntry};

use crate::model::{Engine, LabelBatch, ModelKind};
use std::path::{Path, PathBuf};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

/// Shared PJRT client handle (the `xla` client is an `Rc` internally, so
/// clones are cheap; it is deliberately `!Send` — keep it on one thread).
pub fn cpu_client() -> crate::Result<PjRtClient> {
    PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT client: {e}"))
}

fn compile(client: &PjRtClient, path: &Path) -> crate::Result<PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))
}

/// One model's compiled programs + metadata, implementing [`Engine`].
pub struct PjrtEngine {
    client: PjRtClient,
    kind: ModelKind,
    name: String,
    batch: usize,
    eval_n: usize,
    step_exe: PjRtLoadedExecutable,
    loss_exe: PjRtLoadedExecutable,
    init_exe: PjRtLoadedExecutable,
    grad_exe: Option<PjRtLoadedExecutable>,
    /// Cached on-device eval slab `(x, y)`; filled by the first eval call
    /// with a given slab (keyed by a caller-provided token).
    eval_cache: Option<(u64, PjRtBuffer, PjRtBuffer)>,
    /// Cached on-device learning-rate scalar (keyed by bit pattern) — the
    /// schedule repeats the same lr across all nodes of a round, so this
    /// saves one host->device transfer per local step (§Perf).
    lr_cache: Option<(u32, PjRtBuffer)>,
    /// Executions performed (for perf accounting).
    pub exec_count: u64,
}

impl PjrtEngine {
    /// Load + compile one model's artifacts from `dir`.
    pub fn load(client: &PjRtClient, dir: &Path, model: &str) -> crate::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let entry = manifest
            .models
            .get(model)
            .ok_or_else(|| anyhow::anyhow!("model {model} not in manifest"))?;
        let kind = entry.to_kind()?;
        let p = |suffix: &str| -> PathBuf { dir.join(format!("{model}_{suffix}.hlo.txt")) };
        let step_exe = compile(client, &p("step"))?;
        let loss_exe = compile(client, &p("loss"))?;
        let init_exe = compile(client, &p("init"))?;
        let grad_path = p("grad");
        let grad_exe =
            if grad_path.exists() { Some(compile(client, &grad_path)?) } else { None };
        Ok(PjrtEngine {
            client: client.clone(),
            kind,
            name: model.to_string(),
            batch: entry.batch,
            eval_n: entry.eval_n,
            step_exe,
            loss_exe,
            init_exe,
            grad_exe,
            eval_cache: None,
            lr_cache: None,
            exec_count: 0,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Upload a feature batch; transformer inputs are token ids (i32).
    fn x_buffer(&self, x: &[f32], rows: usize) -> crate::Result<PjRtBuffer> {
        let d = self.kind.d_in();
        anyhow::ensure!(x.len() == rows * d, "x: {} != {rows}x{d}", x.len());
        let buf = match self.kind {
            ModelKind::Transformer { .. } => {
                let toks: Vec<i32> = x.iter().map(|&v| v as i32).collect();
                self.client.buffer_from_host_buffer(&toks, &[rows, d], None)
            }
            _ => self.client.buffer_from_host_buffer(x, &[rows, d], None),
        };
        buf.map_err(|e| anyhow::anyhow!("x upload: {e}"))
    }

    fn y_buffer(&self, y: LabelBatch<'_>, rows: usize) -> crate::Result<PjRtBuffer> {
        let buf = match (y, &self.kind) {
            (LabelBatch::F32(v), ModelKind::LogReg { .. }) => {
                anyhow::ensure!(v.len() == rows, "y: {} != {rows}", v.len());
                self.client.buffer_from_host_buffer(v, &[rows], None)
            }
            (LabelBatch::I32(v), ModelKind::Mlp { .. }) => {
                anyhow::ensure!(v.len() == rows, "y: {} != {rows}", v.len());
                self.client.buffer_from_host_buffer(v, &[rows], None)
            }
            (LabelBatch::I32(v), ModelKind::Transformer { seq, .. }) => {
                anyhow::ensure!(v.len() == rows * seq, "y: {} != {rows}x{seq}", v.len());
                self.client.buffer_from_host_buffer(v, &[rows, *seq], None)
            }
            _ => anyhow::bail!("label dtype does not match model kind"),
        };
        buf.map_err(|e| anyhow::anyhow!("y upload: {e}"))
    }

    fn params_buffer(&self, params: &[f32]) -> crate::Result<PjRtBuffer> {
        anyhow::ensure!(
            params.len() == self.kind.param_count(),
            "params: {} != {}",
            params.len(),
            self.kind.param_count()
        );
        self.client
            .buffer_from_host_buffer(params, &[params.len()], None)
            .map_err(|e| anyhow::anyhow!("params upload: {e}"))
    }

    fn first_out(mut outs: Vec<Vec<PjRtBuffer>>) -> crate::Result<PjRtBuffer> {
        Ok(outs
            .pop()
            .and_then(|mut v| {
                v.truncate(1);
                v.pop()
            })
            .ok_or_else(|| anyhow::anyhow!("executable returned no output"))?)
    }

    fn buf_to_vec(buf: &PjRtBuffer) -> crate::Result<Vec<f32>> {
        let lit = buf.to_literal_sync().map_err(|e| anyhow::anyhow!("download: {e}"))?;
        lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))
    }

    /// Run τ chained local SGD steps fully on-device. `xs`/`ys` hold the τ
    /// gathered minibatches back-to-back; `lrs[t]` is the stepsize of step t.
    pub fn local_sgd_chained(
        &mut self,
        params: &[f32],
        xs: &[f32],
        ys: LabelBatch<'_>,
        lrs: &[f32],
    ) -> crate::Result<Vec<f32>> {
        let b = self.batch;
        let d = self.kind.d_in();
        let tau = lrs.len();
        anyhow::ensure!(xs.len() == tau * b * d, "xs len");
        let mut pbuf = self.params_buffer(params)?;
        for (t, &lr) in lrs.iter().enumerate() {
            let xb = self.x_buffer(&xs[t * b * d..(t + 1) * b * d], b)?;
            let yb = match ys {
                LabelBatch::F32(v) => self.y_buffer(LabelBatch::F32(&v[t * b..(t + 1) * b]), b)?,
                LabelBatch::I32(v) => {
                    let per = v.len() / tau;
                    self.y_buffer(LabelBatch::I32(&v[t * per..(t + 1) * per]), b)?
                }
            };
            if self.lr_cache.as_ref().map(|c| c.0) != Some(lr.to_bits()) {
                let lr_lit = Literal::scalar(lr);
                let buf = self
                    .client
                    .buffer_from_host_literal(None, &lr_lit)
                    .map_err(|e| anyhow::anyhow!("lr upload: {e}"))?;
                self.lr_cache = Some((lr.to_bits(), buf));
            }
            let lr_buf = &self.lr_cache.as_ref().unwrap().1;
            let outs = self
                .step_exe
                .execute_b(&[&pbuf, &xb, &yb, lr_buf])
                .map_err(|e| anyhow::anyhow!("step exec: {e}"))?;
            self.exec_count += 1;
            pbuf = Self::first_out(outs)?;
        }
        Self::buf_to_vec(&pbuf)
    }

    /// Loss on a cached eval slab. `token` identifies the slab so repeated
    /// calls skip the upload (pass a new token to invalidate).
    pub fn eval_loss_cached(
        &mut self,
        params: &[f32],
        token: u64,
        x: &[f32],
        y: LabelBatch<'_>,
    ) -> crate::Result<f32> {
        if self.eval_cache.as_ref().map(|c| c.0) != Some(token) {
            let xb = self.x_buffer(x, self.eval_n)?;
            let yb = self.y_buffer(y, self.eval_n)?;
            self.eval_cache = Some((token, xb, yb));
        }
        let pbuf = self.params_buffer(params)?;
        let (_, xb, yb) = self.eval_cache.as_ref().unwrap();
        let outs = self
            .loss_exe
            .execute_b(&[&pbuf, xb, yb])
            .map_err(|e| anyhow::anyhow!("loss exec: {e}"))?;
        self.exec_count += 1;
        let out = Self::first_out(outs)?;
        let lit = out.to_literal_sync().map_err(|e| anyhow::anyhow!("download: {e}"))?;
        lit.get_first_element::<f32>().map_err(|e| anyhow::anyhow!("scalar: {e}"))
    }
}

impl Engine for PjrtEngine {
    fn kind(&self) -> &ModelKind {
        &self.kind
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn eval_n(&self) -> usize {
        self.eval_n
    }

    fn init_params(&mut self) -> crate::Result<Vec<f32>> {
        let outs = self
            .init_exe
            .execute::<Literal>(&[])
            .map_err(|e| anyhow::anyhow!("init exec: {e}"))?;
        self.exec_count += 1;
        Self::buf_to_vec(&Self::first_out(outs)?)
    }

    fn sgd_step(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: LabelBatch<'_>,
        lr: f32,
    ) -> crate::Result<Vec<f32>> {
        self.local_sgd_chained(params, x, y, &[lr])
    }

    fn eval_loss(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: LabelBatch<'_>,
    ) -> crate::Result<f32> {
        // Un-cached path (distinct slabs): hash-free token 0 + invalidate.
        self.eval_cache = None;
        self.eval_loss_cached(params, 0, x, y)
    }

    fn local_sgd(
        &mut self,
        params: &[f32],
        xs: &[f32],
        ys: LabelBatch<'_>,
        lrs: &[f32],
    ) -> crate::Result<Vec<f32>> {
        self.local_sgd_chained(params, xs, ys, lrs)
    }

    fn eval_loss_token(
        &mut self,
        params: &[f32],
        token: u64,
        x: &[f32],
        y: LabelBatch<'_>,
    ) -> crate::Result<f32> {
        self.eval_loss_cached(params, token, x, y)
    }

    fn grad(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: LabelBatch<'_>,
    ) -> crate::Result<Vec<f32>> {
        let exe = self
            .grad_exe
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("model {} exports no grad program", self.name))?;
        let pbuf = self.params_buffer(params)?;
        let xb = self.x_buffer(x, self.eval_n)?;
        let yb = self.y_buffer(y, self.eval_n)?;
        let outs = exe
            .execute_b(&[&pbuf, &xb, &yb])
            .map_err(|e| anyhow::anyhow!("grad exec: {e}"))?;
        self.exec_count += 1;
        Self::buf_to_vec(&Self::first_out(outs)?)
    }
}

/// Standalone wrapper for the exported Pallas quantizer artifact
/// (`quantize<p>.hlo.txt`) — used to cross-check the rust codec against the
/// L1 kernel bit-for-bit.
pub struct QuantizeKernel {
    exe: PjRtLoadedExecutable,
    pub p: usize,
}

impl QuantizeKernel {
    pub fn load(client: &PjRtClient, dir: &Path) -> crate::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let q = manifest
            .quantizer
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no quantizer artifact in manifest"))?;
        let exe = compile(client, &dir.join(format!("{}.hlo.txt", q.name)))?;
        Ok(QuantizeKernel { exe, p: q.p })
    }

    /// Dequantized QSGD values for `x` with uniforms `u` and level count `s`.
    pub fn run(&self, x: &[f32], u: &[f32], s: f32) -> crate::Result<Vec<f32>> {
        anyhow::ensure!(x.len() == self.p && u.len() == self.p, "length mismatch");
        let xl = Literal::vec1(x);
        let ul = Literal::vec1(u);
        let sl = Literal::scalar(s);
        let outs = self
            .exe
            .execute::<Literal>(&[xl, ul, sl])
            .map_err(|e| anyhow::anyhow!("quantize exec: {e}"))?;
        let out = PjrtEngine::first_out(outs)?;
        PjrtEngine::buf_to_vec(&out)
    }
}
