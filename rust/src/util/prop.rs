//! Randomized property-test driver (proptest is unavailable offline).
//!
//! `check(cases, seed, |rng| ...)` runs a property over many random
//! inputs; on failure it reports the case index and the per-case seed so
//! the exact input can be replayed deterministically:
//!
//! ```ignore
//! prop::check(256, 0xfed_aq, |rng| {
//!     let p = rng.gen_range(1, 2000);
//!     let x: Vec<f32> = (0..p).map(|_| rng.gen_f32() * 2.0 - 1.0).collect();
//!     ... assertions ...
//! });
//! ```

use super::rng::Rng;

/// Run `property` over `cases` random cases. Panics (with replay info) on
/// the first failing case. The property gets a fresh deterministic RNG per
/// case, so shrinking-by-replay is `check(1, reported_seed, ...)`.
pub fn check<F: FnMut(&mut Rng)>(cases: usize, seed: u64, mut property: F) {
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add(case as u64);
        let mut rng = Rng::seed_from_u64(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property failed at case {case}/{cases} (replay: check(1, {case_seed:#x}, ..)):\n{msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_a_true_property() {
        check(100, 1, |rng| {
            let a = rng.gen_range(0, 1000);
            let b = rng.gen_range(0, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn reports_failing_case() {
        check(100, 2, |rng| {
            let x = rng.gen_range(0, 50);
            assert!(x < 49, "x was {x}");
        });
    }
}
