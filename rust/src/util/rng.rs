//! Deterministic PRNG: xoshiro256++ seeded through SplitMix64.
//!
//! Every stochastic choice in the system (data generation, node sampling,
//! minibatch draws, stochastic quantization, straggler times) flows through
//! this generator, keyed by `(master_seed, structural coordinates)`, so any
//! engine — sim, TCP worker, pure-rust oracle — independently reproduces
//! the exact same randomness.

/// xoshiro256++ (Blackman & Vigna). Passes BigCrush; not cryptographic.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the full 256-bit state from one u64 via SplitMix64.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// The raw 256-bit generator state — everything a checkpoint needs
    /// to continue this stream exactly where it left off (see
    /// [`Rng::from_state`]).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator mid-stream from a [`Rng::state`] snapshot:
    /// the restored generator produces the identical continuation of
    /// the stream the snapshot was taken from.
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }

    /// Derive a child stream from a seed plus structural coordinates
    /// (node / round / step …), statistically independent per tuple.
    pub fn from_coords(seed: u64, coords: &[u64]) -> Self {
        let mut sm = seed ^ 0x6a09_e667_f3bc_c908;
        let mut acc = splitmix64(&mut sm);
        for &c in coords {
            let mut s2 = c.wrapping_add(0x9e37_79b9_7f4a_7c15) ^ acc.rotate_left(17);
            acc ^= splitmix64(&mut s2);
            acc = acc.rotate_left(23).wrapping_mul(0x2545_f491_4f6c_dd1d);
        }
        Self::seed_from_u64(acc)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in `[0, 1)` (24-bit mantissa path).
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)` (53-bit mantissa path).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` — Lemire's multiply-shift with rejection.
    #[inline]
    pub fn gen_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `lo..hi`.
    #[inline]
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.gen_below((hi - lo) as u64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f32 {
        loop {
            let u1 = self.gen_f32();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.gen_f32();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * core::f32::consts::PI * u2).cos();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_range(0, i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn coords_streams_differ_per_coordinate() {
        let a: Vec<u64> = {
            let mut r = Rng::from_coords(1, &[2, 3]);
            (0..8).map(|_| r.next_u64()).collect()
        };
        for coords in [[2u64, 4], [3, 3], [2, 2]] {
            let mut r = Rng::from_coords(1, &coords);
            let b: Vec<u64> = (0..8).map(|_| r.next_u64()).collect();
            assert_ne!(a, b, "{coords:?}");
        }
        let mut r2 = Rng::from_coords(1, &[2, 3]);
        let again: Vec<u64> = (0..8).map(|_| r2.next_u64()).collect();
        assert_eq!(a, again);
    }

    #[test]
    fn state_snapshot_resumes_mid_stream() {
        let mut a = Rng::seed_from_u64(11);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let resumed: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_eq!(tail, resumed);
    }

    #[test]
    fn uniform_unit_interval() {
        let mut r = Rng::seed_from_u64(3);
        let n = 100_000;
        let mut acc = 0f64;
        for _ in 0..n {
            let x = r.gen_f32();
            assert!((0.0..1.0).contains(&x));
            acc += x as f64;
        }
        let mean = acc / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gen_below_unbiased() {
        let mut r = Rng::seed_from_u64(4);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.gen_below(7) as usize] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!((9_300..10_700).contains(&c), "bucket {i}: {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(5);
        let n = 50_000;
        let (mut m1, mut m2) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.gen_normal() as f64;
            m1 += x;
            m2 += x * x;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.05, "var {m2}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(6);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
