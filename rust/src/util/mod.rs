//! In-tree substrates for an offline build environment.
//!
//! The registry mirror only carries the `xla` crate's closure, so the
//! usual ecosystem crates are reimplemented here, scoped to exactly what
//! this project needs:
//!
//! * [`rng`]   — deterministic xoshiro256++ PRNG (replaces `rand`/`rand_chacha`)
//! * [`json`]  — minimal JSON parser + writer (replaces `serde_json`)
//! * [`bench`] — measurement harness for the `rust/benches/` targets
//!   (replaces `criterion`)
//! * [`prop`]  — randomized property-test driver (replaces `proptest`)
//! * [`fsio`]  — crash-safe atomic file writes (replaces `tempfile`-style
//!   staging) used by every durable artifact (RunResult dumps, bench
//!   records, `ops` checkpoints)

pub mod bench;
pub mod fsio;
pub mod json;
pub mod prop;
pub mod rng;
