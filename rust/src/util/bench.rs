//! Tiny measurement harness for the `rust/benches/` targets (criterion is
//! unavailable offline). Warmup + N timed samples, robust statistics,
//! criterion-style terminal output, optional throughput, and a
//! machine-readable `BENCH_<group>.json` record written under
//! `target/bench-results/` (override the directory with
//! `FEDPAQ_BENCH_OUT`) so EXPERIMENTS.md §Perf can cite exact numbers and
//! CI can diff throughput against the committed baselines
//! (`rust/benches/baseline/`, checked by `python/bench_check.py`).

use std::time::{Duration, Instant};

/// One benchmark group (mirrors criterion's `benchmark_group`).
pub struct Group {
    name: String,
    /// Samples per benchmark.
    pub sample_size: usize,
    /// Target time per benchmark (warmup excluded).
    pub target_time: Duration,
    results: Vec<Record>,
}

/// A finished measurement.
#[derive(Debug, Clone)]
pub struct Record {
    pub group: String,
    pub name: String,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub samples: usize,
    pub iters_per_sample: u64,
    pub throughput_bytes: Option<u64>,
    /// Elements (e.g. parameters aggregated) processed per iteration —
    /// the unit the CI regression gate compares, since elements/second is
    /// stable across codec bit widths while bytes/second is not.
    pub throughput_elems: Option<u64>,
}

impl Record {
    /// Elements processed per second (median-based; the regression-gate
    /// metric). `None` without a [`Record::throughput_elems`] annotation.
    pub fn elems_per_sec(&self) -> Option<f64> {
        self.throughput_elems.map(|e| e as f64 * 1e9 / self.median_ns)
    }
}

impl Group {
    pub fn new(name: impl Into<String>) -> Self {
        // FEDPAQ_BENCH_FAST=1 turns every bench into a smoke run (CI uses
        // it to keep `rust/benches/` from rotting without paying for real
        // measurements): few samples, tiny time budget, numbers
        // meaningless but every bench body still executes.
        let fast = std::env::var_os("FEDPAQ_BENCH_FAST").is_some();
        Group {
            name: name.into(),
            sample_size: if fast { 2 } else { 20 },
            target_time: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_secs(2)
            },
            results: Vec::new(),
        }
    }

    /// Measure `f`, auto-calibrating iterations per sample.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) {
        self.bench_annotated(name, None, None, f)
    }

    /// Measure with a bytes-processed-per-iteration annotation.
    pub fn bench_throughput<F: FnMut()>(&mut self, name: &str, bytes: Option<u64>, f: F) {
        self.bench_annotated(name, bytes, None, f)
    }

    /// Measure with an elements-processed-per-iteration annotation (the
    /// unit the CI bench-regression gate compares).
    pub fn bench_elems<F: FnMut()>(&mut self, name: &str, elems: u64, f: F) {
        self.bench_annotated(name, None, Some(elems), f)
    }

    /// Measure with explicit throughput annotations.
    pub fn bench_annotated<F: FnMut()>(
        &mut self,
        name: &str,
        bytes: Option<u64>,
        elems: Option<u64>,
        mut f: F,
    ) {
        // Calibrate: run once, then scale to ~target_time/sample_size.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let per_sample = (self.target_time.as_nanos() / self.sample_size as u128)
            .max(once.as_nanos());
        let iters = ((per_sample / once.as_nanos()).max(1)) as u64;

        // Warmup ~3 samples worth.
        for _ in 0..(3 * iters).min(1000) {
            f();
        }

        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.sample_size);
        for _ in 0..self.sample_size {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        samples_ns.sort_by(f64::total_cmp);
        let median = samples_ns[samples_ns.len() / 2];
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let var = samples_ns
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>()
            / samples_ns.len() as f64;
        let rec = Record {
            group: self.name.clone(),
            name: name.to_string(),
            mean_ns: mean,
            median_ns: median,
            stddev_ns: var.sqrt(),
            samples: self.sample_size,
            iters_per_sample: iters,
            throughput_bytes: bytes,
            throughput_elems: elems,
        };
        println!("{}", rec.render());
        self.results.push(rec);
    }

    /// Print & persist the group's results as
    /// `<out>/BENCH_<group>.json`; call at the end of the bench. `out` is
    /// `target/bench-results` unless `FEDPAQ_BENCH_OUT` overrides it.
    /// Returns the written path (`None` if writing failed — benches keep
    /// their measurements on stdout either way).
    pub fn finish(self) -> Option<std::path::PathBuf> {
        use crate::util::json::Json;
        let dir = std::env::var_os("FEDPAQ_BENCH_OUT")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| std::path::PathBuf::from("target/bench-results"));
        if std::fs::create_dir_all(&dir).is_err() {
            return None;
        }
        let path = dir.join(format!("BENCH_{}.json", self.name.replace('/', "_")));
        let records = self
            .results
            .iter()
            .map(|r| {
                let opt = |v: Option<f64>| v.map(Json::num).unwrap_or(Json::Null);
                Json::obj(vec![
                    ("name", Json::str(&r.name)),
                    ("mean_ns", Json::num(r.mean_ns)),
                    ("median_ns", Json::num(r.median_ns)),
                    ("stddev_ns", Json::num(r.stddev_ns)),
                    ("samples", Json::num(r.samples as f64)),
                    ("iters_per_sample", Json::num(r.iters_per_sample as f64)),
                    ("throughput_bytes", opt(r.throughput_bytes.map(|b| b as f64))),
                    ("throughput_elems", opt(r.throughput_elems.map(|e| e as f64))),
                    ("elems_per_sec", opt(r.elems_per_sec())),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("group", Json::str(&self.name)),
            ("records", Json::Arr(records)),
        ]);
        // Atomic staging: a bench process killed mid-write never leaves
        // a truncated BENCH_*.json for the CI regression gate to parse.
        crate::util::fsio::write_atomic_str(&path, &doc.to_string_pretty()).ok()?;
        Some(path)
    }
}

impl Record {
    fn render(&self) -> String {
        let human = |ns: f64| -> String {
            if ns < 1e3 {
                format!("{ns:.1} ns")
            } else if ns < 1e6 {
                format!("{:.2} µs", ns / 1e3)
            } else if ns < 1e9 {
                format!("{:.2} ms", ns / 1e6)
            } else {
                format!("{:.3} s", ns / 1e9)
            }
        };
        let mut line = format!(
            "{}/{:<32} time: [{} ± {}] (median {}, n={}x{})",
            self.group,
            self.name,
            human(self.mean_ns),
            human(self.stddev_ns),
            human(self.median_ns),
            self.samples,
            self.iters_per_sample,
        );
        if let Some(b) = self.throughput_bytes {
            let gbps = b as f64 / self.mean_ns; // bytes/ns == GB/s
            line.push_str(&format!("  thrpt: {gbps:.3} GB/s"));
        }
        if let Some(eps) = self.elems_per_sec() {
            line.push_str(&format!("  thrpt: {:.1} Melem/s", eps / 1e6));
        }
        line
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let mut g = Group::new("selftest");
        g.sample_size = 5;
        g.target_time = Duration::from_millis(50);
        let mut acc = 0u64;
        g.bench("wrapping_mul_loop", || {
            for i in 0..1000u64 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            std::hint::black_box(acc);
        });
        assert_eq!(g.results.len(), 1);
        let r = &g.results[0];
        assert!(r.mean_ns > 0.0 && r.mean_ns < 1e9);
        assert!(r.median_ns > 0.0);
    }
}
