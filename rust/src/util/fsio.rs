//! Crash-safe file writes: temp file + atomic rename.
//!
//! Every durable artifact the system emits — `--out-json` RunResult
//! dumps, `BENCH_*.json` records, `ops` checkpoints — goes through
//! [`write_atomic`], so a process killed mid-write can never leave a
//! truncated file behind: readers either see the previous complete
//! version or the new complete version, never a prefix.

use std::io::Write;
use std::path::Path;

/// Write `bytes` to `path` atomically: the data lands in a sibling
/// temp file first (same directory, so the final `rename` stays on one
/// filesystem and is atomic on POSIX), is flushed, then renamed over
/// `path`. On any error the temp file is cleaned up best-effort and
/// `path` is left untouched.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> crate::Result<()> {
    let dir = path.parent().filter(|d| !d.as_os_str().is_empty());
    if let Some(dir) = dir {
        std::fs::create_dir_all(dir)?;
    }
    let file_name = path
        .file_name()
        .ok_or_else(|| anyhow::anyhow!("cannot write to {}: no file name", path.display()))?;
    let mut tmp = path.to_path_buf();
    // Unique per process: concurrent writers of the same target (e.g.
    // two bench runs) each stage their own temp file; last rename wins
    // with a complete file either way.
    tmp.set_file_name(format!(
        ".{}.tmp.{}",
        file_name.to_string_lossy(),
        std::process::id()
    ));
    let write = (|| -> crate::Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        // Push the bytes to disk before the rename makes them visible,
        // so a crash after rename cannot surface an empty file.
        f.sync_all()?;
        Ok(())
    })();
    if let Err(e) = write {
        let _ = std::fs::remove_file(&tmp);
        return Err(anyhow::anyhow!(
            "staging atomic write of {}: {e}",
            path.display()
        ));
    }
    std::fs::rename(&tmp, path).map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        anyhow::anyhow!("renaming {} into place: {e}", path.display())
    })
}

/// [`write_atomic`] for string content (the common JSON case).
pub fn write_atomic_str(path: &Path, text: &str) -> crate::Result<()> {
    write_atomic(path, text.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("fedpaq-fsio-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn writes_and_overwrites() {
        let dir = tmpdir("basic");
        let path = dir.join("out.json");
        write_atomic_str(&path, "first").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "first");
        write_atomic_str(&path, "second, longer content").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "second, longer content");
        // No temp droppings left behind.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn creates_missing_parent_dirs() {
        let dir = tmpdir("nested");
        let path = dir.join("a/b/out.json");
        write_atomic_str(&path, "x").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "x");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn directory_target_is_an_error_and_leaves_no_tmp() {
        let dir = tmpdir("dirtarget");
        assert!(write_atomic_str(&dir, "x").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
