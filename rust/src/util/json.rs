//! Minimal JSON: a recursive-descent parser + writer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null) — enough for `artifacts/manifest.json`, experiment
//! config files and run manifests. No serde in this build environment.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------- accessors ----------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers with decent error messages.
    pub fn req(&self, key: &str) -> crate::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON field {key:?}"))
    }

    pub fn req_str(&self, key: &str) -> crate::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field {key:?} is not a string"))
    }

    pub fn req_usize(&self, key: &str) -> crate::Result<usize> {
        self.req(key)?
            .as_f64()
            .map(|x| x as usize)
            .ok_or_else(|| anyhow::anyhow!("field {key:?} is not a number"))
    }

    pub fn req_f64(&self, key: &str) -> crate::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field {key:?} is not a number"))
    }

    // ---------- construction ----------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    // ---------- parse ----------
    pub fn parse(text: &str) -> crate::Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        anyhow::ensure!(p.i == p.b.len(), "trailing characters at byte {}", p.i);
        Ok(v)
    }

    // ---------- write ----------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    /// Single-line rendering with no whitespace — one value per line is
    /// exactly the JSONL framing the event bus (`ops::events`) emits.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null | Json::Bool(_) | Json::Num(_) | Json::Str(_) => {
                self.write(out, 0)
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                    item.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> crate::Result<()> {
        anyhow::ensure!(
            self.peek() == Some(c),
            "expected {:?} at byte {}, found {:?}",
            c as char,
            self.i,
            self.peek().map(|b| b as char)
        );
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> crate::Result<Json> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(s.as_bytes()),
            "invalid literal at byte {}",
            self.i
        );
        self.i += s.len();
        Ok(v)
    }

    fn value(&mut self) -> crate::Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {other:?} at byte {}", self.i),
        }
    }

    fn object(&mut self) -> crate::Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => anyhow::bail!("expected ',' or '}}', found {other:?} at {}", self.i),
            }
        }
    }

    fn array(&mut self) -> crate::Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => anyhow::bail!("expected ',' or ']', found {other:?} at {}", self.i),
            }
        }
    }

    fn string(&mut self) -> crate::Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self
                .peek()
                .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self
                        .peek()
                        .ok_or_else(|| anyhow::anyhow!("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            anyhow::ensure!(self.i + 4 <= self.b.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs: join if a high surrogate.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                anyhow::ensure!(
                                    self.b.get(self.i) == Some(&b'\\')
                                        && self.b.get(self.i + 1) == Some(&b'u'),
                                    "lone surrogate"
                                );
                                let hex2 =
                                    std::str::from_utf8(&self.b[self.i + 2..self.i + 6])?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                self.i += 6;
                                char::from_u32(0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00))
                                    .ok_or_else(|| anyhow::anyhow!("bad surrogate pair"))?
                            } else {
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow::anyhow!("bad codepoint"))?
                            };
                            s.push(ch);
                        }
                        other => anyhow::bail!("bad escape \\{}", other as char),
                    }
                }
                c if c < 0x20 => anyhow::bail!("control char in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        anyhow::ensure!(start + len <= self.b.len(), "truncated utf8");
                        s.push_str(std::str::from_utf8(&self.b[start..start + len])?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> crate::Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let text = r#"{
          "batch": 10,
          "models": {"logreg": {"kind": "logreg", "param_count": 785,
                                "layers": [784, 1], "l2": 0.05}},
          "quantizer": {"name": "quantize4096", "p": 4096}
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.req_usize("batch").unwrap(), 10);
        let lr = j.get("models").unwrap().get("logreg").unwrap();
        assert_eq!(lr.req_str("kind").unwrap(), "logreg");
        assert_eq!(lr.req_usize("param_count").unwrap(), 785);
        assert_eq!(
            lr.get("layers").unwrap().as_arr().unwrap()[0].as_usize(),
            Some(784)
        );
        assert!((lr.req_f64("l2").unwrap() - 0.05).abs() < 1e-12);
    }

    #[test]
    fn roundtrip_pretty() {
        let j = Json::obj(vec![
            ("a", Json::num(1.5)),
            ("b", Json::Arr(vec![Json::Bool(true), Json::Null, Json::str("x\"y\n")])),
            ("c", Json::obj(vec![("nested", Json::num(42))])),
        ]);
        let text = j.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn roundtrip_compact_single_line() {
        let j = Json::obj(vec![
            ("a", Json::num(1.5)),
            ("b", Json::Arr(vec![Json::Bool(true), Json::Null, Json::str("x\"y\n")])),
            ("c", Json::obj(vec![("nested", Json::num(42))])),
        ]);
        let line = j.to_string_compact();
        assert!(!line.contains('\n'), "compact output spans lines: {line}");
        assert_eq!(Json::parse(&line).unwrap(), j);
        assert_eq!(
            Json::obj(vec![("k", Json::Arr(vec![]))]).to_string_compact(),
            r#"{"k":[]}"#
        );
    }

    #[test]
    fn escapes_and_unicode() {
        let j = Json::parse(r#""héllo 😀 \t|""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo 😀 \t|");
        let j = Json::parse(r#""héllo➜""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo➜");
    }

    #[test]
    fn numbers() {
        for (s, v) in [("0", 0.0), ("-12.5", -12.5), ("1e3", 1000.0), ("2.5E-2", 0.025)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(v), "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for s in ["{", "[1,]", "{\"a\":}", "tru", "\"abc", "1 2"] {
            assert!(Json::parse(s).is_err(), "{s} should fail");
        }
    }
}
