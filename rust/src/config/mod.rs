//! Typed experiment configuration (JSON files + programmatic builders).
//!
//! One [`ExperimentConfig`] fully determines a training run: model, data,
//! the FedPAQ knobs `(n, r, τ)`, the upload codec, stepsize schedule,
//! cost-model ratio and seeds. Runs are reproducible from the config
//! alone — every RNG in the system is keyed off `seed` plus structural
//! coordinates.
//!
//! ## Codec spec (JSON)
//!
//! The `codec` field is a tagged object naming a built-in
//! [`UpdateCodec`](crate::quant::UpdateCodec) implementation:
//!
//! ```json
//! {"type": "identity"}
//! {"type": "qsgd",  "s": 4, "coding": "naive" | "elias"}
//! {"type": "top_k", "k_permille": 100, "coding": "naive" | "elias"}
//! {"type": "rand_k", "k_permille": 100, "seeded": true | false}
//! {"type": "adaptive_qsgd", "bits_per_coord": 4, "coding": "naive" | "elias"}
//! {"type": "error_feedback", "inner": {"type": "top_k", ...}}
//! ```
//!
//! `error_feedback` nests one level of any non-wrapper codec (see
//! `validated`); the legacy key `quantizer` is accepted as an alias of
//! `codec`, so pre-redesign config files keep working. Codecs beyond the
//! built-ins plug in programmatically through
//! [`ServerBuilder::codec`](crate::coordinator::ServerBuilder::codec).
//!
//! ## Downlink codec (bidirectional compression)
//!
//! `codec` compresses the uplink (node → server). The optional
//! `down_codec` field — same tagged-object grammar — compresses the
//! server → node broadcast as well, QAFeL-style (Zakerinia et al.
//! 2206.10032): the server keeps a shared *reference* model, encodes
//! each new version as a compressed delta against it, and every client
//! reconstructs the identical reference by applying the decoded delta
//! chain (see `coordinator::downlink`). Absent or `null` means the
//! historical raw-f32 broadcast, so pre-bidirectional config files parse
//! unchanged. `down_codec` must be a buildable built-in (`external` has
//! no instance for clients to rebuild); `error_feedback` composes on the
//! downlink too, with one server-side residual stream.
//!
//! ## Transport knobs
//!
//! The transport is an execution-mode choice, not an experiment
//! parameter, so it stays out of this struct: the CLI picks it
//! (`fedpaq train` = in-process, `fedpaq leader`/`worker` = TCP), and
//! library users pass one to
//! [`ServerBuilder::transport`](crate::coordinator::ServerBuilder::transport).
//! Both modes replay identical uploads from the same config + seed.
//!
//! ## Buffered-async rounds
//!
//! The *round protocol* (synchronous barrier vs FedBuff-style buffered
//! async) **is** an experiment parameter — it changes what the model
//! trains on — so it lives here. Every transport serves both protocols:
//! async configs run on [`AsyncSim`](crate::coordinator::AsyncSim) in
//! simulation and on [`TcpAsync`](crate::net::TcpAsync) over real
//! sockets (`fedpaq leader` picks automatically):
//!
//! ```json
//! "async_rounds": true,
//! "buffer_size": 4,
//! "max_staleness": 8,
//! "staleness_rule": {"type": "uniform"}          // or
//! "staleness_rule": {"type": "polynomial", "a": 1.0}
//! ```
//!
//! `buffer_size` is how many uploads the server buffers before committing
//! an averaged update (`0` means `|S_k| = r`, a full barrier's worth);
//! uploads staler than `max_staleness` server versions are dropped; the
//! `staleness_rule` maps an upload's staleness `s` to its aggregation
//! weight (`uniform` → 1; `polynomial` → `(1+s)^-a`, so `a = 1` is the
//! classic `1/(1+s)` damping). All four fields default to the synchronous
//! protocol when absent, so pre-async config files parse unchanged.
//!
//! ## Scale knobs (million-client simulation)
//!
//! ```json
//! "straggler": {"type": "shifted_exp"}           // or
//! "straggler": {"type": "pareto", "alpha": 1.5},
//! "dataset_cap": 16384
//! ```
//!
//! `straggler` selects the [`StragglerDist`] behind the §5 cost model's
//! random compute-time component (absent ⇒ the paper's shifted
//! exponential, bit-identical to historical runs). `dataset_cap` bounds
//! the generated dataset to `min(cap, n·m)` samples — `0` (the default)
//! is the historical `n·m` — letting 10^5–10^7-client cohorts share a
//! fixed dataset via the arithmetic wraparound partition
//! ([`Partition::iid`](crate::data::Partition::iid)). Both default so
//! pre-scale config files parse unchanged.
//!
//! Serialization goes through the in-tree JSON module (`util::json`);
//! see `configs/` for example files.

use crate::coordinator::aggregate::StalenessRule;
use crate::data::{DatasetKind, PartitionKind};
use crate::opt::LrSchedule;
use crate::quant::{CodecSpec, Coding};
use crate::simtime::StragglerDist;
use crate::util::json::Json;
use std::path::Path;

/// Which backend executes the model math.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// AOT HLO through PJRT (the production path).
    #[default]
    Pjrt,
    /// Pure-rust oracle (logreg/MLP only; no PJRT startup).
    Rust,
}

/// Validate a config's codec spec, recursively for wrappers.
/// `allow_wrapper` is true only at the top level: `error_feedback` nests
/// exactly one level (EF-of-EF has no defined semantics — there is only
/// one residual stream per node), and its inner codec must be a concrete
/// built-in (`external` has no instance for workers to rebuild).
fn validate_codec(spec: &CodecSpec, allow_wrapper: bool) -> crate::Result<()> {
    match spec {
        CodecSpec::Qsgd { s, .. } => {
            anyhow::ensure!(*s >= 1, "QSGD needs s >= 1");
        }
        CodecSpec::TopK { k_permille, .. } => {
            anyhow::ensure!(
                (1..=1000).contains(k_permille),
                "top-k needs k_permille in 1..=1000, got {k_permille}"
            );
        }
        CodecSpec::RandK { k_permille, .. } => {
            anyhow::ensure!(
                (1..=1000).contains(k_permille),
                "rand-k needs k_permille in 1..=1000, got {k_permille}"
            );
        }
        CodecSpec::AdaptiveQsgd { bits_per_coord, .. } => {
            anyhow::ensure!(
                (2..=32).contains(bits_per_coord),
                "adaptive QSGD needs bits_per_coord in 2..=32 (1 sign bit + \
                 at least 1 level bit), got {bits_per_coord}"
            );
        }
        CodecSpec::ErrorFeedback { inner } => {
            anyhow::ensure!(
                allow_wrapper,
                "error_feedback cannot nest inside another error_feedback"
            );
            anyhow::ensure!(
                !matches!(**inner, CodecSpec::External { .. }),
                "error_feedback cannot wrap an external codec (no instance \
                 to rebuild from the config)"
            );
            validate_codec(inner, false)?;
        }
        CodecSpec::Identity | CodecSpec::External { .. } => {}
    }
    Ok(())
}

/// Serialize a codec spec to its tagged JSON object (recursively for
/// wrappers). Inverse of [`codec_from_json`].
fn codec_to_json(spec: &CodecSpec) -> Json {
    let coding_str = |coding: &Coding| {
        Json::str(match coding {
            Coding::Naive => "naive",
            Coding::Elias => "elias",
        })
    };
    match spec {
        CodecSpec::Identity => Json::obj(vec![("type", Json::str("identity"))]),
        CodecSpec::Qsgd { s, coding } => Json::obj(vec![
            ("type", Json::str("qsgd")),
            ("s", Json::num(*s as f64)),
            ("coding", coding_str(coding)),
        ]),
        CodecSpec::TopK { k_permille, coding } => Json::obj(vec![
            ("type", Json::str("top_k")),
            ("k_permille", Json::num(*k_permille as f64)),
            ("coding", coding_str(coding)),
        ]),
        CodecSpec::RandK { k_permille, seeded } => Json::obj(vec![
            ("type", Json::str("rand_k")),
            ("k_permille", Json::num(*k_permille as f64)),
            ("seeded", Json::Bool(*seeded)),
        ]),
        CodecSpec::AdaptiveQsgd { bits_per_coord, coding } => Json::obj(vec![
            ("type", Json::str("adaptive_qsgd")),
            ("bits_per_coord", Json::num(*bits_per_coord as f64)),
            ("coding", coding_str(coding)),
        ]),
        CodecSpec::ErrorFeedback { inner } => Json::obj(vec![
            ("type", Json::str("error_feedback")),
            ("inner", codec_to_json(inner)),
        ]),
        CodecSpec::External { id } => Json::obj(vec![
            ("type", Json::str("external")),
            ("id", Json::num(*id as f64)),
        ]),
    }
}

/// Parse a tagged codec JSON object (recursively for wrappers).
/// Structural limits (EF nesting depth, inner-codec legality) are
/// enforced by `validated`, not here, so error messages name the policy
/// rather than a parse failure.
fn codec_from_json(q: &Json) -> crate::Result<CodecSpec> {
    let coding = || match q.get("coding").and_then(Json::as_str).unwrap_or("naive") {
        "elias" => Coding::Elias,
        _ => Coding::Naive,
    };
    Ok(match q.req_str("type")? {
        "identity" => CodecSpec::Identity,
        "qsgd" => {
            let s = q.req_usize("s")?;
            anyhow::ensure!(s <= u32::MAX as usize, "qsgd s {s} out of range");
            CodecSpec::Qsgd { s: s as u32, coding: coding() }
        }
        "top_k" => {
            // Range-check before narrowing: `as u16` would wrap
            // out-of-range values into plausible configs.
            let k = q.req_usize("k_permille")?;
            anyhow::ensure!(k <= 1000, "top-k k_permille {k} out of range 0..=1000");
            CodecSpec::TopK { k_permille: k as u16, coding: coding() }
        }
        "rand_k" => {
            let k = q.req_usize("k_permille")?;
            anyhow::ensure!(k <= 1000, "rand-k k_permille {k} out of range 0..=1000");
            // Seeded (index-free) mode is the default, matching
            // CodecSpec::rand_k.
            let seeded = q.get("seeded").and_then(Json::as_bool).unwrap_or(true);
            CodecSpec::RandK { k_permille: k as u16, seeded }
        }
        "adaptive_qsgd" => {
            let b = q.req_usize("bits_per_coord")?;
            anyhow::ensure!(b <= u8::MAX as usize, "bits_per_coord {b} out of range");
            CodecSpec::AdaptiveQsgd { bits_per_coord: b as u8, coding: coding() }
        }
        "error_feedback" => {
            CodecSpec::ErrorFeedback { inner: Box::new(codec_from_json(q.req("inner")?)?) }
        }
        "external" => {
            let id = q.req_usize("id")?;
            anyhow::ensure!(id <= u32::MAX as usize, "external id {id} out of range");
            CodecSpec::External { id: id as u32 }
        }
        other => anyhow::bail!("unknown codec type {other:?}"),
    })
}

/// Full description of one federated training run.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// Human label (also the curve label on figures).
    pub name: String,
    /// Model name from `artifacts/manifest.json` (e.g. `"logreg"`, `"mlp92k"`).
    pub model: String,
    /// Synthetic dataset standing in for the paper's (DESIGN.md §4).
    pub dataset: DatasetKind,
    /// Total nodes `n`.
    pub n_nodes: usize,
    /// Samples per node `m`.
    pub per_node: usize,
    /// Participants per round `r ≤ n`.
    pub r: usize,
    /// Period length `τ` (local SGD steps between averagings).
    pub tau: usize,
    /// Total SGD iterations `T`; rounds `K = ceil(T/τ)`.
    pub t_total: usize,
    /// Upload codec spec (Identity == FedAvg).
    pub codec: CodecSpec,
    /// Optional downlink (server → node) codec spec. `None` is the
    /// historical raw-f32 broadcast. `Some(spec)` turns on QAFeL-style
    /// bidirectional compression: the server encodes each model version
    /// as a compressed delta against a shared reference model and
    /// clients reconstruct by applying the decoded delta chain (see
    /// `coordinator::downlink`). Must be a buildable built-in — never
    /// `external` — because every client rebuilds it from this config.
    pub down_codec: Option<CodecSpec>,
    /// Stepsize schedule.
    pub lr: LrSchedule,
    /// Cost-model ratio `C_comm/C_comp` (paper: 100 convex, 1000 NN).
    pub ratio: f64,
    /// Master seed.
    pub seed: u64,
    /// Evaluate the training loss every this many rounds.
    pub eval_every: usize,
    /// Backend.
    pub engine: EngineKind,
    /// How samples are assigned to nodes (paper: iid; Dirichlet is the
    /// heterogeneity-extension ablation).
    pub partition: PartitionKind,
    /// Run FedBuff-style buffered-async rounds instead of the paper's
    /// synchronous barrier. Served by
    /// [`AsyncSim`](crate::coordinator::AsyncSim) in simulation and by
    /// [`TcpAsync`](crate::net::TcpAsync) on a real cluster — both driven
    /// by the same event-driven
    /// [`CommitPlanner`](crate::coordinator::commit_loop::CommitPlanner).
    pub async_rounds: bool,
    /// Async mode: uploads buffered per server commit. `0` means
    /// `|S_k| = r` (a full barrier's worth — the synchronous limit).
    pub buffer_size: usize,
    /// Async mode: drop uploads staler than this many server versions.
    pub max_staleness: usize,
    /// Async mode: staleness → aggregation-weight damping rule.
    pub staleness_rule: StalenessRule,
    /// Server-side aggregation shards: the parameter vector is split into
    /// this many contiguous ranges, accumulated on scoped threads
    /// ([`ShardPlan`](crate::coordinator::aggregate::ShardPlan)). A pure
    /// throughput knob — results are bit-identical for every value
    /// (see the `aggregate` module docs). `1` = the historical
    /// single-threaded loop.
    pub agg_shards: usize,
    /// Straggler distribution behind the §5 cost model's random
    /// compute-time component. The default (`ShiftedExp`) is the paper's
    /// model and is bit-identical to pre-knob runs; `Pareto` is the
    /// mean-matched heavy tail for cohort-heterogeneity sweeps.
    pub straggler: StragglerDist,
    /// Cap the generated dataset at this many samples (`0` = the
    /// historical `n_nodes · per_node`). With a cap below `n·m`, node
    /// shards wrap around the dataset and share samples
    /// ([`Partition::iid`](crate::data::Partition::iid) oversubscription)
    /// — what keeps 10^5+-client cohorts in memory. IID partitions only.
    pub dataset_cap: usize,
}

impl ExperimentConfig {
    /// Rounds `K = ceil(T/τ)` — server commits in async mode.
    pub fn rounds(&self) -> usize {
        self.t_total.div_ceil(self.tau)
    }

    /// The resolved async commit threshold: `buffer_size`, with `0`
    /// meaning the full sampled set `r`.
    pub fn effective_buffer_size(&self) -> usize {
        if self.buffer_size == 0 {
            self.r
        } else {
            self.buffer_size
        }
    }

    /// The generated dataset size: `min(dataset_cap, n·m)` with `0`
    /// meaning uncapped. Every process that materializes the dataset
    /// (sim engine, TCP workers) must agree on this number.
    pub fn n_samples(&self) -> usize {
        let full = self.n_nodes * self.per_node;
        if self.dataset_cap == 0 {
            full
        } else {
            self.dataset_cap.min(full)
        }
    }

    /// Validate internal consistency; returns self for chaining.
    pub fn validated(self) -> crate::Result<Self> {
        anyhow::ensure!(self.n_nodes >= 1, "need at least one node");
        anyhow::ensure!(
            (1..=self.n_nodes).contains(&self.r),
            "r={} must be in 1..=n={}",
            self.r,
            self.n_nodes
        );
        anyhow::ensure!(self.tau >= 1, "tau must be >= 1");
        anyhow::ensure!(self.t_total >= self.tau, "T must be >= tau");
        anyhow::ensure!(self.per_node >= 1, "per_node must be >= 1");
        anyhow::ensure!(self.eval_every >= 1, "eval_every must be >= 1");
        anyhow::ensure!(self.ratio > 0.0, "ratio must be positive");
        validate_codec(&self.codec, true)?;
        if let Some(down) = &self.down_codec {
            anyhow::ensure!(
                down.rebuildable(),
                "down_codec must be rebuildable from the config: every \
                 client rebuilds the downlink decoder from the spec, and \
                 `external` has no instance to rebuild"
            );
            validate_codec(down, true)?;
        }
        if let PartitionKind::Dirichlet { alpha } = self.partition {
            anyhow::ensure!(alpha > 0.0, "dirichlet alpha must be positive");
        }
        anyhow::ensure!(
            self.buffer_size <= self.r,
            "buffer_size={} must be <= r={} (0 = full barrier)",
            self.buffer_size,
            self.r
        );
        if let StalenessRule::Polynomial { a } = self.staleness_rule {
            anyhow::ensure!(
                a.is_finite() && a > 0.0,
                "polynomial staleness rule needs a finite exponent a > 0, got {a}"
            );
        }
        anyhow::ensure!(self.agg_shards >= 1, "agg_shards must be >= 1");
        if let StragglerDist::Pareto { alpha } = self.straggler {
            anyhow::ensure!(
                alpha.is_finite() && alpha > 1.0,
                "pareto straggler needs a finite tail index alpha > 1 \
                 (finite mean), got {alpha}"
            );
        }
        if self.dataset_cap != 0 && self.dataset_cap < self.n_nodes * self.per_node {
            anyhow::ensure!(
                self.partition == PartitionKind::Iid,
                "dataset_cap below n_nodes*per_node requires the iid \
                 partition (label-skew shards cannot wrap around)"
            );
        }
        Ok(self)
    }

    /// Paper Fig-1-top base config: logreg on synthetic MNIST-0/8,
    /// `n=50, m=200, T=100, ratio=100`.
    pub fn fig1_logreg_base() -> Self {
        ExperimentConfig {
            name: "fedpaq".into(),
            model: "logreg".into(),
            dataset: DatasetKind::Mnist08,
            n_nodes: 50,
            per_node: 200,
            r: 25,
            tau: 5,
            t_total: 100,
            codec: CodecSpec::qsgd(1),
            down_codec: None,
            lr: LrSchedule::Const { eta: 0.2 },
            ratio: 100.0,
            seed: 42,
            eval_every: 1,
            engine: EngineKind::Pjrt,
            partition: PartitionKind::Iid,
            async_rounds: false,
            buffer_size: 0,
            max_staleness: 8,
            staleness_rule: StalenessRule::Uniform,
            agg_shards: 1,
            straggler: StragglerDist::ShiftedExp,
            dataset_cap: 0,
        }
    }

    /// Paper Fig-1-bottom base config: mlp92k on synthetic CIFAR-10,
    /// `n=50, 10K samples, T=100, ratio=1000`.
    pub fn fig1_nn_base() -> Self {
        ExperimentConfig {
            name: "fedpaq".into(),
            model: "mlp92k".into(),
            dataset: DatasetKind::Cifar10,
            n_nodes: 50,
            per_node: 200,
            r: 25,
            tau: 2,
            t_total: 100,
            codec: CodecSpec::qsgd(1),
            down_codec: None,
            lr: LrSchedule::Const { eta: 0.1 },
            ratio: 1000.0,
            seed: 42,
            eval_every: 1,
            engine: EngineKind::Pjrt,
            partition: PartitionKind::Iid,
            async_rounds: false,
            buffer_size: 0,
            max_staleness: 8,
            staleness_rule: StalenessRule::Uniform,
            agg_shards: 1,
            straggler: StragglerDist::ShiftedExp,
            dataset_cap: 0,
        }
    }

    // ---------------- JSON (de)serialization ----------------

    pub fn to_json(&self) -> Json {
        let codec = codec_to_json(&self.codec);
        let lr = match self.lr {
            LrSchedule::Const { eta } => Json::obj(vec![
                ("type", Json::str("const")),
                ("eta", Json::num(eta as f64)),
            ]),
            LrSchedule::PolyDecay { mu, tau, eta_max } => Json::obj(vec![
                ("type", Json::str("poly_decay")),
                ("mu", Json::num(mu as f64)),
                ("tau", Json::num(tau as f64)),
                ("eta_max", Json::num(eta_max as f64)),
            ]),
            LrSchedule::NonConvex { l_smooth, t_total } => Json::obj(vec![
                ("type", Json::str("non_convex")),
                ("l_smooth", Json::num(l_smooth as f64)),
                ("t_total", Json::num(t_total as f64)),
            ]),
        };
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("model", Json::str(&self.model)),
            ("dataset", Json::str(self.dataset.name())),
            ("n_nodes", Json::num(self.n_nodes as f64)),
            ("per_node", Json::num(self.per_node as f64)),
            ("r", Json::num(self.r as f64)),
            ("tau", Json::num(self.tau as f64)),
            ("t_total", Json::num(self.t_total as f64)),
            ("codec", codec),
            (
                "down_codec",
                match &self.down_codec {
                    // Emit an explicit null so the canonical serialization
                    // always carries the key (config_hash covers it either
                    // way; parse treats absent and null identically).
                    None => Json::Null,
                    Some(down) => codec_to_json(down),
                },
            ),
            ("lr", lr),
            ("ratio", Json::num(self.ratio)),
            // Seeds are u64 and exceed f64's 2^53 integer range: ship as a
            // decimal string (parse accepts either form).
            ("seed", Json::str(self.seed.to_string())),
            ("eval_every", Json::num(self.eval_every as f64)),
            (
                "engine",
                Json::str(match self.engine {
                    EngineKind::Pjrt => "pjrt",
                    EngineKind::Rust => "rust",
                }),
            ),
            (
                "partition",
                match self.partition {
                    PartitionKind::Iid => Json::obj(vec![("type", Json::str("iid"))]),
                    PartitionKind::Dirichlet { alpha } => Json::obj(vec![
                        ("type", Json::str("dirichlet")),
                        ("alpha", Json::num(alpha)),
                    ]),
                },
            ),
            ("async_rounds", Json::Bool(self.async_rounds)),
            ("buffer_size", Json::num(self.buffer_size as f64)),
            ("max_staleness", Json::num(self.max_staleness as f64)),
            (
                "staleness_rule",
                match self.staleness_rule {
                    StalenessRule::Uniform => {
                        Json::obj(vec![("type", Json::str("uniform"))])
                    }
                    StalenessRule::Polynomial { a } => Json::obj(vec![
                        ("type", Json::str("polynomial")),
                        ("a", Json::num(a)),
                    ]),
                },
            ),
            ("agg_shards", Json::num(self.agg_shards as f64)),
            (
                "straggler",
                match self.straggler {
                    StragglerDist::ShiftedExp => {
                        Json::obj(vec![("type", Json::str("shifted_exp"))])
                    }
                    StragglerDist::Pareto { alpha } => Json::obj(vec![
                        ("type", Json::str("pareto")),
                        ("alpha", Json::num(alpha)),
                    ]),
                },
            ),
            ("dataset_cap", Json::num(self.dataset_cap as f64)),
        ])
    }

    /// FNV-1a 64 over the canonical (pretty, sorted-key) config JSON.
    ///
    /// This is the run-identity key stamped into checkpoints
    /// ([`crate::ops::Checkpoint`]) and `RunResult` meta blocks: two
    /// processes agree on the hash iff they agree on *every* knob, so a
    /// `--resume` under a drifted config is rejected up front instead of
    /// silently diverging.
    pub fn config_hash(&self) -> u64 {
        let text = self.to_json().to_string_pretty();
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in text.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        // `codec` is the current key; `quantizer` is the legacy alias
        // kept so pre-redesign config files parse unchanged.
        let codec = codec_from_json(
            j.get("codec")
                .or_else(|| j.get("quantizer"))
                .ok_or_else(|| anyhow::anyhow!("missing JSON field \"codec\""))?,
        )?;
        // Absent (pre-bidirectional files) and explicit null both mean
        // the historical raw-f32 broadcast.
        let down_codec = match j.get("down_codec") {
            None | Some(Json::Null) => None,
            Some(d) => Some(codec_from_json(d)?),
        };
        let lr = {
            let l = j.req("lr")?;
            match l.req_str("type")? {
                "const" => LrSchedule::Const { eta: l.req_f64("eta")? as f32 },
                "poly_decay" => LrSchedule::PolyDecay {
                    mu: l.req_f64("mu")? as f32,
                    tau: l.req_usize("tau")?,
                    eta_max: l.req_f64("eta_max")? as f32,
                },
                "non_convex" => LrSchedule::NonConvex {
                    l_smooth: l.req_f64("l_smooth")? as f32,
                    t_total: l.req_usize("t_total")?,
                },
                other => anyhow::bail!("unknown lr type {other:?}"),
            }
        };
        ExperimentConfig {
            name: j.req_str("name")?.to_string(),
            model: j.req_str("model")?.to_string(),
            dataset: DatasetKind::parse(j.req_str("dataset")?)?,
            n_nodes: j.req_usize("n_nodes")?,
            per_node: j.req_usize("per_node")?,
            r: j.req_usize("r")?,
            tau: j.req_usize("tau")?,
            t_total: j.req_usize("t_total")?,
            codec,
            down_codec,
            lr,
            ratio: j.req_f64("ratio")?,
            seed: match j.req("seed")? {
                Json::Str(t) => t.parse::<u64>()?,
                v => v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("seed must be number or string"))?
                    as u64,
            },
            eval_every: j.get("eval_every").and_then(Json::as_usize).unwrap_or(1),
            engine: match j.get("engine").and_then(Json::as_str).unwrap_or("pjrt") {
                "rust" => EngineKind::Rust,
                _ => EngineKind::Pjrt,
            },
            partition: match j.get("partition") {
                None => PartitionKind::Iid,
                Some(p) => match p.req_str("type")? {
                    "iid" => PartitionKind::Iid,
                    "dirichlet" => PartitionKind::Dirichlet { alpha: p.req_f64("alpha")? },
                    other => anyhow::bail!("unknown partition type {other:?}"),
                },
            },
            // Async knobs all default to the synchronous protocol, so
            // pre-async config files parse unchanged.
            async_rounds: j.get("async_rounds").and_then(Json::as_bool).unwrap_or(false),
            buffer_size: j.get("buffer_size").and_then(Json::as_usize).unwrap_or(0),
            max_staleness: j.get("max_staleness").and_then(Json::as_usize).unwrap_or(8),
            staleness_rule: match j.get("staleness_rule") {
                None => StalenessRule::Uniform,
                Some(rule) => match rule.req_str("type")? {
                    "uniform" => StalenessRule::Uniform,
                    "polynomial" => StalenessRule::Polynomial { a: rule.req_f64("a")? },
                    other => anyhow::bail!("unknown staleness rule {other:?}"),
                },
            },
            // Absent in pre-sharding config files: default to the
            // historical single-threaded accumulation.
            agg_shards: j.get("agg_shards").and_then(Json::as_usize).unwrap_or(1),
            // Scale knobs default so pre-scale config files parse
            // unchanged (shifted-exponential stragglers, uncapped data).
            straggler: match j.get("straggler") {
                None => StragglerDist::ShiftedExp,
                Some(s) => match s.req_str("type")? {
                    "shifted_exp" => StragglerDist::ShiftedExp,
                    "pareto" => StragglerDist::Pareto { alpha: s.req_f64("alpha")? },
                    other => anyhow::bail!("unknown straggler type {other:?}"),
                },
            },
            dataset_cap: j.get("dataset_cap").and_then(Json::as_usize).unwrap_or(0),
        }
        .validated()
    }

    /// Load from a JSON config file.
    pub fn from_json_file(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    // ---------------- builder helpers for the figure grids ----------------

    pub fn with_name(mut self, name: impl Into<String>) -> Self {
        self.name = name.into();
        self
    }

    pub fn with_codec(mut self, codec: CodecSpec) -> Self {
        self.codec = codec;
        self
    }

    /// Enable downlink (server → node) compression with the given codec.
    pub fn with_down_codec(mut self, down: CodecSpec) -> Self {
        self.down_codec = Some(down);
        self
    }

    pub fn with_r(mut self, r: usize) -> Self {
        self.r = r;
        self
    }

    pub fn with_tau(mut self, tau: usize) -> Self {
        self.tau = tau;
        self
    }

    pub fn with_engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_lr(mut self, lr: LrSchedule) -> Self {
        self.lr = lr;
        self
    }

    pub fn with_partition(mut self, partition: PartitionKind) -> Self {
        self.partition = partition;
        self
    }

    /// Enable buffered-async rounds with the given commit threshold
    /// (`0` = full barrier's worth) and staleness cap.
    pub fn with_async(mut self, buffer_size: usize, max_staleness: usize) -> Self {
        self.async_rounds = true;
        self.buffer_size = buffer_size;
        self.max_staleness = max_staleness;
        self
    }

    pub fn with_staleness_rule(mut self, rule: StalenessRule) -> Self {
        self.staleness_rule = rule;
        self
    }

    /// Set the server-side aggregation shard count (`1` = the historical
    /// single-threaded accumulation; bit-identical results either way).
    pub fn with_agg_shards(mut self, agg_shards: usize) -> Self {
        self.agg_shards = agg_shards;
        self
    }

    /// Select the straggler compute-time distribution (cost model).
    pub fn with_straggler(mut self, straggler: StragglerDist) -> Self {
        self.straggler = straggler;
        self
    }

    /// Cap the generated dataset at `dataset_cap` samples; shards wrap
    /// around it (i.i.d. only). `0` = uncapped (`n_nodes * per_node`).
    pub fn with_dataset_cap(mut self, dataset_cap: usize) -> Self {
        self.dataset_cap = dataset_cap;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_configs_validate() {
        ExperimentConfig::fig1_logreg_base().validated().unwrap();
        ExperimentConfig::fig1_nn_base().validated().unwrap();
    }

    #[test]
    fn rounds_is_ceil() {
        let c = ExperimentConfig::fig1_logreg_base().with_tau(3);
        assert_eq!(c.rounds(), 34); // ceil(100/3)
        let c = c.with_tau(5);
        assert_eq!(c.rounds(), 20);
    }

    #[test]
    fn invalid_r_rejected() {
        let c = ExperimentConfig::fig1_logreg_base().with_r(51);
        assert!(c.validated().is_err());
        let c = ExperimentConfig::fig1_logreg_base().with_r(0);
        assert!(c.validated().is_err());
    }

    #[test]
    fn invalid_top_k_rejected() {
        let c = ExperimentConfig::fig1_logreg_base().with_codec(CodecSpec::top_k(0));
        assert!(c.validated().is_err());
        let c = ExperimentConfig::fig1_logreg_base()
            .with_codec(CodecSpec::TopK { k_permille: 1001, coding: Coding::Naive });
        assert!(c.validated().is_err());
    }

    #[test]
    fn invalid_new_codec_specs_rejected() {
        let base = || ExperimentConfig::fig1_logreg_base();
        // rand-k permille bounds.
        assert!(base().with_codec(CodecSpec::rand_k(0)).validated().is_err());
        assert!(base()
            .with_codec(CodecSpec::RandK { k_permille: 1001, seeded: true })
            .validated()
            .is_err());
        // adaptive budget needs at least sign + one level bit.
        assert!(base().with_codec(CodecSpec::adaptive(1)).validated().is_err());
        assert!(base().with_codec(CodecSpec::adaptive(33)).validated().is_err());
        assert!(base().with_codec(CodecSpec::adaptive(2)).validated().is_ok());
        // EF nesting and EF-of-external are policy errors.
        let nested = CodecSpec::error_feedback(CodecSpec::error_feedback(
            CodecSpec::qsgd(1),
        ));
        assert!(base().with_codec(nested).validated().is_err());
        let ef_ext = CodecSpec::error_feedback(CodecSpec::External { id: 9 });
        assert!(base().with_codec(ef_ext).validated().is_err());
        // EF inner params are validated recursively.
        let ef_bad = CodecSpec::error_feedback(CodecSpec::top_k(0));
        assert!(base().with_codec(ef_bad).validated().is_err());
        assert!(base()
            .with_codec(CodecSpec::error_feedback(CodecSpec::qsgd(1)))
            .validated()
            .is_ok());
    }

    #[test]
    fn invalid_async_knobs_rejected() {
        // buffer_size beyond the sampled set is meaningless.
        let c = ExperimentConfig::fig1_logreg_base().with_async(26, 8).with_r(25);
        assert!(c.validated().is_err());
        // Polynomial damping needs a positive finite exponent.
        for a in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let c = ExperimentConfig::fig1_logreg_base()
                .with_staleness_rule(StalenessRule::Polynomial { a });
            assert!(c.validated().is_err(), "a={a} accepted");
        }
        // The synchronous sentinel (0 = full barrier) stays valid.
        let c = ExperimentConfig::fig1_logreg_base().with_async(0, 0);
        assert_eq!(c.effective_buffer_size(), 25);
        c.validated().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        for cfg in [
            ExperimentConfig::fig1_nn_base().with_tau(7).with_r(13),
            ExperimentConfig::fig1_logreg_base()
                .with_codec(CodecSpec::Identity)
                .with_engine(EngineKind::Rust)
                .with_lr(LrSchedule::PolyDecay { mu: 0.1, tau: 5, eta_max: 1.0 }),
            ExperimentConfig::fig1_logreg_base()
                .with_codec(CodecSpec::TopK { k_permille: 125, coding: Coding::Elias }),
            ExperimentConfig::fig1_logreg_base()
                .with_codec(CodecSpec::External { id: 41 }),
            ExperimentConfig::fig1_logreg_base().with_codec(CodecSpec::rand_k(150)),
            ExperimentConfig::fig1_logreg_base()
                .with_codec(CodecSpec::RandK { k_permille: 75, seeded: false }),
            ExperimentConfig::fig1_logreg_base().with_codec(CodecSpec::adaptive(4)),
            ExperimentConfig::fig1_logreg_base().with_codec(CodecSpec::AdaptiveQsgd {
                bits_per_coord: 6,
                coding: Coding::Elias,
            }),
            ExperimentConfig::fig1_logreg_base()
                .with_codec(CodecSpec::error_feedback(CodecSpec::top_k(100))),
            ExperimentConfig::fig1_logreg_base()
                .with_codec(CodecSpec::error_feedback(CodecSpec::rand_k(100))),
            ExperimentConfig::fig1_logreg_base().with_async(4, 16),
            ExperimentConfig::fig1_logreg_base()
                .with_async(7, 0)
                .with_staleness_rule(StalenessRule::Polynomial { a: 0.5 }),
            ExperimentConfig::fig1_logreg_base().with_agg_shards(8),
            ExperimentConfig::fig1_logreg_base().with_down_codec(CodecSpec::qsgd(4)),
            ExperimentConfig::fig1_logreg_base()
                .with_codec(CodecSpec::top_k(100))
                .with_down_codec(CodecSpec::error_feedback(CodecSpec::top_k(100)))
                .with_async(4, 16),
            ExperimentConfig::fig1_logreg_base()
                .with_down_codec(CodecSpec::rand_k(150)),
            ExperimentConfig::fig1_logreg_base()
                .with_straggler(StragglerDist::Pareto { alpha: 1.5 })
                .with_dataset_cap(500),
        ] {
            let j = cfg.to_json();
            let back = ExperimentConfig::from_json(&j).unwrap();
            assert_eq!(cfg, back);
            // And through text.
            let back2 =
                ExperimentConfig::from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
            assert_eq!(cfg, back2);
        }
    }

    #[test]
    fn config_hash_is_stable_and_knob_sensitive() {
        let cfg = ExperimentConfig::fig1_logreg_base();
        // Deterministic across calls and across JSON round-trips (the
        // hash covers the canonical serialization).
        assert_eq!(cfg.config_hash(), cfg.config_hash());
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg.config_hash(), back.config_hash());
        // Any knob drift changes the hash — seed, codec, async shape.
        assert_ne!(cfg.config_hash(), cfg.clone().with_seed(1).config_hash());
        assert_ne!(
            cfg.config_hash(),
            cfg.clone().with_codec(CodecSpec::Identity).config_hash()
        );
        assert_ne!(cfg.config_hash(), cfg.clone().with_async(4, 8).config_hash());
        assert_ne!(
            cfg.config_hash(),
            cfg.clone()
                .with_straggler(StragglerDist::Pareto { alpha: 1.5 })
                .config_hash()
        );
        assert_ne!(cfg.config_hash(), cfg.clone().with_dataset_cap(100).config_hash());
    }

    #[test]
    fn example_config_files_parse() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("../configs");
        for f in [
            "fedpaq_qsgd_logreg.json",
            "topk_logreg.json",
            "legacy_quantizer_key.json",
            "async_fedbuff_logreg.json",
            "async_tcp_logreg.json",
            "ef_randk_logreg.json",
            "bidir_qsgd_logreg.json",
            "scale_logreg.json",
        ] {
            ExperimentConfig::from_json_file(&dir.join(f))
                .unwrap_or_else(|e| panic!("{f}: {e}"));
        }
        let ef_cfg =
            ExperimentConfig::from_json_file(&dir.join("ef_randk_logreg.json")).unwrap();
        assert_eq!(
            ef_cfg.codec,
            CodecSpec::error_feedback(CodecSpec::rand_k(100))
        );
        let async_cfg =
            ExperimentConfig::from_json_file(&dir.join("async_fedbuff_logreg.json")).unwrap();
        assert!(async_cfg.async_rounds);
        assert_eq!(async_cfg.effective_buffer_size(), 4);
        let bidir_cfg =
            ExperimentConfig::from_json_file(&dir.join("bidir_qsgd_logreg.json")).unwrap();
        assert_eq!(bidir_cfg.down_codec, Some(CodecSpec::qsgd(4)));
        assert!(bidir_cfg.async_rounds);
        let scale_cfg =
            ExperimentConfig::from_json_file(&dir.join("scale_logreg.json")).unwrap();
        assert!(scale_cfg.async_rounds);
        assert!(scale_cfg.dataset_cap > 0);
        assert!(matches!(scale_cfg.straggler, StragglerDist::Pareto { .. }));
    }

    #[test]
    fn pre_async_configs_parse_to_synchronous_defaults() {
        // A config JSON written before the async fields existed must land
        // on the synchronous protocol.
        let mut j = ExperimentConfig::fig1_logreg_base().to_json();
        if let Json::Obj(map) = &mut j {
            for key in ["async_rounds", "buffer_size", "max_staleness", "staleness_rule"] {
                map.remove(key);
            }
        } else {
            panic!("config JSON must be an object");
        }
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert!(!back.async_rounds);
        assert_eq!(back.buffer_size, 0);
        assert_eq!(back.staleness_rule, StalenessRule::Uniform);
        assert_eq!(back, ExperimentConfig::fig1_logreg_base());
    }

    #[test]
    fn pre_sharding_configs_parse_to_one_shard() {
        // A config JSON written before `agg_shards` existed must land on
        // the historical single-threaded accumulation.
        let mut j = ExperimentConfig::fig1_logreg_base().to_json();
        if let Json::Obj(map) = &mut j {
            map.remove("agg_shards");
        } else {
            panic!("config JSON must be an object");
        }
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.agg_shards, 1);
        assert_eq!(back, ExperimentConfig::fig1_logreg_base());
    }

    #[test]
    fn pre_scale_configs_parse_to_defaults() {
        // A config JSON written before the scale knobs existed must land
        // on shifted-exponential stragglers and an uncapped dataset.
        let mut j = ExperimentConfig::fig1_logreg_base().to_json();
        if let Json::Obj(map) = &mut j {
            for key in ["straggler", "dataset_cap"] {
                map.remove(key);
            }
        } else {
            panic!("config JSON must be an object");
        }
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.straggler, StragglerDist::ShiftedExp);
        assert_eq!(back.dataset_cap, 0);
        assert_eq!(back, ExperimentConfig::fig1_logreg_base());
    }

    #[test]
    fn invalid_scale_knobs_rejected() {
        // Pareto needs a finite tail index > 1 for a finite mean.
        for alpha in [1.0, 0.5, f64::NAN, f64::INFINITY] {
            let c = ExperimentConfig::fig1_logreg_base()
                .with_straggler(StragglerDist::Pareto { alpha });
            assert!(c.validated().is_err(), "alpha={alpha} accepted");
        }
        // A binding dataset cap requires the arithmetic i.i.d. partition.
        let c = ExperimentConfig::fig1_logreg_base()
            .with_partition(PartitionKind::Dirichlet { alpha: 0.5 })
            .with_dataset_cap(10);
        assert!(c.validated().is_err());
        // Non-binding cap (>= n*m) is fine with any partition.
        let c = ExperimentConfig::fig1_logreg_base()
            .with_partition(PartitionKind::Dirichlet { alpha: 0.5 })
            .with_dataset_cap(10_000_000);
        c.validated().unwrap();
    }

    #[test]
    fn n_samples_honors_the_cap() {
        let base = ExperimentConfig::fig1_logreg_base();
        let full = base.n_nodes * base.per_node;
        assert_eq!(base.n_samples(), full);
        assert_eq!(base.clone().with_dataset_cap(100).n_samples(), 100);
        assert_eq!(base.clone().with_dataset_cap(full * 2).n_samples(), full);
    }

    #[test]
    fn zero_agg_shards_rejected() {
        let c = ExperimentConfig::fig1_logreg_base().with_agg_shards(0);
        assert!(c.validated().is_err());
    }

    #[test]
    fn pre_bidirectional_configs_parse_to_raw_downlink() {
        // A config JSON written before `down_codec` existed must land on
        // the historical raw-f32 broadcast; an explicit null is the same.
        let mut j = ExperimentConfig::fig1_logreg_base().to_json();
        if let Json::Obj(map) = &mut j {
            map.remove("down_codec");
        } else {
            panic!("config JSON must be an object");
        }
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.down_codec, None);
        assert_eq!(back, ExperimentConfig::fig1_logreg_base());
        let back =
            ExperimentConfig::from_json(&ExperimentConfig::fig1_logreg_base().to_json())
                .unwrap();
        assert_eq!(back.down_codec, None);
    }

    #[test]
    fn invalid_down_codec_rejected() {
        let base = || ExperimentConfig::fig1_logreg_base();
        // External downlink codecs are unbuildable on the client side.
        let c = base().with_down_codec(CodecSpec::External { id: 7 });
        assert!(c.validated().is_err());
        let c = base()
            .with_down_codec(CodecSpec::error_feedback(CodecSpec::External { id: 7 }));
        assert!(c.validated().is_err());
        // Parameter bounds apply to the downlink slot too.
        let c = base().with_down_codec(CodecSpec::top_k(0));
        assert!(c.validated().is_err());
        let nested = CodecSpec::error_feedback(CodecSpec::error_feedback(
            CodecSpec::qsgd(1),
        ));
        assert!(base().with_down_codec(nested).validated().is_err());
        // Every concrete built-in family is a legal downlink codec.
        for down in [
            CodecSpec::Identity,
            CodecSpec::qsgd(4),
            CodecSpec::top_k(100),
            CodecSpec::rand_k(100),
            CodecSpec::adaptive(4),
            CodecSpec::error_feedback(CodecSpec::top_k(100)),
        ] {
            base().with_down_codec(down).validated().unwrap();
        }
    }

    #[test]
    fn legacy_quantizer_key_still_parses() {
        // Pre-redesign config files tagged the codec under "quantizer".
        let mut j = ExperimentConfig::fig1_logreg_base().to_json();
        if let Json::Obj(map) = &mut j {
            let codec = map.remove("codec").unwrap();
            map.insert("quantizer".to_string(), codec);
        } else {
            panic!("config JSON must be an object");
        }
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.codec, CodecSpec::qsgd(1));
    }
}
