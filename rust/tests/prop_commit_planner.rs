//! Property tests for the event-driven commit core
//! (`coordinator::commit_loop::CommitPlanner`) driven **in isolation** —
//! no clock, no sockets, just random event interleavings over random
//! protocol knobs. The invariants under test are exactly the ones both
//! `AsyncSim` and `net::TcpAsync` rely on:
//!
//! * no `(node, version)` job is ever dispatched twice;
//! * every commit carries exactly `buffer_size` uploads (only the final
//!   `drain` may surface fewer);
//! * no committed upload exceeds `max_staleness`, and every stamp equals
//!   `commit version − origin version`;
//! * commit batches come back in canonical origin-version order with `r`
//!   jobs back in flight after the refill wave.

use fedpaq::coordinator::commit_loop::{CommitPlanner, Decision, PlannerEvent};
use fedpaq::coordinator::Upload;
use fedpaq::quant::{CodecSpec, Encoded, UpdateCodec};
use fedpaq::util::prop::check;
use fedpaq::util::rng::Rng;
use std::collections::HashSet;

fn enc(rng: &mut Rng) -> Encoded {
    let codec = CodecSpec::qsgd(1).build().unwrap();
    let x: Vec<f32> = (0..4).map(|_| rng.gen_f32() - 0.5).collect();
    codec.encode(&x, rng)
}

/// Sample `r` distinct nodes from `0..n` (order randomized).
fn sample(rng: &mut Rng, n: usize, r: usize) -> Vec<usize> {
    let mut all: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut all);
    all.truncate(r);
    all
}

/// Fold a decision batch into the test's book-keeping: track every
/// dispatch (asserting the no-duplicate invariant), check drops exceed
/// the cap, and hand back the committed uploads if one fired.
fn record(
    decisions: Vec<Decision>,
    max_staleness: usize,
    outstanding: &mut Vec<(usize, usize)>,
    dispatched: &mut HashSet<(usize, usize)>,
) -> Option<Vec<Upload>> {
    let mut committed = None;
    for d in decisions {
        match d {
            Decision::Dispatch { node, version, .. } => {
                assert!(
                    dispatched.insert((node, version)),
                    "duplicate (node={node}, version={version}) dispatch"
                );
                outstanding.push((node, version));
            }
            Decision::Drop { staleness, .. } => {
                assert!(
                    staleness > max_staleness,
                    "dropped an upload within the staleness cap"
                );
            }
            Decision::Commit { uploads, .. } => {
                assert!(committed.is_none(), "two commits in one decision batch");
                committed = Some(uploads);
            }
        }
    }
    committed
}

#[test]
fn prop_random_interleavings_uphold_the_commit_invariants() {
    check(120, 0xfed_cc1, |rng| {
        let n_nodes = rng.gen_range(2, 12);
        let r = rng.gen_range(1, n_nodes + 1);
        let buffer_size = rng.gen_range(1, r + 1);
        let max_staleness = rng.gen_range(0, 4);
        let seed = rng.next_u64();
        let mut planner =
            CommitPlanner::from_parts(seed, n_nodes, r, buffer_size, max_staleness)
                .unwrap();

        // Outstanding dispatched jobs the "transport" may deliver next,
        // and every (node, version) ever dispatched (the invariant set).
        let mut outstanding: Vec<(usize, usize)> = Vec::new();
        let mut dispatched: HashSet<(usize, usize)> = HashSet::new();
        let versions = rng.gen_range(2, 6);

        for k in 0..versions {
            assert_eq!(planner.version(), k);
            let sampled = sample(rng, n_nodes, r);
            let wave = planner.begin_version(&sampled).unwrap();
            let expected_wave = if k == 0 { r } else { buffer_size };
            assert_eq!(wave.len(), expected_wave, "refill wave size");
            assert!(record(wave, max_staleness, &mut outstanding, &mut dispatched)
                .is_none());
            assert_eq!(
                planner.in_flight() + planner.buffered(),
                r,
                "r jobs in flight after every refill"
            );

            // Deliver outstanding uploads in random order until commit.
            let committed = loop {
                assert!(!outstanding.is_empty(), "planner starved before commit");
                let i = rng.gen_range(0, outstanding.len());
                let (node, version) = outstanding.swap_remove(i);
                let decisions = planner
                    .on_event(PlannerEvent::UploadArrived { node, version, enc: enc(rng) })
                    .unwrap();
                if let Some(uploads) =
                    record(decisions, max_staleness, &mut outstanding, &mut dispatched)
                {
                    break uploads;
                }
            };

            // Full commits only, canonically ordered, staleness capped
            // and stamped against this commit's version.
            assert_eq!(committed.len(), buffer_size, "short commit");
            let mut prev_origin = 0;
            for u in &committed {
                assert!(u.staleness <= max_staleness, "staleness cap violated");
                assert_eq!(u.staleness, k - u.origin_round, "bad staleness stamp");
                assert!(u.origin_round >= prev_origin, "batch not in origin order");
                prev_origin = u.origin_round;
            }
        }

        // Final drain: deliver a few more arrivals without filling the
        // buffer, then drain — strictly fewer than buffer_size uploads
        // surface, all stamped against the current version, and the
        // buffer empties.
        let wave = planner.begin_version(&sample(rng, n_nodes, r)).unwrap();
        assert!(record(wave, max_staleness, &mut outstanding, &mut dispatched).is_none());
        let deliver = rng.gen_range(0, buffer_size);
        let mut fed = 0;
        while fed < deliver && !outstanding.is_empty() {
            let i = rng.gen_range(0, outstanding.len());
            let (node, version) = outstanding.swap_remove(i);
            let decisions = planner
                .on_event(PlannerEvent::UploadArrived { node, version, enc: enc(rng) })
                .unwrap();
            assert!(
                record(decisions, max_staleness, &mut outstanding, &mut dispatched)
                    .is_none(),
                "commit fired below buffer_size"
            );
            fed += 1;
        }
        let buffered = planner.buffered();
        assert!(buffered < planner.buffer_size());
        let drained = planner.drain();
        assert_eq!(drained.len(), buffered);
        assert_eq!(planner.buffered(), 0);
        for u in &drained {
            assert_eq!(u.staleness, planner.version() - u.origin_round);
        }
    });
}

#[test]
fn prop_duplicate_and_future_arrivals_are_rejected() {
    check(60, 0xfed_cc2, |rng| {
        let n_nodes = rng.gen_range(2, 10);
        let r = rng.gen_range(2, n_nodes + 1);
        let buffer_size = rng.gen_range(2, r + 1); // ≥ 2 so one arrival never commits
        let mut planner =
            CommitPlanner::from_parts(rng.next_u64(), n_nodes, r, buffer_size, 8).unwrap();
        let sampled: Vec<usize> = (0..n_nodes).collect();
        planner.begin_version(&sampled[..r]).unwrap();
        let node = sampled[rng.gen_range(0, r)];
        planner
            .on_event(PlannerEvent::UploadArrived { node, version: 0, enc: enc(rng) })
            .unwrap();
        // Same (node, version) again: the invariant must reject it.
        let err = planner
            .on_event(PlannerEvent::UploadArrived { node, version: 0, enc: enc(rng) })
            .unwrap_err();
        assert!(err.to_string().contains("invariant"), "{err}");
        // An upload claiming a future version is equally impossible.
        let err = planner
            .on_event(PlannerEvent::UploadArrived {
                node,
                version: planner.version() + 3,
                enc: enc(rng),
            })
            .unwrap_err();
        assert!(err.to_string().contains("unknown"), "{err}");
    });
}

#[test]
fn capacity_freed_retires_the_lost_job_and_redispatches() {
    // Deterministic check of the external CapacityFreed event: the lost
    // job leaves the in-flight set (so transport drain counts stay
    // truthful), exactly one replacement is dispatched at the current
    // version, and the replacement never duplicates a *live* job.
    let mut planner = CommitPlanner::from_parts(7, 6, 4, 2, 1).unwrap();
    planner.begin_version(&[0, 1, 2, 3]).unwrap();
    assert_eq!(planner.in_flight(), 4);
    let decisions = planner
        .on_event(PlannerEvent::CapacityFreed { node: 2, version: 0 })
        .unwrap();
    let picked = match &decisions[..] {
        [Decision::Dispatch { node, version: 0, .. }] => *node,
        other => panic!("unexpected {other:?}"),
    };
    // Nodes 0, 1, 3 still hold live version-0 jobs; only the retired
    // node 2 (its upload can never be counted) or an idle node is a
    // legal replacement.
    assert!(
        !matches!(picked, 0 | 1 | 3),
        "replacement duplicated live job (node {picked}, version 0)"
    );
    assert_eq!(planner.in_flight(), 4, "capacity stays constant");
    // Reporting a job that was never dispatched is an error, not a
    // silent extra dispatch.
    assert!(planner
        .on_event(PlannerEvent::CapacityFreed { node: 5, version: 3 })
        .is_err());
}
